#!/usr/bin/env python3
"""Perf regression gate: compare BENCH_hotpath.json against the checked-in
BENCH_baseline.json and fail CI when the hotpath regresses.

Runner-noise tolerance comes from two mechanisms:

1. *Machine calibration.* The hotpath bench times the frozen `legacy`
   seed kernels in the same run (their `speedup_vs_baseline` fields are
   engine-vs-legacy ratios measured back-to-back on the same machine).
   Where both files carry a speedup, the gate compares the *speedups* —
   a machine-independent quantity — instead of raw nanoseconds.
2. *Geometric-mean aggregation.* A single noisy entry cannot fail the
   gate; the whole hotpath must be >THRESHOLD slower in aggregate.

The committed BENCH_baseline.json holds conservative *speedup floors*
(engine-vs-legacy ratios, see its meta note), so the gate ENFORCES: a
change whose hotpath speedups drop more than 25% geomean below the
floors fails CI. Its dummy median_ns fields are never compared — every
baseline entry carries a speedup, so the machine-independent branch
always applies. Re-baseline deliberately (measure on the CI machine
class, raise the floors conservatively, commit) — never to paper over a
regression.

A baseline marked `"placeholder": "true"` in its meta (a bootstrap
check-in with no recorded run) reports instead of gating.

Usage: bench_gate.py BASELINE.json CURRENT.json
"""

import json
import math
import sys

THRESHOLD = 1.25  # >25% aggregate hotpath regression fails the gate


def load(path):
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for b in doc.get("benchmarks", []):
        if b.get("median_ns", 0) > 0:
            rows[b["name"]] = b
    return doc.get("meta", {}), rows


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    base_meta, base = load(sys.argv[1])
    _, cur = load(sys.argv[2])
    shared = sorted(set(base) & set(cur))
    placeholder = str(base_meta.get("placeholder", "")).lower() == "true"

    if not shared:
        if placeholder:
            print("bench gate: placeholder baseline with no shared entries; reporting only.")
            print("Refresh the baseline from a real run (see bench_gate.py docstring).")
            return 0
        print("bench gate: no shared benchmark names between baseline and current; failing.")
        return 1

    # Prefer speedup-vs-legacy ratios (machine-independent); fall back to
    # median_ns ratios for entries without a speedup field.
    ratios = []
    for name in shared:
        b, c = base[name], cur[name]
        if "speedup_vs_baseline" in b and "speedup_vs_baseline" in c:
            if c["speedup_vs_baseline"] > 0:
                # Regression ratio: how much slower (relative to the frozen
                # legacy kernels) the current engine is vs the baseline run.
                r = b["speedup_vs_baseline"] / c["speedup_vs_baseline"]
                kind = "speedup"
            else:
                continue
        else:
            r = c["median_ns"] / b["median_ns"]
            kind = "median"
        flag = "SLOW" if r > THRESHOLD else "ok"
        print(f"{name:<52} x{r:6.2f} ({kind})  [{flag}]")
        ratios.append(r)

    if not ratios:
        print("bench gate: no comparable entries; failing closed.")
        return 0 if placeholder else 1

    agg = geomean(ratios)
    print(f"aggregate hotpath regression: x{agg:.3f} (threshold x{THRESHOLD})")
    if agg > THRESHOLD:
        if placeholder:
            print("placeholder baseline: reporting only, not failing the build.")
            return 0
        print("FAIL: hotpath regressed beyond the tolerance.")
        return 1
    print("ok: hotpath within tolerance.")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env bash
# Smoke-run one (--algo, --dataset) pair through the multi-process TCP
# mode: `dad serve --sites 2` plus two `dad join`s on localhost, asserting
# that every process exits 0 and that the serve process wrote a non-empty
# per-epoch metrics CSV. `dad join` retries its dial for up to 10 s, so
# the three processes can be launched concurrently.
#
# Special cases enforced here:
#   * edad + lm must be REJECTED up front (`dad serve` exits non-zero
#     with a clear error before binding) — the transformer's attention
#     has no edAD delta recomputation.
#   * dgc:abc (a malformed sparse-density argument) must be rejected at
#     argument parsing on every dataset, before any socket binds.
#   * rank-dad:* runs must emit per-entry eff_rank_* CSV columns with
#     finite values (the adaptive-bandwidth telemetry).
#
# Usage (run from the repository root):
#   remote_smoke.sh <algo> [dataset]
#       serve + 2 joins as separate OS processes (above)
#   remote_smoke.sh recipe <name> <converge|degrade:<k>|fail>
#       run one named chaos recipe (`dad chaos --recipe`) over localhost
#       sockets; convergence recipes must exit 0 with a metrics CSV,
#       degrade:<k> recipes must additionally log k surviving sites in
#       the CSV's sites_live column, and fail recipes must exit non-zero
#       with an error message on stderr — never hang, never panic.
#   remote_smoke.sh strict <name>
#       the same recipe under --strict must exit non-zero with a clean
#       error naming the lost site instead of degrading.
#   remote_smoke.sh tree <algo>
#       2-level aggregation tree as separate OS processes: `dad serve
#       --topology tree:2 --sites 4` + two `dad relay`s + four `dad
#       join`s dialing the relays. Every process must exit 0 and the
#       serve CSV must report sites_live=4. For edad and dad-p2p the
#       serve must instead be REJECTED before binding (their exchange is
#       not an associative reduction), with an error naming the
#       algorithm and the tree topology.
set -euo pipefail

ALGO="${1:?usage: remote_smoke.sh <algo|recipe|strict> [args]}"
DATASET="${2:-mnist}"
BIN="${BIN:-rust/target/release/dad}"
PORT="${PORT:-7411}"

# `timeout` bounds every process: a protocol hang (the exact regression
# class this job exists to catch) becomes a fast red job, not a 6-hour
# runner stall.
LIMIT="${LIMIT:-300}"

# --- chaos recipe modes ----------------------------------------------------

if [ "$ALGO" = "recipe" ]; then
    NAME="${2:?usage: remote_smoke.sh recipe <name> <converge|degrade:<k>|fail>}"
    EXPECT="${3:-converge}"
    CSV="results/chaos_${NAME}.csv"
    rm -f "$CSV"
    err_log=$(mktemp)
    status=0
    timeout "$LIMIT" "$BIN" chaos --recipe "$NAME" --csv "$CSV" 2>"$err_log" || status=$?
    if [ "$EXPECT" = "fail" ]; then
        # Clean failure: exit code 1 (not a timeout's 124, not the
        # expectation-mismatch 3, never a panic's 101) plus a cause on
        # stderr, and no metrics.
        if [ "$status" -ne 1 ]; then
            echo "FAIL(recipe $NAME): expected clean-failure exit 1, got $status"
            cat "$err_log"
            exit 1
        fi
        grep -q "chaos run failed" "$err_log" || {
            echo "FAIL(recipe $NAME): no clean error message on stderr:"
            cat "$err_log"
            exit 1
        }
        if [ -s "$CSV" ]; then
            echo "FAIL(recipe $NAME): failing recipe must not write metrics"
            exit 1
        fi
        echo "ok(recipe $NAME): failed cleanly — $(grep 'chaos run failed' "$err_log" | head -1)"
        exit 0
    fi
    if [ "$status" -ne 0 ]; then
        echo "FAIL(recipe $NAME): expected exit 0, got $status"
        cat "$err_log"
        exit 1
    fi
    test -s "$CSV" || { echo "FAIL(recipe $NAME): metrics CSV missing or empty: $CSV"; exit 1; }
    case "$EXPECT" in
    degrade:*)
        want="${EXPECT#degrade:}"
        # sites_live is CSV field 9; the last epoch must report exactly
        # the expected survivor count.
        got=$(awk -F, 'END { print $9 }' "$CSV")
        if [ "$got" != "$want" ]; then
            echo "FAIL(recipe $NAME): expected $want surviving sites in the CSV, got '$got':"
            cat "$CSV"
            exit 1
        fi
        echo "ok(recipe $NAME): degraded to $got site(s), metrics in $CSV"
        ;;
    *)
        echo "ok(recipe $NAME): converged, metrics in $CSV"
        ;;
    esac
    exit 0
fi

if [ "$ALGO" = "strict" ]; then
    NAME="${2:?usage: remote_smoke.sh strict <name>}"
    err_log=$(mktemp)
    status=0
    timeout "$LIMIT" "$BIN" chaos --recipe "$NAME" --strict 2>"$err_log" || status=$?
    if [ "$status" -ne 1 ]; then
        echo "FAIL(strict $NAME): expected clean-failure exit 1, got $status"
        cat "$err_log"
        exit 1
    fi
    grep -q "lost site" "$err_log" || {
        echo "FAIL(strict $NAME): error does not name the lost site:"
        cat "$err_log"
        exit 1
    }
    grep -q "strict mode" "$err_log" || {
        echo "FAIL(strict $NAME): error does not say strict mode failed the run:"
        cat "$err_log"
        exit 1
    }
    echo "ok(strict $NAME): $(grep 'chaos run failed' "$err_log" | head -1)"
    exit 0
fi

# --- 2-level tree mode ------------------------------------------------------

if [ "$ALGO" = "tree" ]; then
    TREE_ALGO="${2:?usage: remote_smoke.sh tree <algo>}"
    CSV="results/tree_smoke_${TREE_ALGO//[:]/_}.csv"
    rm -f "$CSV"
    RELAY1_PORT=$((PORT + 1))
    RELAY2_PORT=$((PORT + 2))

    # Non-associative exchanges must be rejected on `dad serve`'s terminal
    # before any socket binds — no stranded relays, no stranded joins.
    case "$TREE_ALGO" in
    edad|dad-p2p)
        err_log=$(mktemp)
        if timeout "$LIMIT" "$BIN" serve --addr "127.0.0.1:${PORT}" --sites 4 \
            --topology tree:2 --algo "$TREE_ALGO" --dataset mnist --scale quick \
            --epochs 2 --batch 8 --seed 7 --csv "$CSV" 2>"$err_log"; then
            echo "FAIL(tree,$TREE_ALGO): serve must reject $TREE_ALGO on a tree topology"
            exit 1
        fi
        grep -q "$TREE_ALGO" "$err_log" || {
            echo "FAIL(tree,$TREE_ALGO): rejection error does not name the algorithm:"
            cat "$err_log"
            exit 1
        }
        grep -q "tree topology" "$err_log" || {
            echo "FAIL(tree,$TREE_ALGO): rejection error does not name the tree topology:"
            cat "$err_log"
            exit 1
        }
        if [ -s "$CSV" ]; then
            echo "FAIL(tree,$TREE_ALGO): rejected run must not write metrics"
            exit 1
        fi
        echo "ok(tree,$TREE_ALGO): rejected up front with a clear error"
        exit 0
        ;;
    esac

    pids=()
    cleanup_tree() {
        for pid in "${pids[@]}"; do
            kill "$pid" 2>/dev/null || true
        done
    }
    trap cleanup_tree EXIT

    # All seven processes launch concurrently: the relays retry their
    # parent dial and the joins retry their relay dial for up to 10 s.
    timeout "$LIMIT" "$BIN" serve --addr "127.0.0.1:${PORT}" --sites 4 --topology tree:2 \
        --algo "$TREE_ALGO" --dataset mnist --scale quick --epochs 2 --batch 8 --seed 7 \
        --csv "$CSV" &
    pids+=($!)
    timeout "$LIMIT" "$BIN" relay --parent "127.0.0.1:${PORT}" --sites 2 \
        --addr "127.0.0.1:${RELAY1_PORT}" &
    pids+=($!)
    timeout "$LIMIT" "$BIN" relay --parent "127.0.0.1:${PORT}" --sites 2 \
        --addr "127.0.0.1:${RELAY2_PORT}" &
    pids+=($!)
    for relay_port in "$RELAY1_PORT" "$RELAY1_PORT" "$RELAY2_PORT" "$RELAY2_PORT"; do
        timeout "$LIMIT" "$BIN" join "127.0.0.1:${relay_port}" &
        pids+=($!)
    done
    for pid in "${pids[@]}"; do
        wait "$pid"
    done

    test -s "$CSV" || { echo "FAIL(tree,$TREE_ALGO): metrics CSV missing or empty: $CSV"; exit 1; }
    rows=$(wc -l <"$CSV")
    if [ "$rows" -lt 3 ]; then
        echo "FAIL(tree,$TREE_ALGO): metrics CSV too short ($rows lines):"
        cat "$CSV"
        exit 1
    fi
    # The root must account for all 4 leaves, not its 2 relay links.
    live=$(awk -F, 'END { print $9 }' "$CSV")
    if [ "$live" != "4" ]; then
        echo "FAIL(tree,$TREE_ALGO): expected sites_live=4 at the root, got '$live':"
        cat "$CSV"
        exit 1
    fi
    echo "ok(tree,$TREE_ALGO): serve + 2 relays + 4 joins exited 0; $rows CSV lines in $CSV"
    exit 0
fi

# --- multi-process serve/join mode -----------------------------------------

CSV="results/remote_smoke_${ALGO//[:]/_}_${DATASET}.csv"

rm -f "$CSV"

# The one combination that must fail fast instead of training.
if [ "$ALGO" = "edad" ] && [ "$DATASET" = "lm" ]; then
    err_log=$(mktemp)
    if timeout "$LIMIT" "$BIN" serve --addr "127.0.0.1:${PORT}" --sites 2 --algo "$ALGO" \
        --dataset "$DATASET" --scale quick --epochs 2 --batch 8 --seed 7 --csv "$CSV" \
        2>"$err_log"; then
        echo "FAIL(edad,lm): serve must reject edad for the transformer LM"
        exit 1
    fi
    grep -qi "edad" "$err_log" || {
        echo "FAIL(edad,lm): rejection error does not mention edad:"
        cat "$err_log"
        exit 1
    }
    if [ -s "$CSV" ]; then
        echo "FAIL(edad,lm): rejected run must not write metrics"
        exit 1
    fi
    echo "ok(edad,$DATASET): rejected up front with a clear error"
    exit 0
fi

# Malformed algorithm arguments must fail fast at parsing — no bind, no
# training, no metrics — with an error naming the bad spelling.
if [ "$ALGO" = "dgc:abc" ]; then
    err_log=$(mktemp)
    if timeout "$LIMIT" "$BIN" serve --addr "127.0.0.1:${PORT}" --sites 2 --algo "$ALGO" \
        --dataset "$DATASET" --scale quick --epochs 2 --batch 8 --seed 7 --csv "$CSV" \
        2>"$err_log"; then
        echo "FAIL($ALGO,$DATASET): serve must reject a malformed dgc density"
        exit 1
    fi
    grep -qi "dgc" "$err_log" || {
        echo "FAIL($ALGO,$DATASET): rejection error does not mention dgc:"
        cat "$err_log"
        exit 1
    }
    if [ -s "$CSV" ]; then
        echo "FAIL($ALGO,$DATASET): rejected run must not write metrics"
        exit 1
    fi
    echo "ok($ALGO,$DATASET): malformed density rejected up front with a clear error"
    exit 0
fi

# Kill any survivors if one process fails: an orphaned blocking serve
# would otherwise hang the CI step until the job timeout.
serve_pid=""
join1_pid=""
join2_pid=""
cleanup() {
    for pid in "$serve_pid" "$join1_pid" "$join2_pid"; do
        [ -n "$pid" ] && kill "$pid" 2>/dev/null || true
    done
}
trap cleanup EXIT

timeout "$LIMIT" "$BIN" serve --addr "127.0.0.1:${PORT}" --sites 2 --algo "$ALGO" \
    --dataset "$DATASET" --scale quick --epochs 2 --batch 8 --seed 7 --csv "$CSV" &
serve_pid=$!
timeout "$LIMIT" "$BIN" join "127.0.0.1:${PORT}" &
join1_pid=$!
timeout "$LIMIT" "$BIN" join "127.0.0.1:${PORT}" &
join2_pid=$!

# `wait <pid>` propagates each process's exit status; set -e aborts on any
# non-zero status.
wait "$join1_pid"
wait "$join2_pid"
wait "$serve_pid"

# Non-empty metrics CSV: a header line plus one row per epoch.
test -s "$CSV" || { echo "FAIL($ALGO,$DATASET): metrics CSV missing or empty: $CSV"; exit 1; }
rows=$(wc -l <"$CSV")
if [ "$rows" -lt 3 ]; then
    echo "FAIL($ALGO,$DATASET): metrics CSV too short ($rows lines):"
    cat "$CSV"
    exit 1
fi

# rank-dAD telemetry: the per-entry eff_rank_* columns (after the 9 fixed
# columns, the last of which is sites_live) must exist and carry finite
# values — this is the adaptive-rank telemetry the transformer bandwidth
# analysis reads.
case "$ALGO" in
rank-dad*|rankdad*)
    awk -F, '
        NR == 1 {
            if ($0 !~ /eff_rank_/) { print "missing eff_rank_ columns"; exit 1 }
        }
        NR == 2 {
            if (NF < 10) { print "no rank columns in data row"; exit 1 }
            for (i = 10; i <= NF; i++)
                if ($i == "NaN") { print "rank column " i " is NaN"; exit 1 }
            exit 0
        }
    ' "$CSV" || { echo "FAIL($ALGO,$DATASET): eff_rank columns bad:"; head -2 "$CSV"; exit 1; }
    ;;
esac

echo "ok($ALGO,$DATASET): serve + 2 joins exited 0; $rows CSV lines in $CSV"

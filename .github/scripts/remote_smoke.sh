#!/usr/bin/env bash
# Smoke-run one --algo spelling through the multi-process TCP mode:
# `dad serve --sites 2` plus two `dad join`s on localhost, asserting that
# every process exits 0 and that the serve process wrote a non-empty
# per-epoch metrics CSV. `dad join` retries its dial for up to 10 s, so
# the three processes can be launched concurrently.
#
# Usage: remote_smoke.sh <algo>   (run from the repository root)
set -euo pipefail

ALGO="${1:?usage: remote_smoke.sh <algo>}"
BIN="${BIN:-rust/target/release/dad}"
PORT="${PORT:-7411}"
CSV="results/remote_smoke_${ALGO//[:]/_}.csv"

rm -f "$CSV"

# Kill any survivors if one process fails: an orphaned blocking serve
# would otherwise hang the CI step until the job timeout.
trap 'kill $serve_pid $join1_pid $join2_pid 2>/dev/null || true' EXIT

# `timeout` bounds every process: a protocol hang (the exact regression
# class this job exists to catch) becomes a fast red job, not a 6-hour
# runner stall.
LIMIT="${LIMIT:-300}"
timeout "$LIMIT" "$BIN" serve --addr "127.0.0.1:${PORT}" --sites 2 --algo "$ALGO" \
    --dataset mnist --scale quick --epochs 2 --batch 8 --seed 7 --csv "$CSV" &
serve_pid=$!
timeout "$LIMIT" "$BIN" join "127.0.0.1:${PORT}" &
join1_pid=$!
timeout "$LIMIT" "$BIN" join "127.0.0.1:${PORT}" &
join2_pid=$!

# `wait <pid>` propagates each process's exit status; set -e aborts on any
# non-zero status.
wait "$join1_pid"
wait "$join2_pid"
wait "$serve_pid"

# Non-empty metrics CSV: a header line plus one row per epoch.
test -s "$CSV" || { echo "FAIL($ALGO): metrics CSV missing or empty: $CSV"; exit 1; }
rows=$(wc -l <"$CSV")
if [ "$rows" -lt 3 ]; then
    echo "FAIL($ALGO): metrics CSV too short ($rows lines):"
    cat "$CSV"
    exit 1
fi
echo "ok($ALGO): serve + 2 joins exited 0; $rows CSV lines in $CSV"

#!/usr/bin/env bash
# Checkpoint + serving smoke, end-to-end from the shell the way a user
# would drive it:
#
#   1. `dad train --checkpoint` an uninterrupted 4-epoch run;
#   2. train 2 epochs, `--resume` to 4, and assert the resumed run lands
#      on the IDENTICAL final loss (string-equal CSV field) and writes a
#      byte-identical checkpoint file;
#   3. boot `dad infer --serve` on the checkpoint, drive it with the
#      `dad infer --bench` load generator (+ --shutdown), and gate on a
#      non-empty, well-formed BENCH_serving.json (p50/p99/qps).
#
# Usage (from the repository root): serve_smoke.sh
set -euo pipefail

BIN="${BIN:-rust/target/release/dad}"
PORT="${PORT:-7413}"
LIMIT="${LIMIT:-300}"
OUT="results"
mkdir -p "$OUT"

FULL_CSV="$OUT/serve_smoke_full.csv"
RES_CSV="$OUT/serve_smoke_resumed.csv"
FULL_CKPT="$OUT/serve_smoke_full.ckpt"
PART_CKPT="$OUT/serve_smoke_part.ckpt"
RES_CKPT="$OUT/serve_smoke_resumed.ckpt"
rm -f "$FULL_CSV" "$RES_CSV" "$FULL_CKPT" "$PART_CKPT" "$RES_CKPT" BENCH_serving.json

common=(--algo dad --dataset mnist --scale quick --batch 8 --seed 7)

# --- 1. the uninterrupted reference run ------------------------------------
timeout "$LIMIT" "$BIN" train "${common[@]}" --epochs 4 \
    --csv "$FULL_CSV" --checkpoint "$FULL_CKPT"

# --- 2. interrupt at epoch 2, resume to 4 ----------------------------------
timeout "$LIMIT" "$BIN" train "${common[@]}" --epochs 2 --checkpoint "$PART_CKPT"
timeout "$LIMIT" "$BIN" train "${common[@]}" --epochs 4 \
    --resume "$PART_CKPT" --csv "$RES_CSV" --checkpoint "$RES_CKPT"

test -s "$FULL_CSV" || { echo "FAIL: reference CSV missing or empty"; exit 1; }
test -s "$RES_CSV" || { echo "FAIL: resumed CSV missing or empty"; exit 1; }

# The final epoch's train_loss (CSV field 3) must match exactly — not
# within a tolerance: resume is bit-identical, so the printed decimals
# are too.
full_loss=$(awk -F, 'END { print $3 }' "$FULL_CSV")
res_loss=$(awk -F, 'END { print $3 }' "$RES_CSV")
if [ -z "$full_loss" ] || [ "$full_loss" != "$res_loss" ]; then
    echo "FAIL: resumed final loss '$res_loss' != uninterrupted '$full_loss'"
    echo "--- $FULL_CSV"; cat "$FULL_CSV"
    echo "--- $RES_CSV"; cat "$RES_CSV"
    exit 1
fi
cmp -s "$FULL_CKPT" "$RES_CKPT" || {
    echo "FAIL: resumed checkpoint differs from the uninterrupted one"
    exit 1
}
echo "ok(resume): final loss $res_loss reproduced, checkpoints byte-identical"

# --- 3. serve the checkpoint, benchmark it, shut it down -------------------
serve_pid=""
cleanup() { [ -n "$serve_pid" ] && kill "$serve_pid" 2>/dev/null || true; }
trap cleanup EXIT

timeout "$LIMIT" "$BIN" infer --serve "127.0.0.1:${PORT}" --checkpoint "$FULL_CKPT" &
serve_pid=$!

# The bench connects without retrying, so poll until the server is up
# (it binds after rebuilding the model from the checkpoint meta).
bench_ok=1
for _ in $(seq 1 40); do
    if timeout 60 "$BIN" infer --bench --addr "127.0.0.1:${PORT}" \
        --requests 64 --concurrency 4 --seed 13 \
        --json BENCH_serving.json --shutdown; then
        bench_ok=0
        break
    fi
    sleep 0.5
done
if [ "$bench_ok" -ne 0 ]; then
    echo "FAIL: bench never completed against the server"
    exit 1
fi

# --shutdown drains the server: it must exit 0 on its own.
wait "$serve_pid"
serve_pid=""

test -s BENCH_serving.json || { echo "FAIL: BENCH_serving.json missing or empty"; exit 1; }
for key in '"p50_ms"' '"p99_ms"' '"qps"' '"requests"'; do
    grep -q "$key" BENCH_serving.json || {
        echo "FAIL: BENCH_serving.json is missing $key:"
        cat BENCH_serving.json
        exit 1
    }
done
echo "ok(serving): $(cat BENCH_serving.json)"

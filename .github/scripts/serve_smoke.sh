#!/usr/bin/env bash
# Checkpoint + serving smoke, end-to-end from the shell the way a user
# would drive it:
#
#   1. `dad train --checkpoint` an uninterrupted 4-epoch run;
#   2. train 2 epochs, `--resume` to 4, and assert the resumed run lands
#      on the IDENTICAL final loss (string-equal CSV field) and writes a
#      byte-identical checkpoint file;
#   3. run `dad serve` + 2x `dad join` with `--metrics` and `--trace`,
#      polling /metrics live: every scrape must be well-formed Prometheus
#      text and the dad_step gauge must never go backwards; afterwards
#      the JSONL trace must be sealed and `dad trace summarize` must read
#      it (the trace is uploaded as a CI artifact);
#   4. boot `dad infer --serve` (also with `--metrics`/`--trace`) on the
#      checkpoint, assert /metrics answers while it serves, drive it with
#      the `dad infer --bench` load generator (+ --shutdown), and gate on
#      a non-empty, well-formed BENCH_serving.json (p50/p99/qps).
#
# Usage (from the repository root): serve_smoke.sh
set -euo pipefail

BIN="${BIN:-rust/target/release/dad}"
PORT="${PORT:-7413}"
LIMIT="${LIMIT:-300}"
OUT="results"
mkdir -p "$OUT"

# GET /metrics over bash's /dev/tcp (no curl dependency in the runner's
# PATH assumptions); prints the full HTTP response, fails if refused.
scrape() {
    local host="${1%:*}" port="${1##*:}"
    exec 3<>"/dev/tcp/${host}/${port}" || return 1
    printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3
    cat <&3
    exec 3<&- 3>&-
}

FULL_CSV="$OUT/serve_smoke_full.csv"
RES_CSV="$OUT/serve_smoke_resumed.csv"
FULL_CKPT="$OUT/serve_smoke_full.ckpt"
PART_CKPT="$OUT/serve_smoke_part.ckpt"
RES_CKPT="$OUT/serve_smoke_resumed.ckpt"
rm -f "$FULL_CSV" "$RES_CSV" "$FULL_CKPT" "$PART_CKPT" "$RES_CKPT" BENCH_serving.json

common=(--algo dad --dataset mnist --scale quick --batch 8 --seed 7)

# --- 1. the uninterrupted reference run ------------------------------------
timeout "$LIMIT" "$BIN" train "${common[@]}" --epochs 4 \
    --csv "$FULL_CSV" --checkpoint "$FULL_CKPT"

# --- 2. interrupt at epoch 2, resume to 4 ----------------------------------
timeout "$LIMIT" "$BIN" train "${common[@]}" --epochs 2 --checkpoint "$PART_CKPT"
timeout "$LIMIT" "$BIN" train "${common[@]}" --epochs 4 \
    --resume "$PART_CKPT" --csv "$RES_CSV" --checkpoint "$RES_CKPT"

test -s "$FULL_CSV" || { echo "FAIL: reference CSV missing or empty"; exit 1; }
test -s "$RES_CSV" || { echo "FAIL: resumed CSV missing or empty"; exit 1; }

# The final epoch's train_loss (CSV field 3) must match exactly — not
# within a tolerance: resume is bit-identical, so the printed decimals
# are too.
full_loss=$(awk -F, 'END { print $3 }' "$FULL_CSV")
res_loss=$(awk -F, 'END { print $3 }' "$RES_CSV")
if [ -z "$full_loss" ] || [ "$full_loss" != "$res_loss" ]; then
    echo "FAIL: resumed final loss '$res_loss' != uninterrupted '$full_loss'"
    echo "--- $FULL_CSV"; cat "$FULL_CSV"
    echo "--- $RES_CSV"; cat "$RES_CSV"
    exit 1
fi
cmp -s "$FULL_CKPT" "$RES_CKPT" || {
    echo "FAIL: resumed checkpoint differs from the uninterrupted one"
    exit 1
}
echo "ok(resume): final loss $res_loss reproduced, checkpoints byte-identical"

# --- 3. multi-process training with live /metrics + trace ------------------
SPORT=$((PORT + 1))
MPORT=$((PORT + 2))
TRACE="$OUT/serve_smoke_trace.jsonl"
rm -f "$TRACE"

serve_pid=""
join1_pid=""
join2_pid=""
cleanup() {
    for pid in "$serve_pid" "$join1_pid" "$join2_pid"; do
        [ -n "$pid" ] && kill "$pid" 2>/dev/null || true
    done
}
trap cleanup EXIT

timeout "$LIMIT" "$BIN" serve "${common[@]}" --epochs 3 --sites 2 \
    --addr "127.0.0.1:${SPORT}" \
    --metrics "127.0.0.1:${MPORT}" --trace "$TRACE" &
serve_pid=$!
timeout "$LIMIT" "$BIN" join "127.0.0.1:${SPORT}" &
join1_pid=$!
timeout "$LIMIT" "$BIN" join "127.0.0.1:${SPORT}" &
join2_pid=$!

# Poll /metrics while the run is live: every response must be well-formed
# Prometheus text, and the step gauge must be monotone non-decreasing.
samples=0
prev=-1
for _ in $(seq 1 600); do
    if ! body=$(scrape "127.0.0.1:${MPORT}" 2>/dev/null); then
        # Not up yet, or the run (and its endpoint) already finished.
        if [ "$samples" -gt 0 ]; then break; fi
        kill -0 "$serve_pid" 2>/dev/null || break
        sleep 0.1
        continue
    fi
    echo "$body" | grep -q '^# TYPE dad_step gauge' || {
        echo "FAIL: /metrics response is not well-formed:"; echo "$body"; exit 1
    }
    echo "$body" | grep -q '^# TYPE dad_step_latency_seconds histogram' || {
        echo "FAIL: /metrics is missing the latency histogram:"; echo "$body"; exit 1
    }
    step=$(echo "$body" | awk '$1 == "dad_step" { print $2 }')
    [ -n "$step" ] || { echo "FAIL: no dad_step sample in response"; exit 1; }
    if [ "$step" -lt "$prev" ]; then
        echo "FAIL: dad_step went backwards: $prev -> $step"; exit 1
    fi
    prev=$step
    samples=$((samples + 1))
    sleep 0.1
done

wait "$serve_pid"; serve_pid=""
wait "$join1_pid"; join1_pid=""
wait "$join2_pid"; join2_pid=""

[ "$samples" -ge 1 ] || { echo "FAIL: never scraped a live /metrics sample"; exit 1; }
[ "$prev" -ge 1 ] || { echo "FAIL: dad_step never advanced (last sample: $prev)"; exit 1; }
echo "ok(metrics): $samples scrapes, dad_step monotone to $prev"

# The trace must be sealed (footer present) and readable by the CLI.
test -s "$TRACE" || { echo "FAIL: trace $TRACE missing or empty"; exit 1; }
grep -q '"name":"_meta"' "$TRACE" || { echo "FAIL: trace has no _meta footer"; exit 1; }
grep -q '"dur_ns"' "$TRACE" || { echo "FAIL: trace recorded no spans"; exit 1; }
grep -q '"name":"adam"' "$TRACE" || { echo "FAIL: aggregator optimizer span missing"; exit 1; }
summary=$("$BIN" trace summarize "$TRACE")
[ -n "$summary" ] || { echo "FAIL: trace summarize printed nothing"; exit 1; }
echo "ok(trace): $(wc -l < "$TRACE") spans in $TRACE"
echo "$summary"

# --- 4. serve the checkpoint, benchmark it, shut it down -------------------
IMPORT=$((PORT + 3))
ITRACE="$OUT/serve_smoke_infer_trace.jsonl"
rm -f "$ITRACE"

timeout "$LIMIT" "$BIN" infer --serve "127.0.0.1:${PORT}" --checkpoint "$FULL_CKPT" \
    --metrics "127.0.0.1:${IMPORT}" --trace "$ITRACE" &
serve_pid=$!

# The inference server's endpoint must answer (well-formed, batcher gauge
# present) while it serves.
infer_metrics_ok=1
for _ in $(seq 1 100); do
    if body=$(scrape "127.0.0.1:${IMPORT}" 2>/dev/null); then
        echo "$body" | grep -q '^# TYPE dad_batcher_queue_depth gauge' || {
            echo "FAIL: infer /metrics is missing the batcher gauge:"; echo "$body"; exit 1
        }
        infer_metrics_ok=0
        break
    fi
    sleep 0.2
done
[ "$infer_metrics_ok" -eq 0 ] || { echo "FAIL: infer /metrics never answered"; exit 1; }
echo "ok(infer-metrics): endpoint live under dad infer --serve"

# The bench connects without retrying, so poll until the server is up
# (it binds after rebuilding the model from the checkpoint meta).
bench_ok=1
for _ in $(seq 1 40); do
    if timeout 60 "$BIN" infer --bench --addr "127.0.0.1:${PORT}" \
        --requests 64 --concurrency 4 --seed 13 \
        --json BENCH_serving.json --shutdown; then
        bench_ok=0
        break
    fi
    sleep 0.5
done
if [ "$bench_ok" -ne 0 ]; then
    echo "FAIL: bench never completed against the server"
    exit 1
fi

# --shutdown drains the server: it must exit 0 on its own.
wait "$serve_pid"
serve_pid=""

# The inference trace is sealed on exit and carries the forward-pass
# kernels the batcher ran.
test -s "$ITRACE" || { echo "FAIL: infer trace $ITRACE missing or empty"; exit 1; }
grep -q '"name":"_meta"' "$ITRACE" || { echo "FAIL: infer trace has no _meta footer"; exit 1; }
grep -q '"name":"gemm-' "$ITRACE" || { echo "FAIL: infer trace has no forward-pass spans"; exit 1; }

test -s BENCH_serving.json || { echo "FAIL: BENCH_serving.json missing or empty"; exit 1; }
for key in '"p50_ms"' '"p99_ms"' '"qps"' '"requests"'; do
    grep -q "$key" BENCH_serving.json || {
        echo "FAIL: BENCH_serving.json is missing $key:"
        cat BENCH_serving.json
        exit 1
    }
done
echo "ok(serving): $(cat BENCH_serving.json)"

//! Bandwidth report: regenerates the paper's Θ-bound claims (sections
//! 3.2-3.4) as a measured table — per-algorithm site→aggregator bytes for
//! one synchronized step, swept over layer width and batch size — plus the
//! simulated wire time under LAN and federated-WAN cost models.
//!
//! Run: cargo run --release --example bandwidth_report

use dad::coordinator::experiments::bandwidth_table;
use dad::dist::CostModel;

fn main() {
    println!("== bandwidth report: measured vs paper Θ bounds ==\n");
    println!("2 sites, batch 32/site, MLP 64-h-h-10, one synchronized step.\n");
    let rows = bandwidth_table(&[256, 512, 1024, 2048, 4096], 32);
    println!(
        "{:<14} {:>6} {:>14} {:>14} {:>7}",
        "algo", "h", "measured B", "theta B", "ratio"
    );
    let mut by_h: std::collections::BTreeMap<usize, Vec<(String, u64)>> = Default::default();
    for r in &rows {
        println!(
            "{:<14} {:>6} {:>14} {:>14} {:>7.2}",
            r.algo,
            r.h,
            r.measured_up,
            r.theta_up,
            r.measured_up as f64 / r.theta_up.max(1) as f64
        );
        by_h.entry(r.h).or_default().push((r.algo.clone(), r.measured_up));
    }

    println!("\nreduction vs dSGD (site->agg):");
    for (h, algos) in &by_h {
        let dsgd = algos.iter().find(|(n, _)| n == "dsgd").map(|&(_, b)| b).unwrap_or(1);
        let fmt: Vec<String> = algos
            .iter()
            .filter(|(n, _)| n != "dsgd")
            .map(|(n, b)| format!("{n} {:.1}x", dsgd as f64 / *b as f64))
            .collect();
        println!("  h={h:<6} {}", fmt.join("   "));
    }

    println!("\nwire time for one step's uplink (per site), LAN vs federated WAN:");
    let lan = CostModel::lan_10gbe();
    let wan = CostModel::wan_federated();
    for (h, algos) in &by_h {
        println!("  h={h}:");
        for (name, bytes) in algos {
            let per_site = bytes / 2;
            println!(
                "    {:<14} LAN {:>9.3} ms   WAN {:>9.1} ms",
                name,
                lan.time_for(per_site, 1) * 1e3,
                wan.time_for(per_site, 1) * 1e3
            );
        }
    }
    println!("\n(series written to results/bandwidth.csv)");
}

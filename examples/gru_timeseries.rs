//! Sequence-model scenario (the paper's section 4.1.2 / 4.2): a GRU
//! classifier on a SpokenArabicDigits-analog, trained distributed with
//! dAD, edAD and rank-dAD — demonstrating section 3.5's batch-and-time
//! stacking of the AD statistics and the effective-rank telemetry on
//! recurrent weights.
//!
//! Run: cargo run --release --example gru_timeseries [-- --epochs N]

use dad::algos::AlgoSpec;
use dad::coordinator::{train, Schedule, TrainSpec};
use dad::config::Args;
use dad::data::{arabic_digits_like, split_by_label};
use dad::nn::GruClassifier;
use dad::tensor::Rng;

fn main() {
    let args = Args::from_env();
    let epochs = args.usize_or("epochs", 6);
    let n = args.usize_or("n", 400);

    println!("== gru_timeseries: GRU(64) + FC 512-256 on arabic-digits-analog ==");
    let mut rng = Rng::new(31);
    let full = arabic_digits_like(n + n / 4, &mut rng);
    let train_ds = full.subset(&(0..n).collect::<Vec<_>>());
    let test_ds = full.subset(&(n..n + n / 4).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    println!(
        "T={} channels={} classes={}; 2 sites, disjoint labels",
        full.seq_len, full.channels, full.classes
    );

    for algo in [
        AlgoSpec::Dad,
        AlgoSpec::Edad,
        AlgoSpec::RankDad { max_rank: 8, n_iters: 10, theta: 1e-3 },
    ] {
        let spec = TrainSpec {
            algo: algo.clone(),
            n_sites: 2,
            batch_per_site: 16,
            epochs,
            lr: 1e-3,
            seed: 5,
            schedule: Schedule::EveryBatch,
        };
        let mut mrng = Rng::new(42);
        let model = GruClassifier::new(full.channels, 64, &[512, 256], full.classes, &mut mrng);
        let t0 = std::time::Instant::now();
        let log = train(model, &spec, &train_ds, &shards, &test_ds);
        let last = log.epochs.last().unwrap();
        print!(
            "{:<12} final AUC {:.4}  acc {:.4}  total {:>12} bytes  ({:.1}s)",
            log.algo,
            last.test_auc,
            last.test_acc,
            log.total_bytes(),
            t0.elapsed().as_secs_f32()
        );
        if last.mean_eff_rank.iter().any(|r| r.is_finite()) {
            let pretty: Vec<String> = log
                .entry_names
                .iter()
                .zip(&last.mean_eff_rank)
                .map(|(n, r)| format!("{n}:{r:.1}"))
                .collect();
            print!("  eff-ranks [{}]", pretty.join(", "));
        }
        println!();
    }
    println!("done. (dAD == edAD trajectories; edAD ships fewer bytes; rank-dAD fewest)");
}

//! The paper's Figure-1 scenario as a standalone application: the exact
//! MNIST architecture (784-1024-1024-10, ReLU) trained on a 2-site cluster
//! where each site only ever sees half of the classes — with the per-site
//! statistics computed on the **PJRT backend** (the AOT-compiled JAX+Pallas
//! artifact) when available, proving the three-layer stack composes on the
//! real hot path.
//!
//! Run: cargo run --release --example mnist_split [-- --epochs N --steps K]

use dad::config::Args;
use dad::data::{mnist_like, split_by_label, BatchIter};
use dad::metrics::multiclass_auc;
use dad::nn::model::DistModel;
use dad::nn::stats::{assemble_grads, concat_stats};
use dad::nn::{Adam, Mlp};
use dad::runtime::{MlpBackend, NativeMlpBackend, PjrtMlpBackend};
use dad::tensor::{Matrix, Rng};

fn main() {
    let args = Args::from_env();
    let epochs = args.usize_or("epochs", 2);
    let max_steps = args.usize_or("steps", usize::MAX);
    let n_train = args.usize_or("train-n", 2000);
    let n_test = args.usize_or("test-n", 400);

    println!("== mnist_split: paper architecture, PJRT-backed dAD ==");
    let mut rng = Rng::new(11);
    let full = mnist_like(n_train + n_test, &mut rng);
    let train_ds = full.subset(&(0..n_train).collect::<Vec<_>>());
    let test_ds = full.subset(&(n_train..n_train + n_test).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);

    let mut mrng = Rng::new(42);
    let mut model = Mlp::paper_mnist(&mut mrng);
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::paper(&shapes);

    // Backend selection: --backend native|pjrt (default: compiled artifact
    // if present, else native).
    let mut backend: Box<dyn MlpBackend> = match args.opt("backend") {
        Some("native") => {
            println!("backend: native (forced)");
            Box::new(NativeMlpBackend)
        }
        _ => match PjrtMlpBackend::from_default_artifacts() {
            Ok(b) => {
                println!("backend: PJRT (artifacts/mlp_stats.hlo.txt — JAX+Pallas AOT)");
                Box::new(b)
            }
            Err(e) => {
                println!("backend: native ({e:#})");
                Box::new(NativeMlpBackend)
            }
        },
    };

    let batch = 32; // the artifact's traced per-site batch
    let mut rng_b = Rng::new(23);
    for epoch in 0..epochs {
        let mut iters: Vec<BatchIter> = shards
            .iter()
            .map(|s| BatchIter::new(s.len(), batch, &mut rng_b))
            .collect();
        let n_steps = iters.iter().map(|i| i.n_batches()).min().unwrap().min(max_steps);
        let mut loss_sum = 0.0;
        let mut bytes = 0u64;
        let t0 = std::time::Instant::now();
        for _ in 0..n_steps {
            // Each site computes its stats (the dAD payload) on the backend.
            let mut site_stats = Vec::with_capacity(2);
            for (it, shard) in iters.iter_mut().zip(&shards) {
                let local = it.next().unwrap();
                let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
                let b = train_ds.batch(&idx);
                let stats = backend.local_stats(&model, &b).expect("stats");
                loss_sum += stats.loss as f64 / 2.0;
                bytes += stats.entries.iter().map(|e| e.wire_bytes()).sum::<u64>();
                site_stats.push(stats);
            }
            // Aggregate (vertcat) and reconstruct the exact global gradient.
            let refs: Vec<&[dad::nn::StatsEntry]> =
                site_stats.iter().map(|s| &s.entries[..]).collect();
            let cat = concat_stats(&refs);
            let grads = assemble_grads(&shapes, &cat, &[], 1.0 / (2.0 * batch as f32), 1.0);
            opt.step(&mut params, &grads);
            model.set_params(&params);
        }
        // Evaluate.
        let scores = eval_scores(&model, &test_ds);
        let auc = multiclass_auc(&scores, &test_ds.labels);
        println!(
            "epoch {epoch}: mean loss {:.4}  test AUC {:.4}  stats bytes {}  ({:.1}s, {} steps)",
            loss_sum / n_steps as f64,
            auc,
            bytes,
            t0.elapsed().as_secs_f32(),
            n_steps
        );
    }
    println!("done.");
}

fn eval_scores(model: &Mlp, ds: &dad::data::DenseDataset) -> Matrix {
    let mut parts = Vec::new();
    let mut lo = 0;
    while lo < ds.len() {
        let hi = (lo + 256).min(ds.len());
        let idx: Vec<usize> = (lo..hi).collect();
        parts.push(model.predict(&ds.batch(&idx)));
        lo = hi;
    }
    let refs: Vec<&Matrix> = parts.iter().collect();
    Matrix::vertcat(&refs)
}

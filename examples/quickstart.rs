//! Quickstart: the smallest end-to-end tour of the library.
//!
//! 1. Build a 2-site simulated cluster over a synthetic MNIST-analog with
//!    labels split so no class appears on both sites (the paper's hard
//!    non-IID case).
//! 2. Train the same model with dSGD and with dAD — watch the gradients
//!    agree while dAD ships far fewer bytes.
//! 3. Factor one gradient with structured power iterations (rank-dAD) and
//!    read off its effective rank.
//! 4. If `make artifacts` has run, execute the AOT-compiled JAX/Pallas
//!    smoke artifact through PJRT.
//!
//! Run: cargo run --release --example quickstart

use dad::algos::common::DistAlgorithm;
use dad::algos::{Dad, Dsgd};
use dad::coordinator::{train, Schedule, TrainSpec};
use dad::data::{mnist_like, split_by_label};
use dad::dist::Cluster;
use dad::lowrank::rankdad_factors;
use dad::nn::model::DistModel;
use dad::nn::{Activation, Mlp};
use dad::tensor::Rng;

fn main() {
    println!("== dad quickstart ==\n");

    // --- data: synthetic MNIST-analog, labels split across 2 sites ---
    let mut rng = Rng::new(7);
    let full = mnist_like(1040, &mut rng);
    let train_ds = full.subset(&(0..800).collect::<Vec<_>>());
    let test_ds = full.subset(&(800..1040).collect::<Vec<_>>());
    let shards = split_by_label(&train_ds.labels, 10, 2);
    println!(
        "2 sites, non-IID split: site0 has even classes ({} ex), site1 odd ({} ex)",
        shards[0].len(),
        shards[1].len()
    );

    // --- one synchronized step: dAD == dSGD, cheaper on the wire ---
    let mut mrng = Rng::new(42);
    let model = Mlp::new(&[784, 256, 10], &[Activation::Relu], &mut mrng);
    let batches = vec![train_ds.batch(&shards[0][..32]), train_ds.batch(&shards[1][..32])];
    let mut c1 = Cluster::replicate(model.clone(), 2);
    let out_dsgd = Dsgd.step(&mut c1, &batches);
    let mut c2 = Cluster::replicate(model.clone(), 2);
    let out_dad = Dad.step(&mut c2, &batches);
    let max_diff = out_dsgd
        .grads
        .iter()
        .zip(&out_dad.grads)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0f32, f32::max);
    println!("\none step, same global gradient:");
    println!("  max |grad_dSGD - grad_dAD| = {max_diff:.3e}  (f32 noise)");
    println!("  bytes up: dSGD {} vs dAD {}", out_dsgd.bytes_up, out_dad.bytes_up);

    // --- rank-dAD: factor the gradient without materializing it ---
    let stats = model.local_stats(&batches[0]);
    let e = &stats.entries[0]; // 784 x 256 layer
    let f = rankdad_factors(&e.a, &e.d, 10, 10, 1e-3);
    println!(
        "\nstructured power iterations on the {}x{} layer: effective rank {} (max 10, batch 32)",
        e.a.cols(),
        e.d.cols(),
        f.eff_rank
    );
    println!(
        "  bytes: full grad {} vs rank-dAD factors {}",
        e.a.cols() * e.d.cols() * 4,
        f.wire_bytes()
    );

    // --- short training run ---
    println!("\ntraining 3 epochs with dAD (batch 32/site, Adam 1e-3)...");
    let spec = TrainSpec {
        algo: dad::algos::AlgoSpec::Dad,
        n_sites: 2,
        batch_per_site: 32,
        epochs: 3,
        lr: 1e-3,
        seed: 5,
        schedule: Schedule::EveryBatch,
    };
    let mut mrng = Rng::new(42);
    let model = Mlp::new(&[784, 256, 10], &[Activation::Relu], &mut mrng);
    let log = train(model, &spec, &train_ds, &shards, &test_ds);
    for e in &log.epochs {
        println!(
            "  epoch {}  loss {:.4}  test AUC {:.4}  up {} B  down {} B",
            e.epoch, e.train_loss, e.test_auc, e.bytes_up, e.bytes_down
        );
    }

    // --- PJRT: run the AOT JAX artifact if present ---
    let dir = dad::runtime::PjrtRuntime::default_dir();
    if dir.join("smoke.hlo.txt").is_file() {
        let mut rt = dad::runtime::PjrtRuntime::cpu(&dir).expect("pjrt client");
        let x = dad::runtime::pjrt::PjrtInput { dims: vec![2, 2], data: vec![1., 2., 3., 4.] };
        let y = dad::runtime::pjrt::PjrtInput { dims: vec![2, 2], data: vec![1., 1., 1., 1.] };
        let out = rt.execute("smoke", &[x, y]).expect("smoke exec");
        println!(
            "\nPJRT ({}) smoke artifact: matmul+2 -> {:?}  [expect 5,5,9,9]",
            rt.platform(),
            out[0].data
        );
    } else {
        println!("\n(artifacts not built; run `make artifacts` to enable the PJRT path)");
    }
    println!("\nquickstart done.");
}

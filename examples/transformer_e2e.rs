//! END-TO-END DRIVER: train a ~12.8M-parameter decoder-only transformer LM
//! with distributed auto-differentiation on a 2-site cluster for a few
//! hundred steps over a synthetic token corpus, logging the loss curve and
//! communication ledger (results/e2e_loss.csv + EXPERIMENTS.md §E2E).
//!
//! This exercises every layer of the system at once: the from-scratch
//! tensor/NN stack (attention fwd+bwd), the AD-statistics interface on a
//! non-trivial architecture (20 stats entries + direct grads for
//! embeddings/LayerNorms), the dAD exchange with exact byte accounting,
//! Adam, and the data pipeline.
//!
//! A 100M-parameter model at a few hundred steps is ~2 TFLOP/step — days on
//! this CPU-only testbed's native engine — so the driver defaults to the
//! 12.8M configuration (same depth-to-width regime, documented in
//! EXPERIMENTS.md); pass --big for the full 100M shape if you have the
//! patience.
//!
//! Run: cargo run --release --example transformer_e2e [-- --steps 300]

use dad::algos::common::DistAlgorithm;
use dad::algos::{Dad, Dsgd};
use dad::config::Args;
use dad::data::token_corpus;
use dad::metrics::CsvWriter;
use dad::nn::model::{Batch, DistModel};
use dad::nn::transformer::{Transformer, TransformerConfig};
use dad::nn::Adam;
use dad::dist::Cluster;
use dad::tensor::{Matrix, Rng};

fn main() {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 250);
    let b_per_site = args.usize_or("batch", 2);
    let log_every = args.usize_or("log-every", 10);
    let cfg = if args.has_flag("big") {
        TransformerConfig { vocab: 32_000, d_model: 768, n_heads: 12, n_layers: 12, d_ff: 3072, max_t: 128 }
    } else {
        TransformerConfig::e2e()
    };
    let t_len = cfg.max_t;

    println!("== transformer_e2e: decoder-only LM trained with dAD ==");
    println!(
        "config: vocab {} d_model {} heads {} layers {} d_ff {} T {}  => {:.1}M params",
        cfg.vocab,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_layers,
        cfg.d_ff,
        t_len,
        cfg.n_params() as f64 / 1e6
    );

    // Synthetic corpus with learnable structure; one disjoint shard per site.
    let mut rng = Rng::new(17);
    let corpus: Vec<Vec<u32>> = (0..2)
        .map(|_| token_corpus(400_000, cfg.vocab, &mut rng))
        .collect();

    let mut mrng = Rng::new(42);
    let model = Transformer::new(cfg.clone(), &mut mrng);
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut cluster = Cluster::replicate(model, 2);
    let mut algo = Dad;
    let mut opt = Adam::new(3e-4, &shapes);
    let mut csv = CsvWriter::create(
        "results/e2e_loss.csv",
        &["step", "loss", "bytes_up", "bytes_down", "wall_s"],
    )
    .unwrap();

    let mut rng_b = Rng::new(5);
    let t_start = std::time::Instant::now();
    let mut loss_first = None;
    let mut loss_last = 0.0f32;
    for step in 0..steps {
        // Sample site batches from their private shards.
        let batches: Vec<Batch> = corpus
            .iter()
            .map(|shard| {
                let mut ids = Vec::with_capacity(b_per_site * t_len);
                let mut targets = Vec::with_capacity(b_per_site * t_len);
                for _ in 0..b_per_site {
                    let start = rng_b.below(shard.len() - t_len - 1);
                    ids.extend_from_slice(&shard[start..start + t_len]);
                    targets.extend_from_slice(&shard[start + 1..start + t_len + 1]);
                }
                Batch::Tokens { b: b_per_site, t: t_len, ids, targets }
            })
            .collect();
        let out = algo.step(&mut cluster, &batches);
        opt.step(&mut params, &out.grads);
        for site in &mut cluster.sites {
            site.model.set_params(&params);
        }
        loss_first.get_or_insert(out.loss);
        loss_last = out.loss;
        if step % log_every == 0 || step + 1 == steps {
            let wall = t_start.elapsed().as_secs_f32();
            println!(
                "step {step:>4}  loss {:.4}  up {:>10} B  down {:>10} B  ({:.1}s, {:.2}s/step)",
                out.loss,
                out.bytes_up,
                out.bytes_down,
                wall,
                wall / (step + 1) as f32
            );
            csv.row_f32(&[step as f32, out.loss, out.bytes_up as f32, out.bytes_down as f32, wall])
                .unwrap();
        }
    }
    csv.flush().unwrap();

    // One dSGD step for the bandwidth comparison headline.
    let batches: Vec<Batch> = corpus
        .iter()
        .map(|shard| {
            let mut ids = Vec::with_capacity(b_per_site * t_len);
            let mut targets = Vec::with_capacity(b_per_site * t_len);
            for _ in 0..b_per_site {
                let start = rng_b.below(shard.len() - t_len - 1);
                ids.extend_from_slice(&shard[start..start + t_len]);
                targets.extend_from_slice(&shard[start + 1..start + t_len + 1]);
            }
            Batch::Tokens { b: b_per_site, t: t_len, ids, targets }
        })
        .collect();
    let dsgd_out = Dsgd.step(&mut cluster, &batches);
    println!(
        "\nloss: {:.4} -> {:.4} over {} steps ({} tokens/step global)",
        loss_first.unwrap_or(0.0),
        loss_last,
        steps,
        2 * b_per_site * t_len
    );
    let dad_bytes = {
        let mut c2 = Cluster::replicate(cluster.sites[0].model.clone(), 2);
        Dad.step(&mut c2, &batches).bytes_up
    };
    println!(
        "bytes/step up: dSGD {} vs dAD {}  ({:.2}x reduction; N*T={} vs h<= {})",
        dsgd_out.bytes_up,
        dad_bytes,
        dsgd_out.bytes_up as f64 / dad_bytes.max(1) as f64,
        b_per_site * t_len,
        cfg.d_ff,
    );
    println!("loss curve written to results/e2e_loss.csv");
    assert!(
        loss_last < loss_first.unwrap_or(f32::MAX),
        "loss did not decrease — e2e training failed"
    );
}

//! Transformer LM demo — a thin driver over the first-class `lm` task.
//!
//! The transformer is no longer reachable only through this example: it is
//! a first-class `--dataset lm` workload, so the full pipeline runs from
//! the CLI in both execution modes:
//!
//! ```text
//! dad train --dataset lm --algo dad   --scale quick|default|paper
//! dad serve --dataset lm --algo dad --sites 2   (+ 2x `dad join ADDR`)
//! dad exp lm --scale quick            # dSGD/dAD/rank-dAD/PowerSGD sweep
//! ```
//!
//! This example keeps the old headline — dAD vs dSGD bytes/step on the
//! transformer — as a two-run comparison through the same `build_task` /
//! `train` path the CLI uses (`--scale default` is the ~12.8M-parameter
//! e2e configuration; see EXPERIMENTS.md §LM for the crossover math).
//!
//! Run: cargo run --release --example transformer_e2e [-- --scale quick]

use dad::algos::AlgoSpec;
use dad::config::Args;
use dad::coordinator::{build_task, default_lm_lr, train, Scale, TrainSpec, TrainTask};

fn main() {
    let args = Args::from_env();
    let scale = Scale::parse(args.opt_or("scale", "quick")).unwrap_or(Scale::Quick);
    let epochs = args.usize_or("epochs", 2);
    let batch = args.usize_or("batch", 8);
    let seed = args.usize_or("seed", 17) as u64;

    println!("== transformer_e2e: the `--dataset lm` workload, dAD vs dSGD ==");
    let mut summary: Vec<(String, f32, f32, u64)> = Vec::new();
    for algo in [AlgoSpec::Dad, AlgoSpec::Dsgd] {
        let (train_ds, test_ds, shards, model) = match build_task("lm", scale, 2, seed) {
            Ok(TrainTask::Tokens { train_ds, test_ds, shards, model }) => {
                (train_ds, test_ds, shards, model)
            }
            Ok(_) => unreachable!("lm builds a token task"),
            Err(e) => panic!("{e}"),
        };
        let spec = TrainSpec {
            algo: algo.clone(),
            n_sites: 2,
            batch_per_site: batch,
            epochs,
            lr: default_lm_lr(scale),
            seed,
            ..Default::default()
        };
        println!("-- {} --", algo.name());
        let log = train(model, &spec, &train_ds, &shards, &test_ds);
        for e in &log.epochs {
            println!(
                "epoch {:>2}  loss {:.4}  ppl {:.3}  up {:>12} B  down {:>12} B",
                e.epoch, e.train_loss, e.test_ppl, e.bytes_up, e.bytes_down
            );
        }
        let last = log.epochs.last().expect("at least one epoch");
        summary.push((algo.name(), last.train_loss, last.test_ppl, log.total_bytes()));
    }
    let (dad_bytes, dsgd_bytes) = (summary[0].3, summary[1].3);
    println!("\n{:<8} {:>10} {:>10} {:>14}", "algo", "loss", "ppl", "total bytes");
    for (name, loss, ppl, bytes) in &summary {
        println!("{name:<8} {loss:>10.4} {ppl:>10.3} {bytes:>14}");
    }
    println!(
        "dAD ships {:.2}x {} bytes than dSGD at this batch (crossover at B*T ~ mean layer \
         width; see EXPERIMENTS.md)",
        if dad_bytes <= dsgd_bytes {
            dsgd_bytes as f64 / dad_bytes.max(1) as f64
        } else {
            dad_bytes as f64 / dsgd_bytes.max(1) as f64
        },
        if dad_bytes <= dsgd_bytes { "fewer" } else { "more" },
    );
}

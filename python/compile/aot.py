"""AOT compiler: lower the Layer-2 JAX functions (which embed the Layer-1
Pallas kernels) to HLO *text* artifacts for the Rust PJRT runtime.

HLO text — NOT lowered.compile()/.serialize() — is the interchange format:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
vendored xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README.md
and gen_hlo.py there).

Usage:  cd python && python -m compile.aot --out ../artifacts
Re-running is cheap and deterministic; `make artifacts` skips it when inputs
are unchanged.

Artifacts (shapes fixed at trace time; the Rust native engine handles every
other shape):
  mlp_stats.hlo.txt        per-site local stats, batch 32 (paper's per-site N)
  mlp_grads.hlo.txt        gradient assembly on concatenated stats (SN = 64)
  mlp_train_step.hlo.txt   fused pooled step, batch 64
  rankdad_factors.hlo.txt  structured power iterations, 64x1024 / 64x1024,
                           max_rank 10, n_iters 10 (Figure 4 configuration)
  fused_delta.hlo.txt      standalone Layer-1 kernel (64x1024 stripe)
  smoke.hlo.txt            2x2 matmul+2.0 sanity check for runtime tests
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import rankdad_factors, fused_delta


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _lower_mlp_stats(batch):
    d0, d1, d2, c = model.MLP_DIMS
    return jax.jit(model.mlp_stats_flat).lower(
        _f32(d0, d1), _f32(d1), _f32(d1, d2), _f32(d2), _f32(d2, c), _f32(c),
        _f32(batch, d0), _f32(batch, c),
    )


def _lower_mlp_grads(total_batch):
    d0, d1, d2, c = model.MLP_DIMS
    return jax.jit(model.mlp_grads_flat).lower(
        _f32(total_batch, d0), _f32(total_batch, d1), _f32(total_batch, d2),
        _f32(total_batch, d1), _f32(total_batch, d2), _f32(total_batch, c),
        _f32(),
    )


def _lower_mlp_train_step(batch):
    d0, d1, d2, c = model.MLP_DIMS
    return jax.jit(model.mlp_train_step_flat).lower(
        _f32(d0, d1), _f32(d1), _f32(d1, d2), _f32(d2), _f32(d2, c), _f32(c),
        _f32(batch, d0), _f32(batch, c), _f32(),
    )


def _lower_rankdad(n, h_in, h_out, max_rank, n_iters):
    def fn(a, d):
        q_t, g_t, eff = rankdad_factors(a, d, max_rank=max_rank, n_iters=n_iters)
        return q_t, g_t, eff.astype(jnp.float32)  # uniform f32 outputs

    return jax.jit(fn).lower(_f32(n, h_in), _f32(n, h_out))


def _lower_fused_delta(n, h_in, h_out):
    def fn(dn, w, a):
        return (fused_delta(dn, w, a),)

    return jax.jit(fn).lower(_f32(n, h_out), _f32(h_in, h_out), _f32(n, h_in))


def _lower_smoke():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = _f32(2, 2)
    return jax.jit(fn).lower(s, s)


ARTIFACTS = {
    "mlp_stats": lambda: _lower_mlp_stats(batch=32),
    "mlp_grads": lambda: _lower_mlp_grads(total_batch=64),
    "mlp_train_step": lambda: _lower_mlp_train_step(batch=64),
    "rankdad_factors": lambda: _lower_rankdad(64, 1024, 1024, max_rank=10, n_iters=10),
    "fused_delta": lambda: _lower_fused_delta(64, 1024, 1024),
    "smoke": _lower_smoke,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    names = args.only.split(",") if args.only else list(ARTIFACTS)
    manifest = {}
    for name in names:
        lowered = ARTIFACTS[name]()
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {"bytes": len(text)}
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)


if __name__ == "__main__":
    main()

"""Layer-1 Pallas kernels for distributed auto-differentiation.

All kernels run under interpret=True (the CPU PJRT plugin cannot execute
Mosaic custom-calls); each has a pure-jnp oracle in ref.py, compared by
pytest under hypothesis shape/dtype sweeps.
"""

from .fused_delta import fused_delta
from .grad_outer import grad_outer
from .power_iter import power_iter_step, rankdad_factors
from . import ref

__all__ = ["fused_delta", "grad_outer", "power_iter_step", "rankdad_factors", "ref"]

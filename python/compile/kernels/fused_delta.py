"""Pallas kernel: fused backward delta step (paper eq. (3)/(5)).

    Delta_i = (Delta_{i+1} @ W_{i+1}^T) . phi'_i(A_i)

with phi' evaluated *from the output activation* A_i — the identity that lets
edAD continue backpropagation at the aggregated level without communicating
any deltas past the output layer.

TPU mapping (DESIGN.md section "Hardware adaptation"): the grid tiles the
(N, h_in) output; each program brings one (bn, h_out) stripe of Delta_{i+1}
and one (bh, h_out) stripe of W into VMEM, contracts them on the MXU
(jnp.dot with preferred_element_type=f32) and applies the activation-
derivative Hadamard as the epilogue of the same tile pass — the fusion the
paper gets for free from AD is expressed here as one kernel instead of a
matmul + pointwise pair.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust runtime
runs unmodified (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Nonlinearity epilogues, computed from the *output* activation.
_DERIV = {
    ref.RELU: lambda a: (a > 0.0).astype(a.dtype),
    ref.SIGMOID: lambda a: a * (1.0 - a),
    ref.TANH: lambda a: 1.0 - a * a,
    ref.LINEAR: lambda a: jnp.ones_like(a),
}


def _kernel(dn_ref, w_ref, a_ref, o_ref, *, activation):
    dn = dn_ref[...]  # (bn, h_out) stripe of Delta_{i+1}
    w = w_ref[...]  # (bh, h_out) stripe of W_{i+1}
    a = a_ref[...]  # (bn, bh) tile of A_i
    # MXU contraction: (bn, h_out) x (h_out, bh) -> (bn, bh), fp32 accumulate.
    prod = jax.lax.dot_general(
        dn,
        w,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (prod * _DERIV[activation](a.astype(jnp.float32))).astype(o_ref.dtype)


def _block(dim, want):
    """Largest divisor of `dim` that is <= want (keeps BlockSpecs exact)."""
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("activation", "bn", "bh"))
def fused_delta(delta_next, w, a, activation=ref.RELU, bn=128, bh=256):
    """Pallas fused delta: delta_next (N,h_out), w (h_in,h_out), a (N,h_in).

    Returns Delta_i with shape (N, h_in). Block sizes are VMEM-tuned upper
    bounds; they are clipped to divisors of the actual dims so interpret mode
    sees exact tilings.
    """
    n, h_out = delta_next.shape
    h_in = w.shape[0]
    assert w.shape == (h_in, h_out) and a.shape == (n, h_in)
    bn = _block(n, bn)
    bh = _block(h_in, bh)
    grid = (n // bn, h_in // bh)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, h_out), lambda i, j: (i, 0)),
            pl.BlockSpec((bh, h_out), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, h_in), delta_next.dtype),
        interpret=True,
    )(delta_next, w, a)

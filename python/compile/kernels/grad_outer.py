"""Pallas kernel: tiled gradient outer product (paper eq. (4)).

    grad W_i = scale * A_{i-1}^T @ Delta_i

This is *the* operation dAD distributes: both factors have N rows (batch),
the output has h_in x h_out entries, and N << h for every practically
relevant layer — which is exactly why shipping the factors beats shipping
the gradient.

TPU mapping: the grid tiles the (h_in, h_out) *output*; the reduction
dimension N is small (<= batch size) and streams through VMEM whole. Each
program computes one (bi, bo) output tile as a (bi, N) x (N, bo) MXU
contraction with fp32 accumulation. With N <= 128 both stripes fit VMEM at
any practical h (see DESIGN.md VMEM table).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, d_ref, s_ref, o_ref):
    a = a_ref[...]  # (N, bi) stripe of A_{i-1}
    d = d_ref[...]  # (N, bo) stripe of Delta_i
    scale = s_ref[0, 0]  # traced scalar (1/(S*N)) — not baked into the HLO
    acc = jax.lax.dot_general(
        a,
        d,
        dimension_numbers=(((0,), (0,)), ((), ())),  # contract batch dim
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = (scale * acc).astype(o_ref.dtype)


def _block(dim, want):
    b = min(dim, want)
    while dim % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bi", "bo"))
def grad_outer(a_prev, delta, scale=1.0, bi=256, bo=256):
    """a_prev (N,h_in), delta (N,h_out) -> scale * a_prev.T @ delta.

    `scale` may be a python float or a traced f32 scalar — it is fed to the
    kernel as a (1,1) operand so one lowered artifact serves any site count.
    """
    n, h_in = a_prev.shape
    n2, h_out = delta.shape
    assert n == n2
    bi = _block(h_in, bi)
    bo = _block(h_out, bo)
    grid = (h_in // bi, h_out // bo)
    s = jnp.asarray(scale, a_prev.dtype).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, bi), lambda i, j: (0, i)),
            pl.BlockSpec((n, bo), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h_in, h_out), a_prev.dtype),
        interpret=True,
    )(a_prev, delta, s)

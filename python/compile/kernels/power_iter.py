"""Pallas kernel: structured power iteration on AD factors (paper 3.4.1).

rank-dAD never materializes the gradient M = A^T Delta (h_in x h_out). One
power-iteration step on M^T M is computed purely through the factors:

    v  = Delta g                      O(N h_out)
    t  = A^T v ; w = A t  (= C v)     O(N h_in)     C = A A^T kept factored
    g' = Delta^T w                    O(N h_out)
    g' -= G^T (sigma^2 * (G g))       O(r h_out)    deflation of found pairs

Total O(h N + h r) versus the O(h^2) of iterating on the materialized
gradient (paper eq. (6) vs (7)-(8)).

TPU mapping: every operand of the step fits VMEM simultaneously for all
practical shapes (N <= 128, h <= 8192, r <= 32: A + Delta + vectors < 5 MB
of the ~16 MB budget), so the kernel is a single program (grid=()) that
chains four tiny MXU/VPU contractions without touching HBM in between —
the structured-power-iteration analog of keeping C resident that the paper
exploits on GPU.

interpret=True: see fused_delta.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _step_kernel(a_ref, d_ref, g_ref, gs_ref, sig_ref, o_ref):
    a = a_ref[...]  # (N, h_in)
    d = d_ref[...]  # (N, h_out)
    g = g_ref[...]  # (h_out, 1)
    gs = gs_ref[...]  # (r, h_out)
    sig = sig_ref[...]  # (r, 1)

    f32 = jnp.float32
    dot = functools.partial(jax.lax.dot_general, preferred_element_type=f32)
    v = dot(d, g, dimension_numbers=(((1,), (0,)), ((), ())))  # (N,1)
    t = dot(a, v, dimension_numbers=(((0,), (0,)), ((), ())))  # (h_in,1) = A^T v
    w = dot(a, t, dimension_numbers=(((1,), (0,)), ((), ())))  # (N,1)   = C v
    gn = dot(d, w, dimension_numbers=(((0,), (0,)), ((), ())))  # (h_out,1)
    c = dot(gs, g, dimension_numbers=(((1,), (0,)), ((), ())))  # (r,1) = G g
    c = (sig * sig) * c
    defl = dot(gs, c, dimension_numbers=(((0,), (0,)), ((), ())))  # (h_out,1)
    gn = gn - defl
    # Re-orthogonalization against found vectors, twice — see
    # ref.power_iter_step_ref for why a single pass is not enough in f32.
    for _ in range(2):
        proj = dot(gs, gn, dimension_numbers=(((1,), (0,)), ((), ())))  # (r,1)
        gn = gn - dot(gs, proj, dimension_numbers=(((0,), (0,)), ((), ())))
    o_ref[...] = gn.astype(o_ref.dtype)


@jax.jit
def power_iter_step(a, d, g, gs, sigmas):
    """One deflated structured power-iteration step (unnormalized).

    a: (N,h_in), d: (N,h_out), g: (h_out,), gs: (r,h_out), sigmas: (r,).
    """
    n, h_in = a.shape
    h_out = d.shape[1]
    r = gs.shape[0]
    out = pl.pallas_call(
        _step_kernel,
        out_shape=jax.ShapeDtypeStruct((h_out, 1), a.dtype),
        interpret=True,
    )(a, d, g.reshape(h_out, 1), gs, sigmas.reshape(r, 1))
    return out.reshape(h_out)


@functools.partial(jax.jit, static_argnames=("max_rank", "n_iters"))
def rankdad_factors(a, d, max_rank=10, n_iters=10, theta=1e-3):
    """Jit-traceable structured-power-iteration factorization.

    Matches ref.rankdad_factors_ref: returns (q_t, g_t, eff_rank) with
    q_t (max_rank, h_in) rows = sigma_j q_j, g_t (max_rank, h_out) rows =
    unit right singular vectors, rows past eff_rank zeroed. eff_rank is an
    int32 scalar — the paper's adaptive "effective rank".
    """
    n, h_in = a.shape
    h_out = d.shape[1]
    dt = a.dtype
    q_t = jnp.zeros((max_rank, h_in), dt)
    g_t = jnp.zeros((max_rank, h_out), dt)
    sigmas = jnp.zeros((max_rank,), dt)
    g0 = ref.deterministic_init(h_out, dt)
    alive = jnp.bool_(True)
    eff = jnp.int32(0)
    # Rank cap + f32 noise floor — see ref.rankdad_factors_ref and the Rust
    # twin (rust/src/lowrank/power_iter.rs).
    hard_cap = min(max_rank, n, h_in, h_out)
    theta_stop = jnp.maximum(theta, 3e-4)

    for j in range(hard_cap):  # static unroll: max_rank is small (<= 32)

        def cond(carry):
            k, g, gap, nrm = carry
            return (k < n_iters) & (gap >= theta) & (nrm >= 1e-30)

        def body(carry):
            k, g, _, _ = carry
            gn = power_iter_step(a, d, g, g_t, sigmas)
            nrm = jnp.linalg.norm(gn)
            gn_unit = jnp.where(nrm < 1e-30, g, gn / jnp.maximum(nrm, 1e-30))
            gap = jnp.linalg.norm(g - gn_unit) / (jnp.linalg.norm(g) + 1e-30)
            return k + 1, gn_unit, gap, nrm

        # First step unconditionally (gap initialized to +inf analog).
        _, g, _, nrm = jax.lax.while_loop(cond, body, (jnp.int32(0), g0, jnp.float32(1e9), jnp.float32(1e9)))
        # ||deflated_step(unit g)|| ~= residual sigma^2 — the theta-stop that
        # makes the rank *effective* (see ref.rankdad_factors_ref).
        res_ok = jnp.sqrt(nrm) >= theta_stop * jnp.maximum(1.0, sigmas[0])
        degenerate = nrm < 1e-30
        v = d @ g
        sigma = jnp.sqrt(jnp.maximum(v @ (a @ (a.T @ v)), 0.0))
        keep = alive & ~degenerate & res_ok & (sigma >= theta_stop * jnp.maximum(1.0, sigmas[0]))
        q = (a.T @ v) / jnp.maximum(sigma, 1e-30)
        q_t = q_t.at[j].set(jnp.where(keep, sigma * q, 0.0))
        g_t = g_t.at[j].set(jnp.where(keep, g, 0.0))
        sigmas = sigmas.at[j].set(jnp.where(keep, sigma, 0.0))
        eff = eff + keep.astype(jnp.int32)
        alive = keep
    return q_t, g_t, eff

"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written in
the most direct jnp form possible. pytest compares kernel outputs against
these under hypothesis-driven shape/dtype sweeps; the Rust native engine
mirrors the same math (tested on the Rust side against finite differences).

Shape conventions (match DESIGN.md):
    A_{i-1} in R^{N x h_i}      input activations of layer i
    Delta_i in R^{N x h_{i+1}}  backpropagated error at layer i (unscaled)
    W_i     in R^{h_i x h_{i+1}}
    grad W_i = A_{i-1}^T Delta_i / (S*N)
"""

import jax
import jax.numpy as jnp

# Activation tags shared with the kernels and the Rust engine.
RELU, SIGMOID, TANH, LINEAR = "relu", "sigmoid", "tanh", "linear"


def act(name, z):
    """Forward activation."""
    if name == RELU:
        return jnp.maximum(z, 0.0)
    if name == SIGMOID:
        return jax.nn.sigmoid(z)
    if name == TANH:
        return jnp.tanh(z)
    if name == LINEAR:
        return z
    raise ValueError(f"unknown activation {name!r}")


def act_deriv_from_output(name, a):
    """phi'(z) computed *from the output activation* a = phi(z).

    This is the edAD trick (paper section 3.3): for the common activations the
    derivative is an analytic function of the output, so the aggregator's
    broadcast activations suffice to continue backpropagation without any
    further delta communication.
    """
    if name == RELU:
        return (a > 0.0).astype(a.dtype)
    if name == SIGMOID:
        return a * (1.0 - a)
    if name == TANH:
        return 1.0 - a * a
    if name == LINEAR:
        return jnp.ones_like(a)
    raise ValueError(f"unknown activation {name!r}")


def fused_delta_ref(delta_next, w, a, activation=RELU):
    """Delta_i = (Delta_{i+1} W_{i+1}^T) . phi'_i(A_i)   [paper eq. (3)/(5)].

    delta_next: (N, h_out), w: (h_in, h_out), a: (N, h_in) -> (N, h_in)
    """
    return (delta_next @ w.T) * act_deriv_from_output(activation, a)


def grad_outer_ref(a_prev, delta, scale=1.0):
    """grad W = scale * A_{i-1}^T Delta_i     [paper eq. (4)].

    a_prev: (N, h_in), delta: (N, h_out) -> (h_in, h_out)
    """
    return scale * (a_prev.T @ delta)


def power_iter_step_ref(a, d, g, gs, sigmas):
    """One deflated structured power-iteration step  [paper eq. (6)-(8)].

    Iterates g <- M^T M g in factored space, where M = A^T D is the gradient
    that is never materialized:

        v  = D g            (N,)
        w  = C v, C = A A^T (N,)
        g' = D^T w          (h_out,)
        g' -= sum_j sigma_j^2 g_j (g_j^T g)     (deflation of found pairs)

    a: (N, h_in), d: (N, h_out), g: (h_out,)
    gs: (r, h_out) previously found right singular vectors (rows may be zero)
    sigmas: (r,) corresponding singular values (zero rows are inert)
    Returns the *unnormalized* next iterate.
    """
    v = d @ g
    w = a @ (a.T @ v)  # C v without materializing C
    g_next = d.T @ w
    coeff = (sigmas**2) * (gs @ g)
    g_next = g_next - gs.T @ coeff
    # Re-orthogonalize against the found right singular vectors (unit rows of
    # gs; zero rows are inert). Algebraically redundant with the deflation
    # above, but it keeps the iterate in the orthogonal complement despite
    # f32 cancellation noise — without it the theta-stop floor sits at
    # ~eps*sigma_0 and exhausted spectra are not reliably detected. Applied
    # twice ("twice is enough", Kahan/Parlett): a single pass leaves an
    # O(eps) relative residual that the sigma_0^2 amplification of the next
    # step resurrects into a spurious duplicate dominant component.
    g_next = g_next - gs.T @ (gs @ g_next)
    g_next = g_next - gs.T @ (gs @ g_next)
    return g_next


def deterministic_init(h, dt=jnp.float32):
    """Deterministic pseudo-random unit start vector (PRNG-free so the exact
    same sequence is reproducible from the Rust native engine)."""
    i = jnp.arange(h, dtype=jnp.float32)
    v = jnp.sin(i * 12.9898 + 78.233) * 43758.5453
    v = v - jnp.floor(v) - 0.5
    return (v / jnp.linalg.norm(v)).astype(dt)


def rankdad_factors_ref(a, d, max_rank, n_iters=10, theta=1e-3):
    """Full structured-power-iteration factorization (paper section 3.4.1).

    Returns (q_t, g_t, eff_rank) with q_t: (max_rank, h_in) holding
    sigma_j * q_j rows and g_t: (max_rank, h_out) holding unit right singular
    vectors, so that   A^T D  ~=  q_t^T @ g_t.  Rows past eff_rank are zero.

    The effective rank is the number of components extracted before the
    residual spectrum is indistinguishable from zero (paper's theta-stop on
    the convergence gap ||g^j - g^{j+1}|| / ||g^j|| with theta = 1e-3).
    """
    n, h_in = a.shape
    h_out = d.shape[1]
    dt = a.dtype
    q_t = jnp.zeros((max_rank, h_in), dt)
    g_t = jnp.zeros((max_rank, h_out), dt)
    sigmas = jnp.zeros((max_rank,), dt)
    eff_rank = 0
    g0 = deterministic_init(h_out, dt)
    # True rank is bounded by every dimension; f32 cannot resolve residual
    # spectra below ~sqrt(eps)*sigma_0 (see the Rust twin in
    # rust/src/lowrank/power_iter.rs for the full story).
    hard_cap = min(max_rank, n, h_in, h_out)
    theta_stop = max(theta, 3e-4)
    for j in range(hard_cap):
        g = g0
        degenerate = False
        nrm = 0.0
        for _ in range(n_iters):
            g_new = power_iter_step_ref(a, d, g, g_t, sigmas)
            nrm = float(jnp.linalg.norm(g_new))
            if nrm < 1e-30:  # residual spectrum ~ zero
                degenerate = True
                break
            g_new = g_new / nrm
            gap = float(jnp.linalg.norm(g - g_new)) / (float(jnp.linalg.norm(g)) + 1e-30)
            g = g_new
            if gap < theta:
                break
        # The deflated operator applied to a unit iterate has norm ~= the
        # residual spectrum's sigma^2: once it collapses relative to the
        # dominant sigma, the remaining columns are noise — skip them
        # (the paper's theta-stop, section 3.4.1).
        res_sigma = nrm**0.5
        if degenerate or res_sigma < theta_stop * max(1.0, float(sigmas[0])):
            break
        v = d @ g
        sigma = float(jnp.sqrt(v @ (a @ (a.T @ v))))
        if sigma < theta_stop * max(1.0, float(sigmas[0])):
            break  # noisy column: skip per paper section 3.4.1
        q = (a.T @ v) / sigma
        q_t = q_t.at[j].set(sigma * q)
        g_t = g_t.at[j].set(g)
        sigmas = sigmas.at[j].set(sigma)
        eff_rank = j + 1
    return q_t, g_t, eff_rank


# ---------------------------------------------------------------------------
# MLP local-stats oracle (mirrors model.mlp_local_stats and the Rust tape).
# ---------------------------------------------------------------------------


def mlp_forward_ref(params, x, activations):
    """Forward pass returning all layer activations [A_0 .. A_L]."""
    a = x
    acts = [a]
    for (w, b), name in zip(params, activations):
        a = act(name, a @ w + b)
        acts.append(a)
    return acts


def mlp_local_stats_ref(params, x, y, activations):
    """(loss, [A_0..A_{L-1}], [Delta_1..Delta_L]) for a softmax-CE MLP.

    Deltas are UNSCALED (Delta_L = softmax(z_L) - y); the coordinator applies
    the 1/(S*N) global-mean scale when assembling gradients, so the same
    artifact serves any site count. `activations` names the hidden
    activations; the output layer is always softmax + cross-entropy.
    """
    acts = [x]
    a = x
    for (w, b), name in zip(params[:-1], activations):
        a = act(name, a @ w + b)
        acts.append(a)
    w_l, b_l = params[-1]
    z_l = a @ w_l + b_l
    p = jax.nn.softmax(z_l, axis=-1)
    logp = jax.nn.log_softmax(z_l, axis=-1)
    loss = -jnp.mean(jnp.sum(y * logp, axis=-1))
    deltas = [None] * len(params)
    deltas[-1] = p - y
    for i in range(len(params) - 2, -1, -1):
        w_next = params[i + 1][0]
        deltas[i] = fused_delta_ref(deltas[i + 1], w_next, acts[i + 1], activations[i])
    return loss, acts[:-1], deltas


def mlp_grads_from_stats_ref(a_hats, delta_hats, scale):
    """Exact global gradients from (concatenated) stats [paper eq. (4)]."""
    grads_w = [grad_outer_ref(a, d, scale) for a, d in zip(a_hats, delta_hats)]
    grads_b = [scale * jnp.sum(d, axis=0) for d in delta_hats]
    return grads_w, grads_b

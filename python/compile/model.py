"""Layer-2 JAX model: the paper's feed-forward network, built on the Layer-1
Pallas kernels, structured so each site's *local AD statistics* — not the
gradient — are the function outputs.

Three entry points, each AOT-lowered by aot.py to an HLO-text artifact the
Rust coordinator executes through PJRT:

  mlp_local_stats      one site's forward + backward, returning
                       (loss, A_0..A_{L-1}, Delta_1..Delta_L). This is what a
                       site computes before the dAD exchange. Deltas are
                       unscaled; the coordinator applies 1/(S*N).
  mlp_grads_from_stats the post-exchange gradient assembly
                       grad W_i = scale * A_hat^T Delta_hat (paper eq. 4),
                       run on concatenated stats.
  rankdad_factors      the structured-power-iteration factorization used by
                       rank-dAD (kernels/power_iter.py).

The canonical architecture matches the paper's MNIST experiment:
784 -> 1024 -> 1024 -> 10, ReLU hidden activations, softmax cross-entropy
(Table 2 lists FC1 as 768x1024; 768 is inconsistent with MNIST's 28x28=784
inputs used in Figure 1, and we use 784 throughout).
"""

import jax
import jax.numpy as jnp

from .kernels import fused_delta, grad_outer
from .kernels import ref

# Canonical paper MLP (hidden activations; output layer is softmax-CE).
MLP_DIMS = (784, 1024, 1024, 10)
MLP_ACTS = (ref.RELU, ref.RELU)


def mlp_init(key, dims=MLP_DIMS):
    """He-uniform init, matching rust/src/nn/init.rs."""
    params = []
    for h_in, h_out in zip(dims[:-1], dims[1:]):
        key, sub = jax.random.split(key)
        bound = jnp.sqrt(6.0 / h_in)
        w = jax.random.uniform(sub, (h_in, h_out), jnp.float32, -bound, bound)
        params.append((w, jnp.zeros((h_out,), jnp.float32)))
    return params


def mlp_local_stats(params, x, y, activations=MLP_ACTS):
    """One site's AD statistics for a softmax-CE MLP.

    params: [(W_i, b_i)] with W_i (h_{i-1}, h_i); x (N, h_0); y (N, C) one-hot.
    Returns (loss, acts, deltas): acts = [A_0..A_{L-1}] (A_0 = x),
    deltas = [Delta_1..Delta_L], all unscaled (Delta_L = softmax - y).

    The backward recurrence runs on the Pallas fused_delta kernel — the same
    fused matmul+Hadamard tile pass edAD performs at the aggregated level.
    """
    acts = [x]
    a = x
    for (w, b), name in zip(params[:-1], activations):
        a = ref.act(name, a @ w + b)
        acts.append(a)
    w_l, b_l = params[-1]
    z_l = a @ w_l + b_l
    logp = jax.nn.log_softmax(z_l, axis=-1)
    loss = -jnp.mean(jnp.sum(y * logp, axis=-1))
    deltas = [None] * len(params)
    deltas[-1] = jnp.exp(logp) - y
    for i in range(len(params) - 2, -1, -1):
        w_next = params[i + 1][0]
        deltas[i] = fused_delta(deltas[i + 1], w_next, acts[i + 1], activations[i])
    return loss, acts, deltas


def mlp_grads_from_stats(a_hats, delta_hats, scale):
    """Gradient assembly from (concatenated) statistics, on the Pallas
    grad_outer kernel. Returns ([grad W_i], [grad b_i])."""
    grads_w = [grad_outer(a, d, scale=scale) for a, d in zip(a_hats, delta_hats)]
    grads_b = [scale * jnp.sum(d, axis=0) for d in delta_hats]
    return grads_w, grads_b


# --- flat-signature wrappers for AOT lowering (PJRT takes a flat arg list) --


def mlp_stats_flat(w1, b1, w2, b2, w3, b3, x, y):
    """Flat-tuple mlp_local_stats for the canonical 784-1024-1024-10 MLP.

    Outputs: (loss, a0, a1, a2, d1, d2, d3).
    """
    loss, acts, deltas = mlp_local_stats([(w1, b1), (w2, b2), (w3, b3)], x, y)
    return (loss, acts[0], acts[1], acts[2], deltas[0], deltas[1], deltas[2])


def mlp_grads_flat(a0, a1, a2, d1, d2, d3, scale):
    """Flat-tuple mlp_grads_from_stats. scale is a f32 scalar (1/(S*N)).

    Outputs: (gw1, gb1, gw2, gb2, gw3, gb3).
    """
    gw, gb = mlp_grads_from_stats([a0, a1, a2], [d1, d2, d3], scale)
    return (gw[0], gb[0], gw[1], gb[1], gw[2], gb[2])


def mlp_train_step_flat(w1, b1, w2, b2, w3, b3, x, y, scale):
    """Fused single-site step: stats + gradient assembly in one executable.

    Used by the pooled/PJRT backend where no exchange is needed between the
    two halves. Outputs: (loss, gw1, gb1, gw2, gb2, gw3, gb3,
    a0, a1, a2, d1, d2, d3) — gradients for the update, stats for telemetry.
    """
    loss, a0, a1, a2, d1, d2, d3 = mlp_stats_flat(w1, b1, w2, b2, w3, b3, x, y)
    gw1, gb1, gw2, gb2, gw3, gb3 = mlp_grads_flat(a0, a1, a2, d1, d2, d3, scale)
    return (loss, gw1, gb1, gw2, gb2, gw3, gb3, a0, a1, a2, d1, d2, d3)

"""Layer-1 correctness: fused_delta Pallas kernel vs pure-jnp oracle.

This is the core correctness signal for the backward recurrence the paper
distributes (eq. 3/5): hypothesis sweeps shapes, dtypes and activations and
asserts allclose against ref.fused_delta_ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_delta
from compile.kernels import ref

ACTS = [ref.RELU, ref.SIGMOID, ref.TANH, ref.LINEAR]


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 48),
    h_in=st.integers(1, 96),
    h_out=st.integers(1, 96),
    act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(n, h_in, h_out, act, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    dn = _rand(k1, (n, h_out), jnp.float32)
    w = _rand(k2, (h_in, h_out), jnp.float32)
    # Activations must be *outputs* of the nonlinearity for the
    # derivative-from-output identity to be meaningful.
    a = ref.act(act, _rand(k3, (n, h_in), jnp.float32))
    got = fused_delta(dn, w, a, activation=act)
    want = ref.fused_delta_ref(dn, w, a, activation=act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dtype_sweep(dtype, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    dn = _rand(k1, (16, 64), dtype)
    w = _rand(k2, (32, 64), dtype)
    a = ref.act(ref.RELU, _rand(k3, (16, 32), dtype))
    got = fused_delta(dn, w, a, activation=ref.RELU)
    want = ref.fused_delta_ref(dn, w, a, activation=ref.RELU)
    assert got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@pytest.mark.parametrize("bn,bh", [(8, 16), (16, 32), (128, 256), (7, 13)])
def test_block_size_invariance(bn, bh):
    """Tiling must not change the math."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    dn = _rand(k1, (24, 40), jnp.float32)
    w = _rand(k2, (56, 40), jnp.float32)
    a = ref.act(ref.TANH, _rand(k3, (24, 56), jnp.float32))
    got = fused_delta(dn, w, a, activation=ref.TANH, bn=bn, bh=bh)
    want = ref.fused_delta_ref(dn, w, a, activation=ref.TANH)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_paper_shape():
    """The canonical MNIST-MLP backward stripe: 32x1024 through 1024x1024."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    dn = _rand(k1, (32, 1024), jnp.float32)
    w = _rand(k2, (1024, 1024), jnp.float32)
    a = ref.act(ref.RELU, _rand(k3, (32, 1024), jnp.float32))
    got = fused_delta(dn, w, a)
    want = ref.fused_delta_ref(dn, w, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_relu_derivative_from_output_identity():
    """phi'(z) from a = phi(z) equals phi'(z) from z (edAD's enabling fact)."""
    z = jnp.linspace(-3, 3, 101)
    for name in ACTS:
        a = ref.act(name, z)
        from_out = ref.act_deriv_from_output(name, a)
        from_z = jax.vmap(jax.grad(lambda t: ref.act(name, t)))(z)
        np.testing.assert_allclose(np.asarray(from_out), np.asarray(from_z), rtol=1e-5, atol=1e-5)

"""Layer-1 correctness: grad_outer Pallas kernel vs oracle (paper eq. 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import grad_outer
from compile.kernels import ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 64),
    h_in=st.integers(1, 128),
    h_out=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_shape_sweep(n, h_in, h_out, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (n, h_in))
    d = _rand(k2, (n, h_out))
    got = grad_outer(a, d)
    want = ref.grad_outer_ref(a, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(1e-4, 10.0), seed=st.integers(0, 2**31 - 1))
def test_scale(scale, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = _rand(k1, (16, 48))
    d = _rand(k2, (16, 24))
    got = grad_outer(a, d, scale=scale)
    want = ref.grad_outer_ref(a, d, scale=scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bi,bo", [(16, 16), (64, 32), (256, 256), (11, 29)])
def test_block_size_invariance(bi, bo):
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    a = _rand(k1, (32, 88))
    d = _rand(k2, (32, 56))
    got = grad_outer(a, d, bi=bi, bo=bo)
    want = ref.grad_outer_ref(a, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_concat_linearity():
    """Gradient of the concatenated batch == sum of per-site gradients —
    the identity that makes dAD exact (paper section 3.2)."""
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(3), 4)
    a1, a2 = _rand(k1, (8, 40)), _rand(k2, (8, 40))
    d1, d2 = _rand(k3, (8, 20)), _rand(k4, (8, 20))
    cat = grad_outer(jnp.concatenate([a1, a2]), jnp.concatenate([d1, d2]))
    parts = grad_outer(a1, d1) + grad_outer(a2, d2)
    np.testing.assert_allclose(np.asarray(cat), np.asarray(parts), rtol=1e-4, atol=1e-4)


def test_bandwidth_motivation_shapes():
    """N(h_in+h_out) << h_in*h_out for the paper's layers — sanity-check the
    premise that shipping factors beats shipping gradients."""
    n = 32
    for h_in, h_out in [(784, 1024), (1024, 1024), (1024, 10)]:
        stats = n * (h_in + h_out)
        grad = h_in * h_out
        if h_out > n:  # holds for the hidden layers
            assert stats < grad

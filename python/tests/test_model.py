"""Layer-2 correctness: the stats-producing MLP against jax.grad.

The decisive test: gradients assembled from the model's AD statistics
(A_hat^T Delta_hat, paper eq. 4) must equal jax.grad of the loss — including
when the statistics come from *concatenated multi-site batches* (the dAD
exactness claim).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _one_hot(key, n, c):
    lbl = jax.random.randint(key, (n,), 0, c)
    return jax.nn.one_hot(lbl, c, dtype=jnp.float32)


def _setup(seed, dims=(20, 32, 24, 6), n=8):
    k = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = model.mlp_init(k[0], dims)
    x = jax.random.normal(k[1], (n, dims[0]), jnp.float32)
    y = _one_hot(k[2], n, dims[-1])
    return params, x, y


def _loss_fn(params, x, y):
    a = x
    for (w, b) in params[:-1]:
        a = jnp.maximum(a @ w + b, 0.0)
    z = a @ params[-1][0] + params[-1][1]
    return -jnp.mean(jnp.sum(y * jax.nn.log_softmax(z, axis=-1), axis=-1))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 16))
def test_stats_reconstruct_jax_grad(seed, n):
    params, x, y = _setup(seed, n=n)
    loss, acts, deltas = model.mlp_local_stats(params, x, y)
    gw, gb = model.mlp_grads_from_stats(acts, deltas, 1.0 / n)
    ref_loss = _loss_fn(params, x, y)
    ref_grads = jax.grad(_loss_fn)(params, x, y)
    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    for i, (rw, rb) in enumerate(ref_grads):
        np.testing.assert_allclose(np.asarray(gw[i]), np.asarray(rw), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gb[i]), np.asarray(rb), rtol=1e-4, atol=1e-5)


def test_two_site_concat_equals_pooled_grad():
    """dAD exactness: concatenating two sites' stats gives the pooled
    gradient of the union batch."""
    params, x1, y1 = _setup(23, n=8)
    _, x2, y2 = _setup(29, n=8)
    _, a1, d1 = model.mlp_local_stats(params, x1, y1)
    _, a2, d2 = model.mlp_local_stats(params, x2, y2)
    a_hat = [jnp.concatenate([u, v]) for u, v in zip(a1, a2)]
    d_hat = [jnp.concatenate([u, v]) for u, v in zip(d1, d2)]
    gw, gb = model.mlp_grads_from_stats(a_hat, d_hat, 1.0 / 16)
    x = jnp.concatenate([x1, x2])
    y = jnp.concatenate([y1, y2])
    ref_grads = jax.grad(_loss_fn)(params, x, y)
    for i, (rw, rb) in enumerate(ref_grads):
        np.testing.assert_allclose(np.asarray(gw[i]), np.asarray(rw), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(gb[i]), np.asarray(rb), rtol=1e-4, atol=1e-5)


def test_edad_delta_recurrence_matches_stats():
    """edAD (alg. 2): deltas recomputed at the aggregated level from
    broadcast activations equal the concatenation of local deltas."""
    params, x1, y1 = _setup(31, n=8)
    _, x2, y2 = _setup(37, n=8)
    _, a1, d1 = model.mlp_local_stats(params, x1, y1)
    _, a2, d2 = model.mlp_local_stats(params, x2, y2)
    a_hat = [jnp.concatenate([u, v]) for u, v in zip(a1, a2)]
    d_hat_full = [jnp.concatenate([u, v]) for u, v in zip(d1, d2)]
    # edAD only ever communicates Delta_L; recompute the rest (eq. 5).
    d_l = d_hat_full[-1]
    deltas = [None] * len(params)
    deltas[-1] = d_l
    for i in range(len(params) - 2, -1, -1):
        deltas[i] = ref.fused_delta_ref(
            deltas[i + 1], params[i + 1][0], a_hat[i + 1], ref.RELU
        )
    for i in range(len(params)):
        np.testing.assert_allclose(
            np.asarray(deltas[i]), np.asarray(d_hat_full[i]), rtol=1e-4, atol=1e-5
        )


def test_flat_wrappers_roundtrip():
    params, x, y = _setup(41, dims=model.MLP_DIMS, n=4)
    flat = [t for p in params for t in p]
    out = model.mlp_stats_flat(*flat, x, y)
    assert len(out) == 7
    loss, a0, a1, a2, d1, d2, d3 = out
    assert a0.shape == (4, 784) and a1.shape == (4, 1024)
    assert d3.shape == (4, 10)
    g = model.mlp_grads_flat(a0, a1, a2, d1, d2, d3, jnp.float32(0.25))
    assert g[0].shape == (784, 1024) and g[5].shape == (10,)
    step = model.mlp_train_step_flat(*flat, x, y, jnp.float32(0.25))
    np.testing.assert_allclose(np.asarray(step[1]), np.asarray(g[0]), rtol=1e-5, atol=1e-6)

"""Layer-1 correctness: structured power iterations (paper section 3.4.1).

Checks, in increasing strength:
  1. the Pallas step kernel matches the jnp oracle step;
  2. the jitted factorization matches the python-loop oracle;
  3. the factorization matches a *full SVD* of the materialized gradient
     (the thing the paper avoids computing) on the dominant components;
  4. the effective-rank early stop detects synthetic low-rank gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import power_iter_step, rankdad_factors
from compile.kernels import ref


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 32),
    h_in=st.integers(2, 96),
    h_out=st.integers(2, 96),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_step_matches_ref(n, h_in, h_out, r, seed):
    k = jax.random.split(jax.random.PRNGKey(seed), 5)
    a, d, g = _rand(k[0], (n, h_in)), _rand(k[1], (n, h_out)), _rand(k[2], (h_out,))
    gs, sigmas = _rand(k[3], (r, h_out)), jnp.abs(_rand(k[4], (r,)))
    got = np.asarray(power_iter_step(a, d, g, gs, sigmas))
    want = np.asarray(ref.power_iter_step_ref(a, d, g, gs, sigmas))
    # Hypothesis feeds arbitrary (non-orthonormal, large) gs rows, and the
    # double deflation/orthogonalization amplifies f32 rounding by ~|gs|^2;
    # compare relative to the output scale, not elementwise.
    scale = max(1.0, float(np.linalg.norm(want)))
    np.testing.assert_allclose(got / scale, want / scale, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4, 24),
    h_in=st.integers(8, 64),
    h_out=st.integers(8, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_factors_match_python_oracle(n, h_in, h_out, seed):
    """The jitted factorization and the python-loop oracle take the same path
    up to f32 rounding; near the theta boundary the iteration counts can flip
    on chaotic tail components, so we compare what matters — the low-rank
    *reconstruction* quality and the effective rank (within 1)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a, d = _rand(k1, (n, h_in)), _rand(k2, (n, h_out))
    q_j, g_j, eff_j = rankdad_factors(a, d, max_rank=6, n_iters=10)
    q_r, g_r, eff_r = ref.rankdad_factors_ref(a, d, max_rank=6, n_iters=10)
    assert abs(int(eff_j) - int(eff_r)) <= 1
    m = np.asarray(a.T @ d)
    err_j = np.linalg.norm(m - np.asarray(q_j).T @ np.asarray(g_j))
    err_r = np.linalg.norm(m - np.asarray(q_r).T @ np.asarray(g_r))
    scale = np.linalg.norm(m)
    assert err_j <= 1.05 * err_r + 0.05 * scale
    assert err_r <= 1.05 * err_j + 0.05 * scale


def test_dominant_component_matches_svd():
    """The first extracted pair must match the SVD of M = A^T D."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    a, d = _rand(k1, (16, 80)), _rand(k2, (16, 60))
    m = np.asarray(a.T @ d)
    u, s, vt = np.linalg.svd(m, full_matrices=False)
    q_t, g_t, eff = rankdad_factors(a, d, max_rank=4, n_iters=60)
    sigma0 = float(np.linalg.norm(np.asarray(q_t)[0]))
    np.testing.assert_allclose(sigma0, s[0], rtol=1e-2)
    # Right singular vector up to sign.
    g0 = np.asarray(g_t)[0]
    cos = abs(float(g0 @ vt[0]))
    assert cos > 0.99


def test_low_rank_reconstruction_error():
    """Q^T G must be a near-least-squares-optimal rank-r approximation."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    a, d = _rand(k1, (12, 64)), _rand(k2, (12, 48))
    m = np.asarray(a.T @ d)
    r = 6
    q_t, g_t, eff = rankdad_factors(a, d, max_rank=r, n_iters=80)
    approx = np.asarray(q_t).T @ np.asarray(g_t)
    u, s, vt = np.linalg.svd(m, full_matrices=False)
    optimal = (u[:, :r] * s[:r]) @ vt[:r]
    err = np.linalg.norm(m - approx)
    err_opt = np.linalg.norm(m - optimal)
    assert err <= 1.25 * err_opt + 1e-6


def test_effective_rank_detects_true_rank():
    """A gradient of true rank 3 must stop at effective rank ~3, not max_rank
    (the adaptive-bandwidth claim of section 3.4/5.2)."""
    k = jax.random.split(jax.random.PRNGKey(13), 4)
    # Build A, D sharing a 3-dim latent so M = A^T D has rank exactly 3.
    basis = _rand(k[0], (3, 24))  # latent -> batch
    a = basis.T @ _rand(k[1], (3, 96))
    d = basis.T @ _rand(k[2], (3, 72))
    q_t, g_t, eff = rankdad_factors(a, d, max_rank=10, n_iters=60)
    assert int(eff) <= 4
    approx = np.asarray(q_t).T @ np.asarray(g_t)
    m = np.asarray(a.T @ d)
    rel = np.linalg.norm(m - approx) / np.linalg.norm(m)
    assert rel < 1e-2


def test_rank_bounded_by_batch():
    """Effective rank can never exceed N (the paper's upper bound)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(17))
    a, d = _rand(k1, (4, 64)), _rand(k2, (4, 64))
    _, _, eff = rankdad_factors(a, d, max_rank=10, n_iters=60)
    assert int(eff) <= 4

//! Bench: measured site->aggregator bytes per synchronized step vs the
//! paper's Θ bounds (sections 3.2-3.4), swept over layer width. Checks the
//! orderings the paper claims: rank-dAD < edAD < dAD < dSGD for h >> N.
//!
//! Run: cargo bench --bench bandwidth_table

use dad::coordinator::experiments::bandwidth_table;

fn main() {
    println!("== bandwidth: measured vs Θ (2 sites, batch 32/site) ==");
    let rows = bandwidth_table(&[256, 512, 1024, 2048, 4096], 32);
    println!("{:<14} {:>6} {:>14} {:>14} {:>7}", "algo", "h", "measured", "theta", "ratio");
    for r in &rows {
        println!(
            "{:<14} {:>6} {:>14} {:>14} {:>7.2}",
            r.algo, r.h, r.measured_up, r.theta_up,
            r.measured_up as f64 / r.theta_up.max(1) as f64
        );
    }
    // Assert the paper's ordering at every h >= 1024 (h >> N regime).
    for &h in &[1024usize, 2048, 4096] {
        let get = |name: &str| rows.iter().find(|r| r.algo == name && r.h == h).unwrap().measured_up;
        assert!(get("rank-dad:4") < get("edad"), "h={h}");
        assert!(get("edad") < get("dad"), "h={h}");
        assert!(get("dad") < get("dsgd"), "h={h}");
    }
    println!("ordering rank-dad < edad < dad < dsgd holds for h in {{1024, 2048, 4096}}");
}

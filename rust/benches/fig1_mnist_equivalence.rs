//! Bench: regenerate Figure 1 — test-AUC curves for pooled / dSGD / dAD /
//! edAD on the MNIST-analog MLP with disjoint class shards. The paper's
//! claim: all four curves coincide.
//!
//! Run: cargo bench --bench fig1_mnist_equivalence  (DAD_SCALE=default|paper for bigger runs)

use dad::coordinator::experiments::{fig1, Scale};

fn main() {
    let scale = std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick);
    println!("== Figure 1 (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    let set = fig1(scale);
    println!("{:<12} {:>10} {:>14}", "algo", "final AUC", "total bytes");
    let mut aucs = vec![];
    for ((name, series), (_, bytes)) in set.curves.iter().zip(&set.bytes) {
        let last = series.last().unwrap();
        println!("{:<12} {:>10.4} {:>14}", name, last.0, bytes);
        aucs.push(last.0);
    }
    let spread = aucs.iter().cloned().fold(f32::MIN, f32::max)
        - aucs.iter().cloned().fold(f32::MAX, f32::min);
    println!("AUC spread across algorithms: {spread:.4} (paper: curves coincide)");
    println!("[{:.1}s] results/fig1.csv written", t0.elapsed().as_secs_f32());
    assert!(spread < 0.08, "equivalence violated: spread {spread}");
}

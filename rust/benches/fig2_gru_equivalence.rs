//! Bench: regenerate Figure 2 — the GRU equivalence curves on the
//! SpokenArabicDigits-analog (pooled / dSGD / dAD / edAD coincide).
//!
//! Run: cargo bench --bench fig2_gru_equivalence

use dad::coordinator::experiments::{fig2, Scale};

fn main() {
    let scale = std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick);
    println!("== Figure 2 (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    let set = fig2(scale);
    println!("{:<12} {:>10} {:>14}", "algo", "final AUC", "total bytes");
    let mut aucs = vec![];
    for ((name, series), (_, bytes)) in set.curves.iter().zip(&set.bytes) {
        let last = series.last().unwrap();
        println!("{:<12} {:>10.4} {:>14}", name, last.0, bytes);
        aucs.push(last.0);
    }
    let spread = aucs.iter().cloned().fold(f32::MIN, f32::max)
        - aucs.iter().cloned().fold(f32::MAX, f32::min);
    println!("AUC spread: {spread:.4} (paper: curves coincide)");
    println!("[{:.1}s] results/fig2.csv written", t0.elapsed().as_secs_f32());
    assert!(spread < 0.10, "equivalence violated: spread {spread}");
}

//! Bench: regenerate Figure 3 — rank-dAD vs PowerSGD test AUC for
//! increasing rank on the MNIST-analog MLP. Paper: above rank ~3 both are
//! equivalent; rank-dAD never loses.
//!
//! Run: cargo bench --bench fig3_rank_sweep

use dad::coordinator::experiments::{fig3_mnist, Scale};

fn main() {
    let scale = std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick);
    println!("== Figure 3 / MNIST panel (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    let set = fig3_mnist(scale);
    println!("{:<14} {:>10} {:>14}", "algo", "final AUC", "total bytes");
    for ((name, series), (_, bytes)) in set.curves.iter().zip(&set.bytes) {
        println!("{:<14} {:>10.4} {:>14}", name, series.last().unwrap().0, bytes);
    }
    println!("[{:.1}s] results/fig3_mnist.csv written", t0.elapsed().as_secs_f32());
}

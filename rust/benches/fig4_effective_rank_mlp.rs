//! Bench: regenerate Figure 4 — effective gradient rank per layer during
//! MLP/MNIST training (max rank 10). Paper: output layer's rank is lowest;
//! ranks decrease during training.
//!
//! Run: cargo bench --bench fig4_effective_rank_mlp

use dad::coordinator::experiments::{fig4, Scale};

fn main() {
    let scale = std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick);
    println!("== Figure 4 (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    let curves = fig4(scale);
    println!("mean effective rank per layer (per epoch):");
    print!("{:<8}", "epoch");
    for n in &curves.entry_names {
        print!(" {n:>24}");
    }
    println!();
    for (e, row) in curves.per_epoch.iter().enumerate() {
        print!("{e:<8}");
        for r in row {
            print!(" {r:>24.2}");
        }
        println!();
    }
    let first = &curves.per_epoch[0];
    let last = curves.per_epoch.last().unwrap();
    let out_idx = curves.entry_names.len() - 1;
    println!(
        "output-layer rank {:.2} -> {:.2}; hidden {:.2} -> {:.2}",
        first[out_idx], last[out_idx], first[0], last[0]
    );
    println!("[{:.1}s] results/fig4.csv written", t0.elapsed().as_secs_f32());
    // Paper shape: output layer rank below the widest hidden layer's.
    assert!(
        last[out_idx] <= last[..out_idx].iter().cloned().fold(f32::MIN, f32::max) + 0.5,
        "output layer should have the smallest effective rank"
    );
}

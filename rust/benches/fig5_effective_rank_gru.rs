//! Bench: regenerate Figure 5 — effective rank per layer for the GRU on
//! the four UEA-analog datasets (max rank 32). Paper: output layer lowest;
//! classifier ranks decrease during training; recurrent layer decreases
//! more gently.
//!
//! Run: cargo bench --bench fig5_effective_rank_gru

use dad::coordinator::experiments::{fig5, Scale};

fn main() {
    let scale = std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick);
    println!("== Figure 5 (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    for (name, curves) in fig5(scale) {
        println!("--- {name} ---");
        let first = &curves.per_epoch[0];
        let last = curves.per_epoch.last().unwrap();
        for (i, n) in curves.entry_names.iter().enumerate() {
            println!("  {:<28} {:>6.2} -> {:>6.2}", n, first[i], last[i]);
        }
    }
    println!("[{:.1}s] results/fig5_*.csv written", t0.elapsed().as_secs_f32());
}

//! Bench: regenerate Figure 6 — GRU on the ArabicDigits-analog, rank-dAD
//! vs PowerSGD per maximum rank. Paper: rank-dAD matches or beats PowerSGD.
//!
//! Run: cargo bench --bench fig6_gru_rank_sweep

use dad::coordinator::experiments::{fig3_arabic, Scale};

fn main() {
    let scale = std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick);
    println!("== Figure 6 (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    let set = fig3_arabic(scale);
    println!("{:<14} {:>10} {:>14}", "algo", "final AUC", "total bytes");
    for ((name, series), (_, bytes)) in set.curves.iter().zip(&set.bytes) {
        println!("{:<14} {:>10.4} {:>14}", name, series.last().unwrap().0, bytes);
    }
    println!("[{:.1}s] results/fig6_gru_ranks.csv written", t0.elapsed().as_secs_f32());
}

//! Bench: hot-path kernel timings (the §Perf working set) — the blocked/
//! packed pool-dispatched GEMM engine vs the seed's spawn-per-call kernels
//! (reproduced below as `legacy`), structured power iterations vs a
//! materialize-then-iterate baseline, the allocation-free workspace
//! local-stats step vs the allocating one, and one complete dAD exchange.
//! This is the harness the optimization pass iterates against.
//!
//! Emits BENCH_hotpath.json (see `dad::bench::JsonSink`) so CI tracks the
//! perf trajectory across PRs. Set DAD_BENCH_FAST=1 for a smoke run.
//!
//! Run: cargo bench --bench hotpath

use dad::bench::{bench, gflops, report, JsonSink, Timing};
use dad::lowrank::rankdad_factors;
use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::stats::LocalStats;
use dad::nn::Mlp;
use dad::tensor::{matmul, matmul_nt, matmul_tn, Matrix, Rng, Workspace};

/// The seed's kernels, frozen as the perf baseline: scoped-thread spawns
/// per call, unblocked ikj loops, dot-product / transpose-the-whole-B
/// regimes for A·Bᵀ. Kept verbatim (minus the dead `- 0`) so "speedup vs
/// pre-PR" in BENCH_hotpath.json measures exactly the engine change.
mod legacy {
    use dad::tensor::{parallel::num_threads, Matrix};

    fn rows_mut_spawning<F>(data: &mut [f32], row_len: usize, min_rows: usize, f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        let rows = if row_len == 0 { 0 } else { data.len() / row_len };
        if rows == 0 {
            return;
        }
        let nt = num_threads();
        let chunks = nt.min(rows.div_ceil(min_rows.max(1))).max(1);
        if chunks == 1 {
            f(0, data);
            return;
        }
        let per = rows.div_ceil(chunks);
        std::thread::scope(|s| {
            let mut rest = data;
            let mut row0 = 0usize;
            for _ in 0..chunks {
                let take = per.min(rest.len() / row_len);
                if take == 0 {
                    break;
                }
                let (head, tail) = rest.split_at_mut(take * row_len);
                rest = tail;
                let f = &f;
                let start = row0;
                s.spawn(move || f(start, head));
                row0 += take;
                if rest.is_empty() {
                    break;
                }
            }
        });
    }

    const PAR_FLOP_THRESHOLD: usize = 1 << 20;

    fn min_rows_for(total_rows: usize, flops: usize) -> usize {
        if flops < PAR_FLOP_THRESHOLD {
            total_rows
        } else {
            1
        }
    }

    pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (k2, n) = b.shape();
        assert_eq!(k, k2);
        let mut out = Matrix::zeros(m, n);
        let flops = 2 * m * k * n;
        let bd = b.data();
        let ad = a.data();
        rows_mut_spawning(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
            for (r, crow) in chunk.chunks_mut(n).enumerate() {
                let i = start + r;
                let arow = &ad[i * k..(i + 1) * k];
                for (kk, &aik) in arow.iter().enumerate() {
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += aik * bv;
                    }
                }
            }
        });
        out
    }

    pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
        let (k, m) = a.shape();
        let (k2, n) = b.shape();
        assert_eq!(k, k2);
        let mut out = Matrix::zeros(m, n);
        let flops = 2 * m * k * n;
        let ad = a.data();
        let bd = b.data();
        rows_mut_spawning(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
            let rows = chunk.len() / n;
            for kk in 0..k {
                let brow = &bd[kk * n..(kk + 1) * n];
                let acol = &ad[kk * m..(kk + 1) * m];
                for r in 0..rows {
                    let aik = acol[start + r];
                    if aik == 0.0 {
                        continue;
                    }
                    let crow = &mut chunk[r * n..(r + 1) * n];
                    for (c, &bv) in crow.iter_mut().zip(brow) {
                        *c += aik * bv;
                    }
                }
            }
        });
        out
    }

    pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (n, k2) = b.shape();
        assert_eq!(k, k2);
        let flops = 2 * m * k * n;
        if flops >= 1 << 22 {
            return matmul(a, &b.transpose());
        }
        let mut out = Matrix::zeros(m, n);
        let ad = a.data();
        let bd = b.data();
        rows_mut_spawning(out.data_mut(), n, min_rows_for(m, flops), |start, chunk| {
            for (r, crow) in chunk.chunks_mut(n).enumerate() {
                let i = start + r;
                let arow = &ad[i * k..(i + 1) * k];
                for (j, c) in crow.iter_mut().enumerate() {
                    let brow = &bd[j * k..(j + 1) * k];
                    *c = dad::tensor::dot(arow, brow);
                }
            }
        });
        out
    }
}

fn main() {
    let fast = std::env::var("DAD_BENCH_FAST").is_ok();
    let (wu, ns) = if fast { (1, 4) } else { (3, 15) };
    let mut rng = Rng::new(1);
    let threads = dad::tensor::parallel::num_threads();
    println!("== hotpath kernels ==  (threads: {threads}{})", if fast { ", fast" } else { "" });
    let mut sink = JsonSink::new();
    sink.meta("threads", &threads.to_string());
    sink.meta("fast", &fast.to_string());

    let duel = |sink: &mut JsonSink,
                    name: &str,
                    flops: usize,
                    new_t: Timing,
                    old_t: Timing| {
        report(&format!("{name} [engine]"), new_t);
        report(&format!("{name} [legacy]"), old_t);
        println!(
            "{:<48} {:.2} GFLOP/s, {:.2}x vs legacy",
            "",
            gflops(&new_t, flops),
            old_t.median_ns as f64 / new_t.median_ns.max(1) as f64
        );
        sink.add_vs_baseline(name, new_t, old_t, Some(flops));
    };

    // matmul at the paper's layer shapes (batch 64 = 2 sites x 32).
    for &(m, k, n, tag) in &[
        (64usize, 784usize, 1024usize, "matmul fwd fc1 64x784*784x1024"),
        (64, 1024, 1024, "matmul fwd fc2 64x1024*1024x1024"),
        (1024, 1024, 1024, "matmul square 1024^3"),
    ] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let t_new = bench(wu, ns, || matmul(&a, &b));
        let t_old = bench(wu, ns, || legacy::matmul(&a, &b));
        duel(&mut sink, tag, 2 * m * k * n, t_new, t_old);
    }

    // Gradient outer product and backward delta shapes.
    let a = Matrix::randn(64, 1024, 1.0, &mut rng);
    let d = Matrix::randn(64, 1024, 1.0, &mut rng);
    let t_new = bench(wu, ns, || matmul_tn(&a, &d));
    let t_old = bench(wu, ns, || legacy::matmul_tn(&a, &d));
    duel(&mut sink, "grad outer AᵀΔ 1024x64x1024", 2 * 64 * 1024 * 1024, t_new, t_old);

    let w = Matrix::randn(1024, 1024, 1.0, &mut rng);
    let t_new = bench(wu, ns, || matmul_nt(&d, &w));
    let t_old = bench(wu, ns, || legacy::matmul_nt(&d, &w));
    duel(&mut sink, "delta step ΔWᵀ 64x1024x1024", 2 * 64 * 1024 * 1024, t_new, t_old);

    // Structured power iterations (factored) vs materialized baseline.
    let (wu2, ns2) = if fast { (1, 3) } else { (2, 10) };
    let t_struct = bench(wu2, ns2, || rankdad_factors(&a, &d, 10, 10, 1e-3));
    report("rank-dad factors (structured, r=10, 10 it)", t_struct);
    let t_mat = bench(wu2, ns2, || {
        // Baseline: materialize M = AᵀΔ, then the same iteration on M
        // directly (the O(h^2) path of paper eq. 6).
        let m = matmul_tn(&a, &d);
        let mut g = vec![0.0f32; 1024];
        g[0] = 1.0;
        for _ in 0..10 * 10 {
            let u = dad::tensor::matvec(&m, &g);
            let g2 = dad::tensor::matvec_t(&m, &u);
            let n = g2.iter().map(|x| x * x).sum::<f32>().sqrt();
            for (gi, v) in g.iter_mut().zip(&g2) {
                *gi = v / n;
            }
        }
        g[0]
    });
    report("materialized power iteration baseline", t_mat);
    println!(
        "structured speedup vs materialized: {:.2}x",
        t_mat.median_ns as f64 / t_struct.median_ns as f64
    );
    sink.add_vs_baseline("rank-dad structured vs materialized", t_struct, t_mat, None);

    // Full local-stats step on the paper MLP: allocating entry point vs the
    // workspace-reusing one (the zero-allocation steady state).
    let mut mrng = Rng::new(42);
    let mlp = Mlp::paper_mnist(&mut mrng);
    let x = Matrix::rand_uniform(32, 784, 0.0, 1.0, &mut rng);
    let labels: Vec<usize> = (0..32).map(|i| i % 10).collect();
    let batch = Batch::Dense { x, y: one_hot(&labels, 10) };
    let t_alloc = bench(wu2, ns2, || mlp.local_stats(&batch));
    report("mlp local_stats (allocating, batch 32)", t_alloc);
    let mut ws = Workspace::new();
    let mut out = LocalStats::empty();
    let t_ws = bench(wu2, ns2, || mlp.local_stats_into(&batch, &mut ws, &mut out));
    report("mlp local_stats (workspace reuse)", t_ws);
    sink.add("mlp local_stats allocating", t_alloc);
    sink.add_vs_baseline("mlp local_stats workspace", t_ws, t_alloc, None);

    // Full synchronized steps (2 sites, incl. replica clone). The span
    // clock runs without --trace, so draining it around the dAD bench
    // yields the step's phase breakdown for the JSON summary.
    use dad::algos::common::DistAlgorithm;
    let (wu3, ns3) = if fast { (1, 3) } else { (1, 8) };
    let batches = vec![batch.clone(), batch.clone()];
    let _ = dad::obs::trace::take_step_timing(); // discard pre-bench residue
    let t = bench(wu3, ns3, || {
        let mut cluster = dad::dist::Cluster::replicate(mlp.clone(), 2);
        dad::algos::Dad.step(&mut cluster, &batches)
    });
    report("full dAD step (2 sites, incl. clone)", t);
    sink.add("full dAD step", t);
    let phases = dad::obs::trace::take_step_timing();
    println!(
        "  phase breakdown (all dAD runs): compute {:.4}s, comms {:.4}s, \
         stall {:.4}s, compress {:.4}s",
        phases.compute_s, phases.comms_s, phases.stall_s, phases.compress_s
    );
    sink.meta("dad_step_compute_s", &format!("{:.6}", phases.compute_s));
    sink.meta("dad_step_comms_s", &format!("{:.6}", phases.comms_s));
    sink.meta("dad_step_stall_s", &format!("{:.6}", phases.stall_s));
    sink.meta("dad_step_compress_s", &format!("{:.6}", phases.compress_s));
    let t = bench(wu3, ns3, || {
        let mut cluster = dad::dist::Cluster::replicate(mlp.clone(), 2);
        dad::algos::Dsgd.step(&mut cluster, &batches)
    });
    report("full dSGD step (2 sites, incl. clone)", t);
    sink.add("full dSGD step", t);

    sink.write("BENCH_hotpath.json").expect("writing BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json");
}

//! Bench: hot-path kernel timings (the §Perf working set) — matmul
//! variants at the paper's layer shapes, structured power iterations vs a
//! materialize-then-iterate baseline, the full local-stats step, and one
//! complete dAD exchange. This is the harness the optimization pass
//! iterates against.
//!
//! Run: cargo bench --bench hotpath

use dad::bench::{bench, gflops, report};
use dad::lowrank::rankdad_factors;
use dad::nn::loss::one_hot;
use dad::nn::model::{Batch, DistModel};
use dad::nn::Mlp;
use dad::tensor::{matmul, matmul_nt, matmul_tn, Matrix, Rng};

fn main() {
    let mut rng = Rng::new(1);
    println!("== hotpath kernels ==  (threads: {})", dad::tensor::parallel::num_threads());

    // matmul at the paper's three layer shapes (batch 64 = 2 sites x 32).
    for &(m, k, n, tag) in &[
        (64usize, 784usize, 1024usize, "fwd fc1  64x784 * 784x1024"),
        (64, 1024, 1024, "fwd fc2  64x1024 * 1024x1024"),
        (1024, 1024, 1024, "square   1024^3"),
    ] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let t = bench(3, 15, || matmul(&a, &b));
        report(&format!("matmul {tag}"), t);
        println!("{:<48} {:.2} GFLOP/s", "", gflops(&t, 2 * m * k * n));
    }
    // Gradient outer product and backward delta shapes.
    let a = Matrix::randn(64, 1024, 1.0, &mut rng);
    let d = Matrix::randn(64, 1024, 1.0, &mut rng);
    let t = bench(3, 15, || matmul_tn(&a, &d));
    report("grad outer AᵀΔ 1024x64x1024", t);
    println!("{:<48} {:.2} GFLOP/s", "", gflops(&t, 2 * 64 * 1024 * 1024));
    let w = Matrix::randn(1024, 1024, 1.0, &mut rng);
    let t = bench(3, 15, || matmul_nt(&d, &w));
    report("delta step ΔWᵀ 64x1024x1024", t);

    // Structured power iterations (factored) vs materialized baseline.
    let t_struct = bench(2, 10, || rankdad_factors(&a, &d, 10, 10, 1e-3));
    report("rank-dad factors (structured, r=10, 10 it)", t_struct);
    let t_mat = bench(2, 10, || {
        // Baseline: materialize M = AᵀΔ, then the same iteration on M
        // directly (the O(h^2) path of paper eq. 6).
        let m = matmul_tn(&a, &d);
        let mut g = vec![0.0f32; 1024];
        g[0] = 1.0;
        for _ in 0..10 * 10 {
            let u = dad::tensor::matvec(&m, &g);
            let g2 = dad::tensor::matvec_t(&m, &u);
            let n = g2.iter().map(|x| x * x).sum::<f32>().sqrt();
            for (gi, v) in g.iter_mut().zip(&g2) {
                *gi = v / n;
            }
        }
        g[0]
    });
    report("materialized power iteration baseline", t_mat);
    println!(
        "structured speedup vs materialized: {:.2}x",
        t_mat.median_ns as f64 / t_struct.median_ns as f64
    );

    // Full local-stats step + dAD exchange on the paper MLP.
    let mut mrng = Rng::new(42);
    let mlp = Mlp::paper_mnist(&mut mrng);
    let x = Matrix::rand_uniform(32, 784, 0.0, 1.0, &mut rng);
    let labels: Vec<usize> = (0..32).map(|i| i % 10).collect();
    let batch = Batch::Dense { x, y: one_hot(&labels, 10) };
    let t = bench(2, 10, || mlp.local_stats(&batch));
    report("mlp local_stats (batch 32, paper dims)", t);

    use dad::algos::common::DistAlgorithm;
    let batches = vec![batch.clone(), batch.clone()];
    let t = bench(1, 8, || {
        let mut cluster = dad::dist::Cluster::replicate(mlp.clone(), 2);
        dad::algos::Dad.step(&mut cluster, &batches)
    });
    report("full dAD step (2 sites, incl. clone)", t);
    let t = bench(1, 8, || {
        let mut cluster = dad::dist::Cluster::replicate(mlp.clone(), 2);
        dad::algos::Dsgd.step(&mut cluster, &batches)
    });
    report("full dSGD step (2 sites, incl. clone)", t);
}

//! Bench: regenerate the paper's Table 2 — max |grad_dist - grad_pooled|
//! per layer over one epoch, for dSGD / dAD / edAD. The paper reports
//! ~1e-7 for all three on all layers (f32 reduction-order noise); the
//! reproduction must stay in that regime.
//!
//! Run: cargo bench --bench table2_grad_error

use dad::coordinator::experiments::{table2, Scale};

fn main() {
    let scale = scale_from_env();
    println!("== Table 2 (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    let rows = table2(scale);
    println!("{:<26} {:>12} {:>12} {:>12}", "layer", "dSGD", "dAD", "edAD");
    for r in &rows {
        println!("{:<26} {:>12.3e} {:>12.3e} {:>12.3e}", r.layer, r.dsgd, r.dad, r.edad);
    }
    println!("paper: ~1.5e-7 .. 3.9e-7 on all layers/methods (f32 noise floor)");
    println!("[{:.1}s] results/table2.csv written", t0.elapsed().as_secs_f32());
    for r in &rows {
        assert!(r.dad < 1e-3 && r.edad < 1e-3 && r.dsgd < 1e-3, "exactness violated");
    }
}

fn scale_from_env() -> Scale {
    std::env::var("DAD_SCALE").ok().and_then(|s| Scale::parse(&s)).unwrap_or(Scale::Quick)
}

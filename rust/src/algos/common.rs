//! Shared machinery for the distributed algorithms.

use crate::algos::protocol::StepProtocol;
use crate::dist::Cluster;
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::LocalStats;
use crate::tensor::Matrix;

/// Result of one synchronized distributed step. Exact algorithms guarantee
/// `grads` is what *every* site computes; compressed ones guarantee all
/// sites reconstruct the same approximation (so replicas never diverge).
pub struct StepOutcome {
    /// Batch-size-weighted mean training loss across sites.
    pub loss: f32,
    /// Synchronized global gradient, aligned with the model's param list.
    pub grads: Vec<Matrix>,
    /// rank-dAD telemetry: per stats entry, per site, the effective rank
    /// chosen by the theta-stop. Empty for other algorithms.
    pub eff_ranks: Vec<Vec<usize>>,
    /// Bytes site->aggregator this step (sum over sites).
    pub bytes_up: u64,
    /// Bytes aggregator->sites this step (sum over receiving sites).
    pub bytes_down: u64,
}

/// A distributed training algorithm: one synchronized step over per-site
/// batches. Mutable to allow cross-step compressor state (PowerSGD's warm
/// start + error feedback).
pub trait DistAlgorithm<M: DistModel> {
    /// Algorithm name as reported in logs and CSVs.
    fn name(&self) -> &'static str;
    /// One synchronized step over per-site batches.
    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome;
    /// The algorithm's remote wire protocol: a fresh per-run state machine
    /// describing the same per-step exchange as typed rounds over a
    /// transport (see [`crate::algos::protocol`]). `dad serve`/`dad join`
    /// drive it through the generic drivers in `coordinator::remote`; the
    /// equivalence with [`DistAlgorithm::step`] — gradients, losses and
    /// per-(tag, direction) ledger bytes — is asserted by
    /// `tests/transport_e2e.rs`.
    fn protocol(&self) -> Box<dyn StepProtocol<M>>;
    /// Flattened cross-step compressor state for checkpointing (residuals,
    /// momenta, warm starts, ...), in a stable order the paired
    /// [`DistAlgorithm::load_state`] understands. Stateless algorithms
    /// return an empty list (the default).
    fn state_mats(&self) -> Vec<Matrix> {
        vec![]
    }
    /// Restore cross-step compressor state saved by
    /// [`DistAlgorithm::state_mats`]. The default accepts only an empty
    /// list: handing state to a stateless algorithm is a checkpoint
    /// mismatch, reported as an error rather than silently dropped.
    fn load_state(&mut self, mats: &[Matrix]) -> Result<(), String> {
        if mats.is_empty() {
            Ok(())
        } else {
            Err(format!("algorithm {} is stateless but the checkpoint carries state", self.name()))
        }
    }
}

/// Per-site local statistics + the global row count (Σ output-delta rows),
/// which sets the 1/(S*N) gradient scale.
pub struct GatheredStats {
    /// One `LocalStats` per site, in site order.
    pub per_site: Vec<LocalStats>,
    /// Σ per-site output-delta rows (the global batch size).
    pub total_rows: usize,
    /// Per-site output-delta row counts.
    pub site_rows: Vec<usize>,
}

/// Run every site's forward/backward on its batch (each on its own
/// persistent workspace) and collect the statistics.
pub fn gather_local_stats<M: DistModel>(cluster: &Cluster<M>, batches: &[Batch]) -> GatheredStats {
    assert_eq!(cluster.n_sites(), batches.len(), "one batch per site");
    // Each site computes on its own persistent workspace, so the forward/
    // backward scratch is reused across steps instead of re-allocated.
    let per_site: Vec<LocalStats> = cluster
        .sites
        .iter()
        .zip(batches)
        .map(|(s, b)| s.model.local_stats_ws(b, &mut s.ws.borrow_mut()))
        .collect();
    let site_rows: Vec<usize> =
        per_site.iter().map(|s| s.entries.last().expect("no stats entries").d.rows()).collect();
    let total_rows = site_rows.iter().sum();
    GatheredStats { per_site, total_rows, site_rows }
}

/// Batch-size-weighted mean loss (what the pooled model would report).
pub fn weighted_loss(stats: &GatheredStats) -> f32 {
    let num: f64 = stats
        .per_site
        .iter()
        .zip(&stats.site_rows)
        .map(|(s, &n)| s.loss as f64 * n as f64)
        .sum();
    (num / stats.total_rows as f64) as f32
}

/// dSGD-style exchange for `direct` gradients (embeddings, layer norms):
/// average across sites, count bytes both ways. Returns (param_idx, grad)
/// averaged — identical at every site. These parameters have no
/// outer-product structure, so every algorithm (including dAD/edAD/rank-dAD)
/// falls back to plain gradient averaging for them, exactly as the paper
/// does implicitly by evaluating on architectures where they are absent.
pub fn exchange_direct<M: DistModel>(
    cluster: &mut Cluster<M>,
    stats: &GatheredStats,
) -> Vec<(usize, Matrix)> {
    let n_direct = stats.per_site[0].direct.len();
    if n_direct == 0 {
        return vec![];
    }
    let scale = 1.0 / stats.total_rows as f32;
    // Canonical segment reduction (not a sequential site fold): the same
    // bracketing every tree level uses, so simulated sums stay bit-equal
    // to star *and* tree TCP runs (see `crate::algos::reduce`).
    let leaves: Vec<u32> = (0..stats.per_site.len() as u32).collect();
    let parts: Vec<Vec<Matrix>> = stats
        .per_site
        .iter()
        .map(|s| {
            debug_assert_eq!(s.direct.len(), n_direct);
            s.direct.iter().map(|(_, g)| g.clone()).collect()
        })
        .collect();
    let sums = crate::algos::reduce::reduce_dense(&leaves, parts)
        .expect("uniform direct-gradient layouts across sites")
        .expect("at least one site");
    let mut out: Vec<(usize, Matrix)> = Vec::with_capacity(n_direct);
    for (di, mut sum) in sums.into_iter().enumerate() {
        let idx = stats.per_site[0].direct[di].0;
        debug_assert!(stats.per_site.iter().all(|s| s.direct[di].0 == idx));
        sum.scale_inplace(scale);
        out.push((idx, sum));
    }
    // Bytes: each site uploads its direct grads once; the mean comes back.
    for s in &stats.per_site {
        let payload: Vec<&Matrix> = s.direct.iter().map(|(_, g)| g).collect();
        cluster.send_to_agg("direct-grad", &payload);
    }
    let payload: Vec<&Matrix> = out.iter().map(|(_, g)| g).collect();
    cluster.broadcast("direct-grad", &payload);
    out
}

/// Concatenate per-site batches into one pooled batch (for the pooled
/// baseline and for tests).
pub fn concat_batches(batches: &[Batch]) -> Batch {
    assert!(!batches.is_empty());
    match &batches[0] {
        Batch::Dense { .. } => {
            let xs: Vec<&Matrix> = batches
                .iter()
                .map(|b| match b {
                    Batch::Dense { x, .. } => x,
                    _ => panic!("mixed batch kinds"),
                })
                .collect();
            let ys: Vec<&Matrix> = batches
                .iter()
                .map(|b| match b {
                    Batch::Dense { y, .. } => y,
                    _ => unreachable!(),
                })
                .collect();
            Batch::Dense { x: Matrix::vertcat(&xs), y: Matrix::vertcat(&ys) }
        }
        Batch::Seq { xs: first_xs, .. } => {
            let t = first_xs.len();
            let xs: Vec<Matrix> = (0..t)
                .map(|ti| {
                    let parts: Vec<&Matrix> = batches
                        .iter()
                        .map(|b| match b {
                            Batch::Seq { xs, .. } => &xs[ti],
                            _ => panic!("mixed batch kinds"),
                        })
                        .collect();
                    Matrix::vertcat(&parts)
                })
                .collect();
            let ys: Vec<&Matrix> = batches
                .iter()
                .map(|b| match b {
                    Batch::Seq { y, .. } => y,
                    _ => unreachable!(),
                })
                .collect();
            Batch::Seq { xs, y: Matrix::vertcat(&ys) }
        }
        Batch::Tokens { t, .. } => {
            let t = *t;
            let mut ids = Vec::new();
            let mut targets = Vec::new();
            let mut btot = 0;
            for b in batches {
                match b {
                    Batch::Tokens { b: bb, t: tt, ids: i, targets: tg } => {
                        assert_eq!(*tt, t, "token batches must share T");
                        btot += bb;
                        ids.extend_from_slice(i);
                        targets.extend_from_slice(tg);
                    }
                    _ => panic!("mixed batch kinds"),
                }
            }
            Batch::Tokens { b: btot, t, ids, targets }
        }
    }
}

/// Snapshot ledger totals around a closure; returns (up_delta, down_delta).
pub fn measure_bytes<M, F: FnOnce(&mut Cluster<M>) -> R, R>(
    cluster: &mut Cluster<M>,
    f: F,
) -> (R, u64, u64) {
    use crate::dist::Direction;
    let up0 = cluster.ledger.total_dir(Direction::SiteToAgg);
    let down0 = cluster.ledger.total_dir(Direction::AggToSite);
    let r = f(cluster);
    let up1 = cluster.ledger.total_dir(Direction::SiteToAgg);
    let down1 = cluster.ledger.total_dir(Direction::AggToSite);
    (r, up1 - up0, down1 - down0)
}

//! The compressed algorithms: rank-dAD (the paper's section 3.4) and the
//! PowerSGD baseline (Vogels et al. 2019) it is compared against.
//!
//! rank-dAD factors the AD constituents *before* any gradient exists —
//! structured power iterations cost O(hN) per iteration and the theta-stop
//! adapts the transmitted rank to the gradient's effective rank. PowerSGD
//! compresses the *materialized* gradient with fixed rank r and error
//! feedback. Both ship Θ(r(h_i+h_{i+1})) per layer; rank-dAD's r is an
//! upper bound, PowerSGD's is exact.

use crate::algos::common::{
    exchange_direct, gather_local_stats, weighted_loss, DistAlgorithm, StepOutcome,
};
use crate::dist::Cluster;
use crate::lowrank::{orthonormalize_cols, rankdad_factors, PowerSgdState};
use crate::nn::model::{Batch, DistModel};
use crate::tensor::{Matrix, Rng};

/// Deterministic seed for PowerSGD's warm-start Q (identical on all sites).
const POWERSGD_SEED: u64 = 0x9d5f_17ab_33c0_44de;

/// rank-dAD configuration (paper defaults: 10 iterations, theta = 1e-3).
#[derive(Clone, Debug)]
pub struct RankDadConfig {
    /// Hard cap on the transmitted rank.
    pub max_rank: usize,
    /// Structured power iterations per factorization.
    pub n_iters: usize,
    /// Early-stop threshold on the singular-direction residual.
    pub theta: f32,
}

impl Default for RankDadConfig {
    fn default() -> Self {
        RankDadConfig { max_rank: 10, n_iters: 10, theta: 1e-3 }
    }
}

/// rank-dAD (section 3.4): adaptive low-rank factorization of the AD
/// statistics via structured power iterations, before any gradient is
/// materialized.
pub struct RankDad {
    /// Rank/iteration/theta configuration.
    pub cfg: RankDadConfig,
}

impl RankDad {
    /// Paper-default config at the given max rank.
    pub fn new(max_rank: usize) -> Self {
        RankDad { cfg: RankDadConfig { max_rank, ..Default::default() } }
    }
}

impl<M: DistModel> DistAlgorithm<M> for RankDad {
    fn name(&self) -> &'static str {
        "rank-dad"
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = bytes_now(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();
        let n_sites = stats.per_site.len();

        let mut eff_ranks: Vec<Vec<usize>> = vec![Vec::with_capacity(n_sites); n_entries];
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();

        for ei in 0..n_entries {
            // Each site factors its local outer product (never materializing
            // the gradient) and ships the theta-truncated factors.
            let mut q_parts: Vec<Matrix> = Vec::with_capacity(n_sites);
            let mut g_parts: Vec<Matrix> = Vec::with_capacity(n_sites);
            for s in &stats.per_site {
                let e = &s.entries[ei];
                let f =
                    rankdad_factors(&e.a, &e.d, self.cfg.max_rank, self.cfg.n_iters, self.cfg.theta);
                let (q, g) = f.truncated();
                cluster.send_to_agg("lowrank-q", &[&q]);
                cluster.send_to_agg("lowrank-g", &[&g]);
                eff_ranks[ei].push(f.eff_rank);
                q_parts.push(q);
                g_parts.push(g);
            }
            // Aggregator: stack along the rank dimension; broadcast. The
            // reconstruction is linear: sum_s Q_sᵀ G_s = Q̂ᵀ Ĝ.
            let q_refs: Vec<&Matrix> = q_parts.iter().collect();
            let g_refs: Vec<&Matrix> = g_parts.iter().collect();
            let q_hat = Matrix::vertcat(&q_refs);
            let g_hat = Matrix::vertcat(&g_refs);
            cluster.broadcast("lowrank-q", &[&q_hat]);
            cluster.broadcast("lowrank-g", &[&g_hat]);
            let e0 = &stats.per_site[0].entries[ei];
            let mut gw = crate::tensor::matmul_tn(&q_hat, &g_hat);
            gw.scale_inplace(scale);
            grads[e0.w_idx] = gw;
            // Bias gradients: colsum(Δ) has no outer-product form; ship the
            // tiny (1 x h_out) vectors dSGD-style.
            if let Some(bi) = e0.b_idx {
                grads[bi] = exchange_bias(cluster, &stats.per_site, ei, scale);
            }
        }
        let direct = exchange_direct(cluster, &stats);
        for (idx, g) in direct {
            grads[idx] = g;
        }
        let (up1, down1) = bytes_now(cluster);
        StepOutcome {
            loss: weighted_loss(&stats),
            grads,
            eff_ranks,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
        }
    }
}

/// PowerSGD baseline: rank-r compression of the materialized local
/// gradients with warm start + error feedback, two-phase mean (P then Q).
pub struct PowerSgd {
    /// Fixed compression rank r.
    pub rank: usize,
    /// `states[site][entry]` — per-site error feedback, shared warm start.
    states: Vec<Vec<PowerSgdState>>,
}

impl PowerSgd {
    /// Fresh compressor state at rank `rank` (lazy-initialized on first
    /// step, when the entry shapes are known).
    pub fn new(rank: usize) -> Self {
        PowerSgd { rank, states: vec![] }
    }
}

impl<M: DistModel> DistAlgorithm<M> for PowerSgd {
    fn name(&self) -> &'static str {
        "powersgd"
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = bytes_now(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();
        let n_sites = stats.per_site.len();

        // Lazy init: one compressor per (site, entry); identical seeds so
        // the warm-start Q agrees everywhere.
        if self.states.is_empty() {
            self.states = (0..n_sites)
                .map(|_| {
                    let mut rng = Rng::new(POWERSGD_SEED);
                    stats.per_site[0]
                        .entries
                        .iter()
                        .map(|e| {
                            let (r, c) = shapes[e.w_idx];
                            PowerSgdState::new(r, c, self.rank, &mut rng)
                        })
                        .collect()
                })
                .collect();
        }

        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for ei in 0..n_entries {
            let e0_widx = stats.per_site[0].entries[ei].w_idx;
            // Local "mean-equivalent" gradient: S * contribution, so the
            // cross-site mean equals the global mean gradient.
            let locals: Vec<Matrix> = stats
                .per_site
                .iter()
                .map(|s| s.entries[ei].weight_grad(scale * n_sites as f32))
                .collect();
            // Phase 1: P_s = (M_s + err_s) Q ; allreduce-mean; orthonormalize.
            let mut p_mean: Option<Matrix> = None;
            for (si, m) in locals.iter().enumerate() {
                let p = self.states[si][ei].compress_p(m);
                cluster.send_to_agg("psgd-p", &[&p]);
                p_mean = Some(match p_mean {
                    None => p,
                    Some(mut acc) => {
                        acc.axpy(1.0, &p);
                        acc
                    }
                });
            }
            let mut p_hat = p_mean.unwrap();
            p_hat.scale_inplace(1.0 / n_sites as f32);
            orthonormalize_cols(&mut p_hat);
            cluster.broadcast("psgd-p", &[&p_hat]);
            // Phase 2: Q_s = (M_s+err_s)ᵀ P̂ ; allreduce-mean; broadcast.
            let mut q_mean: Option<Matrix> = None;
            for si in 0..n_sites {
                let q = self.states[si][ei].compress_q(&p_hat);
                cluster.send_to_agg("psgd-q", &[&q]);
                q_mean = Some(match q_mean {
                    None => q,
                    Some(mut acc) => {
                        acc.axpy(1.0, &q);
                        acc
                    }
                });
            }
            let mut q_hat = q_mean.unwrap();
            q_hat.scale_inplace(1.0 / n_sites as f32);
            cluster.broadcast("psgd-q", &[&q_hat]);
            // Reconstruct M̂ = P̂ Q̂ᵀ (same everywhere); update per-site
            // error feedback err_s = (M_s + err_s) - M̂.
            let mut m_hat = Matrix::zeros(0, 0);
            for si in 0..n_sites {
                m_hat = self.states[si][ei].finish(&p_hat, &q_hat);
            }
            grads[e0_widx] = m_hat; // ≈ global mean gradient
            if let Some(bi) = stats.per_site[0].entries[ei].b_idx {
                grads[bi] = exchange_bias(cluster, &stats.per_site, ei, scale);
            }
        }
        let direct = exchange_direct(cluster, &stats);
        for (idx, g) in direct {
            grads[idx] = g;
        }
        let (up1, down1) = bytes_now(cluster);
        StepOutcome {
            loss: weighted_loss(&stats),
            grads,
            eff_ranks: vec![],
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
        }
    }
}

/// Bias-gradient exchange shared by the compressed algorithms.
fn exchange_bias<M>(
    cluster: &mut Cluster<M>,
    per_site: &[crate::nn::stats::LocalStats],
    ei: usize,
    scale: f32,
) -> Matrix {
    let mut bsum = per_site[0].entries[ei].bias_grad(scale);
    for s in &per_site[1..] {
        bsum.axpy(1.0, &s.entries[ei].bias_grad(scale));
    }
    for s in per_site {
        let bg = s.entries[ei].bias_grad(scale);
        cluster.send_to_agg("bias-grad", &[&bg]);
    }
    cluster.broadcast("bias-grad", &[&bsum]);
    bsum
}

fn bytes_now<M>(cluster: &Cluster<M>) -> (u64, u64) {
    use crate::dist::Direction;
    (
        cluster.ledger.total_dir(Direction::SiteToAgg),
        cluster.ledger.total_dir(Direction::AggToSite),
    )
}

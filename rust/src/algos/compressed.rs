//! The compressed algorithms: rank-dAD (the paper's section 3.4) and the
//! PowerSGD baseline (Vogels et al. 2019) it is compared against.
//!
//! rank-dAD factors the AD constituents *before* any gradient exists —
//! structured power iterations cost O(hN) per iteration and the theta-stop
//! adapts the transmitted rank to the gradient's effective rank. PowerSGD
//! compresses the *materialized* gradient with fixed rank r and error
//! feedback. Both ship Θ(r(h_i+h_{i+1})) per layer; rank-dAD's r is an
//! upper bound, PowerSGD's is exact.

use std::io;

use crate::algos::common::{
    exchange_direct, gather_local_stats, weighted_loss, DistAlgorithm, StepOutcome,
};
use crate::algos::protocol::{
    agg_direct_exchange, ctrl_from_leaves, gather_stack1, gather_sum, site_direct_exchange,
    AggExchange, Endpoint, Round, StepMeta, StepPlan, StepProtocol, StepSync,
};
use crate::dist::wire::{proto_err, ByteReader, ByteWriter};
use crate::dist::Cluster;
use crate::lowrank::{orthonormalize_cols, rankdad_factors, PowerSgdState};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::LocalStats;
use crate::tensor::{matmul_nt, matmul_tn, Matrix, Rng};

/// Deterministic seed for PowerSGD's warm-start Q (identical on all sites).
const POWERSGD_SEED: u64 = 0x9d5f_17ab_33c0_44de;

/// rank-dAD configuration (paper defaults: 10 iterations, theta = 1e-3).
#[derive(Clone, Debug)]
pub struct RankDadConfig {
    /// Hard cap on the transmitted rank.
    pub max_rank: usize,
    /// Structured power iterations per factorization.
    pub n_iters: usize,
    /// Early-stop threshold on the singular-direction residual.
    pub theta: f32,
}

impl Default for RankDadConfig {
    fn default() -> Self {
        RankDadConfig { max_rank: 10, n_iters: 10, theta: 1e-3 }
    }
}

/// rank-dAD (section 3.4): adaptive low-rank factorization of the AD
/// statistics via structured power iterations, before any gradient is
/// materialized.
pub struct RankDad {
    /// Rank/iteration/theta configuration.
    pub cfg: RankDadConfig,
}

impl RankDad {
    /// Paper-default config at the given max rank.
    pub fn new(max_rank: usize) -> Self {
        RankDad { cfg: RankDadConfig { max_rank, ..Default::default() } }
    }
}

impl<M: DistModel> DistAlgorithm<M> for RankDad {
    fn name(&self) -> &'static str {
        "rank-dad"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(RankDadProtocol { cfg: self.cfg.clone() })
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = bytes_now(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();
        let n_sites = stats.per_site.len();

        let mut eff_ranks: Vec<Vec<usize>> = vec![Vec::with_capacity(n_sites); n_entries];
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();

        for ei in 0..n_entries {
            // Each site factors its local outer product (never materializing
            // the gradient) and ships the theta-truncated factors.
            let mut q_parts: Vec<Matrix> = Vec::with_capacity(n_sites);
            let mut g_parts: Vec<Matrix> = Vec::with_capacity(n_sites);
            for s in &stats.per_site {
                let e = &s.entries[ei];
                let f =
                    rankdad_factors(&e.a, &e.d, self.cfg.max_rank, self.cfg.n_iters, self.cfg.theta);
                let (q, g) = f.truncated();
                cluster.send_to_agg("lowrank-q", &[&q]);
                cluster.send_to_agg("lowrank-g", &[&g]);
                eff_ranks[ei].push(f.eff_rank);
                q_parts.push(q);
                g_parts.push(g);
            }
            // Aggregator: stack along the rank dimension; broadcast. The
            // reconstruction is linear: sum_s Q_sᵀ G_s = Q̂ᵀ Ĝ.
            let q_refs: Vec<&Matrix> = q_parts.iter().collect();
            let g_refs: Vec<&Matrix> = g_parts.iter().collect();
            let q_hat = Matrix::vertcat(&q_refs);
            let g_hat = Matrix::vertcat(&g_refs);
            cluster.broadcast("lowrank-q", &[&q_hat]);
            cluster.broadcast("lowrank-g", &[&g_hat]);
            let e0 = &stats.per_site[0].entries[ei];
            let mut gw = crate::tensor::matmul_tn(&q_hat, &g_hat);
            gw.scale_inplace(scale);
            grads[e0.w_idx] = gw;
            // Bias gradients: colsum(Δ) has no outer-product form; ship the
            // tiny (1 x h_out) vectors dSGD-style.
            if let Some(bi) = e0.b_idx {
                grads[bi] = exchange_bias(cluster, &stats.per_site, ei, scale);
            }
        }
        let direct = exchange_direct(cluster, &stats);
        for (idx, g) in direct {
            grads[idx] = g;
        }
        let (up1, down1) = bytes_now(cluster);
        StepOutcome {
            loss: weighted_loss(&stats),
            grads,
            eff_ranks,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
        }
    }
}

/// PowerSGD baseline: rank-r compression of the materialized local
/// gradients with warm start + error feedback, two-phase mean (P then Q).
pub struct PowerSgd {
    /// Fixed compression rank r.
    pub rank: usize,
    /// `states[site][entry]` — per-site error feedback, shared warm start.
    states: Vec<Vec<PowerSgdState>>,
    /// Checkpointed `(q, err)` pairs waiting for the lazy init.
    pending: Vec<Matrix>,
}

impl PowerSgd {
    /// Fresh compressor state at rank `rank` (lazy-initialized on first
    /// step, when the entry shapes are known).
    pub fn new(rank: usize) -> Self {
        PowerSgd { rank, states: vec![], pending: vec![] }
    }
}

impl<M: DistModel> DistAlgorithm<M> for PowerSgd {
    fn name(&self) -> &'static str {
        "powersgd"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(PowerSgdProtocol::new(self.rank))
    }

    fn state_mats(&self) -> Vec<Matrix> {
        // Stable flattening: per site, per entry, warm-start Q then the
        // error-feedback accumulator. `load_state` consumes the same order.
        let mut out = Vec::new();
        for site in &self.states {
            for st in site {
                let (q, err) = st.state_mats();
                out.push(q.clone());
                out.push(err.clone());
            }
        }
        out
    }

    fn load_state(&mut self, mats: &[Matrix]) -> Result<(), String> {
        if mats.len() % 2 != 0 {
            return Err("powersgd checkpoint state must be (q, err) pairs".into());
        }
        self.pending = mats.to_vec();
        Ok(())
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = bytes_now(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();
        let n_sites = stats.per_site.len();

        // Lazy init: one compressor per (site, entry); identical seeds so
        // the warm-start Q agrees everywhere.
        if self.states.is_empty() {
            self.states = (0..n_sites)
                .map(|_| {
                    let mut rng = Rng::new(POWERSGD_SEED);
                    stats.per_site[0]
                        .entries
                        .iter()
                        .map(|e| {
                            let (r, c) = shapes[e.w_idx];
                            PowerSgdState::new(r, c, self.rank, &mut rng)
                        })
                        .collect()
                })
                .collect();
            if !self.pending.is_empty() {
                assert_eq!(
                    self.pending.len(),
                    n_sites * n_entries * 2,
                    "checkpointed powersgd state arity mismatch"
                );
                let mut it = std::mem::take(&mut self.pending).into_iter();
                for site in self.states.iter_mut() {
                    for st in site.iter_mut() {
                        let q = it.next().expect("arity checked");
                        let err = it.next().expect("arity checked");
                        *st = PowerSgdState::from_state(self.rank, q, err);
                    }
                }
            }
        }

        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for ei in 0..n_entries {
            let e0_widx = stats.per_site[0].entries[ei].w_idx;
            // Local "mean-equivalent" gradient: S * contribution, so the
            // cross-site mean equals the global mean gradient.
            let locals: Vec<Matrix> = stats
                .per_site
                .iter()
                .map(|s| s.entries[ei].weight_grad(scale * n_sites as f32))
                .collect();
            // Phase 1: P_s = (M_s + err_s) Q ; allreduce-mean (canonical
            // segment sum); orthonormalize.
            let mut p_parts: Vec<Matrix> = Vec::with_capacity(n_sites);
            for (si, m) in locals.iter().enumerate() {
                let p = self.states[si][ei].compress_p(m);
                cluster.send_to_agg("psgd-p", &[&p]);
                p_parts.push(p);
            }
            let mut p_hat = canonical_sum(p_parts.into_iter());
            p_hat.scale_inplace(1.0 / n_sites as f32);
            orthonormalize_cols(&mut p_hat);
            cluster.broadcast("psgd-p", &[&p_hat]);
            // Phase 2: Q_s = (M_s+err_s)ᵀ P̂ ; allreduce-mean; broadcast.
            let mut q_parts: Vec<Matrix> = Vec::with_capacity(n_sites);
            for si in 0..n_sites {
                let q = self.states[si][ei].compress_q(&p_hat);
                cluster.send_to_agg("psgd-q", &[&q]);
                q_parts.push(q);
            }
            let mut q_hat = canonical_sum(q_parts.into_iter());
            q_hat.scale_inplace(1.0 / n_sites as f32);
            cluster.broadcast("psgd-q", &[&q_hat]);
            // Reconstruct M̂ = P̂ Q̂ᵀ (same everywhere); update per-site
            // error feedback err_s = (M_s + err_s) - M̂.
            let mut m_hat = Matrix::zeros(0, 0);
            for si in 0..n_sites {
                m_hat = self.states[si][ei].finish(&p_hat, &q_hat);
            }
            grads[e0_widx] = m_hat; // ≈ global mean gradient
            if let Some(bi) = stats.per_site[0].entries[ei].b_idx {
                grads[bi] = exchange_bias(cluster, &stats.per_site, ei, scale);
            }
        }
        let direct = exchange_direct(cluster, &stats);
        for (idx, g) in direct {
            grads[idx] = g;
        }
        let (up1, down1) = bytes_now(cluster);
        StepOutcome {
            loss: weighted_loss(&stats),
            grads,
            eff_ranks: vec![],
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
        }
    }
}

/// Bias-gradient exchange shared by the compressed and sparsified
/// algorithms. The sum uses the canonical segment bracketing so it stays
/// bit-equal to star and tree wire runs (see `crate::algos::reduce`).
pub(crate) fn exchange_bias<M>(
    cluster: &mut Cluster<M>,
    per_site: &[crate::nn::stats::LocalStats],
    ei: usize,
    scale: f32,
) -> Matrix {
    let bsum = canonical_sum(per_site.iter().map(|s| s.entries[ei].bias_grad(scale)));
    for s in per_site {
        let bg = s.entries[ei].bias_grad(scale);
        cluster.send_to_agg("bias-grad", &[&bg]);
    }
    cluster.broadcast("bias-grad", &[&bsum]);
    bsum
}

/// Canonical segment sum of one matrix per site (site i = leaf i).
pub(crate) fn canonical_sum(parts: impl Iterator<Item = Matrix>) -> Matrix {
    let parts: Vec<Vec<Matrix>> = parts.map(|m| vec![m]).collect();
    let leaves: Vec<u32> = (0..parts.len() as u32).collect();
    crate::algos::reduce::reduce_dense(&leaves, parts)
        .expect("uniform shapes across sites")
        .expect("at least one site")
        .pop()
        .expect("exactly one matrix per site")
}

pub(crate) fn bytes_now<M>(cluster: &Cluster<M>) -> (u64, u64) {
    use crate::dist::Direction;
    (
        cluster.ledger.total_dir(Direction::SiteToAgg),
        cluster.ledger.total_dir(Direction::AggToSite),
    )
}

// ---------------------------------------------------------------------------
// Wire protocols
// ---------------------------------------------------------------------------

/// Wire protocol for [`RankDad`]: each site factors its local outer
/// products and ships the theta-truncated `(q, g)` pairs as `lowrank-q` /
/// `lowrank-g` payload frames plus one ledger-exempt `eff-rank` control
/// frame (the adaptive-bandwidth telemetry); the aggregator stacks the
/// factors along the rank dimension (concatenation is exact — the
/// reconstruction is linear: Σ_s Q_sᵀ G_s = Q̂ᵀ Ĝ) and broadcasts. Bias
/// and direct gradients ride dSGD-style as in the simulation.
pub struct RankDadProtocol {
    /// Rank/iteration/theta configuration (shared with the simulated path).
    pub cfg: RankDadConfig,
}

impl<M: DistModel> StepProtocol<M> for RankDadProtocol {
    fn name(&self) -> &'static str {
        "rank-dad"
    }

    fn supports_degrade(&self) -> bool {
        // The factored concat (Q̂, Ĝ) and the 1/N scale follow the sync
        // frame; the site half never reads the startup site count.
        true
    }

    fn plan(&self, metas: &[StepMeta]) -> io::Result<StepPlan> {
        let meta = metas.first().ok_or_else(|| proto_err("plan needs site metas".into()))?;
        let mut rounds = Vec::new();
        for _ in &meta.entries {
            rounds.push(Round::UpStack { tag: "lowrank-q" });
            rounds.push(Round::UpStack { tag: "lowrank-g" });
        }
        rounds.push(Round::CtrlUp { tag: "eff-rank" });
        for _ in &meta.entries {
            rounds.push(Round::Down { tag: "lowrank-q" });
            rounds.push(Round::Down { tag: "lowrank-g" });
        }
        for &(_, b_idx) in &meta.entries {
            if b_idx != u32::MAX {
                rounds.push(Round::UpSum { tag: "bias-grad" });
                rounds.push(Round::Down { tag: "bias-grad" });
            }
        }
        if !meta.direct_idx.is_empty() {
            rounds.push(Round::UpSum { tag: "direct-grad" });
            rounds.push(Round::Down { tag: "direct-grad" });
        }
        Ok(StepPlan { rounds })
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        let mut ranks = ByteWriter::new();
        ranks.push_u16(stats.entries.len() as u16);
        for e in &stats.entries {
            let f =
                rankdad_factors(&e.a, &e.d, self.cfg.max_rank, self.cfg.n_iters, self.cfg.theta);
            let (q, g) = f.truncated();
            ep.up("lowrank-q", &[&q])?;
            ep.up("lowrank-g", &[&g])?;
            ranks.push_u16(f.eff_rank as u16);
        }
        ep.ctrl_up("eff-rank", &ranks.finish())?;
        for e in &stats.entries {
            let q_hat = ep.down1("lowrank-q")?;
            let g_hat = ep.down1("lowrank-g")?;
            let mut gw = matmul_tn(&q_hat, &g_hat);
            gw.scale_inplace(scale);
            grads[e.w_idx] = gw;
        }
        // Bias gradients: colsum(Δ) has no outer-product form; dSGD-style.
        for e in &stats.entries {
            if e.b_idx.is_some() {
                let bg = e.bias_grad(scale);
                ep.up("bias-grad", &[&bg])?;
            }
        }
        for e in &stats.entries {
            if let Some(bi) = e.b_idx {
                grads[bi] = ep.down1("bias-grad")?;
            }
        }
        for (idx, g) in site_direct_exchange(ep, stats)? {
            grads[idx] = g;
        }
        Ok(grads)
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        let n_entries = metas[0].entries.len();
        for (site, meta) in metas.iter().enumerate() {
            if meta.entries.len() != n_entries {
                return Err(proto_err(format!("site {site} stats layout mismatch")));
            }
        }
        // Round-major, mirroring plan(): per entry, stack the Q then the G
        // factors across every link (each link's frames arrive in its FIFO
        // order, so this consumes exactly the site half's send sequence).
        let mut q_hats: Vec<Matrix> = Vec::with_capacity(n_entries);
        let mut g_hats: Vec<Matrix> = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            q_hats.push(gather_stack1(ep, "lowrank-q")?);
            g_hats.push(gather_stack1(ep, "lowrank-g")?);
        }
        // eff-rank telemetry: one control body per leaf (relay links ship
        // them batched), expanded in ascending leaf order.
        let mut eff_ranks: Vec<Vec<usize>> = vec![Vec::new(); n_entries];
        for link in 0..ep.n_links() {
            for (leaf, body) in ctrl_from_leaves(ep, link, "eff-rank")? {
                let mut r = ByteReader::new(&body);
                if r.read_u16()? as usize != n_entries {
                    return Err(proto_err(format!("leaf {leaf} eff-rank arity mismatch")));
                }
                for ranks in eff_ranks.iter_mut() {
                    ranks.push(r.read_u16()? as usize);
                }
            }
        }
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for (ei, (q_hat, g_hat)) in q_hats.iter().zip(&g_hats).enumerate() {
            ep.bcast("lowrank-q", &[q_hat])?;
            ep.bcast("lowrank-g", &[g_hat])?;
            let mut gw = matmul_tn(q_hat, g_hat);
            gw.scale_inplace(scale);
            grads[metas[0].entries[ei].0 as usize] = gw;
        }
        // Biases: canonical segment sums of the per-leaf scaled bias grads,
        // broadcast per entry. Per-link FIFO is respected: leaves ship
        // their biases in entry order, and each gather_sum round reads
        // exactly one frame per link.
        for &(_, b_idx) in &metas[0].entries {
            if b_idx == u32::MAX {
                continue;
            }
            let sum = gather_sum(ep, "bias-grad")?;
            ep.bcast("bias-grad", &[&sum])?;
            grads[b_idx as usize] = sum;
        }
        for (idx, g) in agg_direct_exchange(ep, metas, scale)? {
            grads[idx] = g;
        }
        Ok(AggExchange { grads, eff_ranks })
    }
}

/// Wire protocol for [`PowerSgd`]: the two-phase factored all-reduce.
/// Phase 1 ships P = (M + err) Q up; the aggregator means and
/// orthonormalizes P̂ and broadcasts it. Phase 2 ships Q = (M + err)ᵀ P̂ up;
/// the aggregator means and broadcasts Q̂; every endpoint reconstructs
/// M̂ = P̂ Q̂ᵀ. The warm-start Q and the error-feedback accumulator live in
/// this value — **site-local**, exactly one compressor per process, unlike
/// the simulation's god's-eye `states[site][entry]` table.
pub struct PowerSgdProtocol {
    rank: usize,
    states: Vec<PowerSgdState>,
}

impl PowerSgdProtocol {
    /// Fresh protocol state at compression rank `rank` (compressors are
    /// lazy-initialized on the first step, when entry shapes are known,
    /// from the shared deterministic seed so every site's warm start
    /// agrees).
    pub fn new(rank: usize) -> Self {
        PowerSgdProtocol { rank, states: vec![] }
    }
}

impl<M: DistModel> StepProtocol<M> for PowerSgdProtocol {
    fn name(&self) -> &'static str {
        "powersgd"
    }

    fn plan(&self, metas: &[StepMeta]) -> io::Result<StepPlan> {
        let meta = metas.first().ok_or_else(|| proto_err("plan needs site metas".into()))?;
        let mut rounds = Vec::new();
        for &(_, b_idx) in &meta.entries {
            rounds.push(Round::UpSum { tag: "psgd-p" });
            rounds.push(Round::Down { tag: "psgd-p" });
            rounds.push(Round::UpSum { tag: "psgd-q" });
            rounds.push(Round::Down { tag: "psgd-q" });
            if b_idx != u32::MAX {
                rounds.push(Round::UpSum { tag: "bias-grad" });
                rounds.push(Round::Down { tag: "bias-grad" });
            }
        }
        if !meta.direct_idx.is_empty() {
            rounds.push(Round::UpSum { tag: "direct-grad" });
            rounds.push(Round::Down { tag: "direct-grad" });
        }
        Ok(StepPlan { rounds })
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        let n_sites = ep.n_sites();
        if self.states.is_empty() {
            let mut rng = Rng::new(POWERSGD_SEED);
            self.states = stats
                .entries
                .iter()
                .map(|e| {
                    let (r, c) = shapes[e.w_idx];
                    PowerSgdState::new(r, c, self.rank, &mut rng)
                })
                .collect();
        }
        if self.states.len() != stats.entries.len() {
            return Err(proto_err("powersgd state/entry arity mismatch".into()));
        }
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for (ei, e) in stats.entries.iter().enumerate() {
            // Mean-equivalent local gradient: S x contribution, so the
            // cross-site mean equals the global mean gradient.
            let m = e.weight_grad(scale * n_sites as f32);
            let p = self.states[ei].compress_p(&m);
            ep.up("psgd-p", &[&p])?;
            let p_hat = ep.down1("psgd-p")?;
            let q = self.states[ei].compress_q(&p_hat);
            ep.up("psgd-q", &[&q])?;
            let q_hat = ep.down1("psgd-q")?;
            grads[e.w_idx] = self.states[ei].finish(&p_hat, &q_hat);
            if let Some(bi) = e.b_idx {
                let bg = e.bias_grad(scale);
                ep.up("bias-grad", &[&bg])?;
                grads[bi] = ep.down1("bias-grad")?;
            }
        }
        for (idx, g) in site_direct_exchange(ep, stats)? {
            grads[idx] = g;
        }
        Ok(grads)
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        let n_sites = metas.len();
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for &(w_idx, b_idx) in &metas[0].entries {
            // Phase 1: mean the P factors (canonical segment sum over the
            // live leaves — the simulated reduction bracketing),
            // orthonormalize, broadcast.
            let mut p_hat = gather_sum(ep, "psgd-p")?;
            p_hat.scale_inplace(1.0 / n_sites as f32);
            orthonormalize_cols(&mut p_hat);
            ep.bcast("psgd-p", &[&p_hat])?;
            // Phase 2: mean the Q factors, broadcast, reconstruct.
            let mut q_hat = gather_sum(ep, "psgd-q")?;
            q_hat.scale_inplace(1.0 / n_sites as f32);
            ep.bcast("psgd-q", &[&q_hat])?;
            grads[w_idx as usize] = matmul_nt(&p_hat, &q_hat);
            if b_idx != u32::MAX {
                let bsum = gather_sum(ep, "bias-grad")?;
                ep.bcast("bias-grad", &[&bsum])?;
                grads[b_idx as usize] = bsum;
            }
        }
        for (idx, g) in agg_direct_exchange(ep, metas, scale)? {
            grads[idx] = g;
        }
        Ok(AggExchange { grads, eff_ranks: vec![] })
    }
}

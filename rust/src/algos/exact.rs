//! The exact algorithms: pooled (single-site oracle), dSGD (gradient
//! averaging), dAD (Algorithm 1) and edAD (Algorithm 2). All four compute
//! the *same* global gradient; they differ only in what crosses the wire —
//! which is precisely the paper's Table-2/Figure-1 claim, asserted
//! bit-tight in this module's tests.

use std::io;

use crate::algos::common::{
    exchange_direct, gather_local_stats, weighted_loss, DistAlgorithm, StepOutcome,
};
use crate::algos::protocol::{
    agg_direct_exchange, gather_seg_sum, gather_stack1, site_direct_exchange, AggExchange,
    Endpoint, Round, StepMeta, StepPlan, StepProtocol, StepSync,
};
use crate::dist::wire::proto_err;
use crate::dist::Cluster;
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{assemble_grads, concat_stats, LocalStats, StatsEntry};
use crate::tensor::Matrix;

/// Pooled baseline: one model sees the union batch; no communication.
pub struct Pooled;

impl<M: DistModel> DistAlgorithm<M> for Pooled {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(PooledProtocol)
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let pooled = crate::algos::common::concat_batches(batches);
        let site = &cluster.sites[0];
        let stats = site.model.local_stats_ws(&pooled, &mut site.ws.borrow_mut());
        let rows = stats.entries.last().unwrap().d.rows();
        let scale = 1.0 / rows as f32;
        let shapes = cluster.sites[0].model.param_shapes();
        let grads = stats.assemble_grads(&shapes, scale, scale);
        StepOutcome { loss: stats.loss, grads, eff_ranks: vec![], bytes_up: 0, bytes_down: 0 }
    }
}

/// Distributed SGD: the classical baseline — every site ships its *full
/// local gradient*, the aggregator averages, sites apply the mean.
pub struct Dsgd;

impl<M: DistModel> DistAlgorithm<M> for Dsgd {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(DsgdProtocol)
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = step_bytes(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        // Per-site full gradients (scaled so the sum is the global mean),
        // summed in the canonical segment bracketing every aggregation
        // level shares (see `crate::algos::reduce`).
        let mut parts: Vec<Vec<Matrix>> = Vec::with_capacity(stats.per_site.len());
        for s in &stats.per_site {
            let g = assemble_grads(&shapes, &s.entries, &s.direct, scale, scale);
            // Wire: the entire gradient (every parameter tensor).
            let refs: Vec<&Matrix> = g.iter().collect();
            cluster.send_to_agg("grad", &refs);
            parts.push(g);
        }
        let leaves: Vec<u32> = (0..parts.len() as u32).collect();
        let grads = crate::algos::reduce::reduce_dense(&leaves, parts)
            .expect("uniform gradient layouts across sites")
            .expect("at least one site");
        let refs: Vec<&Matrix> = grads.iter().collect();
        cluster.broadcast("grad", &refs);
        let (up1, down1) = step_bytes(cluster);
        let (bytes_up, bytes_down) = (up1 - up0, down1 - down0);
        StepOutcome { loss: weighted_loss(&stats), grads, eff_ranks: vec![], bytes_up, bytes_down }
    }
}

/// dAD (Algorithm 1): ship (A_{i-1}, Δ_i) per layer; the aggregator
/// vertcats along the batch dim and broadcasts; every site computes the
/// exact global gradient as Â ᵀ Δ̂.
pub struct Dad;

impl<M: DistModel> DistAlgorithm<M> for Dad {
    fn name(&self) -> &'static str {
        "dad"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(DadProtocol)
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = step_bytes(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        // Site -> aggregator: every entry's (A, Δ).
        for s in &stats.per_site {
            for e in &s.entries {
                cluster.send_to_agg("acts", &[&e.a]);
                cluster.send_to_agg("deltas", &[&e.d]);
            }
        }
        // Aggregator: vertcat; broadcast Â and Δ̂ to all sites.
        let entry_refs: Vec<&[StatsEntry]> = stats.per_site.iter().map(|s| &s.entries[..]).collect();
        let cat = concat_stats(&entry_refs);
        for e in &cat {
            cluster.broadcast("acts", &[&e.a]);
            cluster.broadcast("deltas", &[&e.d]);
        }
        let direct = exchange_direct(cluster, &stats);
        // Every site now computes the identical global gradient.
        let grads = assemble_grads(&shapes, &cat, &direct, scale, 1.0);
        let (up1, down1) = step_bytes(cluster);
        let (bytes_up, bytes_down) = (up1 - up0, down1 - down0);
        StepOutcome { loss: weighted_loss(&stats), grads, eff_ranks: vec![], bytes_up, bytes_down }
    }
}

/// edAD (Algorithm 2): only the output delta Δ_L ever travels; hidden
/// deltas are recomputed at the aggregated level from broadcast activations
/// via the derivative-from-output identity — halving communication while
/// staying exact.
pub struct Edad;

impl<M: DistModel> DistAlgorithm<M> for Edad {
    fn name(&self) -> &'static str {
        "edad"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(EdadProtocol)
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = step_bytes(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();

        // Site -> aggregator: A-stacks for every entry, aux activations,
        // and Δ_L (the last entry's delta) only.
        for s in &stats.per_site {
            for e in &s.entries {
                cluster.send_to_agg("acts", &[&e.a]);
            }
            for aux in &s.aux {
                cluster.send_to_agg("aux-acts", &[aux]);
            }
            cluster.send_to_agg("delta-L", &[&s.entries[n_entries - 1].d]);
        }
        // Aggregator: vertcat all of it; broadcast.
        let a_hats: Vec<Matrix> = (0..n_entries)
            .map(|i| {
                let parts: Vec<&Matrix> = stats.per_site.iter().map(|s| &s.entries[i].a).collect();
                Matrix::vertcat(&parts)
            })
            .collect();
        let n_aux = stats.per_site[0].aux.len();
        let aux_hats: Vec<Matrix> = (0..n_aux)
            .map(|i| {
                let parts: Vec<&Matrix> = stats.per_site.iter().map(|s| &s.aux[i]).collect();
                Matrix::vertcat(&parts)
            })
            .collect();
        let dl_parts: Vec<&Matrix> =
            stats.per_site.iter().map(|s| &s.entries[n_entries - 1].d).collect();
        let delta_l = Matrix::vertcat(&dl_parts);
        for a in &a_hats {
            cluster.broadcast("acts", &[a]);
        }
        for a in &aux_hats {
            cluster.broadcast("aux-acts", &[a]);
        }
        cluster.broadcast("delta-L", &[&delta_l]);

        // Sites recompute the aggregated deltas locally (eq. 5).
        let recomputed = cluster.sites[0]
            .model
            .edad_recompute(&a_hats, &aux_hats, &delta_l, &stats.site_rows)
            .expect(
                "model does not support edAD (DistModel::supports_edad is false) — \
                 the coordinators reject this combination up front; use dad",
            );
        let direct = exchange_direct(cluster, &stats);
        let grads = assemble_grads(&shapes, &recomputed, &direct, scale, 1.0);
        let (up1, down1) = step_bytes(cluster);
        let (bytes_up, bytes_down) = (up1 - up0, down1 - down0);
        StepOutcome { loss: weighted_loss(&stats), grads, eff_ranks: vec![], bytes_up, bytes_down }
    }
}

/// Cumulative ledger totals (per-step deltas are taken around each step).
fn step_bytes<M>(cluster: &Cluster<M>) -> (u64, u64) {
    use crate::dist::Direction;
    (
        cluster.ledger.total_dir(Direction::SiteToAgg),
        cluster.ledger.total_dir(Direction::AggToSite),
    )
}

// ---------------------------------------------------------------------------
// Wire protocols (the same exchanges as typed rounds over a Transport)
// ---------------------------------------------------------------------------

/// Vertcat a per-site stack list in site order (the aggregator's reduce).
fn vertcat_parts(parts: &[Matrix]) -> Matrix {
    let refs: Vec<&Matrix> = parts.iter().collect();
    Matrix::vertcat(&refs)
}

/// Wire protocol for [`Pooled`]: the oracle ships nothing. Every process
/// (the aggregator included) rebuilds the union batch from the seed and
/// computes the pooled gradient locally; only the meta/sync prologue
/// crosses the wire, so the remote ledger is empty — exactly like the
/// simulated oracle's.
pub struct PooledProtocol;

impl<M: DistModel> StepProtocol<M> for PooledProtocol {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn oracle(&self) -> bool {
        true
    }

    fn supports_degrade(&self) -> bool {
        // Every process rebuilds the union batch from the seed, so a lost
        // site changes nothing about the survivors' math.
        true
    }

    fn plan(&self, _metas: &[StepMeta]) -> io::Result<StepPlan> {
        // The oracle ships no payload frames: nothing to relay.
        Ok(StepPlan { rounds: vec![] })
    }

    fn site_exchange(
        &mut self,
        _ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let scale = sync.scale();
        Ok(stats.assemble_grads(&model.param_shapes(), scale, scale))
    }

    fn agg_exchange(
        &mut self,
        _ep: &mut Endpoint<'_>,
        _model: &M,
        _metas: &[StepMeta],
        _sync: &StepSync,
    ) -> io::Result<AggExchange> {
        Err(proto_err(
            "the pooled oracle has no aggregator half; the driver runs the site half \
             on the union batch"
                .into(),
        ))
    }
}

/// Wire protocol for [`Dsgd`]: each site ships its full scaled local
/// gradient; the aggregator sums (the sum of the 1/N-scaled locals *is*
/// the global mean) and broadcasts the result.
pub struct DsgdProtocol;

impl<M: DistModel> StepProtocol<M> for DsgdProtocol {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn supports_degrade(&self) -> bool {
        // The 1/N scale comes from the sync frame's surviving row total,
        // so the degraded mean is the mean over the survivors.
        true
    }

    fn plan(&self, _metas: &[StepMeta]) -> io::Result<StepPlan> {
        Ok(StepPlan {
            rounds: vec![Round::UpSum { tag: "grad" }, Round::Down { tag: "grad" }],
        })
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        let local = stats.assemble_grads(&shapes, scale, scale);
        let refs: Vec<&Matrix> = local.iter().collect();
        ep.up("grad", &refs)?;
        let grads = ep.down("grad")?;
        if grads.len() != shapes.len() {
            return Err(proto_err("grad broadcast arity mismatch".into()));
        }
        Ok(grads)
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        _sync: &StepSync,
    ) -> io::Result<AggExchange> {
        let _ = metas;
        let shapes = model.param_shapes();
        let grads = gather_seg_sum(ep, "grad", shapes.len())?;
        let refs: Vec<&Matrix> = grads.iter().collect();
        ep.bcast("grad", &refs)?;
        Ok(AggExchange { grads, eff_ranks: vec![] })
    }
}

/// Wire protocol for [`Dad`] — Algorithm 1 as typed rounds: per-entry
/// (A, Δ) uplinks, concatenated (Â, Δ̂) broadcasts, direct-grad averaging,
/// local gradient assembly at every endpoint.
pub struct DadProtocol;

impl<M: DistModel> StepProtocol<M> for DadProtocol {
    fn name(&self) -> &'static str {
        "dad"
    }

    fn supports_degrade(&self) -> bool {
        // (Â, Δ̂) concatenation and the 1/N scale are both shaped by the
        // sync frame, so the exchange shrinks with the survivor set.
        true
    }

    fn plan(&self, metas: &[StepMeta]) -> io::Result<StepPlan> {
        let meta = metas.first().ok_or_else(|| proto_err("plan needs site metas".into()))?;
        let mut rounds = Vec::new();
        for _ in &meta.entries {
            rounds.push(Round::UpStack { tag: "acts" });
            rounds.push(Round::UpStack { tag: "deltas" });
        }
        for _ in &meta.entries {
            rounds.push(Round::Down { tag: "acts" });
            rounds.push(Round::Down { tag: "deltas" });
        }
        if !meta.direct_idx.is_empty() {
            rounds.push(Round::UpSum { tag: "direct-grad" });
            rounds.push(Round::Down { tag: "direct-grad" });
        }
        Ok(StepPlan { rounds })
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        for e in &stats.entries {
            ep.up("acts", &[&e.a])?;
            ep.up("deltas", &[&e.d])?;
        }
        let mut cat: Vec<StatsEntry> = Vec::with_capacity(stats.entries.len());
        for e in &stats.entries {
            let a = ep.down1("acts")?;
            let d = ep.down1("deltas")?;
            cat.push(StatsEntry { w_idx: e.w_idx, b_idx: e.b_idx, a, d });
        }
        let direct = site_direct_exchange(ep, stats)?;
        Ok(assemble_grads(&model.param_shapes(), &cat, &direct, sync.scale(), 1.0))
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange> {
        // Round-major, mirroring plan(): one tag's stack is gathered across
        // every link before the next round starts. Each link's frames still
        // arrive in its FIFO order, so this consumes exactly the site
        // half's send sequence.
        let layout = &metas[0].entries;
        for (site, meta) in metas.iter().enumerate() {
            if meta.entries != *layout {
                return Err(proto_err(format!("site {site} stats layout mismatch")));
            }
        }
        let mut cat: Vec<StatsEntry> = Vec::with_capacity(layout.len());
        for &(w_idx, b_idx) in layout {
            let a = gather_stack1(ep, "acts")?;
            let d = gather_stack1(ep, "deltas")?;
            cat.push(StatsEntry {
                w_idx: w_idx as usize,
                b_idx: (b_idx != u32::MAX).then_some(b_idx as usize),
                a,
                d,
            });
        }
        for e in &cat {
            ep.bcast("acts", &[&e.a])?;
            ep.bcast("deltas", &[&e.d])?;
        }
        let scale = sync.scale();
        let direct = agg_direct_exchange(ep, metas, scale)?;
        let grads = assemble_grads(&model.param_shapes(), &cat, &direct, scale, 1.0);
        Ok(AggExchange { grads, eff_ranks: vec![] })
    }
}

/// Wire protocol for [`Edad`] — Algorithm 2 as typed rounds: A-stacks,
/// aux activations and Δ_L travel; every endpoint recomputes the hidden
/// aggregated deltas locally via the model's derivative-from-output
/// identity (eq. 5).
pub struct EdadProtocol;

impl<M: DistModel> StepProtocol<M> for EdadProtocol {
    fn name(&self) -> &'static str {
        "edad"
    }

    fn plan(&self, _metas: &[StepMeta]) -> io::Result<StepPlan> {
        Err(proto_err(
            "edad: weight-coupled delta recomputation is not an associative reduction, \
             so edad cannot run on a tree topology (use dad, or a flat star)"
                .into(),
        ))
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let n_entries = stats.entries.len();
        if n_entries == 0 {
            return Err(proto_err("edad needs at least one stats entry".into()));
        }
        for e in &stats.entries {
            ep.up("acts", &[&e.a])?;
        }
        for aux in &stats.aux {
            ep.up("aux-acts", &[aux])?;
        }
        ep.up("delta-L", &[&stats.entries[n_entries - 1].d])?;
        let mut a_hats = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            a_hats.push(ep.down1("acts")?);
        }
        let mut aux_hats = Vec::with_capacity(stats.aux.len());
        for _ in 0..stats.aux.len() {
            aux_hats.push(ep.down1("aux-acts")?);
        }
        let delta_l = ep.down1("delta-L")?;
        let recomputed = model
            .edad_recompute(&a_hats, &aux_hats, &delta_l, &sync.site_rows)
            .ok_or_else(|| proto_err("model does not support edAD (use dad)".into()))?;
        let direct = site_direct_exchange(ep, stats)?;
        Ok(assemble_grads(&model.param_shapes(), &recomputed, &direct, sync.scale(), 1.0))
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange> {
        let n_entries = metas[0].entries.len();
        let n_aux = metas[0].n_aux as usize;
        let mut a_parts: Vec<Vec<Matrix>> = vec![Vec::new(); n_entries];
        let mut aux_parts: Vec<Vec<Matrix>> = vec![Vec::new(); n_aux];
        let mut dl_parts: Vec<Matrix> = Vec::with_capacity(metas.len());
        for (site, meta) in metas.iter().enumerate() {
            if meta.entries.len() != n_entries || meta.n_aux as usize != n_aux {
                return Err(proto_err(format!("site {site} stats layout mismatch")));
            }
            for part in a_parts.iter_mut() {
                part.push(ep.gather1(site, "acts")?);
            }
            for part in aux_parts.iter_mut() {
                part.push(ep.gather1(site, "aux-acts")?);
            }
            dl_parts.push(ep.gather1(site, "delta-L")?);
        }
        let a_hats: Vec<Matrix> = a_parts.iter().map(|p| vertcat_parts(p)).collect();
        let aux_hats: Vec<Matrix> = aux_parts.iter().map(|p| vertcat_parts(p)).collect();
        let delta_l = vertcat_parts(&dl_parts);
        for a in &a_hats {
            ep.bcast("acts", &[a])?;
        }
        for a in &aux_hats {
            ep.bcast("aux-acts", &[a])?;
        }
        ep.bcast("delta-L", &[&delta_l])?;
        let recomputed = model
            .edad_recompute(&a_hats, &aux_hats, &delta_l, &sync.site_rows)
            .ok_or_else(|| proto_err("model does not support edAD (use dad)".into()))?;
        let scale = sync.scale();
        let direct = agg_direct_exchange(ep, metas, scale)?;
        let grads = assemble_grads(&model.param_shapes(), &recomputed, &direct, scale, 1.0);
        Ok(AggExchange { grads, eff_ranks: vec![] })
    }
}

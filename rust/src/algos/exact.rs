//! The exact algorithms: pooled (single-site oracle), dSGD (gradient
//! averaging), dAD (Algorithm 1) and edAD (Algorithm 2). All four compute
//! the *same* global gradient; they differ only in what crosses the wire —
//! which is precisely the paper's Table-2/Figure-1 claim, asserted
//! bit-tight in this module's tests.

use crate::algos::common::{
    exchange_direct, gather_local_stats, weighted_loss, DistAlgorithm, StepOutcome,
};
use crate::dist::Cluster;
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{assemble_grads, concat_stats, StatsEntry};
use crate::tensor::Matrix;

/// Pooled baseline: one model sees the union batch; no communication.
pub struct Pooled;

impl<M: DistModel> DistAlgorithm<M> for Pooled {
    fn name(&self) -> &'static str {
        "pooled"
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let pooled = crate::algos::common::concat_batches(batches);
        let site = &cluster.sites[0];
        let stats = site.model.local_stats_ws(&pooled, &mut site.ws.borrow_mut());
        let rows = stats.entries.last().unwrap().d.rows();
        let scale = 1.0 / rows as f32;
        let shapes = cluster.sites[0].model.param_shapes();
        let grads = stats.assemble_grads(&shapes, scale, scale);
        StepOutcome { loss: stats.loss, grads, eff_ranks: vec![], bytes_up: 0, bytes_down: 0 }
    }
}

/// Distributed SGD: the classical baseline — every site ships its *full
/// local gradient*, the aggregator averages, sites apply the mean.
pub struct Dsgd;

impl<M: DistModel> DistAlgorithm<M> for Dsgd {
    fn name(&self) -> &'static str {
        "dsgd"
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = step_bytes(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        // Per-site full gradients (scaled so the sum is the global mean).
        let mut grads: Option<Vec<Matrix>> = None;
        for s in &stats.per_site {
            let g = assemble_grads(&shapes, &s.entries, &s.direct, scale, scale);
            // Wire: the entire gradient (every parameter tensor).
            let refs: Vec<&Matrix> = g.iter().collect();
            cluster.send_to_agg("grad", &refs);
            grads = Some(match grads {
                None => g,
                Some(mut acc) => {
                    for (a, b) in acc.iter_mut().zip(&g) {
                        a.axpy(1.0, b);
                    }
                    acc
                }
            });
        }
        let grads = grads.unwrap();
        let refs: Vec<&Matrix> = grads.iter().collect();
        cluster.broadcast("grad", &refs);
        let (up1, down1) = step_bytes(cluster);
        let (bytes_up, bytes_down) = (up1 - up0, down1 - down0);
        StepOutcome { loss: weighted_loss(&stats), grads, eff_ranks: vec![], bytes_up, bytes_down }
    }
}

/// dAD (Algorithm 1): ship (A_{i-1}, Δ_i) per layer; the aggregator
/// vertcats along the batch dim and broadcasts; every site computes the
/// exact global gradient as Â ᵀ Δ̂.
pub struct Dad;

impl<M: DistModel> DistAlgorithm<M> for Dad {
    fn name(&self) -> &'static str {
        "dad"
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = step_bytes(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        // Site -> aggregator: every entry's (A, Δ).
        for s in &stats.per_site {
            for e in &s.entries {
                cluster.send_to_agg("acts", &[&e.a]);
                cluster.send_to_agg("deltas", &[&e.d]);
            }
        }
        // Aggregator: vertcat; broadcast Â and Δ̂ to all sites.
        let entry_refs: Vec<&[StatsEntry]> = stats.per_site.iter().map(|s| &s.entries[..]).collect();
        let cat = concat_stats(&entry_refs);
        for e in &cat {
            cluster.broadcast("acts", &[&e.a]);
            cluster.broadcast("deltas", &[&e.d]);
        }
        let direct = exchange_direct(cluster, &stats);
        // Every site now computes the identical global gradient.
        let grads = assemble_grads(&shapes, &cat, &direct, scale, 1.0);
        let (up1, down1) = step_bytes(cluster);
        let (bytes_up, bytes_down) = (up1 - up0, down1 - down0);
        StepOutcome { loss: weighted_loss(&stats), grads, eff_ranks: vec![], bytes_up, bytes_down }
    }
}

/// edAD (Algorithm 2): only the output delta Δ_L ever travels; hidden
/// deltas are recomputed at the aggregated level from broadcast activations
/// via the derivative-from-output identity — halving communication while
/// staying exact.
pub struct Edad;

impl<M: DistModel> DistAlgorithm<M> for Edad {
    fn name(&self) -> &'static str {
        "edad"
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = step_bytes(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();

        // Site -> aggregator: A-stacks for every entry, aux activations,
        // and Δ_L (the last entry's delta) only.
        for s in &stats.per_site {
            for e in &s.entries {
                cluster.send_to_agg("acts", &[&e.a]);
            }
            for aux in &s.aux {
                cluster.send_to_agg("aux-acts", &[aux]);
            }
            cluster.send_to_agg("delta-L", &[&s.entries[n_entries - 1].d]);
        }
        // Aggregator: vertcat all of it; broadcast.
        let a_hats: Vec<Matrix> = (0..n_entries)
            .map(|i| {
                let parts: Vec<&Matrix> = stats.per_site.iter().map(|s| &s.entries[i].a).collect();
                Matrix::vertcat(&parts)
            })
            .collect();
        let n_aux = stats.per_site[0].aux.len();
        let aux_hats: Vec<Matrix> = (0..n_aux)
            .map(|i| {
                let parts: Vec<&Matrix> = stats.per_site.iter().map(|s| &s.aux[i]).collect();
                Matrix::vertcat(&parts)
            })
            .collect();
        let dl_parts: Vec<&Matrix> =
            stats.per_site.iter().map(|s| &s.entries[n_entries - 1].d).collect();
        let delta_l = Matrix::vertcat(&dl_parts);
        for a in &a_hats {
            cluster.broadcast("acts", &[a]);
        }
        for a in &aux_hats {
            cluster.broadcast("aux-acts", &[a]);
        }
        cluster.broadcast("delta-L", &[&delta_l]);

        // Sites recompute the aggregated deltas locally (eq. 5).
        let recomputed = cluster.sites[0]
            .model
            .edad_recompute(&a_hats, &aux_hats, &delta_l, &stats.site_rows)
            .expect("model does not support edAD (use dAD)");
        let direct = exchange_direct(cluster, &stats);
        let grads = assemble_grads(&shapes, &recomputed, &direct, scale, 1.0);
        let (up1, down1) = step_bytes(cluster);
        let (bytes_up, bytes_down) = (up1 - up0, down1 - down0);
        StepOutcome { loss: weighted_loss(&stats), grads, eff_ranks: vec![], bytes_up, bytes_down }
    }
}

/// Cumulative ledger totals (per-step deltas are taken around each step).
fn step_bytes<M>(cluster: &Cluster<M>) -> (u64, u64) {
    use crate::dist::Direction;
    (
        cluster.ledger.total_dir(Direction::SiteToAgg),
        cluster.ledger.total_dir(Direction::AggToSite),
    )
}

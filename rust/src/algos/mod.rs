//! The training algorithms the paper evaluates, behind one trait. Per-layer
//! uplink bytes are per site, for batch N and an h_i x h_{i+1} layer
//! (dad-p2p has no aggregator: its cost is per *peer*, times S-1 links):
//!
//! | algorithm | exactness | per-layer wire bytes |
//! |---|---|---|
//! | `pooled`    | oracle (single site)      | 0 |
//! | `dsgd`      | exact                     | h_i * h_{i+1} |
//! | `dad`       | exact (Algorithm 1)       | N (h_i + h_{i+1}) |
//! | `dad-p2p`   | exact (section 3.6)       | N (h_i + h_{i+1}) x (S-1) peers |
//! | `edad`      | exact (Algorithm 2)       | N h_i (+ Δ_L once) |
//! | `rank-dad`  | low-rank, adaptive (§3.4) | r_eff (h_i + h_{i+1}), r_eff <= r |
//! | `powersgd`  | low-rank, fixed (baseline)| r (h_i + h_{i+1}) |
//! | `dgc:k`     | sparse top-k + momentum-corrected error feedback | 2 (k/100) h_i h_{i+1} |
//! | `vbc`       | sparse, variance-gated + error feedback          | 2 k_t h_i h_{i+1}, k_t adaptive |
//! | `adacomp`   | sparse, bin-thresholded + error feedback         | 2 k_t h_i h_{i+1}, k_t adaptive |
//!
//! (The sparse rows' factor 2 is the honest u32-index overhead: each
//! transmitted element ships 8 wire bytes, two f32-equivalents.)
//!
//! Every spelling accepted by [`AlgoSpec::parse`] (and therefore by the
//! CLI's `--algo`) appears above; keep the three in sync.

pub mod common;
pub mod compressed;
pub mod exact;
pub mod p2p;
pub mod protocol;
pub mod reduce;
pub mod sparsified;

pub use common::{concat_batches, DistAlgorithm, StepOutcome};
pub use compressed::{PowerSgd, PowerSgdProtocol, RankDad, RankDadConfig, RankDadProtocol};
pub use exact::{
    Dad, DadProtocol, Dsgd, DsgdProtocol, Edad, EdadProtocol, Pooled, PooledProtocol,
};
pub use p2p::{DadP2p, DadP2pProtocol};
pub use protocol::{AggExchange, Endpoint, Round, StepMeta, StepPlan, StepProtocol, StepSync};
pub use sparsified::{SparseAlgo, SparseProtocol, SparseRule};

use crate::nn::model::DistModel;

/// Algorithm selector (config/CLI surface).
#[derive(Clone, Debug, PartialEq)]
pub enum AlgoSpec {
    /// Single-site oracle: the union batch, no communication.
    Pooled,
    /// Distributed SGD: full-gradient averaging.
    Dsgd,
    /// dAD (Algorithm 1): ship (A, Δ) stacks, star topology.
    Dad,
    /// Decentralized dAD (section 3.6): no aggregator, all-to-all stats.
    DadP2p,
    /// edAD (Algorithm 2): ship A-stacks + Δ_L only.
    Edad,
    /// rank-dAD (section 3.4): adaptive low-rank factors.
    RankDad {
        /// Hard cap on the transmitted rank.
        max_rank: usize,
        /// Power iterations per factorization.
        n_iters: usize,
        /// Early-stop threshold.
        theta: f32,
    },
    /// PowerSGD baseline: fixed-rank gradient compression.
    PowerSgd {
        /// Compression rank.
        rank: usize,
    },
    /// Deep Gradient Compression: momentum-corrected top-k sparsification.
    Dgc {
        /// Transmitted percentage of elements per entry, in (0, 100].
        density: f32,
    },
    /// Variance-based compression: transmit batch-significant elements.
    Vbc {
        /// Significance threshold λ >= 0 (0 transmits everything).
        lambda: f32,
    },
    /// AdaComp: bin-local self-adjusting sparsification threshold.
    AdaComp {
        /// Bin size in elements (1 = per-element bins = full density).
        bin: usize,
    },
}

impl AlgoSpec {
    /// Parse a CLI/config spelling: `pooled | dsgd | dad | dad-p2p | edad |
    /// rank-dad[:r] | powersgd[:r]`.
    ///
    /// Malformed spellings are hard errors, not silent fallbacks: a
    /// non-numeric or zero `:rank` argument (`rank-dad:abc`) used to train
    /// at the default rank 10 with the wrong config on record — now it
    /// fails with a message the CLI surfaces.
    pub fn parse(s: &str) -> Result<AlgoSpec, String> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let no_arg = |spec: AlgoSpec| match arg {
            None => Ok(spec),
            Some(a) => Err(format!("algorithm {name:?} takes no :argument (got {a:?})")),
        };
        let rank = |default: usize| match arg {
            None => Ok(default),
            Some(a) => match a.parse::<usize>() {
                Ok(r) if r >= 1 => Ok(r),
                _ => Err(format!(
                    "rank argument {a:?} for {name:?} must be a positive integer (e.g. {name}:8)"
                )),
            },
        };
        match name {
            "pooled" => no_arg(AlgoSpec::Pooled),
            "dsgd" => no_arg(AlgoSpec::Dsgd),
            "dad" => no_arg(AlgoSpec::Dad),
            "dad-p2p" | "dadp2p" => no_arg(AlgoSpec::DadP2p),
            "edad" => no_arg(AlgoSpec::Edad),
            "rank-dad" | "rankdad" => {
                Ok(AlgoSpec::RankDad { max_rank: rank(10)?, n_iters: 10, theta: 1e-3 })
            }
            "powersgd" | "power-sgd" => Ok(AlgoSpec::PowerSgd { rank: rank(10)? }),
            "dgc" => {
                let density = match arg {
                    None => 1.0,
                    Some(a) => match a.parse::<f32>() {
                        Ok(d) if d > 0.0 && d <= 100.0 => d,
                        _ => {
                            return Err(format!(
                                "density argument {a:?} for \"dgc\" must be a percentage \
                                 in (0, 100] (e.g. dgc:25)"
                            ))
                        }
                    },
                };
                Ok(AlgoSpec::Dgc { density })
            }
            "vbc" => {
                let lambda = match arg {
                    None => 2.0,
                    Some(a) => match a.parse::<f32>() {
                        Ok(l) if l >= 0.0 && l.is_finite() => l,
                        _ => {
                            return Err(format!(
                                "lambda argument {a:?} for \"vbc\" must be a finite \
                                 non-negative number (e.g. vbc:2)"
                            ))
                        }
                    },
                };
                Ok(AlgoSpec::Vbc { lambda })
            }
            "adacomp" | "ada-comp" => {
                let bin = match arg {
                    None => 512,
                    Some(a) => match a.parse::<usize>() {
                        Ok(b) if b >= 1 => b,
                        _ => {
                            return Err(format!(
                                "bin argument {a:?} for {name:?} must be a positive \
                                 integer bin size (e.g. adacomp:512)"
                            ))
                        }
                    },
                };
                Ok(AlgoSpec::AdaComp { bin })
            }
            other => Err(format!(
                "unknown algorithm {other:?} \
                 (pooled | dsgd | dad | dad-p2p | edad | rank-dad[:r] | powersgd[:r] | \
                 dgc[:k%] | vbc[:lambda] | adacomp[:bin])"
            )),
        }
    }

    /// Instantiate the selected algorithm for model type `M`.
    pub fn build<M: DistModel>(&self) -> Box<dyn DistAlgorithm<M>> {
        match *self {
            AlgoSpec::Pooled => Box::new(Pooled),
            AlgoSpec::Dsgd => Box::new(Dsgd),
            AlgoSpec::Dad => Box::new(Dad),
            AlgoSpec::DadP2p => Box::new(DadP2p),
            AlgoSpec::Edad => Box::new(Edad),
            AlgoSpec::RankDad { max_rank, n_iters, theta } => {
                Box::new(RankDad { cfg: RankDadConfig { max_rank, n_iters, theta } })
            }
            AlgoSpec::PowerSgd { rank } => Box::new(PowerSgd::new(rank)),
            AlgoSpec::Dgc { density } => Box::new(SparseAlgo::dgc(density)),
            AlgoSpec::Vbc { lambda } => Box::new(SparseAlgo::vbc(lambda)),
            AlgoSpec::AdaComp { bin } => Box::new(SparseAlgo::adacomp(bin)),
        }
    }

    /// Whether `dad serve --resume` can restore this algorithm's cross-step
    /// state from an aggregator-side checkpoint. The sparse compressors and
    /// PowerSGD keep **site-local** protocol state (residuals, momenta,
    /// error feedback) inside each `dad join` process; an aggregator
    /// checkpoint cannot rehydrate a remote process's private state, so TCP
    /// resume refuses those algorithms up front instead of silently
    /// desyncing. Loopback (`dad train --resume`) restores every algorithm,
    /// because the simulation owns all site state.
    pub fn remote_resumable(&self) -> bool {
        !matches!(
            self,
            AlgoSpec::PowerSgd { .. }
                | AlgoSpec::Dgc { .. }
                | AlgoSpec::Vbc { .. }
                | AlgoSpec::AdaComp { .. }
        )
    }

    /// Canonical spelling (round-trips through [`AlgoSpec::parse`]).
    pub fn name(&self) -> String {
        match self {
            AlgoSpec::Pooled => "pooled".into(),
            AlgoSpec::Dsgd => "dsgd".into(),
            AlgoSpec::Dad => "dad".into(),
            AlgoSpec::DadP2p => "dad-p2p".into(),
            AlgoSpec::Edad => "edad".into(),
            AlgoSpec::RankDad { max_rank, .. } => format!("rank-dad:{max_rank}"),
            AlgoSpec::PowerSgd { rank } => format!("powersgd:{rank}"),
            AlgoSpec::Dgc { density } => format!("dgc:{density}"),
            AlgoSpec::Vbc { lambda } => format!("vbc:{lambda}"),
            AlgoSpec::AdaComp { bin } => format!("adacomp:{bin}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::Cluster;
    use crate::nn::loss::one_hot;
    use crate::nn::model::Batch;
    use crate::nn::{Activation, Mlp};
    use crate::tensor::{Matrix, Rng};

    fn setup(seed: u64) -> (Cluster<Mlp>, Vec<Batch>) {
        let mut rng = Rng::new(seed);
        let mlp = Mlp::new(&[12, 16, 10, 4], &[Activation::Relu, Activation::Tanh], &mut rng);
        let cluster = Cluster::replicate(mlp, 2);
        let batches: Vec<Batch> = (0..2)
            .map(|s| {
                let x = Matrix::randn(6, 12, 1.0, &mut rng);
                // Disjoint labels per site — the paper's hard non-IID split.
                let labels: Vec<usize> = (0..6).map(|i| (s * 2 + i % 2) as usize).collect();
                Batch::Dense { x, y: one_hot(&labels, 4) }
            })
            .collect();
        (cluster, batches)
    }

    /// THE core claim (paper §4.1, Table 2): dAD and edAD gradients are
    /// exactly the pooled gradients; dSGD matches too. Tolerance reflects
    /// f32 reduction-order noise only.
    #[test]
    fn exact_algorithms_match_pooled() {
        let (mut cluster, batches) = setup(1);
        let pooled = Pooled.step(&mut cluster, &batches);
        let (mut c2, b2) = setup(1);
        let dsgd = Dsgd.step(&mut c2, &b2);
        let (mut c3, b3) = setup(1);
        let dad = Dad.step(&mut c3, &b3);
        let (mut c4, b4) = setup(1);
        let edad = Edad.step(&mut c4, &b4);
        for (i, pg) in pooled.grads.iter().enumerate() {
            let e_dsgd = pg.max_abs_diff(&dsgd.grads[i]);
            let e_dad = pg.max_abs_diff(&dad.grads[i]);
            let e_edad = pg.max_abs_diff(&edad.grads[i]);
            assert!(e_dsgd < 1e-5, "dsgd param {i}: {e_dsgd}");
            assert!(e_dad < 1e-5, "dad param {i}: {e_dad}");
            assert!(e_edad < 1e-5, "edad param {i}: {e_edad}");
        }
        assert!((pooled.loss - dad.loss).abs() < 1e-5);
    }

    /// Bandwidth ordering on the paper's regime (h >> N): edAD < dAD < dSGD.
    #[test]
    fn bandwidth_ordering_wide_layers() {
        let mut rng = Rng::new(2);
        let mlp = Mlp::new(&[64, 256, 256, 4], &[Activation::Relu, Activation::Relu], &mut rng);
        let mk = |seed: u64| {
            let mut rng = Rng::new(seed);
            let cluster = Cluster::replicate(mlp.clone(), 2);
            let batches: Vec<Batch> = (0..2)
                .map(|_| {
                    let x = Matrix::randn(8, 64, 1.0, &mut rng);
                    let labels: Vec<usize> = (0..8).map(|i| i % 4).collect();
                    Batch::Dense { x, y: one_hot(&labels, 4) }
                })
                .collect();
            (cluster, batches)
        };
        let (mut c1, b1) = mk(3);
        let dsgd = Dsgd.step(&mut c1, &b1);
        let (mut c2, b2) = mk(3);
        let dad = Dad.step(&mut c2, &b2);
        let (mut c3, b3) = mk(3);
        let edad = Edad.step(&mut c3, &b3);
        let (mut c4, b4) = mk(3);
        let rdad = RankDad::new(4).step(&mut c4, &b4);
        assert!(dad.bytes_up < dsgd.bytes_up, "dad {} !< dsgd {}", dad.bytes_up, dsgd.bytes_up);
        assert!(edad.bytes_up < dad.bytes_up, "edad {} !< dad {}", edad.bytes_up, dad.bytes_up);
        assert!(rdad.bytes_up < edad.bytes_up, "rank-dad {} !< edad {}", rdad.bytes_up, edad.bytes_up);
    }

    /// rank-dAD with rank >= N reconstructs the exact gradient (the stats
    /// matrices have at most N independent rows).
    #[test]
    fn rankdad_full_rank_is_exact() {
        let (mut cluster, batches) = setup(4);
        let pooled = Pooled.step(&mut cluster, &batches);
        let (mut c2, b2) = setup(4);
        let mut algo = RankDad { cfg: RankDadConfig { max_rank: 6, n_iters: 80, theta: 1e-7 } };
        let rdad = algo.step(&mut c2, &b2);
        for (i, pg) in pooled.grads.iter().enumerate() {
            let scale = pg.max_abs().max(1e-3);
            let err = pg.max_abs_diff(&rdad.grads[i]) / scale;
            assert!(err < 5e-2, "param {i}: rel err {err}");
        }
        // Effective ranks reported for every entry and site.
        assert_eq!(rdad.eff_ranks.len(), 3);
        for per_site in &rdad.eff_ranks {
            assert_eq!(per_site.len(), 2);
            for &r in per_site {
                assert!(r <= 6);
            }
        }
    }

    /// PowerSGD error feedback: compressed updates accumulate toward the
    /// true gradient over repeated steps on a fixed batch.
    #[test]
    fn powersgd_error_feedback_converges_on_fixed_batch() {
        let (mut cluster, batches) = setup(5);
        let pooled = Pooled.step(&mut cluster, &batches);
        let (mut c2, b2) = setup(5);
        let mut algo = PowerSgd::new(2);
        let mut acc: Option<Vec<Matrix>> = None;
        let steps = 12;
        for _ in 0..steps {
            let out = algo.step(&mut c2, &b2);
            acc = Some(match acc {
                None => out.grads,
                Some(mut a) => {
                    for (x, y) in a.iter_mut().zip(&out.grads) {
                        x.axpy(1.0, y);
                    }
                    a
                }
            });
        }
        let acc = acc.unwrap();
        // Mean applied gradient ≈ true gradient (error feedback drains).
        for (i, pg) in pooled.grads.iter().enumerate() {
            if pg.rows() == 1 {
                continue; // biases are exact by construction
            }
            let mean = acc[i].scale(1.0 / steps as f32);
            let rel = mean.sub(pg).fro_norm() / pg.fro_norm().max(1e-6);
            assert!(rel < 0.2, "param {i}: rel {rel}");
        }
    }

    #[test]
    fn spec_parsing() {
        assert_eq!(AlgoSpec::parse("dad"), Ok(AlgoSpec::Dad));
        assert_eq!(AlgoSpec::parse("dad-p2p"), Ok(AlgoSpec::DadP2p));
        assert_eq!(AlgoSpec::parse("dad-p2p").unwrap().name(), "dad-p2p");
        assert_eq!(
            AlgoSpec::parse("rank-dad:4"),
            Ok(AlgoSpec::RankDad { max_rank: 4, n_iters: 10, theta: 1e-3 })
        );
        assert_eq!(AlgoSpec::parse("powersgd:2"), Ok(AlgoSpec::PowerSgd { rank: 2 }));
        assert!(AlgoSpec::parse("nope").is_err());
        assert_eq!(AlgoSpec::parse("rank-dad:4").unwrap().name(), "rank-dad:4");
        // The sparse family, with and without arguments (defaults: DGC at
        // 1% density, VBC at λ=2, AdaComp with 512-element bins).
        assert_eq!(AlgoSpec::parse("dgc"), Ok(AlgoSpec::Dgc { density: 1.0 }));
        assert_eq!(AlgoSpec::parse("dgc:25"), Ok(AlgoSpec::Dgc { density: 25.0 }));
        assert_eq!(AlgoSpec::parse("dgc:0.5"), Ok(AlgoSpec::Dgc { density: 0.5 }));
        assert_eq!(AlgoSpec::parse("vbc"), Ok(AlgoSpec::Vbc { lambda: 2.0 }));
        assert_eq!(AlgoSpec::parse("vbc:0"), Ok(AlgoSpec::Vbc { lambda: 0.0 }));
        assert_eq!(AlgoSpec::parse("adacomp"), Ok(AlgoSpec::AdaComp { bin: 512 }));
        assert_eq!(AlgoSpec::parse("adacomp:64"), Ok(AlgoSpec::AdaComp { bin: 64 }));
        // Canonical names round-trip through parse.
        for spelling in ["dgc:25", "dgc:0.5", "vbc:2", "adacomp:512"] {
            let spec = AlgoSpec::parse(spelling).unwrap();
            assert_eq!(AlgoSpec::parse(&spec.name()), Ok(spec));
        }
    }

    /// Malformed `:rank` arguments are parse errors, not a silent fallback
    /// to rank 10 — `--algo rank-dad:abc` must refuse to train.
    #[test]
    fn spec_parsing_rejects_malformed_args() {
        assert!(AlgoSpec::parse("rank-dad:abc").is_err());
        assert!(AlgoSpec::parse("rank-dad:0").is_err());
        assert!(AlgoSpec::parse("rank-dad:-3").is_err());
        assert!(AlgoSpec::parse("powersgd:1.5").is_err());
        assert!(AlgoSpec::parse("powersgd:").is_err());
        // Non-parameterized algorithms reject any :argument outright.
        assert!(AlgoSpec::parse("dad:2").is_err());
        assert!(AlgoSpec::parse("edad:x").is_err());
        // Alias spellings parse to the same spec.
        assert_eq!(AlgoSpec::parse("dadp2p"), Ok(AlgoSpec::DadP2p));
        assert_eq!(AlgoSpec::parse("rankdad:3"), AlgoSpec::parse("rank-dad:3"));
        assert_eq!(AlgoSpec::parse("power-sgd:2"), AlgoSpec::parse("powersgd:2"));
        assert_eq!(AlgoSpec::parse("ada-comp:64"), AlgoSpec::parse("adacomp:64"));
        // Sparse-family malformed arguments are hard errors too: `dgc:abc`
        // must refuse to train, not fall back to the default density.
        assert!(AlgoSpec::parse("dgc:abc").is_err());
        assert!(AlgoSpec::parse("dgc:0").is_err());
        assert!(AlgoSpec::parse("dgc:-5").is_err());
        assert!(AlgoSpec::parse("dgc:101").is_err());
        assert!(AlgoSpec::parse("dgc:").is_err());
        assert!(AlgoSpec::parse("vbc:-1").is_err());
        assert!(AlgoSpec::parse("vbc:nan").is_err());
        assert!(AlgoSpec::parse("vbc:inf").is_err());
        assert!(AlgoSpec::parse("vbc:x").is_err());
        assert!(AlgoSpec::parse("adacomp:0").is_err());
        assert!(AlgoSpec::parse("adacomp:1.5").is_err());
        assert!(AlgoSpec::parse("adacomp:abc").is_err());
    }

    /// Transformer path: dAD == pooled on token batches with **uneven**
    /// per-site window counts. The cross-site weighting rides on the
    /// output-delta row count being `b * t` per site (one prediction per
    /// position) — the contract `Batch::len` documents — so a site with 2
    /// windows must weigh 10/25ths of a 5-token-window step, not 2/5ths.
    #[test]
    fn transformer_dad_matches_pooled_with_uneven_token_batches() {
        use crate::nn::{Transformer, TransformerConfig};
        let cfg = TransformerConfig::tiny();
        let t = 5usize;
        let mut rng = Rng::new(17);
        let model = Transformer::new(cfg.clone(), &mut rng);
        let mut mk = |b: usize| {
            let ids: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
            let targets: Vec<u32> = (0..b * t).map(|_| rng.below(cfg.vocab) as u32).collect();
            Batch::Tokens { b, t, ids, targets }
        };
        let batches = vec![mk(2), mk(3)];
        assert_eq!(batches[0].len(), 10, "token batch len counts b*t rows");
        assert_eq!(batches[1].len(), 15);
        let mut c1 = Cluster::replicate(model.clone(), 2);
        let pooled = Pooled.step(&mut c1, &batches);
        let mut c2 = Cluster::replicate(model.clone(), 2);
        let dad = Dad.step(&mut c2, &batches);
        let mut c3 = Cluster::replicate(model, 2);
        let p2p = DadP2p.step(&mut c3, &batches);
        for (i, pg) in pooled.grads.iter().enumerate() {
            assert!(pg.max_abs_diff(&dad.grads[i]) < 1e-5, "dad param {i}");
            assert!(pg.max_abs_diff(&p2p.grads[i]) < 1e-5, "p2p param {i}");
        }
        // Loss weighting: the batch-size-weighted mean equals the union
        // batch's mean only when sites weigh by b*t.
        assert!((pooled.loss - dad.loss).abs() < 1e-5, "{} vs {}", pooled.loss, dad.loss);
    }

    /// GRU path: dAD == pooled on sequence batches too (paper §4.1.2).
    #[test]
    fn gru_dad_matches_pooled() {
        use crate::nn::GruClassifier;
        let mut rng = Rng::new(7);
        let gru = GruClassifier::new(3, 4, &[6], 3, &mut rng);
        let mk_batches = |rng: &mut Rng| {
            (0..2)
                .map(|_| {
                    let xs: Vec<Matrix> = (0..3).map(|_| Matrix::randn(4, 3, 1.0, rng)).collect();
                    let labels: Vec<usize> = (0..4).map(|i| i % 3).collect();
                    Batch::Seq { xs, y: one_hot(&labels, 3) }
                })
                .collect::<Vec<_>>()
        };
        let mut rng_b = Rng::new(8);
        let batches = mk_batches(&mut rng_b);
        let mut c1 = Cluster::replicate(gru.clone(), 2);
        let pooled = Pooled.step(&mut c1, &batches);
        let mut c2 = Cluster::replicate(gru.clone(), 2);
        let dad = Dad.step(&mut c2, &batches);
        let mut c3 = Cluster::replicate(gru, 2);
        let edad = Edad.step(&mut c3, &batches);
        for (i, pg) in pooled.grads.iter().enumerate() {
            assert!(pg.max_abs_diff(&dad.grads[i]) < 1e-5, "dad param {i}");
            assert!(pg.max_abs_diff(&edad.grads[i]) < 1e-5, "edad param {i}");
        }
    }
}

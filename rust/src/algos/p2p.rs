//! Peer-to-peer dAD — the paper's section 3.6 extension: "all of the
//! methods presented could be ameliorated to peer-to-peer communication,
//! where each local site can serve as an aggregator for what is received
//! from other peers."
//!
//! Every site broadcasts its (A, Δ) statistics directly to the other S-1
//! peers; each site then vertcats everything it holds (its own stats plus
//! the received ones, in canonical site order) and computes the exact
//! global gradient locally. No trusted aggregator exists, and the
//! round-trip latency of the star is replaced by a single exchange phase.
//!
//! Bytes: each site sends N(h_i + h_{i+1}) per layer to each of the S-1
//! peers — total S(S-1)·N·Σ(h_i+h_{i+1}); the star's down-link broadcast
//! disappears. For S=2 this is *half* the star topology's total traffic
//! (no aggregator echo); the crossover versus the star is at S where
//! (S-1) ≥ 1 + S (never for the up+down total), i.e. p2p always ships
//! fewer total bytes but spreads them across S uplinks.

use std::io;

use crate::algos::common::{
    gather_local_stats, weighted_loss, DistAlgorithm, StepOutcome,
};
use crate::algos::protocol::{
    expect_mats, mean_direct, one_mat, AggExchange, Endpoint, StepMeta, StepPlan, StepProtocol,
    StepSync,
};
use crate::dist::wire::proto_err;
use crate::dist::{Cluster, Direction};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{assemble_grads, concat_stats, LocalStats, StatsEntry};
use crate::tensor::Matrix;

/// dAD over a fully-connected peer topology (no aggregator).
pub struct DadP2p;

impl<M: DistModel> DistAlgorithm<M> for DadP2p {
    fn name(&self) -> &'static str {
        "dad-p2p"
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(DadP2pProtocol)
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let p2p0 = cluster.ledger.total_dir(Direction::PeerToPeer);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        // Every site sends all of its stats entries to every peer.
        for s in &stats.per_site {
            for e in &s.entries {
                cluster.send_p2p("acts", &[&e.a]);
                cluster.send_p2p("deltas", &[&e.d]);
            }
            let direct_refs: Vec<&Matrix> = s.direct.iter().map(|(_, g)| g).collect();
            if !direct_refs.is_empty() {
                cluster.send_p2p("direct-grad", &direct_refs);
            }
        }
        // Each site now holds the full statistic set; vertcat in canonical
        // site order (deterministic everywhere) and assemble.
        let entry_refs: Vec<&[StatsEntry]> =
            stats.per_site.iter().map(|s| &s.entries[..]).collect();
        let cat = concat_stats(&entry_refs);
        // Direct grads: every peer averages the copies it received (the
        // same canonical segment sum the wire protocol computes).
        let idxs: Vec<usize> = stats.per_site[0].direct.iter().map(|&(i, _)| i).collect();
        let per_direct: Vec<Vec<Matrix>> = stats
            .per_site
            .iter()
            .map(|s| s.direct.iter().map(|(_, g)| g.clone()).collect())
            .collect();
        let direct =
            mean_direct(per_direct, &idxs, scale).expect("uniform direct layouts across sites");
        let grads = assemble_grads(&shapes, &cat, &direct, scale, 1.0);
        let p2p1 = cluster.ledger.total_dir(Direction::PeerToPeer);
        StepOutcome {
            loss: weighted_loss(&stats),
            grads,
            eff_ranks: vec![],
            // P2P has no star directions; report the exchange as up-bytes.
            bytes_up: p2p1 - p2p0,
            bytes_down: 0,
        }
    }
}

/// Wire protocol for [`DadP2p`]: one all-to-all round. Every site ships
/// its (A, Δ) stacks (and raw direct grads) to all S-1 peers; each site
/// then vertcats what it holds — its own statistics plus the received
/// ones, in canonical site order — and assembles the exact global
/// gradient locally, with no trusted aggregation step.
///
/// On the star TCP fabric the hub *relays* the peer frames (a true mesh
/// transport plugs in at the same seam later); the ledger prices each
/// shipment as S-1 direct unicasts under `Direction::PeerToPeer`, so the
/// measured bytes equal what a real mesh would ship — and equal the
/// loopback simulation's. The hub decodes what it relays to keep its
/// evaluation replica in lockstep; it never originates statistics.
pub struct DadP2pProtocol;

impl<M: DistModel> StepProtocol<M> for DadP2pProtocol {
    fn name(&self) -> &'static str {
        "dad-p2p"
    }

    fn plan(&self, _metas: &[StepMeta]) -> io::Result<StepPlan> {
        Err(proto_err(
            "dad-p2p: the all-to-all mesh has no aggregation tree, so dad-p2p cannot \
             run on a tree topology (use dad, or a flat star)"
                .into(),
        ))
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let n_sites = ep.n_sites();
        for e in &stats.entries {
            ep.p2p("acts", &[&e.a])?;
            ep.p2p("deltas", &[&e.d])?;
        }
        if !stats.direct.is_empty() {
            let refs: Vec<&Matrix> = stats.direct.iter().map(|(_, g)| g).collect();
            ep.p2p("direct-grad", &refs)?;
        }
        // Receive every peer's statistics, relayed in canonical site order;
        // this site's own slot is filled locally.
        let mut per_site: Vec<Vec<StatsEntry>> = Vec::with_capacity(n_sites);
        let mut per_direct: Vec<Vec<Matrix>> = Vec::with_capacity(n_sites);
        for src in 0..n_sites {
            if src == site_id {
                per_site.push(stats.entries.clone());
                per_direct.push(stats.direct.iter().map(|(_, g)| g.clone()).collect());
                continue;
            }
            let mut entries = Vec::with_capacity(stats.entries.len());
            for e in &stats.entries {
                let a = ep.p2p_recv1("acts")?;
                let d = ep.p2p_recv1("deltas")?;
                entries.push(StatsEntry { w_idx: e.w_idx, b_idx: e.b_idx, a, d });
            }
            let direct = if stats.direct.is_empty() {
                vec![]
            } else {
                let mats = ep.p2p_recv("direct-grad")?;
                if mats.len() != stats.direct.len() {
                    return Err(proto_err(format!("peer {src} direct-grad arity mismatch")));
                }
                mats
            };
            per_site.push(entries);
            per_direct.push(direct);
        }
        let entry_refs: Vec<&[StatsEntry]> = per_site.iter().map(|e| &e[..]).collect();
        let cat = concat_stats(&entry_refs);
        let scale = sync.scale();
        let idxs: Vec<usize> = stats.direct.iter().map(|&(i, _)| i).collect();
        let direct = mean_direct(per_direct, &idxs, scale)?;
        Ok(assemble_grads(&model.param_shapes(), &cat, &direct, scale, 1.0))
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange> {
        // Phase 1: drain every site's uplink completely before writing a
        // single forwarded byte. A read-one/forward-one hub could block
        // writing to a peer whose own uplink it has not drained yet —
        // mutual blocking at payloads beyond the kernel socket buffers.
        let mut frames: Vec<Vec<crate::dist::wire::Frame>> = Vec::with_capacity(metas.len());
        for (site, meta) in metas.iter().enumerate() {
            let n_frames = meta.entries.len() * 2 + usize::from(!meta.direct_idx.is_empty());
            let mut fs = Vec::with_capacity(n_frames);
            for _ in 0..n_frames {
                fs.push(ep.p2p_pull(site)?);
            }
            frames.push(fs);
        }
        // Phase 2: forward in site order — the order every site's receive
        // loop expects; by now all sites are blocked reading.
        for (site, fs) in frames.iter().enumerate() {
            ep.p2p_forward(site, fs)?;
        }
        // Phase 3: decode the drained frames into per-site statistics for
        // the hub's lockstep evaluation replica.
        let mut per_site: Vec<Vec<StatsEntry>> = Vec::with_capacity(metas.len());
        let mut per_direct: Vec<Vec<Matrix>> = Vec::with_capacity(metas.len());
        for ((site, meta), fs) in metas.iter().enumerate().zip(frames) {
            let mut it = fs.into_iter();
            let mut next = |tag: &str| -> io::Result<Vec<Matrix>> {
                let f = it
                    .next()
                    .ok_or_else(|| proto_err(format!("site {site}: p2p frame underrun")))?;
                expect_mats(f, tag)
            };
            let mut entries = Vec::with_capacity(meta.entries.len());
            for &(w_idx, b_idx) in &meta.entries {
                let a = one_mat(next("acts")?)?;
                let d = one_mat(next("deltas")?)?;
                entries.push(StatsEntry {
                    w_idx: w_idx as usize,
                    b_idx: (b_idx != u32::MAX).then_some(b_idx as usize),
                    a,
                    d,
                });
            }
            let direct = if meta.direct_idx.is_empty() {
                vec![]
            } else {
                let mats = next("direct-grad")?;
                if mats.len() != meta.direct_idx.len() {
                    return Err(proto_err(format!("site {site} direct-grad arity mismatch")));
                }
                mats
            };
            per_site.push(entries);
            per_direct.push(direct);
        }
        let entry_refs: Vec<&[StatsEntry]> = per_site.iter().map(|e| &e[..]).collect();
        let cat = concat_stats(&entry_refs);
        let scale = sync.scale();
        let idxs: Vec<usize> = metas[0].direct_idx.iter().map(|&i| i as usize).collect();
        let direct = mean_direct(per_direct, &idxs, scale)?;
        let grads = assemble_grads(&model.param_shapes(), &cat, &direct, scale, 1.0);
        Ok(AggExchange { grads, eff_ranks: vec![] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{Dad, Pooled};
    use crate::nn::loss::one_hot;
    use crate::nn::{Activation, Mlp};
    use crate::tensor::Rng;

    fn setup(sites: usize) -> (Mlp, Vec<Batch>) {
        let mut rng = Rng::new(61);
        let mlp = Mlp::new(&[10, 14, 4], &[Activation::Relu], &mut rng);
        let batches = (0..sites)
            .map(|_| {
                let x = Matrix::randn(5, 10, 1.0, &mut rng);
                let labels: Vec<usize> = (0..5).map(|i| i % 4).collect();
                Batch::Dense { x, y: one_hot(&labels, 4) }
            })
            .collect();
        (mlp, batches)
    }

    /// Decentralized dAD computes the same exact gradient as star dAD and
    /// the pooled oracle (section 3.6's claim).
    #[test]
    fn p2p_matches_star_and_pooled() {
        for sites in [2usize, 3, 4] {
            let (mlp, batches) = setup(sites);
            let mut c1 = Cluster::replicate(mlp.clone(), sites);
            let pooled = Pooled.step(&mut c1, &batches);
            let mut c2 = Cluster::replicate(mlp.clone(), sites);
            let star = Dad.step(&mut c2, &batches);
            let mut c3 = Cluster::replicate(mlp, sites);
            let p2p = DadP2p.step(&mut c3, &batches);
            for (i, pg) in pooled.grads.iter().enumerate() {
                assert!(pg.max_abs_diff(&star.grads[i]) < 1e-5, "S={sites} star param {i}");
                assert!(pg.max_abs_diff(&p2p.grads[i]) < 1e-5, "S={sites} p2p param {i}");
            }
        }
    }

    /// At S=2 the p2p exchange ships fewer total bytes than the star's
    /// up+down (no aggregator echo); per-peer payloads scale with (S-1).
    #[test]
    fn p2p_bytes_scale_with_peers() {
        let (mlp, batches2) = setup(2);
        let mut c = Cluster::replicate(mlp.clone(), 2);
        let star = Dad.step(&mut c, &batches2);
        let mut c2 = Cluster::replicate(mlp.clone(), 2);
        let p2p2 = DadP2p.step(&mut c2, &batches2);
        assert!(p2p2.bytes_up < star.bytes_up + star.bytes_down);
        let (mlp3, batches3) = setup(4);
        let mut c3 = Cluster::replicate(mlp3, 4);
        let p2p4 = DadP2p.step(&mut c3, &batches3);
        // 4 sites, 3 receivers each: 4*3=12 site-pair payloads vs 2*1=2.
        assert!(p2p4.bytes_up > p2p2.bytes_up * 4);
    }

    /// The ledger files p2p traffic under its own direction.
    #[test]
    fn p2p_direction_recorded() {
        let (mlp, batches) = setup(2);
        let mut c = Cluster::replicate(mlp, 2);
        let _ = DadP2p.step(&mut c, &batches);
        assert!(c.ledger.total_dir(Direction::PeerToPeer) > 0);
        assert_eq!(c.ledger.total_dir(Direction::AggToSite), 0);
    }
}

//! Algorithm-agnostic remote execution: every [`DistAlgorithm`] describes
//! its per-step exchange as typed rounds over a [`Transport`], so one
//! generic driver pair (`coordinator::remote::{remote_site_step,
//! remote_agg_step}`) runs the *entire* algorithm family — `pooled | dsgd |
//! dad | dad-p2p | edad | rank-dad | powersgd` — under `dad serve` /
//! `dad join` with no per-algorithm code in the coordinator.
//!
//! [`DistAlgorithm`]: crate::algos::DistAlgorithm
//!
//! The design inverts the simulated path: there an algorithm is a closure
//! over an in-memory [`crate::dist::Cluster`] with a god's-eye view; here it
//! is a state machine over messages. Each step has a fixed shape:
//!
//! ```text
//! prologue (driver)   site: step-meta ctrl up     agg: gather S metas
//!                     site: step-sync ctrl down   agg: broadcast sync
//! exchange (protocol) typed rounds:  up / gather  (site -> agg payloads)
//!                                    bcast / down (agg -> site broadcasts)
//!                                    p2p / relay  (all-to-all, dad-p2p)
//! ```
//!
//! The prologue carries losses, row counts and the stats-entry layout in
//! *control* frames (ledger-exempt protocol overhead); the exchange moves
//! payload frames with exactly the tags and shapes the loopback simulation
//! prices, which is what makes a TCP run's per-(tag, direction) ledger
//! bit-equal to the simulated run's (`tests/transport_e2e.rs`). Algorithms
//! with cross-step compressor state (PowerSGD's warm start + error
//! feedback) keep it inside their [`StepProtocol`] value — site-local, one
//! instance per process, exactly as a real deployment would.

use std::io;

use crate::algos::reduce::{self, Segments};
use crate::dist::wire::{proto_err, Body, ByteReader, ByteWriter, Frame, SparseMat};
use crate::dist::{Direction, Ledger, Transport};
use crate::nn::model::DistModel;
use crate::nn::stats::LocalStats;
use crate::obs::trace::{tagged_span, Phase};
use crate::tensor::Matrix;

/// One endpoint of the aggregation fabric during one remote step: the
/// transport plus the ledger that prices its payload frames. The methods
/// are the typed rounds the protocols compose; control-frame helpers never
/// touch the ledger.
///
/// On a tree fabric an aggregator's links are not leaf sites but entire
/// subtrees: each link covers a contiguous leaf range assigned at the
/// handshake ([`Transport::link_leaves`]), and the coordinator narrows it
/// to the *live* leaves each step via [`Endpoint::set_link_leaves`] so the
/// gather primitives can place every uplink partial in the canonical
/// segment reduction (see [`crate::algos::reduce`]).
pub struct Endpoint<'a> {
    t: &'a mut dyn Transport,
    ledger: &'a mut Ledger,
    leaves: Option<Vec<Vec<u32>>>,
}

impl<'a> Endpoint<'a> {
    /// Wrap a transport + ledger for one step's rounds.
    pub fn new(t: &'a mut dyn Transport, ledger: &'a mut Ledger) -> Self {
        Endpoint { t, ledger, leaves: None }
    }

    /// Number of sites on the fabric.
    pub fn n_sites(&self) -> usize {
        self.t.n_sites()
    }

    /// Number of direct links on this endpoint (= leaf sites on a star,
    /// child subtrees on a tree aggregator).
    pub fn n_links(&self) -> usize {
        self.t.n_sites()
    }

    /// Handshake-assigned leaf range of link `link`: (first leaf id, count).
    pub fn link_static_leaves(&self, link: usize) -> (u32, u32) {
        self.t.link_leaves(link)
    }

    /// Narrow each link to its live leaves for this step (ascending ids,
    /// ascending by link). Set by the aggregator driver from the gathered
    /// step metadata; without it, every handshake-assigned leaf is live.
    pub fn set_link_leaves(&mut self, leaves: Vec<Vec<u32>>) {
        self.leaves = Some(leaves);
    }

    /// The live leaf ids link `link` aggregates, ascending.
    pub fn link_leaf_ids(&self, link: usize) -> Vec<u32> {
        match &self.leaves {
            Some(v) => v[link].clone(),
            None => {
                let (start, n) = self.t.link_leaves(link);
                (start..start + n).collect()
            }
        }
    }

    /// Site round: ship a tagged payload frame up to the aggregator.
    pub fn up(&mut self, tag: &str, mats: &[&Matrix]) -> io::Result<()> {
        let _s = tagged_span("round-up", tag, Phase::Comms);
        let n = self.t.ship(Direction::SiteToAgg, tag, mats)?;
        self.ledger.record(tag, Direction::SiteToAgg, n);
        Ok(())
    }

    /// Site round: ship a tagged sparse payload frame up to the aggregator
    /// (priced with its u32-index overhead).
    pub fn up_sparse(&mut self, tag: &str, mats: &[&SparseMat]) -> io::Result<()> {
        let _s = tagged_span("round-up-sparse", tag, Phase::Comms);
        let n = self.t.ship_sparse(Direction::SiteToAgg, tag, mats)?;
        self.ledger.record(tag, Direction::SiteToAgg, n);
        Ok(())
    }

    /// Site round: receive the next broadcast payload frame.
    pub fn down(&mut self, tag: &str) -> io::Result<Vec<Matrix>> {
        let _s = tagged_span("round-down", tag, Phase::Stall);
        let f = self.t.recv_broadcast()?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::AggToSite, f.wire_len());
        }
        expect_mats(f, tag)
    }

    /// Site round: receive the next broadcast sparse payload frame.
    pub fn down_sparse(&mut self, tag: &str) -> io::Result<Vec<SparseMat>> {
        let _s = tagged_span("round-down-sparse", tag, Phase::Stall);
        let f = self.t.recv_broadcast()?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::AggToSite, f.wire_len());
        }
        expect_sparse(f, tag)
    }

    /// Site round: receive a single-matrix broadcast payload frame.
    pub fn down1(&mut self, tag: &str) -> io::Result<Matrix> {
        one_mat(self.down(tag)?)
    }

    /// Aggregator round: receive the next payload frame `site` sent up.
    pub fn gather(&mut self, site: usize, tag: &str) -> io::Result<Vec<Matrix>> {
        let _s = tagged_span("round-gather", tag, Phase::Stall);
        let f = self.t.recv_from_site(site)?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::SiteToAgg, f.wire_len());
        }
        expect_mats(f, tag)
    }

    /// Aggregator round: receive the next sparse payload frame from `site`.
    pub fn gather_sparse(&mut self, site: usize, tag: &str) -> io::Result<Vec<SparseMat>> {
        let _s = tagged_span("round-gather-sparse", tag, Phase::Stall);
        let f = self.t.recv_from_site(site)?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::SiteToAgg, f.wire_len());
        }
        expect_sparse(f, tag)
    }

    /// Aggregator round: receive a single-matrix uplink frame from `site`.
    pub fn gather1(&mut self, site: usize, tag: &str) -> io::Result<Matrix> {
        one_mat(self.gather(site, tag)?)
    }

    /// Aggregator round: broadcast a tagged payload frame to every site
    /// (counted once — the down-link is a shared multicast).
    pub fn bcast(&mut self, tag: &str, mats: &[&Matrix]) -> io::Result<()> {
        let _s = tagged_span("round-bcast", tag, Phase::Comms);
        let n = self.t.ship(Direction::AggToSite, tag, mats)?;
        self.ledger.record(tag, Direction::AggToSite, n);
        Ok(())
    }

    /// Aggregator round: broadcast a tagged sparse payload frame to every
    /// site (counted once, index overhead included).
    pub fn bcast_sparse(&mut self, tag: &str, mats: &[&SparseMat]) -> io::Result<()> {
        let _s = tagged_span("round-bcast-sparse", tag, Phase::Comms);
        let n = self.t.ship_sparse(Direction::AggToSite, tag, mats)?;
        self.ledger.record(tag, Direction::AggToSite, n);
        Ok(())
    }

    /// Relay round, parent side: receive the next broadcast frame of any
    /// body kind under `tag`, recording payload bytes — the raw form of
    /// [`Endpoint::down`] a sub-aggregator forwards verbatim down the tree.
    pub fn down_frame(&mut self, tag: &str) -> io::Result<Frame> {
        let _s = tagged_span("round-down-frame", tag, Phase::Stall);
        let f = self.t.recv_broadcast()?;
        if f.tag != tag {
            return Err(proto_err(format!("expected broadcast frame {tag:?}, got {:?}", f.tag)));
        }
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::AggToSite, f.wire_len());
        }
        Ok(f)
    }

    /// Relay round, child side: re-broadcast a frame received via
    /// [`Endpoint::down_frame`] verbatim (encode∘decode is bit-identical,
    /// so the leaves see exactly the root's bytes), with the same ledger
    /// accounting the typed broadcast rounds apply.
    pub fn bcast_frame(&mut self, f: &Frame) -> io::Result<()> {
        match &f.body {
            Body::Mats(ms) => {
                let refs: Vec<&Matrix> = ms.iter().collect();
                self.bcast(&f.tag, &refs)
            }
            Body::Sparse(ms) => {
                let refs: Vec<&SparseMat> = ms.iter().collect();
                self.bcast_sparse(&f.tag, &refs)
            }
            Body::Control(b) => self.ctrl_bcast(&f.tag, b),
        }
    }

    /// All-to-all round, site half: ship a payload frame to every one of
    /// the S-1 peers (relayed through the hub on a star fabric; priced as
    /// S-1 direct unicasts either way).
    pub fn p2p(&mut self, tag: &str, mats: &[&Matrix]) -> io::Result<()> {
        let _s = tagged_span("round-p2p", tag, Phase::Comms);
        let n = self.t.ship(Direction::PeerToPeer, tag, mats)?;
        self.ledger.record(tag, Direction::PeerToPeer, n);
        Ok(())
    }

    /// All-to-all round, site half: receive one relayed peer frame. Not
    /// ledger-recorded — the exchange is priced once on the sending side,
    /// matching the loopback convention.
    pub fn p2p_recv(&mut self, tag: &str) -> io::Result<Vec<Matrix>> {
        let _s = tagged_span("round-p2p-recv", tag, Phase::Stall);
        expect_mats(self.t.recv_broadcast()?, tag)
    }

    /// Single-matrix form of [`Endpoint::p2p_recv`].
    pub fn p2p_recv1(&mut self, tag: &str) -> io::Result<Matrix> {
        one_mat(self.p2p_recv(tag)?)
    }

    /// All-to-all round, hub half, phase 1: pull one p2p frame off
    /// `site`'s uplink *without forwarding yet*, recording it as S-1
    /// direct unicasts under [`Direction::PeerToPeer`]. Draining every
    /// uplink before any [`Endpoint::p2p_forward`] write is what keeps a
    /// blocking single-threaded hub deadlock-free at any payload size.
    pub fn p2p_pull(&mut self, site: usize) -> io::Result<Frame> {
        let _s = tagged_span("round-p2p-pull", "p2p", Phase::Stall);
        let f = self.t.recv_from_site(site)?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            let peers = self.t.n_sites().saturating_sub(1) as u64;
            self.ledger.record(&f.tag, Direction::PeerToPeer, f.wire_len() * peers);
        }
        Ok(f)
    }

    /// All-to-all round, hub half, phase 2: forward one site's pulled
    /// frames to every other site (bytes were already recorded by
    /// [`Endpoint::p2p_pull`]; the transport flushes once per link).
    pub fn p2p_forward(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        let _s = tagged_span("round-p2p-forward", "p2p", Phase::Comms);
        self.t.forward_p2p(from_site, frames)
    }

    /// Site control round: ship a control frame up (ledger-exempt).
    pub fn ctrl_up(&mut self, tag: &str, body: &[u8]) -> io::Result<()> {
        let _s = tagged_span("ctrl-up", tag, Phase::Comms);
        self.t.ship_control(Direction::SiteToAgg, tag, body)?;
        Ok(())
    }

    /// Site control round: receive a broadcast control frame. Blocked
    /// time here is where a site waits out the aggregator's gather of the
    /// slowest peer, so the span is attributed to the stall phase.
    pub fn ctrl_down(&mut self, tag: &str) -> io::Result<Vec<u8>> {
        let _s = tagged_span("ctrl-down", tag, Phase::Stall);
        expect_ctrl(self.t.recv_broadcast()?, tag)
    }

    /// Aggregator control round: broadcast a control frame (ledger-exempt).
    pub fn ctrl_bcast(&mut self, tag: &str, body: &[u8]) -> io::Result<()> {
        let _s = tagged_span("ctrl-bcast", tag, Phase::Comms);
        self.t.ship_control(Direction::AggToSite, tag, body)?;
        Ok(())
    }

    /// Aggregator control round: receive a control frame from `site`.
    /// Blocked time here is the aggregator's straggler stall.
    pub fn ctrl_from(&mut self, site: usize, tag: &str) -> io::Result<Vec<u8>> {
        let _s = tagged_span("ctrl-from", tag, Phase::Stall);
        expect_ctrl(self.t.recv_from_site(site)?, tag)
    }

    /// Operator-facing label for live link index `site` (the originally
    /// assigned id, even after retirements compacted the links).
    pub fn site_label(&self, site: usize) -> String {
        self.t.site_label(site)
    }

    /// Permanently drop live link `site` from the fabric — the degradation
    /// seam `coordinator::remote` uses to continue a round with the
    /// surviving sites (see [`Transport::retire_site`]).
    pub fn retire_site(&mut self, site: usize) -> io::Result<()> {
        self.t.retire_site(site)
    }
}

pub(crate) fn expect_mats(f: Frame, want: &str) -> io::Result<Vec<Matrix>> {
    match f.body {
        Body::Mats(m) if f.tag == want => Ok(m),
        _ => Err(proto_err(format!("expected payload frame {want:?}, got {:?}", f.tag))),
    }
}

pub(crate) fn expect_sparse(f: Frame, want: &str) -> io::Result<Vec<SparseMat>> {
    match f.body {
        Body::Sparse(m) if f.tag == want => Ok(m),
        _ => Err(proto_err(format!("expected sparse frame {want:?}, got {:?}", f.tag))),
    }
}

pub(crate) fn expect_ctrl(f: Frame, want: &str) -> io::Result<Vec<u8>> {
    match f.body {
        Body::Control(b) if f.tag == want => Ok(b),
        _ => Err(proto_err(format!("expected control frame {want:?}, got {:?}", f.tag))),
    }
}

pub(crate) fn one_mat(mats: Vec<Matrix>) -> io::Result<Matrix> {
    let mut mats = mats;
    if mats.len() != 1 {
        return Err(proto_err(format!("expected exactly 1 matrix, got {}", mats.len())));
    }
    Ok(mats.pop().expect("checked non-empty"))
}

/// Per-step uplink metadata (the prologue's `step-meta` control frame):
/// the site's loss and row count plus the parameter-index layout of its
/// stats entries, so the aggregator can drive any algorithm's gather
/// rounds without holding data.
#[derive(Clone, Debug)]
pub struct StepMeta {
    /// Mean loss over the site's batch.
    pub loss: f32,
    /// Output-delta rows (the site's contribution to the global batch).
    pub rows: u32,
    /// Per stats entry: (weight param index, bias param index or u32::MAX).
    pub entries: Vec<(u32, u32)>,
    /// Param indices of direct (non-outer-product) gradients.
    pub direct_idx: Vec<u32>,
    /// Number of edAD aux-activation matrices the site will ship.
    pub n_aux: u16,
}

impl StepMeta {
    /// Describe one site's [`LocalStats`] for the wire.
    pub fn of(stats: &LocalStats) -> StepMeta {
        StepMeta {
            loss: stats.loss,
            rows: stats.entries.last().map(|e| e.d.rows()).unwrap_or(0) as u32,
            entries: stats
                .entries
                .iter()
                .map(|e| (e.w_idx as u32, e.b_idx.map(|b| b as u32).unwrap_or(u32::MAX)))
                .collect(),
            direct_idx: stats.direct.iter().map(|&(i, _)| i as u32).collect(),
            n_aux: stats.aux.len() as u16,
        }
    }

    /// Serialize as a control-frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_f32(self.loss);
        w.push_u32(self.rows);
        w.push_u16(self.entries.len() as u16);
        for &(wi, bi) in &self.entries {
            w.push_u32(wi);
            w.push_u32(bi);
        }
        w.push_u16(self.direct_idx.len() as u16);
        for &i in &self.direct_idx {
            w.push_u32(i);
        }
        w.push_u16(self.n_aux);
        w.finish()
    }

    /// Parse a control-frame body (every read bounds-checked).
    pub fn decode(body: &[u8]) -> io::Result<StepMeta> {
        let mut r = ByteReader::new(body);
        let loss = r.read_f32()?;
        let rows = r.read_u32()?;
        let n_entries = r.read_u16()? as usize;
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let wi = r.read_u32()?;
            let bi = r.read_u32()?;
            entries.push((wi, bi));
        }
        let n_direct = r.read_u16()? as usize;
        let mut direct_idx = Vec::with_capacity(n_direct);
        for _ in 0..n_direct {
            direct_idx.push(r.read_u32()?);
        }
        let n_aux = r.read_u16()?;
        Ok(StepMeta { loss, rows, entries, direct_idx, n_aux })
    }
}

/// The prologue's `step-sync` broadcast: everything a site needs before
/// its exchange rounds — the global row count (which fixes the 1/N
/// gradient scale), the batch-size-weighted global loss, and the per-site
/// row counts (edAD's delta recomputation needs them).
#[derive(Clone, Debug)]
pub struct StepSync {
    /// Σ per-site output-delta rows (the global batch size).
    pub total_rows: usize,
    /// Batch-size-weighted mean training loss across sites.
    pub loss: f32,
    /// Per-site output-delta rows, in canonical site order.
    pub site_rows: Vec<usize>,
}

impl StepSync {
    /// Derive the sync frame from the gathered metas. For the pooled
    /// oracle every site computed the identical union batch, so the global
    /// count is any single site's (they are checked to agree) and the loss
    /// is the union loss, not a weighted mean.
    pub fn from_metas(metas: &[StepMeta], oracle: bool) -> io::Result<StepSync> {
        if metas.is_empty() {
            return Err(proto_err("step-sync needs at least one site meta".into()));
        }
        let site_rows: Vec<usize> = metas.iter().map(|m| m.rows as usize).collect();
        if oracle {
            if site_rows.iter().any(|&r| r != site_rows[0]) {
                return Err(proto_err("pooled oracle sites disagree on the union batch".into()));
            }
            return Ok(StepSync { total_rows: site_rows[0], loss: metas[0].loss, site_rows });
        }
        let total_rows: usize = site_rows.iter().sum();
        let num: f64 = metas.iter().map(|m| m.loss as f64 * m.rows as f64).sum();
        let loss = (num / total_rows.max(1) as f64) as f32;
        Ok(StepSync { total_rows, loss, site_rows })
    }

    /// The 1/(global batch) gradient scale every algorithm applies.
    pub fn scale(&self) -> f32 {
        1.0 / self.total_rows as f32
    }

    /// Serialize as a control-frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_u32(self.total_rows as u32);
        w.push_f32(self.loss);
        w.push_u16(self.site_rows.len() as u16);
        for &r in &self.site_rows {
            w.push_u32(r as u32);
        }
        w.finish()
    }

    /// Parse a control-frame body.
    pub fn decode(body: &[u8]) -> io::Result<StepSync> {
        let mut r = ByteReader::new(body);
        let total_rows = r.read_u32()? as usize;
        let loss = r.read_f32()?;
        let n = r.read_u16()? as usize;
        let mut site_rows = Vec::with_capacity(n);
        for _ in 0..n {
            site_rows.push(r.read_u32()? as usize);
        }
        Ok(StepSync { total_rows, loss, site_rows })
    }
}

/// The aggregator half's result: the synchronized gradient (for the
/// lockstep eval replica) plus rank-dAD's effective-rank telemetry,
/// `eff_ranks[entry][site]` (empty for every other algorithm).
pub struct AggExchange {
    /// Synchronized global gradient, aligned with the param list.
    pub grads: Vec<Matrix>,
    /// rank-dAD effective ranks per stats entry, per site.
    pub eff_ranks: Vec<Vec<usize>>,
}

/// One algorithm's wire protocol: the site and aggregator halves of the
/// per-step exchange, as typed rounds over an [`Endpoint`]. Implementations
/// are state machines — `&mut self` carries cross-step compressor state
/// (PowerSGD's warm start + error feedback stays site-local by
/// construction: each process owns one protocol value).
///
/// The meta/sync prologue has already run when either half is called, so
/// the global row count, weighted loss and per-site rows are available in
/// `sync`. Both halves must ship/gather payload frames with exactly the
/// tags, shapes and order the simulated algorithm prices through the
/// loopback transport — that equivalence is asserted per algorithm by
/// `tests/transport_e2e.rs`.
pub trait StepProtocol<M: DistModel>: Send {
    /// Protocol name for diagnostics (matches the algorithm name).
    fn name(&self) -> &'static str;

    /// True for the pooled oracle: every process computes the union batch
    /// locally and the exchange ships no payload frames. The drivers give
    /// oracle protocols the union batch instead of a shard batch and run
    /// the site half on the aggregator too.
    fn oracle(&self) -> bool {
        false
    }

    /// True when the aggregator half can keep driving this protocol after
    /// sites were retired mid-run (the degraded mode of
    /// `coordinator::remote::serve_training`). Requires the site half to be
    /// shaped only by the sync frame — never by a site count captured at
    /// startup. dAD, dSGD, rank-dAD, the pooled oracle and the sparse
    /// family (DGC / VBC / AdaComp, whose residual state is per-site and
    /// whose scale comes from the sync frame) qualify; edAD
    /// (weight-coupled delta recomputation), dad-p2p (mesh membership) and
    /// PowerSGD (site half scales means by the startup `n_sites`) do not,
    /// so a lost site fails those runs cleanly instead.
    fn supports_degrade(&self) -> bool {
        false
    }

    /// The ordered, directional wire rounds of one step's exchange, derived
    /// from the gathered per-leaf `metas`. This is what a sub-aggregator
    /// (`dad relay`) executes generically — gather + associative combine
    /// and re-ship for `Up*` rounds, verbatim forwarding for [`Round::Down`]
    /// — with no per-algorithm code: the combine rule is implied by the
    /// round type (dense segment sums, leaf-order stacking, sparse
    /// index-union, per-leaf control batching). The round order must match
    /// the site half's frame order exactly; both are asserted equivalent by
    /// `tests/transport_e2e.rs`.
    ///
    /// Algorithms whose exchange is not an associative reduction over a
    /// star — edAD (weight-coupled delta recomputation) and dad-p2p
    /// (all-to-all mesh) — return a named error here, which is what rejects
    /// them on tree topologies up front.
    fn plan(&self, metas: &[StepMeta]) -> io::Result<StepPlan>;

    /// Site half of the exchange. `stats` are this site's local statistics
    /// for the step's batch; returns the synchronized global gradient
    /// (identical on every endpoint, up to the algorithm's compression).
    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>>;

    /// Aggregator half of the exchange: drive the gather/broadcast (or
    /// relay) rounds described by the gathered `metas` and return the same
    /// synchronized gradient the sites assemble.
    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange>;
}

/// Site half of the direct-gradient round shared by dAD, edAD, rank-dAD
/// and PowerSGD: ship the raw local direct grads up, receive the
/// already-scaled global mean back. Returns `(param_idx, mean_grad)`
/// pairs ready for gradient assembly with `direct_scale = 1.0`.
pub fn site_direct_exchange(
    ep: &mut Endpoint<'_>,
    stats: &LocalStats,
) -> io::Result<Vec<(usize, Matrix)>> {
    if stats.direct.is_empty() {
        return Ok(vec![]);
    }
    let refs: Vec<&Matrix> = stats.direct.iter().map(|(_, g)| g).collect();
    ep.up("direct-grad", &refs)?;
    let mats = ep.down("direct-grad")?;
    if mats.len() != stats.direct.len() {
        return Err(proto_err("direct-grad broadcast arity mismatch".into()));
    }
    Ok(stats.direct.iter().map(|&(i, _)| i).zip(mats).collect())
}

/// One directional wire round of a step's exchange — the vocabulary of
/// [`StepProtocol::plan`]. An `Up*` round means every leaf ships one frame
/// toward the root and each aggregation level combines what its links
/// delivered; a [`Round::Down`] round means the root broadcasts one frame
/// which every relay forwards verbatim (bit-identical at every leaf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Round {
    /// Dense uplink combined by the canonical segment sum: each link ships
    /// `n_segments * k` matrices (a leaf ships `1 * k`); siblings merge by
    /// elementwise addition in the fixed dyadic bracketing.
    UpSum {
        /// Frame tag.
        tag: &'static str,
    },
    /// Single-matrix uplink combined by row-stacking in ascending leaf
    /// order (exactly associative — a memcpy, not an f32 reduction).
    UpStack {
        /// Frame tag.
        tag: &'static str,
    },
    /// Sparse uplink combined by the canonical index-union sum: each link
    /// ships one sparse frame holding `n_segments` matrices.
    UpSparse {
        /// Frame tag.
        tag: &'static str,
    },
    /// Control uplink batched per leaf (ledger-exempt): relays re-batch
    /// their links' bodies under the originating leaf ids.
    CtrlUp {
        /// Frame tag.
        tag: &'static str,
    },
    /// Root broadcast of any frame kind, forwarded verbatim down the tree.
    Down {
        /// Frame tag.
        tag: &'static str,
    },
}

/// The ordered round list one step of a protocol's exchange produces —
/// what [`StepProtocol::plan`] returns and what `dad relay` executes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepPlan {
    /// Rounds in exact wire order (up and down rounds interleave for
    /// protocols like PowerSGD).
    pub rounds: Vec<Round>,
}

/// Gather the dense uplink partials of one [`Round::UpSum`] round into the
/// canonical segment stack *without* collapsing it — the relay half, which
/// re-ships the surviving segments to its parent. Each link's frame must
/// carry `n_segments(link) * k` matrices for a consistent `k`.
pub fn gather_seg_parts(ep: &mut Endpoint<'_>, tag: &str) -> io::Result<Segments<Vec<Matrix>>> {
    let mut segs = Segments::new();
    for link in 0..ep.n_links() {
        let leaves = ep.link_leaf_ids(link);
        let expect = reduce::segments_of(&leaves);
        if expect.is_empty() {
            return Err(proto_err(format!(
                "{tag}: link {} has no live leaves",
                ep.site_label(link)
            )));
        }
        let mats = ep.gather(link, tag)?;
        if mats.is_empty() || mats.len() % expect.len() != 0 {
            return Err(proto_err(format!(
                "{tag}: link {} shipped {} matrices for {} segments",
                ep.site_label(link),
                mats.len(),
                expect.len()
            )));
        }
        let k = mats.len() / expect.len();
        let mut it = mats.into_iter();
        for (start, len) in expect {
            let part: Vec<Matrix> = it.by_ref().take(k).collect();
            segs.push(start, len, part, &mut reduce::merge_mats)?;
        }
    }
    Ok(segs)
}

/// Root half of a [`Round::UpSum`] round: gather every link's partials and
/// collapse the canonical segment stack to the global sum of `k` matrices
/// (bit-equal to the flat loopback reduction over the same live leaves).
pub fn gather_seg_sum(ep: &mut Endpoint<'_>, tag: &str, k: usize) -> io::Result<Vec<Matrix>> {
    let segs = gather_seg_parts(ep, tag)?;
    for s in segs.segs() {
        if s.val.len() != k {
            return Err(proto_err(format!(
                "{tag}: segment at leaf {} carries {} matrices, expected {k}",
                s.start,
                s.val.len()
            )));
        }
    }
    segs.emit(&mut reduce::merge_mats)?
        .ok_or_else(|| proto_err(format!("{tag}: gather over zero links")))
}

/// Gather one single-matrix payload frame per link under `tag` and sum
/// them in the canonical segment bracketing (f32 addition is not
/// associative, so the bracketing is part of the loopback/TCP/tree
/// equivalence).
pub fn gather_sum(ep: &mut Endpoint<'_>, tag: &str) -> io::Result<Matrix> {
    one_mat(gather_seg_sum(ep, tag, 1)?)
}

/// Gather the sparse uplink partials of one [`Round::UpSparse`] round into
/// the canonical segment stack without collapsing it (the relay half).
/// Each link's frame must carry exactly `n_segments(link)` sparse matrices.
pub fn gather_sparse_parts(ep: &mut Endpoint<'_>, tag: &str) -> io::Result<Segments<SparseMat>> {
    let mut segs = Segments::new();
    for link in 0..ep.n_links() {
        let leaves = ep.link_leaf_ids(link);
        let expect = reduce::segments_of(&leaves);
        if expect.is_empty() {
            return Err(proto_err(format!(
                "{tag}: link {} has no live leaves",
                ep.site_label(link)
            )));
        }
        let mats = ep.gather_sparse(link, tag)?;
        if mats.len() != expect.len() {
            return Err(proto_err(format!(
                "{tag}: link {} shipped {} sparse matrices for {} segments",
                ep.site_label(link),
                mats.len(),
                expect.len()
            )));
        }
        for ((start, len), m) in expect.into_iter().zip(mats) {
            segs.push(start, len, m, &mut reduce::sparse_union_add)?;
        }
    }
    Ok(segs)
}

/// Root half of a [`Round::UpSparse`] round: collapse every link's sparse
/// partials to the canonical index-union with dyadically bracketed sums.
pub fn gather_sparse_union(ep: &mut Endpoint<'_>, tag: &str) -> io::Result<SparseMat> {
    gather_sparse_parts(ep, tag)?
        .emit(&mut reduce::sparse_union_add)?
        .ok_or_else(|| proto_err(format!("{tag}: gather over zero links")))
}

/// Aggregator half of a [`Round::UpStack`] round: gather one matrix per
/// link and row-stack them in link (= ascending leaf) order. Exactly
/// associative, so a relay's pre-stacked subtree rows splice in bitwise.
pub fn gather_stack1(ep: &mut Endpoint<'_>, tag: &str) -> io::Result<Matrix> {
    let mut parts = Vec::with_capacity(ep.n_links());
    for link in 0..ep.n_links() {
        parts.push(ep.gather1(link, tag)?);
    }
    match parts.first() {
        None => Err(proto_err(format!("{tag}: stack over zero links"))),
        Some(first) => {
            let cols = first.cols();
            if parts.iter().any(|m| m.cols() != cols) {
                return Err(proto_err(format!("{tag}: stacked column mismatch")));
            }
            let refs: Vec<&Matrix> = parts.iter().collect();
            Ok(Matrix::vertcat(&refs))
        }
    }
}

/// Encode per-leaf control bodies as one relay-batched control body:
/// `u16 count`, then per leaf `u32 leaf_id, u32 len, len bytes`.
pub fn encode_leaf_ctrl(items: &[(u32, Vec<u8>)]) -> Vec<u8> {
    let mut w = ByteWriter::new();
    w.push_u16(items.len() as u16);
    for (leaf, body) in items {
        w.push_u32(*leaf);
        w.push_u32(body.len() as u32);
        for &b in body {
            w.push_u8(b);
        }
    }
    w.finish()
}

/// Decode a relay-batched control body produced by [`encode_leaf_ctrl`].
pub fn decode_leaf_ctrl(body: &[u8]) -> io::Result<Vec<(u32, Vec<u8>)>> {
    let mut r = ByteReader::new(body);
    let n = r.read_u16()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let leaf = r.read_u32()?;
        let len = r.read_u32()? as usize;
        let mut item = vec![0u8; len];
        for b in &mut item {
            *b = r.read_u8()?;
        }
        out.push((leaf, item));
    }
    if r.remaining() != 0 {
        return Err(proto_err("trailing bytes after leaf-batched control body".into()));
    }
    Ok(out)
}

/// Aggregator half of a [`Round::CtrlUp`] round for one link: receive the
/// control frame and expand it to per-leaf `(leaf_id, body)` pairs. Links
/// whose handshake declared a single leaf ship the raw body (exactly the
/// flat-star wire format); multi-leaf links ship the batched form.
pub fn ctrl_from_leaves(
    ep: &mut Endpoint<'_>,
    link: usize,
    tag: &str,
) -> io::Result<Vec<(u32, Vec<u8>)>> {
    let (start, n) = ep.link_static_leaves(link);
    let body = ep.ctrl_from(link, tag)?;
    if n <= 1 {
        return Ok(vec![(start, body)]);
    }
    decode_leaf_ctrl(&body)
}

/// Mean the per-site raw direct gradients: canonical segment sum over the
/// sites, then scale — the reduction core shared by the star direct-grad
/// round and dad-p2p's all-to-all (both halves). `idxs[di]` is the param
/// index of the di-th direct gradient.
pub(crate) fn mean_direct(
    per_site: Vec<Vec<Matrix>>,
    idxs: &[usize],
    scale: f32,
) -> io::Result<Vec<(usize, Matrix)>> {
    let leaves: Vec<u32> = (0..per_site.len() as u32).collect();
    let sums = reduce::reduce_dense(&leaves, per_site)?
        .ok_or_else(|| proto_err("direct-grad: mean over zero sites".into()))?;
    let mut out = Vec::with_capacity(idxs.len());
    for (&idx, mut sum) in idxs.iter().zip(sums) {
        sum.scale_inplace(scale);
        out.push((idx, sum));
    }
    Ok(out)
}

/// Aggregator half of the direct-gradient round: gather every link's raw
/// (or pre-combined) direct grads, collapse the canonical segment sum,
/// scale to the mean, broadcast it, and return the pairs.
pub fn agg_direct_exchange(
    ep: &mut Endpoint<'_>,
    metas: &[StepMeta],
    scale: f32,
) -> io::Result<Vec<(usize, Matrix)>> {
    let idxs: Vec<usize> = metas[0].direct_idx.iter().map(|&i| i as usize).collect();
    if idxs.is_empty() {
        return Ok(vec![]);
    }
    let mut sums = gather_seg_sum(ep, "direct-grad", idxs.len())?;
    for m in &mut sums {
        m.scale_inplace(scale);
    }
    let refs: Vec<&Matrix> = sums.iter().collect();
    ep.bcast("direct-grad", &refs)?;
    Ok(idxs.into_iter().zip(sums).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_meta_roundtrips() {
        let meta = StepMeta {
            loss: 1.25,
            rows: 32,
            entries: vec![(0, 1), (2, u32::MAX)],
            direct_idx: vec![7],
            n_aux: 3,
        };
        let got = StepMeta::decode(&meta.encode()).unwrap();
        assert_eq!(got.loss, 1.25);
        assert_eq!(got.rows, 32);
        assert_eq!(got.entries, vec![(0, 1), (2, u32::MAX)]);
        assert_eq!(got.direct_idx, vec![7]);
        assert_eq!(got.n_aux, 3);
    }

    #[test]
    fn step_sync_roundtrips_and_weights_losses() {
        let metas = [
            StepMeta { loss: 1.0, rows: 10, entries: vec![], direct_idx: vec![], n_aux: 0 },
            StepMeta { loss: 3.0, rows: 30, entries: vec![], direct_idx: vec![], n_aux: 0 },
        ];
        let sync = StepSync::from_metas(&metas, false).unwrap();
        assert_eq!(sync.total_rows, 40);
        assert_eq!(sync.site_rows, vec![10, 30]);
        assert!((sync.loss - 2.5).abs() < 1e-6, "weighted loss {}", sync.loss);
        let got = StepSync::decode(&sync.encode()).unwrap();
        assert_eq!(got.total_rows, 40);
        assert_eq!(got.site_rows, vec![10, 30]);
        assert_eq!(got.loss, sync.loss);
    }

    #[test]
    fn oracle_sync_uses_union_rows_and_rejects_disagreement() {
        let mk = |rows| StepMeta { loss: 0.5, rows, entries: vec![], direct_idx: vec![], n_aux: 0 };
        let sync = StepSync::from_metas(&[mk(12), mk(12)], true).unwrap();
        assert_eq!(sync.total_rows, 12);
        assert_eq!(sync.loss, 0.5);
        assert!(StepSync::from_metas(&[mk(12), mk(8)], true).is_err());
    }
}

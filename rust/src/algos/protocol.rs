//! Algorithm-agnostic remote execution: every [`DistAlgorithm`] describes
//! its per-step exchange as typed rounds over a [`Transport`], so one
//! generic driver pair (`coordinator::remote::{remote_site_step,
//! remote_agg_step}`) runs the *entire* algorithm family — `pooled | dsgd |
//! dad | dad-p2p | edad | rank-dad | powersgd` — under `dad serve` /
//! `dad join` with no per-algorithm code in the coordinator.
//!
//! [`DistAlgorithm`]: crate::algos::DistAlgorithm
//!
//! The design inverts the simulated path: there an algorithm is a closure
//! over an in-memory [`crate::dist::Cluster`] with a god's-eye view; here it
//! is a state machine over messages. Each step has a fixed shape:
//!
//! ```text
//! prologue (driver)   site: step-meta ctrl up     agg: gather S metas
//!                     site: step-sync ctrl down   agg: broadcast sync
//! exchange (protocol) typed rounds:  up / gather  (site -> agg payloads)
//!                                    bcast / down (agg -> site broadcasts)
//!                                    p2p / relay  (all-to-all, dad-p2p)
//! ```
//!
//! The prologue carries losses, row counts and the stats-entry layout in
//! *control* frames (ledger-exempt protocol overhead); the exchange moves
//! payload frames with exactly the tags and shapes the loopback simulation
//! prices, which is what makes a TCP run's per-(tag, direction) ledger
//! bit-equal to the simulated run's (`tests/transport_e2e.rs`). Algorithms
//! with cross-step compressor state (PowerSGD's warm start + error
//! feedback) keep it inside their [`StepProtocol`] value — site-local, one
//! instance per process, exactly as a real deployment would.

use std::io;

use crate::dist::wire::{proto_err, Body, ByteReader, ByteWriter, Frame, SparseMat};
use crate::dist::{Direction, Ledger, Transport};
use crate::nn::model::DistModel;
use crate::nn::stats::LocalStats;
use crate::obs::trace::{tagged_span, Phase};
use crate::tensor::Matrix;

/// One endpoint of the star fabric during one remote step: the transport
/// plus the ledger that prices its payload frames. The methods are the
/// typed rounds the protocols compose; control-frame helpers never touch
/// the ledger.
pub struct Endpoint<'a> {
    t: &'a mut dyn Transport,
    ledger: &'a mut Ledger,
}

impl<'a> Endpoint<'a> {
    /// Wrap a transport + ledger for one step's rounds.
    pub fn new(t: &'a mut dyn Transport, ledger: &'a mut Ledger) -> Self {
        Endpoint { t, ledger }
    }

    /// Number of sites on the fabric.
    pub fn n_sites(&self) -> usize {
        self.t.n_sites()
    }

    /// Site round: ship a tagged payload frame up to the aggregator.
    pub fn up(&mut self, tag: &str, mats: &[&Matrix]) -> io::Result<()> {
        let _s = tagged_span("round-up", tag, Phase::Comms);
        let n = self.t.ship(Direction::SiteToAgg, tag, mats)?;
        self.ledger.record(tag, Direction::SiteToAgg, n);
        Ok(())
    }

    /// Site round: ship a tagged sparse payload frame up to the aggregator
    /// (priced with its u32-index overhead).
    pub fn up_sparse(&mut self, tag: &str, mats: &[&SparseMat]) -> io::Result<()> {
        let _s = tagged_span("round-up-sparse", tag, Phase::Comms);
        let n = self.t.ship_sparse(Direction::SiteToAgg, tag, mats)?;
        self.ledger.record(tag, Direction::SiteToAgg, n);
        Ok(())
    }

    /// Site round: receive the next broadcast payload frame.
    pub fn down(&mut self, tag: &str) -> io::Result<Vec<Matrix>> {
        let _s = tagged_span("round-down", tag, Phase::Stall);
        let f = self.t.recv_broadcast()?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::AggToSite, f.wire_len());
        }
        expect_mats(f, tag)
    }

    /// Site round: receive the next broadcast sparse payload frame.
    pub fn down_sparse(&mut self, tag: &str) -> io::Result<Vec<SparseMat>> {
        let _s = tagged_span("round-down-sparse", tag, Phase::Stall);
        let f = self.t.recv_broadcast()?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::AggToSite, f.wire_len());
        }
        expect_sparse(f, tag)
    }

    /// Site round: receive a single-matrix broadcast payload frame.
    pub fn down1(&mut self, tag: &str) -> io::Result<Matrix> {
        one_mat(self.down(tag)?)
    }

    /// Aggregator round: receive the next payload frame `site` sent up.
    pub fn gather(&mut self, site: usize, tag: &str) -> io::Result<Vec<Matrix>> {
        let _s = tagged_span("round-gather", tag, Phase::Stall);
        let f = self.t.recv_from_site(site)?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::SiteToAgg, f.wire_len());
        }
        expect_mats(f, tag)
    }

    /// Aggregator round: receive the next sparse payload frame from `site`.
    pub fn gather_sparse(&mut self, site: usize, tag: &str) -> io::Result<Vec<SparseMat>> {
        let _s = tagged_span("round-gather-sparse", tag, Phase::Stall);
        let f = self.t.recv_from_site(site)?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            self.ledger.record(&f.tag, Direction::SiteToAgg, f.wire_len());
        }
        expect_sparse(f, tag)
    }

    /// Aggregator round: receive a single-matrix uplink frame from `site`.
    pub fn gather1(&mut self, site: usize, tag: &str) -> io::Result<Matrix> {
        one_mat(self.gather(site, tag)?)
    }

    /// Aggregator round: broadcast a tagged payload frame to every site
    /// (counted once — the down-link is a shared multicast).
    pub fn bcast(&mut self, tag: &str, mats: &[&Matrix]) -> io::Result<()> {
        let _s = tagged_span("round-bcast", tag, Phase::Comms);
        let n = self.t.ship(Direction::AggToSite, tag, mats)?;
        self.ledger.record(tag, Direction::AggToSite, n);
        Ok(())
    }

    /// Aggregator round: broadcast a tagged sparse payload frame to every
    /// site (counted once, index overhead included).
    pub fn bcast_sparse(&mut self, tag: &str, mats: &[&SparseMat]) -> io::Result<()> {
        let _s = tagged_span("round-bcast-sparse", tag, Phase::Comms);
        let n = self.t.ship_sparse(Direction::AggToSite, tag, mats)?;
        self.ledger.record(tag, Direction::AggToSite, n);
        Ok(())
    }

    /// All-to-all round, site half: ship a payload frame to every one of
    /// the S-1 peers (relayed through the hub on a star fabric; priced as
    /// S-1 direct unicasts either way).
    pub fn p2p(&mut self, tag: &str, mats: &[&Matrix]) -> io::Result<()> {
        let _s = tagged_span("round-p2p", tag, Phase::Comms);
        let n = self.t.ship(Direction::PeerToPeer, tag, mats)?;
        self.ledger.record(tag, Direction::PeerToPeer, n);
        Ok(())
    }

    /// All-to-all round, site half: receive one relayed peer frame. Not
    /// ledger-recorded — the exchange is priced once on the sending side,
    /// matching the loopback convention.
    pub fn p2p_recv(&mut self, tag: &str) -> io::Result<Vec<Matrix>> {
        let _s = tagged_span("round-p2p-recv", tag, Phase::Stall);
        expect_mats(self.t.recv_broadcast()?, tag)
    }

    /// Single-matrix form of [`Endpoint::p2p_recv`].
    pub fn p2p_recv1(&mut self, tag: &str) -> io::Result<Matrix> {
        one_mat(self.p2p_recv(tag)?)
    }

    /// All-to-all round, hub half, phase 1: pull one p2p frame off
    /// `site`'s uplink *without forwarding yet*, recording it as S-1
    /// direct unicasts under [`Direction::PeerToPeer`]. Draining every
    /// uplink before any [`Endpoint::p2p_forward`] write is what keeps a
    /// blocking single-threaded hub deadlock-free at any payload size.
    pub fn p2p_pull(&mut self, site: usize) -> io::Result<Frame> {
        let _s = tagged_span("round-p2p-pull", "p2p", Phase::Stall);
        let f = self.t.recv_from_site(site)?;
        if f.kind() == crate::dist::wire::FrameKind::Payload {
            let peers = self.t.n_sites().saturating_sub(1) as u64;
            self.ledger.record(&f.tag, Direction::PeerToPeer, f.wire_len() * peers);
        }
        Ok(f)
    }

    /// All-to-all round, hub half, phase 2: forward one site's pulled
    /// frames to every other site (bytes were already recorded by
    /// [`Endpoint::p2p_pull`]; the transport flushes once per link).
    pub fn p2p_forward(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        let _s = tagged_span("round-p2p-forward", "p2p", Phase::Comms);
        self.t.forward_p2p(from_site, frames)
    }

    /// Site control round: ship a control frame up (ledger-exempt).
    pub fn ctrl_up(&mut self, tag: &str, body: &[u8]) -> io::Result<()> {
        let _s = tagged_span("ctrl-up", tag, Phase::Comms);
        self.t.ship_control(Direction::SiteToAgg, tag, body)?;
        Ok(())
    }

    /// Site control round: receive a broadcast control frame. Blocked
    /// time here is where a site waits out the aggregator's gather of the
    /// slowest peer, so the span is attributed to the stall phase.
    pub fn ctrl_down(&mut self, tag: &str) -> io::Result<Vec<u8>> {
        let _s = tagged_span("ctrl-down", tag, Phase::Stall);
        expect_ctrl(self.t.recv_broadcast()?, tag)
    }

    /// Aggregator control round: broadcast a control frame (ledger-exempt).
    pub fn ctrl_bcast(&mut self, tag: &str, body: &[u8]) -> io::Result<()> {
        let _s = tagged_span("ctrl-bcast", tag, Phase::Comms);
        self.t.ship_control(Direction::AggToSite, tag, body)?;
        Ok(())
    }

    /// Aggregator control round: receive a control frame from `site`.
    /// Blocked time here is the aggregator's straggler stall.
    pub fn ctrl_from(&mut self, site: usize, tag: &str) -> io::Result<Vec<u8>> {
        let _s = tagged_span("ctrl-from", tag, Phase::Stall);
        expect_ctrl(self.t.recv_from_site(site)?, tag)
    }

    /// Operator-facing label for live link index `site` (the originally
    /// assigned id, even after retirements compacted the links).
    pub fn site_label(&self, site: usize) -> String {
        self.t.site_label(site)
    }

    /// Permanently drop live link `site` from the fabric — the degradation
    /// seam `coordinator::remote` uses to continue a round with the
    /// surviving sites (see [`Transport::retire_site`]).
    pub fn retire_site(&mut self, site: usize) -> io::Result<()> {
        self.t.retire_site(site)
    }
}

pub(crate) fn expect_mats(f: Frame, want: &str) -> io::Result<Vec<Matrix>> {
    match f.body {
        Body::Mats(m) if f.tag == want => Ok(m),
        _ => Err(proto_err(format!("expected payload frame {want:?}, got {:?}", f.tag))),
    }
}

pub(crate) fn expect_sparse(f: Frame, want: &str) -> io::Result<Vec<SparseMat>> {
    match f.body {
        Body::Sparse(m) if f.tag == want => Ok(m),
        _ => Err(proto_err(format!("expected sparse frame {want:?}, got {:?}", f.tag))),
    }
}

pub(crate) fn expect_ctrl(f: Frame, want: &str) -> io::Result<Vec<u8>> {
    match f.body {
        Body::Control(b) if f.tag == want => Ok(b),
        _ => Err(proto_err(format!("expected control frame {want:?}, got {:?}", f.tag))),
    }
}

pub(crate) fn one_mat(mats: Vec<Matrix>) -> io::Result<Matrix> {
    let mut mats = mats;
    if mats.len() != 1 {
        return Err(proto_err(format!("expected exactly 1 matrix, got {}", mats.len())));
    }
    Ok(mats.pop().expect("checked non-empty"))
}

/// Per-step uplink metadata (the prologue's `step-meta` control frame):
/// the site's loss and row count plus the parameter-index layout of its
/// stats entries, so the aggregator can drive any algorithm's gather
/// rounds without holding data.
#[derive(Clone, Debug)]
pub struct StepMeta {
    /// Mean loss over the site's batch.
    pub loss: f32,
    /// Output-delta rows (the site's contribution to the global batch).
    pub rows: u32,
    /// Per stats entry: (weight param index, bias param index or u32::MAX).
    pub entries: Vec<(u32, u32)>,
    /// Param indices of direct (non-outer-product) gradients.
    pub direct_idx: Vec<u32>,
    /// Number of edAD aux-activation matrices the site will ship.
    pub n_aux: u16,
}

impl StepMeta {
    /// Describe one site's [`LocalStats`] for the wire.
    pub fn of(stats: &LocalStats) -> StepMeta {
        StepMeta {
            loss: stats.loss,
            rows: stats.entries.last().map(|e| e.d.rows()).unwrap_or(0) as u32,
            entries: stats
                .entries
                .iter()
                .map(|e| (e.w_idx as u32, e.b_idx.map(|b| b as u32).unwrap_or(u32::MAX)))
                .collect(),
            direct_idx: stats.direct.iter().map(|&(i, _)| i as u32).collect(),
            n_aux: stats.aux.len() as u16,
        }
    }

    /// Serialize as a control-frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_f32(self.loss);
        w.push_u32(self.rows);
        w.push_u16(self.entries.len() as u16);
        for &(wi, bi) in &self.entries {
            w.push_u32(wi);
            w.push_u32(bi);
        }
        w.push_u16(self.direct_idx.len() as u16);
        for &i in &self.direct_idx {
            w.push_u32(i);
        }
        w.push_u16(self.n_aux);
        w.finish()
    }

    /// Parse a control-frame body (every read bounds-checked).
    pub fn decode(body: &[u8]) -> io::Result<StepMeta> {
        let mut r = ByteReader::new(body);
        let loss = r.read_f32()?;
        let rows = r.read_u32()?;
        let n_entries = r.read_u16()? as usize;
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let wi = r.read_u32()?;
            let bi = r.read_u32()?;
            entries.push((wi, bi));
        }
        let n_direct = r.read_u16()? as usize;
        let mut direct_idx = Vec::with_capacity(n_direct);
        for _ in 0..n_direct {
            direct_idx.push(r.read_u32()?);
        }
        let n_aux = r.read_u16()?;
        Ok(StepMeta { loss, rows, entries, direct_idx, n_aux })
    }
}

/// The prologue's `step-sync` broadcast: everything a site needs before
/// its exchange rounds — the global row count (which fixes the 1/N
/// gradient scale), the batch-size-weighted global loss, and the per-site
/// row counts (edAD's delta recomputation needs them).
#[derive(Clone, Debug)]
pub struct StepSync {
    /// Σ per-site output-delta rows (the global batch size).
    pub total_rows: usize,
    /// Batch-size-weighted mean training loss across sites.
    pub loss: f32,
    /// Per-site output-delta rows, in canonical site order.
    pub site_rows: Vec<usize>,
}

impl StepSync {
    /// Derive the sync frame from the gathered metas. For the pooled
    /// oracle every site computed the identical union batch, so the global
    /// count is any single site's (they are checked to agree) and the loss
    /// is the union loss, not a weighted mean.
    pub fn from_metas(metas: &[StepMeta], oracle: bool) -> io::Result<StepSync> {
        if metas.is_empty() {
            return Err(proto_err("step-sync needs at least one site meta".into()));
        }
        let site_rows: Vec<usize> = metas.iter().map(|m| m.rows as usize).collect();
        if oracle {
            if site_rows.iter().any(|&r| r != site_rows[0]) {
                return Err(proto_err("pooled oracle sites disagree on the union batch".into()));
            }
            return Ok(StepSync { total_rows: site_rows[0], loss: metas[0].loss, site_rows });
        }
        let total_rows: usize = site_rows.iter().sum();
        let num: f64 = metas.iter().map(|m| m.loss as f64 * m.rows as f64).sum();
        let loss = (num / total_rows.max(1) as f64) as f32;
        Ok(StepSync { total_rows, loss, site_rows })
    }

    /// The 1/(global batch) gradient scale every algorithm applies.
    pub fn scale(&self) -> f32 {
        1.0 / self.total_rows as f32
    }

    /// Serialize as a control-frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_u32(self.total_rows as u32);
        w.push_f32(self.loss);
        w.push_u16(self.site_rows.len() as u16);
        for &r in &self.site_rows {
            w.push_u32(r as u32);
        }
        w.finish()
    }

    /// Parse a control-frame body.
    pub fn decode(body: &[u8]) -> io::Result<StepSync> {
        let mut r = ByteReader::new(body);
        let total_rows = r.read_u32()? as usize;
        let loss = r.read_f32()?;
        let n = r.read_u16()? as usize;
        let mut site_rows = Vec::with_capacity(n);
        for _ in 0..n {
            site_rows.push(r.read_u32()? as usize);
        }
        Ok(StepSync { total_rows, loss, site_rows })
    }
}

/// The aggregator half's result: the synchronized gradient (for the
/// lockstep eval replica) plus rank-dAD's effective-rank telemetry,
/// `eff_ranks[entry][site]` (empty for every other algorithm).
pub struct AggExchange {
    /// Synchronized global gradient, aligned with the param list.
    pub grads: Vec<Matrix>,
    /// rank-dAD effective ranks per stats entry, per site.
    pub eff_ranks: Vec<Vec<usize>>,
}

/// One algorithm's wire protocol: the site and aggregator halves of the
/// per-step exchange, as typed rounds over an [`Endpoint`]. Implementations
/// are state machines — `&mut self` carries cross-step compressor state
/// (PowerSGD's warm start + error feedback stays site-local by
/// construction: each process owns one protocol value).
///
/// The meta/sync prologue has already run when either half is called, so
/// the global row count, weighted loss and per-site rows are available in
/// `sync`. Both halves must ship/gather payload frames with exactly the
/// tags, shapes and order the simulated algorithm prices through the
/// loopback transport — that equivalence is asserted per algorithm by
/// `tests/transport_e2e.rs`.
pub trait StepProtocol<M: DistModel>: Send {
    /// Protocol name for diagnostics (matches the algorithm name).
    fn name(&self) -> &'static str;

    /// True for the pooled oracle: every process computes the union batch
    /// locally and the exchange ships no payload frames. The drivers give
    /// oracle protocols the union batch instead of a shard batch and run
    /// the site half on the aggregator too.
    fn oracle(&self) -> bool {
        false
    }

    /// True when the aggregator half can keep driving this protocol after
    /// sites were retired mid-run (the degraded mode of
    /// `coordinator::remote::serve_training`). Requires the site half to be
    /// shaped only by the sync frame — never by a site count captured at
    /// startup. dAD, dSGD, rank-dAD, the pooled oracle and the sparse
    /// family (DGC / VBC / AdaComp, whose residual state is per-site and
    /// whose scale comes from the sync frame) qualify; edAD
    /// (weight-coupled delta recomputation), dad-p2p (mesh membership) and
    /// PowerSGD (site half scales means by the startup `n_sites`) do not,
    /// so a lost site fails those runs cleanly instead.
    fn supports_degrade(&self) -> bool {
        false
    }

    /// Site half of the exchange. `stats` are this site's local statistics
    /// for the step's batch; returns the synchronized global gradient
    /// (identical on every endpoint, up to the algorithm's compression).
    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>>;

    /// Aggregator half of the exchange: drive the gather/broadcast (or
    /// relay) rounds described by the gathered `metas` and return the same
    /// synchronized gradient the sites assemble.
    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange>;
}

/// Site half of the direct-gradient round shared by dAD, edAD, rank-dAD
/// and PowerSGD: ship the raw local direct grads up, receive the
/// already-scaled global mean back. Returns `(param_idx, mean_grad)`
/// pairs ready for gradient assembly with `direct_scale = 1.0`.
pub fn site_direct_exchange(
    ep: &mut Endpoint<'_>,
    stats: &LocalStats,
) -> io::Result<Vec<(usize, Matrix)>> {
    if stats.direct.is_empty() {
        return Ok(vec![]);
    }
    let refs: Vec<&Matrix> = stats.direct.iter().map(|(_, g)| g).collect();
    ep.up("direct-grad", &refs)?;
    let mats = ep.down("direct-grad")?;
    if mats.len() != stats.direct.len() {
        return Err(proto_err("direct-grad broadcast arity mismatch".into()));
    }
    Ok(stats.direct.iter().map(|&(i, _)| i).zip(mats).collect())
}

/// Gather one single-matrix payload frame per site under `tag` and sum
/// them **in site order** — the reduction-order contract every aggregator
/// mean/sum shares with the simulation (f32 addition is not associative,
/// so the order is part of the loopback/TCP equivalence).
pub fn gather_sum(ep: &mut Endpoint<'_>, n_sites: usize, tag: &str) -> io::Result<Matrix> {
    let mut acc: Option<Matrix> = None;
    for site in 0..n_sites {
        let m = ep.gather1(site, tag)?;
        acc = Some(match acc {
            None => m,
            Some(mut a) => {
                a.axpy(1.0, &m);
                a
            }
        });
    }
    acc.ok_or_else(|| proto_err(format!("{tag}: gather over zero sites")))
}

/// Mean the per-site raw direct gradients: sum in **site order**, then
/// scale — the reduction core shared by the star direct-grad round and
/// dad-p2p's all-to-all (both halves). `idxs[di]` is the param index of
/// the di-th direct gradient.
pub(crate) fn mean_direct(
    per_site: &[Vec<Matrix>],
    idxs: &[usize],
    scale: f32,
) -> Vec<(usize, Matrix)> {
    let mut out = Vec::with_capacity(idxs.len());
    for (di, &idx) in idxs.iter().enumerate() {
        let mut sum = per_site[0][di].clone();
        for s in &per_site[1..] {
            sum.axpy(1.0, &s[di]);
        }
        sum.scale_inplace(scale);
        out.push((idx, sum));
    }
    out
}

/// Aggregator half of the direct-gradient round: gather every site's raw
/// direct grads, mean them (sum in site order, then scale — the simulated
/// reduction order), broadcast the mean, and return the pairs.
pub fn agg_direct_exchange(
    ep: &mut Endpoint<'_>,
    metas: &[StepMeta],
    scale: f32,
) -> io::Result<Vec<(usize, Matrix)>> {
    let idxs: Vec<usize> = metas[0].direct_idx.iter().map(|&i| i as usize).collect();
    if idxs.is_empty() {
        return Ok(vec![]);
    }
    let mut per_site: Vec<Vec<Matrix>> = Vec::with_capacity(metas.len());
    for site in 0..metas.len() {
        let mats = ep.gather(site, "direct-grad")?;
        if mats.len() != idxs.len() {
            return Err(proto_err(format!("site {site} direct-grad arity mismatch")));
        }
        per_site.push(mats);
    }
    let out = mean_direct(&per_site, &idxs, scale);
    let refs: Vec<&Matrix> = out.iter().map(|(_, g)| g).collect();
    ep.bcast("direct-grad", &refs)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_meta_roundtrips() {
        let meta = StepMeta {
            loss: 1.25,
            rows: 32,
            entries: vec![(0, 1), (2, u32::MAX)],
            direct_idx: vec![7],
            n_aux: 3,
        };
        let got = StepMeta::decode(&meta.encode()).unwrap();
        assert_eq!(got.loss, 1.25);
        assert_eq!(got.rows, 32);
        assert_eq!(got.entries, vec![(0, 1), (2, u32::MAX)]);
        assert_eq!(got.direct_idx, vec![7]);
        assert_eq!(got.n_aux, 3);
    }

    #[test]
    fn step_sync_roundtrips_and_weights_losses() {
        let metas = [
            StepMeta { loss: 1.0, rows: 10, entries: vec![], direct_idx: vec![], n_aux: 0 },
            StepMeta { loss: 3.0, rows: 30, entries: vec![], direct_idx: vec![], n_aux: 0 },
        ];
        let sync = StepSync::from_metas(&metas, false).unwrap();
        assert_eq!(sync.total_rows, 40);
        assert_eq!(sync.site_rows, vec![10, 30]);
        assert!((sync.loss - 2.5).abs() < 1e-6, "weighted loss {}", sync.loss);
        let got = StepSync::decode(&sync.encode()).unwrap();
        assert_eq!(got.total_rows, 40);
        assert_eq!(got.site_rows, vec![10, 30]);
        assert_eq!(got.loss, sync.loss);
    }

    #[test]
    fn oracle_sync_uses_union_rows_and_rejects_disagreement() {
        let mk = |rows| StepMeta { loss: 0.5, rows, entries: vec![], direct_idx: vec![], n_aux: 0 };
        let sync = StepSync::from_metas(&[mk(12), mk(12)], true).unwrap();
        assert_eq!(sync.total_rows, 12);
        assert_eq!(sync.loss, 0.5);
        assert!(StepSync::from_metas(&[mk(12), mk(8)], true).is_err());
    }
}

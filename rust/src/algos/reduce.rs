//! Canonical segment reduction — the associative combine surface behind
//! tree aggregation.
//!
//! f32 addition is not associative, so "sum the per-site contributions"
//! only means one bit pattern if every reducer — the flat simulator, a
//! star aggregator, and every relay in a deep tree — brackets the adds
//! identically. This module fixes the bracketing once: a partial sum over
//! a set of leaves is its *canonical dyadic segment decomposition*, and
//! two adjacent segments `(s1, n)` and `(s2, n)` merge iff
//! `s2 == s1 + n && s1 % (2 * n) == 0` — i.e. they are the two halves of
//! an aligned power-of-two block. Greedy left-to-right construction with
//! that rule is confluent: any grouping of the leaves into contiguous
//! child ranges (a tree of relays) reaches the same segments through the
//! same pairwise merges, so tree-reduced sums are bit-equal to the flat
//! reduction. Non-contiguous survivor sets after churn simply leave
//! unmergeable segments side by side; the final emit folds whatever
//! remains left to right.
//!
//! The payload carried per segment is generic: dense matrix lists
//! ([`merge_mats`]), sparse index-union matrices ([`sparse_union_add`]),
//! or `()` when only the segment *structure* is needed (a parent
//! predicting how many segments a child will ship — [`segments_of`]).

use std::io;

use crate::dist::wire::{proto_err, SparseMat};
use crate::tensor::Matrix;

/// One contiguous, already-reduced run of leaves `[start, start + len)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Seg<T> {
    /// First leaf id covered by this partial.
    pub start: u32,
    /// Number of consecutive leaves covered.
    pub len: u32,
    /// The reduced payload for those leaves.
    pub val: T,
}

/// Whether `b` is the right sibling of `a` in the canonical dyadic tree.
fn siblings<T>(a: &Seg<T>, b: &Seg<T>) -> bool {
    b.start == a.start + a.len && a.len == b.len && a.start % (2 * a.len) == 0
}

/// A partial reduction over a leaf set: disjoint segments in ascending
/// leaf order, each the canonical reduction of its range. Pushing keeps
/// the stack canonical by greedily merging sibling segments, so the same
/// leaf set always yields the same segments regardless of how it was
/// split across children.
#[derive(Debug, Clone, PartialEq)]
pub struct Segments<T> {
    segs: Vec<Seg<T>>,
}

impl<T> Default for Segments<T> {
    fn default() -> Self {
        Segments { segs: Vec::new() }
    }
}

impl<T> Segments<T> {
    /// An empty partial (no leaves contributed yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// Number of segments currently held.
    pub fn len(&self) -> usize {
        self.segs.len()
    }

    /// True iff no leaf has contributed.
    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    /// The segments, ascending by `start`.
    pub fn segs(&self) -> &[Seg<T>] {
        &self.segs
    }

    /// Push the partial for leaves `[start, start + len)` and re-canonicalize
    /// by merging sibling segments via `merge(left, right)`. Segments must
    /// arrive in ascending, non-overlapping leaf order.
    pub fn push(
        &mut self,
        start: u32,
        len: u32,
        val: T,
        merge: &mut impl FnMut(&mut T, T) -> io::Result<()>,
    ) -> io::Result<()> {
        if len == 0 {
            return Err(proto_err("segment reduce: zero-length segment".into()));
        }
        if let Some(last) = self.segs.last() {
            if start < last.start + last.len {
                return Err(proto_err(format!(
                    "segment reduce: leaf {start} arrived out of order (last range ends at {})",
                    last.start + last.len
                )));
            }
        }
        self.segs.push(Seg { start, len, val });
        while self.segs.len() >= 2 {
            let n = self.segs.len();
            if !siblings(&self.segs[n - 2], &self.segs[n - 1]) {
                break;
            }
            let right = self.segs.pop().expect("len >= 2");
            let left = self.segs.last_mut().expect("len >= 1");
            merge(&mut left.val, right.val)?;
            left.len *= 2;
        }
        Ok(())
    }

    /// Absorb another partial (a child's segments), which must cover leaves
    /// strictly after every leaf already held.
    pub fn absorb(
        &mut self,
        other: Segments<T>,
        merge: &mut impl FnMut(&mut T, T) -> io::Result<()>,
    ) -> io::Result<()> {
        for s in other.segs {
            self.push(s.start, s.len, s.val, merge)?;
        }
        Ok(())
    }

    /// Collapse to the final reduction: fold the remaining (unmergeable)
    /// segments left to right. `None` iff no leaf contributed.
    pub fn emit(
        self,
        merge: &mut impl FnMut(&mut T, T) -> io::Result<()>,
    ) -> io::Result<Option<T>> {
        let mut it = self.segs.into_iter();
        let mut acc = match it.next() {
            Some(s) => s.val,
            None => return Ok(None),
        };
        for s in it {
            merge(&mut acc, s.val)?;
        }
        Ok(Some(acc))
    }
}

/// The canonical segment decomposition (start, len) of a live leaf set,
/// given in ascending order. A parent uses this to predict how many
/// segment partials a child covering exactly `leaves` will ship.
pub fn segments_of(leaves: &[u32]) -> Vec<(u32, u32)> {
    let mut segs: Segments<()> = Segments::new();
    let mut noop = |_: &mut (), _: ()| Ok(());
    for &leaf in leaves {
        segs.push(leaf, 1, (), &mut noop).expect("ascending leaf ids");
    }
    segs.segs.iter().map(|s| (s.start, s.len)).collect()
}

/// Elementwise `left[i] += right[i]` over parallel matrix lists — the
/// dense merge used for gradient sums. Shapes must agree pairwise.
// The `&mut Vec` (not `&mut [Matrix]`) is pinned by the generic merge
// interface `FnMut(&mut T, T)` with `T = Vec<Matrix>`.
#[allow(clippy::ptr_arg)]
pub fn merge_mats(left: &mut Vec<Matrix>, right: Vec<Matrix>) -> io::Result<()> {
    if left.len() != right.len() {
        return Err(proto_err(format!(
            "dense combine: {} matrices vs {}",
            left.len(),
            right.len()
        )));
    }
    for (a, b) in left.iter_mut().zip(&right) {
        if a.shape() != b.shape() {
            return Err(proto_err(format!(
                "dense combine: shape {:?} vs {:?}",
                a.shape(),
                b.shape()
            )));
        }
        for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
            *x += y;
        }
    }
    Ok(())
}

/// Canonical pairwise sparse merge: sorted index union with f32 value
/// adds at collisions (left + right, in that order). The result is a
/// valid wire `SparseMat` (strictly increasing indices).
pub fn sparse_union_add(left: &mut SparseMat, right: SparseMat) -> io::Result<()> {
    if (left.rows, left.cols) != (right.rows, right.cols) {
        return Err(proto_err(format!(
            "sparse combine: shape {}x{} vs {}x{}",
            left.rows, left.cols, right.rows, right.cols
        )));
    }
    let mut idx = Vec::with_capacity(left.idx.len() + right.idx.len());
    let mut vals = Vec::with_capacity(left.vals.len() + right.vals.len());
    let (mut i, mut j) = (0usize, 0usize);
    while i < left.idx.len() && j < right.idx.len() {
        match left.idx[i].cmp(&right.idx[j]) {
            std::cmp::Ordering::Less => {
                idx.push(left.idx[i]);
                vals.push(left.vals[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                idx.push(right.idx[j]);
                vals.push(right.vals[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                idx.push(left.idx[i]);
                vals.push(left.vals[i] + right.vals[j]);
                i += 1;
                j += 1;
            }
        }
    }
    idx.extend_from_slice(&left.idx[i..]);
    vals.extend_from_slice(&left.vals[i..]);
    idx.extend_from_slice(&right.idx[j..]);
    vals.extend_from_slice(&right.vals[j..]);
    left.idx = idx;
    left.vals = vals;
    Ok(())
}

/// Reduce one dense contribution per live leaf to the canonical total.
/// `leaves[i]` is the leaf id of `parts[i]`; ids must be ascending.
/// Returns `None` for an empty leaf set.
pub fn reduce_dense(leaves: &[u32], parts: Vec<Vec<Matrix>>) -> io::Result<Option<Vec<Matrix>>> {
    debug_assert_eq!(leaves.len(), parts.len());
    let mut segs = Segments::new();
    for (&leaf, val) in leaves.iter().zip(parts) {
        segs.push(leaf, 1, val, &mut merge_mats)?;
    }
    segs.emit(&mut merge_mats)
}

/// Reduce one sparse contribution per live leaf to the canonical
/// union-with-sums. Returns `None` for an empty leaf set.
pub fn reduce_sparse(leaves: &[u32], parts: Vec<SparseMat>) -> io::Result<Option<SparseMat>> {
    debug_assert_eq!(leaves.len(), parts.len());
    let mut segs = Segments::new();
    for (&leaf, val) in leaves.iter().zip(parts) {
        segs.push(leaf, 1, val, &mut sparse_union_add)?;
    }
    segs.emit(&mut sparse_union_add)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn mat(rng: &mut Rng) -> Matrix {
        Matrix::randn(3, 5, 1.0, rng)
    }

    /// Flat canonical reduction of per-leaf dense parts.
    fn flat_dense(leaves: &[u32], parts: &[Vec<Matrix>]) -> Vec<Matrix> {
        reduce_dense(leaves, parts.to_vec()).unwrap().unwrap()
    }

    /// Tree reduction: split the (ascending) leaves into contiguous child
    /// ranges per `cuts`, reduce each child to its segments, absorb the
    /// children in order, emit.
    fn tree_dense(leaves: &[u32], parts: &[Vec<Matrix>], cuts: &[usize]) -> Vec<Matrix> {
        let mut root: Segments<Vec<Matrix>> = Segments::new();
        let mut lo = 0usize;
        for &hi in cuts.iter().chain(std::iter::once(&leaves.len())) {
            let mut child: Segments<Vec<Matrix>> = Segments::new();
            for k in lo..hi {
                child.push(leaves[k], 1, parts[k].clone(), &mut merge_mats).unwrap();
            }
            root.absorb(child, &mut merge_mats).unwrap();
            lo = hi;
        }
        root.emit(&mut merge_mats).unwrap().unwrap()
    }

    fn bits(ms: &[Matrix]) -> Vec<u32> {
        ms.iter().flat_map(|m| m.data().iter().map(|v| v.to_bits())).collect()
    }

    #[test]
    fn aligned_power_of_two_ranges_collapse_to_one_segment() {
        assert_eq!(segments_of(&[4, 5, 6, 7]), vec![(4, 4)]);
        assert_eq!(segments_of(&[0, 1, 2, 3, 4, 5, 6, 7]), vec![(0, 8)]);
    }

    #[test]
    fn unaligned_and_gapped_sets_decompose_deterministically() {
        // 1,2,3: leaf 1 cannot pair left, 2+3 form an aligned block.
        assert_eq!(segments_of(&[1, 2, 3]), vec![(1, 1), (2, 2)]);
        // Survivors {0,1,3}: the dead leaf 2 blocks the (2,2) block.
        assert_eq!(segments_of(&[0, 1, 3]), vec![(0, 2), (3, 1)]);
        assert_eq!(segments_of(&[]), Vec::<(u32, u32)>::new());
        assert_eq!(segments_of(&[9]), vec![(9, 1)]);
    }

    #[test]
    fn out_of_order_and_overlapping_pushes_are_rejected() {
        let mut s: Segments<()> = Segments::new();
        let mut noop = |_: &mut (), _: ()| Ok(());
        s.push(3, 1, (), &mut noop).unwrap();
        assert!(s.push(3, 1, (), &mut noop).is_err());
        assert!(s.push(1, 1, (), &mut noop).is_err());
        assert!(s.push(4, 0, (), &mut noop).is_err());
    }

    #[test]
    fn tree_bracketings_are_bit_equal_to_flat_including_empty_children() {
        // Property (hand-rolled; the crate is dependency-free): over random
        // leaf subsets and random contiguous bracketings — including empty
        // and singleton child ranges — the tree reduction is bit-identical
        // to the flat canonical reduction.
        let mut rng = Rng::new(0xbeef);
        for case in 0..200u32 {
            let n = 1 + (rng.next_u64() % 24) as usize;
            // Random survivor subset of 0..n (never empty).
            let mut leaves: Vec<u32> =
                (0..n as u32).filter(|_| rng.next_u64() % 4 != 0).collect();
            if leaves.is_empty() {
                leaves.push((rng.next_u64() % n as u64) as u32);
            }
            let parts: Vec<Vec<Matrix>> = leaves
                .iter()
                .map(|_| vec![mat(&mut rng), Matrix::randn(2, 2, 1.0, &mut rng)])
                .collect();
            let flat = flat_dense(&leaves, &parts);
            // Random cut set (sorted positions inside 0..len), duplicates
            // allowed: a duplicated cut is an empty child.
            let mut cuts: Vec<usize> = (0..(rng.next_u64() % 4) as usize)
                .map(|_| (rng.next_u64() as usize) % (leaves.len() + 1))
                .collect();
            cuts.sort_unstable();
            let tree = tree_dense(&leaves, &parts, &cuts);
            assert_eq!(bits(&flat), bits(&tree), "case {case}: leaves {leaves:?} cuts {cuts:?}");
        }
    }

    #[test]
    fn sparse_tree_bracketings_match_flat_union() {
        let mut rng = Rng::new(0xfeed);
        for case in 0..200u32 {
            let n = 1 + (rng.next_u64() % 12) as usize;
            let leaves: Vec<u32> = (0..n as u32).collect();
            let parts: Vec<SparseMat> = leaves
                .iter()
                .map(|_| {
                    let idx: Vec<u32> = (0..20u32).filter(|_| rng.next_u64() % 3 == 0).collect();
                    let vals: Vec<f32> = idx.iter().map(|_| rng.normal()).collect();
                    SparseMat { rows: 4, cols: 5, idx, vals }
                })
                .collect();
            let flat = reduce_sparse(&leaves, parts.clone()).unwrap().unwrap();
            // Split at a random point into two children, reduce each, absorb.
            let cut = (rng.next_u64() as usize) % (n + 1);
            let mut root: Segments<SparseMat> = Segments::new();
            for range in [0..cut, cut..n] {
                let mut child: Segments<SparseMat> = Segments::new();
                for k in range {
                    child.push(leaves[k], 1, parts[k].clone(), &mut sparse_union_add).unwrap();
                }
                root.absorb(child, &mut sparse_union_add).unwrap();
            }
            let tree = root.emit(&mut sparse_union_add).unwrap().unwrap();
            assert_eq!(flat.idx, tree.idx, "case {case}");
            let fb: Vec<u32> = flat.vals.iter().map(|v| v.to_bits()).collect();
            let tb: Vec<u32> = tree.vals.iter().map(|v| v.to_bits()).collect();
            assert_eq!(fb, tb, "case {case}");
        }
    }

    #[test]
    fn sparse_union_add_merges_and_sums_collisions() {
        let mut a = SparseMat { rows: 2, cols: 3, idx: vec![0, 2, 5], vals: vec![1.0, 2.0, 3.0] };
        let b = SparseMat { rows: 2, cols: 3, idx: vec![2, 4], vals: vec![10.0, 20.0] };
        sparse_union_add(&mut a, b).unwrap();
        assert_eq!(a.idx, vec![0, 2, 4, 5]);
        assert_eq!(a.vals, vec![1.0, 12.0, 20.0, 3.0]);
        let bad = SparseMat { rows: 3, cols: 3, idx: vec![], vals: vec![] };
        assert!(sparse_union_add(&mut a, bad).is_err());
    }

    #[test]
    fn dense_merge_rejects_mismatched_shapes() {
        let mut a = vec![Matrix::zeros(2, 2)];
        assert!(merge_mats(&mut a, vec![Matrix::zeros(2, 3)]).is_err());
        assert!(merge_mats(&mut a, vec![]).is_err());
    }
}

//! The sparse gradient-compression rivals: DGC (Lin et al. 2017,
//! arXiv:1712.01887), variance-based compression (Tsuzuku et al. 2018,
//! arXiv:1802.06058) and AdaComp (Chen et al. 2017, arXiv:1712.02679).
//!
//! All three ship *top-k style* subsets of the materialized weight
//! gradient as sparse `(u32 index, f32 value)` frames (`wire::SparseMat`,
//! 8 honest bytes per transmitted element) and keep what they did not
//! transmit in a per-site error-feedback **residual** that is folded into
//! the next step's candidate update. They differ only in how the transmit
//! set is chosen:
//!
//! | algorithm | transmit rule | residual state |
//! |---|---|---|
//! | `dgc:k`     | top k% of \|v\| after momentum correction  | velocity v + momentum m |
//! | `vbc`       | N·mean² >= λ·var (batch significance test) | residual r |
//! | `adacomp`   | \|r + 2u\| >= bin-local max \|r + u\|      | residual r |
//!
//! The exchange itself is shared: each site ships one `sparse-grad` frame
//! per stats entry; the aggregator folds the per-site contributions with
//! the **canonical segment reduction** (`algos::reduce` — index union,
//! collisions summed in dyadic leaf order, the same f32 reduction-order
//! contract every reduction in this repo obeys) and broadcasts the sparse
//! union. At full density (`dgc:100`, `vbc:0`, `adacomp:1`) every residual
//! clears each step and the update equals dense dSGD bit for bit — the
//! correctness anchor `full_density_configs_match_dsgd_bitwise` pins.
//! Biases and direct gradients ride dSGD-style dense frames, exactly as
//! the low-rank compressors do.

use std::io;

use crate::algos::common::{
    exchange_direct, gather_local_stats, weighted_loss, DistAlgorithm, StepOutcome,
};
use crate::algos::compressed::{bytes_now, exchange_bias};
use crate::algos::protocol::{
    agg_direct_exchange, gather_sparse_union, gather_sum, site_direct_exchange, AggExchange,
    Endpoint, Round, StepMeta, StepPlan, StepProtocol, StepSync,
};
use crate::dist::wire::{proto_err, SparseMat};
use crate::dist::Cluster;
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{LocalStats, StatsEntry};
use crate::tensor::{matmul_tn, Matrix};

/// DGC's momentum-correction factor (Lin et al. use SGD-momentum 0.9).
const DGC_MOMENTUM: f32 = 0.9;

/// Which transmit rule a sparse compressor applies. One rule + one state
/// table = one algorithm; everything else (exchange shape, residual
/// bookkeeping, wire frames) is shared.
#[derive(Clone, Debug, PartialEq)]
pub enum SparseRule {
    /// Deep Gradient Compression: momentum-corrected top-k by magnitude.
    /// `density` is the transmitted percentage of elements, in (0, 100].
    Dgc {
        /// Percentage of elements transmitted per entry per step.
        density: f32,
    },
    /// Variance-based compression: transmit where the batch mean gradient
    /// is significant against its own sample variance (N·mean² >= λ·var).
    Vbc {
        /// Significance threshold λ >= 0 (0 transmits everything).
        lambda: f32,
    },
    /// AdaComp: bin-local self-adjusting threshold, transmit where
    /// |residual + 2·grad| >= max_bin |residual + grad|.
    AdaComp {
        /// Bin size in elements (1 = per-element bins = full density).
        bin: usize,
    },
}

impl SparseRule {
    /// The CLI algorithm family name this rule implements.
    pub fn algo_name(&self) -> &'static str {
        match self {
            SparseRule::Dgc { .. } => "dgc",
            SparseRule::Vbc { .. } => "vbc",
            SparseRule::AdaComp { .. } => "adacomp",
        }
    }

    fn needs_momentum(&self) -> bool {
        matches!(self, SparseRule::Dgc { .. })
    }
}

/// Per-(site, entry) error-feedback state. `residual` is DGC's velocity
/// accumulator / VBC and AdaComp's untransmitted remainder; `momentum`
/// exists only for DGC.
struct EntryState {
    residual: Matrix,
    momentum: Option<Matrix>,
}

impl EntryState {
    fn new(rows: usize, cols: usize, momentum: bool) -> Self {
        EntryState {
            residual: Matrix::zeros(rows, cols),
            momentum: momentum.then(|| Matrix::zeros(rows, cols)),
        }
    }
}

/// One site's compression of one entry's fresh scaled update: fold the
/// update into the residual state, pick the transmit set per `rule`,
/// return it as a sparse matrix and keep the rest as next step's residual.
fn compress(rule: &SparseRule, st: &mut EntryState, e: &StatsEntry, scale: f32) -> SparseMat {
    let _s = crate::obs::trace::phase_span("sparse-compress", crate::obs::trace::Phase::Compress);
    let u = e.weight_grad(scale);
    match *rule {
        SparseRule::Dgc { density } => {
            // Momentum correction (DGC §3.1): accumulate *velocity*, not
            // raw gradients, so delayed elements ship what momentum-SGD
            // would have applied. m and v are cleared where transmitted.
            let m = st.momentum.as_mut().expect("dgc state carries momentum");
            m.scale_inplace(DGC_MOMENTUM);
            m.axpy(1.0, &u);
            st.residual.axpy(1.0, m);
            let k = dgc_target_k(st.residual.numel(), density);
            let keep = top_k_indices(&st.residual, k);
            let sm = SparseMat::from_dense(&st.residual, &keep);
            clear_at(&mut st.residual, &keep);
            clear_at(st.momentum.as_mut().expect("dgc state carries momentum"), &keep);
            sm
        }
        SparseRule::Vbc { lambda } => {
            // Batch significance test on the *current* batch: element ij
            // of the gradient is a sample mean over N local rows; transmit
            // where N·mean² >= λ·var (Tsuzuku et al. eq. 2). The variance
            // needs one extra GEMM: E[x²] via (A∘A)ᵀ(Δ∘Δ).
            let n = e.a.rows() as f32;
            let sum1 = matmul_tn(&e.a, &e.d);
            let sum2 = matmul_tn(&e.a.hadamard(&e.a), &e.d.hadamard(&e.d));
            let mut cand = u; // candidate = update + residual
            cand.axpy(1.0, &st.residual);
            let mut keep = Vec::new();
            for (i, (&s1, &s2)) in sum1.data().iter().zip(sum2.data()).enumerate() {
                let mu = s1 / n;
                let var = (s2 / n - mu * mu).max(0.0);
                if n * mu * mu >= lambda * var {
                    keep.push(i as u32);
                }
            }
            let sm = SparseMat::from_dense(&cand, &keep);
            st.residual = cand;
            clear_at(&mut st.residual, &keep);
            sm
        }
        SparseRule::AdaComp { bin } => {
            // Self-adjusting bin-local threshold (AdaComp §3): G = r + u,
            // H = G + u; transmit where |H| reaches the bin's max |G| —
            // elements whose fresh gradient alone closes the gap.
            let mut g = u.clone(); // G = u + r
            g.axpy(1.0, &st.residual);
            let mut h = g.clone(); // H = G + u
            h.axpy(1.0, &u);
            let gd = g.data();
            let hd = h.data();
            let mut keep = Vec::new();
            for start in (0..gd.len()).step_by(bin.max(1)) {
                let end = (start + bin.max(1)).min(gd.len());
                let t = gd[start..end].iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                for i in start..end {
                    if hd[i].abs() >= t {
                        keep.push(i as u32);
                    }
                }
            }
            let sm = SparseMat::from_dense(&g, &keep);
            st.residual = g;
            clear_at(&mut st.residual, &keep);
            sm
        }
    }
}

/// DGC element budget: ceil(numel · density%) clamped to [1, numel].
fn dgc_target_k(numel: usize, density_pct: f32) -> usize {
    (((numel as f64) * (density_pct as f64) / 100.0).ceil() as usize).clamp(1, numel)
}

/// Indices of the k largest |elements| of `m`, ascending. Deterministic
/// tie-break: larger |value| first, then lower index.
fn top_k_indices(m: &Matrix, k: usize) -> Vec<u32> {
    let data = m.data();
    if k >= data.len() {
        return (0..data.len() as u32).collect();
    }
    let mut idx: Vec<u32> = (0..data.len() as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        let (xa, xb) = (data[a as usize].abs(), data[b as usize].abs());
        xb.total_cmp(&xa).then(a.cmp(&b))
    });
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

fn clear_at(m: &mut Matrix, idx: &[u32]) {
    let d = m.data_mut();
    for &i in idx {
        d[i as usize] = 0.0;
    }
}

/// The simulated sparse-compression algorithm: one [`SparseRule`] plus a
/// god's-eye `states[site][entry]` residual table (the loopback twin of
/// the wire protocol's site-local state, like [`crate::algos::PowerSgd`]).
pub struct SparseAlgo {
    /// The transmit rule (which of dgc / vbc / adacomp this is).
    pub rule: SparseRule,
    states: Vec<Vec<EntryState>>,
    /// Checkpointed state waiting for the lazy shape-discovering init
    /// (`load_state` may run before the entry shapes are known).
    pending: Vec<Matrix>,
}

impl SparseAlgo {
    /// Fresh compressor for `rule` (residuals are lazily shaped on the
    /// first step, when the entry shapes are known).
    pub fn new(rule: SparseRule) -> Self {
        SparseAlgo { rule, states: vec![], pending: vec![] }
    }

    /// DGC at `density` percent.
    pub fn dgc(density: f32) -> Self {
        SparseAlgo::new(SparseRule::Dgc { density })
    }

    /// Variance-based compression at threshold `lambda`.
    pub fn vbc(lambda: f32) -> Self {
        SparseAlgo::new(SparseRule::Vbc { lambda })
    }

    /// AdaComp with `bin`-element bins.
    pub fn adacomp(bin: usize) -> Self {
        SparseAlgo::new(SparseRule::AdaComp { bin })
    }
}

impl<M: DistModel> DistAlgorithm<M> for SparseAlgo {
    fn name(&self) -> &'static str {
        self.rule.algo_name()
    }

    fn protocol(&self) -> Box<dyn StepProtocol<M>> {
        Box::new(SparseProtocol::new(self.rule.clone()))
    }

    fn state_mats(&self) -> Vec<Matrix> {
        // Stable flattening: per site, per entry, residual then (DGC only)
        // momentum. `load_state` consumes the same order.
        let mut out = Vec::new();
        for site in &self.states {
            for st in site {
                out.push(st.residual.clone());
                if let Some(m) = &st.momentum {
                    out.push(m.clone());
                }
            }
        }
        out
    }

    fn load_state(&mut self, mats: &[Matrix]) -> Result<(), String> {
        // Residual shapes are only known after the first step's lazy init;
        // stash the checkpointed state and splice it in at init time.
        self.pending = mats.to_vec();
        Ok(())
    }

    fn step(&mut self, cluster: &mut Cluster<M>, batches: &[Batch]) -> StepOutcome {
        cluster.next_step();
        let (up0, down0) = bytes_now(cluster);
        let stats = gather_local_stats(cluster, batches);
        let shapes = cluster.sites[0].model.param_shapes();
        let scale = 1.0 / stats.total_rows as f32;
        let n_entries = stats.per_site[0].entries.len();
        let n_sites = stats.per_site.len();

        // Lazy init: one residual state per (site, entry).
        if self.states.is_empty() {
            self.states = (0..n_sites)
                .map(|_| {
                    stats.per_site[0]
                        .entries
                        .iter()
                        .map(|e| {
                            let (r, c) = shapes[e.w_idx];
                            EntryState::new(r, c, self.rule.needs_momentum())
                        })
                        .collect()
                })
                .collect();
            if !self.pending.is_empty() {
                let per_entry = 1 + self.rule.needs_momentum() as usize;
                assert_eq!(
                    self.pending.len(),
                    n_sites * n_entries * per_entry,
                    "checkpointed {} state arity mismatch",
                    self.rule.algo_name()
                );
                let mut it = std::mem::take(&mut self.pending).into_iter();
                for site in self.states.iter_mut() {
                    for st in site.iter_mut() {
                        let r = it.next().expect("arity checked");
                        assert_eq!(r.shape(), st.residual.shape(), "residual shape mismatch");
                        st.residual = r;
                        if let Some(m) = st.momentum.as_mut() {
                            let mm = it.next().expect("arity checked");
                            assert_eq!(mm.shape(), m.shape(), "momentum shape mismatch");
                            *m = mm;
                        }
                    }
                }
            }
        }

        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for ei in 0..n_entries {
            let e0 = &stats.per_site[0].entries[ei];
            // Sites compress + ship; the aggregator folds the per-site
            // frames with the canonical segment reduction (index union,
            // collisions summed in dyadic leaf order — the same pairing a
            // tree of relays produces).
            let mut parts: Vec<SparseMat> = Vec::with_capacity(n_sites);
            for (si, s) in stats.per_site.iter().enumerate() {
                let sm = compress(&self.rule, &mut self.states[si][ei], &s.entries[ei], scale);
                cluster.send_to_agg_sparse("sparse-grad", &[&sm]);
                parts.push(sm);
            }
            // Broadcast the sparse union of the per-site transmit sets;
            // every endpoint densifies to the same synchronized update.
            let leaves: Vec<u32> = (0..parts.len() as u32).collect();
            let hat = crate::algos::reduce::reduce_sparse(&leaves, parts)
                .expect("uniform sparse shapes across sites")
                .expect("at least one site");
            cluster.broadcast_sparse("sparse-grad", &[&hat]);
            grads[e0.w_idx] = hat.to_dense();
            if let Some(bi) = e0.b_idx {
                grads[bi] = exchange_bias(cluster, &stats.per_site, ei, scale);
            }
        }
        let direct = exchange_direct(cluster, &stats);
        for (idx, g) in direct {
            grads[idx] = g;
        }
        let (up1, down1) = bytes_now(cluster);
        StepOutcome {
            loss: weighted_loss(&stats),
            grads,
            eff_ranks: vec![],
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
        }
    }
}

/// Wire protocol shared by the sparse family: per entry, each site ships
/// one `sparse-grad` frame up; the aggregator folds the per-leaf
/// contributions with the canonical segment reduction and broadcasts the
/// sparse union; everyone densifies. The error-feedback residual (and
/// DGC's momentum) lives in
/// this value — **site-local**, one compressor per process, surviving
/// site retirements because the aggregator half holds no per-site state
/// and the gradient scale comes from the sync frame.
pub struct SparseProtocol {
    rule: SparseRule,
    states: Vec<EntryState>,
}

impl SparseProtocol {
    /// Fresh protocol state for `rule` (residuals lazily shaped on the
    /// first step).
    pub fn new(rule: SparseRule) -> Self {
        SparseProtocol { rule, states: vec![] }
    }
}

impl<M: DistModel> StepProtocol<M> for SparseProtocol {
    fn name(&self) -> &'static str {
        self.rule.algo_name()
    }

    fn supports_degrade(&self) -> bool {
        // The site half is shaped only by the sync frame (the 1/N scale);
        // residual state is per-site and needs no cross-site bookkeeping,
        // so survivors keep compressing after a retirement.
        true
    }

    fn plan(&self, metas: &[StepMeta]) -> io::Result<StepPlan> {
        let meta = metas.first().ok_or_else(|| proto_err("plan needs site metas".into()))?;
        let mut rounds = Vec::new();
        for &(_, b_idx) in &meta.entries {
            rounds.push(Round::UpSparse { tag: "sparse-grad" });
            rounds.push(Round::Down { tag: "sparse-grad" });
            if b_idx != u32::MAX {
                rounds.push(Round::UpSum { tag: "bias-grad" });
                rounds.push(Round::Down { tag: "bias-grad" });
            }
        }
        if !meta.direct_idx.is_empty() {
            rounds.push(Round::UpSum { tag: "direct-grad" });
            rounds.push(Round::Down { tag: "direct-grad" });
        }
        Ok(StepPlan { rounds })
    }

    fn site_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        stats: &LocalStats,
        _site_id: usize,
        sync: &StepSync,
    ) -> io::Result<Vec<Matrix>> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        if self.states.is_empty() {
            self.states = stats
                .entries
                .iter()
                .map(|e| {
                    let (r, c) = shapes[e.w_idx];
                    EntryState::new(r, c, self.rule.needs_momentum())
                })
                .collect();
        }
        if self.states.len() != stats.entries.len() {
            return Err(proto_err("sparse state/entry arity mismatch".into()));
        }
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for (ei, e) in stats.entries.iter().enumerate() {
            let sm = compress(&self.rule, &mut self.states[ei], e, scale);
            ep.up_sparse("sparse-grad", &[&sm])?;
            let hat = one_sparse(ep.down_sparse("sparse-grad")?)?;
            if (hat.rows, hat.cols) != shapes[e.w_idx] {
                return Err(proto_err(format!("sparse-grad shape mismatch for entry {ei}")));
            }
            grads[e.w_idx] = hat.to_dense();
            if let Some(bi) = e.b_idx {
                let bg = e.bias_grad(scale);
                ep.up("bias-grad", &[&bg])?;
                grads[bi] = ep.down1("bias-grad")?;
            }
        }
        for (idx, g) in site_direct_exchange(ep, stats)? {
            grads[idx] = g;
        }
        Ok(grads)
    }

    fn agg_exchange(
        &mut self,
        ep: &mut Endpoint<'_>,
        model: &M,
        metas: &[StepMeta],
        sync: &StepSync,
    ) -> io::Result<AggExchange> {
        let shapes = model.param_shapes();
        let scale = sync.scale();
        let n_entries = metas[0].entries.len();
        for (site, meta) in metas.iter().enumerate() {
            if meta.entries.len() != n_entries {
                return Err(proto_err(format!("site {site} stats layout mismatch")));
            }
        }
        let mut grads: Vec<Matrix> = shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect();
        for &(w_idx, b_idx) in &metas[0].entries {
            let (r, c) = shapes[w_idx as usize];
            let hat = gather_sparse_union(ep, "sparse-grad")?;
            if (hat.rows, hat.cols) != (r, c) {
                return Err(proto_err(format!(
                    "sparse-grad shape mismatch for param {w_idx}: got {}x{}, want {r}x{c}",
                    hat.rows, hat.cols
                )));
            }
            ep.bcast_sparse("sparse-grad", &[&hat])?;
            grads[w_idx as usize] = hat.to_dense();
            if b_idx != u32::MAX {
                let bsum = gather_sum(ep, "bias-grad")?;
                ep.bcast("bias-grad", &[&bsum])?;
                grads[b_idx as usize] = bsum;
            }
        }
        for (idx, g) in agg_direct_exchange(ep, metas, scale)? {
            grads[idx] = g;
        }
        Ok(AggExchange { grads, eff_ranks: vec![] })
    }
}

fn one_sparse(mut mats: Vec<SparseMat>) -> io::Result<SparseMat> {
    if mats.len() != 1 {
        return Err(proto_err(format!("expected exactly 1 sparse matrix, got {}", mats.len())));
    }
    Ok(mats.pop().expect("checked non-empty"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::exact::{Dsgd, Pooled};
    use crate::nn::loss::one_hot;
    use crate::nn::{Activation, Mlp};
    use crate::tensor::Rng;

    fn setup(seed: u64) -> (Cluster<Mlp>, Vec<Batch>) {
        let mut rng = Rng::new(seed);
        let mlp = Mlp::new(&[12, 16, 10, 4], &[Activation::Relu, Activation::Tanh], &mut rng);
        let cluster = Cluster::replicate(mlp, 2);
        let batches: Vec<Batch> = (0..2)
            .map(|s| {
                let x = Matrix::randn(6, 12, 1.0, &mut rng);
                let labels: Vec<usize> = (0..6).map(|i| (s * 2 + i % 2) as usize).collect();
                Batch::Dense { x, y: one_hot(&labels, 4) }
            })
            .collect();
        (cluster, batches)
    }

    /// THE error-feedback anchor (satellite 2): at full density every
    /// sparse protocol transmits its entire candidate each step, the
    /// residual clears, and the synchronized update equals dense dSGD's
    /// **bit for bit** — same values, same f32 reduction order. Run
    /// several steps so stale residual/momentum state would be caught.
    #[test]
    fn full_density_configs_match_dsgd_bitwise() {
        let rules = [
            SparseRule::Dgc { density: 100.0 },
            SparseRule::Vbc { lambda: 0.0 },
            SparseRule::AdaComp { bin: 1 },
        ];
        for rule in rules {
            let (mut c_ref, b_ref) = setup(11);
            let (mut c_sp, b_sp) = setup(11);
            let mut sparse = SparseAlgo::new(rule.clone());
            for step in 0..3 {
                let dense = Dsgd.step(&mut c_ref, &b_ref);
                let got = sparse.step(&mut c_sp, &b_sp);
                assert_eq!(dense.loss, got.loss, "{rule:?} loss at step {step}");
                for (i, (dg, sg)) in dense.grads.iter().zip(&got.grads).enumerate() {
                    assert_eq!(dg, sg, "{rule:?} param {i} differs from dsgd at step {step}");
                }
                // Honest accounting: at full density the sparse frames cost
                // *more* than dSGD (8 bytes per element vs 4 — the index
                // overhead the Ledger must not hide).
                assert!(
                    got.bytes_up > dense.bytes_up,
                    "{rule:?}: sparse full-density bytes {} must exceed dense {}",
                    got.bytes_up,
                    dense.bytes_up
                );
            }
        }
    }

    /// Residual accumulation (satellite 2): for the pure error-feedback
    /// rules the per-step applied update telescopes — after T steps on a
    /// fixed batch, Σ transmitted = T · (dense mean grad) − residual_T.
    /// The mean applied update therefore converges to the dense gradient,
    /// and the conservation identity holds to f32 reduction noise.
    #[test]
    fn error_feedback_residuals_telescope_to_dense_sum() {
        let rules =
            [SparseRule::Vbc { lambda: 50.0 }, SparseRule::AdaComp { bin: 64 }];
        for rule in rules {
            let (mut cluster, batches) = setup(9);
            let pooled = Pooled.step(&mut cluster, &batches);
            let (mut c2, b2) = setup(9);
            let mut algo = SparseAlgo::new(rule.clone());
            let steps = 12;
            let mut applied: Option<Vec<Matrix>> = None;
            for _ in 0..steps {
                let out = algo.step(&mut c2, &b2);
                applied = Some(match applied {
                    None => out.grads,
                    Some(mut a) => {
                        for (x, y) in a.iter_mut().zip(&out.grads) {
                            x.axpy(1.0, y);
                        }
                        a
                    }
                });
            }
            let applied = applied.unwrap();
            // (a) Exact conservation per weight entry: applied sum equals
            // T·(dense grad) minus the leftover residuals, to f32 noise.
            let stats = gather_local_stats(&c2, &b2);
            let scale = 1.0 / stats.total_rows as f32;
            let mut any_residual = 0.0f32;
            for (ei, e0) in stats.per_site[0].entries.iter().enumerate() {
                let mut expect = Matrix::zeros(e0.a.cols(), e0.d.cols());
                for (si, s) in stats.per_site.iter().enumerate() {
                    expect.axpy(steps as f32, &s.entries[ei].weight_grad(scale));
                    expect.axpy(-1.0, &algo.states[si][ei].residual);
                    any_residual += algo.states[si][ei].residual.fro_norm();
                }
                let err = applied[e0.w_idx].max_abs_diff(&expect);
                let denom = expect.max_abs().max(1e-6);
                assert!(err / denom < 1e-3, "{rule:?} entry {ei}: conservation err {err}");
            }
            // The run must have been genuinely sparse, or (a) is vacuous.
            assert!(any_residual > 0.0, "{rule:?} transmitted everything — not sparse");
            // (b) Convergence: the mean applied update approaches the
            // dense gradient as the residual stops growing.
            for (i, pg) in pooled.grads.iter().enumerate() {
                if pg.rows() == 1 {
                    continue; // biases are exact by construction
                }
                let mean = applied[i].scale(1.0 / steps as f32);
                let rel = mean.sub(pg).fro_norm() / pg.fro_norm().max(1e-6);
                assert!(rel < 0.2, "{rule:?} param {i}: rel {rel}");
            }
        }
    }

    /// DGC's momentum-corrected residual: on a fixed batch at 25% density
    /// every element is eventually transmitted (untransmitted velocity
    /// grows until it wins the top-k), so the union of transmit sets over
    /// a modest horizon covers every weight element.
    #[test]
    fn dgc_momentum_residual_eventually_ships_every_element() {
        let (mut cluster, batches) = setup(13);
        let mut algo = SparseAlgo::dgc(25.0);
        let shapes = cluster.sites[0].model.param_shapes();
        let mut covered: Vec<Vec<bool>> =
            shapes.iter().map(|&(r, c)| vec![false; r * c]).collect();
        let mut per_step_nnz = Vec::new();
        for _ in 0..24 {
            let out = algo.step(&mut cluster, &batches);
            let mut nnz = 0usize;
            for (pi, g) in out.grads.iter().enumerate() {
                if g.rows() == 1 {
                    continue; // biases ride dense frames
                }
                for (i, &v) in g.data().iter().enumerate() {
                    if v != 0.0 {
                        covered[pi][i] = true;
                        nnz += 1;
                    }
                }
            }
            per_step_nnz.push(nnz);
        }
        // Sparse every step: the union is at most ~2x25% of the weights
        // (2 sites with different transmit sets).
        let total_weight_elems: usize = shapes
            .iter()
            .filter(|&&(r, _)| r > 1)
            .map(|&(r, c)| r * c)
            .sum();
        for (t, &nnz) in per_step_nnz.iter().enumerate() {
            assert!(
                nnz <= (total_weight_elems * 6) / 10,
                "step {t}: {nnz}/{total_weight_elems} transmitted — not sparse"
            );
        }
        for (pi, cov) in covered.iter().enumerate() {
            if shapes[pi].0 == 1 {
                continue;
            }
            let missing = cov.iter().filter(|&&c| !c).count();
            assert_eq!(
                missing, 0,
                "param {pi}: {missing} elements never transmitted in 24 steps"
            );
        }
    }

    /// Degradation contract (tentpole): every sparse protocol declares
    /// degrade support — residual state is per-site, the scale comes from
    /// the sync frame.
    #[test]
    fn sparse_protocols_support_degrade() {
        for rule in [
            SparseRule::Dgc { density: 25.0 },
            SparseRule::Vbc { lambda: 2.0 },
            SparseRule::AdaComp { bin: 512 },
        ] {
            let algo = SparseAlgo::new(rule.clone());
            let proto = <SparseAlgo as DistAlgorithm<Mlp>>::protocol(&algo);
            assert!(proto.supports_degrade(), "{rule:?} must support degrade");
            assert!(!proto.oracle());
            assert_eq!(proto.name(), rule.algo_name());
        }
    }

    #[test]
    fn top_k_selects_by_magnitude_with_deterministic_ties() {
        let m = Matrix::from_vec(1, 6, vec![0.5, -2.0, 1.0, -1.0, 2.0, 0.1]);
        assert_eq!(top_k_indices(&m, 2), vec![1, 4]); // |−2| ties |2| → lower idx first
        assert_eq!(top_k_indices(&m, 4), vec![1, 2, 3, 4]);
        assert_eq!(top_k_indices(&m, 99), vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(dgc_target_k(192, 100.0), 192);
        assert_eq!(dgc_target_k(192, 25.0), 48);
        assert_eq!(dgc_target_k(192, 0.01), 1);
    }
}

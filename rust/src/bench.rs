//! Benchmark harness (criterion is unavailable offline): warmup + sampled
//! timing with median/p10/p90, and a tiny table printer. `cargo bench`
//! targets use `harness = false` and drive this directly.

use std::time::Instant;

/// Timing result in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub median_ns: u128,
    pub p10_ns: u128,
    pub p90_ns: u128,
    pub samples: usize,
}

impl Timing {
    pub fn human(&self) -> String {
        fn fmt(ns: u128) -> String {
            if ns >= 1_000_000_000 {
                format!("{:.2} s", ns as f64 / 1e9)
            } else if ns >= 1_000_000 {
                format!("{:.2} ms", ns as f64 / 1e6)
            } else if ns >= 1_000 {
                format!("{:.2} µs", ns as f64 / 1e3)
            } else {
                format!("{ns} ns")
            }
        }
        format!("{} [{} .. {}]", fmt(self.median_ns), fmt(self.p10_ns), fmt(self.p90_ns))
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `samples` timed runs.
pub fn bench<R>(warmup: usize, samples: usize, mut f: impl FnMut() -> R) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<u128> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos());
    }
    times.sort_unstable();
    let n = times.len();
    Timing {
        median_ns: times[n / 2],
        p10_ns: times[n / 10],
        p90_ns: times[(n * 9) / 10],
        samples: n,
    }
}

/// Named benchmark line, criterion-style output.
pub fn report(name: &str, t: Timing) {
    println!("{name:<48} {}", t.human());
}

/// Throughput helper: GFLOP/s given flops per run.
pub fn gflops(t: &Timing, flops: usize) -> f64 {
    flops as f64 / t.median_ns as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders() {
        let t = bench(1, 20, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(t.p10_ns <= t.median_ns && t.median_ns <= t.p90_ns);
        assert_eq!(t.samples, 20);
    }

    #[test]
    fn human_units() {
        let t = Timing { median_ns: 2_500_000, p10_ns: 900, p90_ns: 3_000_000_000, samples: 1 };
        let s = t.human();
        assert!(s.contains("ms") && s.contains("ns") && s.contains("s"), "{s}");
    }
}

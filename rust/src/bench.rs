//! Benchmark harness (criterion is unavailable offline): warmup + sampled
//! timing with median/p10/p90, a tiny table printer, and a machine-readable
//! JSON sink (`JsonSink`) so CI can track the perf trajectory across PRs —
//! benches/hotpath.rs emits BENCH_hotpath.json through it. `cargo bench`
//! targets use `harness = false` and drive this directly.

use std::io::Write;
use std::time::Instant;

/// Timing result in nanoseconds.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    /// Median over all samples.
    pub median_ns: u128,
    /// 10th-percentile sample.
    pub p10_ns: u128,
    /// 90th-percentile sample.
    pub p90_ns: u128,
    /// Number of timed runs.
    pub samples: usize,
}

impl Timing {
    /// `median [p10 .. p90]` with auto-scaled units.
    pub fn human(&self) -> String {
        fn fmt(ns: u128) -> String {
            if ns >= 1_000_000_000 {
                format!("{:.2} s", ns as f64 / 1e9)
            } else if ns >= 1_000_000 {
                format!("{:.2} ms", ns as f64 / 1e6)
            } else if ns >= 1_000 {
                format!("{:.2} µs", ns as f64 / 1e3)
            } else {
                format!("{ns} ns")
            }
        }
        format!("{} [{} .. {}]", fmt(self.median_ns), fmt(self.p10_ns), fmt(self.p90_ns))
    }
}

/// Benchmark a closure: `warmup` untimed runs, then `samples` timed runs.
pub fn bench<R>(warmup: usize, samples: usize, mut f: impl FnMut() -> R) -> Timing {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<u128> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_nanos());
    }
    times.sort_unstable();
    let n = times.len();
    Timing {
        median_ns: times[n / 2],
        p10_ns: times[n / 10],
        p90_ns: times[(n * 9) / 10],
        samples: n,
    }
}

/// Named benchmark line, criterion-style output.
pub fn report(name: &str, t: Timing) {
    println!("{name:<48} {}", t.human());
}

/// Throughput helper: GFLOP/s given flops per run.
pub fn gflops(t: &Timing, flops: usize) -> f64 {
    flops as f64 / t.median_ns as f64
}

/// One benchmark row destined for the JSON artifact.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Benchmark id as printed and serialized.
    pub name: String,
    /// Measured timing distribution.
    pub timing: Timing,
    /// GFLOP/s, when the benchmark has a FLOP count.
    pub gflops: Option<f64>,
    /// Median speedup vs a named baseline timing, when one was measured.
    pub speedup: Option<f64>,
}

/// Collects benchmark records and writes them as a single JSON document —
/// the `BENCH_hotpath.json` contract consumed by CI (uploaded as an
/// artifact) and by EXPERIMENTS.md §Perf. Hand-rolled serialization: no
/// serde offline, and the schema is flat.
#[derive(Debug, Default)]
pub struct JsonSink {
    meta: Vec<(String, String)>,
    records: Vec<BenchRecord>,
}

impl JsonSink {
    /// Empty sink.
    pub fn new() -> Self {
        JsonSink::default()
    }

    /// Attach a free-form metadata key (threads, git rev, scale, ...).
    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }

    /// Record a plain timing.
    pub fn add(&mut self, name: &str, t: Timing) {
        self.records.push(BenchRecord { name: name.to_string(), timing: t, gflops: None, speedup: None });
    }

    /// Record a timing with throughput.
    pub fn add_gflops(&mut self, name: &str, t: Timing, flops: usize) {
        self.records.push(BenchRecord {
            name: name.to_string(),
            timing: t,
            gflops: Some(gflops(&t, flops)),
            speedup: None,
        });
    }

    /// Record a timing together with its speedup over a baseline timing
    /// (baseline_median / median) and optional throughput.
    pub fn add_vs_baseline(&mut self, name: &str, t: Timing, baseline: Timing, flops: Option<usize>) {
        self.records.push(BenchRecord {
            name: name.to_string(),
            timing: t,
            gflops: flops.map(|f| gflops(&t, f)),
            speedup: Some(baseline.median_ns as f64 / t.median_ns.max(1) as f64),
        });
    }

    /// Minimal JSON string escaping (names are ASCII identifiers, but stay
    /// correct anyway).
    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len());
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out
    }

    /// Serialize to a JSON document.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"meta\": {");
        for (i, (k, v)) in self.meta.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": \"{}\"", Self::escape(k), Self::escape(v)));
        }
        s.push_str("\n  },\n  \"benchmarks\": [");
        for (i, r) in self.records.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"median_ns\": {}, \"p10_ns\": {}, \"p90_ns\": {}, \"samples\": {}",
                Self::escape(&r.name),
                r.timing.median_ns,
                r.timing.p10_ns,
                r.timing.p90_ns,
                r.timing.samples
            ));
            if let Some(g) = r.gflops {
                s.push_str(&format!(", \"gflops\": {g:.4}"));
            }
            if let Some(x) = r.speedup {
                s.push_str(&format!(", \"speedup_vs_baseline\": {x:.4}"));
            }
            s.push('}');
        }
        s.push_str("\n  ]\n}\n");
        s
    }

    /// Write the document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_json().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_orders() {
        let t = bench(1, 20, || {
            let mut s = 0u64;
            for i in 0..10_000u64 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(t.p10_ns <= t.median_ns && t.median_ns <= t.p90_ns);
        assert_eq!(t.samples, 20);
    }

    #[test]
    fn human_units() {
        let t = Timing { median_ns: 2_500_000, p10_ns: 900, p90_ns: 3_000_000_000, samples: 1 };
        let s = t.human();
        assert!(s.contains("ms") && s.contains("ns") && s.contains("s"), "{s}");
    }

    #[test]
    fn json_sink_schema() {
        let mut sink = JsonSink::new();
        sink.meta("threads", "8");
        let t = Timing { median_ns: 100, p10_ns: 90, p90_ns: 200, samples: 5 };
        let base = Timing { median_ns: 250, p10_ns: 240, p90_ns: 260, samples: 5 };
        sink.add("plain", t);
        sink.add_gflops("with \"quotes\"", t, 1000);
        sink.add_vs_baseline("sped-up", t, base, Some(1000));
        let json = sink.to_json();
        assert!(json.contains("\"threads\": \"8\""), "{json}");
        assert!(json.contains("\"median_ns\": 100"), "{json}");
        assert!(json.contains("\\\"quotes\\\""), "{json}");
        assert!(json.contains("\"speedup_vs_baseline\": 2.5000"), "{json}");
        assert!(json.contains("\"gflops\": 10.0000"), "{json}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }
}

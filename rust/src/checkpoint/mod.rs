//! Versioned on-disk checkpoint container: save/resume mid-training with
//! optimizer state, RNG cursors and epoch-plan position.
//!
//! The container reuses the wire codec's framing ([`crate::dist::wire`])
//! verbatim, behind a small magic header:
//!
//! ```text
//! 8 bytes  magic "DADCKPT\0"
//! u8       checkpoint container version (CKPT_VERSION)
//! u8       embedded wire codec version (WIRE_VERSION)
//! frame    control "ckpt-meta"   run identity + resume cursors
//! frame    payload "ckpt-params" model parameters, trainer order
//! frame    payload "ckpt-adam-m" Adam first moments, parallel to params
//! frame    payload "ckpt-adam-v" Adam second moments, parallel to params
//! frame    payload "ckpt-algo"   algorithm compressor state (may be empty)
//! frame    control "ckpt-end"    u64 FNV-1a over every preceding byte
//! ```
//!
//! A resumed run restores the parameters, both Adam moment tables and the
//! step counter, the epoch-plan RNG cursor and the next epoch index, so it
//! continues bit-for-bit where the interrupted run left off — asserted by
//! `tests/checkpoint_roundtrip.rs` (loopback) and `tests/remote_resume.rs`
//! (TCP). The byte layout is specified normatively in `rust/docs/FORMATS.md`
//! and cross-checked against these constants by `tests/format_spec.rs`.
//!
//! Decoding is strict: bad magic, unknown container or wire versions,
//! truncation, out-of-order frames, non-parallel moment tables and checksum
//! mismatches each fail with a clean named `InvalidData` error — never a
//! panic — so a half-written or corrupted file cannot silently poison a
//! resumed run. [`Checkpoint::save`] writes through a temp file and renames,
//! so a crash mid-save leaves any previous checkpoint intact.

use std::fs;
use std::io::{self, Cursor};
use std::path::Path;

use crate::dist::wire::{
    decode, encode_control, encode_payload, proto_err, Body, ByteReader, ByteWriter, Frame,
    WIRE_VERSION,
};
use crate::tensor::{Matrix, Rng};

/// Leading magic bytes of every checkpoint file.
pub const CKPT_MAGIC: [u8; 8] = *b"DADCKPT\0";

/// Container version byte; bump when the frame sequence or the `ckpt-meta`
/// field layout changes. Independent of [`WIRE_VERSION`], which versions
/// the embedded frame encoding itself.
pub const CKPT_VERSION: u8 = 1;

/// Run identity and resume cursors frozen into a checkpoint's `ckpt-meta`
/// frame. The identity fields let [`CkptMeta::check_resume`] refuse to
/// resume under a different run configuration; the cursor fields
/// (`next_epoch`, `adam_t`, `rng_*`) are what make the continuation
/// bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptMeta {
    /// Canonical algorithm spelling (`AlgoSpec::name()`).
    pub algo: String,
    /// Dataset key the run was built from (`mnist`, `arabic`, `lm`).
    pub dataset: String,
    /// Scale key (`quick`, `default`, `paper`) used by `build_task`.
    pub scale: String,
    /// Simulated/remote site count.
    pub n_sites: u32,
    /// Per-site batch size.
    pub batch_per_site: u32,
    /// Total epochs of the original run plan.
    pub epochs: u32,
    /// Adam learning rate.
    pub lr: f32,
    /// Run seed (drives data shards, model init and the epoch-plan RNG).
    pub seed: u64,
    /// Sync schedule in the canonical `--sync-every` encoding
    /// (`Schedule::sync_every()`: 1 = every batch, k > 1 = periodic every
    /// k steps; 0 is accepted as a synonym for 1 on decode).
    pub sync_every: u32,
    /// First epoch the resumed run should execute (epochs before it are
    /// already folded into the parameters).
    pub next_epoch: u32,
    /// Adam updates applied so far.
    pub adam_t: u64,
    /// Epoch-plan RNG cursor: PCG state word.
    pub rng_state: u64,
    /// Epoch-plan RNG cursor: PCG increment word.
    pub rng_inc: u64,
    /// Epoch-plan RNG cursor: cached Box-Muller spare, if any.
    pub rng_spare: Option<f32>,
}

impl CkptMeta {
    /// Restore the epoch-plan RNG exactly where the checkpointed run left
    /// it.
    pub fn restore_rng(&self) -> Rng {
        Rng::from_parts(self.rng_state, self.rng_inc, self.rng_spare)
    }

    /// Refuse to resume under a different run identity: every field that
    /// feeds the deterministic replay (algorithm, sharding, batch size,
    /// lr, seed, schedule) must match the checkpoint, and the checkpoint
    /// must not already be complete for the requested epoch count.
    pub fn check_resume(
        &self,
        algo: &str,
        n_sites: u32,
        batch_per_site: u32,
        epochs: u32,
        lr: f32,
        seed: u64,
        sync_every: u32,
    ) -> io::Result<()> {
        let mut mismatch = |field: &str, want: String, have: String| {
            Err(proto_err(format!(
                "checkpoint resume mismatch: {field} is {want} in the checkpoint but {have} in this run"
            )))
        };
        if self.algo != algo {
            return mismatch("algo", self.algo.clone(), algo.to_string());
        }
        if self.n_sites != n_sites {
            return mismatch("n_sites", self.n_sites.to_string(), n_sites.to_string());
        }
        if self.batch_per_site != batch_per_site {
            return mismatch(
                "batch_per_site",
                self.batch_per_site.to_string(),
                batch_per_site.to_string(),
            );
        }
        if self.lr != lr {
            return mismatch("lr", self.lr.to_string(), lr.to_string());
        }
        if self.seed != seed {
            return mismatch("seed", self.seed.to_string(), seed.to_string());
        }
        if self.sync_every != sync_every {
            return mismatch("sync_every", self.sync_every.to_string(), sync_every.to_string());
        }
        if self.next_epoch >= epochs {
            return Err(proto_err(format!(
                "checkpoint is already at epoch {} of a {} epoch run: nothing to resume",
                self.next_epoch, epochs
            )));
        }
        Ok(())
    }

    fn encode_body(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_str(&self.algo);
        w.push_str(&self.dataset);
        w.push_str(&self.scale);
        w.push_u32(self.n_sites);
        w.push_u32(self.batch_per_site);
        w.push_u32(self.epochs);
        w.push_f32(self.lr);
        w.push_u64(self.seed);
        w.push_u32(self.sync_every);
        w.push_u32(self.next_epoch);
        w.push_u64(self.adam_t);
        w.push_u64(self.rng_state);
        w.push_u64(self.rng_inc);
        w.push_u8(self.rng_spare.is_some() as u8);
        w.push_f32(self.rng_spare.unwrap_or(0.0));
        w.finish()
    }

    fn decode_body(body: &[u8]) -> io::Result<CkptMeta> {
        let mut r = ByteReader::new(body);
        let meta = CkptMeta {
            algo: r.read_str()?,
            dataset: r.read_str()?,
            scale: r.read_str()?,
            n_sites: r.read_u32()?,
            batch_per_site: r.read_u32()?,
            epochs: r.read_u32()?,
            lr: r.read_f32()?,
            seed: r.read_u64()?,
            sync_every: r.read_u32()?,
            next_epoch: r.read_u32()?,
            adam_t: r.read_u64()?,
            rng_state: r.read_u64()?,
            rng_inc: r.read_u64()?,
            rng_spare: {
                let has = r.read_u8()? != 0;
                let v = r.read_f32()?;
                has.then_some(v)
            },
        };
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "ckpt-meta frame has {} trailing bytes (container version skew?)",
                r.remaining()
            )));
        }
        Ok(meta)
    }
}

/// Where and how often a training run checkpoints. The default plan
/// (no path) disables checkpointing entirely, which is how the plain
/// `train()` entry point runs.
#[derive(Clone, Debug, Default)]
pub struct CheckpointPlan {
    /// Save target (`--checkpoint PATH`); `None` disables checkpointing.
    pub save_path: Option<String>,
    /// Save every N epochs (`--checkpoint-every N`; 0 = only at the end).
    /// Whenever a path is set, the final epoch always saves.
    pub every: usize,
    /// Dataset key recorded in the checkpoint meta (so `dad infer` can
    /// rebuild the model without extra flags).
    pub dataset: String,
    /// Scale key recorded in the checkpoint meta.
    pub scale: String,
}

impl CheckpointPlan {
    /// Whether this plan saves anything at all.
    pub fn enabled(&self) -> bool {
        self.save_path.is_some()
    }

    /// Whether a save is due once `done_epochs` of `total_epochs` have
    /// completed.
    pub fn due(&self, done_epochs: usize, total_epochs: usize) -> bool {
        self.save_path.is_some()
            && (done_epochs == total_epochs || (self.every > 0 && done_epochs % self.every == 0))
    }
}

/// A full training snapshot: everything needed to continue a run
/// bit-identically, or to serve its weights for inference.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Run identity + resume cursors.
    pub meta: CkptMeta,
    /// Model parameters in trainer order (`DistModel::params`).
    pub params: Vec<Matrix>,
    /// Adam first moments, parallel to `params`.
    pub adam_m: Vec<Matrix>,
    /// Adam second moments, parallel to `params`.
    pub adam_v: Vec<Matrix>,
    /// Flattened algorithm compressor state (`DistAlgorithm::state_mats`);
    /// empty for stateless algorithms.
    pub algo_state: Vec<Matrix>,
}

/// FNV-1a 64 over `bytes` — the `ckpt-end` integrity checksum. Not
/// cryptographic: it catches truncation and bit rot, not tampering.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Serialize a matrix list into a control-frame field stream: u16 count,
/// then per matrix u32 rows, u32 cols, rows*cols f32 LE values. Used by the
/// ledger-exempt `resume` broadcast (`dad serve --resume`); the checkpoint
/// file itself uses full payload frames instead.
pub fn push_mats(w: &mut ByteWriter, mats: &[Matrix]) {
    assert!(mats.len() <= u16::MAX as usize, "too many matrices in one field stream");
    w.push_u16(mats.len() as u16);
    for m in mats {
        w.push_u32(m.rows() as u32);
        w.push_u32(m.cols() as u32);
        for &v in m.data() {
            w.push_f32(v);
        }
    }
}

/// Inverse of [`push_mats`]; every read is bounds-checked.
pub fn read_mats(r: &mut ByteReader) -> io::Result<Vec<Matrix>> {
    let n = r.read_u16()? as usize;
    let mut mats = Vec::with_capacity(n);
    for _ in 0..n {
        let rows = r.read_u32()? as usize;
        let cols = r.read_u32()? as usize;
        let numel = rows
            .checked_mul(cols)
            .filter(|&n| n.checked_mul(4).is_some())
            .ok_or_else(|| proto_err(format!("matrix {rows}x{cols} overflows")))?;
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(r.read_f32()?);
        }
        mats.push(Matrix::from_vec(rows, cols, data));
    }
    Ok(mats)
}

fn expect_control(f: Frame, want: &str) -> io::Result<Vec<u8>> {
    if f.tag != want {
        return Err(proto_err(format!("expected {want} frame, found {:?}", f.tag)));
    }
    match f.body {
        Body::Control(b) => Ok(b),
        _ => Err(proto_err(format!("{want} must be a control frame"))),
    }
}

fn expect_payload(f: Frame, want: &str) -> io::Result<Vec<Matrix>> {
    if f.tag != want {
        return Err(proto_err(format!("expected {want} frame, found {:?}", f.tag)));
    }
    match f.body {
        Body::Mats(ms) => Ok(ms),
        _ => Err(proto_err(format!("{want} must be a dense payload frame"))),
    }
}

impl Checkpoint {
    /// Encode the full container into bytes (the exact on-disk image).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&CKPT_MAGIC);
        buf.push(CKPT_VERSION);
        buf.push(WIRE_VERSION);
        encode_control(&mut buf, "ckpt-meta", &self.meta.encode_body()).expect("vec write");
        let refs = |ms: &[Matrix]| ms.iter().collect::<Vec<&Matrix>>();
        encode_payload(&mut buf, "ckpt-params", &refs(&self.params)).expect("vec write");
        encode_payload(&mut buf, "ckpt-adam-m", &refs(&self.adam_m)).expect("vec write");
        encode_payload(&mut buf, "ckpt-adam-v", &refs(&self.adam_v)).expect("vec write");
        encode_payload(&mut buf, "ckpt-algo", &refs(&self.algo_state)).expect("vec write");
        let mut end = ByteWriter::new();
        end.push_u64(fnv1a64(&buf));
        encode_control(&mut buf, "ckpt-end", &end.finish()).expect("vec write");
        buf
    }

    /// Decode a full container image, validating magic, versions, frame
    /// order, moment-table parallelism and the trailing checksum.
    pub fn decode_bytes(buf: &[u8]) -> io::Result<Checkpoint> {
        if buf.len() < CKPT_MAGIC.len() + 2 {
            return Err(proto_err(format!(
                "checkpoint truncated: {} bytes is smaller than the header",
                buf.len()
            )));
        }
        if buf[..CKPT_MAGIC.len()] != CKPT_MAGIC {
            return Err(proto_err("not a dad checkpoint (bad magic bytes)".into()));
        }
        let ckpt_version = buf[CKPT_MAGIC.len()];
        if ckpt_version != CKPT_VERSION {
            return Err(proto_err(format!(
                "checkpoint container version {ckpt_version}, this build reads {CKPT_VERSION}"
            )));
        }
        let wire_version = buf[CKPT_MAGIC.len() + 1];
        if wire_version != WIRE_VERSION {
            return Err(proto_err(format!(
                "checkpoint embeds wire version {wire_version}, this build speaks {WIRE_VERSION}"
            )));
        }
        let body = &buf[CKPT_MAGIC.len() + 2..];
        let mut cur = Cursor::new(body);
        let meta = CkptMeta::decode_body(&expect_control(decode(&mut cur)?, "ckpt-meta")?)?;
        let params = expect_payload(decode(&mut cur)?, "ckpt-params")?;
        let adam_m = expect_payload(decode(&mut cur)?, "ckpt-adam-m")?;
        let adam_v = expect_payload(decode(&mut cur)?, "ckpt-adam-v")?;
        let algo_state = expect_payload(decode(&mut cur)?, "ckpt-algo")?;
        let hashed = CKPT_MAGIC.len() + 2 + cur.position() as usize;
        let end = expect_control(decode(&mut cur)?, "ckpt-end")?;
        let mut r = ByteReader::new(&end);
        let want = r.read_u64()?;
        let got = fnv1a64(&buf[..hashed]);
        if want != got {
            return Err(proto_err(format!(
                "checkpoint checksum mismatch: file says {want:#018x}, content hashes to {got:#018x}"
            )));
        }
        if (cur.position() as usize) != body.len() {
            return Err(proto_err(format!(
                "{} trailing bytes after ckpt-end frame",
                body.len() - cur.position() as usize
            )));
        }
        for (name, mats) in [("adam-m", &adam_m), ("adam-v", &adam_v)] {
            if mats.len() != params.len()
                || mats.iter().zip(&params).any(|(a, p)| a.shape() != p.shape())
            {
                return Err(proto_err(format!(
                    "checkpoint {name} moment table is not parallel to the parameter list"
                )));
            }
        }
        Ok(Checkpoint { meta, params, adam_m, adam_v, algo_state })
    }

    /// Write the container to `path` atomically: a temp file in the same
    /// directory is written, flushed and renamed over the target, so a
    /// crash mid-save never leaves a half-written checkpoint at `path`.
    pub fn save(&self, path: &Path) -> io::Result<()> {
        let _s = crate::obs::trace::span("ckpt-save");
        let bytes = self.encode();
        let tmp = path.with_extension("ckpt.tmp");
        fs::write(&tmp, &bytes)
            .map_err(|e| io::Error::new(e.kind(), format!("writing {}: {e}", tmp.display())))?;
        fs::rename(&tmp, path)
            .map_err(|e| io::Error::new(e.kind(), format!("renaming into {}: {e}", path.display())))
    }

    /// Read and validate a checkpoint file; every failure mode (missing
    /// file, bad magic, version skew, truncation, corruption) is a named
    /// `io::Error` mentioning the path.
    pub fn load(path: &Path) -> io::Result<Checkpoint> {
        let _s = crate::obs::trace::span("ckpt-load");
        let bytes = fs::read(path)
            .map_err(|e| io::Error::new(e.kind(), format!("reading {}: {e}", path.display())))?;
        Self::decode_bytes(&bytes)
            .map_err(|e| io::Error::new(e.kind(), format!("checkpoint {}: {e}", path.display())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(3);
        let shapes = [(4, 3), (1, 3)];
        let mk = |rng: &mut Rng| {
            shapes.iter().map(|&(r, c)| Matrix::randn(r, c, 1.0, rng)).collect::<Vec<_>>()
        };
        Checkpoint {
            meta: CkptMeta {
                algo: "dad".into(),
                dataset: "mnist".into(),
                scale: "quick".into(),
                n_sites: 2,
                batch_per_site: 8,
                epochs: 5,
                lr: 1e-3,
                seed: 41,
                sync_every: 0,
                next_epoch: 2,
                adam_t: 40,
                rng_state: 0xDEAD_BEEF_0BAD_CAFE,
                rng_inc: 0x1234_5678_9ABC_DEF1,
                rng_spare: Some(-0.75),
            },
            params: mk(&mut rng),
            adam_m: mk(&mut rng),
            adam_v: mk(&mut rng),
            algo_state: vec![Matrix::randn(2, 2, 1.0, &mut rng)],
        }
    }

    #[test]
    fn container_roundtrips_bit_identically() {
        let ck = sample();
        let bytes = ck.encode();
        let back = Checkpoint::decode_bytes(&bytes).unwrap();
        assert_eq!(back.meta, ck.meta);
        assert_eq!(back.params, ck.params);
        assert_eq!(back.adam_m, ck.adam_m);
        assert_eq!(back.adam_v, ck.adam_v);
        assert_eq!(back.algo_state, ck.algo_state);
        // Re-encoding the decoded checkpoint reproduces the exact image.
        assert_eq!(back.encode(), bytes);
    }

    #[test]
    fn restore_rng_continues_cursor() {
        let mut rng = Rng::new(99);
        for _ in 0..10 {
            rng.normal();
        }
        let (state, inc, spare) = rng.state_parts();
        let meta =
            CkptMeta { rng_state: state, rng_inc: inc, rng_spare: spare, ..sample().meta };
        let mut restored = meta.restore_rng();
        for _ in 0..32 {
            assert_eq!(rng.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn mats_field_stream_roundtrips() {
        let mut rng = Rng::new(5);
        let mats =
            vec![Matrix::randn(3, 4, 1.0, &mut rng), Matrix::zeros(0, 7), Matrix::zeros(2, 0)];
        let mut w = ByteWriter::new();
        push_mats(&mut w, &mats);
        let body = w.finish();
        let mut r = ByteReader::new(&body);
        let back = read_mats(&mut r).unwrap();
        assert_eq!(back, mats);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn check_resume_names_the_mismatched_field() {
        let meta = sample().meta;
        assert!(meta.check_resume("dad", 2, 8, 5, 1e-3, 41, 0).is_ok());
        let err = meta.check_resume("dsgd", 2, 8, 5, 1e-3, 41, 0).unwrap_err();
        assert!(err.to_string().contains("algo"), "{err}");
        let err = meta.check_resume("dad", 3, 8, 5, 1e-3, 41, 0).unwrap_err();
        assert!(err.to_string().contains("n_sites"), "{err}");
        let err = meta.check_resume("dad", 2, 8, 5, 1e-3, 42, 0).unwrap_err();
        assert!(err.to_string().contains("seed"), "{err}");
        // Already complete: next_epoch == requested epochs.
        let err = meta.check_resume("dad", 2, 8, 2, 1e-3, 41, 0).unwrap_err();
        assert!(err.to_string().contains("nothing to resume"), "{err}");
    }
}

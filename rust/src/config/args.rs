//! Hand-rolled CLI argument parser: positionals + `--key value` /
//! `--flag` options.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Non-option arguments, in order.
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` pairs.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse an argv-style iterator (no program name).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process's command line (program name skipped).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// The value of `--key`, when present.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// The value of `--key`, or `default`.
    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    /// `--key` parsed as usize, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// `--key` parsed as f32, or `default`.
    pub fn f32_or(&self, key: &str, default: f32) -> f32 {
        self.opt(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Whether bare `--key` was passed.
    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn positionals_options_flags() {
        let a = parse(&["exp", "fig1", "--scale", "quick", "--verbose", "--lr=0.001"]);
        assert_eq!(a.positional, vec!["exp", "fig1"]);
        assert_eq!(a.opt("scale"), Some("quick"));
        assert_eq!(a.f32_or("lr", 0.0), 0.001);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["train", "--fast"]);
        assert!(a.has_flag("fast"));
        assert_eq!(a.opt("fast"), None);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize_or("epochs", 50), 50);
        assert_eq!(a.opt_or("algo", "dad"), "dad");
    }
}

//! Configuration substrate: a TOML-subset parser and a CLI argument parser
//! (no clap/serde offline — both are built here and unit-tested).

pub mod args;
pub mod toml_lite;

pub use args::Args;
pub use toml_lite::TomlLite;

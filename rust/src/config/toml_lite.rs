//! Minimal TOML-subset parser: `[section]` headers, `key = value` lines,
//! strings ("..."), integers, floats, booleans, and flat arrays of those.
//! Enough for experiment config files; not a general TOML implementation.

use std::collections::BTreeMap;

/// One parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// A `"..."` string.
    Str(String),
    /// A decimal integer.
    Int(i64),
    /// A float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// A flat `[v, v, ...]` array.
    Array(Vec<Value>),
}

impl Value {
    /// String contents, when this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Integer value, when this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Float value (integers widen), when numeric.
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Boolean value, when this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: section -> key -> value ("" is the root section).
#[derive(Debug, Default, Clone)]
pub struct TomlLite {
    /// section name -> key -> value ("" is the root section).
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl TomlLite {
    /// Parse config text; errors carry the 1-based line number.
    pub fn parse(text: &str) -> Result<TomlLite, String> {
        let mut out = TomlLite::default();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: malformed section header", ln + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            let value = parse_value(val.trim()).map_err(|e| format!("line {}: {e}", ln + 1))?;
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value);
        }
        Ok(out)
    }

    /// Read and parse `path`.
    pub fn load(path: &str) -> Result<TomlLite, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        TomlLite::parse(&text)
    }

    /// Look up `section.key`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// `section.key` as a string, or `default`.
    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// `section.key` as an integer, or `default`.
    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    /// `section.key` as a float, or `default`.
    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    /// `section.key` as a boolean, or `default`.
    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') {
        if !s.ends_with('"') || s.len() < 2 {
            return Err("unterminated string".into());
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err("unterminated array".into());
        }
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|i| parse_value(i.trim())).collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let text = r#"
# experiment config
name = "fig1"        # inline comment
[train]
epochs = 50
lr = 1e-4
non_iid = true
ranks = [1, 2, 4]
"#;
        let cfg = TomlLite::parse(text).unwrap();
        assert_eq!(cfg.str_or("", "name", "?"), "fig1");
        assert_eq!(cfg.int_or("train", "epochs", 0), 50);
        assert!((cfg.float_or("train", "lr", 0.0) - 1e-4).abs() < 1e-12);
        assert!(cfg.bool_or("train", "non_iid", false));
        match cfg.get("train", "ranks") {
            Some(Value::Array(a)) => assert_eq!(a.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let cfg = TomlLite::parse("").unwrap();
        assert_eq!(cfg.int_or("x", "y", 7), 7);
    }

    #[test]
    fn errors_are_located() {
        let err = TomlLite::parse("[broken\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = TomlLite::parse("key value\n").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn hash_inside_string_kept() {
        let cfg = TomlLite::parse("s = \"a#b\"").unwrap();
        assert_eq!(cfg.str_or("", "s", ""), "a#b");
    }
}

//! Experiment drivers — one function per table/figure in the paper's
//! evaluation (see DESIGN.md per-experiment index). Each is callable from
//! the CLI (`dad exp <id>`) and from the benches, writes its series to
//! results/*.csv, and returns structured numbers for assertions.
//!
//! Scale presets: the paper's exact runs (60k MNIST, 50-100 epochs, 5-fold)
//! are hours of CPU on the native engine, so every experiment takes a
//! `Scale`; `Paper` reproduces the full protocol, `Default`/`Quick` shrink
//! sample counts and epochs while preserving every structural parameter
//! that the claims depend on (architecture shape at Default+, batch size,
//! 2 sites, non-IID label split, Adam 1e-4). EXPERIMENTS.md records which
//! scale produced each committed number.

use crate::algos::AlgoSpec;
use crate::coordinator::trainer::{
    build_task, default_lm_lr, fold_mean_auc, train, DataSource, Schedule, TrainLog, TrainSpec,
    TrainTask,
};
use crate::data::{
    arabic_digits_like, kfold, mnist_like, natops_like, pems_sf_like, pen_digits_like,
    split_by_label, DenseDataset, SeqDataset,
};
use crate::metrics::CsvWriter;
use crate::nn::model::DistModel;
use crate::nn::{Activation, GruClassifier, Mlp};
use crate::tensor::{Matrix, Rng};

/// Experiment scale preset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Seconds per experiment (CI / cargo bench smoke).
    Quick,
    /// Minutes per experiment — the committed EXPERIMENTS.md numbers.
    Default,
    /// The paper's full protocol (hours on this testbed).
    Paper,
}

impl Scale {
    /// Parse a preset name: `quick | default | paper`.
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "quick" => Some(Scale::Quick),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    fn mnist_n(self) -> (usize, usize) {
        match self {
            Scale::Quick => (400, 120),
            Scale::Default => (1600, 400),
            Scale::Paper => (60_000, 10_000),
        }
    }

    fn mlp_dims(self) -> Vec<usize> {
        match self {
            Scale::Quick => vec![784, 128, 128, 10],
            _ => vec![784, 1024, 1024, 10], // the paper architecture
        }
    }

    fn mlp_epochs(self) -> usize {
        match self {
            Scale::Quick => 3,
            Scale::Default => 8,
            Scale::Paper => 50,
        }
    }

    fn seq_n(self) -> (usize, usize) {
        match self {
            Scale::Quick => (240, 80),
            Scale::Default => (480, 160),
            Scale::Paper => (6600, 2200), // SpokenArabicDigits size
        }
    }

    fn gru_epochs(self) -> usize {
        match self {
            Scale::Quick => 4,
            Scale::Default => 10,
            Scale::Paper => 100,
        }
    }

    fn folds(self) -> usize {
        match self {
            Scale::Quick => 1,
            Scale::Default => 2,
            Scale::Paper => 5, // the paper's k
        }
    }

    fn gru(self, c_in: usize, classes: usize, rng: &mut Rng) -> GruClassifier {
        match self {
            Scale::Quick => GruClassifier::new(c_in, 32, &[64, 32], classes, rng),
            _ => GruClassifier::paper_uea(c_in, classes, rng), // h=64, 512-256
        }
    }
}

fn mlp_of(scale: Scale, seed: u64) -> Mlp {
    let dims = scale.mlp_dims();
    let mut rng = Rng::new(seed);
    Mlp::new(&dims, &vec![Activation::Relu; dims.len() - 2], &mut rng)
}

fn base_spec(scale: Scale, algo: AlgoSpec, epochs: usize) -> TrainSpec {
    TrainSpec {
        algo,
        n_sites: 2,
        batch_per_site: 32,
        epochs,
        lr: 1e-4,
        seed: 97,
        schedule: Schedule::EveryBatch,
    }
    .tuned(scale)
}

impl TrainSpec {
    fn tuned(mut self, scale: Scale) -> TrainSpec {
        // Quick preset trains tiny models on few samples; a slightly larger
        // lr keeps the curves informative within 3-4 epochs.
        if scale == Scale::Quick {
            self.lr = 1e-3;
        }
        self
    }
}

// ---------------------------------------------------------------------------
// Table 2 — max gradient error vs pooled, per layer, over one epoch.
// ---------------------------------------------------------------------------

/// One layer's row of Table 2: max |grad_algo - grad_pooled| over an epoch.
pub struct Table2Row {
    /// Layer name (from `DistModel::entry_names`).
    pub layer: String,
    /// Max deviation of the dSGD gradient.
    pub dsgd: f32,
    /// Max deviation of the dAD gradient.
    pub dad: f32,
    /// Max deviation of the edAD gradient.
    pub edad: f32,
}

/// Runs one epoch with all sites/algorithms evaluated on the SAME parameter
/// trajectory (advanced by the pooled gradient, as the paper's "maximum
/// error for the gradients computed during one epoch" implies) and records
/// the max absolute elementwise deviation of each algorithm's gradient from
/// the pooled gradient per layer.
pub fn table2(scale: Scale) -> Vec<Table2Row> {
    use crate::algos::common::DistAlgorithm;
    use crate::algos::{Dad, Dsgd, Edad, Pooled};
    use crate::dist::Cluster;
    let (n_train, _) = scale.mnist_n();
    let mut rng = Rng::new(11);
    let ds = mnist_like(n_train.min(2048), &mut rng); // one epoch; bounded work
    let shards = split_by_label(&ds.labels, ds.classes, 2);
    let model = mlp_of(scale, 42);
    let shapes = model.param_shapes();
    let n_layers = model.n_layers();
    let names = model.entry_names();

    let mut cluster = Cluster::replicate(model, 2);
    let mut pooled = Pooled;
    let mut dsgd = Dsgd;
    let mut dad = Dad;
    let mut edad = Edad;
    let mut opt = crate::nn::Adam::new(1e-4, &shapes);
    let mut params: Vec<Matrix> =
        cluster.sites[0].model.params().into_iter().cloned().collect();

    let batch = 32;
    let mut max_err = vec![[0.0f32; 3]; n_layers];
    let mut rng_b = Rng::new(23);
    let mut iters: Vec<crate::data::BatchIter> = shards
        .iter()
        .map(|s| crate::data::BatchIter::new(s.len(), batch, &mut rng_b))
        .collect();
    let n_steps = iters.iter().map(|i| i.n_batches()).min().unwrap();
    for _ in 0..n_steps {
        let batches: Vec<_> = iters
            .iter_mut()
            .zip(&shards)
            .map(|(it, shard)| {
                let local = it.next().unwrap();
                let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
                ds.batch(&idx)
            })
            .collect();
        let g_pooled = pooled.step(&mut cluster, &batches).grads;
        let g_dsgd = dsgd.step(&mut cluster, &batches).grads;
        let g_dad = dad.step(&mut cluster, &batches).grads;
        let g_edad = edad.step(&mut cluster, &batches).grads;
        for l in 0..n_layers {
            let w = 2 * l; // weight param index
            max_err[l][0] = max_err[l][0].max(g_pooled[w].max_abs_diff(&g_dsgd[w]));
            max_err[l][1] = max_err[l][1].max(g_pooled[w].max_abs_diff(&g_dad[w]));
            max_err[l][2] = max_err[l][2].max(g_pooled[w].max_abs_diff(&g_edad[w]));
        }
        // Shared trajectory: everyone advances by the pooled gradient.
        opt.step(&mut params, &g_pooled);
        for site in &mut cluster.sites {
            site.model.set_params(&params);
        }
    }
    let rows: Vec<Table2Row> = (0..n_layers)
        .map(|l| Table2Row {
            layer: names[l].clone(),
            dsgd: max_err[l][0],
            dad: max_err[l][1],
            edad: max_err[l][2],
        })
        .collect();
    let mut csv = CsvWriter::create("results/table2.csv", &["layer", "dsgd", "dad", "edad"]).unwrap();
    for r in &rows {
        csv.row(&[r.layer.clone(), r.dsgd.to_string(), r.dad.to_string(), r.edad.to_string()])
            .unwrap();
    }
    csv.flush().unwrap();
    rows
}

// ---------------------------------------------------------------------------
// Figures 1 & 2 — equivalence curves (MLP / GRU).
// ---------------------------------------------------------------------------

/// Per-algorithm AUC curves + bandwidth for one figure.
pub struct CurveSet {
    /// (algorithm name, per-epoch (mean, std) test AUC across folds).
    pub curves: Vec<(String, Vec<(f32, f32)>)>,
    /// (algorithm name, total bytes of fold 0).
    pub bytes: Vec<(String, u64)>,
}

fn run_algos_kfold<M: DistModel + Clone, D: DataSource + Clone>(
    make_model: impl Fn(u64) -> M,
    full: &D,
    subset: impl Fn(&D, &[usize]) -> D,
    algos: &[AlgoSpec],
    scale: Scale,
    epochs: usize,
    csv_path: &str,
) -> CurveSet {
    let mut rng = Rng::new(301);
    let folds = kfold(full.len(), scale.folds().max(2), &mut rng);
    let folds = &folds[..scale.folds()];
    let mut curves = Vec::new();
    let mut bytes = Vec::new();
    for algo in algos {
        let mut logs: Vec<TrainLog> = Vec::new();
        for (train_idx, test_idx) in folds {
            let train_ds = subset(full, train_idx);
            let test_ds = subset(full, test_idx);
            let shards = split_by_label(train_ds.labels(), 10, 2);
            let spec = base_spec(scale, algo.clone(), epochs);
            logs.push(train(make_model(42), &spec, &train_ds, &shards, &test_ds));
        }
        let mean = fold_mean_auc(&logs);
        bytes.push((algo.name(), logs[0].total_bytes()));
        curves.push((algo.name(), mean));
    }
    let mut csv = CsvWriter::create(csv_path, &["algo", "epoch", "auc_mean", "auc_std"]).unwrap();
    for (name, series) in &curves {
        for (e, (m, s)) in series.iter().enumerate() {
            csv.row(&[name.clone(), e.to_string(), m.to_string(), s.to_string()]).unwrap();
        }
    }
    csv.flush().unwrap();
    CurveSet { curves, bytes }
}

/// Figure 1: MLP on MNIST-analog, labels split across sites; pooled vs
/// dSGD vs dAD vs edAD must coincide.
pub fn fig1(scale: Scale) -> CurveSet {
    let (n_train, n_test) = scale.mnist_n();
    let mut rng = Rng::new(71);
    let full = mnist_like(n_train + n_test, &mut rng);
    run_algos_kfold(
        |seed| mlp_of(scale, seed),
        &full,
        |d: &DenseDataset, idx| d.subset(idx),
        &[AlgoSpec::Pooled, AlgoSpec::Dsgd, AlgoSpec::Dad, AlgoSpec::Edad],
        scale,
        scale.mlp_epochs(),
        "results/fig1.csv",
    )
}

/// Figure 2: GRU on SpokenArabicDigits-analog; same four algorithms.
pub fn fig2(scale: Scale) -> CurveSet {
    let (n_train, n_test) = scale.seq_n();
    let mut rng = Rng::new(72);
    let full = arabic_digits_like(n_train + n_test, &mut rng);
    let c_in = full.channels;
    let classes = full.classes;
    run_algos_kfold(
        move |seed| {
            let mut r = Rng::new(seed);
            scale.gru(c_in, classes, &mut r)
        },
        &full,
        |d: &SeqDataset, idx| d.subset(idx),
        &[AlgoSpec::Pooled, AlgoSpec::Dsgd, AlgoSpec::Dad, AlgoSpec::Edad],
        scale,
        scale.gru_epochs(),
        "results/fig2.csv",
    )
}

// ---------------------------------------------------------------------------
// Figures 3 & 6 — rank sweeps: rank-dAD vs PowerSGD.
// ---------------------------------------------------------------------------

fn rank_list(scale: Scale) -> Vec<usize> {
    match scale {
        Scale::Quick => vec![1, 4],
        Scale::Default => vec![1, 2, 4, 8],
        Scale::Paper => vec![1, 2, 3, 4, 8, 16],
    }
}

/// Figure 3 (MNIST panel): rank-dAD vs PowerSGD across ranks on the MLP.
pub fn fig3_mnist(scale: Scale) -> CurveSet {
    let (n_train, n_test) = scale.mnist_n();
    let mut rng = Rng::new(73);
    let full = mnist_like(n_train + n_test, &mut rng);
    let mut algos = Vec::new();
    for &r in &rank_list(scale) {
        algos.push(AlgoSpec::RankDad { max_rank: r, n_iters: 10, theta: 1e-3 });
        algos.push(AlgoSpec::PowerSgd { rank: r });
    }
    run_algos_kfold(
        |seed| mlp_of(scale, seed),
        &full,
        |d: &DenseDataset, idx| d.subset(idx),
        &algos,
        scale,
        scale.mlp_epochs(),
        "results/fig3_mnist.csv",
    )
}

/// Figure 3 (ArabicDigits panel) / Figure 6: the GRU rank sweep.
pub fn fig3_arabic(scale: Scale) -> CurveSet {
    let (n_train, n_test) = scale.seq_n();
    let mut rng = Rng::new(74);
    let full = arabic_digits_like(n_train + n_test, &mut rng);
    let c_in = full.channels;
    let classes = full.classes;
    let mut algos = Vec::new();
    for &r in &rank_list(scale) {
        algos.push(AlgoSpec::RankDad { max_rank: r, n_iters: 10, theta: 1e-3 });
        algos.push(AlgoSpec::PowerSgd { rank: r });
    }
    run_algos_kfold(
        move |seed| {
            let mut r = Rng::new(seed);
            scale.gru(c_in, classes, &mut r)
        },
        &full,
        |d: &SeqDataset, idx| d.subset(idx),
        &algos,
        scale,
        scale.gru_epochs(),
        "results/fig6_gru_ranks.csv",
    )
}

// ---------------------------------------------------------------------------
// Figures 4 & 5 — effective-rank trajectories.
// ---------------------------------------------------------------------------

/// Effective-rank trajectories for one rank-dAD run.
pub struct RankCurves {
    /// Stats-entry (layer) names, aligned with `per_epoch` columns.
    pub entry_names: Vec<String>,
    /// per epoch, per entry: mean effective rank.
    pub per_epoch: Vec<Vec<f32>>,
}

fn eff_rank_run<M: DistModel + Clone, D: DataSource>(
    model: M,
    data: &D,
    test: &D,
    scale: Scale,
    max_rank: usize,
    epochs: usize,
    csv_path: &str,
) -> RankCurves {
    let shards = split_by_label(data.labels(), 10, 2);
    let spec = base_spec(
        scale,
        AlgoSpec::RankDad { max_rank, n_iters: 10, theta: 1e-3 },
        epochs,
    );
    let log = train(model, &spec, data, &shards, test);
    let entry_names = log.entry_names.clone();
    let per_epoch: Vec<Vec<f32>> = log.epochs.iter().map(|e| e.mean_eff_rank.clone()).collect();
    let mut header = vec!["epoch".to_string()];
    header.extend(entry_names.iter().cloned());
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut csv = CsvWriter::create(csv_path, &header_refs).unwrap();
    for (e, ranks) in per_epoch.iter().enumerate() {
        let mut row = vec![e.to_string()];
        row.extend(ranks.iter().map(|r| r.to_string()));
        csv.row(&row).unwrap();
    }
    csv.flush().unwrap();
    RankCurves { entry_names, per_epoch }
}

/// Figure 4: effective rank per layer during MLP/MNIST training, max rank 10.
pub fn fig4(scale: Scale) -> RankCurves {
    let (n_train, n_test) = scale.mnist_n();
    let mut rng = Rng::new(75);
    // Single generator call: train and test share class prototypes.
    let full = mnist_like(n_train + n_test, &mut rng);
    let ds = full.subset(&(0..n_train).collect::<Vec<_>>());
    let test = full.subset(&(n_train..n_train + n_test).collect::<Vec<_>>());
    eff_rank_run(
        mlp_of(scale, 42),
        &ds,
        &test,
        scale,
        10,
        scale.mlp_epochs(),
        "results/fig4.csv",
    )
}

/// Figure 5: effective rank per layer for the GRU across the four UEA
/// analogs, max rank 32 (= the per-site batch, its true upper bound).
pub fn fig5(scale: Scale) -> Vec<(&'static str, RankCurves)> {
    let (n_train, n_test) = scale.seq_n();
    let mut rng = Rng::new(76);
    let sets: Vec<SeqDataset> = vec![
        arabic_digits_like(n_train + n_test, &mut rng),
        natops_like((n_train + n_test) / 2, &mut rng),
        pen_digits_like(n_train + n_test, &mut rng),
        pems_sf_like((n_train + n_test) / 3, &mut rng),
    ];
    let max_rank = if scale == Scale::Quick { 8 } else { 32 };
    sets.into_iter()
        .map(|full| {
            let name = full.name;
            let n = full.len();
            let test_n = (n / 5).max(1);
            let idx_train: Vec<usize> = (0..n - test_n).collect();
            let idx_test: Vec<usize> = (n - test_n..n).collect();
            let train_ds = full.subset(&idx_train);
            let test_ds = full.subset(&idx_test);
            let mut r = Rng::new(42);
            let model = scale.gru(train_ds.channels, train_ds.classes, &mut r);
            let csv = format!("results/fig5_{name}.csv");
            let curves = eff_rank_run(
                model,
                &train_ds,
                &test_ds,
                scale,
                max_rank,
                scale.gru_epochs(),
                &csv,
            );
            (name, curves)
        })
        .collect()
}

// ---------------------------------------------------------------------------
// LM comparison — the transformer workload (§5.3.2) across algorithms.
// ---------------------------------------------------------------------------

/// One algorithm's endpoint on the LM task (results/lm_bandwidth.csv holds
/// the full per-epoch series).
pub struct LmRow {
    /// Algorithm name.
    pub algo: String,
    /// Final epoch's mean training loss.
    pub final_loss: f32,
    /// Final epoch's test perplexity.
    pub final_ppl: f32,
    /// Total payload bytes, site->aggregator, across the run.
    pub bytes_up: u64,
    /// Total payload bytes, aggregator->site, across the run.
    pub bytes_down: u64,
    /// Wall-clock seconds the whole training run took — the honest
    /// companion to the byte columns: compression that saves bytes but
    /// burns compute shows up here.
    pub wall_s: f64,
}

/// The paper's §5.3.2 transformer claim, measured in the ledger: train the
/// decoder-only LM with the gradient-centric baselines (dSGD full
/// gradients; PowerSGD compressed gradients, Vogels et al. 2019; the
/// sparse top-k family — DGC, Lin et al. 2017; variance-based, Tsuzuku et
/// al. 2018; AdaComp, Chen et al. 2017) and the statistics-shipping family
/// (dAD; rank-dAD), and record loss/perplexity next to the *actual
/// serialized bytes* each ships — sparse frames priced at 8 bytes per
/// transmitted element (u32 index + f32 value). dAD ships
/// (B·T)×(h_in+h_out) stacks per projection vs. dSGD's h_in·h_out weight
/// gradients, so its advantage is exactly the `B·T < layer width` regime
/// — see EXPERIMENTS.md §LM for the per-config crossover math.
pub fn lm_comparison(scale: Scale) -> Vec<LmRow> {
    let epochs = match scale {
        Scale::Quick => 2,
        Scale::Default => 2,
        Scale::Paper => 3,
    };
    let algos = [
        AlgoSpec::Dsgd,
        AlgoSpec::Dad,
        AlgoSpec::RankDad { max_rank: 4, n_iters: 10, theta: 1e-3 },
        AlgoSpec::PowerSgd { rank: 4 },
        AlgoSpec::Dgc { density: 25.0 },
        AlgoSpec::Vbc { lambda: 2.0 },
        AlgoSpec::AdaComp { bin: 512 },
    ];
    let mut csv = CsvWriter::create(
        "results/lm_bandwidth.csv",
        &["algo", "epoch", "train_loss", "test_ppl", "bytes_up", "bytes_down", "wall_s"],
    )
    .unwrap();
    let mut rows = Vec::new();
    for algo in algos {
        let (train_ds, test_ds, shards, model) =
            match build_task("lm", scale, 2, 97).expect("lm task") {
                TrainTask::Tokens { train_ds, test_ds, shards, model } => {
                    (train_ds, test_ds, shards, model)
                }
                _ => unreachable!("lm builds a token task"),
            };
        let spec = TrainSpec {
            algo: algo.clone(),
            n_sites: 2,
            batch_per_site: 8,
            epochs,
            lr: default_lm_lr(scale),
            seed: 97,
            schedule: Schedule::EveryBatch,
        };
        let t0 = std::time::Instant::now();
        let log = train(model, &spec, &train_ds, &shards, &test_ds);
        let wall_s = t0.elapsed().as_secs_f64();
        // Per-epoch rows share the run's wall clock: epoch-resolution
        // timing lives in the compute/comms/stall/compress CSV columns
        // (`TrainLog::write_csv`); this column answers "which algorithm
        // is cheapest end-to-end on this hardware".
        for e in &log.epochs {
            csv.row(&[
                algo.name(),
                e.epoch.to_string(),
                e.train_loss.to_string(),
                e.test_ppl.to_string(),
                e.bytes_up.to_string(),
                e.bytes_down.to_string(),
                format!("{wall_s:.3}"),
            ])
            .unwrap();
        }
        let last = log.epochs.last().expect("at least one epoch");
        rows.push(LmRow {
            algo: algo.name(),
            final_loss: last.train_loss,
            final_ppl: last.test_ppl,
            bytes_up: log.epochs.iter().map(|e| e.bytes_up).sum(),
            bytes_down: log.epochs.iter().map(|e| e.bytes_down).sum(),
            wall_s,
        });
    }
    csv.flush().unwrap();
    rows
}

// ---------------------------------------------------------------------------
// Bandwidth table — measured ledger bytes vs the paper's Θ bounds.
// ---------------------------------------------------------------------------

/// One (algorithm, width) cell of the bandwidth table.
pub struct BandwidthRow {
    /// Algorithm name.
    pub algo: String,
    /// Hidden width of the probe MLP.
    pub h: usize,
    /// Ledger-measured site->aggregator bytes for one step.
    pub measured_up: u64,
    /// The paper's Θ bound in bytes (raw f32 payload, no framing).
    pub theta_up: u64,
}

/// One synchronized step of each algorithm on a 2-layer h-wide MLP; the
/// measured site->aggregator bytes must track the paper's per-layer Θ
/// bounds (section 3.2-3.4 + PowerSGD's r(h_i+h_{i+1})).
pub fn bandwidth_table(hs: &[usize], n: usize) -> Vec<BandwidthRow> {
    use crate::dist::Cluster;
    use crate::nn::loss::one_hot;
    use crate::nn::model::Batch;
    let mut rows = Vec::new();
    for &h in hs {
        let dims = [64usize, h, h, 10];
        let mut rng = Rng::new(5);
        let mlp = Mlp::new(&dims, &[Activation::Relu, Activation::Relu], &mut rng);
        let mk_batches = |rng: &mut Rng| -> Vec<Batch> {
            (0..2)
                .map(|_| {
                    let x = Matrix::randn(n, 64, 1.0, rng);
                    let labels: Vec<usize> = (0..n).map(|i| i % 10).collect();
                    Batch::Dense { x, y: one_hot(&labels, 10) }
                })
                .collect()
        };
        // Θ formulas per layer i (S sites, batch n per site), summed:
        let grad_numel: usize = dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum();
        let stat_numel: usize = dims.windows(2).map(|w| n * (w[0] + w[1])).sum();
        let act_numel: usize =
            dims[..3].iter().map(|&hh| n * hh).sum::<usize>() + n * dims[3]; // A_0..A_2 + Δ_L
        let r = 4usize;
        let lowrank_numel: usize = dims.windows(2).map(|w| r * (w[0] + w[1])).sum();
        let specs: Vec<(AlgoSpec, u64)> = vec![
            (AlgoSpec::Dsgd, (2 * grad_numel * 4) as u64),
            (AlgoSpec::Dad, (2 * stat_numel * 4) as u64),
            (AlgoSpec::Edad, (2 * act_numel * 4) as u64),
            (AlgoSpec::RankDad { max_rank: r, n_iters: 10, theta: 1e-3 }, (2 * lowrank_numel * 4) as u64),
            (AlgoSpec::PowerSgd { rank: r }, (2 * lowrank_numel * 4) as u64),
        ];
        for (spec, theta_up) in specs {
            let mut rngb = Rng::new(7);
            let batches = mk_batches(&mut rngb);
            let mut cluster = Cluster::replicate(mlp.clone(), 2);
            let mut algo = spec.build::<Mlp>();
            let out = algo.step(&mut cluster, &batches);
            rows.push(BandwidthRow { algo: spec.name(), h, measured_up: out.bytes_up, theta_up });
        }
    }
    let mut csv =
        CsvWriter::create("results/bandwidth.csv", &["algo", "h", "measured_up", "theta_up"])
            .unwrap();
    for r in &rows {
        csv.row(&[r.algo.clone(), r.h.to_string(), r.measured_up.to_string(), r.theta_up.to_string()])
            .unwrap();
    }
    csv.flush().unwrap();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_quick_errors_tiny() {
        let rows = table2(Scale::Quick);
        assert_eq!(rows.len(), 3); // 784-128-128-10 => three dense layers
        for r in &rows {
            // The paper reports ~1e-7; our f32 engine at reduced width stays
            // well under 1e-4.
            assert!(r.dsgd < 1e-4, "dsgd err {}", r.dsgd);
            assert!(r.dad < 1e-4, "dad err {}", r.dad);
            assert!(r.edad < 1e-4, "edad err {}", r.edad);
        }
    }

    #[test]
    fn bandwidth_measured_matches_theta_shape() {
        let rows = bandwidth_table(&[128, 256], 16);
        for r in &rows {
            // Measured includes small extras (bias rides, Δ_L);
            // the Θ bound must explain the bulk within 2x either way.
            let ratio = r.measured_up as f64 / r.theta_up.max(1) as f64;
            assert!(
                (0.4..=2.5).contains(&ratio),
                "{} h={}: measured {} vs theta {} (ratio {ratio})",
                r.algo,
                r.h,
                r.measured_up,
                r.theta_up
            );
        }
        // Ordering at h=256, n=8: rank-dad < edad < dad < dsgd.
        let get = |name: &str| {
            rows.iter().find(|r| r.algo == name && r.h == 256).map(|r| r.measured_up).unwrap()
        };
        assert!(get("rank-dad:4") < get("edad"));
        assert!(get("edad") < get("dad"));
        assert!(get("dad") < get("dsgd"));
    }
}

//! Layer-3 coordinator: the training loop over the cluster, the experiment
//! drivers for every paper table/figure, update schedules, and the
//! multi-process `serve`/`join` drivers.

pub mod experiments;
pub mod remote;
pub mod trainer;

pub use experiments::Scale;
pub use remote::{
    join_training, join_training_resumable, relay_training, remote_agg_step, remote_site_step,
    reshard_indices, serve_training, serve_training_checkpointed, validate_remote,
    validate_remote_topology, EpochSync, FaultPolicy, RemoteConfig, RemoteStep, ResumeMode,
    ResumeState, Topology,
};
pub use trainer::{
    build_task, default_lm_lr, epoch_plan, evaluate, fold_mean_auc, local_update,
    snapshot_checkpoint, train, train_checkpointed, validate_dataset_algo, DataSource,
    EvalMetrics, Schedule, TrainLog, TrainSpec, TrainTask,
};

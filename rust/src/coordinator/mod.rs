//! Layer-3 coordinator: the training loop over the cluster, the experiment
//! drivers for every paper table/figure, update schedules, and the
//! multi-process `serve`/`join` drivers.

pub mod experiments;
pub mod remote;
pub mod trainer;

pub use experiments::Scale;
pub use remote::{
    ensure_remote_supported, join_training, serve_training, RemoteConfig, RemoteStep,
};
pub use trainer::{
    build_task, epoch_plan, evaluate, fold_mean_auc, train, DataSource, Schedule, TrainLog,
    TrainSpec, TrainTask,
};

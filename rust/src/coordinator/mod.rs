//! Layer-3 coordinator: the training loop over the simulated cluster, the
//! experiment drivers for every paper table/figure, and update schedules.

pub mod experiments;
pub mod trainer;

pub use experiments::Scale;
pub use trainer::{evaluate, fold_mean_auc, train, DataSource, Schedule, TrainLog, TrainSpec};

//! Multi-process training over a real transport: the `dad serve` /
//! `dad join` drivers.
//!
//! The simulated trainer (`coordinator::trainer::train`) holds every
//! replica in one process and hands the algorithms a god's-eye view. This
//! module runs the *same* synchronized optimization with the aggregator and
//! each site as separate OS processes exchanging [`crate::dist::wire`]
//! frames over a [`Transport`] (in practice [`crate::dist::TcpAgg`] /
//! [`crate::dist::TcpSite`]). Three invariants tie the two modes together,
//! asserted by `tests/transport_e2e.rs`:
//!
//! 1. **Same math.** Both modes funnel through `nn::stats::concat_stats` +
//!    `assemble_grads`, with sites concatenated in canonical id order, so a
//!    TCP run reproduces the loopback run's loss trajectory bit-for-bit
//!    (modulo nothing: the arithmetic is identical).
//! 2. **Same schedule.** Every process reseeds `Rng::new(seed)` and replays
//!    `trainer::epoch_plan`, so site i draws the same batches it would in
//!    simulation without any index traffic on the wire.
//! 3. **Same bytes.** Payload frames are encoded by the shared codec and
//!    recorded per direction on the aggregator, so `dad serve`'s ledger
//!    equals `dad train`'s for the same seed — the acceptance check for the
//!    paper's bandwidth claims holding on a real wire.
//!
//! Control frames (`step-meta` uplink, `step-sync` downlink, the initial
//! `config` broadcast) carry losses, row counts and parameter indices; they
//! are protocol overhead and never enter the ledger. Currently `dad` and
//! `dsgd` are wired for remote execution; the remaining algorithms run
//! loopback-only (see `ensure_remote_supported`).

use std::io;

use crate::algos::AlgoSpec;
use crate::coordinator::trainer::{
    epoch_plan, evaluate, DataSource, EpochLog, Schedule, TrainLog, TrainSpec,
};
use crate::dist::wire::{Body, ByteReader, ByteWriter, Frame};
use crate::dist::{Direction, Ledger, Transport};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{assemble_grads, concat_stats, StatsEntry};
use crate::nn::Adam;
use crate::tensor::{Matrix, Rng, Workspace};

/// Result of one synchronized remote step, as seen from one endpoint.
/// `grads` is identical on every endpoint (the dAD invariant); the byte
/// counters cover only the traffic this endpoint's ledger observed — the
/// aggregator sees everything, a site sees its own uplink plus the shared
/// broadcast.
pub struct RemoteStep {
    /// Batch-size-weighted global mean training loss for the step.
    pub loss: f32,
    /// The synchronized global gradient (aligned with the param list).
    pub grads: Vec<Matrix>,
    /// Site->aggregator payload bytes recorded locally this step.
    pub bytes_up: u64,
    /// Aggregator->site payload bytes recorded locally this step.
    pub bytes_down: u64,
}

/// Everything a joining site needs to reconstruct the run: training spec,
/// dataset name, and scale preset. Broadcast once, right after the
/// transport handshake, as the `config` control frame.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// The run's training specification (algorithm, sites, epochs, ...).
    pub spec: TrainSpec,
    /// Dataset name as `trainer::build_task` understands it.
    pub dataset: String,
    /// Scale preset string ("quick" | "default" | "paper").
    pub scale: String,
}

impl RemoteConfig {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_str(&self.spec.algo.name());
        w.push_str(&self.dataset);
        w.push_str(&self.scale);
        w.push_u32(self.spec.n_sites as u32);
        w.push_u32(self.spec.batch_per_site as u32);
        w.push_u32(self.spec.epochs as u32);
        w.push_f32(self.spec.lr);
        w.push_u64(self.spec.seed);
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<RemoteConfig> {
        let mut r = ByteReader::new(body);
        let algo_s = r.read_str()?;
        let dataset = r.read_str()?;
        let scale = r.read_str()?;
        let n_sites = r.read_u32()? as usize;
        let batch_per_site = r.read_u32()? as usize;
        let epochs = r.read_u32()? as usize;
        let lr = r.read_f32()?;
        let seed = r.read_u64()?;
        let algo = AlgoSpec::parse(&algo_s)
            .ok_or_else(|| proto(format!("unknown algo {algo_s:?} in config frame")))?;
        Ok(RemoteConfig {
            spec: TrainSpec {
                algo,
                n_sites,
                batch_per_site,
                epochs,
                lr,
                seed,
                schedule: Schedule::EveryBatch,
            },
            dataset,
            scale,
        })
    }

    /// Aggregator side: broadcast this config to every connected site.
    pub fn send(&self, t: &mut dyn Transport) -> io::Result<()> {
        t.ship_control(Direction::AggToSite, "config", &self.encode())?;
        Ok(())
    }

    /// Site side: block for the aggregator's config broadcast.
    pub fn recv(t: &mut dyn Transport) -> io::Result<RemoteConfig> {
        let body = expect_ctrl(t.recv_broadcast()?, "config")?;
        RemoteConfig::decode(&body)
    }
}

/// Per-step uplink metadata: the site's loss/rows plus the parameter-index
/// layout of its stats entries (so the aggregator never needs a model).
struct StepMeta {
    loss: f32,
    rows: u32,
    /// Per entry: (weight param index, bias param index or u32::MAX).
    entries: Vec<(u32, u32)>,
    /// Param indices of direct (non-outer-product) gradients.
    direct_idx: Vec<u32>,
}

impl StepMeta {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_f32(self.loss);
        w.push_u32(self.rows);
        w.push_u16(self.entries.len() as u16);
        for &(wi, bi) in &self.entries {
            w.push_u32(wi);
            w.push_u32(bi);
        }
        w.push_u16(self.direct_idx.len() as u16);
        for &i in &self.direct_idx {
            w.push_u32(i);
        }
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<StepMeta> {
        let mut r = ByteReader::new(body);
        let loss = r.read_f32()?;
        let rows = r.read_u32()?;
        let n_entries = r.read_u16()? as usize;
        let mut entries = Vec::with_capacity(n_entries);
        for _ in 0..n_entries {
            let wi = r.read_u32()?;
            let bi = r.read_u32()?;
            entries.push((wi, bi));
        }
        let n_direct = r.read_u16()? as usize;
        let mut direct_idx = Vec::with_capacity(n_direct);
        for _ in 0..n_direct {
            direct_idx.push(r.read_u32()?);
        }
        Ok(StepMeta { loss, rows, entries, direct_idx })
    }
}

fn proto(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn expect_mats(f: Frame, want: &str) -> io::Result<Vec<Matrix>> {
    match f.body {
        Body::Mats(m) if f.tag == want => Ok(m),
        _ => Err(proto(format!("expected payload frame {want:?}, got {:?}", f.tag))),
    }
}

fn expect_ctrl(f: Frame, want: &str) -> io::Result<Vec<u8>> {
    match f.body {
        Body::Control(b) if f.tag == want => Ok(b),
        _ => Err(proto(format!("expected control frame {want:?}, got {:?}", f.tag))),
    }
}

fn one_mat(mats: Vec<Matrix>) -> io::Result<Matrix> {
    let mut mats = mats;
    if mats.len() != 1 {
        return Err(proto(format!("expected exactly 1 matrix, got {}", mats.len())));
    }
    Ok(mats.pop().unwrap())
}

fn dirs(l: &Ledger) -> (u64, u64) {
    (l.total_dir(Direction::SiteToAgg), l.total_dir(Direction::AggToSite))
}

/// Ship a payload frame and record its serialized bytes.
fn ship(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    dir: Direction,
    tag: &str,
    mats: &[&Matrix],
) -> io::Result<()> {
    let n = t.ship(dir, tag, mats)?;
    ledger.record(tag, dir, n);
    Ok(())
}

/// Receive one broadcast frame (site side), recording payload bytes.
fn recv_down(t: &mut dyn Transport, ledger: &mut Ledger, want: &str) -> io::Result<Vec<Matrix>> {
    let f = t.recv_broadcast()?;
    if matches!(f.body, Body::Mats(_)) {
        ledger.record(&f.tag, Direction::AggToSite, f.wire_len());
    }
    expect_mats(f, want)
}

/// Receive one uplink frame from `site` (aggregator side), recording
/// payload bytes.
fn recv_up(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    site: usize,
    want: &str,
) -> io::Result<Vec<Matrix>> {
    let f = t.recv_from_site(site)?;
    if matches!(f.body, Body::Mats(_)) {
        ledger.record(&f.tag, Direction::SiteToAgg, f.wire_len());
    }
    expect_mats(f, want)
}

// ---------------------------------------------------------------------------
// dAD over the wire (Algorithm 1, star topology)
// ---------------------------------------------------------------------------

/// Site half of one remote dAD step: compute local statistics, ship
/// per-entry (A, Δ) frames up, receive the concatenated (Â, Δ̂) broadcast,
/// and assemble the exact global gradient locally.
pub fn dad_site_step<M: DistModel>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    batch: &Batch,
    ws: &mut Workspace,
) -> io::Result<RemoteStep> {
    let (up0, down0) = dirs(ledger);
    let stats = model.local_stats_ws(batch, ws);
    let rows = stats.entries.last().expect("no stats entries").d.rows();
    let meta = StepMeta {
        loss: stats.loss,
        rows: rows as u32,
        entries: stats
            .entries
            .iter()
            .map(|e| (e.w_idx as u32, e.b_idx.map(|b| b as u32).unwrap_or(u32::MAX)))
            .collect(),
        direct_idx: stats.direct.iter().map(|&(i, _)| i as u32).collect(),
    };
    t.ship_control(Direction::SiteToAgg, "step-meta", &meta.encode())?;
    for e in &stats.entries {
        ship(t, ledger, Direction::SiteToAgg, "acts", &[&e.a])?;
        ship(t, ledger, Direction::SiteToAgg, "deltas", &[&e.d])?;
    }
    if !stats.direct.is_empty() {
        let refs: Vec<&Matrix> = stats.direct.iter().map(|(_, g)| g).collect();
        ship(t, ledger, Direction::SiteToAgg, "direct-grad", &refs)?;
    }

    let sync = expect_ctrl(t.recv_broadcast()?, "step-sync")?;
    let mut rd = ByteReader::new(&sync);
    let total_rows = rd.read_u32()? as usize;
    let loss = rd.read_f32()?;
    let scale = 1.0 / total_rows as f32;
    let mut cat: Vec<StatsEntry> = Vec::with_capacity(stats.entries.len());
    for e in &stats.entries {
        let a = one_mat(recv_down(t, ledger, "acts")?)?;
        let d = one_mat(recv_down(t, ledger, "deltas")?)?;
        cat.push(StatsEntry { w_idx: e.w_idx, b_idx: e.b_idx, a, d });
    }
    let direct: Vec<(usize, Matrix)> = if stats.direct.is_empty() {
        vec![]
    } else {
        let mats = recv_down(t, ledger, "direct-grad")?;
        if mats.len() != stats.direct.len() {
            return Err(proto("direct-grad broadcast arity mismatch".into()));
        }
        stats.direct.iter().map(|&(i, _)| i).zip(mats).collect()
    };
    let shapes = model.param_shapes();
    let grads = assemble_grads(&shapes, &cat, &direct, scale, 1.0);
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep { loss, grads, bytes_up: up1 - up0, bytes_down: down1 - down0 })
}

/// Aggregator half of one remote dAD step: collect every site's (A, Δ)
/// stacks, vertcat in site order, broadcast the concatenation, and return
/// the same global gradient the sites assemble.
pub fn dad_agg_step(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    shapes: &[(usize, usize)],
) -> io::Result<RemoteStep> {
    let (up0, down0) = dirs(ledger);
    let n_sites = t.n_sites();
    let mut metas: Vec<StepMeta> = Vec::with_capacity(n_sites);
    let mut per_site: Vec<Vec<StatsEntry>> = Vec::with_capacity(n_sites);
    let mut per_site_direct: Vec<Vec<Matrix>> = Vec::with_capacity(n_sites);
    for site in 0..n_sites {
        let meta = StepMeta::decode(&expect_ctrl(t.recv_from_site(site)?, "step-meta")?)?;
        let mut entries = Vec::with_capacity(meta.entries.len());
        for &(w_idx, b_idx) in &meta.entries {
            let a = one_mat(recv_up(t, ledger, site, "acts")?)?;
            let d = one_mat(recv_up(t, ledger, site, "deltas")?)?;
            entries.push(StatsEntry {
                w_idx: w_idx as usize,
                b_idx: (b_idx != u32::MAX).then_some(b_idx as usize),
                a,
                d,
            });
        }
        let direct = if meta.direct_idx.is_empty() {
            vec![]
        } else {
            let mats = recv_up(t, ledger, site, "direct-grad")?;
            if mats.len() != meta.direct_idx.len() {
                return Err(proto(format!("site {site} direct-grad arity mismatch")));
            }
            mats
        };
        metas.push(meta);
        per_site.push(entries);
        per_site_direct.push(direct);
    }
    let total_rows: usize = metas.iter().map(|m| m.rows as usize).sum();
    let scale = 1.0 / total_rows as f32;
    let loss = weighted_loss_of(&metas, total_rows);

    let mut w = ByteWriter::new();
    w.push_u32(total_rows as u32);
    w.push_f32(loss);
    t.ship_control(Direction::AggToSite, "step-sync", &w.finish())?;

    let entry_refs: Vec<&[StatsEntry]> = per_site.iter().map(|e| &e[..]).collect();
    let cat = concat_stats(&entry_refs);
    for e in &cat {
        ship(t, ledger, Direction::AggToSite, "acts", &[&e.a])?;
        ship(t, ledger, Direction::AggToSite, "deltas", &[&e.d])?;
    }
    let direct: Vec<(usize, Matrix)> = if metas[0].direct_idx.is_empty() {
        vec![]
    } else {
        let mut out = Vec::with_capacity(metas[0].direct_idx.len());
        for (di, &idx) in metas[0].direct_idx.iter().enumerate() {
            let mut sum = per_site_direct[0][di].clone();
            for s in &per_site_direct[1..] {
                sum.axpy(1.0, &s[di]);
            }
            sum.scale_inplace(scale);
            out.push((idx as usize, sum));
        }
        let refs: Vec<&Matrix> = out.iter().map(|(_, g)| g).collect();
        ship(t, ledger, Direction::AggToSite, "direct-grad", &refs)?;
        out
    };
    let grads = assemble_grads(shapes, &cat, &direct, scale, 1.0);
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep { loss, grads, bytes_up: up1 - up0, bytes_down: down1 - down0 })
}

// ---------------------------------------------------------------------------
// dSGD over the wire (gradient averaging baseline)
// ---------------------------------------------------------------------------

/// Site half of one remote dSGD step: exchange row counts, ship the full
/// scaled local gradient, receive the global mean.
pub fn dsgd_site_step<M: DistModel>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    batch: &Batch,
    ws: &mut Workspace,
) -> io::Result<RemoteStep> {
    let (up0, down0) = dirs(ledger);
    let stats = model.local_stats_ws(batch, ws);
    let rows = stats.entries.last().expect("no stats entries").d.rows();
    let meta =
        StepMeta { loss: stats.loss, rows: rows as u32, entries: vec![], direct_idx: vec![] };
    t.ship_control(Direction::SiteToAgg, "step-meta", &meta.encode())?;
    // The gradient scale needs the *global* row count, so the sync frame
    // comes back before the gradient goes up (unlike dAD, where scaling
    // happens after the broadcast).
    let sync = expect_ctrl(t.recv_broadcast()?, "step-sync")?;
    let mut rd = ByteReader::new(&sync);
    let total_rows = rd.read_u32()? as usize;
    let loss = rd.read_f32()?;
    let scale = 1.0 / total_rows as f32;
    let shapes = model.param_shapes();
    let local = stats.assemble_grads(&shapes, scale, scale);
    let refs: Vec<&Matrix> = local.iter().collect();
    ship(t, ledger, Direction::SiteToAgg, "grad", &refs)?;
    let grads = recv_down(t, ledger, "grad")?;
    if grads.len() != shapes.len() {
        return Err(proto("grad broadcast arity mismatch".into()));
    }
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep { loss, grads, bytes_up: up1 - up0, bytes_down: down1 - down0 })
}

/// Aggregator half of one remote dSGD step: sum the per-site scaled
/// gradients (their sum is the global mean) and broadcast the result.
pub fn dsgd_agg_step(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    shapes: &[(usize, usize)],
) -> io::Result<RemoteStep> {
    let (up0, down0) = dirs(ledger);
    let n_sites = t.n_sites();
    let mut metas: Vec<StepMeta> = Vec::with_capacity(n_sites);
    for site in 0..n_sites {
        metas.push(StepMeta::decode(&expect_ctrl(t.recv_from_site(site)?, "step-meta")?)?);
    }
    let total_rows: usize = metas.iter().map(|m| m.rows as usize).sum();
    let loss = weighted_loss_of(&metas, total_rows);
    let mut w = ByteWriter::new();
    w.push_u32(total_rows as u32);
    w.push_f32(loss);
    t.ship_control(Direction::AggToSite, "step-sync", &w.finish())?;

    let mut acc: Option<Vec<Matrix>> = None;
    for site in 0..n_sites {
        let g = recv_up(t, ledger, site, "grad")?;
        if g.len() != shapes.len() {
            return Err(proto(format!("site {site} grad arity mismatch")));
        }
        acc = Some(match acc {
            None => g,
            Some(mut a) => {
                for (x, y) in a.iter_mut().zip(&g) {
                    x.axpy(1.0, y);
                }
                a
            }
        });
    }
    let grads = acc.expect("at least one site");
    let refs: Vec<&Matrix> = grads.iter().collect();
    ship(t, ledger, Direction::AggToSite, "grad", &refs)?;
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep { loss, grads, bytes_up: up1 - up0, bytes_down: down1 - down0 })
}

fn weighted_loss_of(metas: &[StepMeta], total_rows: usize) -> f32 {
    let num: f64 = metas.iter().map(|m| m.loss as f64 * m.rows as f64).sum();
    (num / total_rows.max(1) as f64) as f32
}

/// Which algorithms have a remote protocol. The rest run loopback-only for
/// now; extending them is a matter of adding a `*_site_step`/`*_agg_step`
/// pair above. `dad serve` calls this *before* binding so an unsupported
/// spec fails on the operator's terminal instead of stranding join
/// processes mid-handshake.
pub fn ensure_remote_supported(spec: &TrainSpec) -> io::Result<()> {
    if !matches!(spec.algo, AlgoSpec::Dad | AlgoSpec::Dsgd) {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            format!(
                "--algo {} is not wired over TCP yet; run it with `dad train` (loopback)",
                spec.algo.name()
            ),
        ));
    }
    if spec.schedule != Schedule::EveryBatch {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "periodic sync schedules are loopback-only for now".to_string(),
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Full training loops
// ---------------------------------------------------------------------------

/// Aggregator training loop (`dad serve`): drive one remote step per batch,
/// keep a model replica in lockstep for per-epoch evaluation, and log the
/// ledger's per-direction byte deltas per epoch.
///
/// `shard_sizes` are the per-site shard lengths — the aggregator never sees
/// data, but needs them to replay the deterministic batch schedule
/// ([`epoch_plan`]) that fixes the per-epoch step count.
pub fn serve_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    shard_sizes: &[usize],
    test: &D,
) -> io::Result<TrainLog> {
    ensure_remote_supported(spec)?;
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let entry_names = model.entry_names();
    let mut epochs = Vec::with_capacity(spec.epochs);
    for epoch in 0..spec.epochs {
        let plan = epoch_plan(shard_sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        for _ in 0..n_steps {
            let out = match spec.algo {
                AlgoSpec::Dad => dad_agg_step(t, ledger, &shapes)?,
                AlgoSpec::Dsgd => dsgd_agg_step(t, ledger, &shapes)?,
                _ => unreachable!("guarded by ensure_remote_supported"),
            };
            loss_sum += out.loss as f64;
            opt.step(&mut params, &out.grads);
            model.set_params(&params);
        }
        let (test_auc, test_acc) = evaluate(&model, test);
        let (up1, down1) = dirs(ledger);
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc,
            test_acc,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            mean_eff_rank: vec![],
        });
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

/// Site training loop (`dad join`): replay the deterministic batch schedule
/// for this site's shard, run one remote site step per batch, and apply the
/// synchronized gradient locally — the replica never diverges from the
/// aggregator's. No evaluation happens on sites (`test_auc`/`test_acc` are
/// NaN in the returned log); the serving process owns reporting.
pub fn join_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    site_id: usize,
) -> io::Result<TrainLog> {
    ensure_remote_supported(spec)?;
    if site_id >= shards.len() {
        return Err(proto(format!("site id {site_id} out of range for {} shards", shards.len())));
    }
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let shard = &shards[site_id];
    let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
    let mut epochs = Vec::with_capacity(spec.epochs);
    for epoch in 0..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let it = &mut plan[site_id];
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        for _ in 0..n_steps {
            let local = it.next().expect("batch iterator exhausted");
            let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
            let batch = data.make_batch(&idx);
            let out = match spec.algo {
                AlgoSpec::Dad => dad_site_step(t, ledger, &model, &batch, &mut ws)?,
                AlgoSpec::Dsgd => dsgd_site_step(t, ledger, &model, &batch, &mut ws)?,
                _ => unreachable!("guarded by ensure_remote_supported"),
            };
            loss_sum += out.loss as f64;
            opt.step(&mut params, &out.grads);
            model.set_params(&params);
        }
        let (up1, down1) = dirs(ledger);
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: f32::NAN,
            test_acc: f32::NAN,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            mean_eff_rank: vec![],
        });
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

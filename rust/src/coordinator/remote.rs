//! Multi-process training over a real transport: the `dad serve` /
//! `dad join` drivers.
//!
//! The simulated trainer (`coordinator::trainer::train`) holds every
//! replica in one process and hands the algorithms a god's-eye view. This
//! module runs the *same* synchronized optimization with the aggregator and
//! each site as separate OS processes exchanging [`crate::dist::wire`]
//! frames over a [`Transport`] (in practice [`crate::dist::TcpAgg`] /
//! [`crate::dist::TcpSite`]).
//!
//! The drivers here are **algorithm-agnostic**: every `DistAlgorithm`
//! exposes its per-step exchange as a [`StepProtocol`] — a state machine of
//! typed rounds (see [`crate::algos::protocol`]) — and [`remote_site_step`]
//! / [`remote_agg_step`] run the shared meta/sync prologue plus whichever
//! rounds the protocol describes. The whole family — `pooled | dsgd | dad |
//! dad-p2p | edad | rank-dad[:r] | powersgd[:r]` — therefore runs under
//! `dad serve` / `dad join`, with `Schedule::Periodic` local phases
//! replayed deterministically in every process. Three invariants tie the
//! two modes together, asserted per algorithm by `tests/transport_e2e.rs`:
//!
//! 1. **Same math.** Both modes funnel through the same per-algorithm
//!    reduction code with sites in canonical id order, so a TCP run
//!    reproduces the loopback run's loss trajectory bit-for-bit.
//! 2. **Same schedule.** Every process reseeds `Rng::new(seed)` and replays
//!    `trainer::epoch_plan` (and the same `step % k` sync decision), so
//!    site i draws the same batches it would in simulation without any
//!    index traffic on the wire.
//! 3. **Same bytes.** Payload frames are encoded by the shared codec and
//!    recorded per (tag, direction), so `dad serve`'s ledger equals
//!    `dad train`'s for the same seed — the acceptance check for the
//!    paper's bandwidth claims holding on a real wire.
//!
//! Control frames (`config`, `step-meta`, `step-sync`, `eff-rank`,
//! `local-loss`) carry protocol metadata and never enter the ledger.
//!
//! # Fault policy and degradation
//!
//! Real deployments lose sites mid-run. The aggregator driver detects a
//! lost site **only at step prologues** (the `step-meta` gather and the
//! off-sync `local-loss` gather), where a link failure
//! ([`crate::dist::is_link_failure`]: timeout, reset, EOF, ...) is
//! attributable to one site and the survivors' state is still consistent.
//! What happens next is the [`FaultPolicy`]'s choice:
//!
//! * **strict** — fail the whole run with a clean `io::Error` naming the
//!   lost site (never a hang, never a panic);
//! * **degrade** (default) — retire the lost links
//!   ([`Transport::retire_site`]) and continue the round with the
//!   survivors, provided the protocol's exchange is shaped purely by the
//!   sync frame ([`StepProtocol::supports_degrade`]) and at least one
//!   site survives. The per-epoch survivor count lands in
//!   [`EpochLog::sites_live`].
//!
//! A failure *inside* an exchange (after the sync broadcast) is never
//! absorbed: the surviving replicas could have applied partial state, so
//! the driver propagates a clean error instead. Stragglers are detected by
//! arming a per-frame receive deadline on the aggregator links
//! (`TcpAgg::set_recv_timeout`) — an armed deadline turns a slow site into
//! the same link-failure path as a dead one.

use std::io;
use std::time::Instant;

use crate::algos::protocol::{expect_ctrl, AggExchange, Endpoint, StepMeta, StepProtocol, StepSync};
use crate::algos::{concat_batches, AlgoSpec};
use crate::checkpoint::{push_mats, read_mats, Checkpoint, CheckpointPlan};
use crate::coordinator::trainer::{
    epoch_plan, evaluate, local_update, snapshot_checkpoint, DataSource, EpochLog, Schedule,
    TrainLog, TrainSpec,
};
use crate::data::{BatchIter, Partition};
use crate::dist::wire::{proto_err, ByteReader, ByteWriter};
use crate::dist::{is_link_failure, Direction, Ledger, Transport};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::LocalStats;
use crate::nn::Adam;
use crate::obs::metrics;
use crate::obs::trace::{self, Phase, StepTiming};
use crate::tensor::{Matrix, Rng, Workspace};

/// Result of one synchronized remote step, as seen from one endpoint.
/// `grads` is identical on every endpoint (the dAD invariant); the byte
/// counters cover only the traffic this endpoint's ledger observed — the
/// aggregator sees everything, a site sees its own uplink plus the shared
/// broadcast. Peer-to-peer traffic (dad-p2p) is folded into `bytes_up`,
/// matching the simulated trainer's reporting.
pub struct RemoteStep {
    /// Batch-size-weighted global mean training loss for the step.
    pub loss: f32,
    /// The synchronized global gradient (aligned with the param list).
    pub grads: Vec<Matrix>,
    /// rank-dAD effective-rank telemetry, `[entry][site]` (aggregator
    /// side only; empty otherwise).
    pub eff_ranks: Vec<Vec<usize>>,
    /// Site->aggregator (+ peer-to-peer) payload bytes recorded locally.
    pub bytes_up: u64,
    /// Aggregator->site payload bytes recorded locally this step.
    pub bytes_down: u64,
    /// Labels of sites retired at this step's prologue (aggregator side,
    /// degrade mode only; empty otherwise).
    pub lost: Vec<String>,
}

/// What the aggregator does when a site stops answering at a step
/// prologue (see the module docs' degradation state machine).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPolicy {
    /// Fail the run on the first lost site — naming it in the error —
    /// instead of degrading to the survivors.
    pub strict: bool,
}

impl FaultPolicy {
    /// The degrade-by-default policy.
    pub fn degrade() -> FaultPolicy {
        FaultPolicy { strict: false }
    }

    /// Fail-fast policy: any lost site aborts the run cleanly.
    pub fn strict() -> FaultPolicy {
        FaultPolicy { strict: true }
    }
}

/// Everything a joining site needs to reconstruct the run: training spec
/// (algorithm, schedule, seed, ...), dataset name, and scale preset.
/// Broadcast once, right after the transport handshake, as the `config`
/// control frame.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// The run's training specification (algorithm, sites, epochs, ...).
    pub spec: TrainSpec,
    /// Dataset name as `trainer::build_task` understands it.
    pub dataset: String,
    /// Scale preset string ("quick" | "default" | "paper").
    pub scale: String,
    /// Per-frame broadcast-read deadline every site arms
    /// (`TcpSite::set_recv_timeout`), in milliseconds; 0 blocks forever.
    /// A dead aggregator then surfaces as a clean timeout on the sites
    /// instead of a wedged process.
    pub recv_timeout_ms: u32,
    /// Partition override every process applies to its shards (from the
    /// shared seed, so the lockstep batch schedule is preserved).
    pub partition: Partition,
    /// True when the aggregator resumes from a checkpoint: immediately
    /// after this config frame it broadcasts one `resume` control frame
    /// ([`ResumeState`]) every site must apply before its first step.
    pub resume: bool,
}

impl RemoteConfig {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_str(&self.spec.algo.name());
        w.push_str(&self.dataset);
        w.push_str(&self.scale);
        w.push_u32(self.spec.n_sites as u32);
        w.push_u32(self.spec.batch_per_site as u32);
        w.push_u32(self.spec.epochs as u32);
        w.push_f32(self.spec.lr);
        w.push_u64(self.spec.seed);
        w.push_u32(self.spec.schedule.sync_every() as u32);
        w.push_u32(self.recv_timeout_ms);
        w.push_str(&self.partition.name());
        w.push_u8(self.resume as u8);
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<RemoteConfig> {
        let mut r = ByteReader::new(body);
        let algo_s = r.read_str()?;
        let dataset = r.read_str()?;
        let scale = r.read_str()?;
        let n_sites = r.read_u32()? as usize;
        let batch_per_site = r.read_u32()? as usize;
        let epochs = r.read_u32()? as usize;
        let lr = r.read_f32()?;
        let seed = r.read_u64()?;
        let sync_every = r.read_u32()? as usize;
        let recv_timeout_ms = r.read_u32()?;
        let partition_s = r.read_str()?;
        let resume = r.read_u8()? != 0;
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "config frame has {} trailing bytes (version skew between serve and join?)",
                r.remaining()
            )));
        }
        let algo = AlgoSpec::parse(&algo_s)
            .map_err(|e| proto_err(format!("bad algo in config frame: {e}")))?;
        let partition = Partition::parse(&partition_s)
            .map_err(|e| proto_err(format!("bad partition in config frame: {e}")))?;
        Ok(RemoteConfig {
            spec: TrainSpec {
                algo,
                n_sites,
                batch_per_site,
                epochs,
                lr,
                seed,
                schedule: Schedule::from_sync_every(sync_every),
            },
            dataset,
            scale,
            recv_timeout_ms,
            partition,
            resume,
        })
    }

    /// Aggregator side: broadcast this config to every connected site.
    pub fn send(&self, t: &mut dyn Transport) -> io::Result<()> {
        t.ship_control(Direction::AggToSite, "config", &self.encode())?;
        Ok(())
    }

    /// Site side: block for the aggregator's config broadcast.
    pub fn recv(t: &mut dyn Transport) -> io::Result<RemoteConfig> {
        let body = expect_ctrl(t.recv_broadcast()?, "config")?;
        RemoteConfig::decode(&body)
    }
}

/// The `resume` control frame a resuming aggregator broadcasts right after
/// the config: everything a site needs to continue the interrupted run in
/// lockstep — canonical parameters, both Adam moment tables and the step
/// counter, the epoch-plan RNG cursor, and the first epoch to execute.
/// Control frames are ledger-exempt by design, so the one-off resume
/// broadcast does not perturb the per-step bandwidth accounting the
/// equivalence tests assert on.
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Canonical model parameters, trainer order.
    pub params: Vec<Matrix>,
    /// Adam first moments, parallel to `params`.
    pub adam_m: Vec<Matrix>,
    /// Adam second moments, parallel to `params`.
    pub adam_v: Vec<Matrix>,
    /// Adam updates applied so far.
    pub adam_t: u64,
    /// Epoch-plan RNG cursor: PCG state word.
    pub rng_state: u64,
    /// Epoch-plan RNG cursor: PCG increment word.
    pub rng_inc: u64,
    /// Epoch-plan RNG cursor: cached Box-Muller spare, if any.
    pub rng_spare: Option<f32>,
    /// First epoch the resumed run executes.
    pub next_epoch: u32,
}

impl ResumeState {
    /// Lift the broadcastable subset out of a loaded checkpoint. The
    /// algorithm compressor state is deliberately absent: remote resume is
    /// limited to algorithms without site-local protocol state
    /// ([`AlgoSpec::remote_resumable`]), whose checkpoints carry none.
    pub fn from_checkpoint(ck: &Checkpoint) -> ResumeState {
        ResumeState {
            params: ck.params.clone(),
            adam_m: ck.adam_m.clone(),
            adam_v: ck.adam_v.clone(),
            adam_t: ck.meta.adam_t,
            rng_state: ck.meta.rng_state,
            rng_inc: ck.meta.rng_inc,
            rng_spare: ck.meta.rng_spare,
            next_epoch: ck.meta.next_epoch,
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        push_mats(&mut w, &self.params);
        push_mats(&mut w, &self.adam_m);
        push_mats(&mut w, &self.adam_v);
        w.push_u64(self.adam_t);
        w.push_u64(self.rng_state);
        w.push_u64(self.rng_inc);
        w.push_u8(self.rng_spare.is_some() as u8);
        w.push_f32(self.rng_spare.unwrap_or(0.0));
        w.push_u32(self.next_epoch);
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<ResumeState> {
        let mut r = ByteReader::new(body);
        let params = read_mats(&mut r)?;
        let adam_m = read_mats(&mut r)?;
        let adam_v = read_mats(&mut r)?;
        let adam_t = r.read_u64()?;
        let rng_state = r.read_u64()?;
        let rng_inc = r.read_u64()?;
        let rng_spare = {
            let has = r.read_u8()? != 0;
            let v = r.read_f32()?;
            has.then_some(v)
        };
        let next_epoch = r.read_u32()?;
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "resume frame has {} trailing bytes (version skew between serve and join?)",
                r.remaining()
            )));
        }
        if adam_m.len() != params.len() || adam_v.len() != params.len() {
            return Err(proto_err(
                "resume frame moment tables are not parallel to the parameter list".into(),
            ));
        }
        Ok(ResumeState { params, adam_m, adam_v, adam_t, rng_state, rng_inc, rng_spare, next_epoch })
    }
}

/// This endpoint's cumulative (up, down) ledger view; peer-to-peer traffic
/// counts as "up" (the exchange has no shared down-link), matching the
/// simulated trainer's `StepOutcome` reporting for dad-p2p.
fn dirs(l: &Ledger) -> (u64, u64) {
    (
        l.total_dir(Direction::SiteToAgg) + l.total_dir(Direction::PeerToPeer),
        l.total_dir(Direction::AggToSite),
    )
}

// ---------------------------------------------------------------------------
// Generic per-step drivers
// ---------------------------------------------------------------------------

/// Site half of one synchronized remote step, for *any* algorithm: compute
/// local statistics, run the meta/sync prologue, then drive the protocol's
/// typed exchange rounds. For the pooled oracle, `batch` must be the union
/// batch (the join driver handles this).
pub fn remote_site_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    batch: &Batch,
    site_id: usize,
    ws: &mut Workspace,
) -> io::Result<RemoteStep> {
    let stats = {
        let _s = trace::phase_span("local-stats", Phase::Compute);
        model.local_stats_ws(batch, ws)
    };
    let (up0, down0) = dirs(ledger);
    let (grads, loss) = {
        let mut ep = Endpoint::new(&mut *t, &mut *ledger);
        ep.ctrl_up("step-meta", &StepMeta::of(&stats).encode())?;
        let sync = StepSync::decode(&ep.ctrl_down("step-sync")?)?;
        let grads = proto.site_exchange(&mut ep, model, &stats, site_id, &sync)?;
        (grads, sync.loss)
    };
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep {
        loss,
        grads,
        eff_ranks: vec![],
        bytes_up: up1 - up0,
        bytes_down: down1 - down0,
        lost: vec![],
    })
}

/// Decide what to do about the sites lost during a prologue gather:
/// nothing (none lost), fail cleanly (strict policy, no survivors, or a
/// protocol whose exchange cannot shrink), or retire the lost links in
/// descending index order and return their labels. Centralizing the
/// decision keeps the `step-meta` and `local-loss` prologues on the same
/// state machine.
fn handle_lost(
    ep: &mut Endpoint<'_>,
    proto_name: &str,
    supports_degrade: bool,
    policy: FaultPolicy,
    survivors: usize,
    lost: Vec<(usize, String, io::Error)>,
) -> io::Result<Vec<String>> {
    if lost.is_empty() {
        return Ok(vec![]);
    }
    let (_, label0, e0) = &lost[0];
    if policy.strict {
        return Err(io::Error::new(
            e0.kind(),
            format!("lost site {label0} ({e0}); strict mode fails the run instead of degrading"),
        ));
    }
    if survivors == 0 {
        return Err(io::Error::new(
            io::ErrorKind::ConnectionAborted,
            format!(
                "every remaining site was lost in the same step (first: site {label0}, {e0})"
            ),
        ));
    }
    if !supports_degrade {
        return Err(io::Error::new(
            e0.kind(),
            format!(
                "lost site {label0} ({e0}), and {proto_name} cannot continue with survivors \
                 (its exchange is shaped by the full site count) — rerun under dad, dsgd, \
                 rank-dad or pooled, or fix the link"
            ),
        ));
    }
    for (site, _, _) in lost.iter().rev() {
        ep.retire_site(*site)?;
    }
    Ok(lost.into_iter().map(|(_, label, _)| label).collect())
}

/// Aggregator half of one synchronized remote step, for *any* algorithm:
/// gather every site's step metadata, broadcast the sync frame (global row
/// count, weighted loss, per-site rows), then drive the protocol's
/// gather/broadcast (or relay) rounds. For the pooled oracle the
/// aggregator runs the *site* half on `oracle_stats` — the union-batch
/// statistics the serve driver computes — since the oracle ships nothing.
///
/// Link failures during the `step-meta` gather are the degradation point:
/// `policy` decides between failing cleanly and retiring the lost sites
/// (see the module docs). Failures after the sync broadcast always
/// propagate — partial exchanges are not recoverable.
pub fn remote_agg_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    oracle_stats: Option<&LocalStats>,
    policy: FaultPolicy,
) -> io::Result<RemoteStep> {
    let (up0, down0) = dirs(ledger);
    let (out, loss, lost) = {
        let mut ep = Endpoint::new(&mut *t, &mut *ledger);
        let n_sites = ep.n_sites();
        let mut metas: Vec<StepMeta> = Vec::with_capacity(n_sites);
        let mut gone: Vec<(usize, String, io::Error)> = Vec::new();
        for site in 0..n_sites {
            match ep.ctrl_from(site, "step-meta") {
                Ok(body) => metas.push(StepMeta::decode(&body)?),
                Err(e) if is_link_failure(&e) => {
                    let label = ep.site_label(site);
                    gone.push((site, label, e));
                }
                Err(e) => return Err(e),
            }
        }
        let lost = handle_lost(
            &mut ep,
            proto.name(),
            proto.supports_degrade(),
            policy,
            metas.len(),
            gone,
        )?;
        let sync = StepSync::from_metas(&metas, proto.oracle())?;
        // Past this point the step is committed: every live site has been
        // promised a sync frame, so a link failure leaves survivors blocked
        // inside the exchange — it must fail the run, never degrade. Tag
        // such errors so operators (and the chaos recipes) can tell a
        // recoverable prologue loss from an unrecoverable mid-step one.
        let mid_exchange = |e: io::Error| {
            if is_link_failure(&e) {
                io::Error::new(
                    e.kind(),
                    format!("link failed mid-exchange (cannot degrade mid-step): {e}"),
                )
            } else {
                e
            }
        };
        ep.ctrl_bcast("step-sync", &sync.encode()).map_err(mid_exchange)?;
        let out = if proto.oracle() {
            let stats = oracle_stats.ok_or_else(|| {
                proto_err(
                    "the pooled oracle needs the aggregator to hold the union batch \
                     (serve_training supplies it)"
                        .into(),
                )
            })?;
            let grads =
                proto.site_exchange(&mut ep, model, stats, 0, &sync).map_err(mid_exchange)?;
            AggExchange { grads, eff_ranks: vec![] }
        } else {
            proto.agg_exchange(&mut ep, model, &metas, &sync).map_err(mid_exchange)?
        };
        (out, sync.loss, lost)
    };
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep {
        loss,
        grads: out.grads,
        eff_ranks: out.eff_ranks,
        bytes_up: up1 - up0,
        bytes_down: down1 - down0,
        lost,
    })
}

// ---------------------------------------------------------------------------
// Full training loops
// ---------------------------------------------------------------------------

/// Validate a spec for multi-process execution. Every algorithm runs
/// remotely, with one schedule restriction: edAD's delta recomputation
/// (eq. 5) uses the *model weights*, and during `Schedule::Periodic`
/// off-sync phases every site's weights drift differently — each endpoint
/// would recompute different aggregated deltas and the replicas would
/// desync silently. The simulated trainer is immune (it recomputes once,
/// on site 0's replica), so the periodic edAD ablation stays available
/// through `dad train`. `dad serve` calls this *before* binding so a bad
/// spec fails on the operator's terminal instead of stranding joins.
pub fn validate_remote(spec: &TrainSpec) -> io::Result<()> {
    if matches!(spec.algo, AlgoSpec::Edad) && spec.schedule != Schedule::EveryBatch {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad over the wire requires --sync-every 1: its delta recomputation depends on \
             model weights, which drift per site during periodic local phases (use `dad train` \
             for the simulated periodic edAD ablation)",
        ));
    }
    Ok(())
}

/// The model-aware half of remote validation: edAD is only runnable on
/// architectures whose `edad_recompute` is defined (the transformer's
/// attention mixes rows, so it is not). Both training loops call this
/// before touching the transport, mirroring [`validate_remote`]'s
/// fail-fast contract — without it the combination would panic (or
/// protocol-error) deep inside the first step.
fn validate_model_algo<M: DistModel>(spec: &TrainSpec, model: &M) -> io::Result<()> {
    if matches!(spec.algo, AlgoSpec::Edad) && !model.supports_edad() {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad is not defined for this architecture (its delta recomputation needs the \
             activation-derivative recurrence, which attention does not admit) — use dad, \
             rank-dad:R or powersgd:R instead",
        ));
    }
    Ok(())
}

/// Assemble one site's batch for this step from its shard and the step's
/// within-shard indices.
fn shard_batch<D: DataSource>(data: &D, shard: &[usize], local: &[usize]) -> Batch {
    let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
    data.make_batch(&idx)
}

/// A site's batch iterator ran dry before the lockstep step count — the
/// processes disagree on the epoch plan (seed, shard or partition
/// mismatch). A clean error instead of a panic: the fail-fast contract of
/// the remote drivers covers bad data layouts too.
fn short_shard(site: usize) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!(
            "site {site}'s batch iterator exhausted before the lockstep step count \
             (seed, shard or partition mismatch between processes)"
        ),
    )
}

/// Assemble the pooled oracle's union batch, drawing every site's batch
/// iterator once in canonical site order (the simulated trainer's exact
/// iterator consumption).
fn union_batch<D: DataSource>(
    data: &D,
    shards: &[Vec<usize>],
    plan: &mut [BatchIter],
) -> io::Result<Batch> {
    let mut batches: Vec<Batch> = Vec::with_capacity(plan.len());
    for (site, (it, shard)) in plan.iter_mut().zip(shards).enumerate() {
        let local = it.next().ok_or_else(|| short_shard(site))?;
        batches.push(shard_batch(data, shard, &local));
    }
    Ok(concat_batches(&batches))
}

/// Aggregator training loop (`dad serve`): drive one remote step per batch
/// through the algorithm's wire protocol, keep a model replica in lockstep
/// for per-epoch evaluation, and log the ledger's per-direction byte
/// deltas per epoch.
///
/// `data`/`shards` are the full deterministic training set and per-site
/// index shards (every process rebuilds them from the seed). The
/// aggregator needs them for two things only: replaying site 0's local
/// updates during `Schedule::Periodic` off-sync phases (so the evaluation
/// replica tracks the simulated trainer's site-0 model exactly) and
/// computing the union batch for the pooled oracle. For every other
/// algorithm no data-derived values are read — statistics arrive over the
/// wire.
///
/// `policy` governs lost sites (module docs): degrade mode retires them
/// and keeps going — the survivor count lands in `EpochLog::sites_live`
/// and each loss is announced on stderr — while strict mode returns a
/// clean error naming the first lost site. In degrade mode with a
/// periodic schedule the off-sync mirror keeps replaying original site
/// 0's batches even if site 0 was lost; the evaluation replica re-enters
/// exact lockstep at the next sync step, which resets it to the canonical
/// Adam trajectory.
pub fn serve_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    model: M,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
    policy: FaultPolicy,
) -> io::Result<TrainLog> {
    serve_training_checkpointed(
        t,
        ledger,
        spec,
        model,
        data,
        shards,
        test,
        policy,
        &CheckpointPlan::default(),
        None,
    )
}

/// Gate shared by checkpoint save *and* resume in remote mode: the v1
/// container freezes only the canonical (aggregator-side) state, so it is
/// sound exactly when no training state lives outside it — every replica
/// on the canonical parameters (`--sync-every 1`) and no site-local
/// compressor state ([`AlgoSpec::remote_resumable`]).
fn validate_remote_checkpoint(spec: &TrainSpec) -> io::Result<()> {
    if spec.schedule != Schedule::EveryBatch {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "remote checkpointing requires --sync-every 1: periodic local phases leave each \
             site's replica drifted off the canonical parameters, state the checkpoint does \
             not carry",
        ));
    }
    if !spec.algo.remote_resumable() {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            format!(
                "{} keeps per-site compressor state (error feedback / warm starts) inside each \
                 join process, which an aggregator-side checkpoint cannot capture — remote \
                 checkpoint/resume supports the stateless algorithms (pooled, dsgd, dad, \
                 dad-p2p, edad, rank-dad); use `dad train` for checkpointed {} runs",
                spec.algo.name(),
                spec.algo.name()
            ),
        ));
    }
    Ok(())
}

/// [`serve_training`] plus checkpoint save/resume (the `dad serve
/// --checkpoint/--resume` path). Saving freezes the canonical state at the
/// epoch boundaries `ckpt` selects, exactly as the simulated trainer
/// would — the two modes produce byte-identical checkpoint files for the
/// same trajectory. Resuming broadcasts a `resume` control frame right
/// after the config so every site restores the same cursors before its
/// first step; `tests/remote_resume.rs` asserts the continuation matches
/// the uninterrupted TCP run bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn serve_training_checkpointed<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
    policy: FaultPolicy,
    ckpt: &CheckpointPlan,
    resume: Option<Checkpoint>,
) -> io::Result<TrainLog> {
    validate_remote(spec)?;
    validate_model_algo(spec, &model)?;
    if ckpt.enabled() || resume.is_some() {
        validate_remote_checkpoint(spec)?;
    }
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let n_entries = model.local_stats_entry_count();
    let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();

    let mut start_epoch = 0usize;
    let mut meta_dataset = ckpt.dataset.clone();
    let mut meta_scale = ckpt.scale.clone();
    if let Some(ck) = resume {
        ck.meta.check_resume(
            &spec.algo.name(),
            spec.n_sites as u32,
            spec.batch_per_site as u32,
            spec.epochs as u32,
            spec.lr,
            spec.seed,
            spec.schedule.sync_every() as u32,
        )?;
        let fits = |mats: &[Matrix]| {
            mats.len() == shapes.len()
                && mats.iter().zip(&shapes).all(|(m, &(r, c))| m.rows() == r && m.cols() == c)
        };
        if !fits(&ck.params) || !fits(&ck.adam_m) || !fits(&ck.adam_v) {
            return Err(proto_err(format!(
                "checkpoint does not fit this model: expected {} parameter/moment matrices \
                 shaped {:?}",
                shapes.len(),
                shapes
            )));
        }
        // One ledger-exempt broadcast restores every site; must precede the
        // first step so the whole cluster enters epoch `next_epoch` as one.
        let rs = ResumeState::from_checkpoint(&ck);
        t.ship_control(Direction::AggToSite, "resume", &rs.encode())?;
        params = ck.params;
        model.set_params(&params);
        opt = Adam::from_state(spec.lr, ck.meta.adam_t, ck.adam_m, ck.adam_v);
        rng = ck.meta.restore_rng();
        start_epoch = ck.meta.next_epoch as usize;
        meta_dataset = ck.meta.dataset;
        meta_scale = ck.meta.scale;
    }

    let mut epochs = Vec::with_capacity(spec.epochs.saturating_sub(start_epoch));
    let mut global_step = 0u64;
    for epoch in start_epoch..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        let mut rank_sums = vec![0.0f64; n_entries];
        let mut rank_count = 0usize;
        let mut timing = StepTiming::default();
        let _ = trace::take_step_timing(); // discard pre-epoch residue
        for step in 0..n_steps {
            let step_t0 = Instant::now();
            // Iterator discipline: the oracle draws every site's iterator
            // (it trains the union batch); otherwise only site 0's is
            // drawn — each `BatchIter` is self-contained, so skipping the
            // others cannot desync anything, and site 0's draw must happen
            // every step so periodic local phases see the step-t batch.
            let (union_stats, local0) = if oracle {
                let union = union_batch(data, shards, &mut plan)?;
                let stats = {
                    let _s = trace::phase_span("local-stats", Phase::Compute);
                    model.local_stats_ws(&union, &mut ws)
                };
                (Some(stats), None)
            } else {
                (None, Some(plan[0].next().ok_or_else(|| short_shard(0))?))
            };
            if oracle || spec.schedule.is_sync_step(step) {
                let out = remote_agg_step(
                    proto.as_mut(),
                    &mut *t,
                    &mut *ledger,
                    &model,
                    union_stats.as_ref(),
                    policy,
                )?;
                for label in &out.lost {
                    eprintln!(
                        "[degrade] lost site {label}; continuing with {} site(s)",
                        t.n_sites()
                    );
                }
                loss_sum += out.loss as f64;
                if !out.eff_ranks.is_empty() {
                    for (ei, per_site) in out.eff_ranks.iter().enumerate() {
                        let mean: f64 = per_site.iter().map(|&r| r as f64).sum::<f64>()
                            / per_site.len() as f64;
                        rank_sums[ei] += mean;
                    }
                    rank_count += 1;
                }
                opt.step(&mut params, &out.grads);
                model.set_params(&params);
            } else {
                // Off-sync phase: no payload traffic. Mirror site 0's local
                // update so the evaluation replica matches the simulated
                // trainer's site-0 model, and average the sites' reported
                // local losses (tiny ledger-exempt control frames). The
                // loss gather is a prologue too: a link failure here goes
                // through the same degrade-or-fail decision as `step-meta`.
                let local0 = local0.ok_or_else(|| {
                    proto_err("internal invariant broken: non-oracle step must draw site 0".into())
                })?;
                let batch = shard_batch(data, &shards[0], &local0);
                local_update(&mut model, &batch, &shapes, spec.lr, &mut ws);
                let (mean_loss, retired) = {
                    let mut ep = Endpoint::new(&mut *t, &mut *ledger);
                    let n_live = ep.n_sites();
                    let mut loss = 0.0f32;
                    let mut gathered = 0usize;
                    let mut gone: Vec<(usize, String, io::Error)> = Vec::new();
                    for site in 0..n_live {
                        match ep.ctrl_from(site, "local-loss") {
                            Ok(body) => {
                                loss += ByteReader::new(&body).read_f32()?;
                                gathered += 1;
                            }
                            Err(e) if is_link_failure(&e) => {
                                let label = ep.site_label(site);
                                gone.push((site, label, e));
                            }
                            Err(e) => return Err(e),
                        }
                    }
                    let retired = handle_lost(
                        &mut ep,
                        proto.name(),
                        proto.supports_degrade(),
                        policy,
                        gathered,
                        gone,
                    )?;
                    (loss / gathered.max(1) as f32, retired)
                };
                for label in &retired {
                    eprintln!(
                        "[degrade] lost site {label} in a local phase; continuing with {} site(s)",
                        t.n_sites()
                    );
                }
                loss_sum += mean_loss as f64;
            }
            timing.accumulate(&trace::take_step_timing());
            global_step += 1;
            metrics::STEP.set(global_step);
            metrics::SITES_LIVE.set(t.n_sites() as u64);
            let (up_now, down_now) = dirs(ledger);
            metrics::record_bytes(up_now, down_now);
            metrics::STEP_LATENCY.observe(step_t0.elapsed().as_secs_f64());
        }
        let eval = evaluate(&model, test);
        let (up1, down1) = dirs(ledger);
        let mean_eff_rank: Vec<f32> = rank_sums
            .iter()
            .map(|&s| if rank_count == 0 { f32::NAN } else { (s / rank_count as f64) as f32 })
            .collect();
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: eval.auc,
            test_acc: eval.acc,
            test_ppl: eval.ppl,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            sites_live: t.n_sites(),
            timing,
            mean_eff_rank,
        });
        if trace::enabled() {
            let _ = trace::flush();
        }
        if ckpt.due(epoch + 1, spec.epochs) {
            let path = ckpt.save_path.as_ref().expect("due implies a save path");
            // Remote-resumable algorithms are stateless by construction
            // (validated above), so the compressor-state frame is empty —
            // matching what the simulated trainer writes for them.
            let ck = snapshot_checkpoint(
                spec,
                &meta_dataset,
                &meta_scale,
                epoch + 1,
                &params,
                &opt,
                &rng,
                vec![],
            );
            ck.save(std::path::Path::new(path))?;
        }
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

/// Site training loop (`dad join`): replay the deterministic batch
/// schedule for this site's shard, run one remote step per batch through
/// the algorithm's wire protocol, and apply the synchronized gradient
/// locally — the replica never diverges from the aggregator's. During
/// `Schedule::Periodic` off-sync phases the site applies its own local
/// update (identical math to the simulated trainer) and ships only its
/// loss as a ledger-exempt control frame. No evaluation happens on sites
/// (`test_auc`/`test_acc` are NaN in the returned log); the serving
/// process owns reporting.
pub fn join_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    model: M,
    data: &D,
    shards: &[Vec<usize>],
    site_id: usize,
) -> io::Result<TrainLog> {
    join_training_resumable(t, ledger, spec, model, data, shards, site_id, false)
}

/// [`join_training`] for a run whose config frame announced a resume
/// (`RemoteConfig::resume`): before the first step the site blocks for the
/// aggregator's `resume` broadcast and restores the shared cursors from
/// it, entering epoch `next_epoch` in lockstep with everyone else.
#[allow(clippy::too_many_arguments)]
pub fn join_training_resumable<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    site_id: usize,
    resume: bool,
) -> io::Result<TrainLog> {
    validate_remote(spec)?;
    validate_model_algo(spec, &model)?;
    if site_id >= shards.len() {
        return Err(proto_err(format!(
            "site id {site_id} out of range for {} shards",
            shards.len()
        )));
    }
    if resume {
        validate_remote_checkpoint(spec)?;
    }
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();

    let mut start_epoch = 0usize;
    if resume {
        let rs = ResumeState::decode(&expect_ctrl(t.recv_broadcast()?, "resume")?)?;
        let fits = |mats: &[Matrix]| {
            mats.len() == shapes.len()
                && mats.iter().zip(&shapes).all(|(m, &(r, c))| m.rows() == r && m.cols() == c)
        };
        if !fits(&rs.params) || !fits(&rs.adam_m) || !fits(&rs.adam_v) {
            return Err(proto_err(format!(
                "resume frame does not fit this model: expected {} parameter/moment matrices \
                 shaped {:?} (dataset/scale mismatch between serve and join?)",
                shapes.len(),
                shapes
            )));
        }
        params = rs.params;
        model.set_params(&params);
        opt = Adam::from_state(spec.lr, rs.adam_t, rs.adam_m, rs.adam_v);
        rng = Rng::from_parts(rs.rng_state, rs.rng_inc, rs.rng_spare);
        start_epoch = rs.next_epoch as usize;
        if start_epoch >= spec.epochs {
            return Err(proto_err(format!(
                "resume frame says epoch {start_epoch} of a {} epoch run: nothing to do",
                spec.epochs
            )));
        }
    }

    let mut epochs = Vec::with_capacity(spec.epochs.saturating_sub(start_epoch));
    let mut global_step = 0u64;
    for epoch in start_epoch..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        let mut timing = StepTiming::default();
        let _ = trace::take_step_timing(); // discard pre-epoch residue
        for step in 0..n_steps {
            let step_t0 = Instant::now();
            let batch = if oracle {
                // The pooled oracle trains the union batch in every process.
                union_batch(data, shards, &mut plan)?
            } else {
                let local = plan[site_id].next().ok_or_else(|| short_shard(site_id))?;
                shard_batch(data, &shards[site_id], &local)
            };
            if oracle || spec.schedule.is_sync_step(step) {
                let out = remote_site_step(
                    proto.as_mut(),
                    &mut *t,
                    &mut *ledger,
                    &model,
                    &batch,
                    site_id,
                    &mut ws,
                )?;
                loss_sum += out.loss as f64;
                opt.step(&mut params, &out.grads);
                model.set_params(&params);
            } else {
                let loss = local_update(&mut model, &batch, &shapes, spec.lr, &mut ws);
                let mut w = ByteWriter::new();
                w.push_f32(loss);
                Endpoint::new(&mut *t, &mut *ledger).ctrl_up("local-loss", &w.finish())?;
                loss_sum += loss as f64;
            }
            timing.accumulate(&trace::take_step_timing());
            global_step += 1;
            metrics::STEP.set(global_step);
            metrics::SITES_LIVE.set(t.n_sites() as u64);
            let (up_now, down_now) = dirs(ledger);
            metrics::record_bytes(up_now, down_now);
            metrics::STEP_LATENCY.observe(step_t0.elapsed().as_secs_f64());
        }
        let (up1, down1) = dirs(ledger);
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: f32::NAN,
            test_acc: f32::NAN,
            test_ppl: f32::NAN,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            // Sites do not observe peer retirements; the serving process
            // owns degraded-run reporting.
            sites_live: spec.n_sites,
            timing,
            mean_eff_rank: vec![],
        });
        if trace::enabled() {
            let _ = trace::flush();
        }
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

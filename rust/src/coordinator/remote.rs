//! Multi-process training over a real transport: the `dad serve` /
//! `dad join` drivers.
//!
//! The simulated trainer (`coordinator::trainer::train`) holds every
//! replica in one process and hands the algorithms a god's-eye view. This
//! module runs the *same* synchronized optimization with the aggregator and
//! each site as separate OS processes exchanging [`crate::dist::wire`]
//! frames over a [`Transport`] (in practice [`crate::dist::TcpAgg`] /
//! [`crate::dist::TcpSite`]).
//!
//! The drivers here are **algorithm-agnostic**: every `DistAlgorithm`
//! exposes its per-step exchange as a [`StepProtocol`] — a state machine of
//! typed rounds (see [`crate::algos::protocol`]) — and [`remote_site_step`]
//! / [`remote_agg_step`] run the shared meta/sync prologue plus whichever
//! rounds the protocol describes. The whole family — `pooled | dsgd | dad |
//! dad-p2p | edad | rank-dad[:r] | powersgd[:r]` — therefore runs under
//! `dad serve` / `dad join`, with `Schedule::Periodic` local phases
//! replayed deterministically in every process. Three invariants tie the
//! two modes together, asserted per algorithm by `tests/transport_e2e.rs`:
//!
//! 1. **Same math.** Both modes funnel through the same per-algorithm
//!    reduction code with sites in canonical id order, so a TCP run
//!    reproduces the loopback run's loss trajectory bit-for-bit.
//! 2. **Same schedule.** Every process reseeds `Rng::new(seed)` and replays
//!    `trainer::epoch_plan` (and the same `step % k` sync decision), so
//!    site i draws the same batches it would in simulation without any
//!    index traffic on the wire.
//! 3. **Same bytes.** Payload frames are encoded by the shared codec and
//!    recorded per (tag, direction), so `dad serve`'s ledger equals
//!    `dad train`'s for the same seed — the acceptance check for the
//!    paper's bandwidth claims holding on a real wire.
//!
//! Control frames (`config`, `step-meta`, `step-sync`, `eff-rank`,
//! `local-loss`) carry protocol metadata and never enter the ledger.

use std::io;

use crate::algos::protocol::{expect_ctrl, AggExchange, Endpoint, StepMeta, StepProtocol, StepSync};
use crate::algos::{concat_batches, AlgoSpec};
use crate::coordinator::trainer::{
    epoch_plan, evaluate, local_update, DataSource, EpochLog, Schedule, TrainLog, TrainSpec,
};
use crate::data::BatchIter;
use crate::dist::wire::{proto_err, ByteReader, ByteWriter};
use crate::dist::{Direction, Ledger, Transport};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::LocalStats;
use crate::nn::Adam;
use crate::tensor::{Matrix, Rng, Workspace};

/// Result of one synchronized remote step, as seen from one endpoint.
/// `grads` is identical on every endpoint (the dAD invariant); the byte
/// counters cover only the traffic this endpoint's ledger observed — the
/// aggregator sees everything, a site sees its own uplink plus the shared
/// broadcast. Peer-to-peer traffic (dad-p2p) is folded into `bytes_up`,
/// matching the simulated trainer's reporting.
pub struct RemoteStep {
    /// Batch-size-weighted global mean training loss for the step.
    pub loss: f32,
    /// The synchronized global gradient (aligned with the param list).
    pub grads: Vec<Matrix>,
    /// rank-dAD effective-rank telemetry, `[entry][site]` (aggregator
    /// side only; empty otherwise).
    pub eff_ranks: Vec<Vec<usize>>,
    /// Site->aggregator (+ peer-to-peer) payload bytes recorded locally.
    pub bytes_up: u64,
    /// Aggregator->site payload bytes recorded locally this step.
    pub bytes_down: u64,
}

/// Everything a joining site needs to reconstruct the run: training spec
/// (algorithm, schedule, seed, ...), dataset name, and scale preset.
/// Broadcast once, right after the transport handshake, as the `config`
/// control frame.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// The run's training specification (algorithm, sites, epochs, ...).
    pub spec: TrainSpec,
    /// Dataset name as `trainer::build_task` understands it.
    pub dataset: String,
    /// Scale preset string ("quick" | "default" | "paper").
    pub scale: String,
}

impl RemoteConfig {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_str(&self.spec.algo.name());
        w.push_str(&self.dataset);
        w.push_str(&self.scale);
        w.push_u32(self.spec.n_sites as u32);
        w.push_u32(self.spec.batch_per_site as u32);
        w.push_u32(self.spec.epochs as u32);
        w.push_f32(self.spec.lr);
        w.push_u64(self.spec.seed);
        w.push_u32(self.spec.schedule.sync_every() as u32);
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<RemoteConfig> {
        let mut r = ByteReader::new(body);
        let algo_s = r.read_str()?;
        let dataset = r.read_str()?;
        let scale = r.read_str()?;
        let n_sites = r.read_u32()? as usize;
        let batch_per_site = r.read_u32()? as usize;
        let epochs = r.read_u32()? as usize;
        let lr = r.read_f32()?;
        let seed = r.read_u64()?;
        let sync_every = r.read_u32()? as usize;
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "config frame has {} trailing bytes (version skew between serve and join?)",
                r.remaining()
            )));
        }
        let algo = AlgoSpec::parse(&algo_s)
            .map_err(|e| proto_err(format!("bad algo in config frame: {e}")))?;
        Ok(RemoteConfig {
            spec: TrainSpec {
                algo,
                n_sites,
                batch_per_site,
                epochs,
                lr,
                seed,
                schedule: Schedule::from_sync_every(sync_every),
            },
            dataset,
            scale,
        })
    }

    /// Aggregator side: broadcast this config to every connected site.
    pub fn send(&self, t: &mut dyn Transport) -> io::Result<()> {
        t.ship_control(Direction::AggToSite, "config", &self.encode())?;
        Ok(())
    }

    /// Site side: block for the aggregator's config broadcast.
    pub fn recv(t: &mut dyn Transport) -> io::Result<RemoteConfig> {
        let body = expect_ctrl(t.recv_broadcast()?, "config")?;
        RemoteConfig::decode(&body)
    }
}

/// This endpoint's cumulative (up, down) ledger view; peer-to-peer traffic
/// counts as "up" (the exchange has no shared down-link), matching the
/// simulated trainer's `StepOutcome` reporting for dad-p2p.
fn dirs(l: &Ledger) -> (u64, u64) {
    (
        l.total_dir(Direction::SiteToAgg) + l.total_dir(Direction::PeerToPeer),
        l.total_dir(Direction::AggToSite),
    )
}

// ---------------------------------------------------------------------------
// Generic per-step drivers
// ---------------------------------------------------------------------------

/// Site half of one synchronized remote step, for *any* algorithm: compute
/// local statistics, run the meta/sync prologue, then drive the protocol's
/// typed exchange rounds. For the pooled oracle, `batch` must be the union
/// batch (the join driver handles this).
pub fn remote_site_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    batch: &Batch,
    site_id: usize,
    ws: &mut Workspace,
) -> io::Result<RemoteStep> {
    let stats = model.local_stats_ws(batch, ws);
    let (up0, down0) = dirs(ledger);
    let (grads, loss) = {
        let mut ep = Endpoint::new(&mut *t, &mut *ledger);
        ep.ctrl_up("step-meta", &StepMeta::of(&stats).encode())?;
        let sync = StepSync::decode(&ep.ctrl_down("step-sync")?)?;
        let grads = proto.site_exchange(&mut ep, model, &stats, site_id, &sync)?;
        (grads, sync.loss)
    };
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep {
        loss,
        grads,
        eff_ranks: vec![],
        bytes_up: up1 - up0,
        bytes_down: down1 - down0,
    })
}

/// Aggregator half of one synchronized remote step, for *any* algorithm:
/// gather every site's step metadata, broadcast the sync frame (global row
/// count, weighted loss, per-site rows), then drive the protocol's
/// gather/broadcast (or relay) rounds. For the pooled oracle the
/// aggregator runs the *site* half on `oracle_stats` — the union-batch
/// statistics the serve driver computes — since the oracle ships nothing.
pub fn remote_agg_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    oracle_stats: Option<&LocalStats>,
) -> io::Result<RemoteStep> {
    let n_sites = t.n_sites();
    let (up0, down0) = dirs(ledger);
    let (out, loss) = {
        let mut ep = Endpoint::new(&mut *t, &mut *ledger);
        let mut metas: Vec<StepMeta> = Vec::with_capacity(n_sites);
        for site in 0..n_sites {
            metas.push(StepMeta::decode(&ep.ctrl_from(site, "step-meta")?)?);
        }
        let sync = StepSync::from_metas(&metas, proto.oracle())?;
        ep.ctrl_bcast("step-sync", &sync.encode())?;
        let out = if proto.oracle() {
            let stats = oracle_stats.ok_or_else(|| {
                proto_err(
                    "the pooled oracle needs the aggregator to hold the union batch \
                     (serve_training supplies it)"
                        .into(),
                )
            })?;
            let grads = proto.site_exchange(&mut ep, model, stats, 0, &sync)?;
            AggExchange { grads, eff_ranks: vec![] }
        } else {
            proto.agg_exchange(&mut ep, model, &metas, &sync)?
        };
        (out, sync.loss)
    };
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep {
        loss,
        grads: out.grads,
        eff_ranks: out.eff_ranks,
        bytes_up: up1 - up0,
        bytes_down: down1 - down0,
    })
}

// ---------------------------------------------------------------------------
// Full training loops
// ---------------------------------------------------------------------------

/// Validate a spec for multi-process execution. Every algorithm runs
/// remotely, with one schedule restriction: edAD's delta recomputation
/// (eq. 5) uses the *model weights*, and during `Schedule::Periodic`
/// off-sync phases every site's weights drift differently — each endpoint
/// would recompute different aggregated deltas and the replicas would
/// desync silently. The simulated trainer is immune (it recomputes once,
/// on site 0's replica), so the periodic edAD ablation stays available
/// through `dad train`. `dad serve` calls this *before* binding so a bad
/// spec fails on the operator's terminal instead of stranding joins.
pub fn validate_remote(spec: &TrainSpec) -> io::Result<()> {
    if matches!(spec.algo, AlgoSpec::Edad) && spec.schedule != Schedule::EveryBatch {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad over the wire requires --sync-every 1: its delta recomputation depends on \
             model weights, which drift per site during periodic local phases (use `dad train` \
             for the simulated periodic edAD ablation)",
        ));
    }
    Ok(())
}

/// The model-aware half of remote validation: edAD is only runnable on
/// architectures whose `edad_recompute` is defined (the transformer's
/// attention mixes rows, so it is not). Both training loops call this
/// before touching the transport, mirroring [`validate_remote`]'s
/// fail-fast contract — without it the combination would panic (or
/// protocol-error) deep inside the first step.
fn validate_model_algo<M: DistModel>(spec: &TrainSpec, model: &M) -> io::Result<()> {
    if matches!(spec.algo, AlgoSpec::Edad) && !model.supports_edad() {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad is not defined for this architecture (its delta recomputation needs the \
             activation-derivative recurrence, which attention does not admit) — use dad, \
             rank-dad:R or powersgd:R instead",
        ));
    }
    Ok(())
}

/// Assemble one site's batch for this step from its shard and the step's
/// within-shard indices.
fn shard_batch<D: DataSource>(data: &D, shard: &[usize], local: &[usize]) -> Batch {
    let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
    data.make_batch(&idx)
}

/// Assemble the pooled oracle's union batch, drawing every site's batch
/// iterator once in canonical site order (the simulated trainer's exact
/// iterator consumption).
fn union_batch<D: DataSource>(data: &D, shards: &[Vec<usize>], plan: &mut [BatchIter]) -> Batch {
    let batches: Vec<Batch> = plan
        .iter_mut()
        .zip(shards)
        .map(|(it, shard)| {
            let local = it.next().expect("batch iterator exhausted");
            shard_batch(data, shard, &local)
        })
        .collect();
    concat_batches(&batches)
}

/// Aggregator training loop (`dad serve`): drive one remote step per batch
/// through the algorithm's wire protocol, keep a model replica in lockstep
/// for per-epoch evaluation, and log the ledger's per-direction byte
/// deltas per epoch.
///
/// `data`/`shards` are the full deterministic training set and per-site
/// index shards (every process rebuilds them from the seed). The
/// aggregator needs them for two things only: replaying site 0's local
/// updates during `Schedule::Periodic` off-sync phases (so the evaluation
/// replica tracks the simulated trainer's site-0 model exactly) and
/// computing the union batch for the pooled oracle. For every other
/// algorithm no data-derived values are read — statistics arrive over the
/// wire.
pub fn serve_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
) -> io::Result<TrainLog> {
    validate_remote(spec)?;
    validate_model_algo(spec, &model)?;
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let n_entries = model.local_stats_entry_count();
    let n_sites = t.n_sites();
    let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
    let mut epochs = Vec::with_capacity(spec.epochs);
    for epoch in 0..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        let mut rank_sums = vec![0.0f64; n_entries];
        let mut rank_count = 0usize;
        for step in 0..n_steps {
            // Iterator discipline: the oracle draws every site's iterator
            // (it trains the union batch); otherwise only site 0's is
            // drawn — each `BatchIter` is self-contained, so skipping the
            // others cannot desync anything, and site 0's draw must happen
            // every step so periodic local phases see the step-t batch.
            let (union_stats, local0) = if oracle {
                let stats = model.local_stats_ws(&union_batch(data, shards, &mut plan), &mut ws);
                (Some(stats), None)
            } else {
                (None, Some(plan[0].next().expect("batch iterator exhausted")))
            };
            if oracle || spec.schedule.is_sync_step(step) {
                let out = remote_agg_step(
                    proto.as_mut(),
                    &mut *t,
                    &mut *ledger,
                    &model,
                    union_stats.as_ref(),
                )?;
                loss_sum += out.loss as f64;
                if !out.eff_ranks.is_empty() {
                    for (ei, per_site) in out.eff_ranks.iter().enumerate() {
                        let mean: f64 = per_site.iter().map(|&r| r as f64).sum::<f64>()
                            / per_site.len() as f64;
                        rank_sums[ei] += mean;
                    }
                    rank_count += 1;
                }
                opt.step(&mut params, &out.grads);
                model.set_params(&params);
            } else {
                // Off-sync phase: no payload traffic. Mirror site 0's local
                // update so the evaluation replica matches the simulated
                // trainer's site-0 model, and average the sites' reported
                // local losses (tiny ledger-exempt control frames).
                let local0 = local0.expect("non-oracle step draws site 0");
                let batch = shard_batch(data, &shards[0], &local0);
                local_update(&mut model, &batch, &shapes, spec.lr, &mut ws);
                let mut ep = Endpoint::new(&mut *t, &mut *ledger);
                let mut loss = 0.0f32;
                for site in 0..n_sites {
                    let body = ep.ctrl_from(site, "local-loss")?;
                    loss += ByteReader::new(&body).read_f32()?;
                }
                loss_sum += (loss / n_sites as f32) as f64;
            }
        }
        let eval = evaluate(&model, test);
        let (up1, down1) = dirs(ledger);
        let mean_eff_rank: Vec<f32> = rank_sums
            .iter()
            .map(|&s| if rank_count == 0 { f32::NAN } else { (s / rank_count as f64) as f32 })
            .collect();
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: eval.auc,
            test_acc: eval.acc,
            test_ppl: eval.ppl,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            mean_eff_rank,
        });
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

/// Site training loop (`dad join`): replay the deterministic batch
/// schedule for this site's shard, run one remote step per batch through
/// the algorithm's wire protocol, and apply the synchronized gradient
/// locally — the replica never diverges from the aggregator's. During
/// `Schedule::Periodic` off-sync phases the site applies its own local
/// update (identical math to the simulated trainer) and ships only its
/// loss as a ledger-exempt control frame. No evaluation happens on sites
/// (`test_auc`/`test_acc` are NaN in the returned log); the serving
/// process owns reporting.
pub fn join_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    site_id: usize,
) -> io::Result<TrainLog> {
    validate_remote(spec)?;
    validate_model_algo(spec, &model)?;
    if site_id >= shards.len() {
        return Err(proto_err(format!(
            "site id {site_id} out of range for {} shards",
            shards.len()
        )));
    }
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
    let mut epochs = Vec::with_capacity(spec.epochs);
    for epoch in 0..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        for step in 0..n_steps {
            let batch = if oracle {
                // The pooled oracle trains the union batch in every process.
                union_batch(data, shards, &mut plan)
            } else {
                let local = plan[site_id].next().expect("batch iterator exhausted");
                shard_batch(data, &shards[site_id], &local)
            };
            if oracle || spec.schedule.is_sync_step(step) {
                let out = remote_site_step(
                    proto.as_mut(),
                    &mut *t,
                    &mut *ledger,
                    &model,
                    &batch,
                    site_id,
                    &mut ws,
                )?;
                loss_sum += out.loss as f64;
                opt.step(&mut params, &out.grads);
                model.set_params(&params);
            } else {
                let loss = local_update(&mut model, &batch, &shapes, spec.lr, &mut ws);
                let mut w = ByteWriter::new();
                w.push_f32(loss);
                Endpoint::new(&mut *t, &mut *ledger).ctrl_up("local-loss", &w.finish())?;
                loss_sum += loss as f64;
            }
        }
        let (up1, down1) = dirs(ledger);
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: f32::NAN,
            test_acc: f32::NAN,
            test_ppl: f32::NAN,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            mean_eff_rank: vec![],
        });
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

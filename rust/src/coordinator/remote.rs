//! Multi-process training over a real transport: the `dad serve` /
//! `dad join` drivers.
//!
//! The simulated trainer (`coordinator::trainer::train`) holds every
//! replica in one process and hands the algorithms a god's-eye view. This
//! module runs the *same* synchronized optimization with the aggregator and
//! each site as separate OS processes exchanging [`crate::dist::wire`]
//! frames over a [`Transport`] (in practice [`crate::dist::TcpAgg`] /
//! [`crate::dist::TcpSite`]).
//!
//! The drivers here are **algorithm-agnostic**: every `DistAlgorithm`
//! exposes its per-step exchange as a [`StepProtocol`] — a state machine of
//! typed rounds (see [`crate::algos::protocol`]) — and [`remote_site_step`]
//! / [`remote_agg_step`] run the shared meta/sync prologue plus whichever
//! rounds the protocol describes. The whole family — `pooled | dsgd | dad |
//! dad-p2p | edad | rank-dad[:r] | powersgd[:r]` — therefore runs under
//! `dad serve` / `dad join`, with `Schedule::Periodic` local phases
//! replayed deterministically in every process. Three invariants tie the
//! two modes together, asserted per algorithm by `tests/transport_e2e.rs`:
//!
//! 1. **Same math.** Both modes funnel through the same per-algorithm
//!    reduction code with sites in canonical id order, so a TCP run
//!    reproduces the loopback run's loss trajectory bit-for-bit.
//! 2. **Same schedule.** Every process reseeds `Rng::new(seed)` and replays
//!    `trainer::epoch_plan` (and the same `step % k` sync decision), so
//!    site i draws the same batches it would in simulation without any
//!    index traffic on the wire.
//! 3. **Same bytes.** Payload frames are encoded by the shared codec and
//!    recorded per (tag, direction), so `dad serve`'s ledger equals
//!    `dad train`'s for the same seed — the acceptance check for the
//!    paper's bandwidth claims holding on a real wire.
//!
//! Control frames (`config`, `step-meta`, `step-sync`, `eff-rank`,
//! `local-loss`) carry protocol metadata and never enter the ledger.
//!
//! # Fault policy and degradation
//!
//! Real deployments lose sites mid-run. The aggregator driver detects a
//! lost site **only at step prologues** (the `step-meta` gather and the
//! off-sync `local-loss` gather), where a link failure
//! ([`crate::dist::is_link_failure`]: timeout, reset, EOF, ...) is
//! attributable to one site and the survivors' state is still consistent.
//! What happens next is the [`FaultPolicy`]'s choice:
//!
//! * **strict** — fail the whole run with a clean `io::Error` naming the
//!   lost site (never a hang, never a panic);
//! * **degrade** (default) — retire the lost links
//!   ([`Transport::retire_site`]) and continue the round with the
//!   survivors, provided the protocol's exchange is shaped purely by the
//!   sync frame ([`StepProtocol::supports_degrade`]) and at least one
//!   site survives. The per-epoch survivor count lands in
//!   [`EpochLog::sites_live`].
//!
//! A failure *inside* an exchange (after the sync broadcast) is never
//! absorbed: the surviving replicas could have applied partial state, so
//! the driver propagates a clean error instead. Stragglers are detected by
//! arming a per-frame receive deadline on the aggregator links
//! (`TcpAgg::set_recv_timeout`) — an armed deadline turns a slow site into
//! the same link-failure path as a dead one.
//!
//! # Tree topologies
//!
//! The aggregator half is written against *links*, not sites: every link
//! covers a contiguous leaf range declared at the transport handshake
//! ([`Transport::link_leaves`]), and the gather primitives combine each
//! link's pre-reduced partials in the canonical segment bracketing
//! (`crate::algos::reduce`), so a multi-level tree of [`relay_training`]
//! sub-aggregators produces bit-identical gradients, losses and
//! per-(tag, direction) ledger bytes to the flat star and the loopback
//! simulation. A relay needs no per-algorithm code: it executes the
//! [`StepProtocol::plan`] rounds generically — gather + associative
//! combine + re-ship for up rounds, verbatim forwarding for down rounds.
//! Algorithms whose exchange is not an associative reduction (edAD's
//! weight-coupled recomputation, dad-p2p's mesh) are rejected by name up
//! front ([`validate_remote_topology`]).
//!
//! # Elastic membership
//!
//! Leaving is the degradation path above: a lost subtree is retired in
//! place and the survivors keep their shards, preserving every degraded
//! trajectory. Joining is root-gated and happens only at epoch
//! boundaries: the root polls for queued dials
//! ([`Transport::admit_joiners`]), hands each admitted leaf its config
//! (with [`ResumeMode::Elastic`]), and broadcasts an `epoch-sync` frame —
//! the membership roll-call every process consumes at every non-final
//! boundary. When a join happened, the roll-call announces a re-shard and
//! is followed by a full [`ResumeState`] broadcast; every process then
//! recomputes the same round-robin shard assignment ([`reshard_indices`])
//! so the next epoch's plan is drawn identically everywhere. All of this
//! traffic is ledger-exempt control framing.

use std::io;
use std::time::Instant;

use crate::algos::protocol::{
    ctrl_from_leaves, encode_leaf_ctrl, expect_ctrl, gather_seg_parts, gather_sparse_parts,
    gather_stack1, AggExchange, Endpoint, Round, StepMeta, StepProtocol, StepSync,
};
use crate::algos::{concat_batches, AlgoSpec};
use crate::checkpoint::{push_mats, read_mats, Checkpoint, CheckpointPlan};
use crate::coordinator::trainer::{
    epoch_plan, evaluate, local_update, snapshot_checkpoint, DataSource, EpochLog, Schedule,
    TrainLog, TrainSpec,
};
use crate::data::{BatchIter, Partition};
use crate::dist::wire::{proto_err, ByteReader, ByteWriter, SparseMat};
use crate::dist::{is_link_failure, Direction, Ledger, Transport};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::LocalStats;
use crate::nn::Adam;
use crate::obs::metrics;
use crate::obs::trace::{self, Phase, StepTiming};
use crate::tensor::{Matrix, Rng, Workspace};

/// Result of one synchronized remote step, as seen from one endpoint.
/// `grads` is identical on every endpoint (the dAD invariant); the byte
/// counters cover only the traffic this endpoint's ledger observed — the
/// aggregator sees everything, a site sees its own uplink plus the shared
/// broadcast. Peer-to-peer traffic (dad-p2p) is folded into `bytes_up`,
/// matching the simulated trainer's reporting.
pub struct RemoteStep {
    /// Batch-size-weighted global mean training loss for the step.
    pub loss: f32,
    /// The synchronized global gradient (aligned with the param list).
    pub grads: Vec<Matrix>,
    /// rank-dAD effective-rank telemetry, `[entry][site]` (aggregator
    /// side only; empty otherwise).
    pub eff_ranks: Vec<Vec<usize>>,
    /// Site->aggregator (+ peer-to-peer) payload bytes recorded locally.
    pub bytes_up: u64,
    /// Aggregator->site payload bytes recorded locally this step.
    pub bytes_down: u64,
    /// Labels of sites retired at this step's prologue (aggregator side,
    /// degrade mode only; empty otherwise).
    pub lost: Vec<String>,
    /// Global leaf ids that answered this step's prologue, in link order
    /// (aggregator side only; empty on sites). This is the live
    /// membership the `epoch-sync` roll-call reports.
    pub leaves_live: Vec<u32>,
}

/// What the aggregator does when a site stops answering at a step
/// prologue (see the module docs' degradation state machine).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultPolicy {
    /// Fail the run on the first lost site — naming it in the error —
    /// instead of degrading to the survivors.
    pub strict: bool,
}

impl FaultPolicy {
    /// The degrade-by-default policy.
    pub fn degrade() -> FaultPolicy {
        FaultPolicy { strict: false }
    }

    /// Fail-fast policy: any lost site aborts the run cleanly.
    pub fn strict() -> FaultPolicy {
        FaultPolicy { strict: true }
    }
}

/// How a joining process bootstraps its training state — the `resume`
/// byte of the config frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ResumeMode {
    /// Fresh run: every process starts from the seeded initialization.
    #[default]
    Fresh,
    /// Checkpoint resume: immediately after the config the aggregator
    /// broadcasts one `resume` control frame ([`ResumeState`]) every site
    /// must apply before its first step.
    Checkpoint,
    /// Elastic join: this config was unicast to a site admitted at an
    /// epoch boundary. The site bootstraps from the `epoch-sync` and
    /// `resume` broadcasts that follow and takes its rank and shard from
    /// the resharded membership (see the module docs).
    Elastic,
}

impl ResumeMode {
    fn wire_byte(self) -> u8 {
        match self {
            ResumeMode::Fresh => 0,
            ResumeMode::Checkpoint => 1,
            ResumeMode::Elastic => 2,
        }
    }

    fn from_wire(b: u8) -> io::Result<ResumeMode> {
        match b {
            0 => Ok(ResumeMode::Fresh),
            1 => Ok(ResumeMode::Checkpoint),
            2 => Ok(ResumeMode::Elastic),
            _ => Err(proto_err(format!(
                "unknown resume mode byte {b} in config frame (version skew between serve \
                 and join?)"
            ))),
        }
    }
}

/// Everything a joining site needs to reconstruct the run: training spec
/// (algorithm, schedule, seed, ...), dataset name, and scale preset.
/// Broadcast once, right after the transport handshake, as the `config`
/// control frame.
#[derive(Clone, Debug)]
pub struct RemoteConfig {
    /// The run's training specification (algorithm, sites, epochs, ...).
    pub spec: TrainSpec,
    /// Dataset name as `trainer::build_task` understands it.
    pub dataset: String,
    /// Scale preset string ("quick" | "default" | "paper").
    pub scale: String,
    /// Per-frame broadcast-read deadline every site arms
    /// (`TcpSite::set_recv_timeout`), in milliseconds; 0 blocks forever.
    /// A dead aggregator then surfaces as a clean timeout on the sites
    /// instead of a wedged process.
    pub recv_timeout_ms: u32,
    /// Partition override every process applies to its shards (from the
    /// shared seed, so the lockstep batch schedule is preserved).
    pub partition: Partition,
    /// How the receiving process bootstraps its training state: fresh,
    /// from a checkpoint broadcast, or as an elastically admitted leaf.
    pub resume: ResumeMode,
}

impl RemoteConfig {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_str(&self.spec.algo.name());
        w.push_str(&self.dataset);
        w.push_str(&self.scale);
        w.push_u32(self.spec.n_sites as u32);
        w.push_u32(self.spec.batch_per_site as u32);
        w.push_u32(self.spec.epochs as u32);
        w.push_f32(self.spec.lr);
        w.push_u64(self.spec.seed);
        w.push_u32(self.spec.schedule.sync_every() as u32);
        w.push_u32(self.recv_timeout_ms);
        w.push_str(&self.partition.name());
        w.push_u8(self.resume.wire_byte());
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<RemoteConfig> {
        let mut r = ByteReader::new(body);
        let algo_s = r.read_str()?;
        let dataset = r.read_str()?;
        let scale = r.read_str()?;
        let n_sites = r.read_u32()? as usize;
        let batch_per_site = r.read_u32()? as usize;
        let epochs = r.read_u32()? as usize;
        let lr = r.read_f32()?;
        let seed = r.read_u64()?;
        let sync_every = r.read_u32()? as usize;
        let recv_timeout_ms = r.read_u32()?;
        let partition_s = r.read_str()?;
        let resume = ResumeMode::from_wire(r.read_u8()?)?;
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "config frame has {} trailing bytes (version skew between serve and join?)",
                r.remaining()
            )));
        }
        let algo = AlgoSpec::parse(&algo_s)
            .map_err(|e| proto_err(format!("bad algo in config frame: {e}")))?;
        let partition = Partition::parse(&partition_s)
            .map_err(|e| proto_err(format!("bad partition in config frame: {e}")))?;
        Ok(RemoteConfig {
            spec: TrainSpec {
                algo,
                n_sites,
                batch_per_site,
                epochs,
                lr,
                seed,
                schedule: Schedule::from_sync_every(sync_every),
            },
            dataset,
            scale,
            recv_timeout_ms,
            partition,
            resume,
        })
    }

    /// Aggregator side: broadcast this config to every connected site.
    pub fn send(&self, t: &mut dyn Transport) -> io::Result<()> {
        t.ship_control(Direction::AggToSite, "config", &self.encode())?;
        Ok(())
    }

    /// Site side: block for the aggregator's config broadcast.
    pub fn recv(t: &mut dyn Transport) -> io::Result<RemoteConfig> {
        let body = expect_ctrl(t.recv_broadcast()?, "config")?;
        RemoteConfig::decode(&body)
    }

    /// Relay side: block for the parent's config broadcast, forward it to
    /// the children verbatim (they must see exactly the root's bytes),
    /// then decode it for this process.
    pub fn recv_forward(
        parent: &mut dyn Transport,
        children: &mut dyn Transport,
    ) -> io::Result<RemoteConfig> {
        let body = expect_ctrl(parent.recv_broadcast()?, "config")?;
        children.ship_control(Direction::AggToSite, "config", &body)?;
        RemoteConfig::decode(&body)
    }
}

/// The `resume` control frame a resuming aggregator broadcasts right after
/// the config: everything a site needs to continue the interrupted run in
/// lockstep — canonical parameters, both Adam moment tables and the step
/// counter, the epoch-plan RNG cursor, and the first epoch to execute.
/// Control frames are ledger-exempt by design, so the one-off resume
/// broadcast does not perturb the per-step bandwidth accounting the
/// equivalence tests assert on.
#[derive(Debug, Clone)]
pub struct ResumeState {
    /// Canonical model parameters, trainer order.
    pub params: Vec<Matrix>,
    /// Adam first moments, parallel to `params`.
    pub adam_m: Vec<Matrix>,
    /// Adam second moments, parallel to `params`.
    pub adam_v: Vec<Matrix>,
    /// Adam updates applied so far.
    pub adam_t: u64,
    /// Epoch-plan RNG cursor: PCG state word.
    pub rng_state: u64,
    /// Epoch-plan RNG cursor: PCG increment word.
    pub rng_inc: u64,
    /// Epoch-plan RNG cursor: cached Box-Muller spare, if any.
    pub rng_spare: Option<f32>,
    /// First epoch the resumed run executes.
    pub next_epoch: u32,
}

impl ResumeState {
    /// Lift the broadcastable subset out of a loaded checkpoint. The
    /// algorithm compressor state is deliberately absent: remote resume is
    /// limited to algorithms without site-local protocol state
    /// ([`AlgoSpec::remote_resumable`]), whose checkpoints carry none.
    pub fn from_checkpoint(ck: &Checkpoint) -> ResumeState {
        ResumeState {
            params: ck.params.clone(),
            adam_m: ck.adam_m.clone(),
            adam_v: ck.adam_v.clone(),
            adam_t: ck.meta.adam_t,
            rng_state: ck.meta.rng_state,
            rng_inc: ck.meta.rng_inc,
            rng_spare: ck.meta.rng_spare,
            next_epoch: ck.meta.next_epoch,
        }
    }

    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        push_mats(&mut w, &self.params);
        push_mats(&mut w, &self.adam_m);
        push_mats(&mut w, &self.adam_v);
        w.push_u64(self.adam_t);
        w.push_u64(self.rng_state);
        w.push_u64(self.rng_inc);
        w.push_u8(self.rng_spare.is_some() as u8);
        w.push_f32(self.rng_spare.unwrap_or(0.0));
        w.push_u32(self.next_epoch);
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<ResumeState> {
        let mut r = ByteReader::new(body);
        let params = read_mats(&mut r)?;
        let adam_m = read_mats(&mut r)?;
        let adam_v = read_mats(&mut r)?;
        let adam_t = r.read_u64()?;
        let rng_state = r.read_u64()?;
        let rng_inc = r.read_u64()?;
        let rng_spare = {
            let has = r.read_u8()? != 0;
            let v = r.read_f32()?;
            has.then_some(v)
        };
        let next_epoch = r.read_u32()?;
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "resume frame has {} trailing bytes (version skew between serve and join?)",
                r.remaining()
            )));
        }
        if adam_m.len() != params.len() || adam_v.len() != params.len() {
            return Err(proto_err(
                "resume frame moment tables are not parallel to the parameter list".into(),
            ));
        }
        Ok(ResumeState { params, adam_m, adam_v, adam_t, rng_state, rng_inc, rng_spare, next_epoch })
    }
}

/// The `epoch-sync` control frame the root broadcasts at every non-final
/// epoch boundary: the membership roll-call that makes elastic joins
/// deterministic. Every process (site, relay, root) consumes it at the
/// same boundary; when `resharded` is set, a [`ResumeState`] broadcast
/// follows immediately and everyone recomputes the round-robin shard
/// assignment ([`reshard_indices`]) over `live` before drawing the next
/// epoch's plan.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EpochSync {
    /// The epoch about to start.
    pub next_epoch: u32,
    /// Global leaf ids of every live site, in link order (ascending).
    pub live: Vec<u32>,
    /// True when this boundary admitted joiners: a `resume` broadcast
    /// follows and the shard assignment is recomputed over `live`.
    pub resharded: bool,
}

impl EpochSync {
    /// Serialize for the `epoch-sync` control frame.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_u32(self.next_epoch);
        w.push_u32(self.live.len() as u32);
        for &leaf in &self.live {
            w.push_u32(leaf);
        }
        w.push_u8(self.resharded as u8);
        w.finish()
    }

    /// Parse an `epoch-sync` control frame body.
    pub fn decode(body: &[u8]) -> io::Result<EpochSync> {
        let mut r = ByteReader::new(body);
        let next_epoch = r.read_u32()?;
        let n = r.read_u32()? as usize;
        let mut live = Vec::with_capacity(n);
        for _ in 0..n {
            live.push(r.read_u32()?);
        }
        let resharded = r.read_u8()? != 0;
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "epoch-sync frame has {} trailing bytes (version skew between serve and join?)",
                r.remaining()
            )));
        }
        Ok(EpochSync { next_epoch, live, resharded })
    }
}

/// Deterministic re-sharding over a changed membership: flatten the
/// original per-site shards in site order and deal the sample indices
/// round-robin across the `n_live` current ranks. Every process computes
/// this independently from the config-derived shards and the broadcast
/// live count, so no index traffic crosses the wire (a relay only ever
/// reads the resulting lengths).
pub fn reshard_indices(shards: &[Vec<usize>], n_live: usize) -> Vec<Vec<usize>> {
    if n_live == 0 {
        return vec![];
    }
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); n_live];
    for (i, idx) in shards.iter().flatten().enumerate() {
        out[i % n_live].push(*idx);
    }
    out
}

/// This endpoint's cumulative (up, down) ledger view; peer-to-peer traffic
/// counts as "up" (the exchange has no shared down-link), matching the
/// simulated trainer's `StepOutcome` reporting for dad-p2p.
fn dirs(l: &Ledger) -> (u64, u64) {
    (
        l.total_dir(Direction::SiteToAgg) + l.total_dir(Direction::PeerToPeer),
        l.total_dir(Direction::AggToSite),
    )
}

// ---------------------------------------------------------------------------
// Generic per-step drivers
// ---------------------------------------------------------------------------

/// Site half of one synchronized remote step, for *any* algorithm: compute
/// local statistics, run the meta/sync prologue, then drive the protocol's
/// typed exchange rounds. For the pooled oracle, `batch` must be the union
/// batch (the join driver handles this).
pub fn remote_site_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    batch: &Batch,
    site_id: usize,
    ws: &mut Workspace,
) -> io::Result<RemoteStep> {
    let stats = {
        let _s = trace::phase_span("local-stats", Phase::Compute);
        model.local_stats_ws(batch, ws)
    };
    let (up0, down0) = dirs(ledger);
    let (grads, loss) = {
        let mut ep = Endpoint::new(&mut *t, &mut *ledger);
        ep.ctrl_up("step-meta", &StepMeta::of(&stats).encode())?;
        let sync = StepSync::decode(&ep.ctrl_down("step-sync")?)?;
        let grads = proto.site_exchange(&mut ep, model, &stats, site_id, &sync)?;
        (grads, sync.loss)
    };
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep {
        loss,
        grads,
        eff_ranks: vec![],
        bytes_up: up1 - up0,
        bytes_down: down1 - down0,
        lost: vec![],
        leaves_live: vec![],
    })
}

/// Decide what to do about the sites lost during a prologue gather:
/// nothing (none lost), fail cleanly (strict policy, no survivors, or a
/// protocol whose exchange cannot shrink), or retire the lost links in
/// descending index order and return their labels. Centralizing the
/// decision keeps the `step-meta` and `local-loss` prologues on the same
/// state machine.
fn handle_lost(
    ep: &mut Endpoint<'_>,
    proto_name: &str,
    supports_degrade: bool,
    policy: FaultPolicy,
    survivors: usize,
    lost: Vec<(usize, String, io::Error)>,
) -> io::Result<Vec<String>> {
    if lost.is_empty() {
        return Ok(vec![]);
    }
    let (_, label0, e0) = &lost[0];
    if policy.strict {
        return Err(io::Error::new(
            e0.kind(),
            format!("lost site {label0} ({e0}); strict mode fails the run instead of degrading"),
        ));
    }
    if survivors == 0 {
        return Err(io::Error::new(
            io::ErrorKind::ConnectionAborted,
            format!(
                "every remaining site was lost in the same step (first: site {label0}, {e0})"
            ),
        ));
    }
    if !supports_degrade {
        return Err(io::Error::new(
            e0.kind(),
            format!(
                "lost site {label0} ({e0}), and {proto_name} cannot continue with survivors \
                 (its exchange is shaped by the full site count) — rerun under dad, dsgd, \
                 rank-dad or pooled, or fix the link"
            ),
        ));
    }
    for (site, _, _) in lost.iter().rev() {
        ep.retire_site(*site)?;
    }
    Ok(lost.into_iter().map(|(_, label, _)| label).collect())
}

/// Aggregator half of one synchronized remote step, for *any* algorithm:
/// gather every site's step metadata, broadcast the sync frame (global row
/// count, weighted loss, per-site rows), then drive the protocol's
/// gather/broadcast (or relay) rounds. For the pooled oracle the
/// aggregator runs the *site* half on `oracle_stats` — the union-batch
/// statistics the serve driver computes — since the oracle ships nothing.
///
/// Link failures during the `step-meta` gather are the degradation point:
/// `policy` decides between failing cleanly and retiring the lost sites
/// (see the module docs). Failures after the sync broadcast always
/// propagate — partial exchanges are not recoverable.
pub fn remote_agg_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    model: &M,
    oracle_stats: Option<&LocalStats>,
    policy: FaultPolicy,
) -> io::Result<RemoteStep> {
    let (up0, down0) = dirs(ledger);
    let (out, loss, lost, leaves_live) = {
        let mut ep = Endpoint::new(&mut *t, &mut *ledger);
        let n_links = ep.n_links();
        let mut metas: Vec<StepMeta> = Vec::with_capacity(n_links);
        let mut leaves_live: Vec<u32> = Vec::with_capacity(n_links);
        let mut link_leaves: Vec<Vec<u32>> = Vec::with_capacity(n_links);
        let mut gone: Vec<(usize, String, io::Error)> = Vec::new();
        for link in 0..n_links {
            match ctrl_from_leaves(&mut ep, link, "step-meta") {
                Ok(pairs) => {
                    link_leaves.push(pairs.iter().map(|p| p.0).collect());
                    for (leaf, body) in pairs {
                        metas.push(StepMeta::decode(&body)?);
                        leaves_live.push(leaf);
                    }
                }
                Err(e) if is_link_failure(&e) => {
                    let label = ep.site_label(link);
                    gone.push((link, label, e));
                }
                Err(e) => return Err(e),
            }
        }
        let lost = handle_lost(
            &mut ep,
            proto.name(),
            proto.supports_degrade(),
            policy,
            metas.len(),
            gone,
        )?;
        // The gathers below combine per-link partials over the leaf counts
        // that *actually answered this step* — a relay whose subtree
        // degraded mid-run ships fewer per-leaf items than its handshake
        // declared, and the batched metas above are the ground truth.
        ep.set_link_leaves(link_leaves);
        let sync = StepSync::from_metas(&metas, proto.oracle())?;
        // Past this point the step is committed: every live site has been
        // promised a sync frame, so a link failure leaves survivors blocked
        // inside the exchange — it must fail the run, never degrade. Tag
        // such errors so operators (and the chaos recipes) can tell a
        // recoverable prologue loss from an unrecoverable mid-step one.
        let mid_exchange = |e: io::Error| {
            if is_link_failure(&e) {
                io::Error::new(
                    e.kind(),
                    format!("link failed mid-exchange (cannot degrade mid-step): {e}"),
                )
            } else {
                e
            }
        };
        ep.ctrl_bcast("step-sync", &sync.encode()).map_err(mid_exchange)?;
        let out = if proto.oracle() {
            let stats = oracle_stats.ok_or_else(|| {
                proto_err(
                    "the pooled oracle needs the aggregator to hold the union batch \
                     (serve_training supplies it)"
                        .into(),
                )
            })?;
            let grads =
                proto.site_exchange(&mut ep, model, stats, 0, &sync).map_err(mid_exchange)?;
            AggExchange { grads, eff_ranks: vec![] }
        } else {
            proto.agg_exchange(&mut ep, model, &metas, &sync).map_err(mid_exchange)?
        };
        (out, sync.loss, lost, leaves_live)
    };
    let (up1, down1) = dirs(ledger);
    Ok(RemoteStep {
        loss,
        grads: out.grads,
        eff_ranks: out.eff_ranks,
        bytes_up: up1 - up0,
        bytes_down: down1 - down0,
        lost,
        leaves_live,
    })
}

// ---------------------------------------------------------------------------
// Full training loops
// ---------------------------------------------------------------------------

/// Validate a spec for multi-process execution. Every algorithm runs
/// remotely, with one schedule restriction: edAD's delta recomputation
/// (eq. 5) uses the *model weights*, and during `Schedule::Periodic`
/// off-sync phases every site's weights drift differently — each endpoint
/// would recompute different aggregated deltas and the replicas would
/// desync silently. The simulated trainer is immune (it recomputes once,
/// on site 0's replica), so the periodic edAD ablation stays available
/// through `dad train`. `dad serve` calls this *before* binding so a bad
/// spec fails on the operator's terminal instead of stranding joins.
pub fn validate_remote(spec: &TrainSpec) -> io::Result<()> {
    if matches!(spec.algo, AlgoSpec::Edad) && spec.schedule != Schedule::EveryBatch {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad over the wire requires --sync-every 1: its delta recomputation depends on \
             model weights, which drift per site during periodic local phases (use `dad train` \
             for the simulated periodic edAD ablation)",
        ));
    }
    Ok(())
}

/// Shape of the aggregation fabric a `dad serve` root expects: the
/// classic flat star (every join is a direct leaf) or a two-plus-level
/// tree where the root's links are `dad relay` subtrees.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Topology {
    /// Every link is a single-leaf site (the default).
    Flat,
    /// The root accepts exactly `root_links` children (relays or direct
    /// leaves) whose declared leaf counts must sum to the spec's site
    /// count.
    Tree {
        /// Number of links the root accepts.
        root_links: usize,
    },
}

impl Topology {
    /// Parse an operator-facing topology spec: `flat` or `tree:<R>` with
    /// `R` the root's fan-out.
    pub fn parse(s: &str) -> io::Result<Topology> {
        if s == "flat" {
            return Ok(Topology::Flat);
        }
        if let Some(r) = s.strip_prefix("tree:") {
            let root_links: usize = r.parse().map_err(|_| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("bad tree fan-out {r:?} in topology spec (want tree:<root-links>)"),
                )
            })?;
            return Ok(Topology::Tree { root_links });
        }
        Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("unknown topology {s:?} (flat | tree:<root-links>)"),
        ))
    }

    /// Operator-facing name, the inverse of [`Topology::parse`].
    pub fn name(&self) -> String {
        match self {
            Topology::Flat => "flat".into(),
            Topology::Tree { root_links } => format!("tree:{root_links}"),
        }
    }
}

/// Fail-fast topology validation, called before the root binds: a tree
/// needs a sane fan-out and an algorithm whose exchange is an associative
/// reduction. edAD and dad-p2p are rejected by name with the same error
/// their [`StepProtocol::plan`] would raise at the first relay step, so
/// the operator sees it on `dad serve`'s terminal instead of stranding a
/// whole fabric of joins.
pub fn validate_remote_topology(spec: &TrainSpec, topo: &Topology) -> io::Result<()> {
    let root_links = match *topo {
        Topology::Flat => return Ok(()),
        Topology::Tree { root_links } => root_links,
    };
    if root_links == 0 || root_links > spec.n_sites {
        return Err(io::Error::new(
            io::ErrorKind::InvalidInput,
            format!(
                "tree topology wants {root_links} root links for {} sites (need 1..={})",
                spec.n_sites, spec.n_sites
            ),
        ));
    }
    if matches!(spec.algo, AlgoSpec::Edad) {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad: weight-coupled delta recomputation is not an associative reduction, \
             so edad cannot run on a tree topology (use dad, or a flat star)",
        ));
    }
    if matches!(spec.algo, AlgoSpec::DadP2p) {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "dad-p2p: the all-to-all mesh has no aggregation tree, so dad-p2p cannot \
             run on a tree topology (use dad, or a flat star)",
        ));
    }
    Ok(())
}

/// The model-aware half of remote validation: edAD is only runnable on
/// architectures whose `edad_recompute` is defined (the transformer's
/// attention mixes rows, so it is not). Both training loops call this
/// before touching the transport, mirroring [`validate_remote`]'s
/// fail-fast contract — without it the combination would panic (or
/// protocol-error) deep inside the first step.
fn validate_model_algo<M: DistModel>(spec: &TrainSpec, model: &M) -> io::Result<()> {
    if matches!(spec.algo, AlgoSpec::Edad) && !model.supports_edad() {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "edad is not defined for this architecture (its delta recomputation needs the \
             activation-derivative recurrence, which attention does not admit) — use dad, \
             rank-dad:R or powersgd:R instead",
        ));
    }
    Ok(())
}

/// Assemble one site's batch for this step from its shard and the step's
/// within-shard indices.
fn shard_batch<D: DataSource>(data: &D, shard: &[usize], local: &[usize]) -> Batch {
    let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
    data.make_batch(&idx)
}

/// A site's batch iterator ran dry before the lockstep step count — the
/// processes disagree on the epoch plan (seed, shard or partition
/// mismatch). A clean error instead of a panic: the fail-fast contract of
/// the remote drivers covers bad data layouts too.
fn short_shard(site: usize) -> io::Error {
    io::Error::new(
        io::ErrorKind::InvalidData,
        format!(
            "site {site}'s batch iterator exhausted before the lockstep step count \
             (seed, shard or partition mismatch between processes)"
        ),
    )
}

/// Assemble the pooled oracle's union batch, drawing every site's batch
/// iterator once in canonical site order (the simulated trainer's exact
/// iterator consumption).
fn union_batch<D: DataSource>(
    data: &D,
    shards: &[Vec<usize>],
    plan: &mut [BatchIter],
) -> io::Result<Batch> {
    let mut batches: Vec<Batch> = Vec::with_capacity(plan.len());
    for (site, (it, shard)) in plan.iter_mut().zip(shards).enumerate() {
        let local = it.next().ok_or_else(|| short_shard(site))?;
        batches.push(shard_batch(data, shard, &local));
    }
    Ok(concat_batches(&batches))
}

/// Aggregator training loop (`dad serve`): drive one remote step per batch
/// through the algorithm's wire protocol, keep a model replica in lockstep
/// for per-epoch evaluation, and log the ledger's per-direction byte
/// deltas per epoch.
///
/// `data`/`shards` are the full deterministic training set and per-site
/// index shards (every process rebuilds them from the seed). The
/// aggregator needs them for two things only: replaying site 0's local
/// updates during `Schedule::Periodic` off-sync phases (so the evaluation
/// replica tracks the simulated trainer's site-0 model exactly) and
/// computing the union batch for the pooled oracle. For every other
/// algorithm no data-derived values are read — statistics arrive over the
/// wire.
///
/// `policy` governs lost sites (module docs): degrade mode retires them
/// and keeps going — the survivor count lands in `EpochLog::sites_live`
/// and each loss is announced on stderr — while strict mode returns a
/// clean error naming the first lost site. In degrade mode with a
/// periodic schedule the off-sync mirror keeps replaying original site
/// 0's batches even if site 0 was lost; the evaluation replica re-enters
/// exact lockstep at the next sync step, which resets it to the canonical
/// Adam trajectory.
pub fn serve_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    model: M,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
    policy: FaultPolicy,
) -> io::Result<TrainLog> {
    serve_training_checkpointed(
        t,
        ledger,
        spec,
        model,
        data,
        shards,
        test,
        policy,
        &CheckpointPlan::default(),
        None,
        None,
    )
}

/// Gate shared by checkpoint save *and* resume in remote mode: the v1
/// container freezes only the canonical (aggregator-side) state, so it is
/// sound exactly when no training state lives outside it — every replica
/// on the canonical parameters (`--sync-every 1`) and no site-local
/// compressor state ([`AlgoSpec::remote_resumable`]).
fn validate_remote_checkpoint(spec: &TrainSpec) -> io::Result<()> {
    if spec.schedule != Schedule::EveryBatch {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "remote checkpointing requires --sync-every 1: periodic local phases leave each \
             site's replica drifted off the canonical parameters, state the checkpoint does \
             not carry",
        ));
    }
    if !spec.algo.remote_resumable() {
        return Err(io::Error::new(
            io::ErrorKind::Unsupported,
            format!(
                "{} keeps per-site compressor state (error feedback / warm starts) inside each \
                 join process, which an aggregator-side checkpoint cannot capture — remote \
                 checkpoint/resume supports the stateless algorithms (pooled, dsgd, dad, \
                 dad-p2p, edad, rank-dad); use `dad train` for checkpointed {} runs",
                spec.algo.name(),
                spec.algo.name()
            ),
        ));
    }
    Ok(())
}

/// [`serve_training`] plus checkpoint save/resume (the `dad serve
/// --checkpoint/--resume` path). Saving freezes the canonical state at the
/// epoch boundaries `ckpt` selects, exactly as the simulated trainer
/// would — the two modes produce byte-identical checkpoint files for the
/// same trajectory. Resuming broadcasts a `resume` control frame right
/// after the config so every site restores the same cursors before its
/// first step; `tests/remote_resume.rs` asserts the continuation matches
/// the uninterrupted TCP run bit-for-bit.
///
/// `admit` opens the fabric to elastic joiners: when it carries the run's
/// config, every non-final epoch boundary polls the transport for queued
/// dials, hands each admitted leaf the config with
/// [`ResumeMode::Elastic`], and re-shards (module docs). `None` keeps the
/// fabric closed, which is what the equivalence tests and the scenario
/// runner use.
#[allow(clippy::too_many_arguments)]
pub fn serve_training_checkpointed<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
    policy: FaultPolicy,
    ckpt: &CheckpointPlan,
    resume: Option<Checkpoint>,
    admit: Option<&RemoteConfig>,
) -> io::Result<TrainLog> {
    validate_remote(spec)?;
    validate_model_algo(spec, &model)?;
    if ckpt.enabled() || resume.is_some() {
        validate_remote_checkpoint(spec)?;
    }
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let n_entries = model.local_stats_entry_count();
    let mut cur_shards: Vec<Vec<usize>> = shards.to_vec();
    let mut sizes: Vec<usize> = cur_shards.iter().map(|s| s.len()).collect();
    let mut live: Vec<u32> = (0..t.n_sites())
        .flat_map(|l| {
            let (start, n) = t.link_leaves(l);
            start..start + n
        })
        .collect();

    let mut start_epoch = 0usize;
    let mut meta_dataset = ckpt.dataset.clone();
    let mut meta_scale = ckpt.scale.clone();
    if let Some(ck) = resume {
        ck.meta.check_resume(
            &spec.algo.name(),
            spec.n_sites as u32,
            spec.batch_per_site as u32,
            spec.epochs as u32,
            spec.lr,
            spec.seed,
            spec.schedule.sync_every() as u32,
        )?;
        let fits = |mats: &[Matrix]| {
            mats.len() == shapes.len()
                && mats.iter().zip(&shapes).all(|(m, &(r, c))| m.rows() == r && m.cols() == c)
        };
        if !fits(&ck.params) || !fits(&ck.adam_m) || !fits(&ck.adam_v) {
            return Err(proto_err(format!(
                "checkpoint does not fit this model: expected {} parameter/moment matrices \
                 shaped {:?}",
                shapes.len(),
                shapes
            )));
        }
        // One ledger-exempt broadcast restores every site; must precede the
        // first step so the whole cluster enters epoch `next_epoch` as one.
        let rs = ResumeState::from_checkpoint(&ck);
        t.ship_control(Direction::AggToSite, "resume", &rs.encode())?;
        params = ck.params;
        model.set_params(&params);
        opt = Adam::from_state(spec.lr, ck.meta.adam_t, ck.adam_m, ck.adam_v);
        rng = ck.meta.restore_rng();
        start_epoch = ck.meta.next_epoch as usize;
        meta_dataset = ck.meta.dataset;
        meta_scale = ck.meta.scale;
    }

    let mut epochs = Vec::with_capacity(spec.epochs.saturating_sub(start_epoch));
    let mut global_step = 0u64;
    metrics::TREE_LEVEL.set(0);
    for epoch in start_epoch..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        let mut rank_sums = vec![0.0f64; n_entries];
        let mut rank_count = 0usize;
        let mut timing = StepTiming::default();
        let _ = trace::take_step_timing(); // discard pre-epoch residue
        for step in 0..n_steps {
            let step_t0 = Instant::now();
            // Iterator discipline: the oracle draws every site's iterator
            // (it trains the union batch); otherwise only site 0's is
            // drawn — each `BatchIter` is self-contained, so skipping the
            // others cannot desync anything, and site 0's draw must happen
            // every step so periodic local phases see the step-t batch.
            let (union_stats, local0) = if oracle {
                let union = union_batch(data, &cur_shards, &mut plan)?;
                let stats = {
                    let _s = trace::phase_span("local-stats", Phase::Compute);
                    model.local_stats_ws(&union, &mut ws)
                };
                (Some(stats), None)
            } else {
                (None, Some(plan[0].next().ok_or_else(|| short_shard(0))?))
            };
            if oracle || spec.schedule.is_sync_step(step) {
                let out = remote_agg_step(
                    proto.as_mut(),
                    &mut *t,
                    &mut *ledger,
                    &model,
                    union_stats.as_ref(),
                    policy,
                )?;
                live = out.leaves_live.clone();
                for label in &out.lost {
                    eprintln!(
                        "[degrade] lost site {label}; continuing with {} site(s)",
                        live.len()
                    );
                }
                loss_sum += out.loss as f64;
                if !out.eff_ranks.is_empty() {
                    for (ei, per_site) in out.eff_ranks.iter().enumerate() {
                        let mean: f64 = per_site.iter().map(|&r| r as f64).sum::<f64>()
                            / per_site.len() as f64;
                        rank_sums[ei] += mean;
                    }
                    rank_count += 1;
                }
                opt.step(&mut params, &out.grads);
                model.set_params(&params);
            } else {
                // Off-sync phase: no payload traffic. Mirror site 0's local
                // update so the evaluation replica matches the simulated
                // trainer's site-0 model, and average the sites' reported
                // local losses (tiny ledger-exempt control frames). The
                // loss gather is a prologue too: a link failure here goes
                // through the same degrade-or-fail decision as `step-meta`.
                let local0 = local0.ok_or_else(|| {
                    proto_err("internal invariant broken: non-oracle step must draw site 0".into())
                })?;
                let batch = shard_batch(data, &cur_shards[0], &local0);
                local_update(&mut model, &batch, &shapes, spec.lr, &mut ws);
                let (mean_loss, retired, leaves_now) = {
                    let mut ep = Endpoint::new(&mut *t, &mut *ledger);
                    let n_links = ep.n_links();
                    let mut loss = 0.0f32;
                    let mut leaves_now: Vec<u32> = Vec::new();
                    let mut gone: Vec<(usize, String, io::Error)> = Vec::new();
                    for link in 0..n_links {
                        match ctrl_from_leaves(&mut ep, link, "local-loss") {
                            Ok(pairs) => {
                                for (leaf, body) in pairs {
                                    loss += ByteReader::new(&body).read_f32()?;
                                    leaves_now.push(leaf);
                                }
                            }
                            Err(e) if is_link_failure(&e) => {
                                let label = ep.site_label(link);
                                gone.push((link, label, e));
                            }
                            Err(e) => return Err(e),
                        }
                    }
                    let retired = handle_lost(
                        &mut ep,
                        proto.name(),
                        proto.supports_degrade(),
                        policy,
                        leaves_now.len(),
                        gone,
                    )?;
                    (loss / leaves_now.len().max(1) as f32, retired, leaves_now)
                };
                live = leaves_now;
                for label in &retired {
                    eprintln!(
                        "[degrade] lost site {label} in a local phase; continuing with {} site(s)",
                        live.len()
                    );
                }
                loss_sum += mean_loss as f64;
            }
            timing.accumulate(&trace::take_step_timing());
            global_step += 1;
            metrics::STEP.set(global_step);
            metrics::SITES_LIVE.set(live.len() as u64);
            metrics::CHILDREN_LIVE.set(t.n_sites() as u64);
            let (up_now, down_now) = dirs(ledger);
            metrics::record_bytes(up_now, down_now);
            metrics::STEP_LATENCY.observe(step_t0.elapsed().as_secs_f64());
        }
        let eval = evaluate(&model, test);
        let (up1, down1) = dirs(ledger);
        let mean_eff_rank: Vec<f32> = rank_sums
            .iter()
            .map(|&s| if rank_count == 0 { f32::NAN } else { (s / rank_count as f64) as f32 })
            .collect();
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: eval.auc,
            test_acc: eval.acc,
            test_ppl: eval.ppl,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            sites_live: live.len(),
            timing,
            mean_eff_rank,
        });
        if trace::enabled() {
            let _ = trace::flush();
        }
        if ckpt.due(epoch + 1, spec.epochs) {
            let path = ckpt.save_path.as_ref().expect("due implies a save path");
            // Remote-resumable algorithms are stateless by construction
            // (validated above), so the compressor-state frame is empty —
            // matching what the simulated trainer writes for them.
            let ck = snapshot_checkpoint(
                spec,
                &meta_dataset,
                &meta_scale,
                epoch + 1,
                &params,
                &opt,
                &rng,
                vec![],
            );
            ck.save(std::path::Path::new(path))?;
        }
        // Elastic membership: admission plus the epoch-sync roll-call.
        // Broadcast at every non-final boundary so the whole fabric agrees
        // on the live set (and, after a join, the resharded assignment)
        // before anyone draws the next epoch's plan.
        if epoch + 1 < spec.epochs {
            let mut resharded = false;
            if let Some(base) = admit {
                let admitted = t.admit_joiners()?;
                if !admitted.is_empty() {
                    let refusal = validate_remote_checkpoint(spec).err().or_else(|| {
                        (!proto.supports_degrade()).then(|| {
                            io::Error::new(
                                io::ErrorKind::Unsupported,
                                format!(
                                    "{} cannot change membership mid-run (its exchange is \
                                     shaped by the full site count)",
                                    proto.name()
                                ),
                            )
                        })
                    });
                    if let Some(e) = refusal {
                        eprintln!("[join] refusing {} joiner(s): {e}", admitted.len());
                        // Reverse order: retiring a link shifts every later
                        // live index down by one.
                        for &link in admitted.iter().rev() {
                            t.retire_site(link)?;
                        }
                    } else {
                        let jcfg = RemoteConfig { resume: ResumeMode::Elastic, ..base.clone() };
                        let body = jcfg.encode();
                        for &link in &admitted {
                            let leaf = t.link_leaves(link).0;
                            t.ship_control_to(link, "config", &body)?;
                            live.push(leaf);
                            resharded = true;
                            eprintln!(
                                "[join] admitted site {leaf}; resharding over {} site(s)",
                                live.len()
                            );
                        }
                    }
                }
            }
            let es =
                EpochSync { next_epoch: (epoch + 1) as u32, live: live.clone(), resharded };
            t.ship_control(Direction::AggToSite, "epoch-sync", &es.encode())?;
            if resharded {
                cur_shards = reshard_indices(shards, live.len());
                sizes = cur_shards.iter().map(|s| s.len()).collect();
                // The joiners need the full cursor state; the incumbents
                // already hold it, but re-applying an exact snapshot of
                // their own state is a no-op, so one broadcast serves all.
                let ck = snapshot_checkpoint(
                    spec,
                    &meta_dataset,
                    &meta_scale,
                    epoch + 1,
                    &params,
                    &opt,
                    &rng,
                    vec![],
                );
                let rs = ResumeState::from_checkpoint(&ck);
                t.ship_control(Direction::AggToSite, "resume", &rs.encode())?;
            }
        }
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

/// Site training loop (`dad join`): replay the deterministic batch
/// schedule for this site's shard, run one remote step per batch through
/// the algorithm's wire protocol, and apply the synchronized gradient
/// locally — the replica never diverges from the aggregator's. During
/// `Schedule::Periodic` off-sync phases the site applies its own local
/// update (identical math to the simulated trainer) and ships only its
/// loss as a ledger-exempt control frame. No evaluation happens on sites
/// (`test_auc`/`test_acc` are NaN in the returned log); the serving
/// process owns reporting.
pub fn join_training<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    model: M,
    data: &D,
    shards: &[Vec<usize>],
    site_id: usize,
) -> io::Result<TrainLog> {
    join_training_resumable(t, ledger, spec, model, data, shards, site_id, ResumeMode::Fresh)
}

/// Sanity-check a [`ResumeState`] against this process's model shapes
/// before applying it.
fn check_resume_fits(shapes: &[(usize, usize)], rs: &ResumeState) -> io::Result<()> {
    let fits = |mats: &[Matrix]| {
        mats.len() == shapes.len()
            && mats.iter().zip(shapes).all(|(m, &(r, c))| m.rows() == r && m.cols() == c)
    };
    if !fits(&rs.params) || !fits(&rs.adam_m) || !fits(&rs.adam_v) {
        return Err(proto_err(format!(
            "resume frame does not fit this model: expected {} parameter/moment matrices \
             shaped {:?} (dataset/scale mismatch between serve and join?)",
            shapes.len(),
            shapes
        )));
    }
    Ok(())
}

/// This site's rank (shard index) in the broadcast live membership.
fn rank_of(leaf: u32, live: &[u32]) -> io::Result<usize> {
    live.iter()
        .position(|&l| l == leaf)
        .ok_or_else(|| proto_err(format!("site {leaf} is not in the live membership {live:?}")))
}

/// [`join_training`] for a run whose config frame announced a non-fresh
/// bootstrap (`RemoteConfig::resume`). [`ResumeMode::Checkpoint`] blocks
/// for the aggregator's `resume` broadcast before the first step and
/// restores the shared cursors from it, entering epoch `next_epoch` in
/// lockstep with everyone else. [`ResumeMode::Elastic`] is the admitted
/// joiner's path: it consumes the admission boundary's `epoch-sync` and
/// `resume` broadcasts, takes its rank from the live membership and its
/// shard from the round-robin re-deal, and joins the next epoch.
#[allow(clippy::too_many_arguments)]
pub fn join_training_resumable<M: DistModel, D: DataSource>(
    t: &mut dyn Transport,
    ledger: &mut Ledger,
    spec: &TrainSpec,
    mut model: M,
    data: &D,
    shards: &[Vec<usize>],
    site_id: usize,
    resume: ResumeMode,
) -> io::Result<TrainLog> {
    validate_remote(spec)?;
    validate_model_algo(spec, &model)?;
    if resume != ResumeMode::Elastic && site_id >= shards.len() {
        return Err(proto_err(format!(
            "site id {site_id} out of range for {} shards",
            shards.len()
        )));
    }
    if resume != ResumeMode::Fresh {
        validate_remote_checkpoint(spec)?;
    }
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    let shapes = model.param_shapes();
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let mut ws = Workspace::new();
    let entry_names = model.entry_names();
    let mut my_rank = site_id;
    let mut cur_shards: Vec<Vec<usize>> = shards.to_vec();
    let mut sizes: Vec<usize> = cur_shards.iter().map(|s| s.len()).collect();

    let mut start_epoch = 0usize;
    match resume {
        ResumeMode::Fresh => {}
        ResumeMode::Checkpoint => {
            let rs = ResumeState::decode(&expect_ctrl(t.recv_broadcast()?, "resume")?)?;
            check_resume_fits(&shapes, &rs)?;
            params = rs.params;
            model.set_params(&params);
            opt = Adam::from_state(spec.lr, rs.adam_t, rs.adam_m, rs.adam_v);
            rng = Rng::from_parts(rs.rng_state, rs.rng_inc, rs.rng_spare);
            start_epoch = rs.next_epoch as usize;
            if start_epoch >= spec.epochs {
                return Err(proto_err(format!(
                    "resume frame says epoch {start_epoch} of a {} epoch run: nothing to do",
                    spec.epochs
                )));
            }
        }
        ResumeMode::Elastic => {
            let es = EpochSync::decode(&expect_ctrl(t.recv_broadcast()?, "epoch-sync")?)?;
            if !es.resharded {
                return Err(proto_err(
                    "elastic join: the admission epoch-sync did not announce a reshard \
                     (aggregator/site version skew?)"
                        .into(),
                ));
            }
            let rs = ResumeState::decode(&expect_ctrl(t.recv_broadcast()?, "resume")?)?;
            check_resume_fits(&shapes, &rs)?;
            params = rs.params;
            model.set_params(&params);
            opt = Adam::from_state(spec.lr, rs.adam_t, rs.adam_m, rs.adam_v);
            rng = Rng::from_parts(rs.rng_state, rs.rng_inc, rs.rng_spare);
            start_epoch = rs.next_epoch as usize;
            my_rank = rank_of(site_id as u32, &es.live)?;
            cur_shards = reshard_indices(shards, es.live.len());
            sizes = cur_shards.iter().map(|s| s.len()).collect();
        }
    }

    let mut epochs = Vec::with_capacity(spec.epochs.saturating_sub(start_epoch));
    let mut global_step = 0u64;
    for epoch in start_epoch..spec.epochs {
        let mut plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let (up0, down0) = dirs(ledger);
        let mut loss_sum = 0.0f64;
        let mut timing = StepTiming::default();
        let _ = trace::take_step_timing(); // discard pre-epoch residue
        for step in 0..n_steps {
            let step_t0 = Instant::now();
            let batch = if oracle {
                // The pooled oracle trains the union batch in every process.
                union_batch(data, &cur_shards, &mut plan)?
            } else {
                let local = plan[my_rank].next().ok_or_else(|| short_shard(my_rank))?;
                shard_batch(data, &cur_shards[my_rank], &local)
            };
            if oracle || spec.schedule.is_sync_step(step) {
                let out = remote_site_step(
                    proto.as_mut(),
                    &mut *t,
                    &mut *ledger,
                    &model,
                    &batch,
                    my_rank,
                    &mut ws,
                )?;
                loss_sum += out.loss as f64;
                opt.step(&mut params, &out.grads);
                model.set_params(&params);
            } else {
                let loss = local_update(&mut model, &batch, &shapes, spec.lr, &mut ws);
                let mut w = ByteWriter::new();
                w.push_f32(loss);
                Endpoint::new(&mut *t, &mut *ledger).ctrl_up("local-loss", &w.finish())?;
                loss_sum += loss as f64;
            }
            timing.accumulate(&trace::take_step_timing());
            global_step += 1;
            metrics::STEP.set(global_step);
            metrics::SITES_LIVE.set(t.n_sites() as u64);
            let (up_now, down_now) = dirs(ledger);
            metrics::record_bytes(up_now, down_now);
            metrics::STEP_LATENCY.observe(step_t0.elapsed().as_secs_f64());
        }
        let (up1, down1) = dirs(ledger);
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: f32::NAN,
            test_acc: f32::NAN,
            test_ppl: f32::NAN,
            bytes_up: up1 - up0,
            bytes_down: down1 - down0,
            // Sites do not observe peer retirements; the serving process
            // owns degraded-run reporting.
            sites_live: spec.n_sites,
            timing,
            mean_eff_rank: vec![],
        });
        if trace::enabled() {
            let _ = trace::flush();
        }
        // Membership roll-call: every process consumes the root's
        // epoch-sync at every non-final boundary. A reshard re-applies the
        // broadcast cursor snapshot (a no-op for incumbents, whose state
        // is already the canonical one) and re-deals the shards.
        if epoch + 1 < spec.epochs {
            let es = EpochSync::decode(&expect_ctrl(t.recv_broadcast()?, "epoch-sync")?)?;
            if es.resharded {
                let rs = ResumeState::decode(&expect_ctrl(t.recv_broadcast()?, "resume")?)?;
                check_resume_fits(&shapes, &rs)?;
                params = rs.params;
                model.set_params(&params);
                opt = Adam::from_state(spec.lr, rs.adam_t, rs.adam_m, rs.adam_v);
                rng = Rng::from_parts(rs.rng_state, rs.rng_inc, rs.rng_spare);
                my_rank = rank_of(site_id as u32, &es.live)?;
                cur_shards = reshard_indices(shards, es.live.len());
                sizes = cur_shards.iter().map(|s| s.len()).collect();
            }
        }
    }
    Ok(TrainLog { algo: spec.algo.name(), epochs, sim_time_s: 0.0, entry_names })
}

// ---------------------------------------------------------------------------
// Sub-aggregator (relay) loop
// ---------------------------------------------------------------------------

/// Sub-aggregator training loop (`dad relay`): one interior tree level,
/// holding no data and no model state. Each synchronized step runs the
/// aggregator half of the prologue against the child links — gathering
/// per-leaf `step-meta` and degrading per subtree exactly like the root —
/// re-ships the batched metas up, forwards the `step-sync` broadcast
/// down, and then executes the protocol's [`StepPlan`] generically:
/// up rounds gather the children's partials and combine them
/// associatively (dense segment sums, leaf-order stacks, sparse index
/// unions, per-leaf control batching) before re-shipping the reduced
/// payload to the parent, and [`Round::Down`] rounds forward the root's
/// broadcast verbatim. No per-algorithm code runs here — that is the
/// point of the [`StepProtocol`] seam.
///
/// The relay replays the epoch plan (and every reshard broadcast) purely
/// to stay in lockstep on the step count; it never draws a batch.
/// `shards` are the canonical per-site shards every process rebuilds from
/// the config. The relay's parent-side ledger is the headline artifact:
/// its `SiteToAgg` bytes are what one root link costs, independent of how
/// many leaves sit below. `_model` is never touched — it only pins the
/// model type the protocol family is instantiated at, exactly as the
/// other drivers' `build::<M>()` call does.
#[allow(clippy::too_many_arguments)]
pub fn relay_training<M: DistModel>(
    parent: &mut dyn Transport,
    children: &mut dyn Transport,
    parent_ledger: &mut Ledger,
    child_ledger: &mut Ledger,
    cfg: &RemoteConfig,
    shards: &[Vec<usize>],
    policy: FaultPolicy,
    _model: M,
) -> io::Result<()> {
    let spec = &cfg.spec;
    validate_remote(spec)?;
    validate_remote_topology(spec, &Topology::Tree { root_links: 1 })?;
    let mut proto = spec.algo.build::<M>().protocol();
    let oracle = proto.oracle();
    // Captured once from the handshake: a subtree that declared a single
    // leaf must ship raw (flat-star) control bodies upward, a multi-leaf
    // one the batched per-leaf form — the shape the parent inferred from
    // this relay's hello, which never changes even if leaves die later.
    let declared: u32 = (0..children.n_sites()).map(|l| children.link_leaves(l).1).sum();
    let batched_up = declared > 1;
    let mut rng = Rng::new(spec.seed);
    let mut sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
    let mut start_epoch = 0usize;
    match cfg.resume {
        ResumeMode::Fresh => {}
        ResumeMode::Checkpoint => {
            let body = expect_ctrl(parent.recv_broadcast()?, "resume")?;
            children.ship_control(Direction::AggToSite, "resume", &body)?;
            let rs = ResumeState::decode(&body)?;
            rng = Rng::from_parts(rs.rng_state, rs.rng_inc, rs.rng_spare);
            start_epoch = rs.next_epoch as usize;
        }
        ResumeMode::Elastic => {
            return Err(proto_err(
                "a relay cannot join elastically: admission only covers single-leaf sites"
                    .into(),
            ));
        }
    }
    metrics::TREE_LEVEL.set(1);
    for epoch in start_epoch..spec.epochs {
        let plan = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = plan.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        for step in 0..n_steps {
            if oracle || spec.schedule.is_sync_step(step) {
                relay_step(
                    proto.as_mut(),
                    &mut *parent,
                    &mut *children,
                    &mut *parent_ledger,
                    &mut *child_ledger,
                    policy,
                    batched_up,
                )?;
            } else {
                // Off-sync phase: gather and re-batch the subtree's
                // ledger-exempt local losses; the root does the averaging.
                let mut cep = Endpoint::new(&mut *children, &mut *child_ledger);
                let mut items: Vec<(u32, Vec<u8>)> = Vec::new();
                let mut gone: Vec<(usize, String, io::Error)> = Vec::new();
                for link in 0..cep.n_links() {
                    match ctrl_from_leaves(&mut cep, link, "local-loss") {
                        Ok(pairs) => items.extend(pairs),
                        Err(e) if is_link_failure(&e) => {
                            let label = cep.site_label(link);
                            gone.push((link, label, e));
                        }
                        Err(e) => return Err(e),
                    }
                }
                let lost = handle_lost(
                    &mut cep,
                    proto.name(),
                    proto.supports_degrade(),
                    policy,
                    items.len(),
                    gone,
                )?;
                for label in &lost {
                    eprintln!(
                        "[degrade] relay lost site {label} in a local phase; subtree \
                         continues with {} leaves",
                        items.len()
                    );
                }
                let mut pep = Endpoint::new(&mut *parent, &mut *parent_ledger);
                if batched_up {
                    pep.ctrl_up("local-loss", &encode_leaf_ctrl(&items))?;
                } else {
                    pep.ctrl_up("local-loss", &items[0].1)?;
                }
            }
            metrics::CHILDREN_LIVE.set(children.n_sites() as u64);
        }
        // Forward the membership roll-call (and any reshard snapshot)
        // verbatim — encode∘decode is bit-identical, so the leaves see
        // exactly the root's bytes — and track the step-count bookkeeping
        // locally.
        if epoch + 1 < spec.epochs {
            let body = expect_ctrl(parent.recv_broadcast()?, "epoch-sync")?;
            children.ship_control(Direction::AggToSite, "epoch-sync", &body)?;
            let es = EpochSync::decode(&body)?;
            if es.resharded {
                let rbody = expect_ctrl(parent.recv_broadcast()?, "resume")?;
                children.ship_control(Direction::AggToSite, "resume", &rbody)?;
                let rs = ResumeState::decode(&rbody)?;
                rng = Rng::from_parts(rs.rng_state, rs.rng_inc, rs.rng_spare);
                sizes = reshard_indices(shards, es.live.len()).iter().map(|s| s.len()).collect();
            }
        }
    }
    Ok(())
}

/// One synchronized step at a relay (see [`relay_training`]): prologue
/// gather + uplink, sync forward, then the generic round interpreter.
fn relay_step<M: DistModel>(
    proto: &mut dyn StepProtocol<M>,
    parent: &mut dyn Transport,
    children: &mut dyn Transport,
    parent_ledger: &mut Ledger,
    child_ledger: &mut Ledger,
    policy: FaultPolicy,
    batched_up: bool,
) -> io::Result<()> {
    let mut cep = Endpoint::new(children, child_ledger);
    let mut items: Vec<(u32, Vec<u8>)> = Vec::new();
    let mut metas: Vec<StepMeta> = Vec::new();
    let mut link_leaves: Vec<Vec<u32>> = Vec::new();
    let mut gone: Vec<(usize, String, io::Error)> = Vec::new();
    for link in 0..cep.n_links() {
        match ctrl_from_leaves(&mut cep, link, "step-meta") {
            Ok(pairs) => {
                link_leaves.push(pairs.iter().map(|p| p.0).collect());
                for (leaf, body) in pairs {
                    metas.push(StepMeta::decode(&body)?);
                    items.push((leaf, body));
                }
            }
            Err(e) if is_link_failure(&e) => {
                let label = cep.site_label(link);
                gone.push((link, label, e));
            }
            Err(e) => return Err(e),
        }
    }
    let lost =
        handle_lost(&mut cep, proto.name(), proto.supports_degrade(), policy, metas.len(), gone)?;
    for label in &lost {
        eprintln!(
            "[degrade] relay lost site {label}; subtree continues with {} leaves",
            items.len()
        );
    }
    cep.set_link_leaves(link_leaves);
    let plan = proto.plan(&metas)?;
    let mut pep = Endpoint::new(parent, parent_ledger);
    if batched_up {
        pep.ctrl_up("step-meta", &encode_leaf_ctrl(&items))?;
    } else {
        pep.ctrl_up("step-meta", &items[0].1)?;
    }
    // Past the uplink the step is committed fabric-wide (the root will
    // broadcast a sync frame covering this subtree's leaves), so any
    // failure below can only fail the run — exactly the root's rule.
    let mid = |e: io::Error| {
        if is_link_failure(&e) {
            io::Error::new(
                e.kind(),
                format!("link failed mid-exchange (cannot degrade mid-step): {e}"),
            )
        } else {
            e
        }
    };
    let f = pep.down_frame("step-sync").map_err(mid)?;
    cep.bcast_frame(&f).map_err(mid)?;
    for round in &plan.rounds {
        let r = match *round {
            Round::UpSum { tag } => gather_seg_parts(&mut cep, tag).and_then(|segs| {
                // Surviving segments sit side by side; the parent folds
                // them left-to-right in the same canonical bracketing.
                let refs: Vec<&Matrix> =
                    segs.segs().iter().flat_map(|s| s.val.iter()).collect();
                pep.up(tag, &refs)
            }),
            Round::UpStack { tag } => gather_stack1(&mut cep, tag)
                .and_then(|stacked| pep.up(tag, &[&stacked])),
            Round::UpSparse { tag } => gather_sparse_parts(&mut cep, tag).and_then(|segs| {
                let refs: Vec<&SparseMat> = segs.segs().iter().map(|s| &s.val).collect();
                pep.up_sparse(tag, &refs)
            }),
            Round::CtrlUp { tag } => (|| {
                let mut up: Vec<(u32, Vec<u8>)> = Vec::new();
                for link in 0..cep.n_links() {
                    up.extend(ctrl_from_leaves(&mut cep, link, tag)?);
                }
                if batched_up {
                    pep.ctrl_up(tag, &encode_leaf_ctrl(&up))
                } else {
                    pep.ctrl_up(tag, &up[0].1)
                }
            })(),
            Round::Down { tag } => {
                pep.down_frame(tag).and_then(|f| cep.bcast_frame(&f))
            }
        };
        r.map_err(mid)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epoch_sync_roundtrip() {
        let es = EpochSync { next_epoch: 3, live: vec![0, 2, 5, 9], resharded: true };
        assert_eq!(EpochSync::decode(&es.encode()).unwrap(), es);
        let empty = EpochSync { next_epoch: 0, live: vec![], resharded: false };
        assert_eq!(EpochSync::decode(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn epoch_sync_rejects_trailing_bytes() {
        let mut body = EpochSync { next_epoch: 1, live: vec![0], resharded: false }.encode();
        body.push(0);
        let e = EpochSync::decode(&body).unwrap_err();
        assert!(e.to_string().contains("trailing"), "{e}");
    }

    #[test]
    fn resume_mode_wire_roundtrip() {
        for m in [ResumeMode::Fresh, ResumeMode::Checkpoint, ResumeMode::Elastic] {
            assert_eq!(ResumeMode::from_wire(m.wire_byte()).unwrap(), m);
        }
        assert!(ResumeMode::from_wire(3).is_err());
    }

    #[test]
    fn reshard_deals_every_index_round_robin() {
        let shards = vec![vec![0usize, 1, 2], vec![3, 4, 5], vec![6, 7]];
        let out = reshard_indices(&shards, 4);
        assert_eq!(out.len(), 4);
        assert_eq!(out[0], vec![0, 4]);
        assert_eq!(out[1], vec![1, 5]);
        assert_eq!(out[2], vec![2, 6]);
        assert_eq!(out[3], vec![3, 7]);
        let mut all: Vec<usize> = out.into_iter().flatten().collect();
        all.sort_unstable();
        assert_eq!(all, (0..8).collect::<Vec<_>>());
        assert!(reshard_indices(&shards, 0).is_empty());
    }

    #[test]
    fn topology_parse_roundtrip_and_errors() {
        assert_eq!(Topology::parse("flat").unwrap(), Topology::Flat);
        assert_eq!(Topology::parse("tree:4").unwrap(), Topology::Tree { root_links: 4 });
        assert_eq!(Topology::Tree { root_links: 4 }.name(), "tree:4");
        assert_eq!(Topology::parse(&Topology::Flat.name()).unwrap(), Topology::Flat);
        assert!(Topology::parse("ring").unwrap_err().to_string().contains("unknown topology"));
        assert!(Topology::parse("tree:x").unwrap_err().to_string().contains("bad tree fan-out"));
    }

    #[test]
    fn tree_topology_rejects_non_associative_algos_by_name() {
        let spec = |algo: &str| TrainSpec {
            algo: AlgoSpec::parse(algo).unwrap(),
            n_sites: 4,
            batch_per_site: 8,
            epochs: 1,
            lr: 1e-4,
            seed: 7,
            schedule: Schedule::EveryBatch,
        };
        let tree = Topology::Tree { root_links: 2 };
        for algo in ["edad", "dad-p2p"] {
            let e = validate_remote_topology(&spec(algo), &tree).unwrap_err();
            assert!(e.to_string().contains(algo), "{algo}: {e}");
            assert!(e.to_string().contains("tree topology"), "{algo}: {e}");
        }
        for algo in ["dad", "dsgd", "pooled", "rank-dad:4", "powersgd:4", "dgc:25"] {
            validate_remote_topology(&spec(algo), &tree).unwrap();
        }
        assert!(validate_remote_topology(&spec("dad"), &Topology::Tree { root_links: 0 })
            .is_err());
        assert!(validate_remote_topology(&spec("dad"), &Topology::Tree { root_links: 9 })
            .is_err());
        validate_remote_topology(&spec("edad"), &Topology::Flat).unwrap();
    }
}

//! The training coordinator: epoch loops over the simulated cluster,
//! synchronized algorithm steps, per-epoch evaluation, bandwidth/rank
//! telemetry, update schedules and the k-fold driver.
//!
//! This is the Layer-3 entry point the paper's experiments run through:
//! `TrainRun::new(model, spec).train(shards, test)` reproduces one curve of
//! Figures 1-6; `kfold_mean` aggregates the 5-fold averages the paper plots.

use crate::algos::AlgoSpec;
use crate::coordinator::experiments::Scale;
use crate::data::{
    arabic_digits_like, mnist_like, split_by_label, BatchIter, DenseDataset, SeqDataset,
};
use crate::dist::Cluster;
use crate::metrics::{accuracy, multiclass_auc};
use crate::nn::model::{Batch, DistModel};
use crate::nn::{Activation, Adam, GruClassifier, Mlp};
use crate::tensor::{Matrix, Rng, Workspace};

/// Synchronization schedule (section 2's "update schedules are orthogonal
/// to the shared statistic" — exercised by the ablation bench).
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// Synchronize every batch (all paper experiments).
    EveryBatch,
    /// Local steps between syncs; every k-th batch runs the distributed
    /// algorithm (statistics can reconstruct gradients at any point, so the
    /// payload is unchanged — only the frequency drops).
    Periodic(usize),
}

impl Schedule {
    /// Whether `step` is a synchronized step — **the** cross-process
    /// lockstep decision. The simulated trainer, `dad serve` and every
    /// `dad join` call this single implementation with the same step
    /// index; a divergent copy anywhere would silently desync TCP runs
    /// from loopback runs.
    pub fn is_sync_step(&self, step: usize) -> bool {
        match *self {
            Schedule::EveryBatch => true,
            Schedule::Periodic(k) => step % k.max(1) == 0,
        }
    }

    /// Canonical `--sync-every` / config-frame encoding (1 = every batch).
    pub fn sync_every(&self) -> usize {
        match *self {
            Schedule::EveryBatch => 1,
            Schedule::Periodic(k) => k,
        }
    }

    /// Inverse of [`Schedule::sync_every`]: 0 and 1 both mean every batch.
    pub fn from_sync_every(k: usize) -> Schedule {
        match k {
            0 | 1 => Schedule::EveryBatch,
            k => Schedule::Periodic(k),
        }
    }
}

/// Training configuration for one run.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    /// Which algorithm synchronizes the sites.
    pub algo: AlgoSpec,
    /// Number of sites (model replicas / join processes).
    pub n_sites: usize,
    /// Mini-batch size per site.
    pub batch_per_site: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Seed for data order (and, via `build_task`, the dataset itself).
    pub seed: u64,
    /// Synchronization schedule.
    pub schedule: Schedule,
}

impl Default for TrainSpec {
    fn default() -> Self {
        // The paper's settings: Adam(1e-4), batch 32/site, 2 sites.
        TrainSpec {
            algo: AlgoSpec::Dad,
            n_sites: 2,
            batch_per_site: 32,
            epochs: 50,
            lr: 1e-4,
            seed: 13,
            schedule: Schedule::EveryBatch,
        }
    }
}

/// Per-epoch telemetry.
#[derive(Clone, Debug)]
pub struct EpochLog {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training loss over the epoch's synchronized steps.
    pub train_loss: f32,
    /// Macro one-vs-rest test AUC (NaN on `dad join` sites, which skip eval).
    pub test_auc: f32,
    /// Test accuracy (NaN on `dad join` sites).
    pub test_acc: f32,
    /// Site->aggregator payload bytes this epoch.
    pub bytes_up: u64,
    /// Aggregator->site payload bytes this epoch.
    pub bytes_down: u64,
    /// Mean effective rank per stats entry (rank-dAD only; NaN otherwise).
    pub mean_eff_rank: Vec<f32>,
}

/// Full run log.
#[derive(Clone, Debug)]
pub struct TrainLog {
    /// Algorithm name (`AlgoSpec::name`).
    pub algo: String,
    /// One entry per epoch, in order.
    pub epochs: Vec<EpochLog>,
    /// Simulated wire time under the cluster's `CostModel` (0 for real
    /// TCP runs, where wall clock is the measurement).
    pub sim_time_s: f64,
    /// Stats-entry (layer) names for rank telemetry.
    pub entry_names: Vec<String>,
}

impl TrainLog {
    /// Last epoch's test AUC (0.5 when no epochs ran).
    pub fn final_auc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_auc).unwrap_or(0.5)
    }

    /// Total payload bytes across all epochs and both directions.
    pub fn total_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.bytes_up + e.bytes_down).sum()
    }

    /// Write the per-epoch log as a CSV file (the CLI's `--csv` option;
    /// the CI remote-matrix job asserts this is non-empty for every
    /// algorithm). Directories are created as needed.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut w = crate::metrics::CsvWriter::create(
            path,
            &["epoch", "algo", "train_loss", "test_auc", "test_acc", "bytes_up", "bytes_down"],
        )?;
        for e in &self.epochs {
            w.row(&[
                e.epoch.to_string(),
                self.algo.clone(),
                format!("{}", e.train_loss),
                format!("{}", e.test_auc),
                format!("{}", e.test_acc),
                e.bytes_up.to_string(),
                e.bytes_down.to_string(),
            ])?;
        }
        w.flush()
    }
}

/// Anything that can produce batches from example indices (DenseDataset,
/// SeqDataset — see `crate::data`).
pub trait DataSource {
    /// Number of examples available.
    fn len(&self) -> usize;
    /// Assemble a batch from example indices.
    fn make_batch(&self, idx: &[usize]) -> Batch;
    /// Class label per example.
    fn labels(&self) -> &[usize];
    /// True when no examples are available.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl DataSource for crate::data::DenseDataset {
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn make_batch(&self, idx: &[usize]) -> Batch {
        self.batch(idx)
    }
    fn labels(&self) -> &[usize] {
        &self.labels
    }
}

impl DataSource for crate::data::SeqDataset {
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn make_batch(&self, idx: &[usize]) -> Batch {
        self.batch(idx)
    }
    fn labels(&self) -> &[usize] {
        &self.labels
    }
}

/// Build the per-site batch iterators for one epoch, consuming `rng`
/// deterministically (one permutation per shard, in site order).
///
/// This is the *entire* coupling between the batch schedule and the
/// process topology: the simulated trainer, a `dad serve` aggregator and
/// every `dad join` site call this with the same seed-derived `rng` stream
/// and shard sizes, so they agree on every batch of every epoch without a
/// single index crossing the wire.
pub fn epoch_plan(shard_sizes: &[usize], batch: usize, rng: &mut Rng) -> Vec<BatchIter> {
    shard_sizes.iter().map(|&n| BatchIter::new(n, batch, rng)).collect()
}

/// A fully-constructed training task: datasets, non-IID shards, and a
/// seeded model, as built by [`build_task`]. The enum splits on batch
/// layout (dense features vs. sequences) because the two arms carry
/// different model types.
pub enum TrainTask {
    /// Dense-feature dataset with an MLP (the paper's MNIST setup).
    Dense {
        /// Training split.
        train_ds: DenseDataset,
        /// Held-out evaluation split.
        test_ds: DenseDataset,
        /// Per-site example indices (hard non-IID label split).
        shards: Vec<Vec<usize>>,
        /// Seeded model (identical for every process given the same args).
        model: Mlp,
    },
    /// Sequence dataset with a GRU classifier (the paper's Arabic Digits
    /// setup).
    Seq {
        /// Training split.
        train_ds: SeqDataset,
        /// Held-out evaluation split.
        test_ds: SeqDataset,
        /// Per-site example indices (hard non-IID label split).
        shards: Vec<Vec<usize>>,
        /// Seeded model (identical for every process given the same args).
        model: GruClassifier,
    },
}

/// Deterministically construct dataset + shards + model for a named task.
///
/// Shared by `dad train` (one process) and `dad serve`/`dad join` (many
/// processes): every process that calls this with the same arguments gets
/// bit-identical data and parameters, which is what lets the multi-process
/// mode ship only statistics — never data or weights — and still stay in
/// lockstep with the simulation.
pub fn build_task(
    dataset: &str,
    scale: Scale,
    n_sites: usize,
    seed: u64,
) -> Result<TrainTask, String> {
    match dataset {
        "mnist" => {
            let (n_train, n_test) = match scale {
                Scale::Quick => (400, 120),
                Scale::Default => (2000, 500),
                Scale::Paper => (60_000, 10_000),
            };
            let mut rng = Rng::new(seed);
            let full = mnist_like(n_train + n_test, &mut rng);
            let train_ds = full.subset(&(0..n_train).collect::<Vec<_>>());
            let test_ds = full.subset(&(n_train..n_train + n_test).collect::<Vec<_>>());
            let shards = split_by_label(&train_ds.labels, 10, n_sites);
            let dims: Vec<usize> = if scale == Scale::Quick {
                vec![784, 128, 128, 10]
            } else {
                vec![784, 1024, 1024, 10]
            };
            let mut mrng = Rng::new(42);
            let model = Mlp::new(&dims, &vec![Activation::Relu; dims.len() - 2], &mut mrng);
            Ok(TrainTask::Dense { train_ds, test_ds, shards, model })
        }
        "arabic" => {
            let (n_train, n_test) = match scale {
                Scale::Quick => (240, 80),
                Scale::Default => (600, 200),
                Scale::Paper => (6600, 2200),
            };
            let mut rng = Rng::new(seed);
            let full = arabic_digits_like(n_train + n_test, &mut rng);
            let train_ds = full.subset(&(0..n_train).collect::<Vec<_>>());
            let test_ds = full.subset(&(n_train..n_train + n_test).collect::<Vec<_>>());
            let shards = split_by_label(&train_ds.labels, 10, n_sites);
            let mut mrng = Rng::new(42);
            let model = if scale == Scale::Quick {
                GruClassifier::new(13, 32, &[64, 32], 10, &mut mrng)
            } else {
                GruClassifier::paper_uea(13, 10, &mut mrng)
            };
            Ok(TrainTask::Seq { train_ds, test_ds, shards, model })
        }
        other => Err(format!("unknown dataset {other:?} (mnist|arabic)")),
    }
}

/// Train `model` under `spec` on per-site index shards of `data`,
/// evaluating on `test` after every epoch.
pub fn train<M: DistModel + Clone, D: DataSource>(
    model: M,
    spec: &TrainSpec,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
) -> TrainLog {
    let pooled = spec.algo == AlgoSpec::Pooled;
    let n_replicas = if pooled { 1 } else { spec.n_sites };
    let mut cluster = Cluster::replicate(model, n_replicas);
    let mut algo = spec.algo.build::<M>();
    let shapes = cluster.sites[0].model.param_shapes();
    let mut params: Vec<Matrix> =
        cluster.sites[0].model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let entry_names = cluster.sites[0].model.entry_names();
    let n_entries = cluster.sites[0].model.local_stats_entry_count();

    let mut epochs = Vec::with_capacity(spec.epochs);
    for epoch in 0..spec.epochs {
        // Per-site shuffled batch iterators; lockstep over the minimum
        // number of batches (paper: equal shards, equal batch counts).
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let mut iters = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = iters.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let mut loss_sum = 0.0f64;
        let mut bytes_up = 0u64;
        let mut bytes_down = 0u64;
        let mut rank_sums = vec![0.0f64; n_entries];
        let mut rank_count = 0usize;
        for step in 0..n_steps {
            let batches: Vec<Batch> = iters
                .iter_mut()
                .zip(shards)
                .map(|(it, shard)| {
                    let local = it.next().expect("batch iterator exhausted");
                    let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
                    data.make_batch(&idx)
                })
                .collect();
            let synchronize = spec.schedule.is_sync_step(step);
            let outcome = if synchronize || pooled {
                algo.step(&mut cluster, &batches)
            } else {
                // Local phase of the periodic schedule: every site applies
                // its own local gradient; replicas diverge until next sync.
                local_step(&mut cluster, &batches, &shapes)
            };
            loss_sum += outcome.loss as f64;
            bytes_up += outcome.bytes_up;
            bytes_down += outcome.bytes_down;
            if !outcome.eff_ranks.is_empty() {
                for (ei, per_site) in outcome.eff_ranks.iter().enumerate() {
                    let mean: f64 =
                        per_site.iter().map(|&r| r as f64).sum::<f64>() / per_site.len() as f64;
                    rank_sums[ei] += mean;
                }
                rank_count += 1;
            }
            if synchronize || pooled {
                // Identical gradient everywhere: advance canonical params,
                // install on every replica.
                opt.step(&mut params, &outcome.grads);
                for site in &mut cluster.sites {
                    site.model.set_params(&params);
                }
            }
        }
        // Evaluation (site 0's replica; all replicas are identical under
        // EveryBatch).
        let (test_auc, test_acc) = evaluate(&cluster.sites[0].model, test);
        let mean_eff_rank: Vec<f32> = rank_sums
            .iter()
            .map(|&s| if rank_count == 0 { f32::NAN } else { (s / rank_count as f64) as f32 })
            .collect();
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc,
            test_acc,
            bytes_up,
            bytes_down,
            mean_eff_rank,
        });
    }
    TrainLog {
        algo: spec.algo.name(),
        epochs,
        sim_time_s: cluster.sim_time_s,
        entry_names,
    }
}

/// One site-local SGD step — the off-sync phase of [`Schedule::Periodic`].
/// Shared verbatim between the simulated trainer and the remote drivers
/// (`coordinator::remote`), so replicas drift identically between syncs in
/// both modes; the fixed 1e-4 step size is part of that contract. Returns
/// the batch loss.
pub fn local_update<M: DistModel>(
    model: &mut M,
    batch: &Batch,
    shapes: &[(usize, usize)],
    ws: &mut Workspace,
) -> f32 {
    let stats = model.local_stats_ws(batch, ws);
    let rows = stats.entries.last().expect("no stats entries").d.rows();
    let grads = stats.assemble_grads(shapes, 1.0 / rows as f32, 1.0 / rows as f32);
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    for (p, g) in params.iter_mut().zip(&grads) {
        p.axpy(-1e-4, g);
    }
    model.set_params(&params);
    stats.loss
}

/// A purely local step (periodic schedule's off-sync phase): each site
/// applies its own gradient with a site-local one-step SGD at the Adam lr
/// scale. No communication.
fn local_step<M: DistModel>(
    cluster: &mut Cluster<M>,
    batches: &[Batch],
    shapes: &[(usize, usize)],
) -> crate::algos::StepOutcome {
    let mut losses = 0.0f32;
    for (site, batch) in cluster.sites.iter_mut().zip(batches) {
        losses += local_update(&mut site.model, batch, shapes, site.ws.get_mut());
    }
    crate::algos::StepOutcome {
        loss: losses / batches.len() as f32,
        grads: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
        eff_ranks: vec![],
        bytes_up: 0,
        bytes_down: 0,
    }
}

/// Chunked test-set evaluation: (macro OvR AUC, accuracy).
pub fn evaluate<M: DistModel, D: DataSource>(model: &M, test: &D) -> (f32, f32) {
    let n = test.len();
    if n == 0 {
        return (0.5, 0.0);
    }
    let chunk = 256;
    let mut all_scores: Vec<Matrix> = Vec::new();
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let idx: Vec<usize> = (lo..hi).collect();
        let batch = test.make_batch(&idx);
        all_scores.push(model.predict(&batch));
        lo = hi;
    }
    let refs: Vec<&Matrix> = all_scores.iter().collect();
    let scores = Matrix::vertcat(&refs);
    (multiclass_auc(&scores, test.labels()), accuracy(&scores, test.labels()))
}

/// Mean curve across folds: average test AUC per epoch (the paper's plotted
/// quantity), with the fold standard deviation.
pub fn fold_mean_auc(logs: &[TrainLog]) -> Vec<(f32, f32)> {
    assert!(!logs.is_empty());
    let n_epochs = logs[0].epochs.len();
    (0..n_epochs)
        .map(|e| {
            let vals: Vec<f32> = logs.iter().map(|l| l.epochs[e].test_auc).collect();
            let mean = vals.iter().sum::<f32>() / vals.len() as f32;
            let var =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            (mean, var.sqrt())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, split_by_label};
    use crate::nn::{Activation, Mlp};

    fn small_mlp(seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        Mlp::new(&[784, 32, 10], &[Activation::Relu], &mut rng)
    }

    fn spec(algo: AlgoSpec, epochs: usize) -> TrainSpec {
        TrainSpec { algo, epochs, batch_per_site: 16, lr: 1e-3, ..Default::default() }
    }

    #[test]
    fn training_improves_auc_and_exact_algos_agree() {
        let mut rng = Rng::new(5);
        // One generator call => one set of class prototypes; train and test
        // must share them (they are different draws of the same classes).
        let full = mnist_like(520, &mut rng);
        let train_ds = full.subset(&(0..400).collect::<Vec<_>>());
        let test_ds = full.subset(&(400..520).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);

        let log_dad = train(small_mlp(1), &spec(AlgoSpec::Dad, 3), &train_ds, &shards, &test_ds);
        assert!(log_dad.final_auc() > 0.8, "dAD AUC {}", log_dad.final_auc());
        // Exact equivalence: dAD and dSGD produce identical trajectories up
        // to f32 reduction order => final AUC within noise.
        let log_dsgd = train(small_mlp(1), &spec(AlgoSpec::Dsgd, 3), &train_ds, &shards, &test_ds);
        assert!(
            (log_dad.final_auc() - log_dsgd.final_auc()).abs() < 2e-2,
            "dad {} vs dsgd {}",
            log_dad.final_auc(),
            log_dsgd.final_auc()
        );
        // Bandwidth: dAD ships less than dSGD on this architecture.
        assert!(log_dad.total_bytes() < log_dsgd.total_bytes());
    }

    #[test]
    fn pooled_runs_without_communication() {
        let mut rng = Rng::new(6);
        let full = mnist_like(260, &mut rng);
        let train_ds = full.subset(&(0..200).collect::<Vec<_>>());
        let test_ds = full.subset(&(200..260).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        let log = train(small_mlp(2), &spec(AlgoSpec::Pooled, 3), &train_ds, &shards, &test_ds);
        assert_eq!(log.total_bytes(), 0);
        assert!(log.final_auc() > 0.65, "pooled AUC {}", log.final_auc());
    }

    #[test]
    fn rankdad_records_effective_ranks() {
        let mut rng = Rng::new(7);
        let full = mnist_like(260, &mut rng);
        let train_ds = full.subset(&(0..200).collect::<Vec<_>>());
        let test_ds = full.subset(&(200..260).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        let algo = AlgoSpec::RankDad { max_rank: 4, n_iters: 6, theta: 1e-3 };
        let log = train(small_mlp(3), &spec(algo, 2), &train_ds, &shards, &test_ds);
        for e in &log.epochs {
            assert_eq!(e.mean_eff_rank.len(), 2); // two layers
            for &r in &e.mean_eff_rank {
                assert!(r.is_finite() && r > 0.0 && r <= 4.0, "rank {r}");
            }
        }
    }

    #[test]
    fn periodic_schedule_reduces_bytes() {
        let mut rng = Rng::new(8);
        let full = mnist_like(360, &mut rng);
        let train_ds = full.subset(&(0..300).collect::<Vec<_>>());
        let test_ds = full.subset(&(300..360).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        let every = train(small_mlp(4), &spec(AlgoSpec::Dad, 2), &train_ds, &shards, &test_ds);
        let mut p = spec(AlgoSpec::Dad, 2);
        p.schedule = Schedule::Periodic(3);
        let periodic = train(small_mlp(4), &p, &train_ds, &shards, &test_ds);
        assert!(periodic.total_bytes() < every.total_bytes());
        assert!(periodic.total_bytes() > 0);
    }

    /// Shard sizes not divisible by the batch size drop the ragged tail;
    /// uneven shards lockstep on the minimum batch count (possibly zero).
    #[test]
    fn epoch_plan_uneven_shards_and_ragged_tail() {
        let mut rng = Rng::new(9);
        let plan = epoch_plan(&[10, 7, 3], 4, &mut rng);
        let counts: Vec<usize> = plan.iter().map(|p| p.n_batches()).collect();
        assert_eq!(counts, vec![2, 1, 0]);
        // The trainers lockstep on the minimum across sites.
        assert_eq!(counts.iter().min().copied(), Some(0));
    }

    /// A single-site cluster partitions its whole shard into full batches.
    #[test]
    fn epoch_plan_single_site() {
        let mut rng = Rng::new(10);
        let mut plan = epoch_plan(&[9], 3, &mut rng);
        assert_eq!(plan.len(), 1);
        let batches: Vec<Vec<usize>> = plan.pop().unwrap().collect();
        assert_eq!(batches.len(), 3);
        let mut all: Vec<usize> = batches.concat();
        all.sort_unstable();
        assert_eq!(all, (0..9).collect::<Vec<_>>());
    }

    /// Two independently-seeded processes (fresh `Rng`s from the same
    /// seed) derive bit-identical plans — the property remote mode's
    /// "no index traffic on the wire" rests on.
    #[test]
    fn epoch_plan_identical_across_processes() {
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            epoch_plan(&[12, 8], 4, &mut rng)
                .into_iter()
                .map(|it| it.collect::<Vec<Vec<usize>>>())
                .collect::<Vec<_>>()
        };
        assert_eq!(draw(123), draw(123));
        assert_ne!(draw(123), draw(124), "different seeds should shuffle differently");
    }

    #[test]
    fn fold_mean_aggregates() {
        let mk = |auc: f32| TrainLog {
            algo: "x".into(),
            epochs: vec![EpochLog {
                epoch: 0,
                train_loss: 1.0,
                test_auc: auc,
                test_acc: 0.5,
                bytes_up: 0,
                bytes_down: 0,
                mean_eff_rank: vec![],
            }],
            sim_time_s: 0.0,
            entry_names: vec![],
        };
        let m = fold_mean_auc(&[mk(0.8), mk(0.9)]);
        assert!((m[0].0 - 0.85).abs() < 1e-6);
        assert!(m[0].1 > 0.0);
    }
}

//! The training coordinator: epoch loops over the simulated cluster,
//! synchronized algorithm steps, per-epoch evaluation, bandwidth/rank
//! telemetry, update schedules and the k-fold driver.
//!
//! This is the Layer-3 entry point the paper's experiments run through:
//! `TrainRun::new(model, spec).train(shards, test)` reproduces one curve of
//! Figures 1-6; `kfold_mean` aggregates the 5-fold averages the paper plots.

use crate::algos::AlgoSpec;
use crate::checkpoint::{Checkpoint, CheckpointPlan, CkptMeta};
use crate::coordinator::experiments::Scale;
use crate::data::{
    arabic_digits_like, mnist_like, split_by_label, token_corpus, BatchIter, DenseDataset,
    Partition, SeqDataset, TokenDataset,
};
use crate::dist::Cluster;
use crate::metrics::multiclass_auc;
use crate::nn::model::{Batch, DistModel};
use crate::nn::{Activation, Adam, GruClassifier, Mlp, Transformer, TransformerConfig};
use crate::obs::trace::{self, Phase, StepTiming};
use crate::tensor::{Matrix, Rng, Workspace};

/// Synchronization schedule (section 2's "update schedules are orthogonal
/// to the shared statistic" — exercised by the ablation bench).
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    /// Synchronize every batch (all paper experiments).
    EveryBatch,
    /// Local steps between syncs; every k-th batch runs the distributed
    /// algorithm (statistics can reconstruct gradients at any point, so the
    /// payload is unchanged — only the frequency drops).
    Periodic(usize),
}

impl Schedule {
    /// Whether `step` is a synchronized step — **the** cross-process
    /// lockstep decision. The simulated trainer, `dad serve` and every
    /// `dad join` call this single implementation with the same step
    /// index; a divergent copy anywhere would silently desync TCP runs
    /// from loopback runs.
    pub fn is_sync_step(&self, step: usize) -> bool {
        match *self {
            Schedule::EveryBatch => true,
            Schedule::Periodic(k) => step % k.max(1) == 0,
        }
    }

    /// Canonical `--sync-every` / config-frame encoding (1 = every batch).
    pub fn sync_every(&self) -> usize {
        match *self {
            Schedule::EveryBatch => 1,
            Schedule::Periodic(k) => k,
        }
    }

    /// Inverse of [`Schedule::sync_every`]: 0 and 1 both mean every batch.
    pub fn from_sync_every(k: usize) -> Schedule {
        match k {
            0 | 1 => Schedule::EveryBatch,
            k => Schedule::Periodic(k),
        }
    }
}

/// Training configuration for one run.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    /// Which algorithm synchronizes the sites.
    pub algo: AlgoSpec,
    /// Number of sites (model replicas / join processes).
    pub n_sites: usize,
    /// Mini-batch size per site.
    pub batch_per_site: usize,
    /// Training epochs.
    pub epochs: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Seed for data order (and, via `build_task`, the dataset itself).
    pub seed: u64,
    /// Synchronization schedule.
    pub schedule: Schedule,
}

impl Default for TrainSpec {
    fn default() -> Self {
        // The paper's settings: Adam(1e-4), batch 32/site, 2 sites.
        TrainSpec {
            algo: AlgoSpec::Dad,
            n_sites: 2,
            batch_per_site: 32,
            epochs: 50,
            lr: 1e-4,
            seed: 13,
            schedule: Schedule::EveryBatch,
        }
    }
}

/// Per-epoch telemetry.
#[derive(Clone, Debug)]
pub struct EpochLog {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training loss over the epoch's synchronized steps.
    pub train_loss: f32,
    /// Macro one-vs-rest test AUC (NaN on `dad join` sites, which skip eval).
    pub test_auc: f32,
    /// Test accuracy — per example for classification tasks, per token for
    /// the LM (NaN on `dad join` sites).
    pub test_acc: f32,
    /// Test perplexity (token tasks only; NaN for classification tasks and
    /// on `dad join` sites).
    pub test_ppl: f32,
    /// Site->aggregator payload bytes this epoch.
    pub bytes_up: u64,
    /// Aggregator->site payload bytes this epoch.
    pub bytes_down: u64,
    /// Sites still participating when the epoch ended. Equals the spec's
    /// site count unless a degraded remote run retired stragglers or
    /// disconnected sites mid-run (`coordinator::remote`'s fault policy) —
    /// the per-epoch survivor count the chaos recipes assert on.
    pub sites_live: usize,
    /// Wall-clock phase breakdown accumulated over the epoch's steps on
    /// this process's training thread (compute / comms / stall /
    /// compress seconds — see `obs::trace`). All zeros when the process
    /// recorded no phase spans.
    pub timing: StepTiming,
    /// Mean effective rank per stats entry (rank-dAD only; NaN otherwise).
    pub mean_eff_rank: Vec<f32>,
}

/// Full run log.
#[derive(Clone, Debug)]
pub struct TrainLog {
    /// Algorithm name (`AlgoSpec::name`).
    pub algo: String,
    /// One entry per epoch, in order.
    pub epochs: Vec<EpochLog>,
    /// Simulated wire time under the cluster's `CostModel` (0 for real
    /// TCP runs, where wall clock is the measurement).
    pub sim_time_s: f64,
    /// Stats-entry (layer) names for rank telemetry.
    pub entry_names: Vec<String>,
}

impl TrainLog {
    /// Last epoch's test AUC (0.5 when no epochs ran).
    pub fn final_auc(&self) -> f32 {
        self.epochs.last().map(|e| e.test_auc).unwrap_or(0.5)
    }

    /// Total payload bytes across all epochs and both directions.
    pub fn total_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.bytes_up + e.bytes_down).sum()
    }

    /// Write the per-epoch log as a CSV file (the CLI's `--csv` option;
    /// the CI remote-matrix job asserts this is non-empty for every
    /// algorithm). The fixed columns are `epoch,algo,train_loss,test_auc,
    /// test_acc,test_ppl,bytes_up,bytes_down,sites_live` followed by the
    /// wall-clock phase breakdown `compute_s,comms_s,stall_s,compress_s`
    /// (see `obs::trace::StepTiming`); after them come one
    /// `eff_rank_<entry>` column per stats entry (finite for rank-dAD
    /// runs, NaN otherwise — the CI smoke asserts finiteness for
    /// `rank-dad:4`), so 20+-entry transformer rank runs stay analyzable
    /// instead of being dropped. Column positions are golden-tested
    /// (`csv_header_column_positions_are_stable`); downstream consumers
    /// key on them. Directories are created as needed.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut header: Vec<String> = [
            "epoch",
            "algo",
            "train_loss",
            "test_auc",
            "test_acc",
            "test_ppl",
            "bytes_up",
            "bytes_down",
            "sites_live",
            "compute_s",
            "comms_s",
            "stall_s",
            "compress_s",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for name in &self.entry_names {
            header.push(format!("eff_rank_{name}"));
        }
        let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut w = crate::metrics::CsvWriter::create(path, &header_refs)?;
        for e in &self.epochs {
            let mut row = vec![
                e.epoch.to_string(),
                self.algo.clone(),
                format!("{}", e.train_loss),
                format!("{}", e.test_auc),
                format!("{}", e.test_acc),
                format!("{}", e.test_ppl),
                e.bytes_up.to_string(),
                e.bytes_down.to_string(),
                e.sites_live.to_string(),
                format!("{:.6}", e.timing.compute_s),
                format!("{:.6}", e.timing.comms_s),
                format!("{:.6}", e.timing.stall_s),
                format!("{:.6}", e.timing.compress_s),
            ];
            // Pad with NaN where telemetry is absent (join sites log an
            // empty rank vector), so the row width always matches.
            for i in 0..self.entry_names.len() {
                let r = e.mean_eff_rank.get(i).copied().unwrap_or(f32::NAN);
                row.push(format!("{r}"));
            }
            w.row(&row)?;
        }
        w.flush()
    }
}

/// Anything that can produce batches from example indices (DenseDataset,
/// SeqDataset, TokenDataset — see `crate::data`).
pub trait DataSource {
    /// Number of examples available.
    fn len(&self) -> usize;
    /// Assemble a batch from example indices.
    fn make_batch(&self, idx: &[usize]) -> Batch;
    /// True class per *prediction row* of the model's score matrix, in
    /// example order. For classification tasks that is one label per
    /// example (`len()` entries); for token tasks, one next-token target
    /// per position (`len() * seq_len` entries) — either way it aligns
    /// row-for-row with the scores [`evaluate`] accumulates.
    fn labels(&self) -> &[usize];
    /// Prediction rows one example contributes to the score matrix: 1 for
    /// classification tasks, `seq_len` for token tasks (one row per
    /// position). [`evaluate`] sizes its chunks in *rows* through this, so
    /// a long-sequence task cannot blow up a single `predict` call.
    fn rows_per_example(&self) -> usize {
        1
    }
    /// True when no examples are available.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl DataSource for crate::data::DenseDataset {
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn make_batch(&self, idx: &[usize]) -> Batch {
        self.batch(idx)
    }
    fn labels(&self) -> &[usize] {
        &self.labels
    }
}

impl DataSource for crate::data::SeqDataset {
    fn len(&self) -> usize {
        self.labels.len()
    }
    fn make_batch(&self, idx: &[usize]) -> Batch {
        self.batch(idx)
    }
    fn labels(&self) -> &[usize] {
        &self.labels
    }
}

impl DataSource for TokenDataset {
    fn len(&self) -> usize {
        self.len()
    }
    fn make_batch(&self, idx: &[usize]) -> Batch {
        self.batch(idx)
    }
    fn labels(&self) -> &[usize] {
        self.labels()
    }
    fn rows_per_example(&self) -> usize {
        self.seq_len
    }
}

/// Build the per-site batch iterators for one epoch, consuming `rng`
/// deterministically (one permutation per shard, in site order).
///
/// This is the *entire* coupling between the batch schedule and the
/// process topology: the simulated trainer, a `dad serve` aggregator and
/// every `dad join` site call this with the same seed-derived `rng` stream
/// and shard sizes, so they agree on every batch of every epoch without a
/// single index crossing the wire.
pub fn epoch_plan(shard_sizes: &[usize], batch: usize, rng: &mut Rng) -> Vec<BatchIter> {
    shard_sizes.iter().map(|&n| BatchIter::new(n, batch, rng)).collect()
}

/// A fully-constructed training task: datasets, non-IID shards, and a
/// seeded model, as built by [`build_task`]. The enum splits on batch
/// layout (dense features vs. sequences) because the two arms carry
/// different model types.
pub enum TrainTask {
    /// Dense-feature dataset with an MLP (the paper's MNIST setup).
    Dense {
        /// Training split.
        train_ds: DenseDataset,
        /// Held-out evaluation split.
        test_ds: DenseDataset,
        /// Per-site example indices (hard non-IID label split).
        shards: Vec<Vec<usize>>,
        /// Seeded model (identical for every process given the same args).
        model: Mlp,
    },
    /// Sequence dataset with a GRU classifier (the paper's Arabic Digits
    /// setup).
    Seq {
        /// Training split.
        train_ds: SeqDataset,
        /// Held-out evaluation split.
        test_ds: SeqDataset,
        /// Per-site example indices (hard non-IID label split).
        shards: Vec<Vec<usize>>,
        /// Seeded model (identical for every process given the same args).
        model: GruClassifier,
    },
    /// Token-stream dataset with the decoder-only transformer LM (the
    /// paper's §5.3.2 "modern architectures" workload).
    Tokens {
        /// Training split (held-out windows come after it in the stream).
        train_ds: TokenDataset,
        /// Held-out evaluation split.
        test_ds: TokenDataset,
        /// Per-site window indices (deterministic contiguous stream
        /// shards — each site owns one contiguous run of the corpus).
        shards: Vec<Vec<usize>>,
        /// Seeded model (identical for every process given the same args).
        model: Transformer,
    },
}

impl TrainTask {
    /// Re-deal the task's shards under a [`Partition`] override (identity
    /// for `Partition::Default`). Deterministic in `seed`, so every
    /// process of a remote run applies the same override and the lockstep
    /// batch schedule is preserved — this is the "partition skew" axis of
    /// the chaos recipes.
    pub fn repartition(self, partition: Partition, seed: u64) -> TrainTask {
        match self {
            TrainTask::Dense { train_ds, test_ds, shards, model } => TrainTask::Dense {
                train_ds,
                test_ds,
                shards: partition.apply(shards, seed),
                model,
            },
            TrainTask::Seq { train_ds, test_ds, shards, model } => TrainTask::Seq {
                train_ds,
                test_ds,
                shards: partition.apply(shards, seed),
                model,
            },
            TrainTask::Tokens { train_ds, test_ds, shards, model } => TrainTask::Tokens {
                train_ds,
                test_ds,
                shards: partition.apply(shards, seed),
                model,
            },
        }
    }
}

/// Deterministically construct dataset + shards + model for a named task.
///
/// Shared by `dad train` (one process) and `dad serve`/`dad join` (many
/// processes): every process that calls this with the same arguments gets
/// bit-identical data and parameters, which is what lets the multi-process
/// mode ship only statistics — never data or weights — and still stay in
/// lockstep with the simulation.
pub fn build_task(
    dataset: &str,
    scale: Scale,
    n_sites: usize,
    seed: u64,
) -> Result<TrainTask, String> {
    match dataset {
        "mnist" => {
            let (n_train, n_test) = match scale {
                Scale::Quick => (400, 120),
                Scale::Default => (2000, 500),
                Scale::Paper => (60_000, 10_000),
            };
            let mut rng = Rng::new(seed);
            let full = mnist_like(n_train + n_test, &mut rng);
            let train_ds = full.subset(&(0..n_train).collect::<Vec<_>>());
            let test_ds = full.subset(&(n_train..n_train + n_test).collect::<Vec<_>>());
            let shards = split_by_label(&train_ds.labels, 10, n_sites);
            let dims: Vec<usize> = if scale == Scale::Quick {
                vec![784, 128, 128, 10]
            } else {
                vec![784, 1024, 1024, 10]
            };
            let mut mrng = Rng::new(42);
            let model = Mlp::new(&dims, &vec![Activation::Relu; dims.len() - 2], &mut mrng);
            Ok(TrainTask::Dense { train_ds, test_ds, shards, model })
        }
        "arabic" => {
            let (n_train, n_test) = match scale {
                Scale::Quick => (240, 80),
                Scale::Default => (600, 200),
                Scale::Paper => (6600, 2200),
            };
            let mut rng = Rng::new(seed);
            let full = arabic_digits_like(n_train + n_test, &mut rng);
            let train_ds = full.subset(&(0..n_train).collect::<Vec<_>>());
            let test_ds = full.subset(&(n_train..n_train + n_test).collect::<Vec<_>>());
            let shards = split_by_label(&train_ds.labels, 10, n_sites);
            let mut mrng = Rng::new(42);
            let model = if scale == Scale::Quick {
                GruClassifier::new(13, 32, &[64, 32], 10, &mut mrng)
            } else {
                GruClassifier::paper_uea(13, 10, &mut mrng)
            };
            Ok(TrainTask::Seq { train_ds, test_ds, shards, model })
        }
        "lm" => {
            // Scales map to the three TransformerConfig presets; window
            // counts keep Quick in CI territory and Default at the e2e
            // driver's corpus size per EXPERIMENTS.md §LM.
            let (cfg, n_train_w, n_test_w) = match scale {
                Scale::Quick => (TransformerConfig::tiny(), 160, 40),
                Scale::Default => (TransformerConfig::e2e(), 512, 64),
                Scale::Paper => (TransformerConfig::big(), 4096, 256),
            };
            let t = cfg.max_t;
            let mut rng = Rng::new(seed);
            // One stream; train windows first, test windows after (the +1
            // gives the last window of each split its lookahead target).
            let stream = token_corpus((n_train_w + n_test_w) * t + 1, cfg.vocab, &mut rng);
            let train_ds =
                TokenDataset::new(stream[..n_train_w * t + 1].to_vec(), cfg.vocab, t);
            let test_ds = TokenDataset::new(stream[n_train_w * t..].to_vec(), cfg.vocab, t);
            let shards = train_ds.stream_shards(n_sites);
            let mut mrng = Rng::new(42);
            let model = Transformer::new(cfg, &mut mrng);
            Ok(TrainTask::Tokens { train_ds, test_ds, shards, model })
        }
        other => Err(format!("unknown dataset {other:?} (mnist|arabic|lm)")),
    }
}

/// Default Adam lr for the LM task at a given scale: the ~3k-parameter
/// Quick model wants a hotter rate than the 12.8M/100M configurations.
/// Shared by `experiments::lm_comparison` and the transformer example so
/// both train with the hyperparameters the committed
/// results/lm_bandwidth.csv numbers used.
pub fn default_lm_lr(scale: Scale) -> f32 {
    if scale == Scale::Quick {
        5e-3
    } else {
        3e-4
    }
}

/// Reject dataset/algorithm combinations that cannot train, *before* any
/// data or model is built — the CLI-facing twin of
/// [`crate::coordinator::remote::validate_remote`]. Today that is exactly
/// one pair: `edad` on the transformer LM, whose attention mixes rows
/// across positions so the delta recomputation (Algorithm 2, eq. 5) is
/// undefined (`Transformer::edad_recompute` returns `None`). `dad train`
/// and `dad serve` both call this up front so the operator sees a clear
/// error instead of a mid-step panic (or a stranded join).
pub fn validate_dataset_algo(dataset: &str, algo: &AlgoSpec) -> Result<(), String> {
    if dataset == "lm" && *algo == AlgoSpec::Edad {
        return Err(
            "edad cannot train the transformer LM: attention mixes rows across positions, \
             so edAD's delta recomputation (Algorithm 2) is undefined for this architecture. \
             Use --algo dad (exact) or rank-dad:R / powersgd:R (compressed) instead."
                .into(),
        );
    }
    Ok(())
}

/// Train `model` under `spec` on per-site index shards of `data`,
/// evaluating on `test` after every epoch. Checkpointing is disabled on
/// this path; [`train_checkpointed`] is the save/resume-capable variant.
pub fn train<M: DistModel + Clone, D: DataSource>(
    model: M,
    spec: &TrainSpec,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
) -> TrainLog {
    train_checkpointed(model, spec, data, shards, test, &CheckpointPlan::default(), None)
        .expect("training without checkpoint io cannot fail")
}

/// [`train`] plus checkpoint save/resume. When `plan` carries a path, the
/// canonical run state — parameters, Adam moments and step count, the
/// epoch-plan RNG cursor, the next epoch index and the algorithm's
/// cross-step compressor state — is written atomically at every epoch
/// boundary the plan selects (and always after the final epoch). Passing
/// a loaded [`Checkpoint`] as `resume` continues that run where it left
/// off: the remaining epochs reproduce what the uninterrupted run would
/// have logged bit-for-bit (`tests/checkpoint_roundtrip.rs` asserts the
/// final checkpoint files are byte-identical).
///
/// Checkpoints are defined at epoch boundaries under
/// [`Schedule::EveryBatch`] only. A periodic schedule leaves replicas
/// drifted away from the canonical parameters between syncs — state the
/// v1 container does not carry — so both saving and resuming reject
/// periodic schedules with a named error instead of resuming wrong.
pub fn train_checkpointed<M: DistModel + Clone, D: DataSource>(
    model: M,
    spec: &TrainSpec,
    data: &D,
    shards: &[Vec<usize>],
    test: &D,
    plan: &CheckpointPlan,
    resume: Option<Checkpoint>,
) -> std::io::Result<TrainLog> {
    let invalid =
        |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
    if (plan.enabled() || resume.is_some()) && spec.schedule != Schedule::EveryBatch {
        return Err(invalid(format!(
            "checkpointing requires --sync-every 1: a periodic schedule leaves replicas \
             drifted off the canonical parameters between syncs, which the v1 checkpoint \
             format does not capture (got sync-every {})",
            spec.schedule.sync_every()
        )));
    }
    let pooled = spec.algo == AlgoSpec::Pooled;
    let n_replicas = if pooled { 1 } else { spec.n_sites };
    let mut cluster = Cluster::replicate(model, n_replicas);
    let mut algo = spec.algo.build::<M>();
    let shapes = cluster.sites[0].model.param_shapes();
    let mut params: Vec<Matrix> =
        cluster.sites[0].model.params().into_iter().cloned().collect();
    let mut opt = Adam::new(spec.lr, &shapes);
    let mut rng = Rng::new(spec.seed);
    let entry_names = cluster.sites[0].model.entry_names();
    let n_entries = cluster.sites[0].model.local_stats_entry_count();

    // Dataset/scale keys recorded in saved checkpoints so `dad infer` and
    // `dad train --resume` can rebuild the model without extra flags; a
    // resumed run inherits them from the checkpoint it came from.
    let mut start_epoch = 0usize;
    let mut meta_dataset = plan.dataset.clone();
    let mut meta_scale = plan.scale.clone();
    if let Some(ck) = resume {
        ck.meta.check_resume(
            &spec.algo.name(),
            spec.n_sites as u32,
            spec.batch_per_site as u32,
            spec.epochs as u32,
            spec.lr,
            spec.seed,
            spec.schedule.sync_every() as u32,
        )?;
        let fits = |mats: &[Matrix]| {
            mats.len() == shapes.len()
                && mats.iter().zip(&shapes).all(|(m, &(r, c))| m.rows() == r && m.cols() == c)
        };
        if !fits(&ck.params) || !fits(&ck.adam_m) || !fits(&ck.adam_v) {
            return Err(invalid(format!(
                "checkpoint does not fit this model: expected {} parameter/moment \
                 matrices shaped {:?}",
                shapes.len(),
                shapes
            )));
        }
        params = ck.params;
        for site in &mut cluster.sites {
            site.model.set_params(&params);
        }
        opt = Adam::from_state(spec.lr, ck.meta.adam_t, ck.adam_m, ck.adam_v);
        rng = ck.meta.restore_rng();
        algo.load_state(&ck.algo_state).map_err(invalid)?;
        start_epoch = ck.meta.next_epoch as usize;
        meta_dataset = ck.meta.dataset;
        meta_scale = ck.meta.scale;
    }

    let mut epochs = Vec::with_capacity(spec.epochs.saturating_sub(start_epoch));
    for epoch in start_epoch..spec.epochs {
        // Per-site shuffled batch iterators; lockstep over the minimum
        // number of batches (paper: equal shards, equal batch counts).
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let mut iters = epoch_plan(&sizes, spec.batch_per_site, &mut rng);
        let n_steps = iters.iter().map(|i| i.n_batches()).min().unwrap_or(0);
        let mut loss_sum = 0.0f64;
        let mut bytes_up = 0u64;
        let mut bytes_down = 0u64;
        let mut rank_sums = vec![0.0f64; n_entries];
        let mut rank_count = 0usize;
        let mut timing = StepTiming::default();
        // Discard phase time accrued outside the step loop (previous
        // epoch's evaluation, checkpoint I/O) so the per-epoch breakdown
        // covers training steps only.
        let _ = trace::take_step_timing();
        for step in 0..n_steps {
            let batches: Vec<Batch> = iters
                .iter_mut()
                .zip(shards)
                .map(|(it, shard)| {
                    let local = it.next().expect("batch iterator exhausted");
                    let idx: Vec<usize> = local.iter().map(|&i| shard[i]).collect();
                    data.make_batch(&idx)
                })
                .collect();
            let synchronize = spec.schedule.is_sync_step(step);
            let outcome = if synchronize || pooled {
                algo.step(&mut cluster, &batches)
            } else {
                // Local phase of the periodic schedule: every site applies
                // its own local gradient; replicas diverge until next sync.
                local_step(&mut cluster, &batches, &shapes, spec.lr)
            };
            loss_sum += outcome.loss as f64;
            bytes_up += outcome.bytes_up;
            bytes_down += outcome.bytes_down;
            if !outcome.eff_ranks.is_empty() {
                for (ei, per_site) in outcome.eff_ranks.iter().enumerate() {
                    let mean: f64 =
                        per_site.iter().map(|&r| r as f64).sum::<f64>() / per_site.len() as f64;
                    rank_sums[ei] += mean;
                }
                rank_count += 1;
            }
            if synchronize || pooled {
                // Identical gradient everywhere: advance canonical params,
                // install on every replica.
                opt.step(&mut params, &outcome.grads);
                for site in &mut cluster.sites {
                    site.model.set_params(&params);
                }
            }
            // Drain this thread's phase buckets into the epoch breakdown
            // (simulated sites all run on this thread, so the sum covers
            // every replica's compute plus the loopback wire work).
            timing.accumulate(&trace::take_step_timing());
        }
        // Evaluation (site 0's replica; all replicas are identical under
        // EveryBatch).
        let eval = evaluate(&cluster.sites[0].model, test);
        let mean_eff_rank: Vec<f32> = rank_sums
            .iter()
            .map(|&s| if rank_count == 0 { f32::NAN } else { (s / rank_count as f64) as f32 })
            .collect();
        epochs.push(EpochLog {
            epoch,
            train_loss: (loss_sum / n_steps.max(1) as f64) as f32,
            test_auc: eval.auc,
            test_acc: eval.acc,
            test_ppl: eval.ppl,
            bytes_up,
            bytes_down,
            sites_live: cluster.n_sites(),
            timing,
            mean_eff_rank,
        });
        // Epoch boundary: safe point to drain span buffers to the JSONL
        // sink (formatting allocates; the hot path never does).
        if trace::enabled() {
            let _ = trace::flush();
        }
        if plan.due(epoch + 1, spec.epochs) {
            let path = plan.save_path.as_ref().expect("due implies a save path");
            let ck = snapshot_checkpoint(
                spec,
                &meta_dataset,
                &meta_scale,
                epoch + 1,
                &params,
                &opt,
                &rng,
                algo.state_mats(),
            );
            ck.save(std::path::Path::new(path))?;
        }
    }
    Ok(TrainLog {
        algo: spec.algo.name(),
        epochs,
        sim_time_s: cluster.sim_time_s,
        entry_names,
    })
}

/// Freeze the canonical run state at an epoch boundary into a
/// [`Checkpoint`]. `next_epoch` is the first epoch a resumed run should
/// execute; `params`/`opt`/`rng` are the canonical parameters, optimizer
/// and epoch-plan RNG exactly as they stand after that many epochs.
/// Shared by the simulated trainer and `dad serve` so a checkpoint is
/// byte-identical whichever mode wrote it (given the same trajectory).
#[allow(clippy::too_many_arguments)]
pub fn snapshot_checkpoint(
    spec: &TrainSpec,
    dataset: &str,
    scale: &str,
    next_epoch: usize,
    params: &[Matrix],
    opt: &Adam,
    rng: &Rng,
    algo_state: Vec<Matrix>,
) -> Checkpoint {
    let (rng_state, rng_inc, rng_spare) = rng.state_parts();
    let (m, v) = opt.moments();
    Checkpoint {
        meta: CkptMeta {
            algo: spec.algo.name(),
            dataset: dataset.to_string(),
            scale: scale.to_string(),
            n_sites: spec.n_sites as u32,
            batch_per_site: spec.batch_per_site as u32,
            epochs: spec.epochs as u32,
            lr: spec.lr,
            seed: spec.seed,
            sync_every: spec.schedule.sync_every() as u32,
            next_epoch: next_epoch as u32,
            adam_t: opt.step_count(),
            rng_state,
            rng_inc,
            rng_spare,
        },
        params: params.to_vec(),
        adam_m: m.to_vec(),
        adam_v: v.to_vec(),
        algo_state,
    }
}

/// One site-local SGD step — the off-sync phase of [`Schedule::Periodic`].
/// Shared verbatim between the simulated trainer and the remote drivers
/// (`coordinator::remote`), so replicas drift identically between syncs in
/// both modes. `lr` is the run's `TrainSpec::lr` (shipped to every remote
/// process in the config frame), applied as one plain SGD step — the lr
/// is part of the cross-mode lockstep contract, so a driver hardcoding a
/// different step size here would silently desync TCP from loopback.
/// Returns the batch loss.
pub fn local_update<M: DistModel>(
    model: &mut M,
    batch: &Batch,
    shapes: &[(usize, usize)],
    lr: f32,
    ws: &mut Workspace,
) -> f32 {
    let _span = trace::phase_span("local-update", Phase::Compute);
    let stats = model.local_stats_ws(batch, ws);
    let rows = stats.entries.last().expect("no stats entries").d.rows();
    let grads = stats.assemble_grads(shapes, 1.0 / rows as f32, 1.0 / rows as f32);
    let mut params: Vec<Matrix> = model.params().into_iter().cloned().collect();
    for (p, g) in params.iter_mut().zip(&grads) {
        p.axpy(-lr, g);
    }
    model.set_params(&params);
    stats.loss
}

/// A purely local step (periodic schedule's off-sync phase): each site
/// applies its own gradient with a site-local one-step SGD at the spec's
/// learning rate. No communication.
fn local_step<M: DistModel>(
    cluster: &mut Cluster<M>,
    batches: &[Batch],
    shapes: &[(usize, usize)],
    lr: f32,
) -> crate::algos::StepOutcome {
    let mut losses = 0.0f32;
    for (site, batch) in cluster.sites.iter_mut().zip(batches) {
        losses += local_update(&mut site.model, batch, shapes, lr, site.ws.get_mut());
    }
    crate::algos::StepOutcome {
        loss: losses / batches.len() as f32,
        grads: shapes.iter().map(|&(r, c)| Matrix::zeros(r, c)).collect(),
        eff_ranks: vec![],
        bytes_up: 0,
        bytes_down: 0,
    }
}

/// One evaluation pass's results. `auc`/`acc` are per prediction row —
/// per example for classification tasks, per token position for the LM.
/// `ppl` is the LM's perplexity (`exp(mean -ln p[target])`), NaN for
/// classification tasks. `auc` is NaN when the stacked score matrix the
/// rank-based AUC needs would blow the memory cap (paper-scale LM:
/// 32k rows x 32k vocab); accuracy and perplexity are always computed.
#[derive(Clone, Copy, Debug)]
pub struct EvalMetrics {
    /// Macro one-vs-rest AUC over the score rows.
    pub auc: f32,
    /// Top-1 accuracy over the score rows (next-token accuracy for the LM).
    pub acc: f32,
    /// Perplexity (token tasks only; NaN otherwise).
    pub ppl: f32,
}

/// Chunked test-set evaluation. Each chunk's scores are compared against
/// the matching slice of [`DataSource::labels`] row-for-row — which is
/// what makes the same path serve classification (one row per example)
/// and the LM (one row per token position, plus perplexity). Accuracy
/// and NLL accumulate chunk-by-chunk; only the rank-based AUC needs the
/// stacked matrix, so the chunks are retained for it only while they fit
/// under [`AUC_MAX_SCORE_ELEMS`] (past that `auc` is NaN instead of the
/// evaluation allocating gigabytes).
pub fn evaluate<M: DistModel, D: DataSource>(model: &M, test: &D) -> EvalMetrics {
    let n = test.len();
    if n == 0 {
        return EvalMetrics { auc: 0.5, acc: 0.0, ppl: f32::NAN };
    }
    // ~256 prediction rows per chunk, whatever one example contributes —
    // for the paper-scale LM (T=128) that is 2 windows per predict, not
    // 256 windows materializing a multi-GB score matrix in one call.
    let chunk = (256 / test.rows_per_example().max(1)).max(1);
    let labels = test.labels();
    let mut token_task = false;
    let mut correct = 0usize;
    let mut nll = 0.0f64;
    let mut rows_done = 0usize;
    let mut auc_chunks: Option<Vec<Matrix>> = Some(Vec::new());
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        let idx: Vec<usize> = (lo..hi).collect();
        let batch = test.make_batch(&idx);
        token_task = matches!(batch, Batch::Tokens { .. });
        let scores = model.predict(&batch);
        let rows = scores.rows();
        let chunk_labels = &labels[rows_done..rows_done + rows];
        correct += crate::metrics::correct_count(&scores, chunk_labels);
        nll += crate::metrics::nll_sum(&scores, chunk_labels);
        rows_done += rows;
        if auc_chunks.is_some() && rows_done * scores.cols() > AUC_MAX_SCORE_ELEMS {
            auc_chunks = None; // too big to stack; skip AUC, keep going
        }
        if let Some(chunks) = auc_chunks.as_mut() {
            chunks.push(scores);
        }
        lo = hi;
    }
    debug_assert_eq!(rows_done, labels.len(), "scores/labels row mismatch");
    let auc = match &auc_chunks {
        Some(chunks) => {
            let refs: Vec<&Matrix> = chunks.iter().collect();
            multiclass_auc(&Matrix::vertcat(&refs), labels)
        }
        None => f32::NAN,
    };
    EvalMetrics {
        auc,
        acc: correct as f32 / rows_done.max(1) as f32,
        ppl: if token_task { (nll / rows_done.max(1) as f64).exp() as f32 } else { f32::NAN },
    }
}

/// Largest stacked score matrix (in f32 elements, ~256 MB) the AUC path
/// will materialize; beyond it [`evaluate`] reports `auc = NaN`. Every
/// committed configuration is far below this — only the paper-scale LM
/// (32,768 rows x 32,000 vocab ≈ 1.0G elements) crosses it.
pub const AUC_MAX_SCORE_ELEMS: usize = 1 << 26;

/// Mean curve across folds: average test AUC per epoch (the paper's plotted
/// quantity), with the fold standard deviation.
pub fn fold_mean_auc(logs: &[TrainLog]) -> Vec<(f32, f32)> {
    assert!(!logs.is_empty());
    let n_epochs = logs[0].epochs.len();
    (0..n_epochs)
        .map(|e| {
            let vals: Vec<f32> = logs.iter().map(|l| l.epochs[e].test_auc).collect();
            let mean = vals.iter().sum::<f32>() / vals.len() as f32;
            let var =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            (mean, var.sqrt())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{mnist_like, split_by_label};
    use crate::nn::{Activation, Mlp};

    fn small_mlp(seed: u64) -> Mlp {
        let mut rng = Rng::new(seed);
        Mlp::new(&[784, 32, 10], &[Activation::Relu], &mut rng)
    }

    fn spec(algo: AlgoSpec, epochs: usize) -> TrainSpec {
        TrainSpec { algo, epochs, batch_per_site: 16, lr: 1e-3, ..Default::default() }
    }

    #[test]
    fn training_improves_auc_and_exact_algos_agree() {
        let mut rng = Rng::new(5);
        // One generator call => one set of class prototypes; train and test
        // must share them (they are different draws of the same classes).
        let full = mnist_like(520, &mut rng);
        let train_ds = full.subset(&(0..400).collect::<Vec<_>>());
        let test_ds = full.subset(&(400..520).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);

        let log_dad = train(small_mlp(1), &spec(AlgoSpec::Dad, 3), &train_ds, &shards, &test_ds);
        assert!(log_dad.final_auc() > 0.8, "dAD AUC {}", log_dad.final_auc());
        // Exact equivalence: dAD and dSGD produce identical trajectories up
        // to f32 reduction order => final AUC within noise.
        let log_dsgd = train(small_mlp(1), &spec(AlgoSpec::Dsgd, 3), &train_ds, &shards, &test_ds);
        assert!(
            (log_dad.final_auc() - log_dsgd.final_auc()).abs() < 2e-2,
            "dad {} vs dsgd {}",
            log_dad.final_auc(),
            log_dsgd.final_auc()
        );
        // Bandwidth: dAD ships less than dSGD on this architecture.
        assert!(log_dad.total_bytes() < log_dsgd.total_bytes());
    }

    #[test]
    fn pooled_runs_without_communication() {
        let mut rng = Rng::new(6);
        let full = mnist_like(260, &mut rng);
        let train_ds = full.subset(&(0..200).collect::<Vec<_>>());
        let test_ds = full.subset(&(200..260).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        let log = train(small_mlp(2), &spec(AlgoSpec::Pooled, 3), &train_ds, &shards, &test_ds);
        assert_eq!(log.total_bytes(), 0);
        assert!(log.final_auc() > 0.65, "pooled AUC {}", log.final_auc());
    }

    #[test]
    fn rankdad_records_effective_ranks() {
        let mut rng = Rng::new(7);
        let full = mnist_like(260, &mut rng);
        let train_ds = full.subset(&(0..200).collect::<Vec<_>>());
        let test_ds = full.subset(&(200..260).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        let algo = AlgoSpec::RankDad { max_rank: 4, n_iters: 6, theta: 1e-3 };
        let log = train(small_mlp(3), &spec(algo, 2), &train_ds, &shards, &test_ds);
        for e in &log.epochs {
            assert_eq!(e.mean_eff_rank.len(), 2); // two layers
            for &r in &e.mean_eff_rank {
                assert!(r.is_finite() && r > 0.0 && r <= 4.0, "rank {r}");
            }
        }
    }

    #[test]
    fn periodic_schedule_reduces_bytes() {
        let mut rng = Rng::new(8);
        let full = mnist_like(360, &mut rng);
        let train_ds = full.subset(&(0..300).collect::<Vec<_>>());
        let test_ds = full.subset(&(300..360).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        let every = train(small_mlp(4), &spec(AlgoSpec::Dad, 2), &train_ds, &shards, &test_ds);
        let mut p = spec(AlgoSpec::Dad, 2);
        p.schedule = Schedule::Periodic(3);
        let periodic = train(small_mlp(4), &p, &train_ds, &shards, &test_ds);
        assert!(periodic.total_bytes() < every.total_bytes());
        assert!(periodic.total_bytes() > 0);
    }

    /// Shard sizes not divisible by the batch size drop the ragged tail;
    /// uneven shards lockstep on the minimum batch count (possibly zero).
    #[test]
    fn epoch_plan_uneven_shards_and_ragged_tail() {
        let mut rng = Rng::new(9);
        let plan = epoch_plan(&[10, 7, 3], 4, &mut rng);
        let counts: Vec<usize> = plan.iter().map(|p| p.n_batches()).collect();
        assert_eq!(counts, vec![2, 1, 0]);
        // The trainers lockstep on the minimum across sites.
        assert_eq!(counts.iter().min().copied(), Some(0));
    }

    /// A single-site cluster partitions its whole shard into full batches.
    #[test]
    fn epoch_plan_single_site() {
        let mut rng = Rng::new(10);
        let mut plan = epoch_plan(&[9], 3, &mut rng);
        assert_eq!(plan.len(), 1);
        let batches: Vec<Vec<usize>> = plan.pop().unwrap().collect();
        assert_eq!(batches.len(), 3);
        let mut all: Vec<usize> = batches.concat();
        all.sort_unstable();
        assert_eq!(all, (0..9).collect::<Vec<_>>());
    }

    /// Two independently-seeded processes (fresh `Rng`s from the same
    /// seed) derive bit-identical plans — the property remote mode's
    /// "no index traffic on the wire" rests on.
    #[test]
    fn epoch_plan_identical_across_processes() {
        let draw = |seed: u64| {
            let mut rng = Rng::new(seed);
            epoch_plan(&[12, 8], 4, &mut rng)
                .into_iter()
                .map(|it| it.collect::<Vec<Vec<usize>>>())
                .collect::<Vec<_>>()
        };
        assert_eq!(draw(123), draw(123));
        assert_ne!(draw(123), draw(124), "different seeds should shuffle differently");
    }

    #[test]
    fn fold_mean_aggregates() {
        let mk = |auc: f32| TrainLog {
            algo: "x".into(),
            epochs: vec![EpochLog {
                epoch: 0,
                train_loss: 1.0,
                test_auc: auc,
                test_acc: 0.5,
                test_ppl: f32::NAN,
                bytes_up: 0,
                bytes_down: 0,
                sites_live: 2,
                timing: StepTiming::default(),
                mean_eff_rank: vec![],
            }],
            sim_time_s: 0.0,
            entry_names: vec![],
        };
        let m = fold_mean_auc(&[mk(0.8), mk(0.9)]);
        assert!((m[0].0 - 0.85).abs() < 1e-6);
        assert!(m[0].1 > 0.0);
    }

    /// Regression for the hardcoded local step size: `--lr 1e-3
    /// --sync-every 3` must apply 1e-3 in the periodic schedule's local
    /// phase, i.e. `local_update` moves every parameter by exactly
    /// `-lr * grad` for the lr it is handed — and lr 0 must be a no-op
    /// (under the old hardcoded 1e-4 it was not).
    #[test]
    fn local_update_honors_the_spec_lr() {
        let mut rng = Rng::new(11);
        let full = mnist_like(40, &mut rng);
        let batch = full.batch(&(0..16).collect::<Vec<_>>());
        let model = small_mlp(3);
        let shapes = model.param_shapes();

        // lr = 0: parameters must be bit-identical after the "update".
        let mut frozen = model.clone();
        local_update(&mut frozen, &batch, &shapes, 0.0, &mut Workspace::new());
        for (p, q) in model.params().into_iter().zip(frozen.params()) {
            assert_eq!(p, q, "lr=0 local update moved parameters");
        }

        // lr = 1e-3: new params == old params - lr * grads, computed
        // through the same stats path.
        let lr = 1e-3f32;
        let stats = model.local_stats(&batch);
        let rows = stats.entries.last().unwrap().d.rows() as f32;
        let grads = stats.assemble_grads(&shapes, 1.0 / rows, 1.0 / rows);
        let mut expect: Vec<Matrix> = model.params().into_iter().cloned().collect();
        for (p, g) in expect.iter_mut().zip(&grads) {
            p.axpy(-lr, g);
        }
        let mut stepped = model.clone();
        local_update(&mut stepped, &batch, &shapes, lr, &mut Workspace::new());
        for (i, (p, e)) in stepped.params().iter().zip(&expect).enumerate() {
            assert!(p.max_abs_diff(e) < 1e-7, "param {i} ignored lr");
        }
    }

    /// lr = 0 must be a no-op for the sparse error-feedback protocols
    /// too: their residual (and, for DGC, momentum) state may churn
    /// internally, but with a zero step size the evaluated parameters
    /// never move, so every epoch reports bit-identical test metrics on
    /// the frozen model. (train_loss can drift slightly with the epoch's
    /// shuffled batch grouping; the test metrics cannot.)
    #[test]
    fn sparse_protocols_with_zero_lr_freeze_the_model() {
        let mut rng = Rng::new(12);
        let full = mnist_like(260, &mut rng);
        let train_ds = full.subset(&(0..200).collect::<Vec<_>>());
        let test_ds = full.subset(&(200..260).collect::<Vec<_>>());
        let shards = split_by_label(&train_ds.labels, 10, 2);
        for algo in [
            AlgoSpec::Dgc { density: 25.0 },
            AlgoSpec::Vbc { lambda: 2.0 },
            AlgoSpec::AdaComp { bin: 64 },
        ] {
            let name = algo.name();
            let mut s = spec(algo, 3);
            s.lr = 0.0;
            let log = train(small_mlp(5), &s, &train_ds, &shards, &test_ds);
            let first = &log.epochs[0];
            for e in &log.epochs[1..] {
                assert_eq!(e.test_auc, first.test_auc, "{name} moved params under lr=0");
                assert_eq!(e.test_acc, first.test_acc, "{name} moved params under lr=0");
            }
            // The no-op is an optimizer property, not silence on the wire:
            // the protocols still exchange their sparse frames every step.
            assert!(log.epochs.iter().all(|e| e.bytes_up > 0), "{name} shipped nothing");
        }
    }

    /// The lm task trains end-to-end through the generic trainer: loss
    /// falls and the token-aware evaluation reports finite per-token
    /// accuracy and perplexity (better than the uniform model's = vocab).
    #[test]
    fn lm_task_trains_and_reports_token_metrics() {
        let task = build_task("lm", Scale::Quick, 2, 7).expect("lm task");
        let (train_ds, test_ds, shards, model) = match task {
            TrainTask::Tokens { train_ds, test_ds, shards, model } => {
                (train_ds, test_ds, shards, model)
            }
            _ => panic!("lm must build a token task"),
        };
        assert_eq!(shards.len(), 2);
        let spec = TrainSpec {
            algo: AlgoSpec::Dad,
            epochs: 3,
            batch_per_site: 8,
            lr: 5e-3,
            ..Default::default()
        };
        let log = train(model, &spec, &train_ds, &shards, &test_ds);
        let first = log.epochs.first().unwrap();
        let last = log.epochs.last().unwrap();
        assert!(
            last.train_loss < first.train_loss,
            "LM loss did not fall: {} -> {}",
            first.train_loss,
            last.train_loss
        );
        for e in &log.epochs {
            assert!(e.test_ppl.is_finite() && e.test_ppl > 1.0, "ppl {}", e.test_ppl);
            assert!((0.0..=1.0).contains(&e.test_acc));
            assert!(e.bytes_up > 0, "dad on tokens must ship stats");
        }
        // Trained perplexity beats the uniform model over the tiny vocab.
        assert!(last.test_ppl < 11.0, "ppl {} not better than uniform", last.test_ppl);
        // 4 entries per block x 2 blocks + lm_head.
        assert_eq!(log.entry_names.len(), 9);
    }

    /// The CSV log carries the ppl column and one eff_rank column per
    /// stats entry, padding NaN where telemetry is absent — rank-dAD
    /// transformer runs (20+ entries) stay analyzable.
    #[test]
    fn write_csv_emits_ppl_and_per_entry_rank_columns() {
        let log = TrainLog {
            algo: "rank-dad:4".into(),
            epochs: vec![EpochLog {
                epoch: 0,
                train_loss: 1.5,
                test_auc: 0.9,
                test_acc: 0.8,
                test_ppl: 12.5,
                bytes_up: 10,
                bytes_down: 20,
                sites_live: 2,
                timing: StepTiming {
                    compute_s: 1.5,
                    comms_s: 0.25,
                    stall_s: 0.125,
                    compress_s: 0.0625,
                },
                mean_eff_rank: vec![2.5], // shorter than entry_names: pad NaN
            }],
            sim_time_s: 0.0,
            entry_names: vec!["l0".into(), "l1".into()],
        };
        let dir = std::env::temp_dir().join("dad_trainlog_csv_test");
        let path = dir.join("log.csv");
        log.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert_eq!(
            header,
            "epoch,algo,train_loss,test_auc,test_acc,test_ppl,bytes_up,bytes_down,\
             sites_live,compute_s,comms_s,stall_s,compress_s,eff_rank_l0,eff_rank_l1"
        );
        let row = lines.next().unwrap();
        assert_eq!(
            row,
            "0,rank-dad:4,1.5,0.9,0.8,12.5,10,20,2,1.500000,0.250000,0.125000,0.062500,2.5,NaN"
        );
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Golden header: downstream CSV consumers (the CI smoke scripts, the
    /// EXPERIMENTS notebooks) key on column *positions* — `sites_live`
    /// must stay at column 9 (1-based) and the `StepTiming` breakdown at
    /// columns 10-13, with the variable `eff_rank_*` tail strictly after
    /// every fixed column. Renaming or reordering anything here is a
    /// breaking change that must be made deliberately, in lockstep with
    /// those consumers.
    #[test]
    fn csv_header_column_positions_are_stable() {
        let log = TrainLog {
            algo: "dad".into(),
            epochs: vec![],
            sim_time_s: 0.0,
            entry_names: vec!["l0".into()],
        };
        let dir = std::env::temp_dir().join("dad_trainlog_header_test");
        let path = dir.join("header.csv");
        log.write_csv(path.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let cols: Vec<&str> = text.lines().next().unwrap().split(',').collect();
        let fixed = [
            "epoch",
            "algo",
            "train_loss",
            "test_auc",
            "test_acc",
            "test_ppl",
            "bytes_up",
            "bytes_down",
            "sites_live",
            "compute_s",
            "comms_s",
            "stall_s",
            "compress_s",
        ];
        assert_eq!(&cols[..fixed.len()], &fixed, "fixed CSV columns drifted");
        assert_eq!(cols[8], "sites_live", "sites_live left column 9");
        assert_eq!(
            &cols[fixed.len()..],
            &["eff_rank_l0"],
            "eff_rank_* tail must start right after the fixed columns"
        );
        let _ = std::fs::remove_dir_all(dir);
    }

    /// `edad` + the transformer LM is rejected by the shared up-front
    /// validation both CLI spellings call (`dad train` and `dad serve`);
    /// every other combination passes.
    #[test]
    fn edad_lm_rejected_up_front() {
        let err = validate_dataset_algo("lm", &AlgoSpec::Edad).unwrap_err();
        assert!(err.contains("edad"), "unclear error: {err}");
        assert!(validate_dataset_algo("lm", &AlgoSpec::Dad).is_ok());
        assert!(validate_dataset_algo("lm", &AlgoSpec::PowerSgd { rank: 4 }).is_ok());
        assert!(validate_dataset_algo("mnist", &AlgoSpec::Edad).is_ok());
        assert!(validate_dataset_algo("arabic", &AlgoSpec::Edad).is_ok());
    }
}

//! Datasets and partitioning: synthetic MNIST/UEA analogs (DESIGN.md
//! "Substitutions"), the LM token-stream dataset, non-IID label sharding,
//! contiguous stream sharding, k-fold CV and batching.

pub mod partition;
pub mod synth;
pub mod tokens;

pub use partition::{
    kfold, split_by_label, split_iid, split_quantity_skew, BatchIter, Partition,
};
pub use synth::{
    arabic_digits_like, mnist_like, natops_like, pems_sf_like, pen_digits_like, token_corpus,
    DenseDataset, SeqDataset,
};
pub use tokens::TokenDataset;

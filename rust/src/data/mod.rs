//! Datasets and partitioning: synthetic MNIST/UEA analogs (DESIGN.md
//! "Substitutions"), non-IID label sharding, k-fold CV and batching.

pub mod partition;
pub mod synth;

pub use partition::{kfold, split_by_label, split_iid, BatchIter};
pub use synth::{
    arabic_digits_like, mnist_like, natops_like, pems_sf_like, pen_digits_like, token_corpus,
    DenseDataset, SeqDataset,
};

//! Data partitioning: non-IID label splits (the paper's hard case — "no one
//! class can be found on more than one site"), IID splits, and k-fold
//! cross-validation (k=5 in all paper experiments).

use crate::tensor::Rng;

/// Split example indices across `n_sites` so that each *class* lives on
/// exactly one site (paper section 4.1.1). Classes are dealt round-robin to
/// sites; examples follow their class.
pub fn split_by_label(labels: &[usize], classes: usize, n_sites: usize) -> Vec<Vec<usize>> {
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_sites];
    for (i, &l) in labels.iter().enumerate() {
        shards[l % n_sites].push(i);
        let _ = classes;
    }
    shards
}

/// Quantity-skewed split: shuffle, then deal geometrically shrinking
/// shards — site i receives a fraction proportional to `ratio^i` of the
/// examples. `ratio = 1` is a balanced IID split; `ratio = 0.5` halves
/// each successive site's share. This is the "quantity shift" axis of the
/// chaos recipes (`crate::scenario`): heterogeneous shard sizes stress the
/// row-weighted loss/gradient averaging and shrink the lockstep step count
/// to the smallest shard's batch budget.
pub fn split_quantity_skew(
    n: usize,
    n_sites: usize,
    ratio: f32,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    assert!(n_sites >= 1, "a split needs at least one site");
    assert!(ratio > 0.0, "quantity-skew ratio must be positive, got {ratio}");
    let perm = rng.permutation(n);
    let weights: Vec<f64> = (0..n_sites).map(|i| (ratio as f64).powi(i as i32)).collect();
    let total: f64 = weights.iter().sum();
    let mut shards = Vec::with_capacity(n_sites);
    let mut cum = 0.0f64;
    let mut start = 0usize;
    for (i, w) in weights.iter().enumerate() {
        cum += w / total;
        let end = if i + 1 == n_sites { n } else { (cum * n as f64).round() as usize };
        let end = end.clamp(start, n);
        shards.push(perm[start..end].to_vec());
        start = end;
    }
    shards
}

/// How training examples are dealt across sites — the partition axis a
/// chaos recipe (or `--partition`) can override on top of a task's native
/// sharding. Applied identically in every process from the run seed, so
/// the lockstep batch schedule survives the override.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    /// The task's native split (label-disjoint for classification tasks,
    /// contiguous token streams for the LM).
    Default,
    /// Shuffle and deal round-robin ([`split_iid`]).
    Iid,
    /// Geometrically shrinking shards ([`split_quantity_skew`]) with the
    /// given per-site ratio.
    QuantitySkew(f32),
}

/// Deterministic stream tag for the partition override's RNG: every
/// process derives the identical deal from the run seed without touching
/// the training RNG sequence.
const PARTITION_STREAM: u64 = 0x7061_7274;

impl Partition {
    /// Parse the CLI/recipe spelling: `default | iid | skew:<ratio>`.
    pub fn parse(s: &str) -> Result<Partition, String> {
        if s == "default" {
            return Ok(Partition::Default);
        }
        if s == "iid" {
            return Ok(Partition::Iid);
        }
        if let Some(r) = s.strip_prefix("skew:") {
            let ratio: f32 = r
                .parse()
                .map_err(|_| format!("bad quantity-skew ratio {r:?} (want e.g. skew:0.5)"))?;
            if !(ratio > 0.0) {
                return Err(format!("quantity-skew ratio must be positive, got {ratio}"));
            }
            return Ok(Partition::QuantitySkew(ratio));
        }
        Err(format!("unknown partition {s:?} (default | iid | skew:<ratio>)"))
    }

    /// The canonical spelling [`Partition::parse`] round-trips.
    pub fn name(&self) -> String {
        match self {
            Partition::Default => "default".into(),
            Partition::Iid => "iid".into(),
            Partition::QuantitySkew(r) => format!("skew:{r}"),
        }
    }

    /// Re-deal the examples held by `shards` under this partition. The
    /// example set is preserved exactly (flattened, sorted, re-dealt);
    /// `Default` is the identity. Deterministic in `seed` — every process
    /// in a remote run applies the same override and stays in lockstep.
    pub fn apply(&self, shards: Vec<Vec<usize>>, seed: u64) -> Vec<Vec<usize>> {
        let n_sites = shards.len();
        if matches!(self, Partition::Default) || n_sites == 0 {
            return shards;
        }
        let mut all: Vec<usize> = shards.into_iter().flatten().collect();
        all.sort_unstable();
        let mut rng = Rng::with_stream(seed, PARTITION_STREAM);
        let dealt = match self {
            Partition::Default => unreachable!("handled above"),
            Partition::Iid => split_iid(all.len(), n_sites, &mut rng),
            Partition::QuantitySkew(r) => split_quantity_skew(all.len(), n_sites, *r, &mut rng),
        };
        dealt.into_iter().map(|shard| shard.into_iter().map(|p| all[p]).collect()).collect()
    }
}

/// IID split: shuffle and deal round-robin.
pub fn split_iid(n: usize, n_sites: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let perm = rng.permutation(n);
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_sites];
    for (pos, &i) in perm.iter().enumerate() {
        shards[pos % n_sites].push(i);
    }
    shards
}

/// k-fold split: returns (train_idx, test_idx) per fold, stratification-free
/// (the paper reports plain 5-fold CV).
pub fn kfold(n: usize, k: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<usize>)> {
    let perm = rng.permutation(n);
    let fold_size = n / k;
    (0..k)
        .map(|f| {
            let lo = f * fold_size;
            let hi = if f + 1 == k { n } else { lo + fold_size };
            let test: Vec<usize> = perm[lo..hi].to_vec();
            let train: Vec<usize> =
                perm[..lo].iter().chain(&perm[hi..]).copied().collect();
            (train, test)
        })
        .collect()
}

/// Mini-batch index iterator: shuffles each epoch, yields fixed-size chunks
/// (dropping the ragged tail, as the paper's fixed batch size implies).
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
}

impl BatchIter {
    /// Fresh shuffled iterator over `n` examples in `batch`-size chunks;
    /// consumes exactly one permutation from `rng`.
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> Self {
        BatchIter { order: rng.permutation(n), batch, cursor: 0 }
    }

    /// Number of full batches this epoch will yield.
    pub fn n_batches(&self) -> usize {
        self.order.len() / self.batch
    }
}

impl Iterator for BatchIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.cursor + self.batch > self.order.len() {
            return None;
        }
        let out = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_split_is_disjoint_by_class() {
        let labels: Vec<usize> = (0..100).map(|i| i % 10).collect();
        let shards = split_by_label(&labels, 10, 2);
        // Every class appears on exactly one site.
        for (s, shard) in shards.iter().enumerate() {
            for &i in shard {
                assert_eq!(labels[i] % 2, s);
            }
        }
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn iid_split_balanced() {
        let mut rng = Rng::new(1);
        let shards = split_iid(101, 4, &mut rng);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 101);
        assert!(sizes.iter().all(|&s| (25..=26).contains(&s)));
    }

    #[test]
    fn kfold_partitions() {
        let mut rng = Rng::new(2);
        let folds = kfold(103, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 103];
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 103);
            for &i in test {
                seen[i] += 1;
            }
            // Disjointness within a fold.
            let mut t = train.clone();
            t.extend(test);
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 103);
        }
        // Every example is tested exactly once.
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn quantity_skew_shrinks_geometrically_and_partitions() {
        let mut rng = Rng::new(9);
        let shards = split_quantity_skew(100, 3, 0.5, &mut rng);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 100);
        assert!(sizes[0] > sizes[1] && sizes[1] > sizes[2], "not shrinking: {sizes:?}");
        // Roughly 4:2:1 proportions.
        assert!((55..=60).contains(&sizes[0]), "{sizes:?}");
        let mut all: Vec<usize> = shards.concat();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
        // ratio = 1 is balanced.
        let even = split_quantity_skew(99, 3, 1.0, &mut Rng::new(9));
        assert!(even.iter().all(|s| (32..=34).contains(&s.len())));
    }

    #[test]
    fn partition_parse_roundtrips_and_apply_is_deterministic() {
        assert_eq!(Partition::parse("default").unwrap(), Partition::Default);
        assert_eq!(Partition::parse("iid").unwrap(), Partition::Iid);
        assert_eq!(Partition::parse("skew:0.5").unwrap(), Partition::QuantitySkew(0.5));
        assert!(Partition::parse("skew:-1").is_err());
        assert!(Partition::parse("zipf").is_err());
        for s in ["default", "iid", "skew:0.5"] {
            assert_eq!(Partition::parse(s).unwrap().name(), s);
        }
        let labels: Vec<usize> = (0..60).map(|i| i % 6).collect();
        let native = split_by_label(&labels, 6, 3);
        assert_eq!(Partition::Default.apply(native.clone(), 7), native);
        let a = Partition::QuantitySkew(0.5).apply(native.clone(), 7);
        let b = Partition::QuantitySkew(0.5).apply(native.clone(), 7);
        assert_eq!(a, b, "same seed must re-deal identically");
        let c = Partition::QuantitySkew(0.5).apply(native.clone(), 8);
        assert_ne!(a, c, "different seeds should re-deal differently");
        // The example set is preserved exactly.
        let mut all: Vec<usize> = a.concat();
        all.sort_unstable();
        assert_eq!(all, (0..60).collect::<Vec<_>>());
        assert!(a[0].len() > a[2].len());
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut rng = Rng::new(3);
        let it = BatchIter::new(70, 32, &mut rng);
        assert_eq!(it.n_batches(), 2);
        let batches: Vec<Vec<usize>> = it.collect();
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.len() == 32));
        let mut all: Vec<usize> = batches.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 64); // no repeats within an epoch
    }
}

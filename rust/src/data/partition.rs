//! Data partitioning: non-IID label splits (the paper's hard case — "no one
//! class can be found on more than one site"), IID splits, and k-fold
//! cross-validation (k=5 in all paper experiments).

use crate::tensor::Rng;

/// Split example indices across `n_sites` so that each *class* lives on
/// exactly one site (paper section 4.1.1). Classes are dealt round-robin to
/// sites; examples follow their class.
pub fn split_by_label(labels: &[usize], classes: usize, n_sites: usize) -> Vec<Vec<usize>> {
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_sites];
    for (i, &l) in labels.iter().enumerate() {
        shards[l % n_sites].push(i);
        let _ = classes;
    }
    shards
}

/// IID split: shuffle and deal round-robin.
pub fn split_iid(n: usize, n_sites: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let perm = rng.permutation(n);
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n_sites];
    for (pos, &i) in perm.iter().enumerate() {
        shards[pos % n_sites].push(i);
    }
    shards
}

/// k-fold split: returns (train_idx, test_idx) per fold, stratification-free
/// (the paper reports plain 5-fold CV).
pub fn kfold(n: usize, k: usize, rng: &mut Rng) -> Vec<(Vec<usize>, Vec<usize>)> {
    let perm = rng.permutation(n);
    let fold_size = n / k;
    (0..k)
        .map(|f| {
            let lo = f * fold_size;
            let hi = if f + 1 == k { n } else { lo + fold_size };
            let test: Vec<usize> = perm[lo..hi].to_vec();
            let train: Vec<usize> =
                perm[..lo].iter().chain(&perm[hi..]).copied().collect();
            (train, test)
        })
        .collect()
}

/// Mini-batch index iterator: shuffles each epoch, yields fixed-size chunks
/// (dropping the ragged tail, as the paper's fixed batch size implies).
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
}

impl BatchIter {
    /// Fresh shuffled iterator over `n` examples in `batch`-size chunks;
    /// consumes exactly one permutation from `rng`.
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> Self {
        BatchIter { order: rng.permutation(n), batch, cursor: 0 }
    }

    /// Number of full batches this epoch will yield.
    pub fn n_batches(&self) -> usize {
        self.order.len() / self.batch
    }
}

impl Iterator for BatchIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.cursor + self.batch > self.order.len() {
            return None;
        }
        let out = self.order[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_split_is_disjoint_by_class() {
        let labels: Vec<usize> = (0..100).map(|i| i % 10).collect();
        let shards = split_by_label(&labels, 10, 2);
        // Every class appears on exactly one site.
        for (s, shard) in shards.iter().enumerate() {
            for &i in shard {
                assert_eq!(labels[i] % 2, s);
            }
        }
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 100);
    }

    #[test]
    fn iid_split_balanced() {
        let mut rng = Rng::new(1);
        let shards = split_iid(101, 4, &mut rng);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 101);
        assert!(sizes.iter().all(|&s| (25..=26).contains(&s)));
    }

    #[test]
    fn kfold_partitions() {
        let mut rng = Rng::new(2);
        let folds = kfold(103, 5, &mut rng);
        assert_eq!(folds.len(), 5);
        let mut seen = vec![0usize; 103];
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 103);
            for &i in test {
                seen[i] += 1;
            }
            // Disjointness within a fold.
            let mut t = train.clone();
            t.extend(test);
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 103);
        }
        // Every example is tested exactly once.
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn batch_iter_covers_epoch() {
        let mut rng = Rng::new(3);
        let it = BatchIter::new(70, 32, &mut rng);
        assert_eq!(it.n_batches(), 2);
        let batches: Vec<Vec<usize>> = it.collect();
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.len() == 32));
        let mut all: Vec<usize> = batches.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 64); // no repeats within an epoch
    }
}

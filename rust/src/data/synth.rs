//! Synthetic dataset generators — stand-ins for MNIST and the UEA archive
//! (no dataset downloads in this environment; see DESIGN.md "Substitutions").
//!
//! Both generators produce class-separable data with controlled difficulty:
//! the phenomena under test (non-IID label splits hurting local training,
//! dAD == pooled equivalence, gradient-rank collapse during training) depend
//! on the statistical *structure*, not on the actual pixels/signals.

use crate::nn::loss::one_hot;
use crate::nn::model::Batch;
use crate::tensor::{Matrix, Rng};

/// Dense classification dataset (the MNIST analog).
#[derive(Clone)]
pub struct DenseDataset {
    /// Feature matrix, one example per row.
    pub x: Matrix,
    /// Class label per example.
    pub labels: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
    /// Dataset name for logs/CSVs.
    pub name: &'static str,
}

/// Sequence classification dataset (the UEA analogs): per-example (T, c_in)
/// trajectories stored contiguously.
#[derive(Clone)]
pub struct SeqDataset {
    /// xs[i] is example i's (T, c_in) trajectory.
    pub xs: Vec<Matrix>,
    /// Class label per example.
    pub labels: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
    /// Timesteps per trajectory.
    pub seq_len: usize,
    /// Input channels per timestep.
    pub channels: usize,
    /// Dataset name for logs/CSVs.
    pub name: &'static str,
}

/// MNIST-analog: 784-dim "images", 10 classes. Each class has a smooth
/// prototype (mixture of low-frequency 2D gaussian bumps on the 28x28 grid);
/// samples are prototype + pixel noise + random intensity, clipped to [0,1]
/// like normalized MNIST.
pub fn mnist_like(n: usize, rng: &mut Rng) -> DenseDataset {
    let classes = 10;
    let side = 28;
    let dim = side * side;
    // Class prototypes.
    let mut protos = Vec::with_capacity(classes);
    for _ in 0..classes {
        let mut p = vec![0.0f32; dim];
        let n_bumps = 3 + rng.below(3);
        for _ in 0..n_bumps {
            let cx = rng.uniform_in(4.0, 24.0);
            let cy = rng.uniform_in(4.0, 24.0);
            let sx = rng.uniform_in(2.0, 5.0);
            let sy = rng.uniform_in(2.0, 5.0);
            let amp = rng.uniform_in(0.5, 1.0);
            for yy in 0..side {
                for xx in 0..side {
                    let dx = (xx as f32 - cx) / sx;
                    let dy = (yy as f32 - cy) / sy;
                    p[yy * side + xx] += amp * (-(dx * dx + dy * dy) / 2.0).exp();
                }
            }
        }
        protos.push(p);
    }
    let mut x = Matrix::zeros(n, dim);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(classes);
        labels.push(c);
        let gain = rng.uniform_in(0.6, 1.4);
        // Noise level chosen so a linear probe cannot saturate instantly:
        // the paper's AUC curves need a task that takes epochs to fit.
        for j in 0..dim {
            let v = gain * protos[c][j] + 0.5 * rng.normal();
            x[(i, j)] = v.clamp(0.0, 1.0);
        }
    }
    DenseDataset { x, labels, classes, name: "mnist-like" }
}

/// UEA-analog family: class prototypes are per-channel sums of sinusoids
/// with class-specific frequencies/phases; samples add AR(1) noise.
fn uea_like(
    name: &'static str,
    n: usize,
    seq_len: usize,
    channels: usize,
    classes: usize,
    rng: &mut Rng,
) -> SeqDataset {
    // Prototype spectra per (class, channel): 2 sinusoids each.
    struct Proto {
        f1: f32,
        p1: f32,
        a1: f32,
        f2: f32,
        p2: f32,
        a2: f32,
    }
    let mut protos: Vec<Vec<Proto>> = Vec::with_capacity(classes);
    for _ in 0..classes {
        protos.push(
            (0..channels)
                .map(|_| Proto {
                    f1: rng.uniform_in(0.5, 3.0),
                    p1: rng.uniform_in(0.0, std::f32::consts::TAU),
                    a1: rng.uniform_in(0.4, 1.0),
                    f2: rng.uniform_in(3.0, 8.0),
                    p2: rng.uniform_in(0.0, std::f32::consts::TAU),
                    a2: rng.uniform_in(0.1, 0.4),
                })
                .collect(),
        );
    }
    let mut xs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        labels.push(c);
        let mut m = Matrix::zeros(seq_len, channels);
        let warp = rng.uniform_in(0.9, 1.1); // mild time warping per sample
        for ch in 0..channels {
            let p = &protos[c][ch];
            let mut ar = 0.0f32; // AR(1) noise state
            for t in 0..seq_len {
                let tt = warp * t as f32 / seq_len as f32 * std::f32::consts::TAU;
                ar = 0.7 * ar + 0.3 * rng.normal();
                let clean = p.a1 * (p.f1 * tt + p.p1).sin() + p.a2 * (p.f2 * tt + p.p2).sin();
                m[(t, ch)] = clean + 0.25 * ar;
            }
        }
        xs.push(m);
    }
    SeqDataset { xs, labels, classes, seq_len, channels, name }
}

/// SpokenArabicDigits analog: 13 MFCC-like channels, T=40, 10 digits.
pub fn arabic_digits_like(n: usize, rng: &mut Rng) -> SeqDataset {
    uea_like("arabic-digits-like", n, 40, 13, 10, rng)
}

/// NATOPS analog: 24 sensor channels, T=51, 6 gesture classes.
pub fn natops_like(n: usize, rng: &mut Rng) -> SeqDataset {
    uea_like("natops-like", n, 51, 24, 6, rng)
}

/// PenDigits analog: 2 pen-trajectory channels, T=8, 10 digits.
pub fn pen_digits_like(n: usize, rng: &mut Rng) -> SeqDataset {
    uea_like("pen-digits-like", n, 8, 2, 10, rng)
}

/// PEMS-SF analog: occupancy-rate channels, T=24, 7 weekday classes.
/// (The real archive has 963 channels; 144 keeps the CPU budget sane while
/// preserving the channels >> classes regime — see DESIGN.md.)
pub fn pems_sf_like(n: usize, rng: &mut Rng) -> SeqDataset {
    uea_like("pems-sf-like", n, 24, 144, 7, rng)
}

impl DenseDataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Assemble a batch from example indices.
    pub fn batch(&self, idx: &[usize]) -> Batch {
        let x = self.x.gather_rows(idx);
        let labels: Vec<usize> = idx.iter().map(|&i| self.labels[i]).collect();
        Batch::Dense { x, y: one_hot(&labels, self.classes) }
    }

    /// Subset view by indices (k-fold splits, site shards).
    pub fn subset(&self, idx: &[usize]) -> DenseDataset {
        DenseDataset {
            x: self.x.gather_rows(idx),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
            classes: self.classes,
            name: self.name,
        }
    }
}

impl SeqDataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when the dataset holds no examples.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Assemble a batch: `xs[t]` is (|idx|, channels).
    pub fn batch(&self, idx: &[usize]) -> Batch {
        let xs: Vec<Matrix> = (0..self.seq_len)
            .map(|t| {
                let mut m = Matrix::zeros(idx.len(), self.channels);
                for (row, &i) in idx.iter().enumerate() {
                    m.row_mut(row).copy_from_slice(self.xs[i].row(t));
                }
                m
            })
            .collect();
        let labels: Vec<usize> = idx.iter().map(|&i| self.labels[i]).collect();
        Batch::Seq { xs, y: one_hot(&labels, self.classes) }
    }

    /// Subset view by indices (k-fold splits, site shards).
    pub fn subset(&self, idx: &[usize]) -> SeqDataset {
        SeqDataset {
            xs: idx.iter().map(|&i| self.xs[i].clone()).collect(),
            labels: idx.iter().map(|&i| self.labels[i]).collect(),
            classes: self.classes,
            seq_len: self.seq_len,
            channels: self.channels,
            name: self.name,
        }
    }
}

/// Synthetic token corpus for the transformer driver: a periodic formal
/// language with per-position structure (so an LM can actually learn it).
pub fn token_corpus(n_tokens: usize, vocab: usize, rng: &mut Rng) -> Vec<u32> {
    let mut out = Vec::with_capacity(n_tokens);
    let mut state = rng.below(vocab) as u32;
    for _ in 0..n_tokens {
        // Markov structure: next = f(prev) with prob 0.8, noise otherwise.
        // (Depends only on the previous token, so any window of the stream
        // is equally learnable — an LM can reach ~H = 0.8 ln(1/0.8) +
        // 0.2 ln(V) nats by mastering the bigram table.)
        let det = (state.wrapping_mul(31).wrapping_add(7)) % vocab as u32;
        state = if rng.uniform() < 0.8 { det } else { rng.below(vocab) as u32 };
        out.push(state);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_range() {
        let mut rng = Rng::new(1);
        let ds = mnist_like(200, &mut rng);
        assert_eq!(ds.x.shape(), (200, 784));
        assert_eq!(ds.labels.len(), 200);
        assert!(ds.labels.iter().all(|&l| l < 10));
        assert!(ds.x.data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // All classes present in a reasonable sample.
        let mut seen = vec![false; 10];
        for &l in &ds.labels {
            seen[l] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn classes_are_separable() {
        // A nearest-prototype classifier on class means must beat chance by
        // a wide margin — otherwise the dataset can't support the paper's
        // AUC curves.
        let mut rng = Rng::new(2);
        let ds = mnist_like(600, &mut rng);
        let mut means = vec![vec![0.0f32; 784]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..ds.len() {
            counts[ds.labels[i]] += 1;
            for j in 0..784 {
                means[ds.labels[i]][j] += ds.x[(i, j)];
            }
        }
        for c in 0..10 {
            for v in &mut means[c] {
                *v /= counts[c].max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.len() {
            let mut best = (f32::MAX, 0);
            for c in 0..10 {
                let d2: f32 =
                    (0..784).map(|j| (ds.x[(i, j)] - means[c][j]).powi(2)).sum();
                if d2 < best.0 {
                    best = (d2, c);
                }
            }
            if best.1 == ds.labels[i] {
                correct += 1;
            }
        }
        let acc = correct as f32 / ds.len() as f32;
        assert!(acc > 0.8, "prototype accuracy too low: {acc}");
    }

    #[test]
    fn seq_datasets_shapes() {
        let mut rng = Rng::new(3);
        let ds = arabic_digits_like(50, &mut rng);
        assert_eq!(ds.xs[0].shape(), (40, 13));
        assert_eq!(ds.classes, 10);
        let n = natops_like(20, &mut rng);
        assert_eq!(n.xs[0].shape(), (51, 24));
        assert_eq!(n.classes, 6);
        let p = pen_digits_like(20, &mut rng);
        assert_eq!(p.xs[0].shape(), (8, 2));
        let pe = pems_sf_like(10, &mut rng);
        assert_eq!(pe.xs[0].shape(), (24, 144));
        assert_eq!(pe.classes, 7);
    }

    #[test]
    fn seq_batch_layout() {
        let mut rng = Rng::new(4);
        let ds = pen_digits_like(30, &mut rng);
        let b = ds.batch(&[0, 5, 7]);
        match b {
            Batch::Seq { xs, y } => {
                assert_eq!(xs.len(), 8);
                assert_eq!(xs[0].shape(), (3, 2));
                assert_eq!(y.shape(), (3, 10));
                // Row 1 of timestep 3 must be example 5's t=3 row.
                assert_eq!(xs[3].row(1), ds.xs[5].row(3));
            }
            _ => panic!("expected Seq"),
        }
    }

    #[test]
    fn token_corpus_learnable_structure() {
        let mut rng = Rng::new(5);
        let toks = token_corpus(10_000, 64, &mut rng);
        assert!(toks.iter().all(|&t| t < 64));
        // The deterministic transition must dominate: measure how often
        // next == f(prev).
        let mut hits = 0;
        for i in 1..toks.len() {
            let det = (toks[i - 1].wrapping_mul(31).wrapping_add(7)) % 64;
            if toks[i] == det {
                hits += 1;
            }
        }
        let rate = hits as f32 / (toks.len() - 1) as f32;
        assert!(rate > 0.7, "structure rate {rate}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = mnist_like(20, &mut Rng::new(9));
        let b = mnist_like(20, &mut Rng::new(9));
        assert_eq!(a.x, b.x);
        assert_eq!(a.labels, b.labels);
    }
}

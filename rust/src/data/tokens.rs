//! Token-stream dataset for the transformer LM workload.
//!
//! A [`TokenDataset`] views one contiguous token stream (from
//! [`crate::data::token_corpus`]) as fixed-length next-token-prediction
//! windows: example `i` is the `seq_len` tokens starting at `i * seq_len`,
//! with targets shifted one position right. Windows never overlap, so the
//! per-site shards produced by [`TokenDataset::stream_shards`] are disjoint
//! contiguous slices of the stream — the token analog of the paper's
//! "sites never pool data" setting, and deterministic (no RNG) so every
//! process in a multi-process run derives identical shards from the seed.

use crate::nn::model::Batch;

/// Next-token-prediction dataset over one contiguous token stream.
///
/// `len()` counts windows (examples); [`TokenDataset::labels`] is the
/// *per-token* target stream (`len() * seq_len` entries, window-major) —
/// aligned row-for-row with the `(len * seq_len, vocab)` score matrix the
/// transformer's `predict` produces, which is what lets the generic
/// evaluation path compute per-token accuracy/AUC/perplexity over it.
#[derive(Clone)]
pub struct TokenDataset {
    /// The backing token stream (`n_windows * seq_len + 1` tokens used).
    pub tokens: Vec<u32>,
    /// Vocabulary size (every token id is `< vocab`).
    pub vocab: usize,
    /// Tokens per window (the trained sequence length T).
    pub seq_len: usize,
    /// Number of full windows the stream supports.
    n_windows: usize,
    /// Flattened next-token targets, window-major: entry `w * seq_len + k`
    /// is window `w`'s target at position `k`.
    labels: Vec<usize>,
    /// Dataset name for logs/CSVs.
    pub name: &'static str,
}

impl TokenDataset {
    /// Wrap a token stream as non-overlapping `seq_len`-token windows.
    /// The last `(tokens.len() - 1) % seq_len` tokens (if any) are unused:
    /// every window needs `seq_len` inputs plus one lookahead target.
    pub fn new(tokens: Vec<u32>, vocab: usize, seq_len: usize) -> TokenDataset {
        assert!(seq_len >= 1, "token windows need at least one position");
        assert!(
            tokens.len() > seq_len,
            "stream of {} tokens cannot fill a {}-token window plus target",
            tokens.len(),
            seq_len
        );
        let n_windows = (tokens.len() - 1) / seq_len;
        let mut labels = Vec::with_capacity(n_windows * seq_len);
        for w in 0..n_windows {
            for k in 0..seq_len {
                labels.push(tokens[w * seq_len + k + 1] as usize);
            }
        }
        TokenDataset { tokens, vocab, seq_len, n_windows, labels, name: "token-stream" }
    }

    /// Number of windows (examples).
    pub fn len(&self) -> usize {
        self.n_windows
    }

    /// True when the stream holds no full window.
    pub fn is_empty(&self) -> bool {
        self.n_windows == 0
    }

    /// Per-token next-token targets, window-major (`len() * seq_len`
    /// entries) — the label stream evaluation scores rows against.
    pub fn labels(&self) -> &[usize] {
        &self.labels
    }

    /// Assemble a token batch from window indices: ids/targets are
    /// `(|idx|, seq_len)` row-major, targets shifted one token right.
    pub fn batch(&self, idx: &[usize]) -> Batch {
        let t = self.seq_len;
        let mut ids = Vec::with_capacity(idx.len() * t);
        let mut targets = Vec::with_capacity(idx.len() * t);
        for &w in idx {
            assert!(w < self.n_windows, "window {w} out of range ({})", self.n_windows);
            let start = w * t;
            ids.extend_from_slice(&self.tokens[start..start + t]);
            targets.extend_from_slice(&self.tokens[start + 1..start + t + 1]);
        }
        Batch::Tokens { b: idx.len(), t, ids, targets }
    }

    /// Deterministic contiguous stream-sharding: site `s` owns a contiguous
    /// run of windows, sizes as equal as possible (earlier sites take the
    /// remainder). No RNG is consumed, so `dad train`, `dad serve` and
    /// every `dad join` derive bit-identical shards from the same stream.
    pub fn stream_shards(&self, n_sites: usize) -> Vec<Vec<usize>> {
        assert!(n_sites >= 1, "sharding needs at least one site");
        let per = self.n_windows / n_sites;
        let rem = self.n_windows % n_sites;
        let mut shards = Vec::with_capacity(n_sites);
        let mut start = 0usize;
        for s in 0..n_sites {
            let size = per + usize::from(s < rem);
            shards.push((start..start + size).collect());
            start += size;
        }
        shards
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::token_corpus;
    use crate::tensor::Rng;

    fn ds(n_tokens: usize, vocab: usize, t: usize, seed: u64) -> TokenDataset {
        let mut rng = Rng::new(seed);
        TokenDataset::new(token_corpus(n_tokens, vocab, &mut rng), vocab, t)
    }

    #[test]
    fn windows_and_labels_align() {
        let d = ds(61, 16, 6, 1);
        assert_eq!(d.len(), 10); // (61 - 1) / 6
        assert_eq!(d.labels().len(), 60);
        // Window 3's label at position 2 is the token after input (3,2).
        assert_eq!(d.labels()[3 * 6 + 2], d.tokens[3 * 6 + 3] as usize);
    }

    #[test]
    fn batch_targets_are_shifted_inputs() {
        let d = ds(100, 8, 5, 2);
        match d.batch(&[0, 7]) {
            Batch::Tokens { b, t, ids, targets } => {
                assert_eq!((b, t), (2, 5));
                assert_eq!(ids.len(), 10);
                // Within a window the target at k equals the input at k+1.
                for row in 0..2 {
                    for k in 0..4 {
                        assert_eq!(targets[row * 5 + k], ids[row * 5 + k + 1]);
                    }
                }
                assert_eq!(&ids[5..10], &d.tokens[35..40]);
            }
            _ => panic!("expected Tokens"),
        }
    }

    #[test]
    fn stream_shards_are_contiguous_disjoint_and_deterministic() {
        let d = ds(200, 16, 4, 3);
        let shards = d.stream_shards(3);
        assert_eq!(shards.len(), 3);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, d.len());
        // Sizes within one window of each other, earlier sites bigger.
        for w in shards.windows(2) {
            assert!(w[0].len() >= w[1].len());
            assert!(w[0].len() - w[1].len() <= 1);
        }
        // Contiguity and global order: concatenation is 0..len.
        let flat: Vec<usize> = shards.concat();
        assert_eq!(flat, (0..d.len()).collect::<Vec<_>>());
        // Determinism: same stream, same shards.
        assert_eq!(d.stream_shards(3), shards);
    }

    #[test]
    fn ragged_tail_is_dropped() {
        // 23 tokens, T=5: windows at 0..5, 5..10, 10..15, 15..20 (+1 target
        // lookahead each); tokens 20..23 cannot fill a fifth window.
        let d = ds(23, 8, 5, 4);
        assert_eq!(d.len(), 4);
    }
}

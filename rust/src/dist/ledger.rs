//! Byte ledger: exact accounting of every matrix that crosses the simulated
//! wire, tagged by payload kind and link direction. The paper's bandwidth
//! claims (Table in section 3, Figure "bytes" panels) are read directly off
//! this ledger — no Θ-bound is ever *assumed* by the experiments, only
//! measured and then compared against the bound.

/// Link direction in the simulated topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Direction {
    /// Site uplink to the aggregator (star topology).
    SiteToAgg,
    /// Aggregator broadcast down to the sites (star topology). Counted
    /// once per broadcast — the down-link is a shared multicast, which is
    /// what makes p2p dAD exactly half the star's total at S = 2
    /// (see `algos::p2p`).
    AggToSite,
    /// Direct peer exchange (section 3.6's decentralized variant).
    PeerToPeer,
}

/// Accumulated bytes per (tag, direction) pair.
///
/// `entries` is the source of truth for [`Ledger::breakdown`]'s
/// first-recorded row order; `index` maps a tag to its (up to three)
/// per-direction entry slots so the hot-path [`Ledger::record`] — called
/// for every frame on every link — is one hash lookup instead of a linear
/// scan over all tags ever seen.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    entries: Vec<(String, Direction, u64)>,
    index: std::collections::HashMap<String, [Option<u32>; 3]>,
}

/// Array slot for a direction in the ledger's per-tag index.
fn dir_slot(dir: Direction) -> usize {
    match dir {
        Direction::SiteToAgg => 0,
        Direction::AggToSite => 1,
        Direction::PeerToPeer => 2,
    }
}

impl Ledger {
    /// Empty ledger.
    pub fn new() -> Self {
        Ledger::default()
    }

    /// Add `bytes` under (tag, dir), merging with an existing row. The
    /// merge path (every frame after a tag's first) allocates nothing:
    /// the `&str` keys the index directly via `Borrow<str>`.
    pub fn record(&mut self, tag: &str, dir: Direction, bytes: u64) {
        let slot = dir_slot(dir);
        if let Some(slots) = self.index.get_mut(tag) {
            if let Some(i) = slots[slot] {
                self.entries[i as usize].2 += bytes;
            } else {
                slots[slot] = Some(self.entries.len() as u32);
                self.entries.push((tag.to_string(), dir, bytes));
            }
            return;
        }
        let mut slots = [None; 3];
        slots[slot] = Some(self.entries.len() as u32);
        self.entries.push((tag.to_string(), dir, bytes));
        self.index.insert(tag.to_string(), slots);
    }

    /// Total bytes across all tags and directions.
    pub fn total(&self) -> u64 {
        self.entries.iter().map(|e| e.2).sum()
    }

    /// Total bytes in one direction.
    pub fn total_dir(&self, dir: Direction) -> u64 {
        self.entries.iter().filter(|e| e.1 == dir).map(|e| e.2).sum()
    }

    /// Per-(tag, direction) rows, in first-recorded order. The sum of the
    /// byte column equals `total()` — asserted by tests/proptests.rs.
    pub fn breakdown(&self) -> &[(String, Direction, u64)] {
        &self.entries
    }

    /// Fold another ledger's rows into this one, per (tag, direction).
    /// Used to sum per-level ledgers of an aggregation tree (every site's
    /// uplink ledger plus the root's broadcast ledger reconstructs the
    /// flat star's census — what the tree equivalence tests assert).
    pub fn merge(&mut self, other: &Ledger) {
        for (tag, dir, bytes) in other.breakdown() {
            self.record(tag, *dir, *bytes);
        }
    }

    /// Forget everything (per-run reuse).
    pub fn reset(&mut self) {
        self.entries.clear();
        self.index.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_merges_by_tag_and_direction() {
        let mut l = Ledger::new();
        l.record("acts", Direction::SiteToAgg, 100);
        l.record("acts", Direction::SiteToAgg, 50);
        l.record("acts", Direction::AggToSite, 7);
        l.record("deltas", Direction::SiteToAgg, 1);
        assert_eq!(l.breakdown().len(), 3);
        assert_eq!(l.total(), 158);
        assert_eq!(l.total_dir(Direction::SiteToAgg), 151);
        assert_eq!(l.total_dir(Direction::AggToSite), 7);
        assert_eq!(l.total_dir(Direction::PeerToPeer), 0);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let mut l = Ledger::new();
        for (i, dir) in [Direction::SiteToAgg, Direction::AggToSite, Direction::PeerToPeer]
            .into_iter()
            .enumerate()
        {
            l.record("t", dir, (i as u64 + 1) * 10);
        }
        let sum: u64 = l.breakdown().iter().map(|&(_, _, b)| b).sum();
        assert_eq!(sum, l.total());
        l.reset();
        assert_eq!(l.total(), 0);
        assert!(l.breakdown().is_empty());
    }

    /// Census: the indexed `record` must agree exactly with the
    /// reference semantics — per-(tag, direction) sums, directional and
    /// grand totals, and `breakdown()`'s first-recorded row order — over
    /// an interleaved many-tag sequence, including after a reset.
    #[test]
    fn indexed_record_preserves_totals_and_row_order() {
        // Reference: the old O(tags) linear-scan merge.
        fn reference(seq: &[(&str, Direction, u64)]) -> Vec<(String, Direction, u64)> {
            let mut rows: Vec<(String, Direction, u64)> = Vec::new();
            for &(tag, dir, b) in seq {
                match rows.iter_mut().find(|e| e.1 == dir && e.0 == tag) {
                    Some(e) => e.2 += b,
                    None => rows.push((tag.to_string(), dir, b)),
                }
            }
            rows
        }
        use Direction::{AggToSite, PeerToPeer, SiteToAgg};
        // Deterministic interleaving: 60 records over 10 tags x 3 dirs,
        // revisiting tags out of first-seen order.
        let tags =
            ["acts", "deltas", "grad", "lowrank-q", "psgd-p", "t5", "t6", "t7", "t8", "t9"];
        let dirs = [SiteToAgg, AggToSite, PeerToPeer];
        let seq: Vec<(&str, Direction, u64)> = (0..60)
            .map(|i| (tags[(i * 7) % 10], dirs[(i * 5) % 3], (i as u64 + 1) * 3))
            .collect();
        let mut l = Ledger::new();
        for &(tag, dir, b) in &seq {
            l.record(tag, dir, b);
        }
        let want = reference(&seq);
        assert_eq!(l.breakdown(), &want[..], "row order or sums diverged from reference");
        assert_eq!(l.total(), want.iter().map(|e| e.2).sum::<u64>());
        for dir in dirs {
            let want_dir: u64 = want.iter().filter(|e| e.1 == dir).map(|e| e.2).sum();
            assert_eq!(l.total_dir(dir), want_dir, "{dir:?} total diverged");
        }
        // The index must not survive a reset: re-recording after reset
        // rebuilds identical rows from scratch.
        l.reset();
        for &(tag, dir, b) in &seq {
            l.record(tag, dir, b);
        }
        assert_eq!(l.breakdown(), &want[..], "post-reset rows diverged");
    }
}

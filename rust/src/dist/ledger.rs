//! Byte ledger: exact accounting of every matrix that crosses the simulated
//! wire, tagged by payload kind and link direction. The paper's bandwidth
//! claims (Table in section 3, Figure "bytes" panels) are read directly off
//! this ledger — no Θ-bound is ever *assumed* by the experiments, only
//! measured and then compared against the bound.

/// Link direction in the simulated topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Direction {
    /// Site uplink to the aggregator (star topology).
    SiteToAgg,
    /// Aggregator broadcast down to the sites (star topology). Counted
    /// once per broadcast — the down-link is a shared multicast, which is
    /// what makes p2p dAD exactly half the star's total at S = 2
    /// (see `algos::p2p`).
    AggToSite,
    /// Direct peer exchange (section 3.6's decentralized variant).
    PeerToPeer,
}

/// Accumulated bytes per (tag, direction) pair.
#[derive(Debug, Clone, Default)]
pub struct Ledger {
    entries: Vec<(String, Direction, u64)>,
}

impl Ledger {
    /// Empty ledger.
    pub fn new() -> Self {
        Ledger { entries: Vec::new() }
    }

    /// Add `bytes` under (tag, dir), merging with an existing row.
    pub fn record(&mut self, tag: &str, dir: Direction, bytes: u64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.1 == dir && e.0 == tag) {
            e.2 += bytes;
        } else {
            self.entries.push((tag.to_string(), dir, bytes));
        }
    }

    /// Total bytes across all tags and directions.
    pub fn total(&self) -> u64 {
        self.entries.iter().map(|e| e.2).sum()
    }

    /// Total bytes in one direction.
    pub fn total_dir(&self, dir: Direction) -> u64 {
        self.entries.iter().filter(|e| e.1 == dir).map(|e| e.2).sum()
    }

    /// Per-(tag, direction) rows, in first-recorded order. The sum of the
    /// byte column equals `total()` — asserted by tests/proptests.rs.
    pub fn breakdown(&self) -> &[(String, Direction, u64)] {
        &self.entries
    }

    /// Forget everything (per-run reuse).
    pub fn reset(&mut self) {
        self.entries.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_merges_by_tag_and_direction() {
        let mut l = Ledger::new();
        l.record("acts", Direction::SiteToAgg, 100);
        l.record("acts", Direction::SiteToAgg, 50);
        l.record("acts", Direction::AggToSite, 7);
        l.record("deltas", Direction::SiteToAgg, 1);
        assert_eq!(l.breakdown().len(), 3);
        assert_eq!(l.total(), 158);
        assert_eq!(l.total_dir(Direction::SiteToAgg), 151);
        assert_eq!(l.total_dir(Direction::AggToSite), 7);
        assert_eq!(l.total_dir(Direction::PeerToPeer), 0);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let mut l = Ledger::new();
        for (i, dir) in [Direction::SiteToAgg, Direction::AggToSite, Direction::PeerToPeer]
            .into_iter()
            .enumerate()
        {
            l.record("t", dir, (i as u64 + 1) * 10);
        }
        let sum: u64 = l.breakdown().iter().map(|&(_, _, b)| b).sum();
        assert_eq!(sum, l.total());
        l.reset();
        assert_eq!(l.total(), 0);
        assert!(l.breakdown().is_empty());
    }
}

//! The multi-site cluster: model replicas, the byte ledger, a wire-cost
//! model, and the pluggable transport the frames move through.
//!
//! The paper's setting is S hospital-style sites that may never pool data;
//! this module gives the algorithms in `crate::algos` a topology to talk
//! over. Three link primitives cover every algorithm:
//!
//! ```text
//! send_to_agg   one site -> aggregator          (star uplink)
//! broadcast     aggregator -> all sites, once   (star shared down-link)
//! send_p2p      one site -> each of S-1 peers   (section 3.6)
//! ```
//!
//! Beneath the primitives sits the [`transport::Transport`] seam: every
//! shipment is a [`wire`] frame, and the bytes recorded in the [`Ledger`]
//! are the frame's *actual serialized size* — header, dimensions and f32
//! body — not a `rows * cols * 4` estimate. The default backend is the
//! in-process [`transport::Loopback`] (deterministic simulation, timed by
//! the cluster's [`CostModel`]); `dad serve` / `dad join` run the same
//! frames over the [`transport::tcp`] backend as separate OS processes,
//! with identical ledger totals (asserted by `tests/transport_e2e.rs`).

pub mod ledger;
pub mod transport;
pub mod wire;

pub use ledger::{Direction, Ledger};
pub use transport::{
    is_link_failure, ChaosSpec, ChaosTransport, FaultEvent, Loopback, TcpAgg, TcpAggListener,
    TcpAggPending, TcpSite, Transport,
};

use std::cell::RefCell;

use crate::nn::model::Replicate;
use crate::tensor::{Matrix, Workspace};

/// Latency + bandwidth model for one link class; `time_for` converts a
/// payload into simulated seconds.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Per-message one-way latency (seconds).
    pub latency_s: f64,
    /// Link throughput (bytes/second).
    pub bytes_per_s: f64,
}

impl CostModel {
    /// Datacenter LAN: 10 GbE, ~50 µs one-way.
    pub fn lan_10gbe() -> Self {
        CostModel { latency_s: 50e-6, bytes_per_s: 10e9 / 8.0 }
    }

    /// Federated/WAN setting (the paper's motivating deployment): ~100 Mbit/s
    /// uplinks with ~30 ms latency between institutions.
    pub fn wan_federated() -> Self {
        CostModel { latency_s: 30e-3, bytes_per_s: 100e6 / 8.0 }
    }

    /// Congested last-mile uplink (the degraded regime the compression
    /// rivals target): ~5 Mbit/s with ~20 ms latency.
    pub fn dsl_uplink() -> Self {
        CostModel { latency_s: 20e-3, bytes_per_s: 5e6 / 8.0 }
    }

    /// Geostationary satellite hop: ~300 ms one-way, ~10 Mbit/s.
    pub fn satellite() -> Self {
        CostModel { latency_s: 300e-3, bytes_per_s: 10e6 / 8.0 }
    }

    /// Arbitrary link class (chaos recipes compose their own).
    pub fn custom(latency_s: f64, bytes_per_s: f64) -> Self {
        CostModel { latency_s, bytes_per_s }
    }

    /// Parse a named preset: `lan | wan | dsl | sat`.
    pub fn parse(name: &str) -> Result<CostModel, String> {
        match name {
            "lan" => Ok(CostModel::lan_10gbe()),
            "wan" => Ok(CostModel::wan_federated()),
            "dsl" => Ok(CostModel::dsl_uplink()),
            "sat" => Ok(CostModel::satellite()),
            other => Err(format!("unknown link preset {other:?} (lan|wan|dsl|sat)")),
        }
    }

    /// Seconds to move `bytes` in `n_messages` transmissions.
    pub fn time_for(&self, bytes: u64, n_messages: usize) -> f64 {
        n_messages as f64 * self.latency_s + bytes as f64 / self.bytes_per_s
    }
}

/// One simulated training site: a model replica plus a reusable per-site
/// step workspace (so repeated `local_stats` calls are allocation-free —
/// see `tensor::workspace`). RefCell because sites are iterated through
/// shared references in `gather_local_stats` while only the workspace needs
/// mutability.
pub struct Site<M> {
    /// Site index (0-based, canonical order everywhere).
    pub id: usize,
    /// The site's model replica.
    pub model: M,
    /// Reusable forward/backward scratch for this site.
    pub ws: RefCell<Workspace>,
}

/// The cluster handed to every `DistAlgorithm::step`: replicas, ledger,
/// cost model, and the transport backend the frames ship through.
pub struct Cluster<M> {
    /// All site replicas, in canonical id order.
    pub sites: Vec<Site<M>>,
    /// Exact per-(tag, direction) byte accounting.
    pub ledger: Ledger,
    /// Wire timing model applied to every shipment.
    pub cost: CostModel,
    /// Simulated wall-clock spent on the wire so far.
    pub sim_time_s: f64,
    /// Synchronized steps taken (each `DistAlgorithm::step` calls
    /// `next_step` once).
    pub step: usize,
    transport: Box<dyn Transport>,
}

impl<M> Cluster<M> {
    /// Build an S-site cluster of bit-identical replicas — the paper's
    /// "every site initializes with the same random seed" requirement,
    /// realized by replicating one already-initialized model. Uses the
    /// loopback transport (the deterministic simulator).
    pub fn replicate(model: M, n_sites: usize) -> Self
    where
        M: Replicate,
    {
        assert!(n_sites >= 1, "a cluster needs at least one site");
        let mut sites = Vec::with_capacity(n_sites);
        for id in 0..n_sites - 1 {
            sites.push(Site { id, model: model.replicate(), ws: RefCell::new(Workspace::new()) });
        }
        sites.push(Site { id: n_sites - 1, model, ws: RefCell::new(Workspace::new()) });
        Cluster {
            sites,
            ledger: Ledger::new(),
            cost: CostModel::lan_10gbe(),
            sim_time_s: 0.0,
            step: 0,
            transport: Box::new(Loopback::new(n_sites)),
        }
    }

    /// Same cluster under a different wire-cost model.
    pub fn with_cost(mut self, cost: CostModel) -> Self {
        self.cost = cost;
        self
    }

    /// Same cluster over a different transport backend.
    pub fn with_transport(mut self, transport: Box<dyn Transport>) -> Self {
        self.transport = transport;
        self
    }

    /// The transport backend the link primitives ship through.
    pub fn transport(&self) -> &dyn Transport {
        self.transport.as_ref()
    }

    /// Number of sites in the cluster.
    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    /// Mark the start of a synchronized step.
    pub fn next_step(&mut self) {
        self.step += 1;
    }

    /// One site ships `payload` up to the aggregator.
    pub fn send_to_agg(&mut self, tag: &str, payload: &[&Matrix]) {
        let bytes = self
            .transport
            .ship(Direction::SiteToAgg, tag, payload)
            .expect("transport failed on the site->aggregator link");
        self.ledger.record(tag, Direction::SiteToAgg, bytes);
        self.sim_time_s += self.cost.time_for(bytes, 1);
    }

    /// The aggregator broadcasts `payload` to every site. Counted (and
    /// timed) once: the down-link is a shared multicast, so its cost does
    /// not scale with S — which is exactly why p2p dAD halves the S = 2
    /// star total (no aggregator echo) rather than merely matching it.
    pub fn broadcast(&mut self, tag: &str, payload: &[&Matrix]) {
        let bytes = self
            .transport
            .ship(Direction::AggToSite, tag, payload)
            .expect("transport failed on the aggregator->site link");
        self.ledger.record(tag, Direction::AggToSite, bytes);
        self.sim_time_s += self.cost.time_for(bytes, 1);
    }

    /// One site ships a sparse `payload` (u32 index + f32 value pairs) up
    /// to the aggregator; ledger bytes include the index overhead.
    pub fn send_to_agg_sparse(&mut self, tag: &str, payload: &[&wire::SparseMat]) {
        let bytes = self
            .transport
            .ship_sparse(Direction::SiteToAgg, tag, payload)
            .expect("transport failed on the site->aggregator link");
        self.ledger.record(tag, Direction::SiteToAgg, bytes);
        self.sim_time_s += self.cost.time_for(bytes, 1);
    }

    /// The aggregator broadcasts a sparse `payload` to every site; like
    /// [`Cluster::broadcast`], counted and timed once (shared multicast).
    pub fn broadcast_sparse(&mut self, tag: &str, payload: &[&wire::SparseMat]) {
        let bytes = self
            .transport
            .ship_sparse(Direction::AggToSite, tag, payload)
            .expect("transport failed on the aggregator->site link");
        self.ledger.record(tag, Direction::AggToSite, bytes);
        self.sim_time_s += self.cost.time_for(bytes, 1);
    }

    /// One site ships `payload` to each of its S-1 peers (no aggregator).
    /// Bytes scale with the peer count; simulated time does not, because the
    /// S-1 unicasts leave on independent links in parallel.
    pub fn send_p2p(&mut self, tag: &str, payload: &[&Matrix]) {
        let total = self
            .transport
            .ship(Direction::PeerToPeer, tag, payload)
            .expect("transport failed on the peer-to-peer links");
        let peers = self.n_sites().saturating_sub(1).max(1) as u64;
        self.ledger.record(tag, Direction::PeerToPeer, total);
        self.sim_time_s += self.cost.time_for(total / peers, 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::DistModel;
    use crate::nn::{Activation, Mlp};
    use crate::tensor::Rng;

    fn mlp() -> Mlp {
        let mut rng = Rng::new(3);
        Mlp::new(&[4, 6, 3], &[Activation::Relu], &mut rng)
    }

    #[test]
    fn replicate_is_bit_identical() {
        let m = mlp();
        let snapshot: Vec<Matrix> = m.params().into_iter().cloned().collect();
        let c = Cluster::replicate(m, 3);
        assert_eq!(c.n_sites(), 3);
        assert_eq!(c.transport().name(), "loopback");
        assert_eq!(c.transport().n_sites(), 3);
        for (i, site) in c.sites.iter().enumerate() {
            assert_eq!(site.id, i);
            for (p, s) in site.model.params().into_iter().zip(&snapshot) {
                assert_eq!(p, s, "site {i} diverged at init");
            }
        }
    }

    #[test]
    fn link_primitives_account_serialized_bytes_and_time() {
        let mut c = Cluster::replicate(mlp(), 4);
        let m = Matrix::zeros(8, 16); // 512 raw f32 bytes
        let one = wire::payload_wire_len("x", &[&m]);
        // Frames carry a fixed header on top of the f32 body.
        assert!(one > m.wire_bytes() && one < m.wire_bytes() + 64);
        c.send_to_agg("x", &[&m]);
        assert_eq!(c.ledger.total_dir(Direction::SiteToAgg), one);
        let two = wire::payload_wire_len("x", &[&m, &m]);
        c.broadcast("x", &[&m, &m]);
        // Broadcast counted once, not per receiving site.
        assert_eq!(c.ledger.total_dir(Direction::AggToSite), two);
        c.send_p2p("x", &[&m]);
        // Peer exchange counted once per receiving peer (S - 1 = 3).
        assert_eq!(c.ledger.total_dir(Direction::PeerToPeer), 3 * one);
        assert!(c.sim_time_s > 0.0);
        assert_eq!(c.ledger.total(), one + two + 3 * one);
    }

    #[test]
    fn cost_models_order_sanely() {
        let lan = CostModel::lan_10gbe();
        let wan = CostModel::wan_federated();
        let bytes = 1_000_000;
        assert!(lan.time_for(bytes, 1) < wan.time_for(bytes, 1));
        // The degraded-link presets are strictly worse than the WAN one,
        // and the named-preset parser round-trips all four classes.
        assert!(wan.time_for(bytes, 1) < CostModel::dsl_uplink().time_for(bytes, 1));
        assert!(wan.time_for(bytes, 1) < CostModel::satellite().time_for(bytes, 1));
        for name in ["lan", "wan", "dsl", "sat"] {
            assert!(CostModel::parse(name).is_ok(), "{name}");
        }
        assert!(CostModel::parse("carrier-pigeon").is_err());
        let c = CostModel::custom(1.0, 8.0);
        assert!((c.time_for(8, 1) - 2.0).abs() < 1e-9);
        // Latency dominates small messages, bandwidth dominates big ones.
        assert!(wan.time_for(1, 1) > 0.9 * wan.latency_s);
        assert!(wan.time_for(10 * bytes, 1) > 5.0 * wan.time_for(bytes, 1));
    }

    #[test]
    fn next_step_counts() {
        let mut c = Cluster::replicate(mlp(), 2);
        assert_eq!(c.step, 0);
        c.next_step();
        c.next_step();
        assert_eq!(c.step, 2);
    }
}

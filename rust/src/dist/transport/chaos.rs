//! Deterministic fault injection: a decorator that wraps any [`Transport`]
//! with a seeded schedule of added latency, jitter, bandwidth caps, frame
//! drops and disconnects.
//!
//! Chaos is a *pure function of the spec*: the per-frame fault decisions
//! come from a [`crate::tensor::Rng`] stream keyed by `(seed, link)` and
//! the frame index, never from wall clock or OS state. Two runs of the
//! same recipe therefore produce byte-identical fault schedules — on the
//! loopback simulator and over real TCP sockets alike — which is what
//! keeps chaos experiments replayable (`tests/chaos_recipes.rs` asserts
//! this).
//!
//! Two fault families exist:
//!
//! * **Frame-level** (modeled by [`ChaosSpec::schedule`], the pure
//!   schedule function): per-frame delay from an added [`CostModel`]
//!   (latency + bandwidth cap) plus uniform jitter, every-k-th frame
//!   drops, and a hard disconnect after N frames. These key off the
//!   endpoint's monotone frame counter.
//! * **Step-level** (protocol-aware): a stall or disconnect gated on the
//!   k-th `step-meta` control ship — i.e. "die (or straggle) at training
//!   step k". Frame counts per step depend on the model architecture;
//!   step gates make fault placement model-independent, so a disconnect
//!   lands exactly on a step boundary where the aggregator's degradation
//!   state machine (see `coordinator::remote`) can retire the site and
//!   continue with the survivors.
//!
//! Delay pacing: on a real socket backend the decorator genuinely sleeps
//! (`pace = true`); on loopback it only accounts the simulated seconds in
//! [`ChaosTransport::chaos_time_s`], keeping tests fast while the
//! *schedule* stays bit-identical. A dropped frame never reaches the inner
//! transport but still returns the bytes the sender put on the lossy wire,
//! so ledger accounting stays send-side honest. A disconnect drops the
//! inner transport entirely (closing its socket, for TCP), and every
//! later operation fails with `ErrorKind::ConnectionAborted`.

use std::io;
use std::time::Duration;

use super::Transport;
use crate::dist::ledger::Direction;
use crate::dist::wire::{self, Frame};
use crate::dist::CostModel;
use crate::tensor::{Matrix, Rng};

/// One seeded fault schedule for one link. The default spec is quiet
/// (no delay, no drops, no disconnect): `ChaosTransport` with a default
/// spec is behaviorally identical to the bare inner transport.
#[derive(Debug, Clone, Copy)]
pub struct ChaosSpec {
    /// Seed of the fault schedule (combined with the link id).
    pub seed: u64,
    /// Added per-frame link cost (latency + bandwidth cap); `None` adds
    /// no deterministic base delay.
    pub link_cost: Option<CostModel>,
    /// Upper bound of the per-frame uniform jitter (seconds; 0 = none).
    pub jitter_s: f64,
    /// Drop every k-th *shipped* frame (0 = never). Received frames are
    /// never dropped — loss happens on the sender's wire.
    pub drop_every: usize,
    /// Hard-disconnect once this many frames have crossed (0 = never).
    pub disconnect_after_frames: usize,
    /// Disconnect immediately before shipping the k-th `step-meta`
    /// control frame, 1-based (0 = never) — "die at training step k".
    pub disconnect_at_step: usize,
    /// Stall (sleep `stall_s`) immediately before shipping the k-th
    /// `step-meta`, 1-based (0 = never) — "straggle at training step k".
    pub stall_at_step: usize,
    /// Stall duration in seconds (used with `stall_at_step`).
    pub stall_s: f64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            seed: 0,
            link_cost: None,
            jitter_s: 0.0,
            drop_every: 0,
            disconnect_after_frames: 0,
            disconnect_at_step: 0,
            stall_at_step: 0,
            stall_s: 0.0,
        }
    }
}

/// One frame's fault decision, as recorded in the live event log and
/// produced by the pure [`ChaosSpec::schedule`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Monotone per-endpoint frame index the decision applies to.
    pub frame: u32,
    /// Injected delay in microseconds (base link cost + jitter).
    pub delay_us: u64,
    /// The frame was dropped (never reached the inner transport).
    pub drop: bool,
    /// The link was severed at this frame.
    pub disconnect: bool,
}

impl FaultEvent {
    fn push_bytes(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.frame.to_le_bytes());
        out.extend_from_slice(&self.delay_us.to_le_bytes());
        out.push(u8::from(self.drop) | (u8::from(self.disconnect) << 1));
    }
}

impl ChaosSpec {
    /// Spec with only a deterministic link cost (pure-delay chaos).
    pub fn delay_only(seed: u64, cost: CostModel, jitter_s: f64) -> Self {
        ChaosSpec { seed, link_cost: Some(cost), jitter_s, ..ChaosSpec::default() }
    }

    /// True when the spec injects no faults at all.
    pub fn is_quiet(&self) -> bool {
        self.link_cost.is_none()
            && self.jitter_s == 0.0
            && self.drop_every == 0
            && self.disconnect_after_frames == 0
            && self.disconnect_at_step == 0
            && self.stall_at_step == 0
    }

    /// True when the spec only delays frames (never drops or severs):
    /// such chaos must leave grads, losses and ledger bytes exactly equal
    /// to the clean run — asserted by `tests/transport_e2e.rs`.
    pub fn is_pure_delay(&self) -> bool {
        self.drop_every == 0
            && self.disconnect_after_frames == 0
            && self.disconnect_at_step == 0
            && self.stall_at_step == 0
    }

    fn rng_for(&self, link: u64) -> Rng {
        Rng::with_stream(self.seed, link.wrapping_mul(2).wrapping_add(0x6368616f73))
    }

    /// One frame's fault decision: pure in `(spec, link-stream rng state,
    /// frame, bytes)`. Exactly one rng draw per frame keeps the stream
    /// aligned whatever the spec's fields are.
    fn event_at(&self, rng: &mut Rng, frame: usize, bytes: u64) -> FaultEvent {
        let base = self.link_cost.map(|c| c.time_for(bytes, 1)).unwrap_or(0.0);
        let jitter = rng.uniform() as f64 * self.jitter_s;
        FaultEvent {
            frame: frame as u32,
            delay_us: ((base + jitter) * 1e6) as u64,
            drop: self.drop_every > 0 && (frame + 1) % self.drop_every == 0,
            disconnect: self.disconnect_after_frames > 0
                && frame >= self.disconnect_after_frames,
        }
    }

    /// The frame-level fault schedule for a link carrying frames of the
    /// given wire sizes — a pure function of `(self, link, frame_bytes)`.
    /// This is what "identical schedules over loopback and TCP" means
    /// mechanically: any backend moving the same frame sequence draws the
    /// same events. (Step-level gates are protocol-driven and appear only
    /// in the live event log.)
    pub fn schedule(&self, link: u64, frame_bytes: &[u64]) -> Vec<FaultEvent> {
        let mut rng = self.rng_for(link);
        frame_bytes
            .iter()
            .enumerate()
            .map(|(i, &b)| self.event_at(&mut rng, i, b))
            .collect()
    }

    /// Canonical byte encoding of [`ChaosSpec::schedule`] — what the
    /// determinism proptest compares across runs.
    pub fn schedule_bytes(&self, link: u64, frame_bytes: &[u64]) -> Vec<u8> {
        let mut out = Vec::new();
        for ev in self.schedule(link, frame_bytes) {
            ev.push_bytes(&mut out);
        }
        out
    }
}

fn severed(label: &str, why: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::ConnectionAborted,
        format!("chaos[{label}]: injected disconnect ({why})"),
    )
}

/// The decorator: wraps any transport endpoint with one [`ChaosSpec`]'s
/// fault schedule. Construct with [`ChaosTransport::new`] (accounting
/// only) or [`ChaosTransport::paced`] (really sleeps — for real-socket
/// runs where delay must be wall-clock-visible to the peer's timeouts).
pub struct ChaosTransport {
    inner: Option<Box<dyn Transport>>,
    spec: ChaosSpec,
    rng: Rng,
    label: String,
    n_sites: usize,
    pace: bool,
    frames_done: usize,
    steps_seen: usize,
    events: Vec<FaultEvent>,
    sever_why: Option<String>,
    /// Simulated seconds of injected delay accumulated so far (also
    /// accumulated when pacing).
    pub chaos_time_s: f64,
}

impl ChaosTransport {
    /// Wrap `inner` under `spec`; `link` keys this endpoint's rng stream
    /// (use the site id, or 0 for a single all-roles endpoint). Delays are
    /// accounted in [`ChaosTransport::chaos_time_s`] but not slept.
    pub fn new(inner: Box<dyn Transport>, spec: ChaosSpec, link: u64) -> Self {
        let n_sites = inner.n_sites();
        ChaosTransport {
            inner: Some(inner),
            rng: spec.rng_for(link),
            label: format!("link{link}"),
            spec,
            n_sites,
            pace: false,
            frames_done: 0,
            steps_seen: 0,
            events: Vec::new(),
            sever_why: None,
            chaos_time_s: 0.0,
        }
    }

    /// [`ChaosTransport::new`] that also genuinely sleeps each injected
    /// delay — required on real sockets so the peer's recv deadlines see
    /// the straggle.
    pub fn paced(inner: Box<dyn Transport>, spec: ChaosSpec, link: u64) -> Self {
        let mut t = ChaosTransport::new(inner, spec, link);
        t.pace = true;
        t
    }

    /// The live fault-event log, in frame order.
    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Canonical byte encoding of [`ChaosTransport::events`] (mirrors
    /// [`ChaosSpec::schedule_bytes`]).
    pub fn events_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for ev in &self.events {
            ev.push_bytes(&mut out);
        }
        out
    }

    fn alive(&self) -> io::Result<()> {
        match &self.sever_why {
            Some(why) => Err(severed(&self.label, why)),
            None => Ok(()),
        }
    }

    fn sever(&mut self, why: String) -> io::Error {
        let e = severed(&self.label, &why);
        self.sever_why = Some(why);
        self.inner = None; // dropping a TcpSite/TcpAgg closes its sockets
        self.events.push(FaultEvent {
            frame: self.frames_done as u32,
            delay_us: 0,
            drop: false,
            disconnect: true,
        });
        e
    }

    fn delay(&mut self, seconds: f64) {
        self.chaos_time_s += seconds;
        if self.pace && seconds > 0.0 {
            // The sleep runs inside the surrounding ship/recv span, so the
            // injected latency lands in the sender's comms_s (and surfaces
            // as the peer's stall_s); the dedicated span makes the injected
            // share separable in `dad trace summarize`.
            let _s = crate::obs::trace::span("chaos-delay");
            std::thread::sleep(Duration::from_secs_f64(seconds));
        }
    }

    /// Per-frame gate: sever when the frame budget is exhausted, otherwise
    /// draw (and apply) this frame's fault event.
    fn frame_event(&mut self, bytes: u64) -> io::Result<FaultEvent> {
        self.alive()?;
        let frame = self.frames_done;
        self.frames_done += 1;
        if self.spec.disconnect_after_frames > 0 && frame >= self.spec.disconnect_after_frames {
            return Err(self.sever(format!(
                "after {} frames",
                self.spec.disconnect_after_frames
            )));
        }
        let ev = self.spec.event_at(&mut self.rng, frame, bytes);
        self.delay(ev.delay_us as f64 * 1e-6);
        self.events.push(ev);
        Ok(ev)
    }

    /// Step gate, fired when a `step-meta` control frame is about to ship:
    /// step-indexed stalls and disconnects land exactly on training-step
    /// boundaries (where the aggregator can degrade instead of failing).
    fn step_gate(&mut self) -> io::Result<()> {
        self.steps_seen += 1;
        if self.spec.stall_at_step > 0 && self.steps_seen == self.spec.stall_at_step {
            self.delay(self.spec.stall_s);
        }
        if self.spec.disconnect_at_step > 0 && self.steps_seen == self.spec.disconnect_at_step {
            return Err(self.sever(format!("at step {}", self.spec.disconnect_at_step)));
        }
        Ok(())
    }

    fn inner_mut(&mut self) -> io::Result<&mut dyn Transport> {
        match self.inner.as_deref_mut() {
            Some(t) => Ok(t),
            // Unreachable after an `alive` check, but never panic here.
            None => Err(severed(&self.label, "link already severed")),
        }
    }
}

impl Transport for ChaosTransport {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn n_sites(&self) -> usize {
        self.n_sites
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        let bytes = wire::payload_wire_len(tag, mats);
        let ev = self.frame_event(bytes)?;
        if ev.drop {
            // The sender paid for the frame; the wire lost it. Return the
            // priced bytes so send-side ledgers stay honest.
            return Ok(match dir {
                Direction::PeerToPeer => bytes * self.n_sites.saturating_sub(1) as u64,
                _ => bytes,
            });
        }
        self.inner_mut()?.ship(dir, tag, mats)
    }

    fn ship_sparse(
        &mut self,
        dir: Direction,
        tag: &str,
        mats: &[&wire::SparseMat],
    ) -> io::Result<u64> {
        let bytes = wire::sparse_wire_len(tag, mats);
        let ev = self.frame_event(bytes)?;
        if ev.drop {
            return Ok(match dir {
                Direction::PeerToPeer => bytes * self.n_sites.saturating_sub(1) as u64,
                _ => bytes,
            });
        }
        self.inner_mut()?.ship_sparse(dir, tag, mats)
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        if tag == "step-meta" {
            self.step_gate()?;
        }
        let bytes = wire::control_wire_len(tag, body);
        let ev = self.frame_event(bytes)?;
        if ev.drop {
            return Ok(bytes);
        }
        self.inner_mut()?.ship_control(dir, tag, body)
    }

    fn recv_from_site(&mut self, site: usize) -> io::Result<Frame> {
        self.alive()?;
        let f = self.inner_mut()?.recv_from_site(site)?;
        let bytes = f.wire_len();
        self.frame_event(bytes)?;
        Ok(f)
    }

    fn recv_broadcast(&mut self) -> io::Result<Frame> {
        self.alive()?;
        let f = self.inner_mut()?.recv_broadcast()?;
        let bytes = f.wire_len();
        self.frame_event(bytes)?;
        Ok(f)
    }

    fn forward_p2p(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        for f in frames {
            let bytes = f.wire_len();
            self.frame_event(bytes)?;
        }
        self.inner_mut()?.forward_p2p(from_site, frames)
    }

    fn retire_site(&mut self, site: usize) -> io::Result<()> {
        self.alive()?;
        self.inner_mut()?.retire_site(site)?;
        self.n_sites = self.inner.as_ref().map(|t| t.n_sites()).unwrap_or(0);
        Ok(())
    }

    fn site_label(&self, site: usize) -> String {
        match &self.inner {
            Some(t) => t.site_label(site),
            None => site.to_string(),
        }
    }

    fn link_leaves(&self, site: usize) -> (u32, u32) {
        match &self.inner {
            Some(t) => t.link_leaves(site),
            None => (site as u32, 1),
        }
    }

    fn admit_joiners(&mut self) -> io::Result<Vec<usize>> {
        self.alive()?;
        let new = self.inner_mut()?.admit_joiners()?;
        self.n_sites = self.inner.as_ref().map(|t| t.n_sites()).unwrap_or(0);
        Ok(new)
    }

    fn ship_control_to(&mut self, site: usize, tag: &str, body: &[u8]) -> io::Result<u64> {
        // Management-plane unicast (admission config): delegated without a
        // fault gate so a drop schedule can never eat a joiner's welcome.
        self.alive()?;
        self.inner_mut()?.ship_control_to(site, tag, body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::transport::Loopback;

    fn quiet_wrap(n: usize, spec: ChaosSpec) -> ChaosTransport {
        ChaosTransport::new(Box::new(Loopback::new(n)), spec, 0)
    }

    #[test]
    fn quiet_spec_is_transparent() {
        let spec = ChaosSpec::default();
        assert!(spec.is_quiet() && spec.is_pure_delay());
        let mut t = quiet_wrap(2, spec);
        let m = Matrix::filled(2, 2, 1.0);
        let direct = wire::payload_wire_len("acts", &[&m]);
        assert_eq!(t.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap(), direct);
        assert_eq!(t.chaos_time_s, 0.0);
        assert_eq!(t.events().len(), 1);
        assert!(!t.events()[0].drop && !t.events()[0].disconnect);
    }

    #[test]
    fn schedule_is_deterministic_and_seed_sensitive() {
        let spec = ChaosSpec {
            seed: 9,
            link_cost: Some(CostModel::wan_federated()),
            jitter_s: 0.01,
            drop_every: 5,
            ..ChaosSpec::default()
        };
        let sizes: Vec<u64> = (0..64).map(|i| 100 + i * 37).collect();
        assert_eq!(spec.schedule_bytes(1, &sizes), spec.schedule_bytes(1, &sizes));
        assert_ne!(spec.schedule_bytes(1, &sizes), spec.schedule_bytes(2, &sizes));
        let other = ChaosSpec { seed: 10, ..spec };
        assert_ne!(spec.schedule_bytes(1, &sizes), other.schedule_bytes(1, &sizes));
        // Every 5th frame drops, nothing disconnects.
        let evs = spec.schedule(1, &sizes);
        for (i, ev) in evs.iter().enumerate() {
            assert_eq!(ev.drop, (i + 1) % 5 == 0);
            assert!(!ev.disconnect);
            assert!(ev.delay_us > 0, "wan cost must delay every frame");
        }
    }

    #[test]
    fn live_events_match_pure_schedule() {
        let spec = ChaosSpec {
            seed: 4,
            link_cost: Some(CostModel::lan_10gbe()),
            jitter_s: 0.002,
            ..ChaosSpec::default()
        };
        let mut t = ChaosTransport::new(Box::new(Loopback::new(2)), spec, 3);
        let m = Matrix::filled(4, 4, 0.5);
        let mut sizes = Vec::new();
        for _ in 0..10 {
            t.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap();
            sizes.push(wire::payload_wire_len("acts", &[&m]));
        }
        assert_eq!(t.events_bytes(), spec.schedule_bytes(3, &sizes));
        assert!(t.chaos_time_s > 0.0);
    }

    #[test]
    fn disconnect_after_frames_severs_with_clean_error() {
        let spec = ChaosSpec { disconnect_after_frames: 3, ..ChaosSpec::default() };
        let mut t = quiet_wrap(2, spec);
        let m = Matrix::filled(1, 1, 0.0);
        for _ in 0..3 {
            t.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap();
        }
        let e = t.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::ConnectionAborted);
        assert!(e.to_string().contains("injected disconnect"), "{e}");
        // Every later op fails identically instead of panicking.
        let e2 = t.recv_broadcast().unwrap_err();
        assert_eq!(e2.kind(), io::ErrorKind::ConnectionAborted);
        assert!(t.events().last().unwrap().disconnect);
    }

    #[test]
    fn disconnect_at_step_fires_before_the_kth_step_meta() {
        let spec = ChaosSpec { disconnect_at_step: 2, ..ChaosSpec::default() };
        let mut t = quiet_wrap(2, spec);
        // Step 1's meta ships fine; step 2's meta is where the site dies.
        t.ship_control(Direction::SiteToAgg, "step-meta", &[]).unwrap();
        t.ship_control(Direction::SiteToAgg, "other", &[]).unwrap();
        let e = t.ship_control(Direction::SiteToAgg, "step-meta", &[]).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::ConnectionAborted);
        assert!(e.to_string().contains("at step 2"), "{e}");
    }

    #[test]
    fn dropped_frames_still_price_send_side_bytes() {
        let spec = ChaosSpec { drop_every: 1, ..ChaosSpec::default() };
        let mut t = quiet_wrap(3, spec);
        let m = Matrix::filled(2, 2, 1.0);
        let one = wire::payload_wire_len("acts", &[&m]);
        assert_eq!(t.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap(), one);
        assert_eq!(t.ship(Direction::PeerToPeer, "acts", &[&m]).unwrap(), 2 * one);
        assert!(t.events().iter().all(|e| e.drop));
    }
}

//! The in-memory loopback backend: the deterministic simulator's wire.
//!
//! All sites live in one process, so payload *delivery* is implicit — the
//! algorithms already hold every replica's statistics. What the loopback
//! models is the *cost*: each shipment reports the exact bytes the frame
//! codec would put on a socket ([`crate::dist::wire::payload_wire_len`]),
//! so a simulated run and a TCP multi-process run with the same seed record
//! identical ledgers (asserted by `tests/transport_e2e.rs`). Simulated
//! latency/bandwidth timing stays in the cluster layer's `CostModel`.

use std::io;

use super::Transport;
use crate::dist::ledger::Direction;
use crate::dist::wire;
use crate::obs::trace::{tagged_span, Phase};
use crate::tensor::Matrix;

/// Byte-accounting loopback endpoint for an `n_sites` fabric.
#[derive(Debug, Clone)]
pub struct Loopback {
    n_sites: usize,
}

impl Loopback {
    /// A loopback fabric connecting `n_sites` simulated sites.
    pub fn new(n_sites: usize) -> Self {
        Loopback { n_sites }
    }

    /// Peer-to-peer shipments fan out to the other `n_sites - 1` replicas;
    /// star links count once.
    fn fanout(&self, dir: Direction) -> u64 {
        match dir {
            Direction::PeerToPeer => self.n_sites.saturating_sub(1) as u64,
            Direction::SiteToAgg | Direction::AggToSite => 1,
        }
    }
}

impl Transport for Loopback {
    fn name(&self) -> &'static str {
        "loopback"
    }

    fn n_sites(&self) -> usize {
        self.n_sites
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        let _s = tagged_span("loopback-ship", tag, Phase::Comms);
        Ok(wire::payload_wire_len(tag, mats) * self.fanout(dir))
    }

    fn ship_sparse(
        &mut self,
        dir: Direction,
        tag: &str,
        mats: &[&wire::SparseMat],
    ) -> io::Result<u64> {
        let _s = tagged_span("loopback-ship", tag, Phase::Comms);
        Ok(wire::sparse_wire_len(tag, mats) * self.fanout(dir))
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _s = tagged_span("loopback-ship", tag, Phase::Comms);
        Ok(wire::control_wire_len(tag, body) * self.fanout(dir))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_counts_serialized_bytes() {
        let mut t = Loopback::new(4);
        let m = Matrix::zeros(8, 16); // 512 raw f32 bytes
        let one = t.ship(Direction::SiteToAgg, "x", &[&m]).unwrap();
        assert_eq!(one, wire::payload_wire_len("x", &[&m]));
        assert!(one > m.wire_bytes(), "framing overhead must be visible");
        // Broadcast counts once; p2p counts once per receiving peer.
        assert_eq!(t.ship(Direction::AggToSite, "x", &[&m]).unwrap(), one);
        assert_eq!(t.ship(Direction::PeerToPeer, "x", &[&m]).unwrap(), 3 * one);
        // Receive halves are not a loopback role.
        assert!(t.recv_from_site(0).is_err());
        assert!(t.recv_broadcast().is_err());
    }
}

//! Pluggable transport backends: how frames physically move between the
//! aggregator and the sites.
//!
//! The distributed algorithms only ever touch three link primitives
//! (`send_to_agg`, `broadcast`, `send_p2p` on [`crate::dist::Cluster`]);
//! this module is the seam beneath them. A [`Transport`] endpoint moves
//! [`crate::dist::wire`] frames and reports the exact serialized bytes each
//! shipment put on the wire, which is what the [`crate::dist::Ledger`]
//! records. Two backends exist:
//!
//! * [`Loopback`] — the deterministic single-process simulator. Nothing is
//!   serialized; byte counts come from `wire::payload_wire_len`, so they are
//!   identical to what a real run would ship, and `CostModel` timing is
//!   preserved by the cluster layer above.
//! * [`TcpAgg`] / [`TcpSite`] — a zero-dependency `std::net` backend that
//!   runs the aggregator and the sites as separate OS processes
//!   (`dad serve` / `dad join`). Every frame genuinely crosses a socket.
//!
//! Endpoints are asymmetric by nature: a TCP site cannot read another
//! site's uplink. Methods that a given endpoint cannot serve return
//! `ErrorKind::Unsupported` via the trait's default implementations; the
//! loopback endpoint plays every role at once and the drivers in
//! `coordinator::remote` only call the half that matches their role.

pub mod chaos;
pub mod loopback;
pub mod tcp;

pub use chaos::{ChaosSpec, ChaosTransport, FaultEvent};
pub use loopback::Loopback;
pub use tcp::{is_link_failure, retry_backoff_ms, TcpAgg, TcpAggListener, TcpAggPending, TcpSite};

use std::io;

use crate::dist::ledger::Direction;
use crate::dist::wire::{Frame, SparseMat};
use crate::tensor::Matrix;

fn unsupported(endpoint: &'static str, op: &'static str) -> io::Error {
    io::Error::new(
        io::ErrorKind::Unsupported,
        format!("{endpoint} endpoint does not implement {op}"),
    )
}

/// One endpoint of the communication fabric (aggregator, site, or the
/// all-roles loopback simulator).
///
/// `ship`/`ship_control` return the serialized bytes that crossed the wire:
/// for a multicast broadcast the shared down-link is counted once, and for
/// a peer-to-peer shipment the per-peer size times `n_sites - 1` — matching
/// the ledger conventions the experiments assert against.
pub trait Transport: Send {
    /// Backend name for diagnostics ("loopback", "tcp-agg", "tcp-site").
    fn name(&self) -> &'static str;

    /// Number of sites on this fabric.
    fn n_sites(&self) -> usize;

    /// Move a tagged payload frame along `dir`; returns ledger bytes.
    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64>;

    /// Move a tagged sparse payload frame (u32 index + f32 value pairs)
    /// along `dir`; returns ledger bytes including the index overhead.
    /// Backends that predate the sparse family refuse with `Unsupported`.
    fn ship_sparse(&mut self, dir: Direction, tag: &str, mats: &[&SparseMat]) -> io::Result<u64> {
        let _ = (dir, tag, mats);
        Err(unsupported(self.name(), "ship_sparse"))
    }

    /// Move a control frame along `dir`; returns wire bytes (control
    /// traffic is protocol overhead and is *not* recorded in the ledger).
    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64>;

    /// Receive the next frame `site` sent toward the aggregator
    /// (aggregator-role endpoints only).
    fn recv_from_site(&mut self, site: usize) -> io::Result<Frame> {
        let _ = site;
        Err(unsupported(self.name(), "recv_from_site"))
    }

    /// Receive the next frame the aggregator broadcast to this site
    /// (site-role endpoints only).
    fn recv_broadcast(&mut self) -> io::Result<Frame> {
        Err(unsupported(self.name(), "recv_broadcast"))
    }

    /// Permanently remove live link `site` from the fabric (aggregator-
    /// role endpoints only): close the link, compact the remaining links,
    /// and shrink `n_sites`. Later live-link indices shift down by one;
    /// [`Transport::site_label`] keeps reporting original handshake ids.
    /// This is the degradation seam `coordinator::remote` uses to continue
    /// a round with the surviving sites after a straggler deadline or a
    /// disconnect.
    fn retire_site(&mut self, site: usize) -> io::Result<()> {
        let _ = site;
        Err(unsupported(self.name(), "retire_site"))
    }

    /// Operator-facing label for live link index `site` — the originally
    /// assigned site id even after earlier retirements compacted the
    /// links. Endpoints without retirement report the index itself.
    fn site_label(&self, site: usize) -> String {
        site.to_string()
    }

    /// The contiguous leaf range live link `site` aggregates, as
    /// `(first leaf id, count)` — assigned at the handshake (aggregator-
    /// role endpoints only). On a flat star every link is a single leaf
    /// whose id is its link index, which is the default; a tree aggregator
    /// overrides this with the subtree ranges its children declared.
    fn link_leaves(&self, site: usize) -> (u32, u32) {
        (site as u32, 1)
    }

    /// Admit any sites waiting to join the fabric (root aggregator
    /// endpoints only): handshake every queued connection and return the
    /// newly created live link indices. The default fabric is closed to
    /// joiners and returns an empty list.
    fn admit_joiners(&mut self) -> io::Result<Vec<usize>> {
        Ok(vec![])
    }

    /// Ship a control frame to exactly one live link (aggregator-role
    /// endpoints only) — the management-plane unicast used to hand a
    /// freshly admitted site its run configuration. Like all control
    /// traffic it is never recorded in the ledger.
    fn ship_control_to(&mut self, site: usize, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _ = (site, tag, body);
        Err(unsupported(self.name(), "ship_control_to"))
    }

    /// Forward one site's peer-to-peer frames through a star hub: write
    /// `frames` verbatim to every site *except* `from_site` (aggregator-
    /// role endpoints only), flushing once per link. The hub reads p2p
    /// uplinks with [`Transport::recv_from_site`] and forwards with this
    /// method in two separate phases — drain every uplink first, then
    /// forward — so a blocking single-threaded hub can never deadlock
    /// against a site that is still flushing its own uplink. The caller
    /// prices each forwarded frame as `n_sites - 1` direct unicasts —
    /// what a true mesh would ship — so the ledger stays topology-honest
    /// even though the bytes physically transit the hub.
    fn forward_p2p(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        let _ = (from_site, frames);
        Err(unsupported(self.name(), "forward_p2p"))
    }
}

//! The TCP backend: aggregator and sites as separate OS processes over
//! `std::net` sockets — no external dependencies.
//!
//! Topology is a star by default, and composes into trees: every `hello`
//! declares how many *leaves* the dialing endpoint aggregates (a leaf site
//! sends the historical empty body, meaning one; a relay declares its
//! subtree's total), and every `welcome` answers with the link's first
//! global leaf id plus the fabric-wide leaf total. The aggregator assigns
//! contiguous leaf ranges in accept order, so `dad relay` can run a
//! [`TcpAgg`] toward its children and a [`TcpSite`] toward its parent with
//! nothing but these two control frames. The two-phase split
//! ([`TcpAggListener::accept_hellos_deadline`] then
//! [`TcpAggPending::welcome_all`]) exists for exactly that: a relay must
//! learn its subtree size before it can dial up and hear its own range.
//! After the handshake both endpoints speak nothing but
//! [`crate::dist::wire`] frames:
//!
//! * [`TcpSite`] ships uplink frames and receives broadcasts.
//! * [`TcpAgg`] receives per-site uplinks and ships broadcasts — written to
//!   every socket, but *counted once*, because the ledger models the
//!   down-link as a shared multicast (see `dist::ledger::Direction`).
//!
//! Blocking I/O is deliberate: the training protocol is phase-ordered
//! (all uplinks, then the broadcast), so each endpoint always knows which
//! frame comes next and the kernel's socket buffers absorb the skew between
//! faster and slower sites. Robustness against *absent* peers is bounded
//! explicitly instead: [`TcpAggListener::accept_sites_deadline`] puts a
//! deadline on the whole handshake phase (naming the site that wedged it),
//! [`TcpAgg::set_recv_timeout`] / [`TcpSite::set_recv_timeout`] bound every
//! later frame read, and [`TcpAgg::retire_site`] (via
//! [`Transport::retire_site`]) removes a dead site so the surviving
//! sub-fabric keeps training — the seams `coordinator::remote`'s
//! degradation state machine is built on.

use std::cell::Cell;
use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::{unsupported, Transport};
use crate::dist::ledger::Direction;
use crate::dist::wire::{self, Body, ByteReader, ByteWriter, Frame};
use crate::obs::trace::{phase_span, tagged_span, Phase};
use crate::tensor::{Matrix, Rng};

/// One established connection: buffered reader + writer over the same
/// stream (`try_clone` shares the socket).
struct Link {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

fn link(stream: TcpStream) -> io::Result<Link> {
    stream.set_nodelay(true)?;
    let r = BufReader::new(stream.try_clone()?);
    Ok(Link { r, w: BufWriter::new(stream) })
}

fn expect_control(f: &Frame, want: &str) -> io::Result<Vec<u8>> {
    match &f.body {
        Body::Control(b) if f.tag == want => Ok(b.clone()),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected control frame {want:?}, got {:?} ({:?})", f.tag, f.kind()),
        )),
    }
}

/// How many leaves a `hello` body declares. The empty body every leaf
/// site sends means one leaf (the historical format); a relay dialing on
/// behalf of a subtree declares the subtree's leaf count as a `u32`.
fn hello_leaves(body: &[u8]) -> io::Result<u32> {
    if body.is_empty() {
        return Ok(1);
    }
    let mut rd = ByteReader::new(body);
    let n = rd.read_u32()?;
    if n == 0 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            "hello declared a zero-leaf subtree",
        ));
    }
    Ok(n)
}

/// A bound-but-not-yet-connected aggregator: lets the caller learn the
/// listen address (e.g. for port 0) before sites dial in.
pub struct TcpAggListener {
    listener: TcpListener,
    n_sites: usize,
}

impl TcpAggListener {
    /// The actual bound address (resolves `:0` to the kernel-chosen port).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Block until connections covering all `n_sites` leaves have completed
    /// the `hello`/`welcome` handshake; leaf ranges are assigned in accept
    /// order. Blocks forever if a site never shows — use
    /// [`TcpAggListener::accept_sites_deadline`] for a bounded wait.
    pub fn accept_sites(self) -> io::Result<TcpAgg> {
        self.accept_sites_deadline(None)
    }

    /// [`TcpAggListener::accept_sites`] with a deadline over the whole
    /// handshake phase. A site that never connects, or connects but never
    /// completes its `hello`, turns into a `TimedOut` error naming the
    /// offending site and how many sites made it — instead of wedging
    /// `dad serve` forever. `None` waits indefinitely (the historical
    /// behavior).
    pub fn accept_sites_deadline(self, timeout: Option<Duration>) -> io::Result<TcpAgg> {
        let total = self.n_sites as u32;
        self.accept_hellos_deadline(timeout)?.welcome_all(0, total)
    }

    /// The first half of the handshake with the `welcome`s deferred:
    /// accept connections and read their `hello`s until the declared leaf
    /// counts sum to exactly `n_sites`. A relay uses this split to learn
    /// its subtree size, dial its own parent, and only then assign leaf
    /// ranges with [`TcpAggPending::welcome_all`]; the root welcomes
    /// immediately via [`TcpAggListener::accept_sites_deadline`]. A link
    /// whose declaration would overshoot the fabric's leaf total is a
    /// named `InvalidData` error.
    pub fn accept_hellos_deadline(self, timeout: Option<Duration>) -> io::Result<TcpAggPending> {
        let deadline = timeout.map(|t| Instant::now() + t);
        if deadline.is_some() {
            self.listener.set_nonblocking(true)?;
        }
        let mut links = Vec::new();
        let mut n_leaves: Vec<u32> = Vec::new();
        let mut leaf_total = 0u32;
        while (leaf_total as usize) < self.n_sites {
            let site_id = links.len();
            let stream = loop {
                match self.listener.accept() {
                    Ok((stream, _)) => break stream,
                    Err(e)
                        if matches!(
                            e.kind(),
                            io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted
                        ) =>
                    {
                        if let Some(d) = deadline {
                            if Instant::now() >= d {
                                return Err(io::Error::new(
                                    io::ErrorKind::TimedOut,
                                    format!(
                                        "handshake deadline: accepted {site_id}/{} sites; \
                                         site {site_id} never connected",
                                        self.n_sites
                                    ),
                                ));
                            }
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e),
                }
            };
            stream.set_nonblocking(false)?;
            if let Some(d) = deadline {
                let left = d.saturating_duration_since(Instant::now());
                // Zero would mean "no timeout"; keep at least a tick.
                stream.set_read_timeout(Some(left.max(Duration::from_millis(1))))?;
            }
            let mut l = link(stream)?;
            let hello = wire::decode(&mut l.r).map_err(|e| {
                if is_link_failure(&e) {
                    io::Error::new(
                        e.kind(),
                        format!(
                            "handshake deadline: site {site_id} connected but never \
                             completed its hello ({e})"
                        ),
                    )
                } else {
                    e
                }
            })?;
            let body = expect_control(&hello, "hello")?;
            let n = hello_leaves(&body)?;
            if leaf_total + n > self.n_sites as u32 {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!(
                        "link {site_id} declared a {n}-leaf subtree, overshooting the \
                         fabric's {} leaves ({leaf_total} already claimed)",
                        self.n_sites
                    ),
                ));
            }
            leaf_total += n;
            links.push(l);
            n_leaves.push(n);
        }
        Ok(TcpAggPending { links, n_leaves, listener: self.listener })
    }
}

/// Accepted links whose `hello`s are read but whose `welcome`s are still
/// deferred — the relay's half-open handshake state between learning its
/// subtree size and hearing its own leaf range from its parent.
pub struct TcpAggPending {
    links: Vec<Link>,
    n_leaves: Vec<u32>,
    listener: TcpListener,
}

impl TcpAggPending {
    /// Total leaves declared across the accepted links.
    pub fn total_leaves(&self) -> u32 {
        self.n_leaves.iter().sum()
    }

    /// Number of direct links accepted (each a leaf site or a relay
    /// subtree).
    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// Complete the handshake: assign each link a contiguous leaf range in
    /// accept order starting at `leaf_start`, and tell every link the
    /// fabric-wide leaf total `global_total`. The `welcome` body is
    /// `(first leaf id, global leaf total)` — on a flat star this is the
    /// historical `(site id, n_sites)` pair, bit for bit.
    pub fn welcome_all(self, leaf_start: u32, global_total: u32) -> io::Result<TcpAgg> {
        let mut links = self.links;
        let mut ids = Vec::with_capacity(links.len());
        let mut leaves = Vec::with_capacity(links.len());
        let mut offset = leaf_start;
        for (l, &n) in links.iter_mut().zip(&self.n_leaves) {
            let mut w = ByteWriter::new();
            w.push_u32(offset);
            w.push_u32(global_total);
            wire::encode_control(&mut l.w, "welcome", &w.finish())?;
            l.w.flush()?;
            // Back to unbounded reads; training timeouts are opted into
            // separately via `TcpAgg::set_recv_timeout`.
            l.r.get_ref().set_read_timeout(None)?;
            ids.push(offset as usize);
            leaves.push((offset, n));
            offset += n;
        }
        Ok(TcpAgg {
            links,
            ids,
            leaves,
            listener: Some(self.listener),
            next_leaf: offset,
            recv_timeout: Cell::new(None),
        })
    }
}

/// Error kinds that mean "the peer is gone or silent" — the degradation
/// triggers — as opposed to protocol corruption (`InvalidData`), which
/// always fails the run. `WouldBlock` appears because platforms disagree
/// on which kind a socket read timeout surfaces as.
pub fn is_link_failure(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::TimedOut
            | io::ErrorKind::WouldBlock
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
    )
}

/// Aggregator endpoint: one socket per child link, star (or tree level)
/// topology. `links` holds the *live* links in handshake order; `ids`
/// remembers each live link's first assigned leaf id and `leaves` its
/// contiguous `(first leaf, count)` range, so diagnostics and re-sharding
/// stay stable after [`TcpAgg::retire_site`] compacts the fabric. The
/// listener is retained so elastic joiners can be admitted later via
/// [`Transport::admit_joiners`].
pub struct TcpAgg {
    links: Vec<Link>,
    ids: Vec<usize>,
    leaves: Vec<(u32, u32)>,
    listener: Option<TcpListener>,
    next_leaf: u32,
    recv_timeout: Cell<Option<Duration>>,
}

/// Handshake one joiner connection: bounded `hello` read, single-leaf
/// check, `welcome` with the fresh leaf id and the new leaf high-water as
/// the global total. Any failure forfeits this joiner's admission without
/// failing the run.
fn admit_one(stream: TcpStream, leaf: u32, recv_timeout: Option<Duration>) -> io::Result<Link> {
    stream.set_nonblocking(false)?;
    // Bounded handshake: a half-open dial must not wedge the epoch
    // boundary this poll runs at.
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut l = link(stream)?;
    let hello = wire::decode(&mut l.r)?;
    let body = expect_control(&hello, "hello")?;
    let n = hello_leaves(&body)?;
    if n != 1 {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("elastic join must be a single leaf site, got a {n}-leaf subtree"),
        ));
    }
    let mut w = ByteWriter::new();
    w.push_u32(leaf);
    w.push_u32(leaf + 1);
    wire::encode_control(&mut l.w, "welcome", &w.finish())?;
    l.w.flush()?;
    l.r.get_ref().set_read_timeout(recv_timeout)?;
    Ok(l)
}

impl TcpAgg {
    /// Bind the aggregator on `addr` (e.g. `"127.0.0.1:7009"` or `":0"`
    /// forms) for a fabric of `n_sites` *leaves*. Accepting is a separate
    /// step so the caller can print/propagate the address first.
    pub fn bind(addr: &str, n_sites: usize) -> io::Result<TcpAggListener> {
        assert!(n_sites >= 1, "a fabric needs at least one site");
        Ok(TcpAggListener { listener: TcpListener::bind(addr)?, n_sites })
    }

    /// Bound every frame read on every live link (`None` restores
    /// unbounded blocking reads). This is the straggler deadline's
    /// mechanism: a site that stays silent past the timeout surfaces as a
    /// `TimedOut`/`WouldBlock` read error, which the remote driver either
    /// degrades on or fails cleanly — never a hang. Links admitted later
    /// inherit the most recent setting.
    pub fn set_recv_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.recv_timeout.set(timeout);
        for l in &self.links {
            l.r.get_ref().set_read_timeout(timeout)?;
        }
        Ok(())
    }
}

impl Transport for TcpAgg {
    fn name(&self) -> &'static str {
        "tcp-agg"
    }

    fn n_sites(&self) -> usize {
        self.links.len()
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_payload(&mut l.w, tag, mats)?;
                    l.w.flush()?;
                }
                Ok(counted) // multicast down-link: counted once
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship")),
        }
    }

    fn ship_sparse(
        &mut self,
        dir: Direction,
        tag: &str,
        mats: &[&wire::SparseMat],
    ) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_sparse(&mut l.w, tag, mats)?;
                    l.w.flush()?;
                }
                Ok(counted) // multicast down-link: counted once
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship_sparse")),
        }
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_control(&mut l.w, tag, body)?;
                    l.w.flush()?;
                }
                Ok(counted)
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship_control")),
        }
    }

    fn recv_from_site(&mut self, site: usize) -> io::Result<Frame> {
        let _s = phase_span("tcp-recv", Phase::Stall);
        wire::decode(&mut self.links[site].r)
    }

    fn forward_p2p(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        let _s = phase_span("tcp-forward", Phase::Comms);
        for (i, l) in self.links.iter_mut().enumerate() {
            if i == from_site {
                continue;
            }
            for f in frames {
                wire::encode_frame(&mut l.w, f)?;
            }
            l.w.flush()?;
        }
        Ok(())
    }

    fn retire_site(&mut self, site: usize) -> io::Result<()> {
        if site >= self.links.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("retire_site {site}: only {} live links", self.links.len()),
            ));
        }
        let l = self.links.remove(site);
        self.ids.remove(site);
        self.leaves.remove(site);
        // Best effort: wake the site (or its stalled kernel buffers) so it
        // fails fast on its side instead of blocking on a broadcast that
        // will never come.
        let _ = l.r.get_ref().shutdown(Shutdown::Both);
        Ok(())
    }

    fn site_label(&self, site: usize) -> String {
        match self.ids.get(site) {
            Some(id) => id.to_string(),
            None => site.to_string(),
        }
    }

    fn link_leaves(&self, site: usize) -> (u32, u32) {
        match self.leaves.get(site) {
            Some(&range) => range,
            None => (site as u32, 1),
        }
    }

    fn admit_joiners(&mut self) -> io::Result<Vec<usize>> {
        if self.listener.is_none() {
            return Ok(vec![]);
        }
        self.listener.as_ref().expect("checked above").set_nonblocking(true)?;
        let mut admitted = Vec::new();
        loop {
            let stream = match self.listener.as_ref().expect("checked above").accept() {
                Ok((stream, _)) => stream,
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted
                    ) =>
                {
                    break;
                }
                Err(e) => return Err(e),
            };
            match admit_one(stream, self.next_leaf, self.recv_timeout.get()) {
                Ok(l) => {
                    self.links.push(l);
                    self.ids.push(self.next_leaf as usize);
                    self.leaves.push((self.next_leaf, 1));
                    self.next_leaf += 1;
                    admitted.push(self.links.len() - 1);
                }
                // A malformed or half-open dial forfeits admission; the
                // run itself goes on.
                Err(_) => continue,
            }
        }
        Ok(admitted)
    }

    fn ship_control_to(&mut self, site: usize, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        let n_links = self.links.len();
        let l = self.links.get_mut(site).ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("ship_control_to {site}: only {n_links} live links"),
            )
        })?;
        let n = wire::encode_control(&mut l.w, tag, body)?;
        l.w.flush()?;
        Ok(n)
    }
}

/// Site endpoint: a single socket to the aggregator plus the identity the
/// handshake assigned.
pub struct TcpSite {
    link: Link,
    site_id: usize,
    n_sites: usize,
}

/// Attempt `attempt`'s retry sleep in milliseconds: capped exponential
/// backoff with deterministic seeded jitter, so a fleet of sites launched
/// by the same script does not re-dial the aggregator in lockstep. The
/// base doubles from 50 ms to a 1600 ms cap; the jittered sleep is
/// uniform in `[base/2, base]`, derived purely from `(seed, attempt)` —
/// a given seed always replays the same schedule.
pub fn retry_backoff_ms(seed: u64, attempt: u32) -> u64 {
    let base = (50u64 << attempt.min(5)).min(1600);
    let mut rng = Rng::new(seed.wrapping_add((attempt as u64).wrapping_mul(0x9e3779b97f4a7c15)));
    base / 2 + rng.next_u64() % (base / 2 + 1)
}

/// Stable FNV-1a jitter seed for [`TcpSite::connect_retry`]: the dial
/// target de-correlates different fabrics, the process id de-correlates
/// sibling sites dialing the same aggregator.
fn retry_seed(addr: &str) -> u64 {
    let mut seed = 0xcbf2_9ce4_8422_2325u64;
    for b in addr.bytes().chain(std::process::id().to_le_bytes()) {
        seed ^= b as u64;
        seed = seed.wrapping_mul(0x100_0000_01b3);
    }
    seed
}

impl TcpSite {
    fn connect_inner(addr: &str, n_leaves: u32) -> io::Result<TcpSite> {
        let stream = TcpStream::connect(addr)?;
        let mut l = link(stream)?;
        let hello = if n_leaves == 1 {
            Vec::new() // the historical empty body: one leaf
        } else {
            let mut w = ByteWriter::new();
            w.push_u32(n_leaves);
            w.finish()
        };
        wire::encode_control(&mut l.w, "hello", &hello)?;
        l.w.flush()?;
        let welcome = wire::decode(&mut l.r)?;
        let body = expect_control(&welcome, "welcome")?;
        let mut rd = ByteReader::new(&body);
        let site_id = rd.read_u32()? as usize;
        let n_sites = rd.read_u32()? as usize;
        Ok(TcpSite { link: l, site_id, n_sites })
    }

    /// Connect to a serving aggregator and complete the handshake as a
    /// single leaf site.
    pub fn connect(addr: &str) -> io::Result<TcpSite> {
        TcpSite::connect_inner(addr, 1)
    }

    /// Connect declaring an `n_leaves`-leaf subtree behind this endpoint —
    /// the relay's parent-side dial. [`TcpSite::site_id`] then reports the
    /// subtree's *first global leaf id* and [`Transport::n_sites`] the
    /// fabric-wide leaf total.
    pub fn connect_with_leaves(addr: &str, n_leaves: u32) -> io::Result<TcpSite> {
        TcpSite::connect_inner(addr, n_leaves)
    }

    /// The first global leaf id the aggregator assigned this endpoint
    /// (0-based; on a flat star this is the classic accept-order site id).
    pub fn site_id(&self) -> usize {
        self.site_id
    }

    /// [`TcpSite::connect`] with bounded, jittered exponential backoff:
    /// launcher scripts (and the CI remote-matrix job) start the
    /// aggregator and the sites concurrently, so the first dials can land
    /// before the listener is bound. Retries connection-refused/reset on
    /// the [`retry_backoff_ms`] schedule until `timeout` elapses; protocol
    /// errors still fail immediately, and the final error reports how
    /// long the site tried.
    pub fn connect_retry(addr: &str, timeout: Duration) -> io::Result<TcpSite> {
        TcpSite::connect_retry_with_leaves(addr, 1, timeout)
    }

    /// [`TcpSite::connect_retry`] declaring an `n_leaves`-leaf subtree —
    /// the relay's parent-side dial with the same bounded backoff.
    pub fn connect_retry_with_leaves(
        addr: &str,
        n_leaves: u32,
        timeout: Duration,
    ) -> io::Result<TcpSite> {
        let start = Instant::now();
        let deadline = start + timeout;
        let seed = retry_seed(addr);
        let mut attempt = 0u32;
        loop {
            match TcpSite::connect_inner(addr, n_leaves) {
                Ok(site) => return Ok(site),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::ConnectionRefused
                            | io::ErrorKind::ConnectionReset
                            | io::ErrorKind::AddrNotAvailable
                    ) =>
                {
                    if Instant::now() >= deadline {
                        return Err(io::Error::new(
                            e.kind(),
                            format!(
                                "no aggregator at {addr} after retrying for {:.1}s: {e}",
                                start.elapsed().as_secs_f32()
                            ),
                        ));
                    }
                    let sleep = Duration::from_millis(retry_backoff_ms(seed, attempt));
                    std::thread::sleep(sleep.min(deadline.saturating_duration_since(
                        Instant::now(),
                    )));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Bound every broadcast read from the aggregator (`None` restores
    /// blocking reads): a dead or wedged aggregator surfaces as a
    /// `TimedOut`/`WouldBlock` error instead of hanging the join process.
    pub fn set_recv_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.link.r.get_ref().set_read_timeout(timeout)
    }
}

impl Transport for TcpSite {
    fn name(&self) -> &'static str {
        "tcp-site"
    }

    fn n_sites(&self) -> usize {
        self.n_sites
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_payload(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n)
            }
            Direction::PeerToPeer => {
                // Physically one uplink to the hub, which relays the frame
                // to the other S-1 sites; the returned count prices what a
                // true mesh would ship (one unicast per receiving peer),
                // matching the loopback fan-out convention.
                let n = wire::encode_payload(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n * self.n_sites.saturating_sub(1) as u64)
            }
            Direction::AggToSite => Err(unsupported("tcp-site", "non-uplink ship")),
        }
    }

    fn ship_sparse(
        &mut self,
        dir: Direction,
        tag: &str,
        mats: &[&wire::SparseMat],
    ) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_sparse(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n)
            }
            _ => Err(unsupported("tcp-site", "non-uplink ship_sparse")),
        }
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_control(&mut self.link.w, tag, body)?;
                self.link.w.flush()?;
                Ok(n)
            }
            _ => Err(unsupported("tcp-site", "non-uplink ship_control")),
        }
    }

    fn recv_broadcast(&mut self) -> io::Result<Frame> {
        let _s = phase_span("tcp-recv", Phase::Stall);
        wire::decode(&mut self.link.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Handshake assigns ids in accept order; frames cross the socket
    /// bit-exactly and byte counts agree with the arithmetic lengths.
    #[test]
    fn handshake_and_frame_exchange() {
        let listener = TcpAgg::bind("127.0.0.1:0", 2).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sites: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut s = TcpSite::connect(&addr).unwrap();
                    let m = Matrix::filled(2, 3, s.site_id() as f32);
                    let n = s.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap();
                    assert_eq!(n, wire::payload_wire_len("acts", &[&m]));
                    let down = s.recv_broadcast().unwrap();
                    assert_eq!(down.tag, "sum");
                    match down.body {
                        Body::Mats(ms) => ms[0][(0, 0)],
                        _ => panic!("wrong kind"),
                    }
                })
            })
            .collect();
        let mut agg = listener.accept_sites().unwrap();
        assert_eq!(agg.n_sites(), 2);
        let mut total = 0.0;
        for site in 0..2 {
            let f = agg.recv_from_site(site).unwrap();
            assert_eq!(f.tag, "acts");
            match f.body {
                Body::Mats(ms) => {
                    // The value encodes the handshake-assigned id.
                    assert_eq!(ms[0][(0, 0)], site as f32);
                    total += ms[0][(0, 0)];
                }
                _ => panic!("wrong kind"),
            }
        }
        let sum = Matrix::filled(1, 1, total);
        agg.ship(Direction::AggToSite, "sum", &[&sum]).unwrap();
        for s in sites {
            assert_eq!(s.join().unwrap(), 1.0);
        }
    }

    /// Nobody connects: the handshake deadline errors out naming the
    /// missing site instead of blocking `accept_sites` forever.
    #[test]
    fn handshake_deadline_names_absent_site() {
        let listener = TcpAgg::bind("127.0.0.1:0", 2).unwrap();
        let e = listener
            .accept_sites_deadline(Some(Duration::from_millis(150)))
            .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::TimedOut);
        let msg = e.to_string();
        assert!(msg.contains("0/2") && msg.contains("site 0"), "{msg}");
    }

    /// A site connects but never sends its hello: the deadline still
    /// fires, attributing the wedge to that site.
    #[test]
    fn handshake_deadline_names_silent_site() {
        let listener = TcpAgg::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.local_addr().unwrap();
        let _mute = TcpStream::connect(addr).unwrap();
        let e = listener
            .accept_sites_deadline(Some(Duration::from_millis(150)))
            .unwrap_err();
        assert!(is_link_failure(&e), "unexpected kind: {e}");
        assert!(e.to_string().contains("site 0"), "{e}");
    }

    /// Retiring a site compacts the live links but `site_label` keeps
    /// reporting original handshake ids; the retired site's socket is shut
    /// down so its next read fails fast instead of blocking.
    #[test]
    fn retire_site_compacts_and_keeps_labels() {
        let listener = TcpAgg::bind("127.0.0.1:0", 3).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sites: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || TcpSite::connect(&addr).unwrap())
            })
            .collect();
        let mut agg = listener.accept_sites().unwrap();
        let mut sites: Vec<TcpSite> = sites.into_iter().map(|t| t.join().unwrap()).collect();
        sites.sort_by_key(|s| s.site_id());
        agg.retire_site(1).unwrap();
        assert_eq!(agg.n_sites(), 2);
        assert_eq!(agg.site_label(0), "0");
        assert_eq!(agg.site_label(1), "2");
        // The survivors still hear broadcasts; the retired site errors.
        let m = Matrix::filled(1, 1, 7.0);
        agg.ship(Direction::AggToSite, "sum", &[&m]).unwrap();
        assert_eq!(sites[0].recv_broadcast().unwrap().tag, "sum");
        assert_eq!(sites[2].recv_broadcast().unwrap().tag, "sum");
        assert!(sites[1].recv_broadcast().is_err(), "retired site must fail fast");
        // Out-of-range retirement is a clean error, not a panic.
        assert!(agg.retire_site(5).is_err());
    }

    /// A silent peer trips the recv timeout with a link-failure kind —
    /// the primitive the aggregator's straggler deadline is built from.
    #[test]
    fn recv_timeout_surfaces_as_link_failure() {
        let listener = TcpAgg::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = thread::spawn(move || {
            let s = TcpSite::connect(&addr).unwrap();
            thread::sleep(Duration::from_millis(400));
            s
        });
        let mut agg = listener.accept_sites().unwrap();
        agg.set_recv_timeout(Some(Duration::from_millis(100))).unwrap();
        let e = agg.recv_from_site(0).unwrap_err();
        assert!(is_link_failure(&e), "unexpected kind: {e}");
        t.join().unwrap();
    }

    /// The retry backoff schedule is a pure function of `(seed, attempt)`:
    /// one seed always replays one schedule, different seeds de-correlate
    /// the jitter, and every sleep stays inside `[base/2, base]` under the
    /// 1600 ms cap.
    #[test]
    fn retry_backoff_schedule_is_deterministic_per_seed_and_capped() {
        let a: Vec<u64> = (0..12).map(|k| retry_backoff_ms(7, k)).collect();
        let b: Vec<u64> = (0..12).map(|k| retry_backoff_ms(7, k)).collect();
        assert_eq!(a, b, "same seed must replay the same schedule");
        let c: Vec<u64> = (0..12).map(|k| retry_backoff_ms(8, k)).collect();
        assert_ne!(a, c, "different seeds must de-correlate the jitter");
        for (k, &ms) in a.iter().enumerate() {
            let base = (50u64 << (k as u32).min(5)).min(1600);
            assert!(
                ms >= base / 2 && ms <= base,
                "attempt {k}: {ms}ms outside [{}, {base}]",
                base / 2
            );
        }
    }

    /// A relay-style handshake: two links declare 4- and 2-leaf subtrees,
    /// the deferred welcome assigns contiguous ranges from an arbitrary
    /// `leaf_start`, and each welcome carries `(first leaf, global total)`.
    #[test]
    fn deferred_welcome_assigns_subtree_leaf_ranges() {
        let listener = TcpAgg::bind("127.0.0.1:0", 6).unwrap();
        let addr = listener.local_addr().unwrap();
        // Dial by hand, sequentially, using the listen backlog (connects
        // complete before accept runs) so accept order is deterministic.
        let dial = |n: u32| {
            let stream = TcpStream::connect(addr).unwrap();
            let mut w = ByteWriter::new();
            w.push_u32(n);
            let mut bw = BufWriter::new(stream.try_clone().unwrap());
            wire::encode_control(&mut bw, "hello", &w.finish()).unwrap();
            bw.flush().unwrap();
            stream
        };
        let s1 = dial(4);
        let s2 = dial(2);
        let pending =
            listener.accept_hellos_deadline(Some(Duration::from_secs(5))).unwrap();
        assert_eq!(pending.total_leaves(), 6);
        let agg = pending.welcome_all(10, 16).unwrap();
        assert_eq!(agg.n_sites(), 2);
        assert_eq!(agg.link_leaves(0), (10, 4));
        assert_eq!(agg.link_leaves(1), (14, 2));
        assert_eq!(agg.site_label(0), "10");
        assert_eq!(agg.site_label(1), "14");
        for (s, want) in [(&s1, 10u32), (&s2, 14u32)] {
            let mut r = BufReader::new(s.try_clone().unwrap());
            let f = wire::decode(&mut r).unwrap();
            let body = expect_control(&f, "welcome").unwrap();
            let mut rd = ByteReader::new(&body);
            assert_eq!(rd.read_u32().unwrap(), want);
            assert_eq!(rd.read_u32().unwrap(), 16);
        }
    }

    /// A subtree declaring more leaves than the fabric has left is a
    /// named handshake error, not a silently mis-sharded run.
    #[test]
    fn overdeclared_leaves_are_rejected_by_name() {
        let listener = TcpAgg::bind("127.0.0.1:0", 3).unwrap();
        let addr = listener.local_addr().unwrap();
        let dial = |n: u32| {
            let stream = TcpStream::connect(addr).unwrap();
            let mut w = ByteWriter::new();
            w.push_u32(n);
            let mut bw = BufWriter::new(stream.try_clone().unwrap());
            wire::encode_control(&mut bw, "hello", &w.finish()).unwrap();
            bw.flush().unwrap();
            stream
        };
        let _s1 = dial(2);
        let _s2 = dial(2);
        let e = listener
            .accept_hellos_deadline(Some(Duration::from_secs(5)))
            .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidData);
        assert!(e.to_string().contains("overshooting"), "{e}");
    }

    /// The retained listener admits late joiners with fresh leaf ids, and
    /// `ship_control_to` reaches exactly the named link.
    #[test]
    fn joiners_are_admitted_with_fresh_leaf_ids() {
        let listener = TcpAgg::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = {
            let addr = addr.clone();
            thread::spawn(move || TcpSite::connect(&addr).unwrap())
        };
        let mut agg = listener.accept_sites().unwrap();
        let _site0 = t.join().unwrap();
        // Nobody waiting: the poll is empty, not an error.
        assert!(agg.admit_joiners().unwrap().is_empty());
        let tj = thread::spawn(move || TcpSite::connect(&addr).unwrap());
        let admitted = loop {
            let got = agg.admit_joiners().unwrap();
            if !got.is_empty() {
                break got;
            }
            thread::sleep(Duration::from_millis(10));
        };
        assert_eq!(admitted, vec![1]);
        let mut joiner = tj.join().unwrap();
        assert_eq!(joiner.site_id(), 1);
        assert_eq!(joiner.n_sites(), 2);
        assert_eq!(agg.link_leaves(1), (1, 1));
        assert_eq!(agg.site_label(1), "1");
        agg.ship_control_to(1, "cfg", b"abc").unwrap();
        let f = joiner.recv_broadcast().unwrap();
        assert_eq!(f.tag, "cfg");
        assert!(matches!(f.body, Body::Control(ref b) if b == b"abc"));
        assert!(agg.ship_control_to(9, "cfg", b"").is_err());
    }

    /// The bounded backoff dial gives up with an error that reports the
    /// retry window instead of spinning forever.
    #[test]
    fn connect_retry_reports_the_window() {
        // Reserve a port, then close it so the dial is refused.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let e = TcpSite::connect_retry(&addr, Duration::from_millis(200)).unwrap_err();
        assert!(e.to_string().contains("after retrying"), "{e}");
    }
}

//! The TCP backend: aggregator and sites as separate OS processes over
//! `std::net` sockets — no external dependencies.
//!
//! Topology is the paper's star. The aggregator binds, accepts exactly
//! `n_sites` connections, and assigns site ids in accept order during a
//! `hello`/`welcome` control handshake (which also pins the codec version).
//! After the handshake both endpoints speak nothing but
//! [`crate::dist::wire`] frames:
//!
//! * [`TcpSite`] ships uplink frames and receives broadcasts.
//! * [`TcpAgg`] receives per-site uplinks and ships broadcasts — written to
//!   every socket, but *counted once*, because the ledger models the
//!   down-link as a shared multicast (see `dist::ledger::Direction`).
//!
//! Blocking I/O is deliberate: the training protocol is phase-ordered
//! (all uplinks, then the broadcast), so each endpoint always knows which
//! frame comes next and the kernel's socket buffers absorb the skew between
//! faster and slower sites. Robustness against *absent* peers is bounded
//! explicitly instead: [`TcpAggListener::accept_sites_deadline`] puts a
//! deadline on the whole handshake phase (naming the site that wedged it),
//! [`TcpAgg::set_recv_timeout`] / [`TcpSite::set_recv_timeout`] bound every
//! later frame read, and [`TcpAgg::retire_site`] (via
//! [`Transport::retire_site`]) removes a dead site so the surviving
//! sub-fabric keeps training — the seams `coordinator::remote`'s
//! degradation state machine is built on.

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::{unsupported, Transport};
use crate::dist::ledger::Direction;
use crate::dist::wire::{self, Body, ByteReader, ByteWriter, Frame};
use crate::obs::trace::{phase_span, tagged_span, Phase};
use crate::tensor::Matrix;

/// One established connection: buffered reader + writer over the same
/// stream (`try_clone` shares the socket).
struct Link {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

fn link(stream: TcpStream) -> io::Result<Link> {
    stream.set_nodelay(true)?;
    let r = BufReader::new(stream.try_clone()?);
    Ok(Link { r, w: BufWriter::new(stream) })
}

fn expect_control(f: &Frame, want: &str) -> io::Result<Vec<u8>> {
    match &f.body {
        Body::Control(b) if f.tag == want => Ok(b.clone()),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected control frame {want:?}, got {:?} ({:?})", f.tag, f.kind()),
        )),
    }
}

/// A bound-but-not-yet-connected aggregator: lets the caller learn the
/// listen address (e.g. for port 0) before sites dial in.
pub struct TcpAggListener {
    listener: TcpListener,
    n_sites: usize,
}

impl TcpAggListener {
    /// The actual bound address (resolves `:0` to the kernel-chosen port).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Block until all `n_sites` sites have connected and completed the
    /// `hello`/`welcome` handshake; site ids are assigned in accept order.
    /// Blocks forever if a site never shows — use
    /// [`TcpAggListener::accept_sites_deadline`] for a bounded wait.
    pub fn accept_sites(self) -> io::Result<TcpAgg> {
        self.accept_sites_deadline(None)
    }

    /// [`TcpAggListener::accept_sites`] with a deadline over the whole
    /// handshake phase. A site that never connects, or connects but never
    /// completes its `hello`, turns into a `TimedOut` error naming the
    /// offending site and how many sites made it — instead of wedging
    /// `dad serve` forever. `None` waits indefinitely (the historical
    /// behavior).
    pub fn accept_sites_deadline(self, timeout: Option<Duration>) -> io::Result<TcpAgg> {
        let deadline = timeout.map(|t| Instant::now() + t);
        if deadline.is_some() {
            self.listener.set_nonblocking(true)?;
        }
        let mut links = Vec::with_capacity(self.n_sites);
        for site_id in 0..self.n_sites {
            let stream = loop {
                match self.listener.accept() {
                    Ok((stream, _)) => break stream,
                    Err(e)
                        if matches!(
                            e.kind(),
                            io::ErrorKind::WouldBlock | io::ErrorKind::Interrupted
                        ) =>
                    {
                        if let Some(d) = deadline {
                            if Instant::now() >= d {
                                return Err(io::Error::new(
                                    io::ErrorKind::TimedOut,
                                    format!(
                                        "handshake deadline: accepted {site_id}/{} sites; \
                                         site {site_id} never connected",
                                        self.n_sites
                                    ),
                                ));
                            }
                        }
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => return Err(e),
                }
            };
            stream.set_nonblocking(false)?;
            if let Some(d) = deadline {
                let left = d.saturating_duration_since(Instant::now());
                // Zero would mean "no timeout"; keep at least a tick.
                stream.set_read_timeout(Some(left.max(Duration::from_millis(1))))?;
            }
            let mut l = link(stream)?;
            let hello = wire::decode(&mut l.r).map_err(|e| {
                if is_link_failure(&e) {
                    io::Error::new(
                        e.kind(),
                        format!(
                            "handshake deadline: site {site_id} connected but never \
                             completed its hello ({e})"
                        ),
                    )
                } else {
                    e
                }
            })?;
            expect_control(&hello, "hello")?;
            let mut w = ByteWriter::new();
            w.push_u32(site_id as u32);
            w.push_u32(self.n_sites as u32);
            wire::encode_control(&mut l.w, "welcome", &w.finish())?;
            l.w.flush()?;
            // Back to unbounded reads; training timeouts are opted into
            // separately via `TcpAgg::set_recv_timeout`.
            l.r.get_ref().set_read_timeout(None)?;
            links.push(l);
        }
        Ok(TcpAgg { links, ids: (0..self.n_sites).collect() })
    }
}

/// Error kinds that mean "the peer is gone or silent" — the degradation
/// triggers — as opposed to protocol corruption (`InvalidData`), which
/// always fails the run. `WouldBlock` appears because platforms disagree
/// on which kind a socket read timeout surfaces as.
pub fn is_link_failure(e: &io::Error) -> bool {
    matches!(
        e.kind(),
        io::ErrorKind::TimedOut
            | io::ErrorKind::WouldBlock
            | io::ErrorKind::UnexpectedEof
            | io::ErrorKind::ConnectionReset
            | io::ErrorKind::ConnectionAborted
            | io::ErrorKind::BrokenPipe
    )
}

/// Aggregator endpoint: one socket per site, star topology. `links` holds
/// the *live* sites in handshake order; `ids` remembers each live link's
/// originally assigned site id so diagnostics stay stable after
/// [`TcpAgg::retire_site`] compacts the fabric.
pub struct TcpAgg {
    links: Vec<Link>,
    ids: Vec<usize>,
}

impl TcpAgg {
    /// Bind the aggregator on `addr` (e.g. `"127.0.0.1:7009"` or `":0"`
    /// forms) for an `n_sites` fabric. Accepting is a separate step so the
    /// caller can print/propagate the address first.
    pub fn bind(addr: &str, n_sites: usize) -> io::Result<TcpAggListener> {
        assert!(n_sites >= 1, "a fabric needs at least one site");
        Ok(TcpAggListener { listener: TcpListener::bind(addr)?, n_sites })
    }

    /// Bound every frame read on every live link (`None` restores
    /// unbounded blocking reads). This is the straggler deadline's
    /// mechanism: a site that stays silent past the timeout surfaces as a
    /// `TimedOut`/`WouldBlock` read error, which the remote driver either
    /// degrades on or fails cleanly — never a hang.
    pub fn set_recv_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        for l in &self.links {
            l.r.get_ref().set_read_timeout(timeout)?;
        }
        Ok(())
    }
}

impl Transport for TcpAgg {
    fn name(&self) -> &'static str {
        "tcp-agg"
    }

    fn n_sites(&self) -> usize {
        self.links.len()
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_payload(&mut l.w, tag, mats)?;
                    l.w.flush()?;
                }
                Ok(counted) // multicast down-link: counted once
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship")),
        }
    }

    fn ship_sparse(
        &mut self,
        dir: Direction,
        tag: &str,
        mats: &[&wire::SparseMat],
    ) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_sparse(&mut l.w, tag, mats)?;
                    l.w.flush()?;
                }
                Ok(counted) // multicast down-link: counted once
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship_sparse")),
        }
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_control(&mut l.w, tag, body)?;
                    l.w.flush()?;
                }
                Ok(counted)
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship_control")),
        }
    }

    fn recv_from_site(&mut self, site: usize) -> io::Result<Frame> {
        let _s = phase_span("tcp-recv", Phase::Stall);
        wire::decode(&mut self.links[site].r)
    }

    fn forward_p2p(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        let _s = phase_span("tcp-forward", Phase::Comms);
        for (i, l) in self.links.iter_mut().enumerate() {
            if i == from_site {
                continue;
            }
            for f in frames {
                wire::encode_frame(&mut l.w, f)?;
            }
            l.w.flush()?;
        }
        Ok(())
    }

    fn retire_site(&mut self, site: usize) -> io::Result<()> {
        if site >= self.links.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidInput,
                format!("retire_site {site}: only {} live links", self.links.len()),
            ));
        }
        let l = self.links.remove(site);
        self.ids.remove(site);
        // Best effort: wake the site (or its stalled kernel buffers) so it
        // fails fast on its side instead of blocking on a broadcast that
        // will never come.
        let _ = l.r.get_ref().shutdown(Shutdown::Both);
        Ok(())
    }

    fn site_label(&self, site: usize) -> String {
        match self.ids.get(site) {
            Some(id) => id.to_string(),
            None => site.to_string(),
        }
    }
}

/// Site endpoint: a single socket to the aggregator plus the identity the
/// handshake assigned.
pub struct TcpSite {
    link: Link,
    site_id: usize,
    n_sites: usize,
}

impl TcpSite {
    /// Connect to a serving aggregator and complete the handshake.
    pub fn connect(addr: &str) -> io::Result<TcpSite> {
        let stream = TcpStream::connect(addr)?;
        let mut l = link(stream)?;
        wire::encode_control(&mut l.w, "hello", &[])?;
        l.w.flush()?;
        let welcome = wire::decode(&mut l.r)?;
        let body = expect_control(&welcome, "welcome")?;
        let mut rd = ByteReader::new(&body);
        let site_id = rd.read_u32()? as usize;
        let n_sites = rd.read_u32()? as usize;
        Ok(TcpSite { link: l, site_id, n_sites })
    }

    /// The id the aggregator assigned this site (0-based, accept order).
    pub fn site_id(&self) -> usize {
        self.site_id
    }

    /// [`TcpSite::connect`] with bounded exponential backoff: launcher
    /// scripts (and the CI remote-matrix job) start the aggregator and the
    /// sites concurrently, so the first dials can land before the listener
    /// is bound. Retries connection-refused/reset with a doubling delay
    /// (50 ms up to a 1.6 s cap) until `timeout` elapses; protocol errors
    /// still fail immediately, and the final error reports how long the
    /// site tried.
    pub fn connect_retry(addr: &str, timeout: Duration) -> io::Result<TcpSite> {
        let start = Instant::now();
        let deadline = start + timeout;
        let mut backoff = Duration::from_millis(50);
        loop {
            match TcpSite::connect(addr) {
                Ok(site) => return Ok(site),
                Err(e)
                    if matches!(
                        e.kind(),
                        io::ErrorKind::ConnectionRefused
                            | io::ErrorKind::ConnectionReset
                            | io::ErrorKind::AddrNotAvailable
                    ) =>
                {
                    if Instant::now() >= deadline {
                        return Err(io::Error::new(
                            e.kind(),
                            format!(
                                "no aggregator at {addr} after retrying for {:.1}s: {e}",
                                start.elapsed().as_secs_f32()
                            ),
                        ));
                    }
                    std::thread::sleep(backoff.min(deadline.saturating_duration_since(
                        Instant::now(),
                    )));
                    backoff = (backoff * 2).min(Duration::from_millis(1600));
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Bound every broadcast read from the aggregator (`None` restores
    /// blocking reads): a dead or wedged aggregator surfaces as a
    /// `TimedOut`/`WouldBlock` error instead of hanging the join process.
    pub fn set_recv_timeout(&self, timeout: Option<Duration>) -> io::Result<()> {
        self.link.r.get_ref().set_read_timeout(timeout)
    }
}

impl Transport for TcpSite {
    fn name(&self) -> &'static str {
        "tcp-site"
    }

    fn n_sites(&self) -> usize {
        self.n_sites
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_payload(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n)
            }
            Direction::PeerToPeer => {
                // Physically one uplink to the hub, which relays the frame
                // to the other S-1 sites; the returned count prices what a
                // true mesh would ship (one unicast per receiving peer),
                // matching the loopback fan-out convention.
                let n = wire::encode_payload(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n * self.n_sites.saturating_sub(1) as u64)
            }
            Direction::AggToSite => Err(unsupported("tcp-site", "non-uplink ship")),
        }
    }

    fn ship_sparse(
        &mut self,
        dir: Direction,
        tag: &str,
        mats: &[&wire::SparseMat],
    ) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_sparse(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n)
            }
            _ => Err(unsupported("tcp-site", "non-uplink ship_sparse")),
        }
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        let _s = tagged_span("tcp-ship", tag, Phase::Comms);
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_control(&mut self.link.w, tag, body)?;
                self.link.w.flush()?;
                Ok(n)
            }
            _ => Err(unsupported("tcp-site", "non-uplink ship_control")),
        }
    }

    fn recv_broadcast(&mut self) -> io::Result<Frame> {
        let _s = phase_span("tcp-recv", Phase::Stall);
        wire::decode(&mut self.link.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Handshake assigns ids in accept order; frames cross the socket
    /// bit-exactly and byte counts agree with the arithmetic lengths.
    #[test]
    fn handshake_and_frame_exchange() {
        let listener = TcpAgg::bind("127.0.0.1:0", 2).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sites: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut s = TcpSite::connect(&addr).unwrap();
                    let m = Matrix::filled(2, 3, s.site_id() as f32);
                    let n = s.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap();
                    assert_eq!(n, wire::payload_wire_len("acts", &[&m]));
                    let down = s.recv_broadcast().unwrap();
                    assert_eq!(down.tag, "sum");
                    match down.body {
                        Body::Mats(ms) => ms[0][(0, 0)],
                        _ => panic!("wrong kind"),
                    }
                })
            })
            .collect();
        let mut agg = listener.accept_sites().unwrap();
        assert_eq!(agg.n_sites(), 2);
        let mut total = 0.0;
        for site in 0..2 {
            let f = agg.recv_from_site(site).unwrap();
            assert_eq!(f.tag, "acts");
            match f.body {
                Body::Mats(ms) => {
                    // The value encodes the handshake-assigned id.
                    assert_eq!(ms[0][(0, 0)], site as f32);
                    total += ms[0][(0, 0)];
                }
                _ => panic!("wrong kind"),
            }
        }
        let sum = Matrix::filled(1, 1, total);
        agg.ship(Direction::AggToSite, "sum", &[&sum]).unwrap();
        for s in sites {
            assert_eq!(s.join().unwrap(), 1.0);
        }
    }

    /// Nobody connects: the handshake deadline errors out naming the
    /// missing site instead of blocking `accept_sites` forever.
    #[test]
    fn handshake_deadline_names_absent_site() {
        let listener = TcpAgg::bind("127.0.0.1:0", 2).unwrap();
        let e = listener
            .accept_sites_deadline(Some(Duration::from_millis(150)))
            .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::TimedOut);
        let msg = e.to_string();
        assert!(msg.contains("0/2") && msg.contains("site 0"), "{msg}");
    }

    /// A site connects but never sends its hello: the deadline still
    /// fires, attributing the wedge to that site.
    #[test]
    fn handshake_deadline_names_silent_site() {
        let listener = TcpAgg::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.local_addr().unwrap();
        let _mute = TcpStream::connect(addr).unwrap();
        let e = listener
            .accept_sites_deadline(Some(Duration::from_millis(150)))
            .unwrap_err();
        assert!(is_link_failure(&e), "unexpected kind: {e}");
        assert!(e.to_string().contains("site 0"), "{e}");
    }

    /// Retiring a site compacts the live links but `site_label` keeps
    /// reporting original handshake ids; the retired site's socket is shut
    /// down so its next read fails fast instead of blocking.
    #[test]
    fn retire_site_compacts_and_keeps_labels() {
        let listener = TcpAgg::bind("127.0.0.1:0", 3).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sites: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || TcpSite::connect(&addr).unwrap())
            })
            .collect();
        let mut agg = listener.accept_sites().unwrap();
        let mut sites: Vec<TcpSite> = sites.into_iter().map(|t| t.join().unwrap()).collect();
        sites.sort_by_key(|s| s.site_id());
        agg.retire_site(1).unwrap();
        assert_eq!(agg.n_sites(), 2);
        assert_eq!(agg.site_label(0), "0");
        assert_eq!(agg.site_label(1), "2");
        // The survivors still hear broadcasts; the retired site errors.
        let m = Matrix::filled(1, 1, 7.0);
        agg.ship(Direction::AggToSite, "sum", &[&m]).unwrap();
        assert_eq!(sites[0].recv_broadcast().unwrap().tag, "sum");
        assert_eq!(sites[2].recv_broadcast().unwrap().tag, "sum");
        assert!(sites[1].recv_broadcast().is_err(), "retired site must fail fast");
        // Out-of-range retirement is a clean error, not a panic.
        assert!(agg.retire_site(5).is_err());
    }

    /// A silent peer trips the recv timeout with a link-failure kind —
    /// the primitive the aggregator's straggler deadline is built from.
    #[test]
    fn recv_timeout_surfaces_as_link_failure() {
        let listener = TcpAgg::bind("127.0.0.1:0", 1).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let t = thread::spawn(move || {
            let s = TcpSite::connect(&addr).unwrap();
            thread::sleep(Duration::from_millis(400));
            s
        });
        let mut agg = listener.accept_sites().unwrap();
        agg.set_recv_timeout(Some(Duration::from_millis(100))).unwrap();
        let e = agg.recv_from_site(0).unwrap_err();
        assert!(is_link_failure(&e), "unexpected kind: {e}");
        t.join().unwrap();
    }

    /// The bounded backoff dial gives up with an error that reports the
    /// retry window instead of spinning forever.
    #[test]
    fn connect_retry_reports_the_window() {
        // Reserve a port, then close it so the dial is refused.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let e = TcpSite::connect_retry(&addr, Duration::from_millis(200)).unwrap_err();
        assert!(e.to_string().contains("after retrying"), "{e}");
    }
}

//! The TCP backend: aggregator and sites as separate OS processes over
//! `std::net` sockets — no external dependencies.
//!
//! Topology is the paper's star. The aggregator binds, accepts exactly
//! `n_sites` connections, and assigns site ids in accept order during a
//! `hello`/`welcome` control handshake (which also pins the codec version).
//! After the handshake both endpoints speak nothing but
//! [`crate::dist::wire`] frames:
//!
//! * [`TcpSite`] ships uplink frames and receives broadcasts.
//! * [`TcpAgg`] receives per-site uplinks and ships broadcasts — written to
//!   every socket, but *counted once*, because the ledger models the
//!   down-link as a shared multicast (see `dist::ledger::Direction`).
//!
//! Blocking I/O is deliberate: the training protocol is phase-ordered
//! (all uplinks, then the broadcast), so each endpoint always knows which
//! frame comes next and the kernel's socket buffers absorb the skew between
//! faster and slower sites.

use std::io::{self, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};

use super::{unsupported, Transport};
use crate::dist::ledger::Direction;
use crate::dist::wire::{self, Body, ByteReader, ByteWriter, Frame};
use crate::tensor::Matrix;

/// One established connection: buffered reader + writer over the same
/// stream (`try_clone` shares the socket).
struct Link {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

fn link(stream: TcpStream) -> io::Result<Link> {
    stream.set_nodelay(true)?;
    let r = BufReader::new(stream.try_clone()?);
    Ok(Link { r, w: BufWriter::new(stream) })
}

fn expect_control(f: &Frame, want: &str) -> io::Result<Vec<u8>> {
    match &f.body {
        Body::Control(b) if f.tag == want => Ok(b.clone()),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("expected control frame {want:?}, got {:?} ({:?})", f.tag, f.kind()),
        )),
    }
}

/// A bound-but-not-yet-connected aggregator: lets the caller learn the
/// listen address (e.g. for port 0) before sites dial in.
pub struct TcpAggListener {
    listener: TcpListener,
    n_sites: usize,
}

impl TcpAggListener {
    /// The actual bound address (resolves `:0` to the kernel-chosen port).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Block until all `n_sites` sites have connected and completed the
    /// `hello`/`welcome` handshake; site ids are assigned in accept order.
    pub fn accept_sites(self) -> io::Result<TcpAgg> {
        let mut links = Vec::with_capacity(self.n_sites);
        for site_id in 0..self.n_sites {
            let (stream, _) = self.listener.accept()?;
            let mut l = link(stream)?;
            let hello = wire::decode(&mut l.r)?;
            expect_control(&hello, "hello")?;
            let mut w = ByteWriter::new();
            w.push_u32(site_id as u32);
            w.push_u32(self.n_sites as u32);
            wire::encode_control(&mut l.w, "welcome", &w.finish())?;
            l.w.flush()?;
            links.push(l);
        }
        Ok(TcpAgg { links })
    }
}

/// Aggregator endpoint: one socket per site, star topology.
pub struct TcpAgg {
    links: Vec<Link>,
}

impl TcpAgg {
    /// Bind the aggregator on `addr` (e.g. `"127.0.0.1:7009"` or `":0"`
    /// forms) for an `n_sites` fabric. Accepting is a separate step so the
    /// caller can print/propagate the address first.
    pub fn bind(addr: &str, n_sites: usize) -> io::Result<TcpAggListener> {
        assert!(n_sites >= 1, "a fabric needs at least one site");
        Ok(TcpAggListener { listener: TcpListener::bind(addr)?, n_sites })
    }
}

impl Transport for TcpAgg {
    fn name(&self) -> &'static str {
        "tcp-agg"
    }

    fn n_sites(&self) -> usize {
        self.links.len()
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_payload(&mut l.w, tag, mats)?;
                    l.w.flush()?;
                }
                Ok(counted) // multicast down-link: counted once
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship")),
        }
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        match dir {
            Direction::AggToSite => {
                let mut counted = 0;
                for l in &mut self.links {
                    counted = wire::encode_control(&mut l.w, tag, body)?;
                    l.w.flush()?;
                }
                Ok(counted)
            }
            _ => Err(unsupported("tcp-agg", "non-broadcast ship_control")),
        }
    }

    fn recv_from_site(&mut self, site: usize) -> io::Result<Frame> {
        wire::decode(&mut self.links[site].r)
    }

    fn forward_p2p(&mut self, from_site: usize, frames: &[Frame]) -> io::Result<()> {
        for (i, l) in self.links.iter_mut().enumerate() {
            if i == from_site {
                continue;
            }
            for f in frames {
                wire::encode_frame(&mut l.w, f)?;
            }
            l.w.flush()?;
        }
        Ok(())
    }
}

/// Site endpoint: a single socket to the aggregator plus the identity the
/// handshake assigned.
pub struct TcpSite {
    link: Link,
    site_id: usize,
    n_sites: usize,
}

impl TcpSite {
    /// Connect to a serving aggregator and complete the handshake.
    pub fn connect(addr: &str) -> io::Result<TcpSite> {
        let stream = TcpStream::connect(addr)?;
        let mut l = link(stream)?;
        wire::encode_control(&mut l.w, "hello", &[])?;
        l.w.flush()?;
        let welcome = wire::decode(&mut l.r)?;
        let body = expect_control(&welcome, "welcome")?;
        let mut rd = ByteReader::new(&body);
        let site_id = rd.read_u32()? as usize;
        let n_sites = rd.read_u32()? as usize;
        Ok(TcpSite { link: l, site_id, n_sites })
    }

    /// The id the aggregator assigned this site (0-based, accept order).
    pub fn site_id(&self) -> usize {
        self.site_id
    }

    /// [`TcpSite::connect`] with retries: launcher scripts (and the CI
    /// remote-matrix job) start the aggregator and the sites concurrently,
    /// so the first dials can land before the listener is bound. Retries
    /// connection-refused/reset every 200 ms until `timeout` elapses;
    /// protocol errors still fail immediately.
    pub fn connect_retry(addr: &str, timeout: std::time::Duration) -> io::Result<TcpSite> {
        let deadline = std::time::Instant::now() + timeout;
        loop {
            match TcpSite::connect(addr) {
                Ok(site) => return Ok(site),
                Err(e)
                    if std::time::Instant::now() < deadline
                        && matches!(
                            e.kind(),
                            io::ErrorKind::ConnectionRefused
                                | io::ErrorKind::ConnectionReset
                                | io::ErrorKind::AddrNotAvailable
                        ) =>
                {
                    std::thread::sleep(std::time::Duration::from_millis(200));
                }
                Err(e) => return Err(e),
            }
        }
    }
}

impl Transport for TcpSite {
    fn name(&self) -> &'static str {
        "tcp-site"
    }

    fn n_sites(&self) -> usize {
        self.n_sites
    }

    fn ship(&mut self, dir: Direction, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_payload(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n)
            }
            Direction::PeerToPeer => {
                // Physically one uplink to the hub, which relays the frame
                // to the other S-1 sites; the returned count prices what a
                // true mesh would ship (one unicast per receiving peer),
                // matching the loopback fan-out convention.
                let n = wire::encode_payload(&mut self.link.w, tag, mats)?;
                self.link.w.flush()?;
                Ok(n * self.n_sites.saturating_sub(1) as u64)
            }
            Direction::AggToSite => Err(unsupported("tcp-site", "non-uplink ship")),
        }
    }

    fn ship_control(&mut self, dir: Direction, tag: &str, body: &[u8]) -> io::Result<u64> {
        match dir {
            Direction::SiteToAgg => {
                let n = wire::encode_control(&mut self.link.w, tag, body)?;
                self.link.w.flush()?;
                Ok(n)
            }
            _ => Err(unsupported("tcp-site", "non-uplink ship_control")),
        }
    }

    fn recv_broadcast(&mut self) -> io::Result<Frame> {
        wire::decode(&mut self.link.r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Handshake assigns ids in accept order; frames cross the socket
    /// bit-exactly and byte counts agree with the arithmetic lengths.
    #[test]
    fn handshake_and_frame_exchange() {
        let listener = TcpAgg::bind("127.0.0.1:0", 2).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let sites: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let mut s = TcpSite::connect(&addr).unwrap();
                    let m = Matrix::filled(2, 3, s.site_id() as f32);
                    let n = s.ship(Direction::SiteToAgg, "acts", &[&m]).unwrap();
                    assert_eq!(n, wire::payload_wire_len("acts", &[&m]));
                    let down = s.recv_broadcast().unwrap();
                    assert_eq!(down.tag, "sum");
                    match down.body {
                        Body::Mats(ms) => ms[0][(0, 0)],
                        Body::Control(_) => panic!("wrong kind"),
                    }
                })
            })
            .collect();
        let mut agg = listener.accept_sites().unwrap();
        assert_eq!(agg.n_sites(), 2);
        let mut total = 0.0;
        for site in 0..2 {
            let f = agg.recv_from_site(site).unwrap();
            assert_eq!(f.tag, "acts");
            match f.body {
                Body::Mats(ms) => {
                    // The value encodes the handshake-assigned id.
                    assert_eq!(ms[0][(0, 0)], site as f32);
                    total += ms[0][(0, 0)];
                }
                Body::Control(_) => panic!("wrong kind"),
            }
        }
        let sum = Matrix::filled(1, 1, total);
        agg.ship(Direction::AggToSite, "sum", &[&sum]).unwrap();
        for s in sites {
            assert_eq!(s.join().unwrap(), 1.0);
        }
    }
}

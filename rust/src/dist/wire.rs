//! The wire codec: length-prefixed, versioned frames carrying the paper's
//! statistics between training processes.
//!
//! Every message any backend moves — loopback or TCP — is one frame:
//!
//! ```text
//! u32  frame length (little-endian; bytes after this prefix)
//! u8   codec version (WIRE_VERSION)
//! u8   frame kind    (0 = control, 1 = payload, 2 = sparse payload)
//! u8   tag length; tag bytes (UTF-8: "acts", "deltas", "direct-grad", ...)
//! kind = payload: u16 matrix count, then per matrix
//!                 u32 rows, u32 cols, rows*cols f32 little-endian values
//! kind = sparse:  u16 matrix count, then per matrix
//!                 u32 rows, u32 cols, u32 nnz, nnz u32 element indices
//!                 (row-major, strictly increasing), nnz f32 values
//! kind = control: raw body bytes (ByteWriter/ByteReader field streams)
//! ```
//!
//! Payload frames carry tagged [`crate::nn::stats::StatsEntry`] constituents
//! (activation stacks, delta stacks) and direct gradients; they are what the
//! [`crate::dist::Ledger`] counts, so the bandwidth experiments report
//! *actual serialized bytes* — headers, dimensions and all — rather than the
//! `rows * cols * 4` estimate the simulator used before this codec existed.
//! Control frames (handshakes, per-step metadata) are protocol overhead and
//! are deliberately excluded from the ledger.
//!
//! The simulated cluster never serializes: [`payload_wire_len`] computes the
//! exact encoded size arithmetically, so the loopback backend stays as fast
//! as the old ledger-increment path while reporting identical byte counts to
//! a real TCP run.
//!
//! ## Tag vocabulary
//!
//! Tags are free-form, but both training modes must spell them identically
//! for the per-(tag, direction) ledger equivalence to hold. The full set:
//!
//! | tag | kind | carried by |
//! |---|---|---|
//! | `acts`, `deltas` | payload | dAD / dad-p2p (A, Δ) stacks |
//! | `aux-acts`, `delta-L` | payload | edAD aux activations + output delta |
//! | `grad` | payload | dSGD full gradients |
//! | `lowrank-q`, `lowrank-g` | payload | rank-dAD factor pairs |
//! | `psgd-p`, `psgd-q` | payload | PowerSGD factor pairs (P, Q) |
//! | `sparse-grad` | sparse | DGC / VBC / AdaComp top-k weight updates |
//! | `bias-grad`, `direct-grad` | payload | non-outer-product gradients |
//! | `hello`, `welcome`, `config` | control | transport + run handshake |
//! | `step-meta`, `step-sync` | control | per-step prologue |
//! | `eff-rank` | control | rank-dAD effective-rank telemetry |
//! | `local-loss` | control | periodic-schedule local-phase losses |
//! | `resume` | control | checkpoint state broadcast on `--resume` |
//! | `infer-hello`, `infer-welcome` | control | inference-server handshake |
//! | `infer-req`, `infer-res` | control | batched inference request/response |
//! | `infer-shutdown` | control | clean inference-server stop |
//!
//! The same framing is reused verbatim as the on-disk checkpoint container
//! (`ckpt-meta` / `ckpt-params` / `ckpt-adam-m` / `ckpt-adam-v` /
//! `ckpt-algo` / `ckpt-end` frames behind a magic header) — see
//! [`crate::checkpoint`] and `rust/docs/FORMATS.md` for the normative spec.

use std::io::{self, Read, Write};

use crate::tensor::Matrix;

/// Codec version byte; both ends of a connection must agree. Bumped to 2
/// when the `config` control frame gained the sync-schedule field (and
/// the step prologue gained `step-meta.n_aux`); to 3 when `config` gained
/// the site recv-timeout and partition-override fields (the chaos/fault
/// layer); to 4 when frame kind 2 (sparse payload: u32 index + f32 value
/// pairs for DGC/VBC/AdaComp) was added; to 5 when `config` gained the
/// resume flag (followed by a `resume` control frame carrying checkpoint
/// state) and the `infer-*` serving handshake was added; to 6 when the
/// hello/welcome handshake gained multi-leaf subtree declarations (tree
/// topologies), the config resume flag became a three-state mode byte
/// (fresh / checkpoint / elastic), and the `epoch-sync` membership
/// roll-call was added. A peer from an older build dialing a newer
/// endpoint fails cleanly at the handshake instead of mid-run.
pub const WIRE_VERSION: u8 = 6;

/// Upper bound on one frame's post-prefix length (1 GiB): a decoder sanity
/// check against corrupt or hostile length prefixes.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Discriminates the two frame families on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Protocol control (handshake, step metadata); never enters the ledger.
    Control,
    /// Tagged statistics payload (matrices); counted by the byte ledger.
    Payload,
}

/// Body of a decoded [`Frame`].
#[derive(Debug, Clone)]
pub enum Body {
    /// Control body: opaque little-endian field stream (see [`ByteReader`]).
    Control(Vec<u8>),
    /// Payload body: the matrices that crossed the link.
    Mats(Vec<Matrix>),
    /// Sparse payload body: (index, value) pairs over a dense shape.
    Sparse(Vec<SparseMat>),
}

/// A sparse matrix on the wire: explicit (element index, value) pairs over
/// a dense `rows x cols` shape. Indices are row-major element offsets and
/// must be strictly increasing — the decoder rejects out-of-range,
/// duplicate and unsorted indices as `InvalidData`, so a frame that decodes
/// is always safe to scatter. Each nonzero costs 8 bytes (u32 index + f32
/// value): the index overhead the Ledger charges so sparse bandwidth
/// numbers are honest.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseMat {
    /// Dense row count of the matrix this sparsifies.
    pub rows: usize,
    /// Dense column count.
    pub cols: usize,
    /// Row-major element offsets of the nonzeros, strictly increasing.
    pub idx: Vec<u32>,
    /// The nonzero values, parallel to `idx`.
    pub vals: Vec<f32>,
}

impl SparseMat {
    /// Collect every element of `m` whose row-major offset is in `keep`
    /// (which must be strictly increasing — the protocol builders produce
    /// sorted index sets).
    pub fn from_dense(m: &Matrix, keep: &[u32]) -> Self {
        let data = m.data();
        let vals = keep.iter().map(|&i| data[i as usize]).collect();
        SparseMat { rows: m.rows(), cols: m.cols(), idx: keep.to_vec(), vals }
    }

    /// Number of transmitted nonzeros.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Serialized body bytes for this matrix: dims + nnz header plus
    /// 8 bytes per nonzero.
    pub fn wire_bytes(&self) -> u64 {
        12 + 8 * self.idx.len() as u64
    }

    /// Materialize as a dense matrix (zeros at untransmitted positions).
    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        self.scatter_add(&mut m);
        m
    }

    /// Add each nonzero into the matching element of `dst` (shape must
    /// agree). The aggregator reduces per-site sparse contributions this
    /// way, in site order, so the f32 add sequence is deterministic.
    pub fn scatter_add(&self, dst: &mut Matrix) {
        assert_eq!(dst.shape(), (self.rows, self.cols), "sparse scatter shape mismatch");
        let data = dst.data_mut();
        for (&i, &v) in self.idx.iter().zip(&self.vals) {
            data[i as usize] += v;
        }
    }

    /// Decode-side structural checks: parallel arrays, strictly increasing
    /// indices (no duplicates), everything inside `rows * cols`.
    fn validate(&self) -> io::Result<()> {
        if self.idx.len() != self.vals.len() {
            return Err(proto_err(format!(
                "sparse frame: {} indices but {} values",
                self.idx.len(),
                self.vals.len()
            )));
        }
        let numel = self.rows * self.cols;
        let mut last: Option<u32> = None;
        for &i in &self.idx {
            if i as usize >= numel {
                return Err(proto_err(format!(
                    "sparse index {i} out of range for {}x{} matrix",
                    self.rows, self.cols
                )));
            }
            if let Some(prev) = last {
                if i <= prev {
                    return Err(proto_err(format!(
                        "sparse indices not strictly increasing: {prev} then {i}"
                    )));
                }
            }
            last = Some(i);
        }
        Ok(())
    }
}

/// One decoded frame, as produced by [`decode`].
#[derive(Debug, Clone)]
pub struct Frame {
    /// Payload tag ("acts", "deltas", ...) or control verb ("hello", ...).
    pub tag: String,
    /// Control bytes or payload matrices.
    pub body: Body,
}

impl Frame {
    /// Which frame family this is. Sparse frames are payload: they carry
    /// model statistics and enter the ledger like dense payload frames.
    pub fn kind(&self) -> FrameKind {
        match self.body {
            Body::Control(_) => FrameKind::Control,
            Body::Mats(_) | Body::Sparse(_) => FrameKind::Payload,
        }
    }

    /// Exact bytes this frame occupies on the wire (prefix included) —
    /// what a receiver records in its ledger for payload frames.
    pub fn wire_len(&self) -> u64 {
        match &self.body {
            Body::Control(b) => control_wire_len(&self.tag, b),
            Body::Mats(ms) => {
                let refs: Vec<&Matrix> = ms.iter().collect();
                payload_wire_len(&self.tag, &refs)
            }
            Body::Sparse(ms) => {
                let refs: Vec<&SparseMat> = ms.iter().collect();
                sparse_wire_len(&self.tag, &refs)
            }
        }
    }
}

/// Shared prefix + header bytes: length, version, kind, tag length, tag.
fn header_len(tag: &str) -> u64 {
    4 + 1 + 1 + 1 + tag.len() as u64
}

/// Exact encoded size of a payload frame (prefix included), computed
/// without serializing — the loopback backend's whole cost model.
pub fn payload_wire_len(tag: &str, mats: &[&Matrix]) -> u64 {
    let bodies: u64 = mats.iter().map(|m| 8 + m.wire_bytes()).sum();
    header_len(tag) + 2 + bodies
}

/// Exact encoded size of a sparse payload frame (prefix included),
/// computed without serializing — the loopback backend's cost model for
/// sparse shipments. Counts the u32 index alongside each f32 value, so
/// the "compressed" byte totals include their addressing overhead.
pub fn sparse_wire_len(tag: &str, mats: &[&SparseMat]) -> u64 {
    let bodies: u64 = mats.iter().map(|m| m.wire_bytes()).sum();
    header_len(tag) + 2 + bodies
}

/// Exact encoded size of a control frame (prefix included).
pub fn control_wire_len(tag: &str, body: &[u8]) -> u64 {
    header_len(tag) + body.len() as u64
}

pub(crate) fn proto_err(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Encode one payload frame into `w`; returns the bytes written (which
/// always equals [`payload_wire_len`]).
pub fn encode_payload<W: Write>(w: &mut W, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
    assert!(tag.len() <= u8::MAX as usize, "frame tag too long: {tag:?}");
    assert!(mats.len() <= u16::MAX as usize, "too many matrices in one frame");
    let total = payload_wire_len(tag, mats);
    w.write_all(&((total - 4) as u32).to_le_bytes())?;
    w.write_all(&[WIRE_VERSION, 1, tag.len() as u8])?;
    w.write_all(tag.as_bytes())?;
    w.write_all(&(mats.len() as u16).to_le_bytes())?;
    // Fixed stack chunk: no per-frame heap allocation on the TCP path
    // (the destination is buffered, so small writes are cheap anyway).
    let mut chunk = [0u8; 4096];
    for m in mats {
        w.write_all(&(m.rows() as u32).to_le_bytes())?;
        w.write_all(&(m.cols() as u32).to_le_bytes())?;
        for vals in m.data().chunks(chunk.len() / 4) {
            for (dst, &v) in chunk.chunks_exact_mut(4).zip(vals) {
                dst.copy_from_slice(&v.to_le_bytes());
            }
            w.write_all(&chunk[..vals.len() * 4])?;
        }
    }
    Ok(total)
}

/// Encode one sparse payload frame into `w`; returns the bytes written
/// (which always equals [`sparse_wire_len`]). Callers must hand over
/// strictly increasing in-range indices — the same invariant `decode`
/// enforces — so loopback and TCP runs ship identical frames.
pub fn encode_sparse<W: Write>(w: &mut W, tag: &str, mats: &[&SparseMat]) -> io::Result<u64> {
    assert!(tag.len() <= u8::MAX as usize, "frame tag too long: {tag:?}");
    assert!(mats.len() <= u16::MAX as usize, "too many matrices in one frame");
    let total = sparse_wire_len(tag, mats);
    w.write_all(&((total - 4) as u32).to_le_bytes())?;
    w.write_all(&[WIRE_VERSION, 2, tag.len() as u8])?;
    w.write_all(tag.as_bytes())?;
    w.write_all(&(mats.len() as u16).to_le_bytes())?;
    let mut chunk = [0u8; 4096];
    for m in mats {
        debug_assert!(m.validate().is_ok(), "encoding an invalid sparse matrix");
        w.write_all(&(m.rows as u32).to_le_bytes())?;
        w.write_all(&(m.cols as u32).to_le_bytes())?;
        w.write_all(&(m.idx.len() as u32).to_le_bytes())?;
        for part in m.idx.chunks(chunk.len() / 4) {
            for (dst, &i) in chunk.chunks_exact_mut(4).zip(part) {
                dst.copy_from_slice(&i.to_le_bytes());
            }
            w.write_all(&chunk[..part.len() * 4])?;
        }
        for part in m.vals.chunks(chunk.len() / 4) {
            for (dst, &v) in chunk.chunks_exact_mut(4).zip(part) {
                dst.copy_from_slice(&v.to_le_bytes());
            }
            w.write_all(&chunk[..part.len() * 4])?;
        }
    }
    Ok(total)
}

/// Encode one control frame into `w`; returns the bytes written (which
/// always equals [`control_wire_len`]).
pub fn encode_control<W: Write>(w: &mut W, tag: &str, body: &[u8]) -> io::Result<u64> {
    assert!(tag.len() <= u8::MAX as usize, "frame tag too long: {tag:?}");
    let total = control_wire_len(tag, body);
    w.write_all(&((total - 4) as u32).to_le_bytes())?;
    w.write_all(&[WIRE_VERSION, 0, tag.len() as u8])?;
    w.write_all(tag.as_bytes())?;
    w.write_all(body)?;
    Ok(total)
}

/// Re-encode a decoded [`Frame`] into `w` (the aggregator's peer-to-peer
/// relay path); returns the bytes written. Round-trips exactly: the f32 LE
/// body is lossless, so a relayed frame is bit-identical to the original.
pub fn encode_frame<W: Write>(w: &mut W, f: &Frame) -> io::Result<u64> {
    match &f.body {
        Body::Control(b) => encode_control(w, &f.tag, b),
        Body::Mats(ms) => {
            let refs: Vec<&Matrix> = ms.iter().collect();
            encode_payload(w, &f.tag, &refs)
        }
        Body::Sparse(ms) => {
            let refs: Vec<&SparseMat> = ms.iter().collect();
            encode_sparse(w, &f.tag, &refs)
        }
    }
}

/// Decode the next frame from `r`, validating version, kind and sizes.
pub fn decode<R: Read>(r: &mut R) -> io::Result<Frame> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    if !(3..=MAX_FRAME_LEN).contains(&len) {
        return Err(proto_err(format!("frame length {len} out of range")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    let mut rd = ByteReader::new(&buf);
    let version = rd.read_u8()?;
    if version != WIRE_VERSION {
        return Err(proto_err(format!("wire version {version}, expected {WIRE_VERSION}")));
    }
    let kind = rd.read_u8()?;
    let tag_len = rd.read_u8()? as usize;
    let tag = std::str::from_utf8(rd.take(tag_len)?)
        .map_err(|_| proto_err("frame tag is not UTF-8".into()))?
        .to_string();
    match kind {
        0 => Ok(Frame { tag, body: Body::Control(rd.rest().to_vec()) }),
        1 => {
            let n_mats = rd.read_u16()? as usize;
            let mut mats = Vec::with_capacity(n_mats);
            for _ in 0..n_mats {
                let rows = rd.read_u32()? as usize;
                let cols = rd.read_u32()? as usize;
                let numel = rows
                    .checked_mul(cols)
                    .filter(|&n| n.checked_mul(4).is_some())
                    .ok_or_else(|| proto_err(format!("matrix {rows}x{cols} overflows")))?;
                let raw = rd.take(numel * 4)?;
                let mut data = Vec::with_capacity(numel);
                for c in raw.chunks_exact(4) {
                    data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                mats.push(Matrix::from_vec(rows, cols, data));
            }
            if rd.remaining() != 0 {
                return Err(proto_err("trailing bytes after payload frame".into()));
            }
            Ok(Frame { tag, body: Body::Mats(mats) })
        }
        2 => {
            let n_mats = rd.read_u16()? as usize;
            let mut mats = Vec::with_capacity(n_mats);
            for _ in 0..n_mats {
                let rows = rd.read_u32()? as usize;
                let cols = rd.read_u32()? as usize;
                let nnz = rd.read_u32()? as usize;
                let numel = rows
                    .checked_mul(cols)
                    .filter(|&n| n.checked_mul(4).is_some())
                    .ok_or_else(|| proto_err(format!("matrix {rows}x{cols} overflows")))?;
                if nnz > numel {
                    return Err(proto_err(format!(
                        "sparse frame claims {nnz} nonzeros in a {rows}x{cols} matrix"
                    )));
                }
                let raw_idx = rd.take(nnz * 4)?;
                let mut idx = Vec::with_capacity(nnz);
                for c in raw_idx.chunks_exact(4) {
                    idx.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                let raw_vals = rd.take(nnz * 4)?;
                let mut vals = Vec::with_capacity(nnz);
                for c in raw_vals.chunks_exact(4) {
                    vals.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                let m = SparseMat { rows, cols, idx, vals };
                m.validate()?;
                mats.push(m);
            }
            if rd.remaining() != 0 {
                return Err(proto_err("trailing bytes after sparse frame".into()));
            }
            Ok(Frame { tag, body: Body::Sparse(mats) })
        }
        k => Err(proto_err(format!("unknown frame kind {k}"))),
    }
}

/// Little-endian field serializer for control-frame bodies.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Fresh empty body.
    pub fn new() -> Self {
        ByteWriter::default()
    }

    /// Append one byte.
    pub fn push_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian u16.
    pub fn push_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u32.
    pub fn push_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u64.
    pub fn push_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian f32.
    pub fn push_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed (u16) UTF-8 string.
    pub fn push_str(&mut self, s: &str) {
        assert!(s.len() <= u16::MAX as usize, "string field too long");
        self.push_u16(s.len() as u16);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// The finished body bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian field deserializer over a control-frame body; every read
/// is bounds-checked and truncation surfaces as `InvalidData`.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Read fields from `buf`, front to back.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(proto_err(format!(
                "truncated frame: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Everything not yet consumed, consuming it.
    pub fn rest(&mut self) -> &'a [u8] {
        let out = &self.buf[self.pos..];
        self.pos = self.buf.len();
        out
    }

    /// Next byte.
    pub fn read_u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Next little-endian u16.
    pub fn read_u16(&mut self) -> io::Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Next little-endian u32.
    pub fn read_u32(&mut self) -> io::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next little-endian u64.
    pub fn read_u64(&mut self) -> io::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Next little-endian f32.
    pub fn read_f32(&mut self) -> io::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next length-prefixed (u16) UTF-8 string.
    pub fn read_str(&mut self) -> io::Result<String> {
        let n = self.read_u16()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| proto_err("string field not UTF-8".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn payload_roundtrip_preserves_matrices() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 7, 1.0, &mut rng);
        let b = Matrix::randn(1, 4, 1.0, &mut rng);
        let mut buf = Vec::new();
        let n = encode_payload(&mut buf, "acts", &[&a, &b]).unwrap();
        assert_eq!(n as usize, buf.len());
        assert_eq!(n, payload_wire_len("acts", &[&a, &b]));
        let f = decode(&mut buf.as_slice()).unwrap();
        assert_eq!(f.tag, "acts");
        assert_eq!(f.kind(), FrameKind::Payload);
        assert_eq!(f.wire_len(), n);
        match f.body {
            Body::Mats(ms) => {
                assert_eq!(ms.len(), 2);
                assert_eq!(ms[0], a);
                assert_eq!(ms[1], b);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn control_roundtrip_and_field_streams() {
        let mut w = ByteWriter::new();
        w.push_u8(7);
        w.push_u32(123_456);
        w.push_u64(u64::MAX - 5);
        w.push_f32(-0.25);
        w.push_str("mnist");
        let body = w.finish();
        let mut buf = Vec::new();
        let n = encode_control(&mut buf, "config", &body).unwrap();
        assert_eq!(n, control_wire_len("config", &body));
        let f = decode(&mut buf.as_slice()).unwrap();
        assert_eq!(f.tag, "config");
        let got = match f.body {
            Body::Control(b) => b,
            _ => panic!("wrong kind"),
        };
        let mut r = ByteReader::new(&got);
        assert_eq!(r.read_u8().unwrap(), 7);
        assert_eq!(r.read_u32().unwrap(), 123_456);
        assert_eq!(r.read_u64().unwrap(), u64::MAX - 5);
        assert_eq!(r.read_f32().unwrap(), -0.25);
        assert_eq!(r.read_str().unwrap(), "mnist");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn empty_matrix_roundtrips() {
        let m = Matrix::zeros(0, 5);
        let mut buf = Vec::new();
        encode_payload(&mut buf, "deltas", &[&m]).unwrap();
        let f = decode(&mut buf.as_slice()).unwrap();
        match f.body {
            Body::Mats(ms) => assert_eq!(ms[0].shape(), (0, 5)),
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn sparse_roundtrip_preserves_pairs() {
        let a = SparseMat {
            rows: 4,
            cols: 5,
            idx: vec![0, 3, 7, 19],
            vals: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
        };
        let b = SparseMat { rows: 2, cols: 2, idx: vec![], vals: vec![] };
        let mut buf = Vec::new();
        let n = encode_sparse(&mut buf, "sparse-grad", &[&a, &b]).unwrap();
        assert_eq!(n as usize, buf.len());
        assert_eq!(n, sparse_wire_len("sparse-grad", &[&a, &b]));
        let f = decode(&mut buf.as_slice()).unwrap();
        assert_eq!(f.tag, "sparse-grad");
        assert_eq!(f.kind(), FrameKind::Payload);
        assert_eq!(f.wire_len(), n);
        match f.body {
            Body::Sparse(ms) => {
                assert_eq!(ms.len(), 2);
                assert_eq!(ms[0], a);
                assert_eq!(ms[1], b);
            }
            _ => panic!("wrong kind"),
        }
    }

    #[test]
    fn sparse_scatter_matches_dense() {
        let mut rng = Rng::new(7);
        let dense = Matrix::randn(3, 4, 1.0, &mut rng);
        let all: Vec<u32> = (0..12).collect();
        let s = SparseMat::from_dense(&dense, &all);
        assert_eq!(s.to_dense(), dense);
        let some = SparseMat::from_dense(&dense, &[1, 6, 11]);
        let d = some.to_dense();
        assert_eq!(d.data()[1], dense.data()[1]);
        assert_eq!(d.data()[0], 0.0);
    }

    #[test]
    fn sparse_bad_indices_rejected() {
        let encode_one = |m: &SparseMat| {
            let mut buf = Vec::new();
            // Bypass the encoder's debug assertion by writing the frame
            // by hand from a valid template, then corrupting the index.
            let good = SparseMat {
                rows: m.rows,
                cols: m.cols,
                idx: (0..m.idx.len() as u32).collect(),
                vals: m.vals.clone(),
            };
            encode_sparse(&mut buf, "sparse-grad", &[&good]).unwrap();
            // Indices start after prefix(4)+ver+kind+taglen+tag(11)+count(2)+dims(12).
            let base = 4 + 3 + "sparse-grad".len() + 2 + 12;
            for (k, &i) in m.idx.iter().enumerate() {
                buf[base + 4 * k..base + 4 * k + 4].copy_from_slice(&i.to_le_bytes());
            }
            decode(&mut buf.as_slice())
        };
        // Out of range: index 20 in a 4x5 matrix.
        let oor = SparseMat { rows: 4, cols: 5, idx: vec![20], vals: vec![1.0] };
        assert!(encode_one(&oor).unwrap_err().to_string().contains("out of range"));
        // Duplicate index.
        let dup = SparseMat { rows: 4, cols: 5, idx: vec![3, 3], vals: vec![1.0, 2.0] };
        assert!(encode_one(&dup).unwrap_err().to_string().contains("strictly increasing"));
        // Unsorted.
        let uns = SparseMat { rows: 4, cols: 5, idx: vec![7, 2], vals: vec![1.0, 2.0] };
        assert!(encode_one(&uns).unwrap_err().to_string().contains("strictly increasing"));
    }

    #[test]
    fn sparse_nnz_overflow_rejected() {
        // A frame claiming more nonzeros than elements must fail cleanly
        // before any allocation of nnz size.
        let good = SparseMat { rows: 2, cols: 2, idx: vec![0], vals: vec![1.0] };
        let mut buf = Vec::new();
        encode_sparse(&mut buf, "s", &[&good]).unwrap();
        let nnz_at = 4 + 3 + 1 + 2 + 8;
        buf[nnz_at..nnz_at + 4].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn version_mismatch_and_truncation_rejected() {
        let mut buf = Vec::new();
        encode_control(&mut buf, "hello", &[1, 2, 3]).unwrap();
        let mut bad = buf.clone();
        bad[4] = WIRE_VERSION + 1; // version byte lives right after the prefix
        assert!(decode(&mut bad.as_slice()).is_err());
        let cut = &buf[..buf.len() - 1];
        assert!(decode(&mut &cut[..]).is_err());
    }
}

//! The wire codec: length-prefixed, versioned frames carrying the paper's
//! statistics between training processes.
//!
//! Every message any backend moves — loopback or TCP — is one frame:
//!
//! ```text
//! u32  frame length (little-endian; bytes after this prefix)
//! u8   codec version (WIRE_VERSION)
//! u8   frame kind    (0 = control, 1 = payload)
//! u8   tag length; tag bytes (UTF-8: "acts", "deltas", "direct-grad", ...)
//! kind = payload: u16 matrix count, then per matrix
//!                 u32 rows, u32 cols, rows*cols f32 little-endian values
//! kind = control: raw body bytes (ByteWriter/ByteReader field streams)
//! ```
//!
//! Payload frames carry tagged [`crate::nn::stats::StatsEntry`] constituents
//! (activation stacks, delta stacks) and direct gradients; they are what the
//! [`crate::dist::Ledger`] counts, so the bandwidth experiments report
//! *actual serialized bytes* — headers, dimensions and all — rather than the
//! `rows * cols * 4` estimate the simulator used before this codec existed.
//! Control frames (handshakes, per-step metadata) are protocol overhead and
//! are deliberately excluded from the ledger.
//!
//! The simulated cluster never serializes: [`payload_wire_len`] computes the
//! exact encoded size arithmetically, so the loopback backend stays as fast
//! as the old ledger-increment path while reporting identical byte counts to
//! a real TCP run.
//!
//! ## Tag vocabulary
//!
//! Tags are free-form, but both training modes must spell them identically
//! for the per-(tag, direction) ledger equivalence to hold. The full set:
//!
//! | tag | kind | carried by |
//! |---|---|---|
//! | `acts`, `deltas` | payload | dAD / dad-p2p (A, Δ) stacks |
//! | `aux-acts`, `delta-L` | payload | edAD aux activations + output delta |
//! | `grad` | payload | dSGD full gradients |
//! | `lowrank-q`, `lowrank-g` | payload | rank-dAD factor pairs |
//! | `psgd-p`, `psgd-q` | payload | PowerSGD factor pairs (P, Q) |
//! | `bias-grad`, `direct-grad` | payload | non-outer-product gradients |
//! | `hello`, `welcome`, `config` | control | transport + run handshake |
//! | `step-meta`, `step-sync` | control | per-step prologue |
//! | `eff-rank` | control | rank-dAD effective-rank telemetry |
//! | `local-loss` | control | periodic-schedule local-phase losses |

use std::io::{self, Read, Write};

use crate::tensor::Matrix;

/// Codec version byte; both ends of a connection must agree. Bumped to 2
/// when the `config` control frame gained the sync-schedule field (and
/// the step prologue gained `step-meta.n_aux`); to 3 when `config` gained
/// the site recv-timeout and partition-override fields (the chaos/fault
/// layer). A peer from an older build dialing a newer endpoint fails
/// cleanly at the handshake instead of mid-run.
pub const WIRE_VERSION: u8 = 3;

/// Upper bound on one frame's post-prefix length (1 GiB): a decoder sanity
/// check against corrupt or hostile length prefixes.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Discriminates the two frame families on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Protocol control (handshake, step metadata); never enters the ledger.
    Control,
    /// Tagged statistics payload (matrices); counted by the byte ledger.
    Payload,
}

/// Body of a decoded [`Frame`].
#[derive(Debug, Clone)]
pub enum Body {
    /// Control body: opaque little-endian field stream (see [`ByteReader`]).
    Control(Vec<u8>),
    /// Payload body: the matrices that crossed the link.
    Mats(Vec<Matrix>),
}

/// One decoded frame, as produced by [`decode`].
#[derive(Debug, Clone)]
pub struct Frame {
    /// Payload tag ("acts", "deltas", ...) or control verb ("hello", ...).
    pub tag: String,
    /// Control bytes or payload matrices.
    pub body: Body,
}

impl Frame {
    /// Which frame family this is.
    pub fn kind(&self) -> FrameKind {
        match self.body {
            Body::Control(_) => FrameKind::Control,
            Body::Mats(_) => FrameKind::Payload,
        }
    }

    /// Exact bytes this frame occupies on the wire (prefix included) —
    /// what a receiver records in its ledger for payload frames.
    pub fn wire_len(&self) -> u64 {
        match &self.body {
            Body::Control(b) => control_wire_len(&self.tag, b),
            Body::Mats(ms) => {
                let refs: Vec<&Matrix> = ms.iter().collect();
                payload_wire_len(&self.tag, &refs)
            }
        }
    }
}

/// Shared prefix + header bytes: length, version, kind, tag length, tag.
fn header_len(tag: &str) -> u64 {
    4 + 1 + 1 + 1 + tag.len() as u64
}

/// Exact encoded size of a payload frame (prefix included), computed
/// without serializing — the loopback backend's whole cost model.
pub fn payload_wire_len(tag: &str, mats: &[&Matrix]) -> u64 {
    let bodies: u64 = mats.iter().map(|m| 8 + m.wire_bytes()).sum();
    header_len(tag) + 2 + bodies
}

/// Exact encoded size of a control frame (prefix included).
pub fn control_wire_len(tag: &str, body: &[u8]) -> u64 {
    header_len(tag) + body.len() as u64
}

pub(crate) fn proto_err(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Encode one payload frame into `w`; returns the bytes written (which
/// always equals [`payload_wire_len`]).
pub fn encode_payload<W: Write>(w: &mut W, tag: &str, mats: &[&Matrix]) -> io::Result<u64> {
    assert!(tag.len() <= u8::MAX as usize, "frame tag too long: {tag:?}");
    assert!(mats.len() <= u16::MAX as usize, "too many matrices in one frame");
    let total = payload_wire_len(tag, mats);
    w.write_all(&((total - 4) as u32).to_le_bytes())?;
    w.write_all(&[WIRE_VERSION, 1, tag.len() as u8])?;
    w.write_all(tag.as_bytes())?;
    w.write_all(&(mats.len() as u16).to_le_bytes())?;
    // Fixed stack chunk: no per-frame heap allocation on the TCP path
    // (the destination is buffered, so small writes are cheap anyway).
    let mut chunk = [0u8; 4096];
    for m in mats {
        w.write_all(&(m.rows() as u32).to_le_bytes())?;
        w.write_all(&(m.cols() as u32).to_le_bytes())?;
        for vals in m.data().chunks(chunk.len() / 4) {
            for (dst, &v) in chunk.chunks_exact_mut(4).zip(vals) {
                dst.copy_from_slice(&v.to_le_bytes());
            }
            w.write_all(&chunk[..vals.len() * 4])?;
        }
    }
    Ok(total)
}

/// Encode one control frame into `w`; returns the bytes written (which
/// always equals [`control_wire_len`]).
pub fn encode_control<W: Write>(w: &mut W, tag: &str, body: &[u8]) -> io::Result<u64> {
    assert!(tag.len() <= u8::MAX as usize, "frame tag too long: {tag:?}");
    let total = control_wire_len(tag, body);
    w.write_all(&((total - 4) as u32).to_le_bytes())?;
    w.write_all(&[WIRE_VERSION, 0, tag.len() as u8])?;
    w.write_all(tag.as_bytes())?;
    w.write_all(body)?;
    Ok(total)
}

/// Re-encode a decoded [`Frame`] into `w` (the aggregator's peer-to-peer
/// relay path); returns the bytes written. Round-trips exactly: the f32 LE
/// body is lossless, so a relayed frame is bit-identical to the original.
pub fn encode_frame<W: Write>(w: &mut W, f: &Frame) -> io::Result<u64> {
    match &f.body {
        Body::Control(b) => encode_control(w, &f.tag, b),
        Body::Mats(ms) => {
            let refs: Vec<&Matrix> = ms.iter().collect();
            encode_payload(w, &f.tag, &refs)
        }
    }
}

/// Decode the next frame from `r`, validating version, kind and sizes.
pub fn decode<R: Read>(r: &mut R) -> io::Result<Frame> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix) as usize;
    if !(3..=MAX_FRAME_LEN).contains(&len) {
        return Err(proto_err(format!("frame length {len} out of range")));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    let mut rd = ByteReader::new(&buf);
    let version = rd.read_u8()?;
    if version != WIRE_VERSION {
        return Err(proto_err(format!("wire version {version}, expected {WIRE_VERSION}")));
    }
    let kind = rd.read_u8()?;
    let tag_len = rd.read_u8()? as usize;
    let tag = std::str::from_utf8(rd.take(tag_len)?)
        .map_err(|_| proto_err("frame tag is not UTF-8".into()))?
        .to_string();
    match kind {
        0 => Ok(Frame { tag, body: Body::Control(rd.rest().to_vec()) }),
        1 => {
            let n_mats = rd.read_u16()? as usize;
            let mut mats = Vec::with_capacity(n_mats);
            for _ in 0..n_mats {
                let rows = rd.read_u32()? as usize;
                let cols = rd.read_u32()? as usize;
                let numel = rows
                    .checked_mul(cols)
                    .filter(|&n| n.checked_mul(4).is_some())
                    .ok_or_else(|| proto_err(format!("matrix {rows}x{cols} overflows")))?;
                let raw = rd.take(numel * 4)?;
                let mut data = Vec::with_capacity(numel);
                for c in raw.chunks_exact(4) {
                    data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
                }
                mats.push(Matrix::from_vec(rows, cols, data));
            }
            if rd.remaining() != 0 {
                return Err(proto_err("trailing bytes after payload frame".into()));
            }
            Ok(Frame { tag, body: Body::Mats(mats) })
        }
        k => Err(proto_err(format!("unknown frame kind {k}"))),
    }
}

/// Little-endian field serializer for control-frame bodies.
#[derive(Debug, Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    /// Fresh empty body.
    pub fn new() -> Self {
        ByteWriter::default()
    }

    /// Append one byte.
    pub fn push_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a little-endian u16.
    pub fn push_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u32.
    pub fn push_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian u64.
    pub fn push_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a little-endian f32.
    pub fn push_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed (u16) UTF-8 string.
    pub fn push_str(&mut self, s: &str) {
        assert!(s.len() <= u16::MAX as usize, "string field too long");
        self.push_u16(s.len() as u16);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// The finished body bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Little-endian field deserializer over a control-frame body; every read
/// is bounds-checked and truncation surfaces as `InvalidData`.
#[derive(Debug)]
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    /// Read fields from `buf`, front to back.
    pub fn new(buf: &'a [u8]) -> Self {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(proto_err(format!(
                "truncated frame: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Everything not yet consumed, consuming it.
    pub fn rest(&mut self) -> &'a [u8] {
        let out = &self.buf[self.pos..];
        self.pos = self.buf.len();
        out
    }

    /// Next byte.
    pub fn read_u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Next little-endian u16.
    pub fn read_u16(&mut self) -> io::Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Next little-endian u32.
    pub fn read_u32(&mut self) -> io::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next little-endian u64.
    pub fn read_u64(&mut self) -> io::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Next little-endian f32.
    pub fn read_f32(&mut self) -> io::Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Next length-prefixed (u16) UTF-8 string.
    pub fn read_str(&mut self) -> io::Result<String> {
        let n = self.read_u16()? as usize;
        let b = self.take(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| proto_err("string field not UTF-8".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn payload_roundtrip_preserves_matrices() {
        let mut rng = Rng::new(1);
        let a = Matrix::randn(3, 7, 1.0, &mut rng);
        let b = Matrix::randn(1, 4, 1.0, &mut rng);
        let mut buf = Vec::new();
        let n = encode_payload(&mut buf, "acts", &[&a, &b]).unwrap();
        assert_eq!(n as usize, buf.len());
        assert_eq!(n, payload_wire_len("acts", &[&a, &b]));
        let f = decode(&mut buf.as_slice()).unwrap();
        assert_eq!(f.tag, "acts");
        assert_eq!(f.kind(), FrameKind::Payload);
        assert_eq!(f.wire_len(), n);
        match f.body {
            Body::Mats(ms) => {
                assert_eq!(ms.len(), 2);
                assert_eq!(ms[0], a);
                assert_eq!(ms[1], b);
            }
            Body::Control(_) => panic!("wrong kind"),
        }
    }

    #[test]
    fn control_roundtrip_and_field_streams() {
        let mut w = ByteWriter::new();
        w.push_u8(7);
        w.push_u32(123_456);
        w.push_u64(u64::MAX - 5);
        w.push_f32(-0.25);
        w.push_str("mnist");
        let body = w.finish();
        let mut buf = Vec::new();
        let n = encode_control(&mut buf, "config", &body).unwrap();
        assert_eq!(n, control_wire_len("config", &body));
        let f = decode(&mut buf.as_slice()).unwrap();
        assert_eq!(f.tag, "config");
        let got = match f.body {
            Body::Control(b) => b,
            Body::Mats(_) => panic!("wrong kind"),
        };
        let mut r = ByteReader::new(&got);
        assert_eq!(r.read_u8().unwrap(), 7);
        assert_eq!(r.read_u32().unwrap(), 123_456);
        assert_eq!(r.read_u64().unwrap(), u64::MAX - 5);
        assert_eq!(r.read_f32().unwrap(), -0.25);
        assert_eq!(r.read_str().unwrap(), "mnist");
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn empty_matrix_roundtrips() {
        let m = Matrix::zeros(0, 5);
        let mut buf = Vec::new();
        encode_payload(&mut buf, "deltas", &[&m]).unwrap();
        let f = decode(&mut buf.as_slice()).unwrap();
        match f.body {
            Body::Mats(ms) => assert_eq!(ms[0].shape(), (0, 5)),
            Body::Control(_) => panic!("wrong kind"),
        }
    }

    #[test]
    fn version_mismatch_and_truncation_rejected() {
        let mut buf = Vec::new();
        encode_control(&mut buf, "hello", &[1, 2, 3]).unwrap();
        let mut bad = buf.clone();
        bad[4] = WIRE_VERSION + 1; // version byte lives right after the prefix
        assert!(decode(&mut bad.as_slice()).is_err());
        let cut = &buf[..buf.len() - 1];
        assert!(decode(&mut &cut[..]).is_err());
    }
}

//! Batched inference serving from a checkpoint: `dad infer`.
//!
//! [`InferServer`] loads a [`Checkpoint`](crate::checkpoint::Checkpoint),
//! rebuilds the model it describes (via the same deterministic
//! [`build_task`] every training process uses), installs the checkpointed
//! parameters, and serves predictions over the zero-dependency TCP stack.
//! Requests are coalesced: concurrent in-flight requests are drained into
//! one forward pass per batch window — row batches for the MLP, token
//! batches (bucketed by sequence length) for the transformer LM — so
//! throughput scales with concurrency instead of paying one matmul per
//! request.
//!
//! The protocol is four control frames over the shared wire codec
//! ([`crate::dist::wire`]; the codec's version check covers the handshake):
//!
//! ```text
//! client -> server   infer-hello     (empty body)
//! server -> client   infer-welcome   model kind, dataset, scale,
//!                                    in_dim, out_dim, max_t
//! client -> server   infer-req       u64 req id, u8 kind,
//!                                    kind 0: u32 d,  d  f32 features
//!                                    kind 1: u32 t,  t  u32 token ids
//! server -> client   infer-res       u64 req id, u8 status,
//!                                    status 0: u32 argmax, f32 prob
//!                                    status 1: str error
//! client -> server   infer-shutdown  (empty body; drains, then stops)
//! ```
//!
//! Byte layouts are specified normatively in `rust/docs/FORMATS.md`;
//! operational usage (flags, exit behavior, the bench loop) in
//! `rust/docs/OPERATIONS.md`. `tests/infer_serving.rs` drives a live
//! server end-to-end for both model kinds.
//!
//! [`run_bench`] is the closed-loop load generator behind `dad infer
//! --bench`: N client threads issue requests back-to-back and the merged
//! latency distribution is reported as p50/p99/QPS (the `BENCH_serving.json`
//! schema EXPERIMENTS.md defines).

use std::collections::{BTreeMap, VecDeque};
use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::checkpoint::Checkpoint;
use crate::coordinator::experiments::Scale;
use crate::coordinator::trainer::{build_task, TrainTask};
use crate::dist::wire::{decode, encode_control, proto_err, Body, ByteReader, ByteWriter};
use crate::nn::model::{Batch, DistModel};
use crate::nn::{Mlp, Transformer};
use crate::tensor::{Matrix, Rng};

/// Server-side batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct InferOpts {
    /// Largest number of requests folded into one forward pass.
    pub max_batch: usize,
    /// How long the batcher waits after the first queued request before
    /// running the pass, to let concurrent requests coalesce.
    pub window: Duration,
}

impl Default for InferOpts {
    fn default() -> Self {
        InferOpts { max_batch: 64, window: Duration::from_millis(2) }
    }
}

/// What the server tells every client in the `infer-welcome` frame.
#[derive(Clone, Debug)]
pub struct ServerInfo {
    /// Model kind: `"mlp"` (row requests) or `"lm"` (token requests).
    pub model: String,
    /// Dataset key the checkpoint was trained on.
    pub dataset: String,
    /// Scale key the checkpoint was trained at.
    pub scale: String,
    /// Expected feature count per row request (0 for the LM).
    pub in_dim: usize,
    /// Classes (MLP) or vocabulary size (LM) — the score-row width.
    pub out_dim: usize,
    /// Longest accepted token sequence (0 for the MLP).
    pub max_t: usize,
}

impl ServerInfo {
    fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.push_str(&self.model);
        w.push_str(&self.dataset);
        w.push_str(&self.scale);
        w.push_u32(self.in_dim as u32);
        w.push_u32(self.out_dim as u32);
        w.push_u32(self.max_t as u32);
        w.finish()
    }

    fn decode(body: &[u8]) -> io::Result<ServerInfo> {
        let mut r = ByteReader::new(body);
        let info = ServerInfo {
            model: r.read_str()?,
            dataset: r.read_str()?,
            scale: r.read_str()?,
            in_dim: r.read_u32()? as usize,
            out_dim: r.read_u32()? as usize,
            max_t: r.read_u32()? as usize,
        };
        if r.remaining() != 0 {
            return Err(proto_err(format!(
                "infer-welcome frame has {} trailing bytes (version skew?)",
                r.remaining()
            )));
        }
        Ok(info)
    }
}

/// The model a server answers with. The GRU classifier is deliberately
/// absent: its per-timestep matrix input has no compact request encoding,
/// so `arabic` checkpoints are rejected at load time with a named error.
enum ServedModel {
    /// MLP over dense feature rows (`mnist` checkpoints).
    Dense(Mlp),
    /// Decoder-only transformer over token windows (`lm` checkpoints).
    Tokens(Transformer),
}

/// A parsed, validated request waiting for the batcher.
enum ReqInput {
    /// One dense feature row (already length-checked).
    Row(Vec<f32>),
    /// One token window (already range-checked).
    Ids(Vec<u32>),
}

struct Pending {
    req_id: u64,
    input: ReqInput,
    out: Arc<Mutex<TcpStream>>,
}

/// State shared between the accept loop, per-connection readers and the
/// batcher.
struct Shared {
    queue: Mutex<VecDeque<Pending>>,
    ready: Condvar,
    stop: AtomicBool,
    served: AtomicU64,
}

impl Shared {
    fn new() -> Shared {
        Shared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
            stop: AtomicBool::new(false),
            served: AtomicU64::new(0),
        }
    }
}

/// A batched inference server bound to a TCP address, ready to
/// [`run`](InferServer::run).
pub struct InferServer {
    listener: TcpListener,
    model: ServedModel,
    info: ServerInfo,
    opts: InferOpts,
}

fn invalid(msg: String) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn check_fit(params: &[Matrix], shapes: &[(usize, usize)]) -> io::Result<()> {
    if params.len() != shapes.len()
        || params.iter().zip(shapes).any(|(p, &(r, c))| p.rows() != r || p.cols() != c)
    {
        return Err(invalid(format!(
            "checkpoint does not fit the model its meta describes: expected {} matrices \
             shaped {:?}",
            shapes.len(),
            shapes
        )));
    }
    Ok(())
}

impl InferServer {
    /// Rebuild the checkpointed model (deterministically, from the
    /// dataset/scale/seed recorded in its meta), install the parameters,
    /// and bind `addr`. Fails with named errors on unservable checkpoints
    /// (the `arabic` GRU) or parameters that do not fit the architecture.
    pub fn bind(addr: &str, ck: Checkpoint, opts: InferOpts) -> io::Result<InferServer> {
        let scale = Scale::parse(&ck.meta.scale).ok_or_else(|| {
            invalid(format!("checkpoint records unknown scale {:?}", ck.meta.scale))
        })?;
        let task = build_task(&ck.meta.dataset, scale, ck.meta.n_sites as usize, ck.meta.seed)
            .map_err(invalid)?;
        let (model, in_dim, out_dim, max_t, kind) = match task {
            TrainTask::Dense { mut model, .. } => {
                check_fit(&ck.params, &model.param_shapes())?;
                model.set_params(&ck.params);
                let in_dim = model.dims[0];
                let out_dim = *model.dims.last().expect("mlp has layers");
                (ServedModel::Dense(model), in_dim, out_dim, 0, "mlp")
            }
            TrainTask::Tokens { mut model, .. } => {
                check_fit(&ck.params, &model.param_shapes())?;
                model.set_params(&ck.params);
                let (vocab, max_t) = (model.cfg.vocab, model.cfg.max_t);
                (ServedModel::Tokens(model), 0, vocab, max_t, "lm")
            }
            TrainTask::Seq { .. } => {
                return Err(io::Error::new(
                    io::ErrorKind::Unsupported,
                    "the arabic GRU classifier is not servable: its per-timestep matrix \
                     input has no inference request encoding (serve mnist or lm checkpoints)",
                ));
            }
        };
        let info = ServerInfo {
            model: kind.to_string(),
            dataset: ck.meta.dataset.clone(),
            scale: ck.meta.scale.clone(),
            in_dim,
            out_dim,
            max_t,
        };
        let listener = TcpListener::bind(addr)
            .map_err(|e| io::Error::new(e.kind(), format!("bind {addr}: {e}")))?;
        Ok(InferServer { listener, model, info, opts })
    }

    /// The bound address (useful with `:0` ephemeral ports in tests).
    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// What this server will announce in `infer-welcome`.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    /// Serve until a client sends `infer-shutdown`: accept connections,
    /// coalesce their requests into batched forward passes, answer each
    /// request on its own connection. Returns the number of requests
    /// served. Never panics on malformed input — bad requests get a
    /// status-1 `infer-res` (or, for undecodable frames, a dropped
    /// connection with a note on stderr).
    pub fn run(self) -> io::Result<u64> {
        let InferServer { listener, model, info, opts } = self;
        let self_addr = listener.local_addr()?;
        let shared = Arc::new(Shared::new());
        let batcher = {
            let shared = Arc::clone(&shared);
            thread::spawn(move || batch_loop(model, &shared, opts))
        };
        for conn in listener.incoming() {
            if shared.stop.load(Ordering::SeqCst) {
                break;
            }
            let stream = match conn {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("[infer] accept failed: {e}");
                    continue;
                }
            };
            let shared = Arc::clone(&shared);
            let info = info.clone();
            thread::spawn(move || connection_loop(stream, &info, &shared, self_addr));
        }
        // Wake the batcher so it drains the queue and observes the stop
        // flag even if no request arrives after shutdown.
        shared.ready.notify_all();
        batcher.join().map_err(|_| {
            io::Error::new(io::ErrorKind::Other, "inference batcher thread panicked")
        })?;
        Ok(shared.served.load(Ordering::SeqCst))
    }
}

/// Serialize + send one `infer-res` under the connection's write lock (so
/// batched responses to the same client never interleave mid-frame).
fn send_res(out: &Mutex<TcpStream>, req_id: u64, result: Result<(u32, f32), &str>) {
    let mut w = ByteWriter::new();
    w.push_u64(req_id);
    match result {
        Ok((argmax, prob)) => {
            w.push_u8(0);
            w.push_u32(argmax);
            w.push_f32(prob);
        }
        Err(msg) => {
            w.push_u8(1);
            w.push_str(msg);
        }
    }
    let mut frame = Vec::new();
    encode_control(&mut frame, "infer-res", &w.finish()).expect("vec write");
    let stream = out.lock().expect("infer writer lock poisoned");
    if let Err(e) = io::Write::write_all(&mut &*stream, &frame) {
        eprintln!("[infer] dropping response {req_id}: {e}");
    }
}

/// Parse + validate one `infer-req` body against the served model's
/// expectations. `Err` carries the client-facing message.
fn parse_req(body: &[u8], info: &ServerInfo) -> Result<(u64, ReqInput), (u64, String)> {
    let mut r = ByteReader::new(body);
    let req_id = r.read_u64().map_err(|e| (0, e.to_string()))?;
    let fail = |msg: String| (req_id, msg);
    let kind = r.read_u8().map_err(|e| fail(e.to_string()))?;
    match kind {
        0 => {
            if info.model != "mlp" {
                return Err(fail(format!(
                    "this server serves a {} model; send token requests (kind 1)",
                    info.model
                )));
            }
            let d = r.read_u32().map_err(|e| fail(e.to_string()))? as usize;
            if d != info.in_dim {
                return Err(fail(format!(
                    "row request has {d} features, the model takes {}",
                    info.in_dim
                )));
            }
            let mut row = Vec::with_capacity(d);
            for _ in 0..d {
                row.push(r.read_f32().map_err(|e| fail(e.to_string()))?);
            }
            if r.remaining() != 0 {
                return Err(fail(format!("{} trailing bytes in infer-req", r.remaining())));
            }
            Ok((req_id, ReqInput::Row(row)))
        }
        1 => {
            if info.model != "lm" {
                return Err(fail(format!(
                    "this server serves a {} model; send row requests (kind 0)",
                    info.model
                )));
            }
            let t = r.read_u32().map_err(|e| fail(e.to_string()))? as usize;
            if t == 0 || t > info.max_t {
                return Err(fail(format!(
                    "sequence length {t} outside the model's 1..={} window",
                    info.max_t
                )));
            }
            let mut ids = Vec::with_capacity(t);
            for _ in 0..t {
                let id = r.read_u32().map_err(|e| fail(e.to_string()))?;
                if id as usize >= info.out_dim {
                    return Err(fail(format!(
                        "token id {id} outside the {} entry vocabulary",
                        info.out_dim
                    )));
                }
                ids.push(id);
            }
            if r.remaining() != 0 {
                return Err(fail(format!("{} trailing bytes in infer-req", r.remaining())));
            }
            Ok((req_id, ReqInput::Ids(ids)))
        }
        k => Err(fail(format!("unknown infer-req kind {k}"))),
    }
}

/// One connection's reader: answer the hello, enqueue valid requests,
/// reject invalid ones inline, and translate `infer-shutdown` into the
/// server-wide stop (plus a self-dial that unblocks the accept loop).
fn connection_loop(stream: TcpStream, info: &ServerInfo, shared: &Shared, self_addr: SocketAddr) {
    let out = Arc::new(Mutex::new(match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("[infer] cannot clone connection for writes: {e}");
            return;
        }
    }));
    let mut rd = &stream;
    loop {
        let frame = match decode(&mut rd) {
            Ok(f) => f,
            Err(e) => {
                // EOF after the last response is the normal client
                // hang-up; anything else is worth a note.
                if e.kind() != io::ErrorKind::UnexpectedEof {
                    eprintln!("[infer] dropping connection: {e}");
                }
                return;
            }
        };
        let body = match frame.body {
            Body::Control(b) => b,
            _ => {
                eprintln!("[infer] dropping connection: payload frame {:?}", frame.tag);
                return;
            }
        };
        match frame.tag.as_str() {
            "infer-hello" => {
                let mut buf = Vec::new();
                encode_control(&mut buf, "infer-welcome", &info.encode()).expect("vec write");
                let w = out.lock().expect("infer writer lock poisoned");
                if io::Write::write_all(&mut &*w, &buf).is_err() {
                    return;
                }
            }
            "infer-req" => match parse_req(&body, info) {
                Ok((req_id, input)) => {
                    let mut q = shared.queue.lock().expect("infer queue lock poisoned");
                    q.push_back(Pending { req_id, input, out: Arc::clone(&out) });
                    drop(q);
                    shared.ready.notify_all();
                }
                Err((req_id, msg)) => send_res(&out, req_id, Err(&msg)),
            },
            "infer-shutdown" => {
                shared.stop.store(true, Ordering::SeqCst);
                shared.ready.notify_all();
                // Unblock the accept loop so `run` can return.
                let _ = TcpStream::connect(self_addr);
                return;
            }
            other => {
                eprintln!("[infer] dropping connection: unexpected frame {other:?}");
                return;
            }
        }
    }
}

/// The batcher: wait for work, let the window fill, drain up to
/// `max_batch` requests, run one forward pass per shape group, answer.
/// Exits once the stop flag is set *and* the queue is drained — queued
/// requests are answered even when shutdown races them.
fn batch_loop(model: ServedModel, shared: &Shared, opts: InferOpts) {
    loop {
        let mut q = shared.queue.lock().expect("infer queue lock poisoned");
        while q.is_empty() && !shared.stop.load(Ordering::SeqCst) {
            q = shared
                .ready
                .wait_timeout(q, Duration::from_millis(50))
                .expect("infer queue lock poisoned")
                .0;
        }
        if q.is_empty() {
            return; // stopped and drained
        }
        drop(q);
        // Coalescing window: let concurrent clients land in this batch.
        thread::sleep(opts.window);
        let batch: Vec<Pending> = {
            let mut q = shared.queue.lock().expect("infer queue lock poisoned");
            crate::obs::metrics::BATCHER_QUEUE_DEPTH.set(q.len() as u64);
            let n = q.len().min(opts.max_batch);
            q.drain(..n).collect()
        };
        run_batch(&model, batch, shared);
        crate::obs::metrics::STEP.set(shared.served.load(Ordering::SeqCst) as u64);
    }
}

/// Row-major argmax + probability of one score row.
fn row_argmax(scores: &Matrix, row: usize) -> (u32, f32) {
    let cols = scores.cols();
    let data = &scores.data()[row * cols..(row + 1) * cols];
    let mut best = 0usize;
    for (i, &v) in data.iter().enumerate() {
        if v > data[best] {
            best = i;
        }
    }
    (best as u32, data[best])
}

/// One drained batch -> grouped forward passes -> responses.
fn run_batch(model: &ServedModel, batch: Vec<Pending>, shared: &Shared) {
    match model {
        ServedModel::Dense(mlp) => {
            let (d, c) = (mlp.dims[0], *mlp.dims.last().expect("mlp has layers"));
            let mut flat = Vec::with_capacity(batch.len() * d);
            for p in &batch {
                match &p.input {
                    ReqInput::Row(row) => flat.extend_from_slice(row),
                    ReqInput::Ids(_) => unreachable!("parse_req rejects tokens for mlp"),
                }
            }
            let x = Matrix::from_vec(batch.len(), d, flat);
            let scores =
                mlp.predict(&Batch::Dense { x, y: Matrix::zeros(batch.len(), c) });
            for (i, p) in batch.iter().enumerate() {
                let (argmax, prob) = row_argmax(&scores, i);
                send_res(&p.out, p.req_id, Ok((argmax, prob)));
                shared.served.fetch_add(1, Ordering::SeqCst);
            }
        }
        ServedModel::Tokens(tf) => {
            // Bucket by sequence length: one forward pass per distinct T,
            // in deterministic (ascending) order.
            let mut groups: BTreeMap<usize, Vec<&Pending>> = BTreeMap::new();
            for p in &batch {
                match &p.input {
                    ReqInput::Ids(ids) => groups.entry(ids.len()).or_default().push(p),
                    ReqInput::Row(_) => unreachable!("parse_req rejects rows for lm"),
                }
            }
            for (t, group) in groups {
                let b = group.len();
                let mut ids = Vec::with_capacity(b * t);
                for p in &group {
                    match &p.input {
                        ReqInput::Ids(w) => ids.extend_from_slice(w),
                        ReqInput::Row(_) => unreachable!(),
                    }
                }
                let scores =
                    tf.predict(&Batch::Tokens { b, t, ids, targets: vec![0; b * t] });
                for (i, p) in group.iter().enumerate() {
                    // The next-token distribution lives on the window's
                    // last position: row i*t + (t-1) of the (b*t, vocab)
                    // score matrix.
                    let (argmax, prob) = row_argmax(&scores, i * t + (t - 1));
                    send_res(&p.out, p.req_id, Ok((argmax, prob)));
                    shared.served.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Client + load generator
// ---------------------------------------------------------------------------

/// A synchronous inference client: one connection, one request in flight.
pub struct InferClient {
    stream: TcpStream,
    info: ServerInfo,
    next_id: u64,
}

impl InferClient {
    /// Dial the server and complete the hello/welcome handshake.
    pub fn connect(addr: &str) -> io::Result<InferClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| io::Error::new(e.kind(), format!("connect {addr}: {e}")))?;
        encode_control(&mut &stream, "infer-hello", &[])?;
        let frame = decode(&mut &stream)?;
        if frame.tag != "infer-welcome" {
            return Err(proto_err(format!("expected infer-welcome, got {:?}", frame.tag)));
        }
        let body = match frame.body {
            Body::Control(b) => b,
            _ => return Err(proto_err("infer-welcome must be a control frame".into())),
        };
        Ok(InferClient { stream, info: ServerInfo::decode(&body)?, next_id: 1 })
    }

    /// What the server announced about itself.
    pub fn info(&self) -> &ServerInfo {
        &self.info
    }

    /// Classify one dense feature row; returns `(class, probability)`.
    pub fn classify(&mut self, row: &[f32]) -> io::Result<(usize, f32)> {
        let mut w = ByteWriter::new();
        let id = self.next_id;
        w.push_u64(id);
        w.push_u8(0);
        w.push_u32(row.len() as u32);
        for &v in row {
            w.push_f32(v);
        }
        self.roundtrip(id, &w.finish())
    }

    /// Predict the next token after `ids`; returns `(token, probability)`.
    pub fn next_token(&mut self, ids: &[u32]) -> io::Result<(usize, f32)> {
        let mut w = ByteWriter::new();
        let id = self.next_id;
        w.push_u64(id);
        w.push_u8(1);
        w.push_u32(ids.len() as u32);
        for &t in ids {
            w.push_u32(t);
        }
        self.roundtrip(id, &w.finish())
    }

    fn roundtrip(&mut self, id: u64, body: &[u8]) -> io::Result<(usize, f32)> {
        self.next_id += 1;
        encode_control(&mut &self.stream, "infer-req", body)?;
        let frame = decode(&mut &self.stream)?;
        if frame.tag != "infer-res" {
            return Err(proto_err(format!("expected infer-res, got {:?}", frame.tag)));
        }
        let res = match frame.body {
            Body::Control(b) => b,
            _ => return Err(proto_err("infer-res must be a control frame".into())),
        };
        let mut r = ByteReader::new(&res);
        let got_id = r.read_u64()?;
        if got_id != id {
            return Err(proto_err(format!("response for request {got_id}, expected {id}")));
        }
        match r.read_u8()? {
            0 => Ok((r.read_u32()? as usize, r.read_f32()?)),
            1 => Err(proto_err(format!("server rejected request: {}", r.read_str()?))),
            s => Err(proto_err(format!("unknown infer-res status {s}"))),
        }
    }

    /// Ask the server to drain its queue and stop accepting.
    pub fn shutdown(self) -> io::Result<()> {
        encode_control(&mut &self.stream, "infer-shutdown", &[])?;
        Ok(())
    }
}

/// One `dad infer --bench` run's results — the `BENCH_serving.json` schema
/// (EXPERIMENTS.md §serving).
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// Model kind the server announced ("mlp" | "lm").
    pub model: String,
    /// Total requests completed.
    pub requests: usize,
    /// Closed-loop client threads.
    pub concurrency: usize,
    /// Wall-clock seconds for the whole run.
    pub wall_s: f64,
    /// Median request latency, milliseconds.
    pub p50_ms: f64,
    /// 99th-percentile request latency, milliseconds.
    pub p99_ms: f64,
    /// Completed requests per wall-clock second.
    pub qps: f64,
}

impl BenchReport {
    /// Hand-rolled JSON (no serializer dependency), one flat object.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"model\":\"{}\",\"requests\":{},\"concurrency\":{},\"wall_s\":{:.6},\
             \"p50_ms\":{:.3},\"p99_ms\":{:.3},\"qps\":{:.1}}}",
            self.model, self.requests, self.concurrency, self.wall_s, self.p50_ms,
            self.p99_ms, self.qps
        )
    }
}

/// Sorted-latency percentile (nearest-rank on the merged distribution).
fn percentile_ms(sorted: &[f64], pct: usize) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = (sorted.len() * pct / 100).min(sorted.len() - 1);
    sorted[idx]
}

/// Closed-loop load generator: `concurrency` threads each dial `addr`,
/// issue deterministic (seeded) requests back-to-back until the shared
/// total reaches `requests`, and record per-request wall latency. Inputs
/// are synthesized to match the served model — standard-normal rows for
/// the MLP, uniform token windows for the LM.
pub fn run_bench(
    addr: &str,
    requests: usize,
    concurrency: usize,
    seed: u64,
) -> io::Result<BenchReport> {
    let concurrency = concurrency.max(1);
    let requests = requests.max(1);
    let model = InferClient::connect(addr)?.info().model.clone();
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(concurrency);
    for worker in 0..concurrency {
        // Spread the remainder so thread totals sum exactly to `requests`.
        let n = requests / concurrency + usize::from(worker < requests % concurrency);
        let addr = addr.to_string();
        handles.push(thread::spawn(move || -> io::Result<Vec<f64>> {
            let mut client = InferClient::connect(&addr)?;
            let info = client.info().clone();
            let mut rng = Rng::new(seed.wrapping_add(worker as u64));
            let mut lats = Vec::with_capacity(n);
            for _ in 0..n {
                let start = Instant::now();
                if info.model == "lm" {
                    let t = info.max_t.min(8).max(1);
                    let ids: Vec<u32> =
                        (0..t).map(|_| rng.next_u32() % info.out_dim as u32).collect();
                    client.next_token(&ids)?;
                } else {
                    let row: Vec<f32> = (0..info.in_dim).map(|_| rng.normal()).collect();
                    client.classify(&row)?;
                }
                lats.push(start.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lats)
        }));
    }
    let mut lats: Vec<f64> = Vec::with_capacity(requests);
    for h in handles {
        let worker_lats = h
            .join()
            .map_err(|_| io::Error::new(io::ErrorKind::Other, "bench worker panicked"))??;
        lats.extend(worker_lats);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
    Ok(BenchReport {
        model,
        requests: lats.len(),
        concurrency,
        wall_s,
        p50_ms: percentile_ms(&lats, 50),
        p99_ms: percentile_ms(&lats, 99),
        qps: lats.len() as f64 / wall_s.max(1e-9),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_info_roundtrips() {
        let info = ServerInfo {
            model: "lm".into(),
            dataset: "lm".into(),
            scale: "quick".into(),
            in_dim: 0,
            out_dim: 50,
            max_t: 16,
        };
        let back = ServerInfo::decode(&info.encode()).unwrap();
        assert_eq!(back.model, info.model);
        assert_eq!(back.out_dim, 50);
        assert_eq!(back.max_t, 16);
        assert!(ServerInfo::decode(&info.encode()[..5]).is_err());
    }

    #[test]
    fn parse_req_validates_against_model() {
        let mlp = ServerInfo {
            model: "mlp".into(),
            dataset: "mnist".into(),
            scale: "quick".into(),
            in_dim: 3,
            out_dim: 10,
            max_t: 0,
        };
        let mut w = ByteWriter::new();
        w.push_u64(7);
        w.push_u8(0);
        w.push_u32(3);
        for v in [0.1f32, 0.2, 0.3] {
            w.push_f32(v);
        }
        let (id, input) = parse_req(&w.finish(), &mlp).unwrap();
        assert_eq!(id, 7);
        assert!(matches!(input, ReqInput::Row(ref r) if r.len() == 3));

        // Wrong feature count -> named rejection carrying the request id.
        let mut w = ByteWriter::new();
        w.push_u64(8);
        w.push_u8(0);
        w.push_u32(2);
        w.push_f32(0.0);
        w.push_f32(0.0);
        let (id, msg) = parse_req(&w.finish(), &mlp).unwrap_err();
        assert_eq!(id, 8);
        assert!(msg.contains("features"), "{msg}");

        // Token request against an MLP server -> kind mismatch.
        let mut w = ByteWriter::new();
        w.push_u64(9);
        w.push_u8(1);
        w.push_u32(1);
        w.push_u32(0);
        let (_, msg) = parse_req(&w.finish(), &mlp).unwrap_err();
        assert!(msg.contains("kind 0"), "{msg}");

        let lm = ServerInfo { model: "lm".into(), in_dim: 0, out_dim: 10, max_t: 4, ..mlp };
        // Out-of-vocab token id.
        let mut w = ByteWriter::new();
        w.push_u64(10);
        w.push_u8(1);
        w.push_u32(2);
        w.push_u32(3);
        w.push_u32(99);
        let (_, msg) = parse_req(&w.finish(), &lm).unwrap_err();
        assert!(msg.contains("vocabulary"), "{msg}");
        // Over-long window.
        let mut w = ByteWriter::new();
        w.push_u64(11);
        w.push_u8(1);
        w.push_u32(9);
        for _ in 0..9 {
            w.push_u32(0);
        }
        let (_, msg) = parse_req(&w.finish(), &lm).unwrap_err();
        assert!(msg.contains("window"), "{msg}");
    }

    #[test]
    fn percentiles_and_json_shape() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_ms(&lats, 50), 51.0);
        assert_eq!(percentile_ms(&lats, 99), 100.0);
        let report = BenchReport {
            model: "mlp".into(),
            requests: 100,
            concurrency: 4,
            wall_s: 0.5,
            p50_ms: 1.25,
            p99_ms: 4.5,
            qps: 200.0,
        };
        let json = report.to_json();
        for key in ["\"model\"", "\"requests\"", "\"p50_ms\"", "\"p99_ms\"", "\"qps\""] {
            assert!(json.contains(key), "{json}");
        }
    }
}

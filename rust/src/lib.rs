//! # dad — distributed auto-differentiation
//!
//! A reproduction of Baker, Calhoun, Pearlmutter & Plis, *"Efficient
//! Distributed Auto-Differentiation"* (arXiv title: *"Peering Beyond the
//! Gradient Veil with Distributed Auto Differentiation"*, 2021): instead of
//! shipping gradients between training sites, ship the reverse-AD
//! intermediates (activations A and deltas Δ) whose outer product *is* the
//! gradient — exactly (dAD, edAD) or in adaptively low-rank form via
//! structured power iterations (rank-dAD).
//!
//! Architecture (see DESIGN.md): a Rust coordinator (this crate) owns the
//! training loop, the simulated multi-site cluster, and all the algorithms
//! (pooled / dSGD / dAD / dAD-p2p / edAD / rank-dAD / PowerSGD); JAX+Pallas exists
//! only at build time, AOT-lowering the model's stats computation and the
//! power-iteration kernel to HLO-text artifacts executed through PJRT
//! (`runtime`). A from-scratch tensor/NN stack (`tensor`, `nn`) provides the
//! native backend and all substrates.
//!
//! Communication is a real subsystem, not a simulation detail: `dist::wire`
//! defines the frame codec, `dist::transport` the pluggable backends
//! (in-process loopback and multi-process TCP), and `coordinator::remote`
//! the `dad serve` / `dad join` drivers — see ARCHITECTURE.md for the
//! data-flow walkthrough.

#![warn(missing_docs)]

pub mod algos;
pub mod bench;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod infer;
pub mod lowrank;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod runtime;
pub mod scenario;
pub mod tensor;

/// The normative wire-protocol and checkpoint-container specification,
/// embedded verbatim from `rust/docs/FORMATS.md` so the `cargo doc`
/// CI job (which denies warnings) fails on broken intra-doc links in the
/// spec, and so the spec ships inside the rendered rustdoc.
pub mod specs {
    #![doc = include_str!("../docs/FORMATS.md")]
}

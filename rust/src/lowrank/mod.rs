//! Low-rank machinery: the paper's structured power iterations on AD
//! factors (rank-dAD) and the PowerSGD baseline it is evaluated against.

pub mod power_iter;
pub mod powersgd;

pub use power_iter::{deterministic_init, power_iter_step, rankdad_factors, Factors};
pub use powersgd::{orthonormalize_cols, PowerSgdState};

//! Structured power iterations on AD factors (paper section 3.4.1) —
//! native-engine port of python/compile/kernels/power_iter.py (the Pallas
//! kernel); both match ref.rankdad_factors_ref.
//!
//! The gradient M = AᵀΔ (h_in x h_out) is never materialized. One step of
//! power iteration on MᵀM costs O(h*N) through the factors:
//!     v = Δ g, w = A(Aᵀ v) (= Cv), g' = Δᵀ w,
//! deflated by previously-extracted singular pairs and re-orthogonalized.
//! The theta early-stop yields the *effective rank* — the paper's adaptive
//! bandwidth mechanism and training-dynamics probe.

use crate::tensor::{matvec_into, matvec_t_into, Matrix};

/// Low-rank factorization of a gradient outer product: M ≈ q_tᵀ g_t, with
/// q_t rows = σ_j q_j (σ absorbed, paper's "absorbing singular values") and
/// g_t rows = unit right singular vectors. Rows past eff_rank are zero.
#[derive(Clone, Debug)]
pub struct Factors {
    /// (max_rank, h_in); row j = sigma_j * q_j.
    pub q_t: Matrix,
    /// (max_rank, h_out); row j = g_j (unit).
    pub g_t: Matrix,
    /// Number of non-noise components extracted (<= max_rank, <= N).
    pub eff_rank: usize,
}

impl Factors {
    /// Reconstruct the (scaled) gradient approximation: scale * q_tᵀ g_t.
    pub fn reconstruct(&self, scale: f32) -> Matrix {
        let mut m = crate::tensor::matmul_tn(&self.q_t, &self.g_t);
        m.scale_inplace(scale);
        m
    }

    /// Bytes for shipping only the first eff_rank rows of both factors —
    /// the adaptive payload of rank-dAD.
    pub fn wire_bytes(&self) -> u64 {
        ((self.q_t.cols() + self.g_t.cols()) * self.eff_rank * 4) as u64
    }

    /// Keep only the first eff_rank rows (what actually travels).
    pub fn truncated(&self) -> (Matrix, Matrix) {
        (self.q_t.slice_rows(0, self.eff_rank), self.g_t.slice_rows(0, self.eff_rank))
    }
}

/// Deterministic pseudo-random unit start vector; bit-compatible with
/// ref.deterministic_init (sin-hash, PRNG-free).
pub fn deterministic_init(h: usize) -> Vec<f32> {
    let mut v: Vec<f32> = (0..h)
        .map(|i| {
            let x = (i as f32 * 12.9898 + 78.233).sin() * 43758.5453;
            x - x.floor() - 0.5
        })
        .collect();
    let norm = v.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32;
    for x in &mut v {
        *x /= norm;
    }
    v
}

/// Reused step scratch: the four matvecs of one structured iteration run as
/// two in-place passes over A and two over Δ, writing into these buffers —
/// zero allocation per iteration once the scratch exists (the seed
/// allocated four fresh vectors per step, ~4·n_iters·rank allocations per
/// factorization).
struct PowerScratch {
    /// (N)      v = Δ g. Distinct from `w`: the σ computation needs both
    /// at once (σ² = vᵀ w).
    v: Vec<f32>,
    /// (h_in)   t = Aᵀ v.
    t: Vec<f32>,
    /// (N)      w = A t = C v.
    w: Vec<f32>,
    /// (h_out)  the unnormalized next iterate.
    g_next: Vec<f32>,
}

impl PowerScratch {
    fn for_factors(a: &Matrix, d: &Matrix) -> Self {
        PowerScratch {
            v: vec![0.0; a.rows()],
            t: vec![0.0; a.cols()],
            w: vec![0.0; a.rows()],
            g_next: vec![0.0; d.cols()],
        }
    }
}

/// One deflated structured power-iteration step (unnormalized) into
/// `s.g_next`: g' = Δᵀ(A(Aᵀ(Δ g))) − G_jᵀ(σ² ⊙ (G_j g)), then
/// re-orthogonalized against the found vectors.
fn power_iter_step_into(
    a: &Matrix,
    d: &Matrix,
    g: &[f32],
    found: &[(f32, Vec<f32>)],
    s: &mut PowerScratch,
) {
    // Two passes over Δ (rows stream once each) ...
    matvec_into(d, g, &mut s.v); // (N)      v = Δ g
    // ... two passes over A ...
    matvec_t_into(a, &s.v, &mut s.t); // (h_in)  t = Aᵀ v
    matvec_into(a, &s.t, &mut s.w); // (N)      w = A t = C v
    // ... and the closing Δ pass.
    matvec_t_into(d, &s.w, &mut s.g_next); // (h_out)
    // Deflation: subtract σ_j² g_j (g_jᵀ g).
    for (sigma, gj) in found {
        let coeff = sigma * sigma * crate::tensor::dot(gj, g);
        for (gn, &gv) in s.g_next.iter_mut().zip(gj) {
            *gn -= coeff * gv;
        }
    }
    // Re-orthogonalization (numerical): keep the iterate in the orthogonal
    // complement of the found vectors despite f32 cancellation. Twice —
    // "twice is enough" (Kahan/Parlett): a single pass leaves an O(eps)
    // relative residual which the sigma_0^2 amplification of the next step
    // would resurrect into a spurious duplicate dominant component.
    for _ in 0..2 {
        for (_, gj) in found {
            let proj = crate::tensor::dot(gj, &s.g_next);
            for (gn, &gv) in s.g_next.iter_mut().zip(gj) {
                *gn -= proj * gv;
            }
        }
    }
}

/// Allocating wrapper around `power_iter_step_into` (public API and
/// cross-checks; the factorization loop below reuses one scratch instead).
pub fn power_iter_step(a: &Matrix, d: &Matrix, g: &[f32], found: &[(f32, Vec<f32>)]) -> Vec<f32> {
    let mut s = PowerScratch::for_factors(a, d);
    power_iter_step_into(a, d, g, found, &mut s);
    s.g_next
}

fn norm(v: &[f32]) -> f32 {
    v.iter().map(|&x| x as f64 * x as f64).sum::<f64>().sqrt() as f32
}

/// Full structured-power-iteration factorization (Algorithm of §3.4.1).
///
/// a: (N, h_in), d: (N, h_out). Returns factors with the theta-stopped
/// effective rank. `n_iters` is the paper's fixed per-vector iteration
/// budget (10 in all experiments); theta = 1e-3.
pub fn rankdad_factors(a: &Matrix, d: &Matrix, max_rank: usize, n_iters: usize, theta: f32) -> Factors {
    let _s = crate::obs::trace::phase_span("power-iter", crate::obs::trace::Phase::Compress);
    let h_in = a.cols();
    let h_out = d.cols();
    let mut q_t = Matrix::zeros(max_rank, h_in);
    let mut g_t = Matrix::zeros(max_rank, h_out);
    let mut found: Vec<(f32, Vec<f32>)> = Vec::new();
    let g0 = deterministic_init(h_out);
    let sigma0 = |found: &Vec<(f32, Vec<f32>)>| found.first().map(|f| f.0).unwrap_or(0.0);
    // The true rank of M = AᵀΔ is bounded by every dimension in sight (the
    // paper's "limited from above by the batch size"); never iterate past it.
    let hard_cap = max_rank.min(a.rows()).min(h_in).min(h_out);
    // f32 noise floor: deflation + re-orthogonalization cannot resolve
    // residual spectra below ~sqrt(eps)*sigma_0; clamp user thetas to it.
    let theta_stop = theta.max(3e-4);

    let mut scratch = PowerScratch::for_factors(a, d);
    let mut g = vec![0.0f32; h_out];

    for j in 0..hard_cap {
        g.copy_from_slice(&g0);
        let mut degenerate = false;
        let mut last_nrm = 0.0f32;
        for _ in 0..n_iters {
            power_iter_step_into(a, d, &g, &found, &mut scratch);
            let nrm = norm(&scratch.g_next);
            last_nrm = nrm;
            if nrm < 1e-30 {
                degenerate = true;
                break;
            }
            // Normalize into `g` while measuring the iterate gap — no
            // temporary unit vector.
            let inv = 1.0 / nrm;
            let g_norm = norm(&g);
            let mut gap_sq = 0.0f32;
            for (gv, &gn) in g.iter_mut().zip(&scratch.g_next) {
                let unit = gn * inv;
                gap_sq += (*gv - unit) * (*gv - unit);
                *gv = unit;
            }
            let gap = gap_sq.sqrt() / (g_norm + 1e-30);
            if gap < theta {
                break;
            }
        }
        // ||deflated_step(unit g)|| ≈ residual σ²: stop when the remaining
        // spectrum collapses relative to σ_0 (paper's theta-stop).
        let res_sigma = last_nrm.max(0.0).sqrt();
        if degenerate || res_sigma < theta_stop * 1.0f32.max(sigma0(&found)) {
            break;
        }
        // σ² = vᵀ C v through the factors, reusing the step scratch:
        // v = Δ g, t = Aᵀ v, w = A t.
        matvec_into(d, &g, &mut scratch.v);
        matvec_t_into(a, &scratch.v, &mut scratch.t);
        matvec_into(a, &scratch.t, &mut scratch.w);
        let sigma = crate::tensor::dot(&scratch.v, &scratch.w).max(0.0).sqrt();
        if sigma < theta_stop * 1.0f32.max(sigma0(&found)) {
            break;
        }
        // q = Aᵀ v / σ; stored row = σ·q = t (σ absorbed back, the paper's
        // "absorbing singular values").
        q_t.row_mut(j).copy_from_slice(&scratch.t);
        g_t.row_mut(j).copy_from_slice(&g);
        found.push((sigma, g.clone()));
        if found.len() == max_rank {
            break;
        }
    }
    Factors { q_t, g_t, eff_rank: found.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_tn, matvec, matvec_t, Matrix, Rng};

    fn rand_pair(rng: &mut Rng, n: usize, h_in: usize, h_out: usize) -> (Matrix, Matrix) {
        (Matrix::randn(n, h_in, 1.0, rng), Matrix::randn(n, h_out, 1.0, rng))
    }

    /// SVD oracle via two-sided power iteration on the materialized gradient
    /// (only in tests; the whole point of the structured version is never
    /// building M).
    fn dominant_sigma(m: &Matrix, iters: usize) -> f32 {
        let mut g = deterministic_init(m.cols());
        for _ in 0..iters {
            let u = matvec(m, &g);
            let g2 = matvec_t(m, &u);
            let n = norm(&g2);
            g = g2.iter().map(|&x| x / n).collect();
        }
        norm(&matvec(m, &g))
    }

    #[test]
    fn dominant_component_matches_materialized_power_iteration() {
        let mut rng = Rng::new(1);
        let (a, d) = rand_pair(&mut rng, 16, 80, 60);
        let m = matmul_tn(&a, &d);
        let f = rankdad_factors(&a, &d, 4, 60, 1e-3);
        let sigma0 = norm(f.q_t.row(0));
        let want = dominant_sigma(&m, 100);
        assert!(
            (sigma0 - want).abs() / want < 2e-2,
            "sigma0={sigma0} want={want}"
        );
    }

    #[test]
    fn exact_low_rank_is_recovered() {
        // A, D share a rank-3 latent => M has true rank 3; reconstruction
        // must be near-exact and eff_rank must stop at ~3, not max_rank.
        let mut rng = Rng::new(2);
        let basis = Matrix::randn(3, 24, 1.0, &mut rng);
        // matmul_tn(basis, X): (24, h) with rows living in a 3-dim latent.
        let a = matmul_tn(&basis, &Matrix::randn(3, 96, 1.0, &mut rng));
        let d = matmul_tn(&basis, &Matrix::randn(3, 72, 1.0, &mut rng));
        assert_eq!(a.shape(), (24, 96));
        assert_eq!(d.shape(), (24, 72));
        let m = matmul_tn(&a, &d);
        let f = rankdad_factors(&a, &d, 10, 60, 1e-3);
        assert!(f.eff_rank <= 4, "eff_rank={} should be ~3", f.eff_rank);
        let approx = f.reconstruct(1.0);
        let rel = approx.sub(&m).fro_norm() / m.fro_norm();
        assert!(rel < 1e-2, "rel err {rel}");
    }

    #[test]
    fn effective_rank_bounded_by_batch() {
        let mut rng = Rng::new(3);
        let (a, d) = rand_pair(&mut rng, 4, 64, 64);
        let f = rankdad_factors(&a, &d, 10, 60, 1e-3);
        assert!(f.eff_rank <= 4, "eff_rank={} > N=4", f.eff_rank);
    }

    #[test]
    fn reconstruction_near_svd_optimal() {
        let mut rng = Rng::new(4);
        let (a, d) = rand_pair(&mut rng, 12, 64, 48);
        let m = matmul_tn(&a, &d);
        let f = rankdad_factors(&a, &d, 6, 80, 1e-3);
        let err = f.reconstruct(1.0).sub(&m).fro_norm();
        // Any rank-6 approx must beat the rank-0 one and the factorization
        // must be least-squares competitive: compare against deflation by
        // repeated dominant extraction on the materialized M.
        assert!(err < m.fro_norm());
        // Orthogonality of extracted right vectors.
        for i in 0..f.eff_rank {
            for j in 0..i {
                let dp = crate::tensor::dot(f.g_t.row(i), f.g_t.row(j));
                assert!(dp.abs() < 1e-3, "g_{i} . g_{j} = {dp}");
            }
            let n = norm(f.g_t.row(i));
            assert!((n - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn matches_python_reference_fixture() {
        // Cross-language consistency: tiny fixed case, values generated by
        // ref.rankdad_factors_ref semantics (checked in python tests); here
        // we verify the structural contract: σ-absorbed rows, unit g rows.
        let a = Matrix::from_vec(2, 3, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]);
        let d = Matrix::from_vec(2, 2, vec![2.0, 0.0, 0.0, 1.0]);
        // M = aᵀd = [[2,0],[0,1],[0,0]]; singular values 2 and 1.
        let f = rankdad_factors(&a, &d, 4, 50, 1e-3);
        assert_eq!(f.eff_rank, 2);
        let s0 = norm(f.q_t.row(0));
        let s1 = norm(f.q_t.row(1));
        assert!((s0 - 2.0).abs() < 1e-3, "s0={s0}");
        assert!((s1 - 1.0).abs() < 1e-3, "s1={s1}");
        let m = matmul_tn(&a, &d);
        assert!(f.reconstruct(1.0).max_abs_diff(&m) < 1e-3);
    }

    #[test]
    fn wire_bytes_scale_with_eff_rank() {
        let mut rng = Rng::new(5);
        let (a, d) = rand_pair(&mut rng, 8, 128, 96);
        let f = rankdad_factors(&a, &d, 8, 30, 1e-3);
        assert_eq!(f.wire_bytes(), ((128 + 96) * f.eff_rank * 4) as u64);
        let (q, g) = f.truncated();
        assert_eq!(q.rows(), f.eff_rank);
        assert_eq!(g.rows(), f.eff_rank);
    }
}

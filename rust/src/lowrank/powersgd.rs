//! PowerSGD (Vogels, Karimireddy & Jaggi, 2019) — the baseline the paper
//! compares rank-dAD against (its section 4.2), reimplemented from the
//! algorithm description: rank-r power iteration ON THE MATERIALIZED
//! GRADIENT with warm-started Q, Gram-Schmidt orthonormalization and error
//! feedback.
//!
//! Contrast with rank-dAD: PowerSGD compresses after the gradient exists
//! (O(h²r) work per step, fixed rank r); rank-dAD factors the gradient's AD
//! constituents directly (O(hNr) work, adaptive effective rank <= r).

use crate::obs::trace::{phase_span, Phase};
use crate::tensor::{matmul, matmul_tn, Matrix, Rng};

/// Orthonormalize the columns of `m` in place (modified Gram-Schmidt).
pub fn orthonormalize_cols(m: &mut Matrix) {
    let (rows, cols) = m.shape();
    for c in 0..cols {
        // Subtract projections onto previous columns.
        for p in 0..c {
            let mut dp = 0.0f32;
            for r in 0..rows {
                dp += m[(r, c)] * m[(r, p)];
            }
            for r in 0..rows {
                let v = m[(r, p)];
                m[(r, c)] -= dp * v;
            }
        }
        let mut nrm = 0.0f32;
        for r in 0..rows {
            nrm += m[(r, c)] * m[(r, c)];
        }
        let nrm = nrm.sqrt();
        if nrm > 1e-12 {
            let inv = 1.0 / nrm;
            for r in 0..rows {
                m[(r, c)] *= inv;
            }
        } else {
            // Degenerate column: re-seed deterministically to keep ranks.
            for r in 0..rows {
                m[(r, c)] = if r == c % rows { 1.0 } else { 0.0 };
            }
        }
    }
}

/// Per-parameter PowerSGD compressor state (one per site in dSGD-style use;
/// all sites stay in lockstep because the inputs are identical postbroadcast).
pub struct PowerSgdState {
    /// Compression rank r.
    pub rank: usize,
    /// Warm-start Q (n_cols x r).
    q: Matrix,
    /// Error-feedback accumulator (same shape as the gradient).
    err: Matrix,
}

impl PowerSgdState {
    /// Fresh state for a rows x cols parameter at rank `rank`; `rng` seeds
    /// the warm-start Q (identical seed => identical Q on every site).
    pub fn new(rows: usize, cols: usize, rank: usize, rng: &mut Rng) -> Self {
        PowerSgdState {
            rank,
            q: Matrix::randn(cols, rank, 1.0, rng),
            err: Matrix::zeros(rows, cols),
        }
    }

    /// Compress the local gradient into P (rows x r): the first half of the
    /// all-reduce. Adds the error-feedback memory first.
    pub fn compress_p(&mut self, grad: &Matrix) -> Matrix {
        let _s = phase_span("psgd-compress", Phase::Compress);
        let m = grad.add(&self.err);
        self.err = m.clone(); // provisional: finalized in `finish`
        matmul(&m, &self.q)
    }

    /// After P has been averaged across sites and orthonormalized, compute
    /// the local Q update: Q = Mᵀ P̂ (second all-reduce half).
    pub fn compress_q(&self, p_hat: &Matrix) -> Matrix {
        matmul_tn(&self.err, p_hat) // self.err currently holds M
    }

    /// Final reconstruction from averaged factors; updates error feedback
    /// (err = M - M̂) and warm-starts Q for the next step.
    pub fn finish(&mut self, p_hat: &Matrix, q_mean: &Matrix) -> Matrix {
        // M̂ = P̂ Qᵀ : (rows x r)(r x cols).
        let m_hat = crate::tensor::matmul_nt(p_hat, q_mean);
        self.err = self.err.sub(&m_hat); // err = M - M̂
        self.q = q_mean.clone();
        m_hat
    }

    /// Bytes for one direction of the exchange (P or Q).
    pub fn wire_bytes(&self, rows: usize, cols: usize) -> u64 {
        ((rows + cols) * self.rank * 4) as u64
    }

    /// Checkpoint view of the mutable state: the warm-start `Q`
    /// (cols x rank) and the error-feedback accumulator (rows x cols).
    pub fn state_mats(&self) -> (&Matrix, &Matrix) {
        (&self.q, &self.err)
    }

    /// Rebuild a compressor mid-run from checkpointed `(q, err)` state.
    pub fn from_state(rank: usize, q: Matrix, err: Matrix) -> Self {
        assert_eq!(q.cols(), rank, "warm-start Q must be cols x rank");
        assert_eq!(q.rows(), err.cols(), "Q rows must match gradient cols");
        PowerSgdState { rank, q, err }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gram_schmidt_orthonormal() {
        let mut rng = Rng::new(1);
        let mut m = Matrix::randn(20, 5, 1.0, &mut rng);
        orthonormalize_cols(&mut m);
        for i in 0..5 {
            for j in 0..=i {
                let mut dp = 0.0;
                for r in 0..20 {
                    dp += m[(r, i)] * m[(r, j)];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dp - want).abs() < 1e-4, "col {i}.{j} = {dp}");
            }
        }
    }

    #[test]
    fn rank_deficient_columns_reseeded() {
        // Two identical columns: second must be replaced, not zeroed.
        let mut m = Matrix::from_vec(3, 2, vec![1.0, 1.0, 0.0, 0.0, 0.0, 0.0]);
        orthonormalize_cols(&mut m);
        let mut n1 = 0.0;
        for r in 0..3 {
            n1 += m[(r, 1)] * m[(r, 1)];
        }
        assert!(n1 > 0.5, "degenerate column not reseeded");
    }

    /// Single-site PowerSGD must converge to the gradient as rank grows.
    #[test]
    fn full_rank_recovers_gradient_with_error_feedback() {
        let mut rng = Rng::new(2);
        let grad = Matrix::randn(12, 10, 1.0, &mut rng);
        let mut st = PowerSgdState::new(12, 10, 10, &mut rng);
        // A couple of warm-start rounds tighten the subspace.
        let mut last = f32::MAX;
        for _ in 0..3 {
            let mut p = st.compress_p(&grad);
            orthonormalize_cols(&mut p);
            let q = st.compress_q(&p);
            let m_hat = st.finish(&p, &q);
            last = m_hat.max_abs_diff(&grad);
        }
        assert!(last < 1e-2, "full-rank reconstruction err {last}");
    }

    /// With rank 1 the reconstruction error must be bounded by the optimal
    /// rank-1 residual plus slack, and error feedback must carry the rest.
    #[test]
    fn error_feedback_accumulates_residual() {
        let mut rng = Rng::new(3);
        let grad = Matrix::randn(8, 6, 1.0, &mut rng);
        let mut st = PowerSgdState::new(8, 6, 1, &mut rng);
        let mut p = st.compress_p(&grad);
        orthonormalize_cols(&mut p);
        let q = st.compress_q(&p);
        let m_hat = st.finish(&p, &q);
        // err + m_hat == grad exactly (error feedback invariant).
        let resid = grad.sub(&m_hat);
        assert!(st.err.max_abs_diff(&resid) < 1e-5);
    }
}

//! `dad` — the launcher for distributed auto-differentiation experiments.
//!
//! Subcommands:
//!
//! ```text
//! exp <id> [--scale quick|default|paper]
//!     regenerate a paper table/figure: table2, fig1, fig2, fig3, fig4,
//!     fig5, fig6, lm, bandwidth, all
//! train [--algo A] [--dataset D] [--epochs N] [--batch B] [--sites S]
//!       [--scale SC] [--config path.toml]
//!     one training run with full telemetry (in-process loopback cluster)
//! serve [--sites S] [--addr HOST:PORT] [train options]
//!     run the aggregator for a multi-process TCP run and wait for S
//!     `dad join` processes
//! join [HOST:PORT]
//!     run one training site against a serving aggregator
//! info
//!     platform, artifact and thread-pool status
//! ```

use dad::algos::AlgoSpec;
use dad::config::{Args, TomlLite};
use dad::coordinator::experiments::{self, Scale};
use dad::coordinator::{
    build_task, join_training, serve_training, train, validate_dataset_algo, validate_remote,
    RemoteConfig, Schedule, TrainLog, TrainSpec, TrainTask,
};
use dad::dist::{Direction, Ledger, TcpAgg, TcpSite};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "join" => cmd_join(&args),
        "info" => cmd_info(),
        _ => print_help(),
    }
}

fn print_help() {
    println!(
        "dad — distributed auto-differentiation (dAD / edAD / rank-dAD)\n\
         \n\
         USAGE:\n\
           dad exp <table2|fig1|fig2|fig3|fig4|fig5|fig6|lm|bandwidth|all> [--scale quick|default|paper]\n\
           dad train [--algo pooled|dsgd|dad|dad-p2p|edad|rank-dad:R|powersgd:R] [--dataset mnist|arabic|lm]\n\
                     [--epochs N] [--batch B] [--sites S] [--lr F] [--seed N] [--sync-every K]\n\
                     [--scale quick|default|paper] [--config path.toml] [--csv PATH]\n\
           dad serve [--addr HOST:PORT] [--sites S] [--csv PATH] [train options]\n\
           dad join  [HOST:PORT] [--csv PATH]\n\
           dad info\n\
         \n\
         `train` simulates all sites in one process over the loopback transport;\n\
         `serve`/`join` run the same optimization as separate OS processes over\n\
         TCP, with identical losses and ledger byte counts for the same seed.\n\
         Every --algo (and --sync-every schedule) runs in both modes, on every\n\
         dataset: mnist (MLP), arabic (GRU), lm (decoder-only transformer;\n\
         edad is rejected up front — attention has no delta recomputation).\n\
         Experiment outputs land in results/*.csv; see EXPERIMENTS.md."
    );
}

fn scale_of(args: &Args) -> Scale {
    Scale::parse(args.opt_or("scale", "default")).unwrap_or(Scale::Default)
}

fn cmd_info() {
    println!("dad v{}", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", dad::tensor::parallel::num_threads());
    let dir = dad::runtime::PjrtRuntime::default_dir();
    println!("artifacts dir: {}", dir.display());
    for name in ["smoke", "mlp_stats", "mlp_grads", "mlp_train_step", "rankdad_factors", "fused_delta"] {
        let ok = dir.join(format!("{name}.hlo.txt")).is_file();
        println!("  {name}: {}", if ok { "present" } else { "MISSING (run `make artifacts`)" });
    }
    match dad::runtime::PjrtRuntime::cpu(&dir) {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
}

fn cmd_exp(args: &Args) {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let scale = scale_of(args);
    println!("== experiment {id} (scale {scale:?}) ==");
    let t0 = std::time::Instant::now();
    match id {
        "table2" => run_table2(scale),
        "fig1" => run_curves("fig1", experiments::fig1(scale)),
        "fig2" => run_curves("fig2", experiments::fig2(scale)),
        "fig3" => {
            run_curves("fig3/mnist", experiments::fig3_mnist(scale));
            run_curves("fig3/arabic", experiments::fig3_arabic(scale));
        }
        "fig4" => run_rank_curves("fig4 (MLP/MNIST, max rank 10)", &experiments::fig4(scale)),
        "fig5" => {
            for (name, curves) in experiments::fig5(scale) {
                run_rank_curves(&format!("fig5 {name} (max rank 32)"), &curves);
            }
        }
        "fig6" => run_curves("fig6 (GRU ranks)", experiments::fig3_arabic(scale)),
        "lm" => run_lm(scale),
        "bandwidth" => run_bandwidth(),
        "all" => {
            run_table2(scale);
            run_curves("fig1", experiments::fig1(scale));
            run_curves("fig2", experiments::fig2(scale));
            run_curves("fig3/mnist", experiments::fig3_mnist(scale));
            run_curves("fig3+6/arabic", experiments::fig3_arabic(scale));
            run_rank_curves("fig4", &experiments::fig4(scale));
            for (name, curves) in experiments::fig5(scale) {
                run_rank_curves(&format!("fig5 {name}"), &curves);
            }
            run_bandwidth();
            if scale == Scale::Quick {
                run_lm(scale);
            } else {
                // Deliberately excluded at default/paper scale: the LM sweep
                // trains the 12.8M/100M transformer four times (hours of
                // CPU); surface that instead of silently skipping it.
                println!(
                    "[lm sweep skipped at {scale:?} scale — run `dad exp lm --scale {}` \
                     explicitly; it trains the transformer 4x]",
                    match scale {
                        Scale::Default => "default",
                        _ => "paper",
                    }
                );
            }
        }
        other => {
            eprintln!("unknown experiment {other:?}");
            print_help();
            std::process::exit(2);
        }
    }
    println!("[{} done in {:.1}s]", id, t0.elapsed().as_secs_f32());
}

fn run_table2(scale: Scale) {
    let rows = experiments::table2(scale);
    println!("Table 2 — max |grad_dist - grad_pooled| over one epoch:");
    println!("{:<24} {:>12} {:>12} {:>12}", "layer", "dSGD", "dAD", "edAD");
    for r in rows {
        println!("{:<24} {:>12.3e} {:>12.3e} {:>12.3e}", r.layer, r.dsgd, r.dad, r.edad);
    }
}

fn run_curves(tag: &str, set: experiments::CurveSet) {
    println!("{tag}: final test AUC (mean over folds) and total bytes:");
    for ((name, series), (_, bytes)) in set.curves.iter().zip(&set.bytes) {
        let last = series.last().copied().unwrap_or((0.5, 0.0));
        println!("  {:<14} auc {:.4} ± {:.4}   bytes {:>12}", name, last.0, last.1, bytes);
    }
}

fn run_rank_curves(tag: &str, curves: &experiments::RankCurves) {
    println!("{tag}: mean effective rank per layer (first -> last epoch):");
    for (i, name) in curves.entry_names.iter().enumerate() {
        let first = curves.per_epoch.first().map(|e| e[i]).unwrap_or(f32::NAN);
        let last = curves.per_epoch.last().map(|e| e[i]).unwrap_or(f32::NAN);
        println!("  {:<28} {:>6.2} -> {:>6.2}", name, first, last);
    }
}

fn run_lm(scale: Scale) {
    let rows = experiments::lm_comparison(scale);
    println!("LM (decoder-only transformer, 2 sites): final loss/ppl and total payload bytes:");
    println!("{:<14} {:>10} {:>10} {:>14} {:>14}", "algo", "loss", "ppl", "bytes_up", "bytes_down");
    for r in rows {
        println!(
            "{:<14} {:>10.4} {:>10.3} {:>14} {:>14}",
            r.algo, r.final_loss, r.final_ppl, r.bytes_up, r.bytes_down
        );
    }
}

fn run_bandwidth() {
    let rows = experiments::bandwidth_table(&[256, 512, 1024, 2048], 32);
    println!("Bandwidth (site->agg bytes, one step, 2 sites, batch 32/site):");
    println!("{:<14} {:>6} {:>14} {:>14} {:>7}", "algo", "h", "measured", "theta-bound", "ratio");
    for r in rows {
        println!(
            "{:<14} {:>6} {:>14} {:>14} {:>7.2}",
            r.algo,
            r.h,
            r.measured_up,
            r.theta_up,
            r.measured_up as f64 / r.theta_up.max(1) as f64
        );
    }
}

/// Training spec + dataset name from CLI options over optional TOML config
/// (CLI wins). Shared by `train` and `serve` so a multi-process run is
/// specified exactly like a simulated one.
fn train_spec_from(args: &Args) -> (TrainSpec, String) {
    let cfg = args
        .opt("config")
        .map(|p| TomlLite::load(p).unwrap_or_else(|e| panic!("config: {e}")))
        .unwrap_or_default();
    let algo_s = args
        .opt("algo")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.str_or("train", "algo", "dad").to_string());
    let algo = AlgoSpec::parse(&algo_s).unwrap_or_else(|e| {
        eprintln!("--algo {algo_s:?}: {e}");
        std::process::exit(2)
    });
    let dataset = args
        .opt("dataset")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.str_or("train", "dataset", "mnist").to_string());
    let spec = TrainSpec {
        algo,
        n_sites: args.usize_or("sites", cfg.int_or("train", "sites", 2) as usize),
        batch_per_site: args.usize_or("batch", cfg.int_or("train", "batch", 32) as usize),
        epochs: args.usize_or("epochs", cfg.int_or("train", "epochs", 10) as usize),
        lr: args.f32_or("lr", cfg.float_or("train", "lr", 1e-4) as f32),
        seed: args.usize_or("seed", cfg.int_or("train", "seed", 13) as usize) as u64,
        schedule: Schedule::from_sync_every(args.usize_or("sync-every", 1)),
    };
    (spec, dataset)
}

/// Honor `--csv PATH`: write the per-epoch metrics log (shared by train,
/// serve and join — the CI remote-matrix job asserts the file is
/// non-empty for every algorithm).
fn maybe_write_csv(args: &Args, log: &TrainLog) {
    if let Some(path) = args.opt("csv") {
        log.write_csv(path).unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("metrics written to {path}");
    }
}

fn print_epochs(log: &TrainLog) {
    for e in &log.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  auc {:.4}  acc {:.4}{}  up {:>10}B  down {:>10}B{}",
            e.epoch,
            e.train_loss,
            e.test_auc,
            e.test_acc,
            if e.test_ppl.is_finite() {
                format!("  ppl {:.3}", e.test_ppl)
            } else {
                String::new()
            },
            e.bytes_up,
            e.bytes_down,
            if e.mean_eff_rank.iter().any(|r| r.is_finite()) {
                format!("  eff-rank {:?}", e.mean_eff_rank)
            } else {
                String::new()
            }
        );
    }
}

fn cmd_train(args: &Args) {
    let (spec, dataset) = train_spec_from(args);
    // Fail fast with a clear error on combinations that cannot train
    // (edad + lm), before any dataset/model construction.
    validate_dataset_algo(&dataset, &spec.algo).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let scale = scale_of(args);
    println!("training {} on {dataset} ({:?})", spec.algo.name(), scale);
    let t0 = std::time::Instant::now();
    let log = match build_task(&dataset, scale, spec.n_sites, spec.seed) {
        Ok(TrainTask::Dense { train_ds, test_ds, shards, model }) => {
            train(model, &spec, &train_ds, &shards, &test_ds)
        }
        Ok(TrainTask::Seq { train_ds, test_ds, shards, model }) => {
            train(model, &spec, &train_ds, &shards, &test_ds)
        }
        Ok(TrainTask::Tokens { train_ds, test_ds, shards, model }) => {
            train(model, &spec, &train_ds, &shards, &test_ds)
        }
        Err(e) => panic!("{e}"),
    };
    print_epochs(&log);
    maybe_write_csv(args, &log);
    let up: u64 = log.epochs.iter().map(|e| e.bytes_up).sum();
    let down: u64 = log.epochs.iter().map(|e| e.bytes_down).sum();
    println!(
        "done in {:.1}s wall; simulated wire time {:.3}s; ledger bytes: up {up} down {down}",
        t0.elapsed().as_secs_f32(),
        log.sim_time_s,
    );
}

fn cmd_serve(args: &Args) {
    let (spec, dataset) = train_spec_from(args);
    // Fail fast on the operator's terminal, before any site can connect:
    // first the dataset/algorithm pairing (edad + lm), then the remote
    // schedule restriction (edad + periodic).
    validate_dataset_algo(&dataset, &spec.algo).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    validate_remote(&spec).unwrap_or_else(|e| panic!("{e}"));
    let scale_s = args.opt_or("scale", "default").to_string();
    let scale = Scale::parse(&scale_s).unwrap_or(Scale::Default);
    let addr = args.opt_or("addr", "127.0.0.1:7009").to_string();
    let listener =
        TcpAgg::bind(&addr, spec.n_sites).unwrap_or_else(|e| panic!("bind {addr}: {e}"));
    let shown = listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.clone());
    println!(
        "serving {} on {dataset} ({scale:?}) at {shown}; waiting for {} x `dad join {shown}`",
        spec.algo.name(),
        spec.n_sites
    );
    let mut agg = listener.accept_sites().unwrap_or_else(|e| panic!("handshake: {e}"));
    RemoteConfig { spec: spec.clone(), dataset: dataset.clone(), scale: scale_s }
        .send(&mut agg)
        .unwrap_or_else(|e| panic!("config broadcast: {e}"));
    let mut ledger = Ledger::new();
    let t0 = std::time::Instant::now();
    let log = match build_task(&dataset, scale, spec.n_sites, spec.seed) {
        Ok(TrainTask::Dense { train_ds, test_ds, shards, model }) => {
            serve_training(&mut agg, &mut ledger, &spec, model, &train_ds, &shards, &test_ds)
        }
        Ok(TrainTask::Seq { train_ds, test_ds, shards, model }) => {
            serve_training(&mut agg, &mut ledger, &spec, model, &train_ds, &shards, &test_ds)
        }
        Ok(TrainTask::Tokens { train_ds, test_ds, shards, model }) => {
            serve_training(&mut agg, &mut ledger, &spec, model, &train_ds, &shards, &test_ds)
        }
        Err(e) => panic!("{e}"),
    }
    .unwrap_or_else(|e| panic!("serve: {e}"));
    print_epochs(&log);
    maybe_write_csv(args, &log);
    println!(
        "done in {:.1}s wall; measured wire bytes: up {} down {}",
        t0.elapsed().as_secs_f32(),
        ledger.total_dir(Direction::SiteToAgg),
        ledger.total_dir(Direction::AggToSite),
    );
    for (tag, dir, bytes) in ledger.breakdown() {
        println!("  {dir:?} {tag:<12} {bytes:>12} B");
    }
}

fn cmd_join(args: &Args) {
    let addr =
        args.positional.get(1).map(|s| s.as_str()).unwrap_or("127.0.0.1:7009").to_string();
    // Retry the dial briefly: launcher scripts (and CI) start serve and
    // joins concurrently, so the listener may not be bound yet.
    let mut site = TcpSite::connect_retry(&addr, std::time::Duration::from_secs(10))
        .unwrap_or_else(|e| panic!("connect {addr}: {e}"));
    let site_id = site.site_id();
    let cfg = RemoteConfig::recv(&mut site).unwrap_or_else(|e| panic!("config: {e}"));
    let scale = Scale::parse(&cfg.scale).unwrap_or(Scale::Default);
    println!(
        "joined {addr} as site {site_id}/{}: {} on {} ({scale:?})",
        cfg.spec.n_sites,
        cfg.spec.algo.name(),
        cfg.dataset,
    );
    let mut ledger = Ledger::new();
    let t0 = std::time::Instant::now();
    let log = match build_task(&cfg.dataset, scale, cfg.spec.n_sites, cfg.spec.seed) {
        Ok(TrainTask::Dense { train_ds, shards, model, .. }) => {
            join_training(&mut site, &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        Ok(TrainTask::Seq { train_ds, shards, model, .. }) => {
            join_training(&mut site, &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        Ok(TrainTask::Tokens { train_ds, shards, model, .. }) => {
            join_training(&mut site, &mut ledger, &cfg.spec, model, &train_ds, &shards, site_id)
        }
        Err(e) => panic!("{e}"),
    }
    .unwrap_or_else(|e| panic!("join: {e}"));
    for e in &log.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  up {:>10}B  down {:>10}B",
            e.epoch, e.train_loss, e.bytes_up, e.bytes_down
        );
    }
    maybe_write_csv(args, &log);
    println!(
        "done in {:.1}s; this site shipped {} B up, received {} B down",
        t0.elapsed().as_secs_f32(),
        ledger.total_dir(Direction::SiteToAgg),
        ledger.total_dir(Direction::AggToSite),
    );
}

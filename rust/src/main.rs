//! `dad` — the launcher for distributed auto-differentiation experiments.
//!
//! Subcommands:
//!
//! ```text
//! exp <id> [--scale quick|default|paper]
//!     regenerate a paper table/figure: table2, fig1, fig2, fig3, fig4,
//!     fig5, fig6, lm, bandwidth, all
//! train [--algo A] [--dataset D] [--epochs N] [--batch B] [--sites S]
//!       [--scale SC] [--config path.toml]
//!     one training run with full telemetry (in-process loopback cluster)
//! serve [--sites S] [--addr HOST:PORT] [--strict] [--partition P]
//!       [--topology flat|tree:R] [train options]
//!     run the aggregator for a multi-process TCP run and wait for S
//!     `dad join` processes (or, under --topology tree:R, for R direct
//!     children — `dad relay` subtrees and/or leaves — covering S sites);
//!     lost sites degrade the run (or fail it, under --strict) instead of
//!     hanging it
//! join [HOST:PORT]
//!     run one training site against a serving aggregator
//! relay --parent HOST:PORT --sites N [--addr HOST:PORT] [--strict]
//!     run one interior level of an aggregation tree: accept N leaves,
//!     dial the parent as a single N-leaf subtree, and reduce each
//!     exchange before forwarding (gather → associative combine → emit)
//! chaos --list | --recipe NAME [--strict] | --recipe-file PATH
//!     run a named fault-injection scenario over real TCP sockets and
//!     assert its convergence-or-clean-failure expectation
//! infer --serve ADDR --checkpoint PATH | --bench --addr ADDR
//!     serve batched predictions from a checkpoint over TCP, or drive a
//!     running server with the closed-loop load generator
//! trace summarize PATH
//!     aggregate a JSONL span trace (written via --trace) into a
//!     per-span table with a per-phase rollup
//! info
//!     platform, artifact and thread-pool status
//! ```
//!
//! `train` and `serve` both accept `--checkpoint PATH`
//! (+ `--checkpoint-every N`) to save resumable state at epoch
//! boundaries, and `--resume PATH` to continue a saved run; see
//! `docs/OPERATIONS.md` for the runbook and `docs/FORMATS.md` for the
//! container layout. Every run command accepts `--trace PATH` (JSONL
//! span trace); `serve`, `join` and `infer --serve` accept
//! `--metrics HOST:PORT` (live Prometheus text endpoint at `/metrics`);
//! see `docs/OPERATIONS.md` §Observability.

use std::path::Path;
use std::time::Duration;

use dad::algos::AlgoSpec;
use dad::checkpoint::{Checkpoint, CheckpointPlan};
use dad::config::{Args, TomlLite};
use dad::coordinator::experiments::{self, Scale};
use dad::coordinator::{
    build_task, join_training_resumable, relay_training, serve_training_checkpointed,
    train_checkpointed, validate_dataset_algo, validate_remote, validate_remote_topology,
    FaultPolicy, RemoteConfig, ResumeMode, Schedule, Topology, TrainLog, TrainSpec, TrainTask,
};
use dad::infer::{run_bench, InferClient, InferOpts, InferServer};
use dad::data::Partition;
use dad::dist::{Direction, Ledger, TcpAgg, TcpSite, Transport};
use dad::scenario::{find_recipe, named_recipes, run_recipe, Recipe};

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "exp" => cmd_exp(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "join" => cmd_join(&args),
        "relay" => cmd_relay(&args),
        "chaos" => cmd_chaos(&args),
        "infer" => cmd_infer(&args),
        "trace" => cmd_trace(&args),
        "info" => cmd_info(),
        _ => print_help(),
    }
}

/// Arm the run-wide observability the common flags ask for: `--trace PATH`
/// starts the JSONL span trace, `--metrics HOST:PORT` serves live
/// Prometheus text at `/metrics`. Returns the server guard — it must stay
/// alive for the run's duration — and is paired with [`obs_finish`].
fn obs_setup(args: &Args) -> Option<dad::obs::serve::MetricsServer> {
    if let Some(path) = args.opt("trace") {
        dad::obs::trace::enable(Path::new(path)).unwrap_or_else(|e| {
            eprintln!("--trace {path}: {e}");
            std::process::exit(1)
        });
        println!("tracing spans to {path}");
    }
    args.opt("metrics").map(|addr| {
        dad::obs::metrics::reset_all();
        let srv = dad::obs::serve::MetricsServer::start(addr).unwrap_or_else(|e| {
            eprintln!("--metrics {addr}: {e}");
            std::process::exit(1)
        });
        println!("metrics at http://{}/metrics", srv.addr());
        srv
    })
}

/// Seal the trace file (final flush + footer); errors are reported, not
/// fatal — the run itself already succeeded.
fn obs_finish() {
    if dad::obs::trace::enabled() {
        if let Err(e) = dad::obs::trace::finish() {
            eprintln!("finishing trace: {e}");
        }
    }
}

/// `dad trace summarize PATH`: per-span aggregate table for a JSONL trace.
fn cmd_trace(args: &Args) {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    let path = args.positional.get(2).map(|s| s.as_str());
    match (sub, path) {
        ("summarize", Some(p)) => {
            let table = dad::obs::summarize_trace(Path::new(p)).unwrap_or_else(|e| {
                eprintln!("{p}: {e}");
                std::process::exit(1)
            });
            print!("{table}");
        }
        _ => {
            eprintln!("usage: dad trace summarize PATH");
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "dad — distributed auto-differentiation (dAD / edAD / rank-dAD)\n\
         \n\
         USAGE:\n\
           dad exp <table2|fig1|fig2|fig3|fig4|fig5|fig6|lm|bandwidth|all> [--scale quick|default|paper]\n\
           dad train [--algo pooled|dsgd|dad|dad-p2p|edad|rank-dad:R|powersgd:R|dgc:K%|vbc:L|adacomp:B]\n\
                     [--dataset mnist|arabic|lm]\n\
                     [--epochs N] [--batch B] [--sites S] [--lr F] [--seed N] [--sync-every K]\n\
                     [--scale quick|default|paper] [--config path.toml] [--csv PATH]\n\
                     [--checkpoint PATH] [--checkpoint-every N] [--resume PATH]\n\
           dad serve [--addr HOST:PORT] [--sites S] [--csv PATH] [--strict]\n\
                     [--partition default|iid|skew:R] [--topology flat|tree:R]\n\
                     [--straggler-deadline SECS]\n\
                     [--handshake-timeout SECS] [--recv-timeout SECS]\n\
                     [--checkpoint PATH] [--checkpoint-every N] [--resume PATH] [train options]\n\
           dad join  [HOST:PORT] [--csv PATH]\n\
           dad relay --parent HOST:PORT --sites N [--addr HOST:PORT] [--strict]\n\
                     [--straggler-deadline SECS] [--handshake-timeout SECS]\n\
           dad chaos --list | --recipe NAME [--strict] [--csv PATH] | --recipe-file PATH\n\
           dad infer --serve HOST:PORT --checkpoint PATH [--max-batch N] [--batch-window-ms MS]\n\
           dad infer --bench --addr HOST:PORT [--requests N] [--concurrency C]\n\
                     [--json PATH] [--shutdown]\n\
           dad trace summarize PATH\n\
           dad info\n\
         \n\
         `train` simulates all sites in one process over the loopback transport;\n\
         `serve`/`join` run the same optimization as separate OS processes over\n\
         TCP, with identical losses and ledger byte counts for the same seed.\n\
         Every --algo (and --sync-every schedule) runs in both modes, on every\n\
         dataset: mnist (MLP), arabic (GRU), lm (decoder-only transformer;\n\
         edad is rejected up front — attention has no delta recomputation).\n\
         A site lost at a step boundary degrades the run to the survivors\n\
         (logged as sites_live in the CSV); --strict fails it cleanly instead.\n\
         `serve --topology tree:R` + `relay` build a multi-level aggregation\n\
         tree that is bit-equal to the flat star (grads, losses, per-tag\n\
         ledger bytes); a site dialing a running fabric is admitted at the\n\
         next epoch boundary and the shards are re-dealt (elastic join).\n\
         `chaos` replays named deterministic fault scenarios (see README).\n\
         --checkpoint saves resumable state (model, Adam moments, RNG cursor,\n\
         epoch position) at epoch boundaries; --resume continues a saved run\n\
         bit-for-bit (requires --sync-every 1; see docs/OPERATIONS.md).\n\
         `infer` serves batched predictions from a checkpoint over TCP and\n\
         benchmarks a running server into BENCH_serving.json.\n\
         Observability: train/serve/join/chaos/infer accept --trace PATH\n\
         (JSONL span trace; read it with `dad trace summarize PATH`), and\n\
         serve/join/infer --serve accept --metrics HOST:PORT (a live\n\
         Prometheus text endpoint at /metrics). The per-epoch CSV carries\n\
         the compute/comms/stall/compress seconds breakdown.\n\
         Experiment outputs land in results/*.csv; see EXPERIMENTS.md."
    );
}

fn scale_of(args: &Args) -> Scale {
    Scale::parse(args.opt_or("scale", "default")).unwrap_or(Scale::Default)
}

fn cmd_info() {
    println!("dad v{}", env!("CARGO_PKG_VERSION"));
    println!("threads: {}", dad::tensor::parallel::num_threads());
    let dir = dad::runtime::PjrtRuntime::default_dir();
    println!("artifacts dir: {}", dir.display());
    for name in ["smoke", "mlp_stats", "mlp_grads", "mlp_train_step", "rankdad_factors", "fused_delta"] {
        let ok = dir.join(format!("{name}.hlo.txt")).is_file();
        println!("  {name}: {}", if ok { "present" } else { "MISSING (run `make artifacts`)" });
    }
    match dad::runtime::PjrtRuntime::cpu(&dir) {
        Ok(rt) => println!("PJRT platform: {}", rt.platform()),
        Err(e) => println!("PJRT unavailable: {e:#}"),
    }
}

fn cmd_exp(args: &Args) {
    let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let scale = scale_of(args);
    println!("== experiment {id} (scale {scale:?}) ==");
    let _obs = obs_setup(args);
    let t0 = std::time::Instant::now();
    match id {
        "table2" => run_table2(scale),
        "fig1" => run_curves("fig1", experiments::fig1(scale)),
        "fig2" => run_curves("fig2", experiments::fig2(scale)),
        "fig3" => {
            run_curves("fig3/mnist", experiments::fig3_mnist(scale));
            run_curves("fig3/arabic", experiments::fig3_arabic(scale));
        }
        "fig4" => run_rank_curves("fig4 (MLP/MNIST, max rank 10)", &experiments::fig4(scale)),
        "fig5" => {
            for (name, curves) in experiments::fig5(scale) {
                run_rank_curves(&format!("fig5 {name} (max rank 32)"), &curves);
            }
        }
        "fig6" => run_curves("fig6 (GRU ranks)", experiments::fig3_arabic(scale)),
        "lm" => run_lm(scale),
        "bandwidth" => run_bandwidth(),
        "all" => {
            run_table2(scale);
            run_curves("fig1", experiments::fig1(scale));
            run_curves("fig2", experiments::fig2(scale));
            run_curves("fig3/mnist", experiments::fig3_mnist(scale));
            run_curves("fig3+6/arabic", experiments::fig3_arabic(scale));
            run_rank_curves("fig4", &experiments::fig4(scale));
            for (name, curves) in experiments::fig5(scale) {
                run_rank_curves(&format!("fig5 {name}"), &curves);
            }
            run_bandwidth();
            if scale == Scale::Quick {
                run_lm(scale);
            } else {
                // Deliberately excluded at default/paper scale: the LM sweep
                // trains the 12.8M/100M transformer four times (hours of
                // CPU); surface that instead of silently skipping it.
                println!(
                    "[lm sweep skipped at {scale:?} scale — run `dad exp lm --scale {}` \
                     explicitly; it trains the transformer 4x]",
                    match scale {
                        Scale::Default => "default",
                        _ => "paper",
                    }
                );
            }
        }
        other => {
            eprintln!("unknown experiment {other:?}");
            print_help();
            std::process::exit(2);
        }
    }
    println!("[{} done in {:.1}s]", id, t0.elapsed().as_secs_f32());
    obs_finish();
}

fn run_table2(scale: Scale) {
    let rows = experiments::table2(scale);
    println!("Table 2 — max |grad_dist - grad_pooled| over one epoch:");
    println!("{:<24} {:>12} {:>12} {:>12}", "layer", "dSGD", "dAD", "edAD");
    for r in rows {
        println!("{:<24} {:>12.3e} {:>12.3e} {:>12.3e}", r.layer, r.dsgd, r.dad, r.edad);
    }
}

fn run_curves(tag: &str, set: experiments::CurveSet) {
    println!("{tag}: final test AUC (mean over folds) and total bytes:");
    for ((name, series), (_, bytes)) in set.curves.iter().zip(&set.bytes) {
        let last = series.last().copied().unwrap_or((0.5, 0.0));
        println!("  {:<14} auc {:.4} ± {:.4}   bytes {:>12}", name, last.0, last.1, bytes);
    }
}

fn run_rank_curves(tag: &str, curves: &experiments::RankCurves) {
    println!("{tag}: mean effective rank per layer (first -> last epoch):");
    for (i, name) in curves.entry_names.iter().enumerate() {
        let first = curves.per_epoch.first().map(|e| e[i]).unwrap_or(f32::NAN);
        let last = curves.per_epoch.last().map(|e| e[i]).unwrap_or(f32::NAN);
        println!("  {:<28} {:>6.2} -> {:>6.2}", name, first, last);
    }
}

fn run_lm(scale: Scale) {
    let rows = experiments::lm_comparison(scale);
    println!("LM (decoder-only transformer, 2 sites): final loss/ppl, total payload bytes, wall:");
    println!(
        "{:<14} {:>10} {:>10} {:>14} {:>14} {:>9}",
        "algo", "loss", "ppl", "bytes_up", "bytes_down", "wall_s"
    );
    for r in rows {
        println!(
            "{:<14} {:>10.4} {:>10.3} {:>14} {:>14} {:>9.3}",
            r.algo, r.final_loss, r.final_ppl, r.bytes_up, r.bytes_down, r.wall_s
        );
    }
}

fn run_bandwidth() {
    let rows = experiments::bandwidth_table(&[256, 512, 1024, 2048], 32);
    println!("Bandwidth (site->agg bytes, one step, 2 sites, batch 32/site):");
    println!("{:<14} {:>6} {:>14} {:>14} {:>7}", "algo", "h", "measured", "theta-bound", "ratio");
    for r in rows {
        println!(
            "{:<14} {:>6} {:>14} {:>14} {:>7.2}",
            r.algo,
            r.h,
            r.measured_up,
            r.theta_up,
            r.measured_up as f64 / r.theta_up.max(1) as f64
        );
    }
}

/// Training spec + dataset name from CLI options over optional TOML config
/// (CLI wins). Shared by `train` and `serve` so a multi-process run is
/// specified exactly like a simulated one.
fn train_spec_from(args: &Args) -> (TrainSpec, String) {
    let cfg = args
        .opt("config")
        .map(|p| TomlLite::load(p).unwrap_or_else(|e| panic!("config: {e}")))
        .unwrap_or_default();
    let algo_s = args
        .opt("algo")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.str_or("train", "algo", "dad").to_string());
    let algo = AlgoSpec::parse(&algo_s).unwrap_or_else(|e| {
        eprintln!("--algo {algo_s:?}: {e}");
        std::process::exit(2)
    });
    let dataset = args
        .opt("dataset")
        .map(str::to_string)
        .unwrap_or_else(|| cfg.str_or("train", "dataset", "mnist").to_string());
    let spec = TrainSpec {
        algo,
        n_sites: args.usize_or("sites", cfg.int_or("train", "sites", 2) as usize),
        batch_per_site: args.usize_or("batch", cfg.int_or("train", "batch", 32) as usize),
        epochs: args.usize_or("epochs", cfg.int_or("train", "epochs", 10) as usize),
        lr: args.f32_or("lr", cfg.float_or("train", "lr", 1e-4) as f32),
        seed: args.usize_or("seed", cfg.int_or("train", "seed", 13) as usize) as u64,
        schedule: Schedule::from_sync_every(args.usize_or("sync-every", 1)),
    };
    (spec, dataset)
}

/// Honor `--csv PATH`: write the per-epoch metrics log (shared by train,
/// serve and join — the CI remote-matrix job asserts the file is
/// non-empty for every algorithm).
fn maybe_write_csv(args: &Args, log: &TrainLog) {
    if let Some(path) = args.opt("csv") {
        log.write_csv(path).unwrap_or_else(|e| panic!("writing {path}: {e}"));
        println!("metrics written to {path}");
    }
}

fn print_epochs(log: &TrainLog) {
    for e in &log.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  auc {:.4}  acc {:.4}{}  up {:>10}B  down {:>10}B{}",
            e.epoch,
            e.train_loss,
            e.test_auc,
            e.test_acc,
            if e.test_ppl.is_finite() {
                format!("  ppl {:.3}", e.test_ppl)
            } else {
                String::new()
            },
            e.bytes_up,
            e.bytes_down,
            if e.mean_eff_rank.iter().any(|r| r.is_finite()) {
                format!("  eff-rank {:?}", e.mean_eff_rank)
            } else {
                String::new()
            }
        );
    }
}

/// `--resume PATH`: load the checkpoint, or exit with its named error.
fn load_resume(args: &Args) -> Option<Checkpoint> {
    args.opt("resume").map(|p| {
        Checkpoint::load(Path::new(p)).unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(1)
        })
    })
}

/// `--checkpoint PATH` / `--checkpoint-every N` into a save plan carrying
/// the dataset/scale keys the checkpoint meta records.
fn ckpt_plan(args: &Args, dataset: &str, scale_s: &str) -> CheckpointPlan {
    CheckpointPlan {
        save_path: args.opt("checkpoint").map(str::to_string),
        every: args.usize_or("checkpoint-every", 0),
        dataset: dataset.to_string(),
        scale: scale_s.to_string(),
    }
}

fn cmd_train(args: &Args) {
    let (spec, mut dataset) = train_spec_from(args);
    let mut scale_s = args.opt_or("scale", "default").to_string();
    let resume = load_resume(args);
    if let Some(ck) = &resume {
        // The checkpoint records what it was trained on; CLI dataset/scale
        // flags would rebuild a different model than the saved parameters,
        // so the meta wins.
        dataset = ck.meta.dataset.clone();
        scale_s = ck.meta.scale.clone();
    }
    let scale = Scale::parse(&scale_s).unwrap_or(Scale::Default);
    // Fail fast with a clear error on combinations that cannot train
    // (edad + lm), before any dataset/model construction.
    validate_dataset_algo(&dataset, &spec.algo).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let plan = ckpt_plan(args, &dataset, &scale_s);
    println!(
        "training {} on {dataset} ({scale:?}){}",
        spec.algo.name(),
        if resume.is_some() { " [resumed]" } else { "" }
    );
    let _obs = obs_setup(args);
    let t0 = std::time::Instant::now();
    let log = match build_task(&dataset, scale, spec.n_sites, spec.seed) {
        Ok(TrainTask::Dense { train_ds, test_ds, shards, model }) => {
            train_checkpointed(model, &spec, &train_ds, &shards, &test_ds, &plan, resume)
        }
        Ok(TrainTask::Seq { train_ds, test_ds, shards, model }) => {
            train_checkpointed(model, &spec, &train_ds, &shards, &test_ds, &plan, resume)
        }
        Ok(TrainTask::Tokens { train_ds, test_ds, shards, model }) => {
            train_checkpointed(model, &spec, &train_ds, &shards, &test_ds, &plan, resume)
        }
        Err(e) => panic!("{e}"),
    }
    .unwrap_or_else(|e| {
        eprintln!("train: {e}");
        std::process::exit(1)
    });
    if let Some(path) = &plan.save_path {
        println!("checkpoint written to {path}");
    }
    print_epochs(&log);
    maybe_write_csv(args, &log);
    let up: u64 = log.epochs.iter().map(|e| e.bytes_up).sum();
    let down: u64 = log.epochs.iter().map(|e| e.bytes_down).sum();
    println!(
        "done in {:.1}s wall; simulated wire time {:.3}s; ledger bytes: up {up} down {down}",
        t0.elapsed().as_secs_f32(),
        log.sim_time_s,
    );
    obs_finish();
}

fn cmd_serve(args: &Args) {
    let (spec, mut dataset) = train_spec_from(args);
    let mut scale_arg = args.opt_or("scale", "default").to_string();
    let resume = load_resume(args);
    if let Some(ck) = &resume {
        // As in `train`: the checkpoint meta fixes the task; the joining
        // sites learn it from the broadcast config.
        dataset = ck.meta.dataset.clone();
        scale_arg = ck.meta.scale.clone();
    }
    // Fail fast on the operator's terminal, before any site can connect:
    // first the dataset/algorithm pairing (edad + lm), then the remote
    // schedule restriction (edad + periodic).
    validate_dataset_algo(&dataset, &spec.algo).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    validate_remote(&spec).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let topology = Topology::parse(args.opt_or("topology", "flat")).unwrap_or_else(|e| {
        eprintln!("--topology: {e}");
        std::process::exit(2)
    });
    validate_remote_topology(&spec, &topology).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let partition = Partition::parse(args.opt_or("partition", "default")).unwrap_or_else(|e| {
        eprintln!("--partition: {e}");
        std::process::exit(2)
    });
    let policy =
        if args.has_flag("strict") { FaultPolicy::strict() } else { FaultPolicy::degrade() };
    // Robustness deadlines, in whole seconds (0 disarms): the handshake
    // deadline bounds `accept_sites`, the straggler deadline bounds every
    // per-frame aggregator read, and the recv timeout is shipped to the
    // sites so a dead aggregator can't wedge them either.
    let secs = |key: &str, default: usize| -> Option<Duration> {
        let s = args.usize_or(key, default);
        (s > 0).then(|| Duration::from_secs(s as u64))
    };
    let handshake = secs("handshake-timeout", 120);
    let straggler = secs("straggler-deadline", 300);
    let recv_timeout_ms = secs("recv-timeout", 600).map(|d| d.as_millis() as u32).unwrap_or(0);
    let scale_s = scale_arg;
    let scale = Scale::parse(&scale_s).unwrap_or(Scale::Default);
    let plan = ckpt_plan(args, &dataset, &scale_s);
    let _obs = obs_setup(args);
    let addr = args.opt_or("addr", "127.0.0.1:7009").to_string();
    let listener = TcpAgg::bind(&addr, spec.n_sites).unwrap_or_else(|e| {
        eprintln!("bind {addr}: {e}");
        std::process::exit(1)
    });
    let shown = listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.clone());
    match topology {
        Topology::Flat => println!(
            "serving {} on {dataset} ({scale:?}) at {shown}; waiting for {} x `dad join {shown}`",
            spec.algo.name(),
            spec.n_sites
        ),
        Topology::Tree { root_links } => println!(
            "serving {} on {dataset} ({scale:?}) at {shown}; waiting for {root_links} tree \
             link(s) covering {} site(s)",
            spec.algo.name(),
            spec.n_sites
        ),
    }
    let mut agg = match topology {
        Topology::Flat => listener.accept_sites_deadline(handshake),
        Topology::Tree { root_links } => {
            listener.accept_hellos_deadline(handshake).and_then(|pending| {
                if pending.n_links() != root_links {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!(
                            "tree topology expected {root_links} root links, got {} (did a \
                             relay's leaves connect here directly?)",
                            pending.n_links()
                        ),
                    ));
                }
                pending.welcome_all(0, spec.n_sites as u32)
            })
        }
    }
    .unwrap_or_else(|e| {
        eprintln!("handshake: {e}");
        std::process::exit(1)
    });
    agg.set_recv_timeout(straggler).unwrap_or_else(|e| {
        eprintln!("arming straggler deadline: {e}");
        std::process::exit(1)
    });
    let cfg = RemoteConfig {
        spec: spec.clone(),
        dataset: dataset.clone(),
        scale: scale_s,
        recv_timeout_ms,
        partition,
        resume: if resume.is_some() { ResumeMode::Checkpoint } else { ResumeMode::Fresh },
    };
    cfg.send(&mut agg).unwrap_or_else(|e| {
        eprintln!("config broadcast: {e}");
        std::process::exit(1)
    });
    let mut ledger = Ledger::new();
    let t0 = std::time::Instant::now();
    let task = build_task(&dataset, scale, spec.n_sites, spec.seed)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        })
        .repartition(partition, spec.seed);
    let log = match task {
        TrainTask::Dense { train_ds, test_ds, shards, model } => serve_training_checkpointed(
            &mut agg,
            &mut ledger,
            &spec,
            model,
            &train_ds,
            &shards,
            &test_ds,
            policy,
            &plan,
            resume,
            Some(&cfg),
        ),
        TrainTask::Seq { train_ds, test_ds, shards, model } => serve_training_checkpointed(
            &mut agg,
            &mut ledger,
            &spec,
            model,
            &train_ds,
            &shards,
            &test_ds,
            policy,
            &plan,
            resume,
            Some(&cfg),
        ),
        TrainTask::Tokens { train_ds, test_ds, shards, model } => serve_training_checkpointed(
            &mut agg,
            &mut ledger,
            &spec,
            model,
            &train_ds,
            &shards,
            &test_ds,
            policy,
            &plan,
            resume,
            Some(&cfg),
        ),
    }
    .unwrap_or_else(|e| {
        eprintln!("serve: {e}");
        std::process::exit(1)
    });
    if let Some(path) = &plan.save_path {
        println!("checkpoint written to {path}");
    }
    print_epochs(&log);
    maybe_write_csv(args, &log);
    println!(
        "done in {:.1}s wall; measured wire bytes: up {} down {}",
        t0.elapsed().as_secs_f32(),
        ledger.total_dir(Direction::SiteToAgg),
        ledger.total_dir(Direction::AggToSite),
    );
    for (tag, dir, bytes) in ledger.breakdown() {
        println!("  {dir:?} {tag:<12} {bytes:>12} B");
    }
    obs_finish();
}

fn cmd_join(args: &Args) {
    let addr =
        args.positional.get(1).map(|s| s.as_str()).unwrap_or("127.0.0.1:7009").to_string();
    // Retry the dial briefly: launcher scripts (and CI) start serve and
    // joins concurrently, so the listener may not be bound yet.
    let mut site = TcpSite::connect_retry(&addr, Duration::from_secs(10)).unwrap_or_else(|e| {
        eprintln!("connect {addr}: {e}");
        std::process::exit(1)
    });
    let site_id = site.site_id();
    let cfg = RemoteConfig::recv(&mut site).unwrap_or_else(|e| {
        eprintln!("config: {e}");
        std::process::exit(1)
    });
    // Arm the read deadline the aggregator asked for: if the aggregator
    // dies mid-run this process fails with a clean timeout, not a wedge.
    if cfg.recv_timeout_ms > 0 {
        site.set_recv_timeout(Some(Duration::from_millis(u64::from(cfg.recv_timeout_ms))))
            .unwrap_or_else(|e| {
                eprintln!("arming recv timeout: {e}");
                std::process::exit(1)
            });
    }
    let scale = Scale::parse(&cfg.scale).unwrap_or(Scale::Default);
    println!(
        "joined {addr} as site {site_id}/{}: {} on {} ({scale:?}){}",
        cfg.spec.n_sites,
        cfg.spec.algo.name(),
        cfg.dataset,
        match cfg.resume {
            ResumeMode::Fresh => "",
            ResumeMode::Checkpoint => " [resumed]",
            ResumeMode::Elastic => " [elastic]",
        }
    );
    let mut ledger = Ledger::new();
    let _obs = obs_setup(args);
    let t0 = std::time::Instant::now();
    let task = build_task(&cfg.dataset, scale, cfg.spec.n_sites, cfg.spec.seed)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        })
        .repartition(cfg.partition, cfg.spec.seed);
    let log = match task {
        TrainTask::Dense { train_ds, shards, model, .. } => join_training_resumable(
            &mut site,
            &mut ledger,
            &cfg.spec,
            model,
            &train_ds,
            &shards,
            site_id,
            cfg.resume,
        ),
        TrainTask::Seq { train_ds, shards, model, .. } => join_training_resumable(
            &mut site,
            &mut ledger,
            &cfg.spec,
            model,
            &train_ds,
            &shards,
            site_id,
            cfg.resume,
        ),
        TrainTask::Tokens { train_ds, shards, model, .. } => join_training_resumable(
            &mut site,
            &mut ledger,
            &cfg.spec,
            model,
            &train_ds,
            &shards,
            site_id,
            cfg.resume,
        ),
    }
    .unwrap_or_else(|e| {
        eprintln!("join: {e}");
        std::process::exit(1)
    });
    for e in &log.epochs {
        println!(
            "epoch {:>3}  loss {:.4}  up {:>10}B  down {:>10}B",
            e.epoch, e.train_loss, e.bytes_up, e.bytes_down
        );
    }
    maybe_write_csv(args, &log);
    println!(
        "done in {:.1}s; this site shipped {} B up, received {} B down",
        t0.elapsed().as_secs_f32(),
        ledger.total_dir(Direction::SiteToAgg),
        ledger.total_dir(Direction::AggToSite),
    );
    obs_finish();
}

/// `dad relay`: one interior level of an aggregation tree. Accepts
/// `--sites N` direct children (leaves and/or deeper relays), dials
/// `--parent` as a single N-leaf subtree, forwards the parent's config
/// verbatim, then runs the algorithm's aggregator half against the
/// children and its site half against the parent with each exchange
/// reduced in place (gather → associative combine → emit).
fn cmd_relay(args: &Args) {
    let parent_addr = args
        .opt("parent")
        .map(str::to_string)
        .or_else(|| args.positional.get(1).map(|s| s.to_string()))
        .unwrap_or_else(|| {
            eprintln!(
                "usage: dad relay --parent HOST:PORT --sites N [--addr HOST:PORT] [--strict]"
            );
            std::process::exit(2)
        });
    let n_children = args.usize_or("sites", 0);
    if n_children == 0 {
        eprintln!("relay: --sites N (direct children of this relay) is required and must be > 0");
        std::process::exit(2);
    }
    let policy =
        if args.has_flag("strict") { FaultPolicy::strict() } else { FaultPolicy::degrade() };
    let secs = |key: &str, default: usize| -> Option<Duration> {
        let s = args.usize_or(key, default);
        (s > 0).then(|| Duration::from_secs(s as u64))
    };
    let handshake = secs("handshake-timeout", 120);
    let straggler = secs("straggler-deadline", 300);
    let addr = args.opt_or("addr", "127.0.0.1:7011").to_string();
    let listener = TcpAgg::bind(&addr, n_children).unwrap_or_else(|e| {
        eprintln!("bind {addr}: {e}");
        std::process::exit(1)
    });
    let shown = listener.local_addr().map(|a| a.to_string()).unwrap_or_else(|_| addr.clone());
    println!(
        "relay at {shown}: waiting for {n_children} child link(s), then dialing {parent_addr}"
    );
    // Children first: the hello to the parent declares this subtree's leaf
    // count, which is only known once every child has said hello.
    let pending = listener.accept_hellos_deadline(handshake).unwrap_or_else(|e| {
        eprintln!("child handshake: {e}");
        std::process::exit(1)
    });
    let total = pending.total_leaves();
    let mut parent =
        TcpSite::connect_retry_with_leaves(&parent_addr, total, Duration::from_secs(10))
            .unwrap_or_else(|e| {
                eprintln!("connect {parent_addr}: {e}");
                std::process::exit(1)
            });
    // The parent's welcome assigns this subtree a contiguous global leaf
    // range; re-welcome the children inside it so every leaf id is
    // fabric-unique and the fabric-wide site count reaches every site.
    let leaf_start = parent.site_id() as u32;
    let global_total = parent.n_sites() as u32;
    let mut children = pending.welcome_all(leaf_start, global_total).unwrap_or_else(|e| {
        eprintln!("welcoming children: {e}");
        std::process::exit(1)
    });
    children.set_recv_timeout(straggler).unwrap_or_else(|e| {
        eprintln!("arming straggler deadline: {e}");
        std::process::exit(1)
    });
    let cfg = RemoteConfig::recv_forward(&mut parent, &mut children).unwrap_or_else(|e| {
        eprintln!("config: {e}");
        std::process::exit(1)
    });
    if cfg.recv_timeout_ms > 0 {
        parent
            .set_recv_timeout(Some(Duration::from_millis(u64::from(cfg.recv_timeout_ms))))
            .unwrap_or_else(|e| {
                eprintln!("arming recv timeout: {e}");
                std::process::exit(1)
            });
    }
    let scale = Scale::parse(&cfg.scale).unwrap_or(Scale::Default);
    println!(
        "relaying leaves {leaf_start}..{} of {}: {} on {} ({scale:?})",
        leaf_start + total,
        cfg.spec.n_sites,
        cfg.spec.algo.name(),
        cfg.dataset,
    );
    let mut parent_ledger = Ledger::new();
    let mut child_ledger = Ledger::new();
    let _obs = obs_setup(args);
    let t0 = std::time::Instant::now();
    let task = build_task(&cfg.dataset, scale, cfg.spec.n_sites, cfg.spec.seed)
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        })
        .repartition(cfg.partition, cfg.spec.seed);
    match task {
        TrainTask::Dense { shards, model, .. } => relay_training(
            &mut parent,
            &mut children,
            &mut parent_ledger,
            &mut child_ledger,
            &cfg,
            &shards,
            policy,
            model,
        ),
        TrainTask::Seq { shards, model, .. } => relay_training(
            &mut parent,
            &mut children,
            &mut parent_ledger,
            &mut child_ledger,
            &cfg,
            &shards,
            policy,
            model,
        ),
        TrainTask::Tokens { shards, model, .. } => relay_training(
            &mut parent,
            &mut children,
            &mut parent_ledger,
            &mut child_ledger,
            &cfg,
            &shards,
            policy,
            model,
        ),
    }
    .unwrap_or_else(|e| {
        eprintln!("relay: {e}");
        std::process::exit(1)
    });
    println!(
        "relay done in {:.1}s; uplink {} B up / {} B down; subtree {} B up / {} B down",
        t0.elapsed().as_secs_f32(),
        parent_ledger.total_dir(Direction::SiteToAgg),
        parent_ledger.total_dir(Direction::AggToSite),
        child_ledger.total_dir(Direction::SiteToAgg),
        child_ledger.total_dir(Direction::AggToSite),
    );
    obs_finish();
}

/// `dad chaos`: run one deterministic fault-injection recipe end-to-end
/// over real TCP sockets (aggregator + site threads in this process) and
/// check its convergence-or-clean-failure expectation.
///
/// Exit codes: 0 = the run completed (converged or degraded, metrics
/// printed); 1 = the run failed cleanly (error printed — the *expected*
/// outcome for `fail:` recipes, which CI asserts as a nonzero exit);
/// 2 = bad usage; 3 = the run's outcome contradicted the recipe's
/// expectation.
fn cmd_chaos(args: &Args) {
    if args.has_flag("list") {
        println!("{:<22} {:<28} summary", "recipe", "expectation");
        for r in named_recipes() {
            println!("{:<22} {:<28} {}", r.name, r.expect.name(), r.summary);
        }
        return;
    }
    let recipe = if let Some(path) = args.opt("recipe-file") {
        let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
            eprintln!("{path}: {e}");
            std::process::exit(2)
        });
        Recipe::from_toml(&text).unwrap_or_else(|e| {
            eprintln!("{path}: {e}");
            std::process::exit(2)
        })
    } else if let Some(name) = args.opt("recipe") {
        find_recipe(name).unwrap_or_else(|| {
            eprintln!("unknown recipe {name:?}; `dad chaos --list` shows the registry");
            std::process::exit(2)
        })
    } else {
        eprintln!(
            "usage: dad chaos --list | --recipe NAME [--strict] [--csv PATH] | --recipe-file PATH"
        );
        std::process::exit(2)
    };
    let strict = args.has_flag("strict");
    println!(
        "chaos recipe {} ({}{}): {}",
        recipe.name,
        recipe.expect.name(),
        if strict { ", --strict" } else { "" },
        recipe.summary
    );
    let _obs = obs_setup(args);
    let t0 = std::time::Instant::now();
    let report = run_recipe(&recipe, strict);
    for (site, err) in &report.site_errors {
        if *site == usize::MAX {
            eprintln!("[site] pre-handshake failure: {err}");
        } else {
            eprintln!("[site {site}] {err}");
        }
    }
    if let Some(log) = &report.log {
        print_epochs(log);
        maybe_write_csv(args, log);
    }
    println!("[{} finished in {:.1}s]", recipe.name, t0.elapsed().as_secs_f32());
    let mut code = 0;
    if let Some(e) = &report.error {
        eprintln!("chaos run failed: {e}");
        code = 1;
    }
    // --strict deliberately changes the outcome (degrade recipes become
    // clean failures), so the recipe's own expectation only binds the
    // default policy.
    if !strict {
        match report.check(&recipe) {
            Ok(()) => println!("[expectation met: {}]", recipe.expect.name()),
            Err(msg) => {
                eprintln!("[expectation mismatch] {msg}");
                code = 3;
            }
        }
    }
    obs_finish();
    std::process::exit(code);
}

/// `dad infer`: either serve batched predictions from a checkpoint
/// (`--serve ADDR --checkpoint PATH`) or benchmark a running server
/// (`--bench --addr ADDR`), writing the latency report to
/// `BENCH_serving.json` (or `--json PATH`).
fn cmd_infer(args: &Args) {
    if args.has_flag("bench") || args.opt("addr").is_some() {
        let addr = args.opt_or("addr", "127.0.0.1:7010").to_string();
        let requests = args.usize_or("requests", 200);
        let concurrency = args.usize_or("concurrency", 4);
        let seed = args.usize_or("seed", 13) as u64;
        println!("bench: {requests} requests x {concurrency} client(s) against {addr}");
        let report = run_bench(&addr, requests, concurrency, seed).unwrap_or_else(|e| {
            eprintln!("bench: {e}");
            std::process::exit(1)
        });
        println!(
            "{} model: p50 {:.3} ms  p99 {:.3} ms  {:.1} req/s over {:.2}s",
            report.model, report.p50_ms, report.p99_ms, report.qps, report.wall_s
        );
        let path = args.opt_or("json", "BENCH_serving.json");
        std::fs::write(path, report.to_json()).unwrap_or_else(|e| {
            eprintln!("writing {path}: {e}");
            std::process::exit(1)
        });
        println!("report written to {path}");
        if args.has_flag("shutdown") {
            InferClient::connect(&addr)
                .and_then(InferClient::shutdown)
                .unwrap_or_else(|e| {
                    eprintln!("shutdown: {e}");
                    std::process::exit(1)
                });
            println!("server asked to shut down");
        }
        return;
    }
    let ckpt_path = args.opt("checkpoint").unwrap_or_else(|| {
        eprintln!(
            "usage: dad infer --serve HOST:PORT --checkpoint PATH [--max-batch N] \
             [--batch-window-ms MS]\n       dad infer --bench --addr HOST:PORT \
             [--requests N] [--concurrency C] [--json PATH] [--shutdown]"
        );
        std::process::exit(2)
    });
    let ck = Checkpoint::load(Path::new(ckpt_path)).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1)
    });
    let addr = args.opt_or("serve", "127.0.0.1:7010");
    let _obs = obs_setup(args);
    let opts = InferOpts {
        max_batch: args.usize_or("max-batch", 64).max(1),
        window: Duration::from_millis(args.usize_or("batch-window-ms", 2) as u64),
    };
    let server = InferServer::bind(addr, ck, opts).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1)
    });
    let info = server.info().clone();
    let shown = server
        .local_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| addr.to_string());
    println!(
        "serving {} checkpoint ({} @ {}) at {shown}; stop with \
         `dad infer --bench --addr {shown} --shutdown`",
        info.model, info.dataset, info.scale
    );
    let served = server.run().unwrap_or_else(|e| {
        eprintln!("infer: {e}");
        std::process::exit(1)
    });
    println!("served {served} request(s)");
    obs_finish();
}

//! AUC and accuracy. The paper plots macro-averaged one-vs-rest test AUC;
//! we compute exact (rank-based) ROC AUC per class and average over classes
//! present in the test set.

use crate::tensor::Matrix;

/// Exact binary ROC AUC from scores via the rank statistic (ties averaged).
pub fn binary_auc(scores: &[f32], is_pos: &[bool]) -> Option<f32> {
    let n_pos = is_pos.iter().filter(|&&p| p).count();
    let n_neg = is_pos.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return None;
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    // Average ranks over ties.
    let mut rank_sum_pos = 0.0f64;
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0; // 1-based
        for &k in &idx[i..=j] {
            if is_pos[k] {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let auc =
        (rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0) / (n_pos as f64 * n_neg as f64);
    Some(auc as f32)
}

/// Macro-averaged one-vs-rest AUC. `scores` is (N, C) class probabilities,
/// `labels` the true classes. Classes absent from the labels are skipped.
pub fn multiclass_auc(scores: &Matrix, labels: &[usize]) -> f32 {
    let c = scores.cols();
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for class in 0..c {
        let col: Vec<f32> = (0..scores.rows()).map(|i| scores[(i, class)]).collect();
        let pos: Vec<bool> = labels.iter().map(|&l| l == class).collect();
        if let Some(a) = binary_auc(&col, &pos) {
            sum += a as f64;
            count += 1;
        }
    }
    if count == 0 {
        0.5
    } else {
        (sum / count as f64) as f32
    }
}

/// Summed negative log-likelihood of the targets: `Σ -ln p[target]`.
///
/// The chunk-accumulable core of [`perplexity`]: evaluation loops sum it
/// over score chunks without ever stacking them. Probabilities are
/// floored at 1e-12 so a confidently-wrong model yields a large finite
/// value, not inf.
pub fn nll_sum(probs: &Matrix, targets: &[usize]) -> f64 {
    let mut nll = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        nll -= (probs[(i, t)].max(1e-12) as f64).ln();
    }
    nll
}

/// Perplexity from class probabilities: `exp(mean -ln p[target])`.
///
/// `probs` is `(N, C)` softmax probabilities (one row per prediction),
/// `targets` the true class per row — for the LM workload, one row per
/// token position and `C = vocab`.
pub fn perplexity(probs: &Matrix, targets: &[usize]) -> f32 {
    if targets.is_empty() {
        return f32::NAN;
    }
    (nll_sum(probs, targets) / targets.len() as f64).exp() as f32
}

/// Number of rows whose argmax matches the label (the chunk-accumulable
/// core of [`accuracy`]).
pub fn correct_count(scores: &Matrix, labels: &[usize]) -> usize {
    let mut correct = 0usize;
    for (i, &l) in labels.iter().enumerate() {
        let row = scores.row(i);
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(j, _)| j)
            .unwrap_or(0);
        if pred == l {
            correct += 1;
        }
    }
    correct
}

/// Top-1 accuracy.
pub fn accuracy(scores: &Matrix, labels: &[usize]) -> f32 {
    correct_count(scores, labels) as f32 / labels.len().max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_is_one() {
        let scores = vec![0.1, 0.2, 0.8, 0.9];
        let pos = vec![false, false, true, true];
        assert_eq!(binary_auc(&scores, &pos), Some(1.0));
    }

    #[test]
    fn reversed_is_zero() {
        let scores = vec![0.9, 0.8, 0.2, 0.1];
        let pos = vec![false, false, true, true];
        assert_eq!(binary_auc(&scores, &pos), Some(0.0));
    }

    #[test]
    fn random_is_half() {
        // Constant scores => all ties => AUC 0.5 exactly.
        let scores = vec![0.5; 10];
        let pos: Vec<bool> = (0..10).map(|i| i % 2 == 0).collect();
        let a = binary_auc(&scores, &pos).unwrap();
        assert!((a - 0.5).abs() < 1e-6);
    }

    #[test]
    fn degenerate_returns_none() {
        assert_eq!(binary_auc(&[0.1, 0.2], &[true, true]), None);
    }

    #[test]
    fn matches_pair_counting() {
        // Oracle: AUC = P(score_pos > score_neg) + 0.5 P(equal).
        let scores = vec![0.3, 0.7, 0.7, 0.1, 0.9, 0.4];
        let pos = vec![true, false, true, false, true, false];
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for i in 0..6 {
            for j in 0..6 {
                if pos[i] && !pos[j] {
                    den += 1.0;
                    if scores[i] > scores[j] {
                        num += 1.0;
                    } else if scores[i] == scores[j] {
                        num += 0.5;
                    }
                }
            }
        }
        let want = (num / den) as f32;
        let got = binary_auc(&scores, &pos).unwrap();
        assert!((got - want).abs() < 1e-6, "got {got} want {want}");
    }

    #[test]
    fn perplexity_matches_hand_computation() {
        // Rows: p[target] = 0.5 and 0.25 -> mean nll = (ln2 + ln4)/2,
        // ppl = exp(1.5 ln 2) = 2^1.5.
        let probs = Matrix::from_vec(2, 2, vec![0.5, 0.5, 0.75, 0.25]);
        let ppl = perplexity(&probs, &[0, 1]);
        assert!((ppl - 2f32.powf(1.5)).abs() < 1e-5, "ppl {ppl}");
        // A uniform model over C classes has perplexity C.
        let uniform = Matrix::filled(4, 8, 1.0 / 8.0);
        let ppl_u = perplexity(&uniform, &[0, 3, 5, 7]);
        assert!((ppl_u - 8.0).abs() < 1e-4, "uniform ppl {ppl_u}");
        // Zero probability is floored, not inf.
        let bad = Matrix::from_vec(1, 2, vec![0.0, 1.0]);
        assert!(perplexity(&bad, &[0]).is_finite());
        assert!(perplexity(&bad, &[]).is_nan());
    }

    #[test]
    fn multiclass_and_accuracy() {
        // 3-class toy with clearly correct argmax.
        let scores = Matrix::from_vec(
            3,
            3,
            vec![0.8, 0.1, 0.1, 0.1, 0.8, 0.1, 0.1, 0.1, 0.8],
        );
        let labels = vec![0, 1, 2];
        assert_eq!(accuracy(&scores, &labels), 1.0);
        assert!((multiclass_auc(&scores, &labels) - 1.0).abs() < 1e-6);
    }
}

//! Tiny CSV emitter for experiment outputs (figures are regenerated as CSV
//! series; EXPERIMENTS.md references the files under results/).

use std::io::Write;
use std::path::Path;

/// Buffered CSV writer with a fixed header.
pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    n_cols: usize,
}

impl CsvWriter {
    /// Create/truncate `path` (directories made as needed), write `header`.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, n_cols: header.len() })
    }

    /// Write one row (width-checked against the header).
    pub fn row(&mut self, values: &[String]) -> std::io::Result<()> {
        assert_eq!(values.len(), self.n_cols, "CSV row width mismatch");
        writeln!(self.file, "{}", values.join(","))
    }

    /// Write one row of f32 values.
    pub fn row_f32(&mut self, values: &[f32]) -> std::io::Result<()> {
        self.row(&values.iter().map(|v| format!("{v}")).collect::<Vec<_>>())
    }

    /// Flush the underlying buffer.
    pub fn flush(&mut self) -> std::io::Result<()> {
        self.file.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("dad_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "2".into()]).unwrap();
            w.row_f32(&[0.5, 1.5]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n0.5,1.5\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let dir = std::env::temp_dir().join("dad_csv_test2");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(path, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }
}

//! Evaluation metrics and experiment telemetry: multiclass OvR AUC (the
//! paper's headline metric), accuracy, and CSV emission for the figures.

pub mod auc;
pub mod csv;

pub use auc::{accuracy, multiclass_auc};
pub use csv::CsvWriter;

//! Evaluation metrics and experiment telemetry: multiclass OvR AUC (the
//! paper's headline metric), accuracy, LM perplexity, and CSV emission
//! for the figures.

pub mod auc;
pub mod csv;

pub use auc::{accuracy, correct_count, multiclass_auc, nll_sum, perplexity};
pub use csv::CsvWriter;

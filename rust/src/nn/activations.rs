//! Activation functions with derivative-from-output — the analytic identity
//! phi'(z) = f(phi(z)) that lets edAD continue backpropagation at the
//! aggregated level without communicating deltas (paper section 3.3).

use crate::tensor::Matrix;

/// Activation tag, shared with the Python kernels (kernels/ref.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    /// max(z, 0).
    Relu,
    /// 1 / (1 + e^-z).
    Sigmoid,
    /// tanh(z).
    Tanh,
    /// Identity (output layers).
    Linear,
}

impl Activation {
    /// Canonical lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Sigmoid => "sigmoid",
            Activation::Tanh => "tanh",
            Activation::Linear => "linear",
        }
    }

    /// phi(z) for one scalar.
    #[inline]
    pub fn apply_scalar(self, z: f32) -> f32 {
        match self {
            Activation::Relu => z.max(0.0),
            Activation::Sigmoid => sigmoid(z),
            Activation::Tanh => z.tanh(),
            Activation::Linear => z,
        }
    }

    /// phi'(z) expressed through a = phi(z).
    #[inline]
    pub fn deriv_from_output_scalar(self, a: f32) -> f32 {
        match self {
            Activation::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Sigmoid => a * (1.0 - a),
            Activation::Tanh => 1.0 - a * a,
            Activation::Linear => 1.0,
        }
    }

    /// phi applied elementwise in place.
    pub fn apply(self, z: &mut Matrix) {
        if self == Activation::Linear {
            return;
        }
        z.map_inplace(|v| self.apply_scalar(v));
    }

    /// Elementwise phi' evaluated from the output activations.
    pub fn deriv_from_output(self, a: &Matrix) -> Matrix {
        a.map(|v| self.deriv_from_output_scalar(v))
    }

    /// d ⊙ phi'(a) in place — the Hadamard of eq. (3)/(5).
    pub fn mask_delta_inplace(self, d: &mut Matrix, a: &Matrix) {
        assert_eq!(d.shape(), a.shape());
        let ad = a.data();
        for (dv, &av) in d.data_mut().iter_mut().zip(ad) {
            *dv *= self.deriv_from_output_scalar(av);
        }
    }
}

/// Numerically-stable logistic sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Row-wise softmax (numerically stable).
pub fn softmax_rows(z: &Matrix) -> Matrix {
    let mut out = z.clone();
    for i in 0..out.rows() {
        let row = out.row_mut(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn deriv_from_output_matches_finite_difference() {
        // phi'(z) via output must equal (phi(z+e)-phi(z-e))/2e.
        let eps = 1e-3f32;
        for act in [Activation::Relu, Activation::Sigmoid, Activation::Tanh, Activation::Linear] {
            for i in -20..=20 {
                let z = i as f32 * 0.17 + 0.05; // avoid the ReLU kink at 0
                let a = act.apply_scalar(z);
                let fd = (act.apply_scalar(z + eps) - act.apply_scalar(z - eps)) / (2.0 * eps);
                let an = act.deriv_from_output_scalar(a);
                assert!((fd - an).abs() < 2e-3, "{act:?} z={z} fd={fd} an={an}");
            }
        }
    }

    #[test]
    fn sigmoid_stable_extremes() {
        assert!(sigmoid(100.0) <= 1.0 && sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(-100.0) < 1e-4);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(1);
        let z = Matrix::randn(5, 7, 3.0, &mut rng);
        let p = softmax_rows(&z);
        for i in 0..5 {
            let s: f32 = p.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(p.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_shift_invariant() {
        let z = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let z2 = z.map(|v| v + 1000.0);
        assert!(softmax_rows(&z).max_abs_diff(&softmax_rows(&z2)) < 1e-6);
    }

    #[test]
    fn mask_delta_inplace_matches_hadamard() {
        let mut rng = Rng::new(2);
        let a = Matrix::randn(4, 6, 1.0, &mut rng).map(|v| v.tanh());
        let d0 = Matrix::randn(4, 6, 1.0, &mut rng);
        let mut d = d0.clone();
        Activation::Tanh.mask_delta_inplace(&mut d, &a);
        let want = d0.hadamard(&Activation::Tanh.deriv_from_output(&a));
        assert!(d.max_abs_diff(&want) < 1e-6);
    }
}

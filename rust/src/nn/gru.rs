//! GRU sequence classifier — the paper's UEA architecture: a GRU cell
//! (hidden 64) feeding a fully-connected classifier (512 -> 256 -> C),
//! with BPTT statistics stacked over batch AND time (paper section 3.5):
//! for each recurrent weight, A and Δ stacks have T*N rows, so rank-dAD
//! still ships O(r*h) numbers per layer.
//!
//! Gate math (PyTorch layout [r | z | n]):
//!     r_t = σ(x_t W_ir + h W_hr + b_.r)
//!     z_t = σ(x_t W_iz + h W_hz + b_.z)
//!     n_t = tanh(x_t W_in + b_in + r_t ⊙ (h W_hn + b_hn))
//!     h_t = (1 - z_t) ⊙ n_t + z_t ⊙ h_{t-1}
//!
//! Parameter layout: [W_i (c_in,3h), b_i, W_h (h,3h), b_h, classifier...].
//! Stats entries: [W_i (Δ = [δr|δz|δn]), W_h (Δ = [δr|δz|δn⊙r]), classifier
//! layers...]. edAD aux = per-site t-major stacks of [r|z|n|s] (s = h W_hn
//! + b_hn), which together with the A-stacks let the aggregated deltas be
//! recomputed from Δ_L alone.

use crate::nn::activations::{sigmoid, Activation};
use crate::nn::init::xavier_uniform;
use crate::nn::mlp::{add_bias, Mlp};
use crate::nn::model::{Batch, DistModel};
use crate::nn::stats::{LocalStats, StatsEntry};
use crate::tensor::{matmul_into, matmul_nt, matmul_nt_into, Matrix, Rng, Workspace};

/// GRU + MLP-classifier sequence model.
#[derive(Clone)]
pub struct GruClassifier {
    /// Input channels per timestep.
    pub c_in: usize,
    /// GRU hidden width.
    pub hidden: usize,
    w_i: Matrix, // (c_in, 3h)
    b_i: Matrix, // (1, 3h)
    w_h: Matrix, // (h, 3h)
    b_h: Matrix, // (1, 3h)
    /// Readout MLP over the final hidden state.
    pub classifier: Mlp,
}

/// Saved forward state for one timestep.
struct StepState {
    h_prev: Matrix,
    r: Matrix,
    z: Matrix,
    n: Matrix,
    s: Matrix, // h_prev W_hn + b_hn (pre-r-Hadamard candidate input)
}

impl GruClassifier {
    /// The paper's UEA configuration: hidden 64, classifier 512 -> 256 -> C.
    pub fn paper_uea(c_in: usize, classes: usize, rng: &mut Rng) -> Self {
        GruClassifier::new(c_in, 64, &[512, 256], classes, rng)
    }

    /// Xavier-initialized GRU with an MLP readout of widths `fc_dims`;
    /// deterministic in `rng` (sites share the seed).
    pub fn new(
        c_in: usize,
        hidden: usize,
        fc_dims: &[usize],
        classes: usize,
        rng: &mut Rng,
    ) -> Self {
        let w_i = xavier_uniform(c_in, 3 * hidden, rng);
        let w_h = xavier_uniform(hidden, 3 * hidden, rng);
        let mut dims = vec![hidden];
        dims.extend_from_slice(fc_dims);
        dims.push(classes);
        let acts = vec![Activation::Relu; dims.len() - 2];
        let classifier = Mlp::new(&dims, &acts, rng);
        GruClassifier {
            c_in,
            hidden,
            w_i,
            b_i: Matrix::zeros(1, 3 * hidden),
            w_h,
            b_h: Matrix::zeros(1, 3 * hidden),
            classifier,
        }
    }

    /// One GRU step; consumes `h_prev` (it is saved in the state without a
    /// clone) and draws every buffer from `arena`.
    fn step_ws(&self, x_t: &Matrix, h_prev: Matrix, arena: &mut Workspace) -> (Matrix, StepState) {
        let h = self.hidden;
        let n_rows = x_t.rows();
        let mut gi = arena.take(n_rows, 3 * h);
        matmul_into(x_t, &self.w_i, &mut gi);
        add_bias(&mut gi, &self.b_i);
        let mut gh = arena.take(n_rows, 3 * h);
        matmul_into(&h_prev, &self.w_h, &mut gh);
        add_bias(&mut gh, &self.b_h);
        let mut r = arena.take(n_rows, h);
        let mut z = arena.take(n_rows, h);
        let mut n = arena.take(n_rows, h);
        let mut s = arena.take(n_rows, h);
        let mut h_t = arena.take(n_rows, h);
        for i in 0..n_rows {
            let gi_row = gi.row(i);
            let gh_row = gh.row(i);
            let hp = h_prev.row(i);
            for j in 0..h {
                let rv = sigmoid(gi_row[j] + gh_row[j]);
                let zv = sigmoid(gi_row[h + j] + gh_row[h + j]);
                let sv = gh_row[2 * h + j];
                let nv = (gi_row[2 * h + j] + rv * sv).tanh();
                r[(i, j)] = rv;
                z[(i, j)] = zv;
                s[(i, j)] = sv;
                n[(i, j)] = nv;
                h_t[(i, j)] = (1.0 - zv) * nv + zv * hp[j];
            }
        }
        arena.recycle(gi);
        arena.recycle(gh);
        (h_t, StepState { h_prev, r, z, n, s })
    }

    /// Full forward; returns (h_T, per-step states).
    fn forward_seq(&self, xs: &[Matrix]) -> (Matrix, Vec<StepState>) {
        self.forward_seq_ws(xs, &mut Workspace::new())
    }

    fn forward_seq_ws(&self, xs: &[Matrix], arena: &mut Workspace) -> (Matrix, Vec<StepState>) {
        let n_rows = xs[0].rows();
        let mut h = arena.take(n_rows, self.hidden);
        let mut states = Vec::with_capacity(xs.len());
        for x_t in xs {
            let (h_t, st) = self.step_ws(x_t, h, arena);
            states.push(st);
            h = h_t;
        }
        (h, states)
    }

    /// Gate backward for one timestep. Returns (δ_i stack row block,
    /// δ_h stack row block, δh_{t-1}).
    fn step_backward(&self, st: &StepState, dh: &Matrix) -> (Matrix, Matrix, Matrix) {
        self.step_backward_ws(st, dh, &mut Workspace::new())
    }

    fn step_backward_ws(
        &self,
        st: &StepState,
        dh: &Matrix,
        arena: &mut Workspace,
    ) -> (Matrix, Matrix, Matrix) {
        let h = self.hidden;
        let n_rows = dh.rows();
        let mut d_i = arena.take(n_rows, 3 * h); // [δr | δz | δn]
        let mut d_h = arena.take(n_rows, 3 * h); // [δr | δz | δn⊙r]
        for i in 0..n_rows {
            for j in 0..h {
                let (rv, zv, nv, sv) = (st.r[(i, j)], st.z[(i, j)], st.n[(i, j)], st.s[(i, j)]);
                let dhv = dh[(i, j)];
                let dz = dhv * (st.h_prev[(i, j)] - nv) * zv * (1.0 - zv);
                let dn = dhv * (1.0 - zv) * (1.0 - nv * nv);
                let dr = dn * sv * rv * (1.0 - rv);
                d_i[(i, j)] = dr;
                d_i[(i, h + j)] = dz;
                d_i[(i, 2 * h + j)] = dn;
                d_h[(i, j)] = dr;
                d_h[(i, h + j)] = dz;
                d_h[(i, 2 * h + j)] = dn * rv;
            }
        }
        // δh_{t-1} = δh ⊙ z + d_h W_hᵀ
        let mut dh_prev = arena.take(n_rows, h);
        matmul_nt_into(&d_h, &self.w_h, &mut dh_prev);
        for i in 0..n_rows {
            for j in 0..h {
                dh_prev[(i, j)] += dh[(i, j)] * st.z[(i, j)];
            }
        }
        (d_i, d_h, dh_prev)
    }

    /// BPTT from states + classifier output delta; writes the t-major
    /// stacks (δ_i stack, δ_h stack) directly into arena-backed matrices —
    /// no per-t block list, no vertcat. Consumes `dh_last`.
    fn bptt_ws(
        &self,
        states: &[StepState],
        dh_last: Matrix,
        arena: &mut Workspace,
    ) -> (Matrix, Matrix) {
        let t_len = states.len();
        let n_rows = dh_last.rows();
        let h3 = 3 * self.hidden;
        let mut d_i_stack = arena.take(t_len * n_rows, h3);
        let mut d_h_stack = arena.take(t_len * n_rows, h3);
        let mut dh = dh_last;
        for t in (0..t_len).rev() {
            let (d_i, d_h, dh_prev) = self.step_backward_ws(&states[t], &dh, arena);
            copy_rows(&mut d_i_stack, t * n_rows, &d_i);
            copy_rows(&mut d_h_stack, t * n_rows, &d_h);
            arena.recycle(d_i);
            arena.recycle(d_h);
            arena.recycle(std::mem::replace(&mut dh, dh_prev));
        }
        arena.recycle(dh);
        (d_i_stack, d_h_stack)
    }

    /// Number of classifier dense layers.
    fn fc_layers(&self) -> usize {
        self.classifier.n_layers()
    }
}

impl DistModel for GruClassifier {
    fn param_shapes(&self) -> Vec<(usize, usize)> {
        let mut shapes = vec![self.w_i.shape(), self.b_i.shape(), self.w_h.shape(), self.b_h.shape()];
        shapes.extend(self.classifier.param_shapes());
        shapes
    }

    fn params(&self) -> Vec<&Matrix> {
        let mut ps = vec![&self.w_i, &self.b_i, &self.w_h, &self.b_h];
        ps.extend(self.classifier.params());
        ps
    }

    fn params_mut(&mut self) -> Vec<&mut Matrix> {
        let mut ps: Vec<&mut Matrix> =
            vec![&mut self.w_i, &mut self.b_i, &mut self.w_h, &mut self.b_h];
        ps.extend(self.classifier.params_mut());
        ps
    }

    fn local_stats_into(&self, batch: &Batch, arena: &mut Workspace, out: &mut LocalStats) {
        let (xs, y) = match batch {
            Batch::Seq { xs, y } => (xs, y),
            _ => panic!("GruClassifier consumes sequence batches"),
        };
        out.recycle_into(arena);
        let n_rows = xs[0].rows();
        let t_len = xs.len();
        let h = self.hidden;
        let (h_t, mut states) = self.forward_seq_ws(xs, arena);
        // Classifier forward/backward on h_T.
        let cls_batch = Batch::Dense { x: h_t, y: y.clone() };
        let mut cls_stats = self.classifier.local_stats_ws(&cls_batch, arena);
        // Delta w.r.t. classifier input = Δ_c1 W_c1ᵀ (no activation on h_T).
        let mut dh_last = arena.take(n_rows, h);
        matmul_nt_into(&cls_stats.entries[0].d, self.classifier.weight(0), &mut dh_last);
        let (d_i_stack, d_h_stack) = self.bptt_ws(&states, dh_last, arena);
        // A-stacks (t-major), written straight into arena matrices.
        let mut x_stack = arena.take(t_len * n_rows, self.c_in);
        for (t, x_t) in xs.iter().enumerate() {
            copy_rows(&mut x_stack, t * n_rows, x_t);
        }
        let mut hp_stack = arena.take(t_len * n_rows, h);
        for (t, st) in states.iter().enumerate() {
            copy_rows(&mut hp_stack, t * n_rows, &st.h_prev);
        }
        // edAD aux: [r|z|n|s] stack (t-major), one matrix.
        let mut aux = arena.take(t_len * n_rows, 4 * h);
        for (t, st) in states.iter().enumerate() {
            for i in 0..n_rows {
                let row = aux.row_mut(t * n_rows + i);
                row[..h].copy_from_slice(st.r.row(i));
                row[h..2 * h].copy_from_slice(st.z.row(i));
                row[2 * h..3 * h].copy_from_slice(st.n.row(i));
                row[3 * h..4 * h].copy_from_slice(st.s.row(i));
            }
        }
        // The forward tape is fully consumed; hand its buffers back.
        for st in states.drain(..) {
            arena.recycle(st.h_prev);
            arena.recycle(st.r);
            arena.recycle(st.z);
            arena.recycle(st.n);
            arena.recycle(st.s);
        }
        if let Batch::Dense { x, .. } = cls_batch {
            arena.recycle(x); // h_T
        }

        out.entries.push(StatsEntry { w_idx: 0, b_idx: Some(1), a: x_stack, d: d_i_stack });
        out.entries.push(StatsEntry { w_idx: 2, b_idx: Some(3), a: hp_stack, d: d_h_stack });
        // Shift classifier entries past the 4 GRU params.
        for e in cls_stats.entries.drain(..) {
            out.entries.push(StatsEntry {
                w_idx: e.w_idx + 4,
                b_idx: e.b_idx.map(|b| b + 4),
                a: e.a,
                d: e.d,
            });
        }
        out.aux.push(aux);
        out.loss = cls_stats.loss;
    }

    fn predict(&self, batch: &Batch) -> Matrix {
        let (xs, y) = match batch {
            Batch::Seq { xs, y } => (xs, y),
            _ => panic!("GruClassifier consumes sequence batches"),
        };
        let (h_t, _) = self.forward_seq(xs);
        self.classifier.predict(&Batch::Dense { x: h_t, y: y.clone() })
    }

    fn edad_recompute(
        &self,
        a_hats: &[Matrix],
        aux: &[Matrix],
        delta_out: &Matrix,
        site_rows: &[usize],
    ) -> Option<Vec<StatsEntry>> {
        // a_hats: [x_stack, hp_stack, cls A_0 (= h_T), cls A_1, ...]
        // aux:    [rzns stack]
        // Row-independence of the recurrence means recomputation on the
        // site-major concatenated stacks is exact as long as per-t slices
        // are taken per site block; here stacks arrive already vertcat'd
        // over sites with t-major blocks inside, and batch rows never mix —
        // so we recover T from stack heights and process per site block.
        let h = self.hidden;
        let fc = self.fc_layers();
        assert_eq!(a_hats.len(), 2 + fc);
        let x_stack = &a_hats[0];
        let hp_stack = &a_hats[1];
        let rzns = &aux[0];
        let n_total = delta_out.rows(); // total examples across sites
        let tn = x_stack.rows();
        if n_total == 0 || tn % n_total != 0 {
            return None;
        }
        let t_len = tn / n_total;

        // Classifier deltas from aggregated activations (MLP recurrence).
        let cls_a_hats: Vec<Matrix> = a_hats[2..].to_vec();
        let cls_entries = self.classifier.edad_recompute(&cls_a_hats, &[], delta_out, site_rows)?;
        let dh_last = matmul_nt(&cls_entries[0].d, self.classifier.weight(0));

        // Rebuild per-t states from the stacks. Stacks are t-major over the
        // *whole* concatenated batch only if every site contributed equal
        // rows per t — which holds because concat_stats vertcats per-site
        // t-major stacks and every row is independent. We process per-t
        // slices of size n_total by gathering each site's t-block; with
        // equal site batches the layout [s][t][n] maps t-slices to strided
        // row gathers.
        // To stay layout-exact for ANY site split we instead recompute per
        // "site block": each block of T*n_s consecutive rows in x_stack
        // corresponds to n_s consecutive rows in delta_out.
        let mut d_i_total = Matrix::zeros(tn, 3 * h);
        let mut d_h_total = Matrix::zeros(tn, 3 * h);
        // Site blocks come from the aggregator: stacks are site-major with
        // t-major blocks of T*n_s rows inside.
        let blocks: Vec<usize> =
            if site_rows.is_empty() { vec![n_total] } else { site_rows.to_vec() };
        debug_assert_eq!(blocks.iter().sum::<usize>(), n_total);
        let mut row_n = 0usize; // cursor in delta_out rows
        let mut row_tn = 0usize; // cursor in stack rows
        for &n_s in &blocks {
            let dh_site = dh_last.slice_rows(row_n, row_n + n_s);
            let mut dh = dh_site;
            let mut d_i_blocks = vec![Matrix::zeros(0, 0); t_len];
            let mut d_h_blocks = vec![Matrix::zeros(0, 0); t_len];
            for t in (0..t_len).rev() {
                let lo = row_tn + t * n_s;
                let hi = lo + n_s;
                let st = StepState {
                    h_prev: hp_stack.slice_rows(lo, hi),
                    r: slice_cols(&rzns.slice_rows(lo, hi), 0, h),
                    z: slice_cols(&rzns.slice_rows(lo, hi), h, 2 * h),
                    n: slice_cols(&rzns.slice_rows(lo, hi), 2 * h, 3 * h),
                    s: slice_cols(&rzns.slice_rows(lo, hi), 3 * h, 4 * h),
                };
                let (d_i, d_h, dh_prev) = self.step_backward(&st, &dh);
                d_i_blocks[t] = d_i;
                d_h_blocks[t] = d_h;
                dh = dh_prev;
            }
            for t in 0..t_len {
                copy_rows(&mut d_i_total, row_tn + t * n_s, &d_i_blocks[t]);
                copy_rows(&mut d_h_total, row_tn + t * n_s, &d_h_blocks[t]);
            }
            row_n += n_s;
            row_tn += t_len * n_s;
        }

        let mut entries = vec![
            StatsEntry { w_idx: 0, b_idx: Some(1), a: x_stack.clone(), d: d_i_total },
            StatsEntry { w_idx: 2, b_idx: Some(3), a: hp_stack.clone(), d: d_h_total },
        ];
        for e in cls_entries {
            entries.push(StatsEntry {
                w_idx: e.w_idx + 4,
                b_idx: e.b_idx.map(|b| b + 4),
                a: e.a,
                d: e.d,
            });
        }
        Some(entries)
    }

    fn local_stats_entry_count(&self) -> usize {
        2 + self.fc_layers()
    }

    fn entry_names(&self) -> Vec<String> {
        let mut names = vec![
            format!("gru-input ({}x{})", self.c_in, 3 * self.hidden),
            format!("gru-hidden ({}x{})", self.hidden, 3 * self.hidden),
        ];
        for (i, n) in self.classifier.entry_names().into_iter().enumerate() {
            names.push(format!("fc{}-{}", i + 1, n));
        }
        names
    }
}

fn slice_cols(m: &Matrix, lo: usize, hi: usize) -> Matrix {
    let mut out = Matrix::zeros(m.rows(), hi - lo);
    for i in 0..m.rows() {
        out.row_mut(i).copy_from_slice(&m.row(i)[lo..hi]);
    }
    out
}

fn copy_rows(dst: &mut Matrix, row0: usize, src: &Matrix) {
    for i in 0..src.rows() {
        dst.row_mut(row0 + i).copy_from_slice(src.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::loss::one_hot;

    fn tiny(rng: &mut Rng) -> GruClassifier {
        GruClassifier::new(3, 5, &[7], 4, rng)
    }

    fn seq_batch(rng: &mut Rng, n: usize, t: usize, c_in: usize, classes: usize) -> Batch {
        let xs: Vec<Matrix> = (0..t).map(|_| Matrix::randn(n, c_in, 1.0, rng)).collect();
        let labels: Vec<usize> = (0..n).map(|i| i % classes).collect();
        Batch::Seq { xs, y: one_hot(&labels, classes) }
    }

    /// BPTT statistics must reproduce finite-difference gradients — this
    /// validates the full gate backward derivation.
    #[test]
    fn gru_grads_match_finite_difference() {
        let mut rng = Rng::new(21);
        let gru = tiny(&mut rng);
        let b = seq_batch(&mut rng, 4, 3, 3, 4);
        let stats = gru.local_stats(&b);
        let shapes = gru.param_shapes();
        let grads = stats.assemble_grads(&shapes, 1.0 / 4.0, 1.0);
        let eps = 3e-3f32;
        let loss_of = |m: &GruClassifier| m.local_stats(&b).loss;
        for (pi, g) in grads.iter().enumerate() {
            let (rows, cols) = g.shape();
            for &(i, j) in &[(0usize, 0usize), (rows / 2, cols / 2), (rows - 1, cols - 1)] {
                let mut mp = gru.clone();
                mp.params_mut()[pi][(i, j)] += eps;
                let mut mm = gru.clone();
                mm.params_mut()[pi][(i, j)] -= eps;
                let fd = (loss_of(&mp) - loss_of(&mm)) / (2.0 * eps);
                let an = g[(i, j)];
                assert!(
                    (fd - an).abs() < 3e-2 * (1.0 + an.abs()),
                    "param {pi} ({i},{j}): fd={fd} an={an}"
                );
            }
        }
    }

    /// Stats stacks have T*N rows for the recurrent weights (section 3.5).
    #[test]
    fn stacks_are_time_by_batch() {
        let mut rng = Rng::new(2);
        let gru = tiny(&mut rng);
        let b = seq_batch(&mut rng, 4, 6, 3, 4);
        let stats = gru.local_stats(&b);
        assert_eq!(stats.entries[0].a.shape(), (24, 3)); // x stack
        assert_eq!(stats.entries[0].d.shape(), (24, 15)); // [δr|δz|δn]
        assert_eq!(stats.entries[1].a.shape(), (24, 5)); // h_prev stack
        assert_eq!(stats.aux[0].shape(), (24, 20)); // [r|z|n|s]
    }

    /// edAD recompute on a single site must reproduce local deltas exactly.
    #[test]
    fn edad_single_site_identity() {
        let mut rng = Rng::new(3);
        let gru = tiny(&mut rng);
        let b = seq_batch(&mut rng, 5, 4, 3, 4);
        let stats = gru.local_stats(&b);
        let a_hats: Vec<Matrix> = stats.entries.iter().map(|e| e.a.clone()).collect();
        let d_out = stats.entries.last().unwrap().d.clone();
        let re = gru.edad_recompute(&a_hats, &stats.aux, &d_out, &[5]).unwrap();
        for (i, e) in re.iter().enumerate() {
            let diff = e.d.max_abs_diff(&stats.entries[i].d);
            assert!(diff < 1e-5, "entry {i} mismatch {diff}");
        }
    }

    #[test]
    fn training_reduces_loss() {
        use crate::nn::optimizer::Adam;
        let mut rng = Rng::new(5);
        let mut gru = tiny(&mut rng);
        let b = seq_batch(&mut rng, 12, 4, 3, 4);
        let shapes = gru.param_shapes();
        let mut opt = Adam::new(5e-3, &shapes);
        let first = gru.local_stats(&b).loss;
        for _ in 0..80 {
            let stats = gru.local_stats(&b);
            let grads = stats.assemble_grads(&shapes, 1.0 / 12.0, 1.0);
            let mut params: Vec<Matrix> = gru.params().into_iter().cloned().collect();
            opt.step(&mut params, &grads);
            gru.set_params(&params);
        }
        let last = gru.local_stats(&b).loss;
        assert!(last < first * 0.7, "loss did not drop: {first} -> {last}");
    }

    #[test]
    fn predict_shapes_and_distribution() {
        let mut rng = Rng::new(6);
        let gru = tiny(&mut rng);
        let b = seq_batch(&mut rng, 3, 4, 3, 4);
        let p = gru.predict(&b);
        assert_eq!(p.shape(), (3, 4));
        for i in 0..3 {
            assert!((p.row(i).iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }
}

//! Weight initializers. Sites must initialize identically (the paper seeds
//! every site the same way), so all initializers are driven by the caller's
//! deterministic `Rng`.

use crate::tensor::{Matrix, Rng};

/// He (Kaiming) uniform: U(-sqrt(6/fan_in), +sqrt(6/fan_in)) — matches
/// python/compile/model.py::mlp_init.
pub fn he_uniform(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Matrix {
    let bound = (6.0 / fan_in as f32).sqrt();
    Matrix::rand_uniform(fan_in, fan_out, -bound, bound, rng)
}

/// Xavier/Glorot uniform: U(±sqrt(6/(fan_in+fan_out))) — used for the GRU
/// and transformer projections.
pub fn xavier_uniform(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Matrix {
    let bound = (6.0 / (fan_in + fan_out) as f32).sqrt();
    Matrix::rand_uniform(fan_in, fan_out, -bound, bound, rng)
}

/// Scaled normal init (transformer embeddings / residual projections).
pub fn normal(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Matrix {
    Matrix::randn(rows, cols, std, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn he_bounds_and_spread() {
        let mut rng = Rng::new(9);
        let w = he_uniform(100, 50, &mut rng);
        let bound = (6.0f32 / 100.0).sqrt();
        assert!(w.data().iter().all(|&v| v > -bound && v < bound));
        // Not degenerate.
        assert!(w.fro_norm() > 0.1);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(4);
        let mut r2 = Rng::new(4);
        assert_eq!(he_uniform(10, 10, &mut r1), he_uniform(10, 10, &mut r2));
    }
}
